//! Offline **stub** of the `xla` PJRT bindings.
//!
//! The build container has no XLA/PJRT toolchain, so this crate
//! provides just enough API surface for `floatsd_lstm::runtime` and
//! `floatsd_lstm::coordinator` to type-check under the `pjrt` feature.
//! Every entry point that would touch a real PJRT client returns a
//! descriptive [`Error`] at run time; pure host-side value plumbing
//! ([`Literal`] construction/reshape) works for real so unit tests of
//! the calling code can exercise argument marshalling.
//!
//! To run the actual training stack, repoint the `xla` path dependency
//! in `rust/Cargo.toml` at real PJRT bindings exposing this surface.

use std::fmt;

/// Stub error type (implements `std::error::Error` so `?` converts it
/// into `anyhow::Error` at call sites).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (offline `xla` stub, see vendor/xla); \
         point the `xla` dependency at real PJRT bindings to enable the training runtime"
    )))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor value. The stub stores nothing — construction and
/// reshape succeed (shape bookkeeping only), device round-trips error.
pub struct Literal {
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let old: i64 = self.dims.iter().product();
        let new: i64 = dims.iter().product();
        if old != new {
            return Err(Error(format!("reshape {:?} -> {dims:?}: element count mismatch", self.dims)));
        }
        Ok(Literal { dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Array shape (dims only; the stub carries no element type).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: parsing always errors — there is no HLO
/// parser offline).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub: creation errors).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub: execution errors).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub: readback errors).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping_works() {
        let l = Literal::vec1(&[1f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[4, 4]).is_err());
    }

    #[test]
    fn device_paths_error_descriptively() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline"), "{e}");
    }
}
