//! Minimal, dependency-free stand-in for the `anyhow` crate so the
//! workspace builds fully offline (the vendored registry is not
//! available in the build container).
//!
//! Implements the subset the repo uses: [`Error`], [`Result`], the
//! [`Context`] extension trait on `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a
//! context chain that `{:?}` prints `anyhow`-style ("Caused by:").

use std::fmt;

/// An error with a chain of context messages (newest first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        let mut e = self;
        while let Some(s) = e.source.as_deref() {
            e = s;
        }
        &e.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error` (same as
// real anyhow) so this blanket conversion stays coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = Some(&e);
        while let Some(err) = cur {
            chain.push(err.to_string());
            cur = err.source();
        }
        let mut built: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            built = Some(match built {
                None => Error::msg(msg),
                Some(inner) => inner.context(msg),
            });
        }
        built.unwrap_or_else(|| Error::msg("unknown error"))
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn context_wraps_results_and_options() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(e.root_cause(), "missing file");
        assert!(format!("{e:?}").contains("Caused by:"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("key {} absent", 7)).unwrap_err();
        assert_eq!(e.to_string(), "key 7 absent");
    }

    #[test]
    fn macros_build_errors() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too large: {n}");
            if n == 3 {
                bail!("three is right out (n={})", n);
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out (n=3)");
        assert_eq!(f(11).unwrap_err().to_string(), "n too large: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
