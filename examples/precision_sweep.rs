//! Precision-format explorer: quantization-error statistics of every
//! grid in the paper over several value distributions, plus the
//! group-truncation ablation (paper Fig. 3) and the accumulation-mode
//! comparison (exact-tree vs serial rounding).
//!
//! Run: `cargo run --release --example precision_sweep`

use anyhow::Result;

use floatsd_lstm::formats::{round_f16, round_f8, round_sd8, FLOAT_SD8};
use floatsd_lstm::formats::sd::GenericFloatSd;
use floatsd_lstm::qmath::mac::{mac_exact, mac_serial};
use floatsd_lstm::formats::{FloatSd8, Fp16, Fp8};
use floatsd_lstm::rng::SplitMix64;

fn err_stats(name: &str, q: impl Fn(f32) -> f32, samples: &[f32]) {
    let (mut sum, mut max, mut n) = (0f64, 0f64, 0usize);
    for &x in samples {
        let rel = ((q(x) - x).abs() / x.abs().max(1e-30)) as f64;
        sum += rel;
        max = max.max(rel);
        n += 1;
    }
    println!("  {name:<10} mean rel err {:>9.5}  max rel err {:>9.5}", sum / n as f64, max);
}

fn main() -> Result<()> {
    let mut rng = SplitMix64::new(7);
    for (dist, samples) in [
        ("weights U(-1,1)", (0..20_000).map(|_| rng.uniform(-1.0, 1.0)).collect::<Vec<_>>()),
        ("acts N(0,1)", (0..20_000).map(|_| rng.normal()).collect::<Vec<_>>()),
        ("grads N(0,0.01)", (0..20_000).map(|_| rng.normal() * 0.01).collect::<Vec<_>>()),
    ] {
        println!("{dist}:");
        err_stats("floatsd8", round_sd8, &samples);
        err_stats("fp8", round_f8, &samples);
        err_stats("fp16", round_f16, &samples);
        println!();
    }

    // Fig. 3: truncating the generic FloatSD format to 2 groups
    println!("Fig. 3 — group truncation of the 8×3-digit FloatSD format:");
    let f = GenericFloatSd::fig2_example();
    let groups = vec![4, -2, 1, -1, 2, -4, 1, 1];
    let full = f.mantissa_value(&groups);
    for n in [8usize, 4, 2, 1] {
        let t = f.truncate_groups(&groups, n);
        let v = f.mantissa_value(&t);
        println!(
            "  keep {n} group(s): mantissa {v:>10.6} (err {:.2e}, partial products ≤ {n})",
            (full - v).abs()
        );
    }

    // accumulation-mode divergence rate (exact Wallace tree vs serial)
    println!("\naccumulation modes over 100k random 4-groups:");
    let mut diff = 0usize;
    for _ in 0..100_000 {
        let xs: Vec<Fp8> =
            (0..4).map(|_| Fp8::from_f32((rng.next_f32() - 0.5) * 512.0)).collect();
        let ws: Vec<FloatSd8> =
            (0..4).map(|_| FLOAT_SD8.encode((rng.next_f32() - 0.5) * 4.0)).collect();
        if mac_exact(Fp16::ZERO, &xs, &ws).0 != mac_serial(Fp16::ZERO, &xs, &ws).0 {
            diff += 1;
        }
    }
    println!(
        "  exact-tree vs serial-round differ on {diff}/100000 groups \
         ({:.2}%) — why Fig. 8 adds in carry-save before rounding",
        diff as f64 / 1000.0
    );
    Ok(())
}
