//! **End-to-end driver** (the repo's headline validation run): trains
//! the WikiText-2-like language model under the FP32 baseline AND the
//! paper's modified FloatSD8 scheme (Table VI) on the identical token
//! stream, logging both loss curves — the miniature of paper Fig. 6(d).
//!
//! Run: `cargo run --release --example train_lm -- [epochs [div]]`
//! (default: the standard preset divided by 2). Curves land in
//! `results/curves/*.csv`; the console prints the side-by-side table.
//! The full-scale run is recorded in EXPERIMENTS.md.

use anyhow::Result;

use floatsd_lstm::coordinator::{run_experiment, ExperimentSpec};
use floatsd_lstm::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: Option<usize> = args.get(1).and_then(|s| s.parse().ok());
    let div: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);

    let mut rt = Runtime::new("artifacts")?;
    let mut results = Vec::new();
    for artifact in ["lm_fp32", "lm_fsd8m16"] {
        let mut spec = ExperimentSpec::standard(&rt, artifact, div)?;
        if let Some(e) = epochs {
            spec.preset.epochs = e;
        }
        println!(
            "=== {artifact}: {} epochs × {} steps (batch 32 × seq 32) ===",
            spec.preset.epochs, spec.preset.steps_per_epoch
        );
        let res = run_experiment(&mut rt, &spec)?;
        println!(
            "{artifact}: final ppl {:.2} (best {:.2}) — {} steps in {:.1?} (exec {:.1?}, transfer {:.1?})\n",
            res.final_metric, res.best_metric, res.steps, res.wall,
            res.execute_time, res.transfer_time
        );
        results.push(res);
    }

    println!("epoch | fp32 ppl | fsd8m16 ppl");
    let n = results[0].curve.len().min(results[1].curve.len());
    for e in 0..n {
        println!(
            "{:>5} | {:>8.2} | {:>10.2}",
            e, results[0].curve[e].eval_metric, results[1].curve[e].eval_metric
        );
    }
    let degradation =
        (results[1].final_metric - results[0].final_metric) / results[0].final_metric * 100.0;
    println!(
        "\nFloatSD8(m16) vs FP32 perplexity delta: {degradation:+.1}% \
         (paper Table IV: +3.7% on WikiText-2)"
    );
    println!("curves: results/curves/lm_fp32.csv, results/curves/lm_fsd8m16.csv");
    Ok(())
}
