//! Quickstart: the whole stack in one page.
//!
//! 1. loads the **tiny** AOT artifact (whose matmuls went through the
//!    L1 Pallas qmatmul kernel — `pallas: true` in the manifest),
//! 2. trains it for a few dozen steps from rust via PJRT (no python),
//! 3. saves a checkpoint and reloads it into the pure-rust FloatSD8
//!    inference engine,
//! 4. prints the 4× weight-memory saving.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;

use floatsd_lstm::data::make_source;
use floatsd_lstm::lstm::model::{build_tiny_from_params, ParamBag};
use floatsd_lstm::runtime::{Runtime, TrainSession};
use floatsd_lstm::tensorfile::read_tensors;

fn main() -> Result<()> {
    let mut rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.client.platform_name());

    // -- train the Pallas-kernel artifact ---------------------------------
    let mut session = TrainSession::new(&mut rt, "tiny_fsd8m16")?;
    println!(
        "artifact tiny_fsd8m16 (pallas={}): {} state tensors",
        session.artifact.pallas, session.task.n_state
    );
    let task = session.task.clone();
    let mut src = make_source(
        &task.name, task.batch, &task.x_shape, &task.y_shape,
        task.vocab, task.vocab_tgt, task.n_classes, 2, 1,
    )?;
    for step in 0..60 {
        let m = session.step(&src.next_train())?;
        if step % 10 == 0 {
            println!("step {step:>3}: loss {:.4}  ppl {:.2}", m.mean_loss(), m.perplexity());
        }
    }
    let eval = session.eval(src.eval_set())?;
    println!("eval: loss {:.4}  ppl {:.2}", eval.mean_loss(), eval.perplexity());

    // -- hand the weights to the rust inference engine --------------------
    let ckpt = std::env::temp_dir().join("quickstart.tensors");
    session.save_checkpoint(&ckpt)?;
    let bag = ParamBag::from_tensors(read_tensors(&ckpt)?);
    let engine = build_tiny_from_params(&bag)?;
    let logits = engine.forward(&[3, 1, 4, 1, 5]);
    let next: usize = logits.last().unwrap().iter().enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap();
    println!("engine: argmax next-token after [3,1,4,1,5] = {next}");
    let (sd8, fp32) = engine.weight_bytes();
    println!("engine weight storage: {sd8} B (FloatSD8) vs {fp32} B (FP32) — {}x", fp32 / sd8);
    Ok(())
}
