//! Inference-accelerator demo (paper §V): runs a quantized LSTM layer
//! on the cycle/bit-accurate Fig. 9 unit simulator, shows the batch-
//! size-vs-utilization behaviour (§V-A), and prints the Table VII
//! cost-model comparison.
//!
//! Run: `cargo run --release --example inference_accel`

use anyhow::Result;

use floatsd_lstm::formats::{round_f16, round_f8};
use floatsd_lstm::hardware::cost;
use floatsd_lstm::hardware::lstm_unit::LstmUnit;
use floatsd_lstm::lstm::cell::QLstmCell;
use floatsd_lstm::rng::SplitMix64;

fn main() -> Result<()> {
    let (d, hidden) = (32, 64);
    let mut rng = SplitMix64::new(2020);
    let wx: Vec<f32> = (0..d * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
    let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
    let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let cell = QLstmCell::from_jax_layout(d, hidden, &wx, &wh, &b);

    println!("LSTM unit (Fig. 9): D={d}, H={hidden}, 4 PEs + LUTs + 2 MACs\n");
    println!("batch | PE cycles | elementwise | PE utilization");
    for batch in [1usize, 2, 4, 5, 8, 16] {
        let xs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..d).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect())
            .collect();
        let hs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..hidden).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect())
            .collect();
        let cs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..hidden).map(|_| round_f16(rng.uniform(-1.0, 1.0))).collect())
            .collect();
        let unit = LstmUnit::new(&cell, batch.min(8));
        let (_, _, stats) = unit.step_batch(&xs, &hs, &cs);
        println!(
            "{batch:>5} | {:>9} | {:>11} | {:>6.1}%",
            stats.pe_cycles,
            stats.elementwise_cycles,
            stats.pe_utilization * 100.0
        );
    }
    println!("\n(§V-A: utilization saturates once ≥5 outputs interleave in the 5-stage pipe)");

    let (fp32, fsd8, ar, pr) = cost::table7();
    println!("\nTable VII (40nm @ 400MHz, gate-level cost model):");
    println!("  {:<22} {:>10} {:>10}", "MAC", "area µm²", "power mW");
    println!("  {:<22} {:>10.0} {:>10.3}", fp32.name, fp32.area_um2(), fp32.power_mw());
    println!("  {:<22} {:>10.0} {:>10.3}", fsd8.name, fsd8.area_um2(), fsd8.power_mw());
    println!("  ratio: {ar:.2}x area, {pr:.2}x power (paper: 7.66x, 5.75x)");
    Ok(())
}
