"""L2 model tests: shapes, quantization invariants, trainability,
baseline-vs-quantized equivalences, and the fake-quant gradient paths.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import fq, lstm, optim, precision, tasks
from compile.kernels import quant


def _batch(spec, seed=0, vocab=None):
    rng = np.random.default_rng(seed)
    v = vocab or spec.vocab
    x = jnp.asarray(rng.integers(0, v, (spec.batch, *spec.x_shape)), jnp.int32)
    ymax = spec.n_classes if spec.n_classes else v
    y = jnp.asarray(rng.integers(0, ymax, (spec.batch, *spec.y_shape)), jnp.int32)
    return x, y


# ----------------------------------------------------------------------
# fake-quant machinery
# ----------------------------------------------------------------------


def test_fq_forward_and_backward_grids():
    x = jnp.array([0.3, -1.7, 2.2])
    y, vjp = jax.vjp(lambda v: fq.fq(v, "sd8", "fp8"), x)
    assert np.array_equal(y, quant.floatsd8_round(x))
    g = jnp.array([0.123, -0.456, 7.89])
    (gx,) = vjp(g)
    assert np.array_equal(gx, quant.fp8_round(g))


def test_fq_none_is_identity():
    x = jnp.array([0.3, -1.7])
    assert fq.fq(x, "none", "none") is x


def test_sigmoid_ste_gradient():
    x = jnp.array([0.5, -2.0, 0.0])
    y, vjp = jax.vjp(lambda v: fq.sigmoid_sd8(v, bwd="none"), x)
    assert np.array_equal(y, quant.sigmoid_floatsd8(x))
    (gx,) = vjp(jnp.ones_like(x))
    s = jax.nn.sigmoid(x)
    assert np.allclose(gx, s * (1 - s), atol=1e-6)


def test_tanh_q_gradient():
    x = jnp.array([0.5, -1.0])
    y, vjp = jax.vjp(lambda v: fq.tanh_q(v, fwd="fp8", bwd="none"), x)
    assert np.array_equal(y, quant.fp8_round(jnp.tanh(x)))
    (gx,) = vjp(jnp.ones_like(x))
    assert np.allclose(gx, 1 - jnp.tanh(x) ** 2, atol=1e-6)


# ----------------------------------------------------------------------
# LSTM blocks
# ----------------------------------------------------------------------


def test_lstm_cell_baseline_matches_textbook():
    """With the fp32 config the cell must be a plain LSTM."""
    cfg = precision.fp32()
    key = jax.random.PRNGKey(0)
    p = lstm.init_lstm_cell(key, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    h = jnp.zeros((4, 16))
    c = jnp.zeros((4, 16))
    h1, c1 = lstm.lstm_cell(p, x, h, c, cfg, "none")
    z = x @ p["wx"] + h @ p["wh"] + p["b"]
    zf, zi, zo, zg = jnp.split(z, 4, axis=-1)
    f, i, o = jax.nn.sigmoid(zf), jax.nn.sigmoid(zi), jax.nn.sigmoid(zo)
    c_ref = f * c + i * jnp.tanh(zg)
    h_ref = o * jnp.tanh(c_ref)
    assert np.allclose(h1, h_ref, atol=1e-6)
    assert np.allclose(c1, c_ref, atol=1e-6)


def test_quantized_cell_outputs_on_fp8_grid():
    cfg = precision.paper_original()
    p = lstm.init_lstm_cell(jax.random.PRNGKey(0), 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    h1, c1 = lstm.lstm_cell(p, x, jnp.zeros((4, 16)), jnp.zeros((4, 16)), cfg, "fp8")
    assert np.array_equal(h1, quant.fp8_round(h1)), "h must be on the FP8 grid"
    assert np.array_equal(c1, quant.fp16_round(c1)), "c must be on the FP16 grid"


def test_bilstm_output_shape():
    cfg = precision.fp32()
    p = lstm.init_bilstm(jax.random.PRNGKey(0), 8, 16)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 4, 8))  # [T,B,D]
    hs, (hf, hb) = lstm.bilstm_layer(p, xs, cfg, "none")
    assert hs.shape == (5, 4, 32)
    assert hf.shape == (4, 16) and hb.shape == (4, 16)
    # forward half of step t=0 must not depend on future inputs: perturb
    # the last timestep and check hs[0, :, :16] unchanged.
    xs2 = xs.at[-1].add(10.0)
    hs2, _ = lstm.bilstm_layer(p, xs2, cfg, "none")
    assert np.allclose(hs[0, :, :16], hs2[0, :, :16])
    assert not np.allclose(hs[0, :, 16:], hs2[0, :, 16:])


# ----------------------------------------------------------------------
# Optimizer / master copy
# ----------------------------------------------------------------------


def test_master_copy_fp16_rounding():
    cfg = precision.paper_modified()
    params = {"w": jnp.array([1.0001, -0.12345])}
    grads = {"w": jnp.array([0.1, 0.2])}
    state = optim.sgd_init(params)
    new, _ = optim.sgd_update(params, grads, state, cfg, lr=0.5)
    assert np.array_equal(new["w"], quant.fp16_round(new["w"]))


def test_grad_processing_quantizes_then_unscales():
    cfg = precision.paper_original()  # loss_scale 1024
    g = {"w": jnp.array([1024.0 * 0.111])}
    out = optim.process_grads(g, cfg, clip_norm=None)
    want = quant.fp8_round(jnp.array([1024.0 * 0.111])) / 1024.0
    assert np.array_equal(out["w"], want)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    out = optim._clip_by_global_norm(g, 1.0)
    norm = float(jnp.sqrt(out["a"][0] ** 2 + out["b"][0] ** 2))
    assert abs(norm - 1.0) < 1e-5


def test_adam_moves_params():
    cfg = precision.fp32()
    params = {"w": jnp.ones((4,))}
    state = optim.adam_init(params)
    grads = {"w": jnp.full((4,), 0.5)}
    new, st2 = optim.adam_update(params, grads, state, cfg, lr=0.01)
    assert float(st2["t"]) == 1.0
    assert np.all(np.asarray(new["w"]) < 1.0)


# ----------------------------------------------------------------------
# Whole tasks: one jit step runs, loss finite, training reduces loss
# ----------------------------------------------------------------------


@pytest.mark.parametrize("task", ["pos", "nli", "mt", "lm", "tiny"])
@pytest.mark.parametrize("scheme", ["fp32", "fsd8m16"])
def test_task_one_step(task, scheme):
    cfg = precision.all_schemes()[scheme]
    init_state, train_step, eval_step, spec = tasks.make_steps(task, cfg)
    state = init_state(0)
    x, y = _batch(spec)
    st, loss_sum, metric_sum, count = jax.jit(train_step)(state, x, y)
    assert np.isfinite(float(loss_sum))
    assert float(count) > 0
    ls, ms, c = jax.jit(eval_step)(st, x, y)
    assert np.isfinite(float(ls))
    assert 0.0 <= float(ms) <= float(c)


def test_tiny_training_reduces_loss_both_schemes():
    """A few steps on a learnable deterministic pattern must reduce the
    loss for the FP32 baseline AND the quantized scheme (the paper's
    core claim in miniature)."""
    rng = np.random.default_rng(0)
    spec = tasks.TINY_SPEC
    # next-token pattern: y = (x + 1) mod V on a cyclic sequence
    base = rng.integers(0, spec.vocab, (spec.batch, spec.x_shape[0] + 1))
    base = np.sort(base, axis=1) % spec.vocab
    x = jnp.asarray(base[:, :-1], jnp.int32)
    y = jnp.asarray((base[:, :-1] + 1) % spec.vocab, jnp.int32)
    for scheme in ("fp32", "fsd8m16"):
        cfg = precision.all_schemes()[scheme]
        init_state, train_step, _, _ = tasks.make_steps("tiny", cfg)
        state = init_state(0)
        step = jax.jit(train_step)
        losses = []
        for _ in range(30):
            state, ls, _, cnt = step(state, x, y)
            losses.append(float(ls) / float(cnt))
        assert losses[-1] < losses[0] * 0.9, f"{scheme}: {losses[0]} -> {losses[-1]}"


def test_quantized_weights_reach_matmul_on_sd8_grid():
    """Inside the quantized scheme the effective weights must sit on the
    FloatSD8 grid — check via the dense layer output of a known case."""
    cfg = precision.paper_original()
    p = {"w": jnp.array([[0.3]]), "b": jnp.array([0.0])}
    x = jnp.array([[1.0]])
    y = lstm.qdense(p, x, cfg, act="fp8")
    assert float(y[0, 0]) == float(quant.floatsd8_round(jnp.float32(0.3)))
