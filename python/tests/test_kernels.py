"""Pallas kernels vs the pure-jnp oracle (ref.py) — the core L1 signal.

hypothesis sweeps shapes, block sizes and value distributions; every
assertion is bit-equality (the kernels must implement the *same grid*,
not an approximation).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(shape, seed, scale=4.0):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(-scale, scale, shape).astype(np.float32)
    )


# ----------------------------------------------------------------------
# Elementwise kernels
# ----------------------------------------------------------------------


@given(n=st.integers(1, 300), block=st.sampled_from([16, 64, 256]),
       seed=st.integers(0, 2**16), scale=st.sampled_from([0.01, 1.0, 8.0, 1e4]))
def test_sd8_kernel_matches_ref(n, block, seed, scale):
    x = _rand((n,), seed, scale)
    assert np.array_equal(pk.floatsd8_round_pallas(x, block=block),
                          ref.ref_floatsd8_round(x))


@given(n=st.integers(1, 300), block=st.sampled_from([16, 64, 256]),
       seed=st.integers(0, 2**16), scale=st.sampled_from([1e-5, 1.0, 1e5]))
def test_fp8_kernel_matches_ref(n, block, seed, scale):
    x = _rand((n,), seed, scale)
    assert np.array_equal(pk.fp8_round_pallas(x, block=block), ref.ref_fp8_round(x))


@given(n=st.integers(1, 300), block=st.sampled_from([16, 64]),
       seed=st.integers(0, 2**16))
def test_sigmoid_kernel_matches_ref(n, block, seed):
    x = _rand((n,), seed, 9.0)
    assert np.array_equal(pk.sigmoid_sd8_pallas(x, block=block),
                          ref.ref_sigmoid_sd8(x))


def test_kernels_handle_multidim():
    x = _rand((7, 5, 3), 1)
    assert np.array_equal(pk.floatsd8_round_pallas(x, block=16),
                          ref.ref_floatsd8_round(x))


def test_kernels_handle_specials():
    x = jnp.array([0.0, -0.0, 1e9, -1e9, 4.5, -4.5, 2.0**-20])
    assert np.array_equal(pk.floatsd8_round_pallas(x, block=8),
                          ref.ref_floatsd8_round(x))
    assert np.array_equal(pk.fp8_round_pallas(x, block=8), ref.ref_fp8_round(x))


# ----------------------------------------------------------------------
# qmatmul
# ----------------------------------------------------------------------


@given(
    mnk=st.sampled_from([(16, 16, 16), (32, 64, 32), (64, 32, 64), (8, 8, 8)]),
    blocks=st.sampled_from([(8, 8, 8), (16, 16, 16)]),
    seed=st.integers(0, 2**16),
)
def test_qmatmul_matches_ref(mnk, blocks, seed):
    m, n, k = mnk
    bm, bn, bk = blocks
    if m % bm or n % bn or k % bk:
        return  # skip indivisible combos
    x = _rand((m, k), seed, 2.0)
    w = _rand((k, n), seed + 1, 1.0)
    got = pk.qmatmul_pallas(x, w, bm=bm, bn=bn, bk=bk)
    want = ref.ref_qmatmul(x, w)
    assert np.array_equal(got, want)


def test_qmatmul_multi_k_blocks_accumulate_f32():
    """Accumulation across k blocks must happen in f32 with a single
    fp16 rounding at the end — many small k-blocks must equal one big
    block exactly."""
    x = _rand((16, 64), 3, 2.0)
    w = _rand((64, 16), 4, 1.0)
    one = pk.qmatmul_pallas(x, w, bm=16, bn=16, bk=64)
    many = pk.qmatmul_pallas(x, w, bm=16, bn=16, bk=8)
    assert np.array_equal(one, many)


def test_qmatmul_rejects_indivisible():
    with pytest.raises(AssertionError):
        pk.qmatmul_pallas(_rand((10, 16), 0), _rand((16, 8), 1), bm=4, bn=4, bk=5)


# ----------------------------------------------------------------------
# Fused LSTM gates
# ----------------------------------------------------------------------


@given(n=st.integers(1, 200), block=st.sampled_from([16, 64]),
       seed=st.integers(0, 2**16))
def test_lstm_gates_match_ref(n, block, seed):
    rng = np.random.default_rng(seed)
    zs = [jnp.asarray(rng.uniform(-4, 4, n).astype(np.float32)) for _ in range(4)]
    c = jnp.asarray(rng.uniform(-2, 2, n).astype(np.float32))
    co, ho = pk.lstm_gates_pallas(*zs, c, block=block)
    rco, rho = ref.ref_lstm_gates(*zs, c)
    assert np.array_equal(co, rco)
    assert np.array_equal(ho, rho)


# ----------------------------------------------------------------------
# Static perf model sanity (DESIGN.md §8)
# ----------------------------------------------------------------------


def test_vmem_budget():
    est = pk.perf_estimate(bm=32, bn=64, bk=32)
    assert est["vmem_bytes"] < 4 * 2**20, "tile set must fit VMEM"
    assert 0 < est["mxu_utilization"] <= 1
