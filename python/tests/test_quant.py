"""Unit + property tests for the quantization grids (L2 semantics).

These pin the jnp implementations; the equivalence with the bit-exact
rust formats is checked on the rust side via the golden vectors.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant


# ----------------------------------------------------------------------
# FloatSD8
# ----------------------------------------------------------------------


def test_sd8_grid_shape():
    assert quant.SD8_MANTISSAS.shape == (31,)
    assert quant.SD8_VALUES.shape == (129,)
    assert quant.SD8_MAX == 4.5
    assert quant.SD8_MIN_POSITIVE == 0.25 * 2.0**-7


def test_sd8_mantissas_match_paper_construction():
    # every mantissa must be g0 + g1/4 with legal SD groups
    legal = set()
    for g0 in (-4, -2, -1, 0, 1, 2, 4):
        for g1 in (-2, -1, 0, 1, 2):
            legal.add(g0 + g1 / 4.0)
    assert set(quant.SD8_MANTISSAS.tolist()) == legal
    assert len(legal) == 31


def test_sd8_grid_symmetric():
    v = quant.SD8_VALUES
    assert np.array_equal(v, -v[::-1])


def test_sd8_round_fixpoints():
    v = jnp.asarray(quant.SD8_VALUES)
    assert np.array_equal(quant.floatsd8_round(v), v)


def test_sd8_round_saturates():
    x = jnp.array([1e9, -1e9, 100.0, -7.0])
    assert np.array_equal(quant.floatsd8_round(x), jnp.array([4.5, -4.5, 4.5, -4.5]))


def test_sd8_nan_to_zero():
    assert float(quant.floatsd8_round(jnp.array([jnp.nan]))[0]) == 0.0


def test_sd8_ties_away_from_zero():
    v = quant.SD8_VALUES_F64
    mids = 0.5 * (v[:-1] + v[1:])
    got = np.asarray(quant.floatsd8_round(jnp.asarray(mids, jnp.float32)))
    for m, g, lo, hi in zip(mids, got, v[:-1], v[1:]):
        m32 = np.float32(m)
        if m32 != m:  # not an exact f32 midpoint; just check nearest-ness
            continue
        expect = hi if m >= 0 else lo
        assert g == np.float32(expect), f"tie at {m}: got {g} want {expect}"


@settings(max_examples=300, deadline=None)
@given(st.floats(-10, 10, allow_nan=False, width=32))
def test_sd8_round_is_nearest(x):
    q = float(quant.floatsd8_round(jnp.float32(x)))
    dists = np.abs(quant.SD8_VALUES_F64 - float(np.float32(x)))
    assert abs(abs(q - np.float32(x)) - dists.min()) <= 1e-12


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e6, 1e6, allow_nan=False, width=32))
def test_sd8_idempotent(x):
    q1 = quant.floatsd8_round(jnp.float32(x))
    assert float(quant.floatsd8_round(q1)) == float(q1)


# ----------------------------------------------------------------------
# FP8
# ----------------------------------------------------------------------


def _fp8_grid():
    """All non-negative fp8 values by direct construction."""
    vals = [0.0]
    for m in range(4):  # subnormals
        vals.append(m * 2.0**-16)
    for e in range(1, 32):
        for m in range(4):
            vals.append((1 + m / 4.0) * 2.0 ** (e - 15))
    return np.unique(np.array(vals, dtype=np.float32))


def test_fp8_fixpoints():
    g = _fp8_grid()
    got = np.asarray(quant.fp8_round(jnp.asarray(g)))
    assert np.array_equal(got, g)


def test_fp8_saturation():
    x = jnp.array([1e9, -1e9, 120000.0, jnp.inf, -jnp.inf])
    got = np.asarray(quant.fp8_round(x))
    assert np.array_equal(
        got, np.array([114688.0, -114688.0, 114688.0, 114688.0, -114688.0], np.float32)
    )


def test_fp8_subnormals():
    ulp = 2.0**-16
    x = jnp.array([ulp, 2 * ulp, 3 * ulp, 0.4 * ulp, 0.6 * ulp])
    got = np.asarray(quant.fp8_round(x))
    assert np.array_equal(got, np.array([ulp, 2 * ulp, 3 * ulp, 0.0, ulp], np.float32))


def test_fp8_rne_ties():
    # 1.125 is halfway between 1.0 (even mantissa) and 1.25 -> 1.0
    assert float(quant.fp8_round(jnp.float32(1.125))) == 1.0
    # 1.375 halfway between 1.25 and 1.5 (even) -> 1.5
    assert float(quant.fp8_round(jnp.float32(1.375))) == 1.5


@settings(max_examples=300, deadline=None)
@given(st.floats(-120000, 120000, allow_nan=False, width=32))
def test_fp8_is_nearest_on_grid(x):
    g = _fp8_grid()
    q = float(quant.fp8_round(jnp.float32(x)))
    a = abs(np.float32(x))
    best = np.abs(g - a).min()
    assert abs(abs(q) - a) <= best * (1 + 1e-6) + 1e-12


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e5, 1e5, allow_nan=False, width=32))
def test_fp8_stochastic_brackets(x):
    """Stochastic rounding must land on one of the two bracketing grid
    points (or the saturation value)."""
    g = _fp8_grid()
    q = float(quant.fp8_round_stochastic(jnp.float32(x)))
    a = abs(np.float32(x))
    lo = g[g <= a].max() if (g <= a).any() else 0.0
    hi = g[g >= a].min() if (g >= a).any() else g.max()
    assert abs(q) in (lo, hi)


# ----------------------------------------------------------------------
# FP16
# ----------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.floats(-60000, 60000, allow_nan=False, width=32))
def test_fp16_matches_numpy(x):
    got = float(quant.fp16_round(jnp.float32(x)))
    want = float(np.float32(np.float16(np.float32(x))))
    assert got == want


# ----------------------------------------------------------------------
# Quantized sigmoid (Eq. 7/8)
# ----------------------------------------------------------------------


def test_sigmoid_two_region_symmetry():
    """Eq. 7/8 imply q(x) + q(-x) == 1 exactly."""
    x = jnp.linspace(-8, 8, 4001)
    q = np.asarray(quant.sigmoid_floatsd8(x))
    qr = np.asarray(quant.sigmoid_floatsd8(-x))
    assert np.allclose(q + qr, 1.0, atol=0)


def test_sigmoid_values_on_grid_for_nonpositive():
    x = jnp.linspace(-10, 0, 1001)
    q = np.asarray(quant.sigmoid_floatsd8(x))
    grid = set(quant.SD8_VALUES.tolist())
    assert all(v in grid for v in q)


def test_sigmoid_lut_entry_count():
    """The paper claims 42 distinct quantized σ outputs for x ≤ 0; the
    exact count depends on the (unspecified) exponent bias — with bias 7
    the enumeration gives the LUT size we pin here and report in
    EXPERIMENTS.md."""
    # σ over x<=0 spans (0, 0.5]; count distinct grid points hit
    x = jnp.linspace(-30, 0, 200001)
    q = np.unique(np.asarray(quant.sigmoid_floatsd8(x)))
    # all values in (0, 0.5] on the sd8 grid, plus nothing else
    grid = quant.SD8_VALUES_F64
    expect = np.unique(
        np.concatenate([[0.0], grid[(grid > 0) & (grid <= 0.5)]])
    ).astype(np.float32)
    assert set(q.tolist()) <= set(expect.tolist())
    # the reachable LUT (excluding the asymptotic 0) — pinned count:
    assert len(q) == len(expect), (len(q), len(expect))


def test_sigmoid_monotone_nondecreasing():
    x = jnp.linspace(-9, 9, 2001)
    q = np.asarray(quant.sigmoid_floatsd8(x))
    assert np.all(np.diff(q) >= 0)


def test_one_region_error_is_asymmetric():
    """Fig. 4's point: single-region quantization error does not decay
    for positive inputs (the grid is log-spaced around 0, not around 1),
    while the two-region scheme's error vanishes as σ saturates."""
    x = np.linspace(2, 8, 1000, dtype=np.float32)
    s = 1 / (1 + np.exp(-x))
    err_pos = np.abs(np.asarray(quant.sigmoid_floatsd8_one_region(jnp.asarray(x))) - s)
    err_two = np.abs(np.asarray(quant.sigmoid_floatsd8(jnp.asarray(x))) - s)
    assert err_pos.mean() > 5 * err_two.mean()
    # and on the negative side the two coincide by construction
    xn = -x
    a = np.asarray(quant.sigmoid_floatsd8_one_region(jnp.asarray(xn)))
    b = np.asarray(quant.sigmoid_floatsd8(jnp.asarray(xn)))
    assert np.array_equal(a, b)
