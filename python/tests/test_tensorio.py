"""Round-trip tests for the .tensors interchange format."""

import numpy as np
import pytest

from compile import tensorio


def test_round_trip(tmp_path):
    p = str(tmp_path / "t.tensors")
    tensors = [
        ("a", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b/nested", np.array([-1, 0, 7], dtype=np.int32)),
        ("scalar", np.float32(3.5).reshape(())),
        ("empty_name_ok", np.zeros((0,), np.float32)),
    ]
    tensorio.write_tensors(p, tensors)
    back = tensorio.read_tensors(p)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, want), (_, got) in zip(tensors, back):
        assert want.dtype == got.dtype
        assert want.shape == got.shape
        assert np.array_equal(want, got)


def test_dtype_coercion(tmp_path):
    p = str(tmp_path / "t.tensors")
    tensorio.write_tensors(p, [("x", np.array([1.5], np.float64)),
                               ("y", np.array([2], np.int64))])
    back = dict(tensorio.read_tensors(p))
    assert back["x"].dtype == np.float32
    assert back["y"].dtype == np.int32


def test_rejects_unsupported(tmp_path):
    with pytest.raises(TypeError):
        tensorio.write_tensors(str(tmp_path / "t.tensors"),
                               [("x", np.array(["s"], dtype=object))])
