"""L2 — quantized LSTM building blocks (paper Eq. 1-6 with §III hooks).

Every block takes a :class:`..precision.PrecisionConfig`; with the fp32
baseline config every quantizer is the identity and this file reduces to
a vanilla LSTM, so *one* code path produces both curves in Fig. 6.

Where each precision knob lands (paper Table II/VI):

* ``cfg.weights`` — every weight matrix entering a matmul (Eq. 1-4
  and all dense layers);
* ``cfg.activations`` / ``first_layer_acts`` / ``last_layer_acts`` —
  quantize the *inputs* of matmuls (forward) and their cotangents
  (backward = the paper's "backward activations");
* ``cfg.sigmoid`` — gates f, i, o via the two-region FloatSD8 σ;
* ``cfg.accum`` — FP16 rounding at every dot-product output and at the
  cell-state accumulation (Eq. 5);
* ``cfg.gradients`` — cotangent grid (see also ``optim.py`` for the
  weight-gradient quantization at the update).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fq
from .precision import PrecisionConfig


def _grad_name(cfg: PrecisionConfig) -> str:
    if cfg.gradients == "fp8" and cfg.stochastic_gradients:
        return "fp8sr"
    return cfg.gradients


#: when True (set by aot.py for the quickstart/tiny artifacts), quantized
#: matmuls are lowered through the L1 Pallas qmatmul kernel so the full
#: L1→L2→L3 composition is exercised; the jnp path is numerically
#: identical (pytest pins kernel == ref) and lowers to leaner HLO for the
#: larger experiment artifacts. See DESIGN.md §2.
USE_PALLAS_MATMUL = False


def _auto_blocks(m: int, n: int, k: int):
    """Largest power-of-two-ish divisors ≤ (32, 64, 32) for exact tiling."""

    def best(dim, cap):
        d = min(dim, cap)
        while dim % d:
            d -= 1
        return d

    return best(m, 32), best(n, 64), best(k, 32)


@jax.custom_vjp
def _pallas_qmatmul_2d(x, w):
    from .kernels import pallas_kernels

    bm, bn, bk = _auto_blocks(x.shape[0], w.shape[1], x.shape[1])
    return pallas_kernels.qmatmul_pallas(x, w, bm=bm, bn=bn, bk=bk)


def _pallas_qmatmul_fwd(x, w):
    from .kernels import quant

    return _pallas_qmatmul_2d(x, w), (quant.fp8_round(x), quant.floatsd8_round(w))


def _pallas_qmatmul_bwd(res, g):
    # Mirrors the autodiff of the jnp path: STE through the fp16 output
    # rounding; cotangents flow through the quantized operands. The fp8
    # quantization of the activation cotangent is applied by the
    # enclosing fq hook, exactly as in the jnp path.
    from .kernels import quant

    xq, wq = res
    return g @ wq.T, xq.T @ g


_pallas_qmatmul_2d.defvjp(_pallas_qmatmul_fwd, _pallas_qmatmul_bwd)


def qmatmul(xq, wq, cfg: PrecisionConfig):
    """Quantized matmul with the FP16 accumulation boundary.

    Inputs are already fake-quantized by the caller; the Pallas path
    re-quantizes in-kernel (idempotent, bit-identical).
    """
    if (
        USE_PALLAS_MATMUL
        and cfg.accum == "fp16"
        and cfg.weights == "sd8"
        and cfg.activations == "fp8"
    ):
        shape = xq.shape
        x2d = xq.reshape(-1, shape[-1])
        y = _pallas_qmatmul_2d(x2d, wq)
        return y.reshape(*shape[:-1], wq.shape[1])
    return fq.fq(xq @ wq, cfg.accum, "none")


def acc_round(x, cfg: PrecisionConfig):
    """The paper's FP16 accumulation boundary."""
    return fq.fq(x, cfg.accum, "none")


def quantize_weight(w, cfg: PrecisionConfig):
    """FloatSD8 weight quantization with straight-through gradient
    (gradient flows unchanged to the master copy; the master copy itself
    is rounded in optim.py)."""
    return fq.fq(w, cfg.weights, "none")


def qdense(p, x, cfg: PrecisionConfig, act: str):
    """Quantized dense layer: y = round_acc(fq(x) @ Q(w) + b).

    ``act`` is the activation grid for this layer's *input* ('fp8',
    'fp16' or 'none' — callers pass cfg.activations / first / last as
    appropriate).
    """
    xq = fq.fq(x, act, _grad_name(cfg))
    wq = quantize_weight(p["w"], cfg)
    b = fq.fq(p["b"], "fp16" if cfg.accum == "fp16" else "none", "none")
    return qmatmul(xq, wq, cfg) + b


def lstm_cell(p, x, h, c, cfg: PrecisionConfig, x_act: str):
    """One LSTM step (Eq. 1-6) under the precision config.

    ``x_act`` is the grid of the incoming activation `x` (first layer
    uses cfg.first_layer_acts, stacked layers use cfg.activations).
    Weights are packed as wx [D, 4H], wh [H, 4H], b [4H] in gate order
    (f, i, o, g) — one fused matmul per input, like cuDNN/paper Fig. 7's
    four PEs fed from the same input registers.
    """
    g = _grad_name(cfg)
    xq = fq.fq(x, x_act, g)
    hq = fq.fq(h, cfg.activations, g)
    wx = quantize_weight(p["wx"], cfg)
    wh = quantize_weight(p["wh"], cfg)
    b = fq.fq(p["b"], "fp16" if cfg.accum == "fp16" else "none", "none")
    z = qmatmul(xq, wx, cfg) + qmatmul(hq, wh, cfg) + b
    zf, zi, zo, zg = jnp.split(z, 4, axis=-1)

    if cfg.sigmoid == "sd8":
        f = fq.sigmoid_sd8(zf, bwd=g)
        i = fq.sigmoid_sd8(zi, bwd=g)
        o = fq.sigmoid_sd8(zo, bwd=g)
    else:
        f = jax.nn.sigmoid(zf)
        i = jax.nn.sigmoid(zi)
        o = jax.nn.sigmoid(zo)
    gg = fq.tanh_q(zg, fwd=cfg.activations, bwd=g)

    c_new = acc_round(f * c + i * gg, cfg)
    tc = fq.tanh_q(c_new, fwd=cfg.activations, bwd=g)
    h_new = fq.fq(o * tc, cfg.activations, g)
    return h_new, c_new


def lstm_layer(p, xs, cfg: PrecisionConfig, x_act: str, reverse: bool = False):
    """Run a unidirectional LSTM over ``xs`` [T, B, D] → hs [T, B, H]."""
    hdim = p["wh"].shape[0]
    bsz = xs.shape[1]
    h0 = jnp.zeros((bsz, hdim), xs.dtype)
    c0 = jnp.zeros((bsz, hdim), xs.dtype)

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell(p, x, h, c, cfg, x_act)
        return (h, c), h

    (h_last, c_last), hs = jax.lax.scan(step, (h0, c0), xs, reverse=reverse)
    return hs, (h_last, c_last)


def bilstm_layer(p, xs, cfg: PrecisionConfig, x_act: str):
    """Bidirectional layer: concat of forward and backward passes.

    ``p`` = {'fwd': cell-params, 'bwd': cell-params}; output [T, B, 2H].
    """
    hs_f, (hf, _) = lstm_layer(p["fwd"], xs, cfg, x_act, reverse=False)
    hs_b, (hb, _) = lstm_layer(p["bwd"], xs, cfg, x_act, reverse=True)
    return jnp.concatenate([hs_f, hs_b], axis=-1), (hf, hb)


def embedding(p, ids, cfg: PrecisionConfig):
    """Embedding lookup; outputs are the paper's "first layer"
    activations (the embedding *inputs* are just indices — §IV-B(a))."""
    e = jnp.take(p["emb"], ids, axis=0)
    return fq.fq(e, cfg.first_layer_acts, _grad_name(cfg))


def output_logits(p, x, cfg: PrecisionConfig):
    """Output (last) layer: dense fed by hidden activations; its
    activations (the logits) live on cfg.last_layer_acts."""
    y = qdense(p, x, cfg, act=cfg.activations)
    return fq.fq(y, cfg.last_layer_acts, _grad_name(cfg))


# ----------------------------------------------------------------------
# Parameter initialisation (PyTorch-style, matching the paper's claim of
# "common weight initialization methods without modification" §III-B)
# ----------------------------------------------------------------------


def init_lstm_cell(key, in_dim: int, hidden: int, dtype=jnp.float32):
    """U(-1/sqrt(H), 1/sqrt(H)) — torch.nn.LSTM default."""
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k1, (in_dim, 4 * hidden), dtype, -s, s),
        "wh": jax.random.uniform(k2, (hidden, 4 * hidden), dtype, -s, s),
        "b": jax.random.uniform(k3, (4 * hidden,), dtype, -s, s),
    }


def init_bilstm(key, in_dim: int, hidden: int, dtype=jnp.float32):
    kf, kb = jax.random.split(key)
    return {
        "fwd": init_lstm_cell(kf, in_dim, hidden, dtype),
        "bwd": init_lstm_cell(kb, in_dim, hidden, dtype),
    }


def init_dense(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    """Kaiming-uniform fan-in (torch.nn.Linear default)."""
    k1, k2 = jax.random.split(key)
    s = 1.0 / jnp.sqrt(in_dim)
    return {
        "w": jax.random.uniform(k1, (in_dim, out_dim), dtype, -s, s),
        "b": jax.random.uniform(k2, (out_dim,), dtype, -s, s),
    }


def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    """N(0, 0.1) embeddings (kept modest so FP8 covers the range)."""
    return {"emb": 0.1 * jax.random.normal(key, (vocab, dim), dtype)}
