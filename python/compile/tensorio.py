"""Tensor-file interchange between the python compile path and rust.

A deliberately tiny binary format (``.tensors``) both sides implement
from scratch (rust: ``rust/src/tensorfile``):

    magic  b"TSF1"
    u32    n_tensors                      (little-endian throughout)
    repeat n_tensors times:
        u16  name_len ; name (utf-8)
        u8   dtype    (0 = f32, 1 = i32)
        u8   ndim
        u32  dims[ndim]
        raw  data (C order, little-endian)

Used for: initial model/optimizer state (``<task>.init.tensors``),
golden vectors pinning jnp quantizers to the bit-exact rust formats,
and checkpoints written back by the rust coordinator.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TSF1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.dtype(np.float32), 1: np.dtype(np.int32)}


def write_tensors(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    """Write named arrays (f32/i32 only) to ``path``."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            # NB: not ascontiguousarray — it promotes 0-d arrays to 1-d;
            # tobytes() below already emits C order for any layout.
            arr = np.asarray(arr)
            if arr.dtype not in DTYPES:
                if arr.dtype in (np.float64, np.float16):
                    arr = arr.astype(np.float32)
                elif arr.dtype in (np.int64, np.uint32, np.int8, np.uint8):
                    arr = arr.astype(np.int32)
                else:
                    raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_tensors(path: str) -> list[tuple[str, np.ndarray]]:
    """Read a ``.tensors`` file (round-trip of :func:`write_tensors`)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            dtype_code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = DTYPES_INV[dtype_code]
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(count * dtype.itemsize), dtype=dtype)
            out.append((name, data.reshape(dims).copy()))
    return out
