"""Pure-jnp oracle for every Pallas kernel (the CORE correctness signal).

Each ``ref_*`` function defines the mathematically-intended result of the
corresponding kernel in ``pallas_kernels.py``; pytest
(``python/tests/test_kernels.py``) asserts allclose/bit-equality across a
hypothesis sweep of shapes and value distributions.

The oracle itself is pinned to the bit-exact rust implementation through
the golden vectors (``aot.py --golden``), closing the loop:

    rust formats  ==golden==  ref.py  ==pytest==  pallas kernels
"""

from __future__ import annotations

import jax.numpy as jnp

from . import quant


def ref_floatsd8_round(x):
    """FloatSD8 round-to-nearest (ties away from zero)."""
    return quant.floatsd8_round(x)


def ref_fp8_round(x):
    """FP8 (1-5-2) RNE with subnormals + saturation."""
    return quant.fp8_round(x)


def ref_fp16_round(x):
    """IEEE binary16 RNE."""
    return quant.fp16_round(x)


def ref_sigmoid_sd8(x):
    """Two-region FloatSD8-quantized sigmoid (paper Eq. 7/8)."""
    return quant.sigmoid_floatsd8(x)


def ref_qmatmul(x, w):
    """Quantized matmul: the paper's forward-pass GEMM semantics.

    ``x`` is rounded to FP8, ``w`` to FloatSD8, the product is
    accumulated and the result rounded to the FP16 grid (the paper's
    FP16-accumulation boundary, modeled at the dot output — see
    DESIGN.md §6 for the fidelity note; per-add rounding is validated
    separately by the rust hardware simulator).
    """
    xq = quant.fp8_round(x)
    wq = quant.floatsd8_round(w)
    acc = jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32))
    return quant.fp16_round(acc).astype(x.dtype)


def ref_lstm_gates(z_f, z_i, z_o, z_g, c_prev):
    """The quantized elementwise half of an LSTM cell (paper Eq. 5/6).

    σ-gates are FloatSD8-quantized (two-region), the cell gate uses
    tanh rounded to FP8, the cell state and output accumulate on the
    FP16 grid, and h is re-quantized to FP8 (activation precision).

    The incoming cell state is architecturally FP16 (it is the output
    of the previous step's FP16 accumulation), so we round it to the
    grid at entry. This also makes every product below exactly
    representable in f32 (≤ 11+11 significant bits), so the result is
    independent of FMA/fusion choices — bit-stable across backends.
    """
    c_prev = quant.fp16_round(c_prev)
    f = quant.sigmoid_floatsd8(z_f)
    i = quant.sigmoid_floatsd8(z_i)
    o = quant.sigmoid_floatsd8(z_o)
    g = quant.fp8_round(jnp.tanh(z_g))
    c = quant.fp16_round(f * c_prev + i * g)
    h = quant.fp8_round(o * quant.fp8_round(jnp.tanh(c)))
    return c, h
