"""Quantization grids shared by the L1 Pallas kernels and the L2 model.

These are the pure-jnp *definitions* of the paper's number formats; the
rust crate implements the same grids bit-exactly in
``rust/src/formats/`` and the two are pinned together by the golden
vectors written by ``aot.py`` (checked by ``rust/tests/golden_formats.rs``).

Formats (paper Table II / VI):

* **FloatSD8** (weights ``w``, quantized sigmoid outputs ``s``): 3-bit
  exponent (bias 7) + 31-value SD mantissa codebook ``g0 + g1/4`` with
  ``g0 in {0,±1,±2,±4}``, ``g1 in {0,±1,±2}``. 129 distinct values.
  Round to nearest, ties away from zero (hardware midpoint compare).
* **FP8 (1-5-2)** (gradients ``g``, activations ``a``): bias 15,
  subnormals, RNE, saturating at ±114688 [Wang et al., NeurIPS 2018].
* **FP16** (master copy ``m``, last-layer activations ``o``, and *all
  accumulations*): IEEE binary16 RNE via numpy's float16.

Everything here is traceable (no python branching on values), so the
same functions run inside jax.jit, lax.scan, custom_vjp and Pallas
(interpret mode).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# FloatSD8 grid construction (mirrors rust formats::floatsd)
# ----------------------------------------------------------------------

SD8_EXP_BIAS = 7
SD8_EXP_LEVELS = 8


def _sd8_mantissas() -> np.ndarray:
    """The 31 distinct mantissa values g0 + g1/4, ascending."""
    vals = set()
    for g0 in (-4, -2, -1, 0, 1, 2, 4):
        for g1 in (-2, -1, 0, 1, 2):
            vals.add(g0 * 4 + g1)  # in units of 1/4
    return np.array(sorted(v / 4.0 for v in vals), dtype=np.float64)


def _sd8_values() -> np.ndarray:
    """All distinct representable FloatSD8 values, ascending (129)."""
    m = _sd8_mantissas()
    vals = set()
    for e in range(SD8_EXP_LEVELS):
        for mv in m:
            vals.add(float(mv) * 2.0 ** (e - SD8_EXP_BIAS))
    return np.array(sorted(vals), dtype=np.float64)


SD8_MANTISSAS = _sd8_mantissas()
SD8_VALUES_F64 = _sd8_values()
#: the FloatSD8 grid as f32 (every entry is exactly representable)
SD8_VALUES = SD8_VALUES_F64.astype(np.float32)
#: midpoints between consecutive grid values (exact in f32: dyadic)
SD8_MIDPOINTS = (0.5 * (SD8_VALUES_F64[:-1] + SD8_VALUES_F64[1:])).astype(np.float32)
SD8_MAX = float(SD8_VALUES[-1])  # 4.5
SD8_MIN_POSITIVE = float(SD8_VALUES[SD8_VALUES > 0][0])  # 0.25 * 2^-7


def floatsd8_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest FloatSD8 value, ties away from zero.

    NaN maps to 0 (mirrors rust). Implemented with two searchsorted
    passes so the tie direction depends on the operand sign, exactly
    like the hardware midpoint comparator.
    """
    x32 = x.astype(jnp.float32)
    mids = jnp.asarray(SD8_MIDPOINTS)
    grid = jnp.asarray(SD8_VALUES)
    idx_pos = jnp.searchsorted(mids, x32, side="right")
    idx_neg = jnp.searchsorted(mids, x32, side="left")
    idx = jnp.where(x32 >= 0, idx_pos, idx_neg)
    out = grid[jnp.clip(idx, 0, grid.shape[0] - 1)]
    return jnp.where(jnp.isnan(x32), jnp.float32(0.0), out).astype(x.dtype)


# ----------------------------------------------------------------------
# FP8 (1-5-2)
# ----------------------------------------------------------------------

F8_BIAS = 15
F8_MAX = 1.75 * 65536.0  # 114688 = (1 + 3/4) * 2^16
F8_MIN_NORMAL_EXP = -14  # value exponent of the smallest normal
F8_SUBNORMAL_ULP = 2.0 ** -16


def _exact_pow2(e: jnp.ndarray) -> jnp.ndarray:
    """2**e for integer e in [-126, 127], *exact* (XLA's exp2 lowers to
    exp(e·ln2) which is off by ulps — fatal for grid construction)."""
    bits = ((e + 127) << 23).astype(jnp.int32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def fp8_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the FP8 (1-5-2) grid: RNE, subnormals, saturating.

    Grid spacing for a value with exponent E (value in [2^E, 2^(E+1)))
    is 2^(E-2); below 2^-14 the spacing is the fixed subnormal ulp
    2^-16. ``jnp.round`` is round-half-to-even, matching the rust RNE.
    NaN saturates to +max (mirrors rust).
    """
    x32 = x.astype(jnp.float32)
    a = jnp.abs(x32)
    # frexp: a = f * 2^e with f in [0.5, 1)  =>  value exponent E = e - 1.
    _, e = jnp.frexp(jnp.where(a > 0, a, jnp.float32(1.0)))
    value_exp = e.astype(jnp.int32) - 1
    ulp_exp = jnp.maximum(value_exp, F8_MIN_NORMAL_EXP) - 2
    ulp = _exact_pow2(ulp_exp)
    q = jnp.round(a / ulp) * ulp
    q = jnp.minimum(q, jnp.float32(F8_MAX))
    q = jnp.where(a == 0, jnp.float32(0.0), q)
    q = jnp.where(jnp.isnan(x32), jnp.float32(F8_MAX), q * jnp.sign(x32) + 0.0)
    # note: q * sign(x) keeps signed zeros out (we use +0 uniformly)
    return q.astype(x.dtype)


# ----------------------------------------------------------------------
# FP16
# ----------------------------------------------------------------------


def fp16_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the IEEE binary16 grid (RNE) and back to f32."""
    return x.astype(jnp.float16).astype(x.dtype)


# ----------------------------------------------------------------------
# Two-region quantized sigmoid / activation quantizers (paper §III-C)
# ----------------------------------------------------------------------


def sigmoid_floatsd8(x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (7)/(8): ``Q(σ(x))`` for x ≤ 0 and ``1 − Q(σ(−x))`` for x > 0.

    The positive branch is *exactly* the value the hardware computes as
    the two-FloatSD8 pair ``(+1, −Q(σ(−x)))`` summed in the MAC; here we
    return the summed scalar because the MAC consumes the pair natively.
    """
    s = jnp.float32(1.0) / (jnp.float32(1.0) + jnp.exp(-jnp.abs(x.astype(jnp.float32))))
    # σ(-|x|) = 1 - σ(|x|)
    q_neg = floatsd8_round(jnp.float32(1.0) - s)  # = Q(sigma(-|x|))
    out = jnp.where(x <= 0, q_neg, jnp.float32(1.0) - q_neg)
    return out.astype(x.dtype)


def sigmoid_floatsd8_one_region(x: jnp.ndarray) -> jnp.ndarray:
    """Fig. 4's strawman: apply Q(σ(x)) over the whole input range.

    Only used to regenerate the paper's Fig. 4 error plot and the
    ablation bench; training always uses the two-region version.
    """
    s = jnp.float32(1.0) / (jnp.float32(1.0) + jnp.exp(-x.astype(jnp.float32)))
    return floatsd8_round(s).astype(x.dtype)


def fp8_round_stochastic(x: jnp.ndarray) -> jnp.ndarray:
    """FP8 with *bit-reuse* stochastic rounding (extension ablation).

    The paper rejected stochastic rounding for hardware complexity
    (§III-D); we implement it deterministically — the random threshold is
    a hash of the operand's own low mantissa bits, so the op stays pure
    and AOT-compilable (no RNG key plumbing through the artifact).
    """
    x32 = x.astype(jnp.float32)
    a = jnp.abs(x32)
    _, e = jnp.frexp(jnp.where(a > 0, a, jnp.float32(1.0)))
    value_exp = e.astype(jnp.int32) - 1
    ulp_exp = jnp.maximum(value_exp, F8_MIN_NORMAL_EXP) - 2
    ulp = _exact_pow2(ulp_exp)
    scaled = a / ulp
    lo = jnp.floor(scaled)
    frac = scaled - lo
    # integer hash of the raw bits -> uniform threshold in [0, 1)
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    h = bits * jnp.uint32(0x9E3779B9)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    thresh = (h >> 8).astype(jnp.float32) * jnp.float32(2.0**-24)
    q = (lo + (frac > thresh).astype(jnp.float32)) * ulp
    q = jnp.minimum(q, jnp.float32(F8_MAX))
    q = jnp.where(a == 0, jnp.float32(0.0), q)
    q = jnp.where(jnp.isnan(x32), jnp.float32(F8_MAX), q * jnp.sign(x32) + 0.0)
    return q.astype(x.dtype)


QUANTIZERS = {
    "none": lambda x: x,
    "fp8": fp8_round,
    "fp8sr": fp8_round_stochastic,
    "fp16": fp16_round,
    "sd8": floatsd8_round,
}


def get_quantizer(name: str):
    """Look up a quantizer by config name ('none'|'fp8'|'fp16'|'sd8')."""
    return QUANTIZERS[name]
