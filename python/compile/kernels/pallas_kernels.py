"""L1 — Pallas kernels for the FloatSD8 hot paths.

All kernels are authored TPU-shaped (BlockSpec-tiled for VMEM, branch-free
vector code for the VPU, MXU-sized matmul tiles) but are **lowered with
``interpret=True``**: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode turns each kernel into plain HLO that any
backend runs. Correctness is the contract here (pytest vs ``ref.py``);
real-TPU performance is *estimated* in DESIGN.md §8 from the BlockSpec
VMEM footprints.

Hardware-adaptation notes (paper ASIC → TPU, DESIGN.md §3):

* the ASIC's "≤2 partial products per weight" becomes a branch-free
  **midpoint-rank quantizer**: rank = Σ (x ≥ midpoint) over the 128-entry
  midpoint table, then a one-hot contraction against the 129-entry value
  grid — no gathers, no sorts, pure VPU compare/add. The tables ride
  into VMEM as broadcast operands (every grid step maps block 0), the
  Pallas analogue of pinning a small LUT in scratchpad;
* the ASIC's output-stationary PE with FP16 accumulation becomes a
  K-revisiting matmul grid that accumulates f32 in the output tile and
  rounds to the binary16 grid once per output tile (the paper's
  accumulation boundary).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant

# ----------------------------------------------------------------------
# Branch-free quantizer bodies (shared by several kernels)
# ----------------------------------------------------------------------


def _sd8_round_vector(x, mids, grid):
    """FloatSD8 round via midpoint rank + one-hot contraction.

    Equivalent to quant.floatsd8_round but with no searchsorted (which
    has no TPU lowering): rank(x) = #{midpoints m : m <= x} for x >= 0
    (ties away from zero) and #{m : m < x} for x < 0.
    """
    xe = x[..., None]
    rank_pos = jnp.sum((mids <= xe).astype(jnp.int32), axis=-1)
    rank_neg = jnp.sum((mids < xe).astype(jnp.int32), axis=-1)
    rank = jnp.where(x >= 0, rank_pos, rank_neg)
    # one-hot contraction instead of gather (VPU-friendly)
    onehot = (rank[..., None] == jax.lax.iota(jnp.int32, grid.shape[0])).astype(x.dtype)
    out = jnp.sum(onehot * grid.astype(x.dtype), axis=-1)
    return jnp.where(jnp.isnan(x), jnp.zeros_like(x), out)


def _fp8_round_vector(x):
    """FP8 (1-5-2) RNE — already branch-free in quant.fp8_round."""
    return quant.fp8_round(x)


def _fp16_round_vector(x):
    return quant.fp16_round(x)


def _sd8_tables():
    """The (midpoints, grid) LUT pair fed to kernels as operands."""
    return jnp.asarray(quant.SD8_MIDPOINTS), jnp.asarray(quant.SD8_VALUES)


def _table_spec(table):
    """BlockSpec broadcasting a small LUT to every grid step (block 0)."""
    return pl.BlockSpec(table.shape, lambda *_: (0,) * table.ndim)


# ----------------------------------------------------------------------
# Elementwise kernels
# ----------------------------------------------------------------------


def _elementwise_call(body, x, block=4096, with_tables=False):
    """Tile a flat elementwise kernel over 1-D VMEM-sized blocks.

    ``body(x_block [, mids, grid])`` computes the per-element result;
    when ``with_tables`` the SD8 LUTs are passed as broadcast operands.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    # pad to a multiple of the block so BlockSpec tiling is exact
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    grid_steps = flat.shape[0] // block

    operands = [flat]
    in_specs = [pl.BlockSpec((block,), lambda i: (i,))]
    if with_tables:
        mids, grid = _sd8_tables()
        operands += [mids, grid]
        in_specs += [_table_spec(mids), _table_spec(grid)]

    def kernel(x_ref, *rest):
        o_ref = rest[-1]
        tables = tuple(r[...] for r in rest[:-1])
        o_ref[...] = body(x_ref[...], *tables)

    out = pl.pallas_call(
        kernel,
        grid=(grid_steps,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(*operands)
    if pad:
        out = out[:n]
    return out.reshape(x.shape)


def floatsd8_round_pallas(x, block=4096):
    """Pallas FloatSD8 quantizer (vs ref_floatsd8_round)."""
    return _elementwise_call(_sd8_round_vector, x, block, with_tables=True)


def fp8_round_pallas(x, block=4096):
    """Pallas FP8 quantizer (vs ref_fp8_round)."""
    return _elementwise_call(_fp8_round_vector, x, block)


def _sigmoid_sd8_body(v, mids, grid):
    s = jnp.float32(1.0) / (jnp.float32(1.0) + jnp.exp(-jnp.abs(v)))
    q_neg = _sd8_round_vector(jnp.float32(1.0) - s, mids, grid)
    return jnp.where(v <= 0, q_neg, jnp.float32(1.0) - q_neg)


def sigmoid_sd8_pallas(x, block=4096):
    """Pallas two-region quantized sigmoid (vs ref_sigmoid_sd8)."""
    return _elementwise_call(_sigmoid_sd8_body, x, block, with_tables=True)


# ----------------------------------------------------------------------
# Quantized matmul (the forward-pass GEMM of Eq. 1-4)
# ----------------------------------------------------------------------


def qmatmul_pallas(x, w, bm=32, bn=64, bk=32):
    """FP8(x) × FloatSD8(w) → FP16-rounded result, tiled (bm, bn, bk).

    Output-stationary: the (m, n) output tile accumulates in f32 across
    the k grid dimension and is rounded to the binary16 grid on the last
    k step — exactly the paper's PE accumulation discipline, with the
    FP16 boundary at the output tile.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    )
    nk = k // bk
    mids, grid = _sd8_tables()

    def kernel(x_ref, w_ref, mids_ref, grid_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        xq = _fp8_round_vector(x_ref[...])
        wq = _sd8_round_vector(w_ref[...], mids_ref[...], grid_ref[...])
        o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == nk - 1)
        def _finish():
            o_ref[...] = _fp16_round_vector(o_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            _table_spec(mids),
            _table_spec(grid),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, mids, grid)


# ----------------------------------------------------------------------
# Fused LSTM gate kernel (Eq. 5/6 elementwise half)
# ----------------------------------------------------------------------


def lstm_gates_pallas(z_f, z_i, z_o, z_g, c_prev, block=1024):
    """Fused quantized gate math: returns (c_t, h_t).

    One VMEM pass over five inputs and two outputs; all the per-element
    quantization (σ→FloatSD8 two-region, tanh→FP8, FP16 cell-state
    accumulation, FP8 output) happens in-register.
    """
    shape = z_f.shape
    flats = [a.reshape(-1) for a in (z_f, z_i, z_o, z_g, c_prev)]
    n = flats[0].shape[0]
    pad = (-n) % block
    if pad:
        flats = [jnp.concatenate([f, jnp.zeros((pad,), f.dtype)]) for f in flats]
    grid_steps = flats[0].shape[0] // block
    mids, grid = _sd8_tables()

    def kernel(f_ref, i_ref, o_ref, g_ref, c_ref, mids_ref, grid_ref,
               co_ref, ho_ref):
        mids_v, grid_v = mids_ref[...], grid_ref[...]
        f = _sigmoid_sd8_body(f_ref[...], mids_v, grid_v)
        i = _sigmoid_sd8_body(i_ref[...], mids_v, grid_v)
        o = _sigmoid_sd8_body(o_ref[...], mids_v, grid_v)
        g = _fp8_round_vector(jnp.tanh(g_ref[...]))
        # cell state is architecturally FP16 (see ref.ref_lstm_gates)
        cp = _fp16_round_vector(c_ref[...])
        c = _fp16_round_vector(f * cp + i * g)
        h = _fp8_round_vector(o * _fp8_round_vector(jnp.tanh(c)))
        co_ref[...] = c
        ho_ref[...] = h

    spec = pl.BlockSpec((block,), lambda i: (i,))
    c_out, h_out = pl.pallas_call(
        kernel,
        grid=(grid_steps,),
        in_specs=[spec] * 5 + [_table_spec(mids), _table_spec(grid)],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(flats[0].shape, z_f.dtype),
            jax.ShapeDtypeStruct(flats[0].shape, z_f.dtype),
        ],
        interpret=True,
    )(*flats, mids, grid)
    if pad:
        c_out, h_out = c_out[:n], h_out[:n]
    return c_out.reshape(shape), h_out.reshape(shape)


# ----------------------------------------------------------------------
# VMEM / MXU static analysis (perf estimation, DESIGN.md §8)
# ----------------------------------------------------------------------


def qmatmul_vmem_bytes(bm, bn, bk, dtype_bytes=4):
    """VMEM bytes resident for one qmatmul grid step (x, w, o tiles +
    the two SD8 LUTs)."""
    luts = (quant.SD8_MIDPOINTS.size + quant.SD8_VALUES.size) * dtype_bytes
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes + luts


def qmatmul_mxu_utilization(bm, bn, bk, mxu=128):
    """Fraction of the 128×128 MXU systolic array a (bm,bn,bk) tile keeps
    busy: min(bm,mxu)/mxu * min(bn,mxu)/mxu (bk streams through)."""
    return min(bm, mxu) / mxu * min(bn, mxu) / mxu


def perf_estimate(bm=32, bn=64, bk=32):
    """Static perf summary used by DESIGN.md §8 / EXPERIMENTS.md §Perf."""
    return {
        "vmem_bytes": qmatmul_vmem_bytes(bm, bn, bk),
        "mxu_utilization": qmatmul_mxu_utilization(bm, bn, bk),
        "blocks": (bm, bn, bk),
    }
