"""AOT compile path: lower every (task × precision-scheme) train/eval
step to **HLO text** and emit the interchange artifacts consumed by the
rust coordinator.

HLO *text* — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``make artifacts`` → ``artifacts/``):

* ``<task>_<scheme>.train.hlo.txt`` / ``.eval.hlo.txt`` — the AOT steps;
* ``<task>.init.tensors``  — initial (params, optimizer) state, one f32
  tensor per pytree leaf in flattening order (the order rust feeds back);
* ``golden/formats.tensors`` — jnp quantizer outputs pinning the grids
  to the bit-exact rust ``formats::`` implementations;
* ``manifest.json`` — shapes, state layouts, scheme table, artifact map.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import lstm, precision, tasks, tensorio
from .kernels import quant, ref

SEED = 20200711  # fixed: every scheme starts from identical weights


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def state_specs(state):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
    )


def flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(np.asarray(leaf))
    return names, leaves


# ----------------------------------------------------------------------
# Artifact set
# ----------------------------------------------------------------------


def artifact_plan() -> list[tuple[str, str, bool]]:
    """(task, scheme, use_pallas) triples to lower.

    ``ab1`` is numerically identical to ``fsd8`` — aliased in the
    manifest instead of recompiled. The tiny task is lowered through the
    L1 Pallas kernels to prove the full-stack composition.
    """
    plan = []
    for task in ("pos", "nli", "mt", "lm"):
        for scheme in ("fp32", "fsd8", "fsd8m16"):
            plan.append((task, scheme, False))
    for scheme in ("ab2", "ab3", "ab4", "ab5", "fsd8sr"):
        plan.append(("lm", scheme, False))
    plan.append(("tiny", "fp32", False))
    plan.append(("tiny", "fsd8m16", True))
    return plan


def lower_artifact(task: str, scheme: str, use_pallas: bool, out_dir: str,
                   manifest: dict) -> None:
    cfg = precision.all_schemes()[scheme]
    lstm.USE_PALLAS_MATMUL = use_pallas
    try:
        init_state, train_step, eval_step, spec = tasks.make_steps(task, cfg)
        state = init_state(SEED)
        sspec = state_specs(state)
        bsz = spec.batch
        x_spec = jax.ShapeDtypeStruct((bsz, *spec.x_shape), jnp.int32)
        y_spec = jax.ShapeDtypeStruct((bsz, *spec.y_shape), jnp.int32)

        name = f"{task}_{scheme}"
        train_path = f"{name}.train.hlo.txt"
        eval_path = f"{name}.eval.hlo.txt"

        # keep_unused=True: the eval step ignores the optimizer state,
        # and jit would silently prune those parameters from the HLO
        # signature — the rust driver needs a stable (state, x, y) ABI.
        lowered_t = jax.jit(train_step, keep_unused=True).lower(sspec, x_spec, y_spec)
        with open(os.path.join(out_dir, train_path), "w") as f:
            f.write(to_hlo_text(lowered_t))
        lowered_e = jax.jit(eval_step, keep_unused=True).lower(sspec, x_spec, y_spec)
        with open(os.path.join(out_dir, eval_path), "w") as f:
            f.write(to_hlo_text(lowered_e))

        # init state (scheme-independent given task: same seed & arch; the
        # optimizer layout is also identical) — write once per task.
        init_file = f"{task}.init.tensors"
        init_full = os.path.join(out_dir, init_file)
        names, leaves = flatten_with_names(state)
        if not os.path.exists(init_full):
            tensorio.write_tensors(init_full, list(zip(names, leaves)))

        manifest["tasks"].setdefault(
            task,
            {
                "init": init_file,
                "n_state": len(leaves),
                "state_names": names,
                "state_shapes": [list(a.shape) for a in leaves],
                "batch": bsz,
                "x_shape": list(spec.x_shape),
                "y_shape": list(spec.y_shape),
                "vocab": spec.vocab,
                "vocab_tgt": spec.vocab_tgt,
                "n_classes": spec.n_classes,
                "optimizer": spec.optimizer,
                "lr": spec.lr,
                "metric": spec.metric,
                "clip_norm": spec.clip_norm,
            },
        )
        manifest["artifacts"][name] = {
            "task": task,
            "scheme": scheme,
            "train": train_path,
            "eval": eval_path,
            "pallas": use_pallas,
        }
        print(f"  lowered {name} (pallas={use_pallas})")
    finally:
        lstm.USE_PALLAS_MATMUL = False


# ----------------------------------------------------------------------
# Golden vectors (rust <-> jnp grid pinning)
# ----------------------------------------------------------------------


def write_golden(out_dir: str) -> None:
    gd = os.path.join(out_dir, "golden")
    os.makedirs(gd, exist_ok=True)
    rng = np.random.default_rng(7)

    # Mixed-scale probe covering normals, subnormals, ties, saturation.
    xs = np.concatenate(
        [
            rng.uniform(-6, 6, 2048),
            rng.uniform(-1, 1, 1024) * 10.0 ** rng.uniform(-8, 5, 1024),
            np.array([0.0, -0.0, 1.0, -1.0, 0.5, 4.5, -4.5, 1e9, -1e9,
                      2.0**-16, 2.0**-25, 114688.0, 2.25 * 2.0**-7]),
            quant.SD8_VALUES_F64,  # every sd8 grid point must be a fixpoint
        ]
    ).astype(np.float32)

    tensors = [
        ("x", xs),
        ("fp8", np.asarray(ref.ref_fp8_round(jnp.asarray(xs)))),
        ("fp16", np.asarray(ref.ref_fp16_round(jnp.asarray(xs)))),
        ("sd8", np.asarray(ref.ref_floatsd8_round(jnp.asarray(xs)))),
        ("sig2", np.asarray(ref.ref_sigmoid_sd8(jnp.asarray(xs)))),
        ("sig1", np.asarray(quant.sigmoid_floatsd8_one_region(jnp.asarray(xs)))),
        ("sd8_grid", quant.SD8_VALUES.astype(np.float32)),
    ]

    # qmatmul golden
    x = rng.uniform(-2, 2, (16, 32)).astype(np.float32)
    w = rng.uniform(-1, 1, (32, 16)).astype(np.float32)
    y = np.asarray(ref.ref_qmatmul(jnp.asarray(x), jnp.asarray(w)))
    tensors += [("mm_x", x), ("mm_w", w), ("mm_y", y)]

    # lstm gate golden (Eq. 5/6 elementwise half)
    zf, zi, zo, zg = (rng.uniform(-4, 4, 256).astype(np.float32) for _ in range(4))
    c = rng.uniform(-2, 2, 256).astype(np.float32)
    co, ho = ref.ref_lstm_gates(*(jnp.asarray(a) for a in (zf, zi, zo, zg, c)))
    tensors += [
        ("g_zf", zf), ("g_zi", zi), ("g_zo", zo), ("g_zg", zg), ("g_c", c),
        ("g_c_out", np.asarray(co)), ("g_h_out", np.asarray(ho)),
    ]

    tensorio.write_tensors(os.path.join(gd, "formats.tensors"), tensors)
    print(f"  wrote golden vectors ({len(tensors)} tensors)")


# ----------------------------------------------------------------------
# main
# ----------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names (task_scheme) to lower",
    )
    ap.add_argument("--golden-only", action="store_true")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    write_golden(out_dir)
    if args.golden_only:
        return

    manifest: dict = {
        "format_version": 1,
        "seed": SEED,
        "tasks": {},
        "artifacts": {},
        "schemes": {
            name: {
                "weights": c.weights,
                "activations": c.activations,
                "first_layer_acts": c.first_layer_acts,
                "last_layer_acts": c.last_layer_acts,
                "gradients": c.gradients,
                "master": c.master,
                "sigmoid": c.sigmoid,
                "accum": c.accum,
                "loss_scale": c.loss_scale,
                "stochastic_gradients": c.stochastic_gradients,
            }
            for name, c in precision.all_schemes().items()
        },
        "sd8_values": [float(v) for v in quant.SD8_VALUES],
    }

    plan = artifact_plan()
    if args.only:
        keep = set(args.only.split(","))
        plan = [p for p in plan if f"{p[0]}_{p[1]}" in keep]

    for task, scheme, use_pallas in plan:
        lower_artifact(task, scheme, use_pallas, out_dir, manifest)

    # ab1 is numerically fsd8 (Table V row 1): alias, don't recompile.
    if "lm_fsd8" in manifest["artifacts"]:
        manifest["artifacts"]["lm_ab1"] = dict(
            manifest["artifacts"]["lm_fsd8"], scheme="ab1"
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifact entries to manifest.json")


if __name__ == "__main__":
    sys.exit(main())
