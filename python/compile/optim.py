"""Optimizers + the paper's weight-update discipline (§III-B, §IV-C).

The paper keeps a *master copy* of the weights in conventional FP
(FP32 originally, FP16 in the modified scheme), updates it with the
standard rule, then re-quantizes to FloatSD8 for the next iteration
(the re-quantization lives in the model's forward pass — ``lstm.
quantize_weight``). Here we implement:

* gradient post-processing: unscale (loss scaling ×1024), FP8
  quantization of the weight gradients ("all gradients" — Table II),
  optional global-norm clipping (LM task, both schemes identically);
* ADAM (UDPOS/SNLI/Multi30K) and SGD (WikiText-2) updates;
* master-copy rounding to the FP16 grid when cfg.master == 'fp16'
  (Table IV column 4) — Adam moments stay f32 (the paper quantizes
  only the master copy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import quant
from .precision import PrecisionConfig


def _quantize_grads(grads, cfg: PrecisionConfig):
    name = cfg.gradients
    if name == "fp8" and cfg.stochastic_gradients:
        name = "fp8sr"
    if name == "none":
        return grads
    q = quant.get_quantizer(name)
    return jax.tree_util.tree_map(q, grads)


def _round_master(params, cfg: PrecisionConfig):
    if cfg.master == "fp16":
        return jax.tree_util.tree_map(quant.fp16_round, params)
    return params


def _clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    total = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (total + 1e-6))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def process_grads(grads, cfg: PrecisionConfig, clip_norm: float | None):
    """Paper order: quantize the (loss-scaled) gradients to FP8 first —
    that is what the hardware produces — then unscale and (optionally)
    clip for the update arithmetic."""
    grads = _quantize_grads(grads, cfg)
    grads = jax.tree_util.tree_map(lambda g: g / cfg.loss_scale, grads)
    if clip_norm is not None:
        grads = _clip_by_global_norm(grads, clip_norm)
    return grads


# ----------------------------------------------------------------------
# ADAM
# ----------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, cfg: PrecisionConfig, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return _round_master(params, cfg), {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------
# SGD (WikiText-2 task)
# ----------------------------------------------------------------------


def sgd_init(params):
    return {"t": jnp.zeros((), jnp.float32)}


def sgd_update(params, grads, state, cfg: PrecisionConfig, lr=1.0):
    params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return _round_master(params, cfg), {"t": state["t"] + 1.0}


OPTIMIZERS = {
    "adam": (adam_init, adam_update),
    "sgd": (sgd_init, sgd_update),
}
