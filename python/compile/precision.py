"""Precision configurations — Tables II, V and VI of the paper as code.

A :class:`PrecisionConfig` names the grid used for each variable class;
``'none'`` means keep f32 (the FP32 baseline sets everything to
``'none'``). Presets:

* :func:`fp32` — baseline (Table IV column 2, Fig. 6 dashed curves);
* :func:`paper_original` — Table II: FloatSD8 w, FP8 g/a, FP32 master,
  FloatSD8 σ, FP16 accumulation, loss scale 1024;
* :func:`paper_modified` — Table VI: FP16 master + FP16 last-layer
  activations (the scheme the paper recommends);
* :func:`table5_rows` — the five first/last/other activation ablations.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """Which quantization grid each variable class lives on."""

    name: str
    #: weight grid used in all matmuls ('sd8' or 'none')
    weights: str = "none"
    #: hidden-layer activation grid ('fp8' / 'fp16' / 'none')
    activations: str = "none"
    #: first-layer activations = embedding outputs (Table V col 1)
    first_layer_acts: str = "none"
    #: last-layer activations = output-layer pre-softmax (Table V col 2)
    last_layer_acts: str = "none"
    #: gradient grid, applied to backward activations and weight grads
    gradients: str = "none"
    #: master-copy grid ('fp32' or 'fp16') — Table IV column 4
    master: str = "fp32"
    #: sigmoid-output grid ('sd8' two-region, or 'none')
    sigmoid: str = "none"
    #: accumulation boundary ('fp16' rounds dot outputs, or 'none')
    accum: str = "none"
    #: loss-scaling factor (paper: single static factor 1024)
    loss_scale: float = 1.0
    #: use stochastic rounding for FP8 gradients (paper ablation: the
    #: paper chose regular rounding for hardware simplicity; we expose
    #: the alternative for the extension bench)
    stochastic_gradients: bool = False

    def is_baseline(self) -> bool:
        return self.weights == "none" and self.activations == "none"


def fp32() -> PrecisionConfig:
    """IEEE single-precision baseline."""
    return PrecisionConfig(name="fp32")


def paper_original() -> PrecisionConfig:
    """Table II: the initially-proposed scheme (FP32 master, FP8 acts
    everywhere including first/last layers)."""
    return PrecisionConfig(
        name="fsd8",
        weights="sd8",
        activations="fp8",
        first_layer_acts="fp8",
        last_layer_acts="fp8",
        gradients="fp8",
        master="fp32",
        sigmoid="sd8",
        accum="fp16",
        loss_scale=1024.0,
    )


def paper_modified() -> PrecisionConfig:
    """Table VI: the recommended scheme — FP16 master copy and FP16
    last-layer activations, everything else as Table II."""
    return dataclasses.replace(
        paper_original(),
        name="fsd8m16",
        master="fp16",
        last_layer_acts="fp16",
    )


def with_master(cfg: PrecisionConfig, master: str) -> PrecisionConfig:
    """Table IV column 4: same scheme, FP16 master copy."""
    return dataclasses.replace(cfg, name=f"{cfg.name}_m{master[2:]}", master=master)


def table5_rows() -> list[PrecisionConfig]:
    """The five activation-precision settings of Table V (on the LM task,
    FP32 master, everything else per Table II)."""
    rows = [
        ("ab1", "fp8", "fp8", "fp8"),
        ("ab2", "fp16", "fp16", "fp16"),
        ("ab3", "fp8", "fp16", "fp8"),
        ("ab4", "fp16", "fp8", "fp8"),
        ("ab5", "fp16", "fp16", "fp8"),
    ]
    out = []
    for name, first, last, other in rows:
        out.append(
            dataclasses.replace(
                paper_original(),
                name=name,
                first_layer_acts=first,
                last_layer_acts=last,
                activations=other,
            )
        )
    return out


def stochastic_variant() -> PrecisionConfig:
    """Extension ablation: Table II scheme with stochastic FP8 gradient
    rounding (the paper cites it as better-performing but rejected it
    for hardware complexity)."""
    return dataclasses.replace(
        paper_original(), name="fsd8sr", stochastic_gradients=True
    )


#: every named scheme, for CLI/bench lookup
def all_schemes() -> dict[str, PrecisionConfig]:
    schemes = {
        "fp32": fp32(),
        "fsd8": paper_original(),
        "fsd8m16": paper_modified(),
        "fsd8sr": stochastic_variant(),
    }
    for r in table5_rows():
        schemes[r.name] = r
    return schemes
