"""Fake-quantization machinery (the QPyTorch-equivalent, from scratch).

``fq(x, fwd, bwd)`` quantizes the value on the forward pass with the
``fwd`` grid and the incoming cotangent on the backward pass with the
``bwd`` grid — this is how the paper's "FP8 forward activations and FP8
backward activations/gradients" are realised inside a single
differentiable graph (QPyTorch does the same with autograd Functions).

``ste_*`` variants give piecewise-constant quantizers a useful gradient
(straight-through / true-function derivative), required for the
FloatSD8-quantized sigmoid whose exact derivative is 0 a.e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import quant


@functools.lru_cache(maxsize=None)
def _make_fq(fwd_name: str, bwd_name: str):
    f = quant.get_quantizer(fwd_name)
    b = quant.get_quantizer(bwd_name)

    @jax.custom_vjp
    def _fq(x):
        return f(x)

    def _fwd(x):
        return f(x), None

    def _bwd(_, g):
        return (b(g),)

    _fq.defvjp(_fwd, _bwd)
    return _fq


def fq(x, fwd: str, bwd: str = "none"):
    """Quantize forward with `fwd`, quantize the cotangent with `bwd`.

    Both names index :data:`quant.QUANTIZERS`
    ('none' | 'fp8' | 'fp16' | 'sd8'). ``fq(x, 'none', 'none')`` is the
    identity and costs nothing after tracing.
    """
    if fwd == "none" and bwd == "none":
        return x
    return _make_fq(fwd, bwd)(x)


@functools.lru_cache(maxsize=None)
def _make_sigmoid_sd8(bwd_name: str):
    b = quant.get_quantizer(bwd_name)

    @jax.custom_vjp
    def _qsig(x):
        return quant.sigmoid_floatsd8(x)

    def _fwd(x):
        s = jax.nn.sigmoid(x)
        return quant.sigmoid_floatsd8(x), s

    def _bwd(s, g):
        # straight-through: derivative of the *unquantized* sigmoid,
        # cotangent quantized to the backward-activation grid.
        return (b(g * s * (1.0 - s)),)

    _qsig.defvjp(_fwd, _bwd)
    return _qsig


def sigmoid_sd8(x, bwd: str = "none"):
    """Two-region FloatSD8-quantized sigmoid with an STE gradient."""
    return _make_sigmoid_sd8(bwd)(x)


@functools.lru_cache(maxsize=None)
def _make_tanh_q(fwd_name: str, bwd_name: str):
    f = quant.get_quantizer(fwd_name)
    b = quant.get_quantizer(bwd_name)

    @jax.custom_vjp
    def _qtanh(x):
        return f(jnp.tanh(x))

    def _fwd(x):
        t = jnp.tanh(x)
        return f(t), t

    def _bwd(t, g):
        return (b(g * (1.0 - t * t)),)

    _qtanh.defvjp(_fwd, _bwd)
    return _qtanh


def tanh_q(x, fwd: str = "none", bwd: str = "none"):
    """tanh with quantized output (activation grid) and STE gradient."""
    return _make_tanh_q(fwd, bwd)(x)
