//! Minimal CLI argument parser (clap is unavailable offline): ordered
//! positionals + `--flag[=value]` options, with typed accessors.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: subcommand, positionals, options.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator (first element = argv[0], skipped).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut args = Args::default();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positionals.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got {v:?}")),
        }
    }

    /// Full-width u64 option (seeds: `usize` round trips would be
    /// lossy on 32-bit targets and invite silent truncation).
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a u64, got {v:?}")),
        }
    }

    /// Float option (thresholds, rates). Parse errors name the flag;
    /// range/finiteness checks stay with the caller, which knows the
    /// domain.
    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn require_opt(&self, key: &str) -> Result<&str> {
        match self.opt(key) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(std::iter::once("bin".to_string()).chain(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: a bare `--name tok` consumes `tok` as the value (there is
        // no schema to disambiguate); boolean flags go last or use `=`.
        let a = parse("train extra --artifact lm_fsd8 --epochs=5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("artifact"), Some("lm_fsd8"));
        assert_eq!(a.opt_usize("epochs", 1).unwrap(), 5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn flag_at_end_and_defaults() {
        let a = parse("bench --quick");
        assert!(a.has_flag("quick"));
        assert_eq!(a.opt_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.opt_usize("div", 2).unwrap(), 2);
    }

    #[test]
    fn opt_u64_keeps_full_width() {
        let big = (1u64 << 53) + 1; // above f64-exact and i32 range
        let a = parse(&format!("x --seed {big}"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), big);
        assert_eq!(a.opt_u64("other", 7).unwrap(), 7);
        assert!(parse("x --seed nope").opt_u64("seed", 0).is_err());
    }

    #[test]
    fn opt_f64_parses_and_defaults() {
        let a = parse("report --sat-delta-pp 2.5");
        assert_eq!(a.opt_f64("sat-delta-pp", 5.0).unwrap(), 2.5);
        assert_eq!(a.opt_f64("span-regression-pct", 20.0).unwrap(), 20.0);
        assert!(parse("report --sat-delta-pp nope").opt_f64("sat-delta-pp", 5.0).is_err());
    }

    #[test]
    fn bad_usize_errors() {
        let a = parse("x --n abc");
        assert!(a.opt_usize("n", 0).is_err());
    }

    #[test]
    fn required_opt() {
        let a = parse("x");
        assert!(a.require_opt("artifact").is_err());
    }
}
