//! Multi-task heads + evaluation harness — the paper's Table IV
//! scenario grid (language modeling, POS tagging, NLI classification,
//! translation) running offline on the pure-rust quantized training
//! engine.
//!
//! The [`train`](crate::train) subsystem provides the quantized
//! machinery (traced forwards, STE backward passes, FP16-master
//! updates, dynamic loss scaling); this module provides the *task
//! structure* on top:
//!
//! * [`TaskHead`] — the per-task contract: one gradient window
//!   (forward + loss + backward), the buffered update, deterministic
//!   held-out evaluation, and checkpointing;
//! * [`lm`] / [`pos`] / [`nli`] / [`mt`] — the four heads, each wired
//!   to its [`crate::data`] generator, its loss (masked cross-entropy
//!   honoring PAD where the task has one), and its metric (perplexity,
//!   tag accuracy, classification accuracy);
//! * [`TaskTrainer`] — the shared optimizer loop (`floatsd-lstm train
//!   --task {lm,pos,nli,mt}`): loss-scale bookkeeping and skip/apply
//!   logic identical to the char-LM [`crate::train::Trainer`];
//! * [`eval`] — the harness behind `floatsd-lstm eval`: load any
//!   `.tensors` checkpoint (task topology + generators rebuilt from
//!   its `meta/task_cfg` blob), run the held-out set, and emit a
//!   deterministic JSON report covering all four tasks.
//!
//! Head wiring, loss masking rules, and the report schema are
//! documented in `DESIGN.md` ("Tasks & evaluation subsystem").

pub mod eval;
pub mod lm;
pub mod mt;
pub mod nli;
pub mod pos;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::lstm::cell::QLstmCell;
use crate::lstm::model::{Dense, Embedding, ParamBag, QLstmLayer};
use crate::lstm::QLstmStack;
use crate::qmath::vector::QMatrix;
use crate::qmath::{IsaPath, KernelTier};
use crate::telemetry::{self, trace, ActSnapshot, SpanTimer, TraceSink};
use crate::tensorfile::json::Json;
use crate::tensorfile::Tensor;
use crate::train::optimizer::MasterCell;
use crate::train::{
    check_threads, finalize_grads, lane_spans, merge_finalize_overlapped, merge_shards, LaneShard,
    LossScaler, MasterStack, PresetTier, ScaleEvent, StackGrads, StackTape, StepOutcome,
};

/// The four offline task heads (paper Table IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// language modeling: per-step next-token CE over the vocabulary
    Lm,
    /// POS tagging: per-step classification over the tag set
    Pos,
    /// NLI: final-hidden-state 3-way classification of a pair
    Nli,
    /// translation: encoder–decoder teacher-forced seq2seq
    Mt,
}

impl TaskKind {
    /// All tasks, in the report's canonical order.
    pub const ALL: [TaskKind; 4] = [TaskKind::Lm, TaskKind::Pos, TaskKind::Nli, TaskKind::Mt];

    pub fn parse(s: &str) -> Result<TaskKind> {
        Ok(match s {
            "lm" => TaskKind::Lm,
            "pos" => TaskKind::Pos,
            "nli" => TaskKind::Nli,
            "mt" => TaskKind::Mt,
            other => bail!("unknown task {other:?} (expected lm|pos|nli|mt)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Lm => "lm",
            TaskKind::Pos => "pos",
            TaskKind::Nli => "nli",
            TaskKind::Mt => "mt",
        }
    }
}

/// Configuration of one offline task-training run — the multi-task
/// superset of [`crate::train::TrainConfig`].
#[derive(Clone, Debug)]
pub struct TaskConfig {
    pub task: TaskKind,
    /// (source) vocabulary
    pub vocab: usize,
    /// target-language vocabulary (`mt` only; 0 elsewhere)
    pub vocab_tgt: usize,
    /// tag/label classes (`pos`/`nli`; 0 elsewhere)
    pub n_classes: usize,
    pub dim: usize,
    pub hidden: usize,
    pub layers: usize,
    pub batch: usize,
    /// per-example sequence length (LM window, POS sentence, NLI
    /// premise/hypothesis half, MT source length)
    pub seq: usize,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    pub loss_scale: f32,
    pub clip_norm: Option<f32>,
    pub log_every: usize,
    pub eval_batches: usize,
    /// worker threads the lane shards are distributed over
    /// (numerics-neutral — `--threads N` ≡ `--threads 1` bit-for-bit,
    /// see [`crate::train::parallel`]); training-only, never
    /// checkpointed
    pub threads: usize,
    pub checkpoint: Option<PathBuf>,
    /// `--trace`: write a `floatsd-trace-v1` JSONL numerics-health
    /// stream here (numerics-neutral — see [`crate::telemetry`]);
    /// training-only, never checkpointed
    pub trace: Option<PathBuf>,
    /// `--trace-every N`: emit `step`/`reencode` trace events (and pay
    /// the gradient scan) only every N-th step; `run_start`/`run_end`/
    /// `loss_scale` always emit, so a sampled trace is a strict
    /// subsequence of the N=1 trace; training-only, never checkpointed
    pub trace_every: usize,
    /// `--kernel-tier`: forward matvec/matmul tier (runtime-only —
    /// never checkpointed; see [`crate::qmath::shiftadd`])
    pub kernel_tier: KernelTier,
    /// `--kernel-isa`: SIMD execution path of the forward kernels
    /// (runtime-only — never checkpointed, bit-identical across
    /// paths; see [`crate::qmath::simd`])
    pub kernel_isa: IsaPath,
}

impl TaskConfig {
    /// The miniature-but-learnable default per task — also what the
    /// eval harness uses for `"source": "init"` grid entries, so keep
    /// these stable.
    pub fn preset(task: TaskKind) -> TaskConfig {
        let mut cfg = TaskConfig {
            task,
            vocab: 64,
            vocab_tgt: 0,
            n_classes: 0,
            dim: 16,
            hidden: 24,
            layers: 1,
            batch: 8,
            seq: 16,
            steps: 400,
            lr: 0.3,
            momentum: 0.9,
            seed: 42,
            loss_scale: 1024.0,
            clip_norm: None,
            log_every: 25,
            eval_batches: 4,
            threads: 1,
            checkpoint: None,
            trace: None,
            trace_every: 1,
            kernel_tier: KernelTier::Decoded,
            kernel_isa: IsaPath::detect(),
        };
        match task {
            TaskKind::Lm => {}
            TaskKind::Pos => {
                cfg.vocab = 120;
                cfg.n_classes = 8;
                cfg.seq = 12;
                cfg.steps = 300;
            }
            TaskKind::Nli => {
                cfg.n_classes = 3;
                cfg.batch = 16;
                cfg.seq = 8;
            }
            TaskKind::Mt => {
                cfg.vocab = 48;
                cfg.vocab_tgt = 48;
                cfg.hidden = 32;
                cfg.seq = 8;
            }
        }
        cfg
    }

    /// The `--preset {tiny,default,paper}` size tiers. `default` is
    /// exactly [`Self::preset`] (the grid the eval harness scores
    /// untrained tasks at — keep it stable); `tiny` is the CI smoke
    /// scale; `paper` is the source paper's scale class (10k-class LM,
    /// 2-layer 256-hidden stacks, with the other heads scaled to
    /// match).
    pub fn preset_tier(task: TaskKind, tier: PresetTier) -> TaskConfig {
        let mut cfg = TaskConfig::preset(task);
        match tier {
            PresetTier::Default => {}
            PresetTier::Tiny => {
                cfg.dim = 8;
                cfg.hidden = 12;
                cfg.layers = 1;
                cfg.batch = 4;
                cfg.seq = 8;
                cfg.steps = 80;
                cfg.eval_batches = 2;
                cfg.log_every = 0;
                match task {
                    TaskKind::Lm => cfg.vocab = 32,
                    TaskKind::Pos => {
                        cfg.vocab = 60;
                        cfg.n_classes = 6;
                    }
                    TaskKind::Nli => {
                        cfg.vocab = 24;
                        cfg.batch = 8;
                        cfg.seq = 6;
                    }
                    TaskKind::Mt => {
                        cfg.vocab = 16;
                        cfg.vocab_tgt = 16;
                        cfg.seq = 4;
                    }
                }
            }
            PresetTier::Paper => {
                cfg.dim = 128;
                cfg.hidden = 256;
                cfg.layers = 2;
                cfg.batch = 16;
                cfg.steps = 500;
                cfg.lr = 0.1;
                cfg.log_every = 10;
                cfg.eval_batches = 2;
                match task {
                    TaskKind::Lm => {
                        cfg.vocab = 10_000;
                        cfg.seq = 32;
                    }
                    TaskKind::Pos => {
                        cfg.vocab = 5_000;
                        cfg.n_classes = 45;
                        cfg.seq = 24;
                    }
                    TaskKind::Nli => {
                        cfg.vocab = 2_000;
                        cfg.batch = 32;
                        cfg.seq = 16;
                    }
                    TaskKind::Mt => {
                        cfg.vocab = 2_000;
                        cfg.vocab_tgt = 2_000;
                        cfg.seq = 16;
                    }
                }
            }
        }
        cfg
    }

    /// The JSON metadata blob stored in checkpoints (`meta/task_cfg`):
    /// everything the eval harness needs to rebuild the model topology
    /// and the deterministic held-out stream. Training-only knobs
    /// (lr, momentum, …) are deliberately absent.
    pub fn to_meta_json(&self) -> String {
        let mut m = BTreeMap::new();
        let num = |v: usize| Json::Num(v as f64);
        m.insert("task".to_string(), Json::Str(self.task.name().to_string()));
        m.insert("vocab".to_string(), num(self.vocab));
        m.insert("vocab_tgt".to_string(), num(self.vocab_tgt));
        m.insert("n_classes".to_string(), num(self.n_classes));
        m.insert("dim".to_string(), num(self.dim));
        m.insert("hidden".to_string(), num(self.hidden));
        m.insert("layers".to_string(), num(self.layers));
        m.insert("batch".to_string(), num(self.batch));
        m.insert("seq".to_string(), num(self.seq));
        m.insert("eval_batches".to_string(), num(self.eval_batches));
        // decimal string, not a JSON number: a u64 seed above 2^53
        // would silently lose bits through the f64 number path
        m.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        Json::Obj(m).to_string()
    }

    /// Seed of the task's data generators — every head derives its
    /// train/eval streams from this one value, so anything rebuilding
    /// a head's held-out set (the eval harness, the serve parity
    /// tests) must use it too.
    pub fn data_seed(&self) -> u64 {
        self.seed ^ 0xDA7A
    }

    /// Inverse of [`Self::to_meta_json`] (training knobs come from the
    /// task preset).
    pub fn from_meta_json(text: &str) -> Result<TaskConfig> {
        let j = Json::parse(text).context("parse meta/task_cfg")?;
        let task_name =
            j.get("task").and_then(Json::as_str).context("task_cfg: missing task")?;
        let task = TaskKind::parse(task_name)?;
        let mut cfg = TaskConfig::preset(task);
        let get = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("task_cfg: missing {k}"))
        };
        cfg.vocab = get("vocab")?;
        cfg.vocab_tgt = get("vocab_tgt")?;
        cfg.n_classes = get("n_classes")?;
        cfg.dim = get("dim")?;
        cfg.hidden = get("hidden")?;
        cfg.layers = get("layers")?;
        cfg.batch = get("batch")?;
        cfg.seq = get("seq")?;
        cfg.eval_batches = get("eval_batches")?;
        cfg.seed = j
            .get("seed")
            .and_then(Json::as_str)
            .context("task_cfg: missing seed")?
            .parse::<u64>()
            .context("task_cfg: seed is not a u64")?;
        Ok(cfg)
    }
}

/// Per-class confusion counts of one held-out evaluation — kept by
/// the classification heads (pos/nli). Row-major
/// `counts[gold * n_classes + predicted]`; the fixed class order makes
/// the JSON rendering byte-deterministic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfusionMatrix {
    pub n_classes: usize,
    /// row-major counts: `counts[gold * n_classes + pred]`
    pub counts: Vec<u64>,
}

impl ConfusionMatrix {
    pub fn new(n_classes: usize) -> Self {
        ConfusionMatrix { n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, gold: usize, pred: usize) {
        self.counts[gold * self.n_classes + pred] += 1;
    }

    /// Total scored examples (sum over all cells).
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Diagonal sum — correct predictions.
    pub fn correct(&self) -> u64 {
        (0..self.n_classes).map(|c| self.counts[c * self.n_classes + c]).sum()
    }

    /// Gold-ordered rows, each a pred-ordered count array.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            (0..self.n_classes)
                .map(|g| {
                    Json::Arr(
                        (0..self.n_classes)
                            .map(|p| Json::Num(self.counts[g * self.n_classes + p] as f64))
                            .collect(),
                    )
                })
                .collect(),
        )
    }
}

/// One row of the Table-IV-style evaluation grid.
#[derive(Clone, Debug)]
pub struct TaskEval {
    pub task: &'static str,
    /// mean cross-entropy (nats) per scored token/example, held-out
    pub loss: f64,
    /// `"ppl"` (lm/mt), `"tag_acc"` (pos), `"cls_acc"` (nli)
    pub metric_name: &'static str,
    pub metric: f64,
    /// scored positions (PAD-masked targets excluded)
    pub count: usize,
    /// per-class confusion counts (pos/nli only; `None` for lm/mt
    /// whose per-token "classes" are the whole vocabulary)
    pub confusion: Option<ConfusionMatrix>,
    /// per-shard span timings of the sharded eval pass, ascending-span
    /// order. **Timing data**: never folded into loss/metric/count and
    /// never rendered into the eval report JSON (which stays
    /// byte-identical trace-on vs trace-off) — `eval --trace` emits
    /// them as `eval_span` events with the wall clock under `"timing"`.
    pub spans: Vec<SpanTiming>,
    /// length-bucketed cross-entropy (mt only; `None` elsewhere):
    /// every scored target position of a lane lands in the bucket of
    /// that lane's total scored length, so the report separates short-
    /// from long-sequence quality. Always all four buckets in fixed
    /// order (zero-count buckets included) — byte-deterministic.
    pub length_buckets: Option<Vec<LengthBucket>>,
}

/// One target-length bucket of an mt evaluation: mean CE is
/// `loss / count` (guard the empty bucket).
#[derive(Clone, Copy, Debug)]
pub struct LengthBucket {
    /// inclusive scored-length range, e.g. `"9-16"` or `"33+"`
    pub label: &'static str,
    /// summed eval CE (nats) over the bucket's scored positions
    pub loss: f64,
    /// scored positions in the bucket
    pub count: u64,
}

/// Fixed bucket labels, index-aligned with [`length_bucket_index`].
pub const LENGTH_BUCKET_LABELS: [&str; 4] = ["1-8", "9-16", "17-32", "33+"];

/// Bucket index for a lane whose scored target length is `len`.
pub fn length_bucket_index(len: usize) -> usize {
    match len {
        0..=8 => 0,
        9..=16 => 1,
        17..=32 => 2,
        _ => 3,
    }
}

/// Wall-clock timing of one eval lane span (`[lo, hi)`), recorded by a
/// [`SpanTimer`] inside the shard worker.
#[derive(Clone, Copy, Debug)]
pub struct SpanTiming {
    pub lo: usize,
    pub hi: usize,
    /// scored positions this span contributed
    pub count: usize,
    /// wall-clock span duration — timing-only data
    pub ms: f64,
}

/// The per-task contract on top of the shared quantized machinery.
///
/// A window is split in two so the generic trainer owns the
/// loss-scale bookkeeping: [`Self::compute_window`] buffers the (still
/// loss-scaled) gradients, [`Self::apply_update`] finalizes and
/// applies them — or reports the FP8 overflow that makes the trainer
/// skip the step and shrink the scale.
pub trait TaskHead {
    fn kind(&self) -> TaskKind;
    fn config(&self) -> &TaskConfig;
    /// Forward (traced) + loss + backward over the next training
    /// batch; returns the mean unscaled loss per scored position.
    fn compute_window(&mut self, scale: f32) -> f64;
    /// Finalize + apply the buffered gradients; `false` = overflow.
    fn apply_update(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool;
    /// Deterministic held-out evaluation. Must not disturb training
    /// state (the LM head's carried lanes keep streaming). Sharded
    /// over `cfg.threads` on the fixed lane partition — byte-identical
    /// results for any worker count (see [`crate::train::parallel`]).
    fn evaluate(&self) -> TaskEval;
    /// Write a `.tensors` checkpoint carrying `meta/task_cfg` so
    /// `floatsd-lstm eval` can rebuild the task from the file alone.
    fn save_checkpoint(&self, path: &Path) -> Result<()>;
    /// Force the merged gradient buffers of the last
    /// [`Self::compute_window`] to materialize (the window's tree
    /// reduction is otherwise deferred into [`Self::apply_update`]);
    /// must run before [`Self::grad_tensors`] on traced steps.
    fn merge_grads(&mut self);
    /// Named merged gradient tensors of the last
    /// [`Self::compute_window`], still loss-scaled — the telemetry
    /// scan surface ([`crate::telemetry::grad_saturation`]); call
    /// [`Self::merge_grads`] first.
    fn grad_tensors(&self) -> Vec<(String, &[f32])>;
    /// Named live FloatSD8 weight matrices — the re-encode saturation
    /// scan surface ([`crate::telemetry::code_stats`]).
    fn weight_matrices(&self) -> Vec<(String, &QMatrix)>;
    /// Select the forward-kernel tier on every stack the head owns
    /// (runtime-only; applied by [`build_task`]/[`load_task`] from
    /// `cfg.kernel_tier`, so heads never persist it).
    fn set_kernel_tier(&mut self, tier: KernelTier);
    /// Select the SIMD execution path on every stack the head owns
    /// (runtime-only, like the tier; applied from `cfg.kernel_isa`).
    fn set_kernel_isa(&mut self, isa: IsaPath);
}

/// Build a fresh (deterministically initialized) head for a config.
pub fn build_task(cfg: &TaskConfig) -> Result<Box<dyn TaskHead>> {
    validate(cfg)?;
    let mut head: Box<dyn TaskHead> = match cfg.task {
        TaskKind::Lm => Box::new(lm::LmTask::new(cfg.clone())),
        TaskKind::Pos => Box::new(pos::PosTask::new(cfg.clone())),
        TaskKind::Nli => Box::new(nli::NliTask::new(cfg.clone())),
        TaskKind::Mt => Box::new(mt::MtTask::new(cfg.clone())),
    };
    head.set_kernel_tier(cfg.kernel_tier);
    head.set_kernel_isa(cfg.kernel_isa);
    Ok(head)
}

/// Extract and parse the `meta/task_cfg` blob from a checkpoint's
/// tensors, if present — the single parser shared by `floatsd-lstm
/// eval` and `floatsd-lstm serve`, so both rebuild identical task
/// topologies from the same file. `Ok(None)` means the file carries no
/// task metadata (a raw LM checkpoint).
pub fn read_task_cfg(tensors: &[Tensor]) -> Result<Option<TaskConfig>> {
    let Some(meta) = tensors.iter().find(|t| t.name == "meta/task_cfg") else {
        return Ok(None);
    };
    Ok(Some(TaskConfig::from_meta_json(&meta.as_text()?)?))
}

/// Rebuild a head from checkpointed parameters.
pub fn load_task(cfg: TaskConfig, bag: &ParamBag) -> Result<Box<dyn TaskHead>> {
    validate(&cfg)?;
    let tier = cfg.kernel_tier;
    let isa = cfg.kernel_isa;
    let mut head: Box<dyn TaskHead> = match cfg.task {
        TaskKind::Lm => Box::new(lm::LmTask::from_bag(cfg, bag)?),
        TaskKind::Pos => Box::new(pos::PosTask::from_bag(cfg, bag)?),
        TaskKind::Nli => Box::new(nli::NliTask::from_bag(cfg, bag)?),
        TaskKind::Mt => Box::new(mt::MtTask::from_bag(cfg, bag)?),
    };
    head.set_kernel_tier(tier);
    head.set_kernel_isa(isa);
    Ok(head)
}

/// Turn the generators' assert-style preconditions into errors before
/// any constructor can panic on them. The generator domain rules live
/// once, in [`crate::data::check_task_args`]; only the model-shape
/// and head-specific constraints are checked here.
fn validate(cfg: &TaskConfig) -> Result<()> {
    if cfg.dim == 0 || cfg.hidden == 0 || cfg.layers == 0 || cfg.batch == 0 {
        bail!("{}: dim/hidden/layers/batch must all be >= 1", cfg.task.name());
    }
    if cfg.seq < 2 {
        bail!("{}: seq {} too short (need >= 2)", cfg.task.name(), cfg.seq);
    }
    if cfg.eval_batches == 0 {
        bail!("{}: need >= 1 eval batch (the held-out set)", cfg.task.name());
    }
    if cfg.trace_every == 0 {
        bail!("{}: --trace-every must be >= 1 (N samples every N-th step)", cfg.task.name());
    }
    if cfg.task == TaskKind::Nli && cfg.n_classes != 3 {
        bail!("nli: labels are 3-way (entail/contradict/neutral), got {}", cfg.n_classes);
    }
    check_threads(cfg.threads)
        .with_context(|| format!("{}: invalid --threads {}", cfg.task.name(), cfg.threads))?;
    crate::data::check_task_args(cfg.task.name(), cfg.vocab, cfg.vocab_tgt, cfg.n_classes)
}

// ---------------------------------------------------------------------
// shared single-stack machinery
// ---------------------------------------------------------------------

/// One quantized stack + its FP16 masters + the lane-sharded
/// gradient/state buffers — the building block every head is made of
/// (`mt` uses two: encoder and decoder).
///
/// Training state lives **per lane shard** ([`LaneShard`]): each
/// shard owns its lanes' carried recurrent state, trace scratches,
/// and gradient buffers, so a window's shards can run on the parallel
/// engine ([`crate::train::run_shards`]) with no shared mutable
/// state; [`Self::collect_window`] folds the loss sums and leaves the
/// fixed-order gradient tree reduction pending so [`Self::apply`] can
/// overlap it with the finalize (or [`Self::ensure_merged`] runs it
/// eagerly for readers of [`Self::grads`]).
pub(crate) struct SingleStack {
    pub stack: QLstmStack,
    pub masters: MasterStack,
    /// merged (tree-reduced) gradients of the last collected window
    pub grads: StackGrads,
    /// the fixed lane partition's shards (a function of `batch` only)
    pub shards: Vec<LaneShard>,
    pub batch: usize,
    /// `true` while the last window's shard gradients are still
    /// unmerged — [`Self::collect_window`] defers the tree reduction
    /// so [`Self::apply`] can overlap it with the gradient finalize
    /// ([`merge_finalize_overlapped`]); [`Self::ensure_merged`] forces
    /// the classic merge for any path that reads [`Self::grads`].
    pending_merge: bool,
}

impl SingleStack {
    pub fn init(
        vocab: usize,
        dim: usize,
        hidden: usize,
        layers: usize,
        n_out: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        let (masters, stack) =
            MasterStack::init_with_stack_dims(vocab, dim, hidden, layers, n_out, seed);
        Self::from_parts(stack, masters, batch)
    }

    pub fn from_parts(stack: QLstmStack, masters: MasterStack, batch: usize) -> Self {
        let shards = LaneShard::build(&stack, batch);
        let grads = StackGrads::zeros(&stack);
        SingleStack { stack, masters, grads, shards, batch, pending_merge: false }
    }

    /// Zero every shard's carried recurrent state (per-window reset
    /// for tasks whose batches are independent examples).
    pub fn reset_state(&mut self) {
        for s in &mut self.shards {
            s.reset_state();
        }
    }

    /// Forward from fresh zero state with throwaway buffers — the
    /// evaluation path; never disturbs the carried training state.
    pub fn forward_fresh(&self, ids: &[Vec<usize>]) -> Vec<Vec<f32>> {
        let (mut hs, mut cs) = self.stack.zero_flat_state(self.batch);
        let mut scr = self.stack.trace_scratches(self.batch);
        let mut tape = StackTape::new(&self.stack, self.batch);
        self.stack.forward_batch_traced(ids, &mut hs, &mut cs, &mut scr, &mut tape)
    }

    /// Collect the shards' window results: the `(loss, scored)` sums
    /// fold immediately (in fixed shard order), but the gradient tree
    /// reduction is *deferred* — [`Self::apply`] overlaps it with the
    /// finalize, and [`Self::ensure_merged`] runs it on demand for
    /// readers of [`Self::grads`] (the telemetry gradient scan, the
    /// `mt` cross-stack overflow check).
    pub fn collect_window(&mut self) -> (f64, usize) {
        let mut loss = 0f64;
        let mut scored = 0usize;
        for s in &self.shards {
            loss += s.loss;
            scored += s.scored;
        }
        self.pending_merge = true;
        (loss, scored)
    }

    /// Force the classic fixed-order tree reduction ([`merge_shards`])
    /// into [`Self::grads`] if the last window is still unmerged.
    pub fn ensure_merged(&mut self) {
        if !self.pending_merge {
            return;
        }
        self.pending_merge = false;
        let SingleStack { shards, grads, .. } = self;
        let mut refs: Vec<&mut LaneShard> = shards.iter_mut().collect();
        merge_shards(&mut refs, grads);
    }

    /// Finalize + apply the merged gradients (single-stack heads). On
    /// the common path (window still unmerged, no clip norm) the tree
    /// merge overlaps slot-by-slot with the finalize
    /// ([`merge_finalize_overlapped`]) — bit-identical to the classic
    /// two-phase sequence, which still runs whenever [`Self::grads`]
    /// was already materialized or a global clip norm needs every slot
    /// merged first.
    pub fn apply(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool {
        let applied = if self.pending_merge && clip.is_none() {
            self.pending_merge = false;
            let SingleStack { shards, grads, .. } = self;
            let mut refs: Vec<&mut LaneShard> = shards.iter_mut().collect();
            let (_loss, _scored, ok) = merge_finalize_overlapped(&mut refs, grads, scale);
            ok
        } else {
            self.ensure_merged();
            finalize_grads(&mut self.grads, scale, clip)
        };
        if !applied {
            return false;
        }
        self.masters.apply(&mut self.stack, &self.grads, lr, momentum);
        true
    }
}

/// Column-major view of a flat `[B][T]` id matrix: `out[t][b]` — the
/// layout the traced forward consumes.
pub(crate) fn to_steps(x: &[i32], batch: usize, seq: usize) -> Vec<Vec<usize>> {
    assert_eq!(x.len(), batch * seq, "flat batch shape mismatch");
    (0..seq).map(|t| (0..batch).map(|b| x[b * seq + t] as usize).collect()).collect()
}

/// The same transpose for raw i32 targets (kept i32 so PAD masking
/// stays representable).
pub(crate) fn to_step_labels(y: &[i32], batch: usize, seq: usize) -> Vec<Vec<i32>> {
    assert_eq!(y.len(), batch * seq, "flat batch shape mismatch");
    (0..seq).map(|t| (0..batch).map(|b| y[b * seq + t]).collect()).collect()
}

/// Index of the largest logit (first on ties — deterministic).
pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------
// lane-sharded evaluation
// ---------------------------------------------------------------------

/// One lane span of a sharded evaluation pass: the half-open lane
/// range plus its locally accumulated results. Spans come from the
/// same fixed lane partition training uses ([`lane_spans`]), and the
/// heads fold finished spans in ascending-span order — so
/// `--threads N` evaluation is byte-identical to single-threaded.
pub(crate) struct EvalSpan {
    pub lo: usize,
    pub hi: usize,
    pub loss: f64,
    pub correct: usize,
    pub count: usize,
    /// row-major gold × predicted counts (empty when the task keeps
    /// no confusion matrix)
    pub confusion: Vec<u64>,
    /// wall clock the shard spent on this span (timing-only; surfaces
    /// as [`SpanTiming::ms`], never in the deterministic fold)
    pub ms: f64,
    /// per-length-bucket `(loss_sum, count)` accumulators (mt only;
    /// left empty by heads without buckets — [`fold_spans`] never
    /// touches them, the owning head folds them itself in the same
    /// ascending-span order)
    pub buckets: Vec<(f64, u64)>,
}

/// Fresh accumulator spans for a `batch`-lane evaluation;
/// `n_classes = 0` for heads without a confusion matrix.
pub(crate) fn eval_spans(batch: usize, n_classes: usize) -> Vec<EvalSpan> {
    lane_spans(batch)
        .into_iter()
        .map(|(lo, hi)| EvalSpan {
            lo,
            hi,
            loss: 0.0,
            correct: 0,
            count: 0,
            confusion: vec![0; n_classes * n_classes],
            ms: 0.0,
            buckets: Vec::new(),
        })
        .collect()
}

/// Extract the per-span wall-clock timings ([`TaskEval::spans`]) in
/// the same ascending-span order the fold uses.
pub(crate) fn span_timings(spans: &[EvalSpan]) -> Vec<SpanTiming> {
    spans
        .iter()
        .map(|sp| SpanTiming { lo: sp.lo, hi: sp.hi, count: sp.count, ms: sp.ms })
        .collect()
}

/// Fold finished spans in their fixed order into one [`TaskEval`]-
/// shaped tuple: `(loss_sum, correct, count, confusion)`.
pub(crate) fn fold_spans(spans: &[EvalSpan], n_classes: usize) -> (f64, usize, usize, Vec<u64>) {
    let mut loss = 0f64;
    let mut correct = 0usize;
    let mut count = 0usize;
    let mut confusion = vec![0u64; n_classes * n_classes];
    for sp in spans {
        loss += sp.loss;
        correct += sp.correct;
        count += sp.count;
        for (acc, &c) in confusion.iter_mut().zip(&sp.confusion) {
            *acc += c;
        }
    }
    (loss, correct, count, confusion)
}

// ---------------------------------------------------------------------
// checkpoint naming shared by every head
// ---------------------------------------------------------------------

pub(crate) use crate::lstm::model::param_key;

/// Serialize one stack's FP16 masters under `prefix` in the JAX layout
/// (the exact convention of
/// [`crate::train::Trainer::save_checkpoint`]): reloading re-quantizes
/// the masters to the same FloatSD8 codes the live stack serves.
pub(crate) fn stack_tensors(prefix: &str, stack: &QLstmStack, ms: &MasterStack) -> Vec<Tensor> {
    let (vocab, dim) = (stack.embed.vocab, stack.embed.dim);
    let mut tensors = vec![Tensor::from_f32(
        &param_key(prefix, "['emb']['emb']"),
        &[vocab, dim],
        &ms.emb,
    )];
    let mut in_dim = dim;
    for (l, m) in ms.layers.iter().enumerate() {
        let hidden = stack.layers[l].fwd.hidden;
        // QMatrix layout [4H][in] -> JAX layout [in][4H]
        let mut wx = vec![0f32; m.wx.len()];
        for r in 0..4 * hidden {
            for k in 0..in_dim {
                wx[k * 4 * hidden + r] = m.wx[r * in_dim + k];
            }
        }
        let mut wh = vec![0f32; m.wh.len()];
        for r in 0..4 * hidden {
            for k in 0..hidden {
                wh[k * 4 * hidden + r] = m.wh[r * hidden + k];
            }
        }
        let idx = l + 1;
        tensors.push(Tensor::from_f32(
            &param_key(prefix, &format!("['l{idx}']['wx']")),
            &[in_dim, 4 * hidden],
            &wx,
        ));
        tensors.push(Tensor::from_f32(
            &param_key(prefix, &format!("['l{idx}']['wh']")),
            &[hidden, 4 * hidden],
            &wh,
        ));
        tensors.push(Tensor::from_f32(
            &param_key(prefix, &format!("['l{idx}']['b']")),
            &[4 * hidden],
            &m.b,
        ));
        in_dim = hidden;
    }
    let n_out = stack.n_out();
    let mut ow = vec![0f32; ms.head_w.len()];
    for r in 0..n_out {
        for k in 0..in_dim {
            ow[k * n_out + r] = ms.head_w[r * in_dim + k];
        }
    }
    tensors.push(Tensor::from_f32(&param_key(prefix, "['out']['w']"), &[in_dim, n_out], &ow));
    tensors.push(Tensor::from_f32(&param_key(prefix, "['out']['b']"), &[n_out], &ms.head_b));
    tensors
}

/// Inverse of [`stack_tensors`]: rebuild `(live stack, masters)` from
/// a checkpoint sub-tree. The live weights are re-quantized from the
/// FP16 masters exactly like a fresh init, so a save → load round trip
/// serves bit-identical logits.
pub(crate) fn load_stack(bag: &ParamBag, prefix: &str) -> Result<(QLstmStack, MasterStack)> {
    let transpose = |src: &[f32], rows: usize, cols: usize| {
        let mut t = vec![0f32; src.len()];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = src[r * cols + c];
            }
        }
        t
    };

    let (esh, emb) = bag.f32(&[param_key(prefix, "['emb']['emb']").as_str()])?;
    if esh.len() != 2 {
        bail!("embedding under {prefix:?} must be rank 2, got {esh:?}");
    }
    let (vocab, dim) = (esh[0], esh[1]);
    let mut layers = Vec::new();
    let mut masters = Vec::new();
    let mut in_dim = dim;
    for l in 1usize.. {
        let wx_key = param_key(prefix, &format!("['l{l}']['wx']"));
        if l > 1 && bag.f32(&[wx_key.as_str()]).is_err() {
            break;
        }
        let (_, wx) = bag.f32(&[wx_key.as_str()])?;
        let (whs, wh) = bag.f32(&[param_key(prefix, &format!("['l{l}']['wh']")).as_str()])?;
        let (_, b) = bag.f32(&[param_key(prefix, &format!("['l{l}']['b']")).as_str()])?;
        let hidden = whs[0];
        layers.push(QLstmLayer {
            fwd: QLstmCell::from_jax_layout(in_dim, hidden, &wx, &wh, &b),
            bwd: None,
        });
        masters.push(MasterCell::new(
            transpose(&wx, in_dim, 4 * hidden),
            transpose(&wh, hidden, 4 * hidden),
            b.clone(),
        ));
        in_dim = hidden;
    }
    let (_, ow) = bag.f32(&[param_key(prefix, "['out']['w']").as_str()])?;
    let (obs, ob) = bag.f32(&[param_key(prefix, "['out']['b']").as_str()])?;
    let n_out = obs[0];
    let stack = QLstmStack {
        embed: Embedding { vocab, dim, table: emb.clone() },
        layers,
        head: Dense::from_jax_layout(in_dim, n_out, &ow, &ob),
    };
    let ms = MasterStack::from_parts(emb, masters, transpose(&ow, in_dim, n_out), ob);
    Ok((stack, ms))
}

// ---------------------------------------------------------------------
// the shared training loop
// ---------------------------------------------------------------------

/// Summary of a full [`TaskTrainer::train`] run.
#[derive(Clone, Debug)]
pub struct TaskTrainReport {
    pub losses: Vec<f64>,
    /// held-out evaluation at initialization (before any update)
    pub eval_init: TaskEval,
    /// held-out evaluation after the last step
    pub eval_final: TaskEval,
    pub steps_applied: usize,
    pub steps_skipped: u64,
    pub final_scale: f32,
}

/// The generic offline trainer: any [`TaskHead`] + the char-LM
/// trainer's loss-scale/skip discipline.
pub struct TaskTrainer {
    pub head: Box<dyn TaskHead>,
    pub scaler: LossScaler,
    pub steps_done: usize,
    pub steps_applied: usize,
    /// open `--trace` sink, if any (never touches the value path)
    trace: Option<TraceSink>,
    /// activation-clip counter baselines at sink creation
    act_base: (ActSnapshot, ActSnapshot),
}

impl TaskTrainer {
    pub fn new(cfg: TaskConfig) -> Result<Self> {
        let scaler = LossScaler::new(cfg.loss_scale);
        let mut trace = match &cfg.trace {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        let act_base = (telemetry::SIGMOID.snapshot(), telemetry::TANH.snapshot());
        let head = build_task(&cfg)?;
        if let Some(sink) = trace.as_mut() {
            // the checkpoint meta blob already carries the topology +
            // seed; add the training-only knobs the trace reader wants
            let Json::Obj(mut config) = Json::parse(&cfg.to_meta_json())? else {
                bail!("task_cfg meta must be a JSON object");
            };
            config.insert("steps".to_string(), Json::Num(cfg.steps as f64));
            config.insert("threads".to_string(), Json::Num(cfg.threads as f64));
            config.insert("loss_scale".to_string(), Json::Num(f64::from(cfg.loss_scale)));
            let mut fields = BTreeMap::new();
            fields.insert("config".to_string(), Json::Obj(config));
            sink.emit("run_start", 0, fields);
        }
        Ok(TaskTrainer { head, scaler, steps_done: 0, steps_applied: 0, trace, act_base })
    }

    /// One window: compute gradients, apply (or skip on overflow).
    pub fn step(&mut self) -> StepOutcome {
        // wall-clock is telemetry-only: it lands in the trace's marked
        // `timing` field and never influences any computed value;
        // `--trace-every N` samples the per-step events (and skips the
        // gradient scan) on all but every N-th step
        let trace_every = self.head.config().trace_every;
        let sampled = self.trace.is_some() && (self.steps_done + 1) % trace_every == 0;
        let timer = sampled.then(SpanTimer::start);
        let (lr, momentum, clip) = {
            let c = self.head.config();
            (c.lr, c.momentum, c.clip_norm)
        };
        let scale = self.scaler.scale;
        let loss = self.head.compute_window(scale);
        // telemetry: the merged gradients are still loss-scaled here —
        // force the deferred merge, then scan before apply_update
        // finalizes them in place
        let grads_ev = sampled.then(|| {
            self.head.merge_grads();
            trace::grads_json(&self.head.grad_tensors())
        });
        let applied = self.head.apply_update(scale, lr, momentum, clip);
        let scale_ev = if applied {
            self.steps_applied += 1;
            self.scaler.on_good_step()
        } else {
            Some(self.scaler.on_overflow())
        };
        self.steps_done += 1;
        if self.trace.is_some() {
            self.emit_step_events(loss, applied, scale, scale_ev, grads_ev, timer, sampled);
        }
        StepOutcome { loss, applied, scale }
    }

    /// Emit this step's trace events: `loss_scale` on scaler action
    /// (always — scaler actions are too rare and too important to
    /// sample away), `step`/`reencode` only on steps sampled by
    /// `--trace-every`. Only called with an open sink.
    #[allow(clippy::too_many_arguments)]
    fn emit_step_events(
        &mut self,
        loss: f64,
        applied: bool,
        scale: f32,
        scale_ev: Option<ScaleEvent>,
        grads_ev: Option<Json>,
        timer: Option<SpanTimer>,
        sampled: bool,
    ) {
        let step = self.steps_done as u64;
        let skipped = self.scaler.skipped;
        let acts = sampled.then(|| {
            trace::acts_json(
                telemetry::SIGMOID.snapshot().since(self.act_base.0),
                telemetry::TANH.snapshot().since(self.act_base.1),
            )
        });
        let reencode =
            (sampled && applied).then(|| trace::codes_json(&self.head.weight_matrices()));
        let Some(sink) = self.trace.as_mut() else { return };
        if let Some(ev) = scale_ev {
            let (cause, from, to) = match ev {
                ScaleEvent::Backoff { from, to } => ("backoff", from, to),
                ScaleEvent::Growth { from, to } => ("growth", from, to),
            };
            sink.emit("loss_scale", step, trace::scale_fields(cause, from, to, skipped));
        }
        let Some(acts) = acts else { return };
        let mut fields = BTreeMap::new();
        fields.insert("loss".to_string(), trace::fnum(loss));
        fields.insert("scale".to_string(), Json::Num(f64::from(scale)));
        fields.insert("applied".to_string(), Json::Bool(applied));
        fields.insert("skipped_total".to_string(), Json::Num(skipped as f64));
        if let Some(g) = grads_ev {
            fields.insert("grads".to_string(), g);
        }
        fields.insert("acts".to_string(), acts);
        if let Some(t) = &timer {
            fields.insert("timing".to_string(), trace::timing_json(t.elapsed_ms()));
        }
        sink.emit("step", step, fields);
        if let Some(weights) = reencode {
            let mut fields = BTreeMap::new();
            fields.insert("weights".to_string(), weights);
            sink.emit("reencode", step, fields);
        }
    }

    /// Emit the `run_end` event and flush/close the trace sink,
    /// surfacing any deferred IO error. No-op without a sink.
    fn finish_trace(&mut self) -> Result<()> {
        if self.trace.is_none() {
            return Ok(());
        }
        let acts = trace::acts_json(
            telemetry::SIGMOID.snapshot().since(self.act_base.0),
            telemetry::TANH.snapshot().since(self.act_base.1),
        );
        let weights = trace::codes_json(&self.head.weight_matrices());
        let mut fields = BTreeMap::new();
        fields.insert("steps".to_string(), Json::Num(self.steps_done as f64));
        fields.insert("applied".to_string(), Json::Num(self.steps_applied as f64));
        fields.insert("skipped".to_string(), Json::Num(self.scaler.skipped as f64));
        fields.insert("final_scale".to_string(), Json::Num(f64::from(self.scaler.scale)));
        fields.insert("weights".to_string(), weights);
        fields.insert("acts".to_string(), acts);
        let sink = self.trace.as_mut().expect("checked above");
        sink.emit("run_end", self.steps_done as u64, fields);
        sink.finish()
    }

    /// Point-in-time numerics-health block for bench rows: loss-scale
    /// totals + per-matrix FloatSD8 code stats. Deterministic — no
    /// wall-clock fields.
    pub fn numerics_snapshot(&self) -> Json {
        let mut scale = BTreeMap::new();
        scale.insert("final".to_string(), Json::Num(f64::from(self.scaler.scale)));
        scale.insert("applied".to_string(), Json::Num(self.steps_applied as f64));
        scale.insert("skipped".to_string(), Json::Num(self.scaler.skipped as f64));
        scale.insert("steps".to_string(), Json::Num(self.steps_done as f64));
        let mut m = BTreeMap::new();
        m.insert("loss_scale".to_string(), Json::Obj(scale));
        m.insert("weights".to_string(), trace::codes_json(&self.head.weight_matrices()));
        Json::Obj(m)
    }

    /// Run the configured number of steps, bracketed by held-out
    /// evaluations; writes the checkpoint at the end when configured.
    pub fn train(&mut self) -> Result<TaskTrainReport> {
        let (steps, log_every, checkpoint) = {
            let c = self.head.config();
            (c.steps, c.log_every, c.checkpoint.clone())
        };
        let eval_init = self.head.evaluate();
        let mut losses = Vec::with_capacity(steps);
        for s in 0..steps {
            let out = self.step();
            losses.push(out.loss);
            if log_every > 0 && (s + 1) % log_every == 0 {
                let window = &losses[losses.len().saturating_sub(log_every)..];
                let mean: f64 = window.iter().sum::<f64>() / window.len() as f64;
                println!(
                    "step {:>5}  loss {:.4}  scale {:>7.0}  skipped {:>4}{}",
                    s + 1,
                    mean,
                    out.scale,
                    self.scaler.skipped,
                    if out.applied { "" } else { "  (skipped)" }
                );
            }
        }
        let eval_final = self.head.evaluate();
        self.finish_trace()?;
        if let Some(path) = checkpoint {
            self.head.save_checkpoint(&path)?;
            println!("checkpoint: {}", path.display());
        }
        Ok(TaskTrainReport {
            losses,
            eval_init,
            eval_final,
            steps_applied: self.steps_applied,
            steps_skipped: self.scaler.skipped,
            final_scale: self.scaler.scale,
        })
    }
}

/// `floatsd-lstm train --task {lm,pos,nli,mt}` — see `main.rs` docs.
pub fn run_train_cli(args: &Args) -> Result<()> {
    let task = TaskKind::parse(args.opt("task").unwrap_or("lm"))?;
    let tier = PresetTier::parse(args.opt("preset").unwrap_or("default"))?;
    let preset = TaskConfig::preset_tier(task, tier);
    let parse_f32 = |key: &str, default: f32| -> Result<f32> {
        match args.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse::<f32>()?),
        }
    };
    // explicit flags override the preset tier; shape validation (and
    // its descriptive errors) happens in `build_task`, not via silent
    // clamping here
    let cfg = TaskConfig {
        task,
        vocab: args.opt_usize("vocab", preset.vocab)?,
        vocab_tgt: args.opt_usize("vocab-tgt", preset.vocab_tgt)?,
        n_classes: args.opt_usize("classes", preset.n_classes)?,
        dim: args.opt_usize("dim", preset.dim)?,
        hidden: args.opt_usize("hidden", preset.hidden)?,
        layers: args.opt_usize("layers", preset.layers)?,
        batch: args.opt_usize("batch", preset.batch)?,
        seq: args.opt_usize("seq", preset.seq)?,
        steps: args.opt_usize("steps", preset.steps)?,
        lr: parse_f32("lr", preset.lr)?,
        momentum: parse_f32("momentum", preset.momentum)?,
        seed: args.opt_u64("seed", preset.seed)?,
        loss_scale: parse_f32("loss-scale", preset.loss_scale)?,
        clip_norm: match args.opt("clip") {
            None => None,
            Some(v) => Some(v.parse::<f32>()?),
        },
        log_every: args.opt_usize("log-every", preset.log_every)?,
        eval_batches: args.opt_usize("eval-batches", preset.eval_batches)?,
        threads: args.opt_usize("threads", preset.threads)?,
        checkpoint: Some(PathBuf::from(
            args.opt_or("out", &format!("{}.tensors", task.name())),
        )),
        trace: args.opt("trace").map(PathBuf::from),
        trace_every: args.opt_usize("trace-every", 1)?,
        kernel_tier: KernelTier::parse(args.opt_or("kernel-tier", "decoded"))?,
        kernel_isa: IsaPath::parse(args.opt_or("kernel-isa", "auto"))?,
    };
    println!(
        "offline FloatSD8 multi-task training [{} preset]: task={} vocab={}{} dim={} hidden={} \
         layers={} | batch={} seq={} steps={} threads={} lr={} momentum={} loss-scale={}",
        tier.name(),
        task.name(),
        cfg.vocab,
        if task == TaskKind::Mt { format!("->{}", cfg.vocab_tgt) } else { String::new() },
        cfg.dim,
        cfg.hidden,
        cfg.layers,
        cfg.batch,
        cfg.seq,
        cfg.steps,
        cfg.threads,
        cfg.lr,
        cfg.momentum,
        cfg.loss_scale
    );
    let mut trainer = TaskTrainer::new(cfg)?;
    let report = trainer.train()?;
    let (e0, e1) = (&report.eval_init, &report.eval_final);
    let rel = 100.0 * (e0.loss - e1.loss) / e0.loss.max(1e-12);
    println!(
        "eval: loss {:.4} -> {:.4} ({rel:+.1}%) | {} {:.4} -> {:.4} over {} positions",
        e0.loss, e1.loss, e1.metric_name, e0.metric, e1.metric, e1.count
    );
    println!(
        "({} applied, {} skipped, final scale {})",
        report.steps_applied, report.steps_skipped, report.final_scale
    );
    println!("report it: floatsd-lstm eval --model <checkpoint> [--out report.json]");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_cfg_meta_round_trips() {
        for kind in TaskKind::ALL {
            let mut cfg = TaskConfig::preset(kind);
            cfg.vocab += 7;
            cfg.hidden = 13;
            // above 2^53: must survive the JSON round trip exactly
            cfg.seed = (1u64 << 53) + 1;
            let back = TaskConfig::from_meta_json(&cfg.to_meta_json()).unwrap();
            assert_eq!(back.task, cfg.task);
            assert_eq!(back.vocab, cfg.vocab);
            assert_eq!(back.vocab_tgt, cfg.vocab_tgt);
            assert_eq!(back.n_classes, cfg.n_classes);
            assert_eq!(back.dim, cfg.dim);
            assert_eq!(back.hidden, cfg.hidden);
            assert_eq!(back.layers, cfg.layers);
            assert_eq!(back.batch, cfg.batch);
            assert_eq!(back.seq, cfg.seq);
            assert_eq!(back.eval_batches, cfg.eval_batches);
            assert_eq!(back.seed, cfg.seed);
        }
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        let mut cfg = TaskConfig::preset(TaskKind::Pos);
        cfg.n_classes = 1;
        assert!(build_task(&cfg).is_err());
        let mut cfg = TaskConfig::preset(TaskKind::Nli);
        cfg.vocab = 4;
        assert!(build_task(&cfg).is_err());
        let mut cfg = TaskConfig::preset(TaskKind::Mt);
        cfg.vocab_tgt = 1;
        assert!(build_task(&cfg).is_err());
        let mut cfg = TaskConfig::preset(TaskKind::Lm);
        cfg.seq = 1;
        assert!(build_task(&cfg).is_err());
        let mut cfg = TaskConfig::preset(TaskKind::Lm);
        cfg.threads = 0;
        let err = build_task(&cfg).err().expect("0 threads must be refused").to_string();
        assert!(err.contains("threads"), "got: {err}");
    }

    #[test]
    fn preset_tiers_cover_every_task_and_validate() {
        for kind in TaskKind::ALL {
            let tiny = TaskConfig::preset_tier(kind, PresetTier::Tiny);
            let default = TaskConfig::preset_tier(kind, PresetTier::Default);
            let paper = TaskConfig::preset_tier(kind, PresetTier::Paper);
            assert!(tiny.hidden < default.hidden && default.hidden < paper.hidden);
            assert_eq!(paper.hidden, 256, "{}: paper tier is 256-wide", kind.name());
            assert_eq!(paper.layers, 2, "{}: paper tier is 2-layer", kind.name());
            for cfg in [tiny, default, paper] {
                validate(&cfg).expect("preset tiers must validate");
            }
        }
        assert_eq!(
            TaskConfig::preset_tier(TaskKind::Lm, PresetTier::Paper).vocab,
            10_000,
            "paper lm is the 10k-class LM"
        );
    }

    #[test]
    fn step_transposes_are_column_major() {
        // flat [B=2][T=3]: lane 0 = 1,2,3; lane 1 = 4,5,6
        let x = [1i32, 2, 3, 4, 5, 6];
        let ids = to_steps(&x, 2, 3);
        assert_eq!(ids, vec![vec![1usize, 4], vec![2, 5], vec![3, 6]]);
        let ys = to_step_labels(&x, 2, 3);
        assert_eq!(ys[0], vec![1, 4]);
    }

    #[test]
    fn argmax_is_first_on_ties() {
        assert_eq!(argmax(&[0.5, 2.0, 2.0, -1.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn save_load_stack_round_trips_bitwise() {
        use crate::tensorfile::{read_tensors, write_tensors};
        let core = SingleStack::init(20, 6, 9, 2, 5, 3, 77);
        let tensors = stack_tensors("enc", &core.stack, &core.masters);
        let dir = std::env::temp_dir().join("fsd_tasks_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.tensors");
        write_tensors(&path, &tensors).unwrap();
        let bag = ParamBag::from_tensors(read_tensors(&path).unwrap());
        let (stack2, _ms2) = load_stack(&bag, "enc").unwrap();
        // same topology, bit-identical forward
        let ids: Vec<Vec<usize>> = vec![vec![1, 7, 19], vec![0, 3, 5], vec![2, 2, 2]];
        let a = core.forward_fresh(&ids);
        let b = SingleStack::from_parts(stack2, _ms2, 3).forward_fresh(&ids);
        for (ra, rb) in a.iter().zip(&b) {
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.to_bits(), y.to_bits(), "reloaded stack diverged");
            }
        }
    }
}
