//! Evaluation harness — `floatsd-lstm eval`.
//!
//! Loads `.tensors` checkpoints written by the task heads, rebuilds
//! each task (topology + deterministic held-out stream) from the
//! checkpoint's own `meta/task_cfg` blob, runs the eval set, and
//! emits a machine-readable Table-IV-style grid as JSON. The grid
//! always covers **all four tasks**: tasks without a checkpoint are
//! evaluated at their deterministic preset initialization and marked
//! `"source": "init"` — so a single report shows trained-vs-untrained
//! per workload.
//!
//! Determinism contract: same checkpoints in, byte-identical JSON out
//! (fixed key order via `BTreeMap`, deterministic generators, no
//! timestamps). Pinned by `tests/tasks_train.rs`.
//!
//! Two saved reports diff against each other with `floatsd-lstm
//! report --diff a.json b.json` ([`crate::telemetry::report`]): the
//! same `--sat-delta-pp` / `--span-regression-pct` thresholds that
//! govern trace diffs flag per-task accuracy drift and loss/ppl
//! regressions between a baseline grid and a candidate grid.
//!
//! Report schema (`schema = "floatsd-eval-v1"`):
//!
//! ```json
//! {
//!   "schema": "floatsd-eval-v1",
//!   "tasks": {
//!     "lm":  { "source": "checkpoint:<path>" | "init",
//!              "loss": 2.31, "metric": 10.1, "metric_name": "ppl",
//!              "count": 1024,
//!              "config": { "vocab": 64, "hidden": 24, ... } },
//!     "pos": { ..., "confusion": [[gold0_pred0, ...], ...] },
//!     "nli": { ... },
//!     "mt":  { ..., "length_buckets": [
//!                {"label": "1-8", "loss": 12.3, "count": 30}, ...] }
//!   }
//! }
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::lstm::model::ParamBag;
use crate::tensorfile::json::Json;
use crate::tensorfile::read_tensors;

use crate::qmath::{IsaPath, KernelTier};

use super::{build_task, load_task, TaskConfig, TaskEval, TaskKind};

/// Evaluate one checkpoint: rebuild the task from its `meta/task_cfg`
/// (via the parser shared with `serve`) and run the held-out eval set
/// sharded over `threads` workers (byte-identical for any count —
/// the heads fold the fixed lane spans in canonical order).
pub fn evaluate_checkpoint(path: &Path, threads: usize) -> Result<(TaskConfig, TaskEval)> {
    evaluate_checkpoint_tier(path, threads, KernelTier::Decoded)
}

/// [`evaluate_checkpoint`] with an explicit forward-kernel tier
/// (`--kernel-tier`). Like `threads`, the tier is a runtime knob
/// applied after the checkpoint's `meta/task_cfg` is parsed — it is
/// never stored in (or read from) the checkpoint itself.
pub fn evaluate_checkpoint_tier(
    path: &Path,
    threads: usize,
    tier: KernelTier,
) -> Result<(TaskConfig, TaskEval)> {
    evaluate_checkpoint_exec(path, threads, tier, IsaPath::detect())
}

/// [`evaluate_checkpoint_tier`] with an explicit SIMD execution path
/// (`--kernel-isa`) — another runtime-only knob; reports are
/// bit-identical across every path.
pub fn evaluate_checkpoint_exec(
    path: &Path,
    threads: usize,
    tier: KernelTier,
    isa: IsaPath,
) -> Result<(TaskConfig, TaskEval)> {
    let tensors = read_tensors(path)?;
    let mut cfg = super::read_task_cfg(&tensors)?.with_context(|| {
        format!(
            "{}: no meta/task_cfg tensor — not a task checkpoint \
             (write one with `floatsd-lstm train --task ...`)",
            path.display()
        )
    })?;
    cfg.threads = threads;
    cfg.kernel_tier = tier;
    cfg.kernel_isa = isa;
    let bag = ParamBag::from_tensors(tensors);
    let head = load_task(cfg.clone(), &bag)?;
    Ok((cfg, head.evaluate()))
}

fn entry(cfg: &TaskConfig, eval: &TaskEval, source: &str) -> Json {
    let num = |v: usize| Json::Num(v as f64);
    let mut cfg_m = BTreeMap::new();
    cfg_m.insert("vocab".to_string(), num(cfg.vocab));
    cfg_m.insert("vocab_tgt".to_string(), num(cfg.vocab_tgt));
    cfg_m.insert("n_classes".to_string(), num(cfg.n_classes));
    cfg_m.insert("dim".to_string(), num(cfg.dim));
    cfg_m.insert("hidden".to_string(), num(cfg.hidden));
    cfg_m.insert("layers".to_string(), num(cfg.layers));
    cfg_m.insert("batch".to_string(), num(cfg.batch));
    cfg_m.insert("seq".to_string(), num(cfg.seq));
    cfg_m.insert("eval_batches".to_string(), num(cfg.eval_batches));
    cfg_m.insert("seed".to_string(), Json::Str(cfg.seed.to_string()));
    let mut m = BTreeMap::new();
    m.insert("source".to_string(), Json::Str(source.to_string()));
    m.insert("loss".to_string(), Json::Num(eval.loss));
    m.insert("metric".to_string(), Json::Num(eval.metric));
    m.insert("metric_name".to_string(), Json::Str(eval.metric_name.to_string()));
    m.insert("count".to_string(), num(eval.count));
    if let Some(cm) = &eval.confusion {
        // gold-ordered rows × pred-ordered columns; fixed class order
        // keeps the rendering byte-deterministic
        m.insert("confusion".to_string(), cm.to_json());
    }
    if let Some(buckets) = &eval.length_buckets {
        // all buckets in their fixed label order (zero-count included)
        // so the array shape is stable across runs and checkpoints
        m.insert(
            "length_buckets".to_string(),
            Json::Arr(
                buckets
                    .iter()
                    .map(|b| {
                        let mut o = BTreeMap::new();
                        o.insert("label".to_string(), Json::Str(b.label.to_string()));
                        o.insert("loss".to_string(), Json::Num(b.loss));
                        o.insert("count".to_string(), Json::Num(b.count as f64));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
    }
    m.insert("config".to_string(), Json::Obj(cfg_m));
    Json::Obj(m)
}

/// Build the full four-task grid. Checkpoints cover their own task;
/// the rest are evaluated at preset init. Pure (no output): this is
/// the embeddable API — `run_cli` owns the human-readable rendering.
pub fn build_report(models: &[PathBuf], threads: usize) -> Result<Json> {
    build_report_tier(models, threads, KernelTier::Decoded)
}

/// [`build_report`] with an explicit forward-kernel tier. The report
/// text itself never mentions the tier: a `shiftadd` report must be
/// byte-identical to a `decoded` one (pinned by
/// `tests/shiftadd_equivalence.rs`).
pub fn build_report_tier(models: &[PathBuf], threads: usize, tier: KernelTier) -> Result<Json> {
    build_report_exec(models, threads, tier, IsaPath::detect())
}

/// [`build_report_tier`] with an explicit SIMD execution path — the
/// report never mentions the ISA either: every path must produce the
/// same bytes (pinned by `tests/shiftadd_equivalence.rs`).
pub fn build_report_exec(
    models: &[PathBuf],
    threads: usize,
    tier: KernelTier,
    isa: IsaPath,
) -> Result<Json> {
    build_report_traced(models, threads, tier, isa, None)
}

/// [`build_report_tier`] with an optional trace sink: each task's
/// per-shard eval span timings ([`TaskEval::spans`]) are emitted as
/// `eval_span` events on the `floatsd-trace-v1` stream (wall clock
/// under `"timing"`; the train summarizer ignores unknown event
/// kinds). The report JSON itself is byte-identical with or without a
/// sink (pinned by `tests/serve_trace.rs`).
pub fn build_report_traced(
    models: &[PathBuf],
    threads: usize,
    tier: KernelTier,
    isa: IsaPath,
    mut trace: Option<&mut crate::telemetry::TraceSink>,
) -> Result<Json> {
    let mut emit_spans = |sink: &mut Option<&mut crate::telemetry::TraceSink>,
                          task: &str,
                          eval: &TaskEval| {
        if let Some(sink) = sink.as_deref_mut() {
            for sp in &eval.spans {
                let mut f = BTreeMap::new();
                f.insert("task".to_string(), Json::Str(task.to_string()));
                f.insert("lo".to_string(), Json::Num(sp.lo as f64));
                f.insert("hi".to_string(), Json::Num(sp.hi as f64));
                f.insert("count".to_string(), Json::Num(sp.count as f64));
                let mut t = BTreeMap::new();
                t.insert("ms".to_string(), crate::telemetry::trace::fnum(sp.ms));
                f.insert("timing".to_string(), Json::Obj(t));
                sink.emit("eval_span", 0, f);
            }
        }
    };
    let mut tasks: BTreeMap<String, Json> = BTreeMap::new();
    for path in models {
        let (cfg, eval) = evaluate_checkpoint_exec(path, threads, tier, isa)
            .with_context(|| format!("evaluate {}", path.display()))?;
        let name = cfg.task.name().to_string();
        if tasks.contains_key(&name) {
            bail!("duplicate checkpoint for task {name}: {}", path.display());
        }
        emit_spans(&mut trace, &name, &eval);
        tasks.insert(name, entry(&cfg, &eval, &format!("checkpoint:{}", path.display())));
    }
    for kind in TaskKind::ALL {
        if tasks.contains_key(kind.name()) {
            continue;
        }
        let mut cfg = TaskConfig::preset(kind);
        cfg.threads = threads;
        cfg.kernel_tier = tier;
        cfg.kernel_isa = isa;
        let head = build_task(&cfg)?;
        let eval = head.evaluate();
        emit_spans(&mut trace, kind.name(), &eval);
        tasks.insert(kind.name().to_string(), entry(&cfg, &eval, "init"));
    }
    let mut root = BTreeMap::new();
    let schema = crate::telemetry::report::EVAL_SCHEMA;
    root.insert("schema".to_string(), Json::Str(schema.to_string()));
    root.insert("tasks".to_string(), Json::Obj(tasks));
    Ok(Json::Obj(root))
}

/// `floatsd-lstm eval [--model a.tensors[,b.tensors...]] [ckpt ...]
/// [--out report.json]` — see `main.rs` docs.
///
/// The human-readable grid goes to **stderr**; stdout carries only
/// the JSON document, so `floatsd-lstm eval | jq .` works.
pub fn run_cli(args: &Args) -> Result<()> {
    let mut models: Vec<PathBuf> = Vec::new();
    if let Some(list) = args.opt("model") {
        models.extend(list.split(',').filter(|s| !s.is_empty()).map(PathBuf::from));
    }
    models.extend(args.positionals.iter().map(PathBuf::from));
    let threads = args.opt_usize("threads", 1)?;
    let tier = KernelTier::parse(args.opt_or("kernel-tier", "decoded"))?;
    let isa = IsaPath::parse(args.opt_or("kernel-isa", "auto"))?;
    let mut sink = match args.opt("trace") {
        Some(path) => Some(crate::telemetry::TraceSink::create(Path::new(path))?),
        None => None,
    };
    let report = build_report_traced(&models, threads, tier, isa, sink.as_mut())?;
    if let Some(sink) = &mut sink {
        sink.finish()?;
    }

    eprintln!("Table-IV grid (held-out eval):");
    if let Some(tasks) = report.get("tasks").and_then(Json::as_obj) {
        for (name, e) in tasks {
            let s = |k: &str| e.get(k).and_then(Json::as_str).unwrap_or("?");
            let n = |k: &str| e.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            eprintln!(
                "  {:<4} loss {:.4}  {} {:.4}  ({} positions)  [{}]",
                name,
                n("loss"),
                s("metric_name"),
                n("metric"),
                e.get("count").and_then(Json::as_usize).unwrap_or(0),
                s("source")
            );
        }
    }
    let text = report.to_string();
    println!("{text}");
    if let Some(out) = args.opt("out") {
        std::fs::write(out, format!("{text}\n")).with_context(|| format!("write {out}"))?;
        eprintln!("report: {out}");
    }
    Ok(())
}
