//! Language-modeling head: per-step next-token cross-entropy over the
//! vocabulary, on the `data::lm` Markov stream. The lanes are
//! contiguous streams, so the recurrent state carries across training
//! windows (stateful truncated BPTT) — the same protocol as the
//! char-LM [`crate::train::Trainer`], rehosted on the [`TaskHead`]
//! contract so it trains and evaluates beside the other heads.
//! Checkpoints use the unprefixed parameter names and therefore stay
//! loadable by `floatsd-lstm serve --model`.

use std::path::Path;

use anyhow::Result;

use crate::data::lm::LmGen;
use crate::data::BatchSource;
use crate::lstm::model::ParamBag;
use crate::tensorfile::{write_tensors, Tensor};
use crate::train::{eval_ce, lane_slice_ids, masked_cross_entropy_grad, run_shards, StackTape};

use super::{
    eval_spans, fold_spans, load_stack, stack_tensors, to_step_labels, to_steps, SingleStack,
    TaskConfig, TaskEval, TaskHead, TaskKind,
};
use crate::qmath::vector::QMatrix;

pub struct LmTask {
    cfg: TaskConfig,
    core: SingleStack,
    gen: LmGen,
    steps_done: usize,
}

impl LmTask {
    pub fn new(cfg: TaskConfig) -> Self {
        let core = SingleStack::init(
            cfg.vocab,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            cfg.vocab,
            cfg.batch,
            cfg.seed,
        );
        Self::with_core(cfg, core)
    }

    pub fn from_bag(cfg: TaskConfig, bag: &ParamBag) -> Result<Self> {
        let (stack, masters) = load_stack(bag, "")?;
        let core = SingleStack::from_parts(stack, masters, cfg.batch);
        Ok(Self::with_core(cfg, core))
    }

    fn with_core(cfg: TaskConfig, core: SingleStack) -> Self {
        // same data-seed convention as the char-LM trainer
        let gen = LmGen::new(cfg.batch, cfg.seq, cfg.vocab, cfg.eval_batches, cfg.data_seed());
        LmTask { cfg, core, gen, steps_done: 0 }
    }
}

impl TaskHead for LmTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Lm
    }

    fn config(&self) -> &TaskConfig {
        &self.cfg
    }

    fn compute_window(&mut self, scale: f32) -> f64 {
        let (b_n, seq, vocab) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab);
        let threads = self.cfg.threads;
        let batch = self.gen.next_train();
        let ids = to_steps(&batch.x, b_n, seq);
        let targets = to_step_labels(&batch.y, b_n, seq);

        let inv = 1.0 / (b_n * seq) as f32;
        let core = &mut self.core;
        let stack = &core.stack;
        let ids_ref = &ids;
        let targets_ref = &targets;
        run_shards(&mut core.shards, threads, |_, shard| {
            shard.begin_window();
            // state carries across windows: no reset — the lanes are
            // contiguous streams and the shard owns them permanently
            let ids_s = lane_slice_ids(ids_ref, shard.lo, shard.hi);
            let (tape, logits) = shard.forward_traced(stack, &ids_s);
            let lanes = shard.lanes();
            let mut loss_sum = 0f64;
            let mut scored = 0usize;
            let mut dlogits = Vec::with_capacity(seq);
            for t in 0..seq {
                let mut dl = vec![0f32; lanes * vocab];
                let (l, n) = masked_cross_entropy_grad(
                    &logits[t],
                    &targets_ref[t][shard.lo..shard.hi],
                    vocab,
                    None,
                    inv,
                    scale,
                    &mut dl,
                );
                loss_sum += l;
                scored += n;
                dlogits.push(dl);
            }
            shard.loss = loss_sum;
            shard.scored = scored;
            shard.backward(stack, &tape, &dlogits);
        });
        let (loss_sum, scored) = core.collect_window();
        self.steps_done += 1;
        loss_sum / scored.max(1) as f64
    }

    fn apply_update(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool {
        self.core.apply(scale, lr, momentum, clip)
    }

    fn evaluate(&self) -> TaskEval {
        let (b_n, seq, vocab) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab);
        // the eval lanes are contiguous held-out streams: each span
        // carries its lanes' state across the fixed eval batches,
        // starting from zero (local buffers — training state is
        // untouched). Lanes are independent, so per-position CE values
        // are bit-identical to a full-width pass; only the span-ordered
        // f64 fold defines the sum, and that order is fixed.
        let stack = &self.core.stack;
        let batches: Vec<(Vec<Vec<usize>>, &[i32])> = self
            .gen
            .eval_set()
            .iter()
            .map(|b| (to_steps(&b.x, b_n, seq), b.y.as_slice()))
            .collect();
        let mut spans = eval_spans(b_n, 0);
        run_shards(&mut spans, self.cfg.threads, |_, sp| {
            let timer = crate::telemetry::SpanTimer::start();
            let lanes = sp.hi - sp.lo;
            let (mut hs, mut cs) = stack.zero_flat_state(lanes);
            let mut scr = stack.trace_scratches(lanes);
            for (ids, ys) in &batches {
                let ids_s = lane_slice_ids(ids, sp.lo, sp.hi);
                let mut tape = StackTape::new(stack, lanes);
                let logits =
                    stack.forward_batch_traced(&ids_s, &mut hs, &mut cs, &mut scr, &mut tape);
                for (t, row) in logits.iter().enumerate() {
                    for b in 0..lanes {
                        let y = ys[(sp.lo + b) * seq + t] as usize;
                        sp.loss += eval_ce(&row[b * vocab..(b + 1) * vocab], y);
                        sp.count += 1;
                    }
                }
            }
            sp.ms = timer.elapsed_ms();
        });
        let (loss_sum, _, count, _) = fold_spans(&spans, 0);
        let loss = loss_sum / count.max(1) as f64;
        TaskEval {
            task: "lm",
            loss,
            metric_name: "ppl",
            metric: loss.exp(),
            count,
            confusion: None,
            spans: super::span_timings(&spans),
            length_buckets: None,
        }
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tensors = stack_tensors("", &self.core.stack, &self.core.masters);
        tensors.push(Tensor::from_text("meta/task_cfg", &self.cfg.to_meta_json()));
        tensors.push(Tensor::scalar_f32("meta/steps", self.steps_done as f32));
        write_tensors(path, &tensors)
    }

    fn merge_grads(&mut self) {
        self.core.ensure_merged();
    }

    fn grad_tensors(&self) -> Vec<(String, &[f32])> {
        self.core.grads.named_slices("")
    }

    fn weight_matrices(&self) -> Vec<(String, &QMatrix)> {
        crate::telemetry::stack_qmatrices(&self.core.stack, "")
    }

    fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.core.stack.set_kernel_tier(tier);
    }

    fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.core.stack.set_kernel_isa(isa);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TaskConfig {
        let mut cfg = TaskConfig::preset(TaskKind::Lm);
        cfg.vocab = 32;
        cfg.dim = 8;
        cfg.hidden = 10;
        cfg.batch = 4;
        cfg.seq = 8;
        cfg.eval_batches = 2;
        cfg.seed = 5;
        cfg
    }

    #[test]
    fn first_window_loss_sits_near_uniform() {
        let mut task = LmTask::new(tiny_cfg());
        let loss = task.compute_window(1024.0);
        let uniform = (32f64).ln();
        assert!((loss - uniform).abs() < 1.5, "loss {loss} vs ln V {uniform}");
        assert!(task.apply_update(1024.0, 0.3, 0.9, None));
    }

    #[test]
    fn evaluation_does_not_disturb_training_state() {
        let mut task = LmTask::new(tiny_cfg());
        task.compute_window(1024.0);
        let hs_before: Vec<Vec<Vec<f32>>> =
            task.core.shards.iter().map(|s| s.hs.clone()).collect();
        let e1 = task.evaluate();
        let e2 = task.evaluate();
        let hs_after: Vec<Vec<Vec<f32>>> =
            task.core.shards.iter().map(|s| s.hs.clone()).collect();
        assert_eq!(hs_after, hs_before, "evaluate touched carried state");
        assert_eq!(e1.loss.to_bits(), e2.loss.to_bits(), "eval must be deterministic");
        assert!(e1.count > 0);
        assert!((e1.metric - e1.loss.exp()).abs() < 1e-12);
    }
}
