//! POS-tagging head: per-timestep classification over the tag set on
//! the `data::pos` template grammar. Every batch is a fresh set of
//! sentences, so the recurrent state resets each window; every
//! position carries a tag (the generator emits no PAD), but the loss
//! still goes through the masked cross-entropy so the masking rules
//! are uniform across heads. Metric: held-out tag accuracy.

use std::path::Path;

use anyhow::Result;

use crate::data::pos::PosGen;
use crate::data::BatchSource;
use crate::lstm::model::ParamBag;
use crate::tensorfile::{write_tensors, Tensor};
use crate::train::{eval_ce, lane_slice_ids, masked_cross_entropy_grad, run_shards, StackTape};

use super::{
    argmax, eval_spans, fold_spans, load_stack, stack_tensors, to_step_labels, to_steps,
    ConfusionMatrix, SingleStack, TaskConfig, TaskEval, TaskHead, TaskKind,
};
use crate::qmath::vector::QMatrix;

pub struct PosTask {
    cfg: TaskConfig,
    core: SingleStack,
    gen: PosGen,
    steps_done: usize,
}

impl PosTask {
    pub fn new(cfg: TaskConfig) -> Self {
        let core = SingleStack::init(
            cfg.vocab,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            cfg.n_classes,
            cfg.batch,
            cfg.seed,
        );
        Self::with_core(cfg, core)
    }

    pub fn from_bag(cfg: TaskConfig, bag: &ParamBag) -> Result<Self> {
        let (stack, masters) = load_stack(bag, "")?;
        let core = SingleStack::from_parts(stack, masters, cfg.batch);
        Ok(Self::with_core(cfg, core))
    }

    fn with_core(cfg: TaskConfig, core: SingleStack) -> Self {
        let gen = PosGen::new(
            cfg.batch,
            cfg.seq,
            cfg.vocab,
            cfg.n_classes,
            cfg.eval_batches,
            cfg.data_seed(),
        );
        PosTask { cfg, core, gen, steps_done: 0 }
    }
}

impl TaskHead for PosTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Pos
    }

    fn config(&self) -> &TaskConfig {
        &self.cfg
    }

    fn compute_window(&mut self, scale: f32) -> f64 {
        let (b_n, seq, n_tags) = (self.cfg.batch, self.cfg.seq, self.cfg.n_classes);
        let threads = self.cfg.threads;
        let batch = self.gen.next_train();
        let ids = to_steps(&batch.x, b_n, seq);
        let targets = to_step_labels(&batch.y, b_n, seq);

        let inv = 1.0 / (b_n * seq) as f32;
        let core = &mut self.core;
        let stack = &core.stack;
        let ids_ref = &ids;
        let targets_ref = &targets;
        run_shards(&mut core.shards, threads, |_, shard| {
            shard.begin_window();
            shard.reset_state(); // every batch is a fresh set of sentences
            let ids_s = lane_slice_ids(ids_ref, shard.lo, shard.hi);
            let (tape, logits) = shard.forward_traced(stack, &ids_s);
            let lanes = shard.lanes();
            let mut loss_sum = 0f64;
            let mut scored = 0usize;
            let mut dlogits = Vec::with_capacity(seq);
            for t in 0..seq {
                let mut dl = vec![0f32; lanes * n_tags];
                let (l, n) = masked_cross_entropy_grad(
                    &logits[t],
                    &targets_ref[t][shard.lo..shard.hi],
                    n_tags,
                    None,
                    inv,
                    scale,
                    &mut dl,
                );
                loss_sum += l;
                scored += n;
                dlogits.push(dl);
            }
            shard.loss = loss_sum;
            shard.scored = scored;
            shard.backward(stack, &tape, &dlogits);
        });
        let (loss_sum, scored) = core.collect_window();
        self.steps_done += 1;
        loss_sum / scored.max(1) as f64
    }

    fn apply_update(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool {
        self.core.apply(scale, lr, momentum, clip)
    }

    fn evaluate(&self) -> TaskEval {
        let (b_n, seq, n_tags) = (self.cfg.batch, self.cfg.seq, self.cfg.n_classes);
        // span-sharded over the fixed lane partition: lanes are
        // independent sentences, so per-position values are
        // bit-identical to a full-width pass, and the span-ordered
        // fold makes any `--threads N` byte-identical
        let stack = &self.core.stack;
        let batches: Vec<(Vec<Vec<usize>>, &[i32])> = self
            .gen
            .eval_set()
            .iter()
            .map(|b| (to_steps(&b.x, b_n, seq), b.y.as_slice()))
            .collect();
        let mut spans = eval_spans(b_n, n_tags);
        run_shards(&mut spans, self.cfg.threads, |_, sp| {
            let timer = crate::telemetry::SpanTimer::start();
            let lanes = sp.hi - sp.lo;
            for (ids, ys) in &batches {
                // fresh zero state per batch: independent sentences
                let ids_s = lane_slice_ids(ids, sp.lo, sp.hi);
                let (mut hs, mut cs) = stack.zero_flat_state(lanes);
                let mut scr = stack.trace_scratches(lanes);
                let mut tape = StackTape::new(stack, lanes);
                let logits =
                    stack.forward_batch_traced(&ids_s, &mut hs, &mut cs, &mut scr, &mut tape);
                for (t, row) in logits.iter().enumerate() {
                    for b in 0..lanes {
                        let y = ys[(sp.lo + b) * seq + t] as usize;
                        let lg = &row[b * n_tags..(b + 1) * n_tags];
                        sp.loss += eval_ce(lg, y);
                        let pred = argmax(lg);
                        sp.correct += usize::from(pred == y);
                        sp.count += 1;
                        sp.confusion[y * n_tags + pred] += 1;
                    }
                }
            }
            sp.ms = timer.elapsed_ms();
        });
        let (loss_sum, correct, count, counts) = fold_spans(&spans, n_tags);
        TaskEval {
            task: "pos",
            loss: loss_sum / count.max(1) as f64,
            metric_name: "tag_acc",
            metric: correct as f64 / count.max(1) as f64,
            count,
            confusion: Some(ConfusionMatrix { n_classes: n_tags, counts }),
            spans: super::span_timings(&spans),
            length_buckets: None,
        }
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tensors = stack_tensors("", &self.core.stack, &self.core.masters);
        tensors.push(Tensor::from_text("meta/task_cfg", &self.cfg.to_meta_json()));
        tensors.push(Tensor::scalar_f32("meta/steps", self.steps_done as f32));
        write_tensors(path, &tensors)
    }

    fn merge_grads(&mut self) {
        self.core.ensure_merged();
    }

    fn grad_tensors(&self) -> Vec<(String, &[f32])> {
        self.core.grads.named_slices("")
    }

    fn weight_matrices(&self) -> Vec<(String, &QMatrix)> {
        crate::telemetry::stack_qmatrices(&self.core.stack, "")
    }

    fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.core.stack.set_kernel_tier(tier);
    }

    fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.core.stack.set_kernel_isa(isa);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TaskConfig {
        let mut cfg = TaskConfig::preset(TaskKind::Pos);
        cfg.vocab = 60;
        cfg.n_classes = 6;
        cfg.dim = 8;
        cfg.hidden = 10;
        cfg.batch = 4;
        cfg.seq = 8;
        cfg.eval_batches = 2;
        cfg.seed = 9;
        cfg
    }

    #[test]
    fn first_window_loss_sits_near_uniform_over_tags() {
        let mut task = PosTask::new(tiny_cfg());
        let loss = task.compute_window(1024.0);
        let uniform = (6f64).ln();
        assert!((loss - uniform).abs() < 1.0, "loss {loss} vs ln K {uniform}");
        assert!(task.apply_update(1024.0, 0.3, 0.9, None));
    }

    #[test]
    fn eval_accuracy_starts_near_chance_and_is_deterministic() {
        let task = PosTask::new(tiny_cfg());
        let e1 = task.evaluate();
        let e2 = task.evaluate();
        assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
        assert_eq!(e1.metric.to_bits(), e2.metric.to_bits());
        // random init: accuracy should be within a loose band of 1/K
        assert!(e1.metric < 0.6, "suspiciously high init accuracy {}", e1.metric);
        assert!(e1.count == 2 * 4 * 8, "count {}", e1.count);
    }
}
