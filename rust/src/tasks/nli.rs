//! NLI head: the premise and hypothesis are read as one concatenated
//! sequence (`T = 2·seq`, hypothesis second so the final state is
//! dominated by it) and the **final hidden state** feeds a 3-way
//! classification — entailment / contradiction / neutral. The loss
//! attaches only to the last step's logits (`dlogits` are zero
//! everywhere else), so all earlier gradient flow is recurrent — the
//! long-horizon credit-assignment path where quantization error shows.
//! PAD tokens (id 0) appear *inside* the hypothesis as inputs; labels
//! are never PAD, so no target masking applies. Metric: held-out
//! classification accuracy.

use std::path::Path;

use anyhow::Result;

use crate::data::nli::NliGen;
use crate::data::BatchSource;
use crate::lstm::model::ParamBag;
use crate::tensorfile::{write_tensors, Tensor};
use crate::train::{eval_ce, lane_slice_ids, masked_cross_entropy_grad, run_shards, StackTape};

use super::{
    argmax, eval_spans, fold_spans, load_stack, stack_tensors, to_steps, ConfusionMatrix,
    SingleStack, TaskConfig, TaskEval, TaskHead, TaskKind,
};
use crate::qmath::vector::QMatrix;

pub struct NliTask {
    cfg: TaskConfig,
    core: SingleStack,
    gen: NliGen,
    steps_done: usize,
}

impl NliTask {
    pub fn new(cfg: TaskConfig) -> Self {
        let core = SingleStack::init(
            cfg.vocab,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            cfg.n_classes,
            cfg.batch,
            cfg.seed,
        );
        Self::with_core(cfg, core)
    }

    pub fn from_bag(cfg: TaskConfig, bag: &ParamBag) -> Result<Self> {
        let (stack, masters) = load_stack(bag, "")?;
        let core = SingleStack::from_parts(stack, masters, cfg.batch);
        Ok(Self::with_core(cfg, core))
    }

    fn with_core(cfg: TaskConfig, core: SingleStack) -> Self {
        let gen = NliGen::new(cfg.batch, cfg.seq, cfg.vocab, cfg.eval_batches, cfg.data_seed());
        NliTask { cfg, core, gen, steps_done: 0 }
    }
}

impl TaskHead for NliTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Nli
    }

    fn config(&self) -> &TaskConfig {
        &self.cfg
    }

    fn compute_window(&mut self, scale: f32) -> f64 {
        let (b_n, n_cls) = (self.cfg.batch, self.cfg.n_classes);
        let t_total = 2 * self.cfg.seq;
        let threads = self.cfg.threads;
        let batch = self.gen.next_train();
        // x is flat [B, 2, seq] — lane-major with 2·seq tokens per
        // lane, exactly the column transpose below
        let ids = to_steps(&batch.x, b_n, t_total);

        let inv = 1.0 / b_n as f32;
        let core = &mut self.core;
        let stack = &core.stack;
        let ids_ref = &ids;
        let labels_ref = &batch.y;
        run_shards(&mut core.shards, threads, |_, shard| {
            shard.begin_window();
            shard.reset_state(); // every batch is a fresh set of pairs
            let ids_s = lane_slice_ids(ids_ref, shard.lo, shard.hi);
            let (tape, logits) = shard.forward_traced(stack, &ids_s);
            let lanes = shard.lanes();
            // loss attaches only to the last step's logits
            let mut dlogits: Vec<Vec<f32>> =
                (0..t_total).map(|_| vec![0f32; lanes * n_cls]).collect();
            let (loss_sum, scored) = masked_cross_entropy_grad(
                &logits[t_total - 1],
                &labels_ref[shard.lo..shard.hi],
                n_cls,
                None,
                inv,
                scale,
                &mut dlogits[t_total - 1],
            );
            shard.loss = loss_sum;
            shard.scored = scored;
            shard.backward(stack, &tape, &dlogits);
        });
        let (loss_sum, scored) = core.collect_window();
        self.steps_done += 1;
        loss_sum / scored.max(1) as f64
    }

    fn apply_update(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool {
        self.core.apply(scale, lr, momentum, clip)
    }

    fn evaluate(&self) -> TaskEval {
        let (b_n, n_cls) = (self.cfg.batch, self.cfg.n_classes);
        let t_total = 2 * self.cfg.seq;
        // span-sharded over the fixed lane partition (see the pos
        // head): only the final step's logits score, one per pair
        let stack = &self.core.stack;
        let batches: Vec<(Vec<Vec<usize>>, &[i32])> = self
            .gen
            .eval_set()
            .iter()
            .map(|b| (to_steps(&b.x, b_n, t_total), b.y.as_slice()))
            .collect();
        let mut spans = eval_spans(b_n, n_cls);
        run_shards(&mut spans, self.cfg.threads, |_, sp| {
            let timer = crate::telemetry::SpanTimer::start();
            let lanes = sp.hi - sp.lo;
            for (ids, ys) in &batches {
                let ids_s = lane_slice_ids(ids, sp.lo, sp.hi);
                let (mut hs, mut cs) = stack.zero_flat_state(lanes);
                let mut scr = stack.trace_scratches(lanes);
                let mut tape = StackTape::new(stack, lanes);
                let logits =
                    stack.forward_batch_traced(&ids_s, &mut hs, &mut cs, &mut scr, &mut tape);
                let last = &logits[t_total - 1];
                for (b, &label) in ys[sp.lo..sp.hi].iter().enumerate() {
                    let y = label as usize;
                    let lg = &last[b * n_cls..(b + 1) * n_cls];
                    sp.loss += eval_ce(lg, y);
                    let pred = argmax(lg);
                    sp.correct += usize::from(pred == y);
                    sp.count += 1;
                    sp.confusion[y * n_cls + pred] += 1;
                }
            }
            sp.ms = timer.elapsed_ms();
        });
        let (loss_sum, correct, count, counts) = fold_spans(&spans, n_cls);
        TaskEval {
            task: "nli",
            loss: loss_sum / count.max(1) as f64,
            metric_name: "cls_acc",
            metric: correct as f64 / count.max(1) as f64,
            count,
            confusion: Some(ConfusionMatrix { n_classes: n_cls, counts }),
            spans: super::span_timings(&spans),
            length_buckets: None,
        }
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tensors = stack_tensors("", &self.core.stack, &self.core.masters);
        tensors.push(Tensor::from_text("meta/task_cfg", &self.cfg.to_meta_json()));
        tensors.push(Tensor::scalar_f32("meta/steps", self.steps_done as f32));
        write_tensors(path, &tensors)
    }

    fn merge_grads(&mut self) {
        self.core.ensure_merged();
    }

    fn grad_tensors(&self) -> Vec<(String, &[f32])> {
        self.core.grads.named_slices("")
    }

    fn weight_matrices(&self) -> Vec<(String, &QMatrix)> {
        crate::telemetry::stack_qmatrices(&self.core.stack, "")
    }

    fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.core.stack.set_kernel_tier(tier);
    }

    fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.core.stack.set_kernel_isa(isa);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TaskConfig {
        let mut cfg = TaskConfig::preset(TaskKind::Nli);
        cfg.vocab = 24;
        cfg.dim = 8;
        cfg.hidden = 10;
        cfg.batch = 6;
        cfg.seq = 5;
        cfg.eval_batches = 2;
        cfg.seed = 11;
        cfg
    }

    #[test]
    fn first_window_loss_sits_near_ln3() {
        let mut task = NliTask::new(tiny_cfg());
        let loss = task.compute_window(1024.0);
        let uniform = (3f64).ln();
        assert!((loss - uniform).abs() < 0.8, "loss {loss} vs ln 3 {uniform}");
        assert!(task.apply_update(1024.0, 0.3, 0.9, None));
    }

    #[test]
    fn gradient_reaches_the_embedding_through_the_final_step_only() {
        let mut task = NliTask::new(tiny_cfg());
        task.compute_window(1024.0);
        task.merge_grads();
        let emb_g: f32 = task.core.grads.emb.iter().map(|g| g.abs()).sum();
        assert!(emb_g > 0.0, "final-step loss must reach the embedding via recurrence");
    }

    #[test]
    fn eval_is_deterministic_with_sane_count() {
        let task = NliTask::new(tiny_cfg());
        let e1 = task.evaluate();
        let e2 = task.evaluate();
        assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
        assert_eq!(e1.count, 2 * 6, "one scored label per pair");
        assert!(e1.metric <= 1.0);
    }
}
