//! Translation head: encoder–decoder teacher-forced seq2seq on the
//! `data::translation` reverse+relabel task.
//!
//! Two independent quantized stacks share one gradient step:
//!
//! * **encoder** — source embedding → LSTM layers; its dense head is a
//!   vestigial 1-wide layer that never feeds a loss (`dlogits = []`);
//! * **decoder** — target embedding → LSTM layers → vocab_tgt head,
//!   teacher-forced on `y[:, t]` to predict `y[:, t + 1]` over the
//!   `BOS · mapped-reverse · EOS` target row (the last scored position
//!   is the EOS the serving decode loop retires on).
//!
//! Windows run on the lane-sharded parallel engine: encoder/decoder
//! shard pairs share a lane span, so the state bridge (forward copy +
//! backward [`crate::train::StateCot`] carry) never crosses a shard.
//!
//! The decoder's initial `(h, c)` per layer is the encoder's final
//! state; in the backward pass the decoder's initial-state cotangents
//! ([`crate::train::StateCot`]) re-enter the encoder at its last step via
//! [`backward_batch_carry`](crate::lstm::QLstmStack::backward_batch_carry)
//! — the gradient bridge that makes the bottleneck trainable. Targets
//! equal to PAD are masked out of loss and cotangent. Metric:
//! held-out per-token perplexity (eval CE).

use std::path::Path;

use anyhow::Result;

use crate::data::translation::{MtGen, PAD};
use crate::data::BatchSource;
use crate::lstm::model::ParamBag;
use crate::qmath::grad::grads_overflow;
use crate::tensorfile::{write_tensors, Tensor};
use crate::train::{
    eval_ce, finalize_grads, lane_slice_ids, masked_cross_entropy_grad, run_shards, LaneShard,
    StackTape,
};

use super::{
    eval_spans, fold_spans, length_bucket_index, load_stack, stack_tensors, to_steps,
    LengthBucket, SingleStack, TaskConfig, TaskEval, TaskHead, TaskKind, LENGTH_BUCKET_LABELS,
};
use crate::qmath::vector::QMatrix;

pub struct MtTask {
    cfg: TaskConfig,
    enc: SingleStack,
    dec: SingleStack,
    gen: MtGen,
    steps_done: usize,
}

impl MtTask {
    pub fn new(cfg: TaskConfig) -> Self {
        let enc = SingleStack::init(
            cfg.vocab,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            1, // loss-less head
            cfg.batch,
            cfg.seed,
        );
        let dec = SingleStack::init(
            cfg.vocab_tgt,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            cfg.vocab_tgt,
            cfg.batch,
            cfg.seed ^ 0x00DE_C0DE,
        );
        Self::with_parts(cfg, enc, dec)
    }

    pub fn from_bag(cfg: TaskConfig, bag: &ParamBag) -> Result<Self> {
        let (es, em) = load_stack(bag, "enc")?;
        let (ds, dm) = load_stack(bag, "dec")?;
        let enc = SingleStack::from_parts(es, em, cfg.batch);
        let dec = SingleStack::from_parts(ds, dm, cfg.batch);
        Ok(Self::with_parts(cfg, enc, dec))
    }

    fn with_parts(cfg: TaskConfig, enc: SingleStack, dec: SingleStack) -> Self {
        let gen = MtGen::new(
            cfg.batch,
            cfg.seq,
            cfg.seq + 2,
            cfg.vocab,
            cfg.vocab_tgt,
            cfg.eval_batches,
            cfg.data_seed(),
        );
        MtTask { cfg, enc, dec, gen, steps_done: 0 }
    }

    /// Teacher-forced decoder steps per example: the target row is
    /// `BOS · mapped-reverse · EOS` (length `seq + 2`), so the decoder
    /// consumes `seq + 1` inputs (`y[:, :-1]`) to predict `seq + 1`
    /// targets (`y[:, 1:]`) — the last scored position is EOS itself.
    fn dec_steps(s_len: usize) -> usize {
        s_len + 1
    }

    /// Teacher-forcing split of the flat target matrix `y [B][S+2]`:
    /// decoder inputs `y[:, t]` and targets `y[:, t + 1]`, both in the
    /// per-step column layout.
    fn teacher_forcing(
        y: &[i32],
        batch: usize,
        s_len: usize,
    ) -> (Vec<Vec<usize>>, Vec<Vec<i32>>) {
        let t_len = s_len + 2;
        let steps = Self::dec_steps(s_len);
        assert_eq!(y.len(), batch * t_len);
        let inputs = (0..steps)
            .map(|t| (0..batch).map(|b| y[b * t_len + t] as usize).collect())
            .collect();
        let targets = (0..steps)
            .map(|t| (0..batch).map(|b| y[b * t_len + t + 1]).collect())
            .collect();
        (inputs, targets)
    }
}

impl TaskHead for MtTask {
    fn kind(&self) -> TaskKind {
        TaskKind::Mt
    }

    fn config(&self) -> &TaskConfig {
        &self.cfg
    }

    fn compute_window(&mut self, scale: f32) -> f64 {
        let (b_n, s_len, v_tgt) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab_tgt);
        let threads = self.cfg.threads;
        let t_steps = Self::dec_steps(s_len);
        let batch = self.gen.next_train();
        let src_ids = to_steps(&batch.x, b_n, s_len);
        let (dec_ids, targets) = Self::teacher_forcing(&batch.y, b_n, s_len);

        let inv = 1.0 / (b_n * t_steps) as f32;
        let enc_stack = &self.enc.stack;
        let dec_stack = &self.dec.stack;
        let src_ref = &src_ids;
        let dec_ref = &dec_ids;
        let targets_ref = &targets;
        // encoder and decoder shard the same lane spans by
        // construction (one fixed partition of `batch`), so pairing by
        // index keeps each lane's state bridge entirely shard-local
        let mut pairs: Vec<(&mut LaneShard, &mut LaneShard)> =
            self.enc.shards.iter_mut().zip(self.dec.shards.iter_mut()).collect();
        run_shards(&mut pairs, threads, |_, (enc, dec)| {
            enc.begin_window();
            dec.begin_window();
            enc.reset_state();
            let src_s = lane_slice_ids(src_ref, enc.lo, enc.hi);
            let (tape_e, _enc_logits) = enc.forward_traced(enc_stack, &src_s);
            // state bridge: decoder starts from the encoder's final state
            dec.hs.clone_from(&enc.hs);
            dec.cs.clone_from(&enc.cs);
            let dec_s = lane_slice_ids(dec_ref, dec.lo, dec.hi);
            let (tape_d, logits) = dec.forward_traced(dec_stack, &dec_s);

            let lanes = dec.lanes();
            let mut loss_sum = 0f64;
            let mut scored = 0usize;
            let mut dlogits = Vec::with_capacity(t_steps);
            for t in 0..t_steps {
                let mut dl = vec![0f32; lanes * v_tgt];
                let (l, n) = masked_cross_entropy_grad(
                    &logits[t],
                    &targets_ref[t][dec.lo..dec.hi],
                    v_tgt,
                    Some(PAD),
                    inv,
                    scale,
                    &mut dl,
                );
                loss_sum += l;
                scored += n;
                dlogits.push(dl);
            }
            // the window loss lives on the decoder shard (the encoder
            // never feeds a loss)
            dec.loss = loss_sum;
            dec.scored = scored;

            // decoder backward hands back its initial-state cotangents;
            // they re-enter the encoder at its last step
            let cots = dec.backward_carry(dec_stack, &tape_d, &dlogits, None);
            enc.backward_carry(enc_stack, &tape_e, &[], Some(&cots));
        });
        drop(pairs);
        let (loss_sum, scored) = self.dec.collect_window();
        let _ = self.enc.collect_window();
        self.steps_done += 1;
        loss_sum / scored.max(1) as f64
    }

    fn apply_update(&mut self, scale: f32, lr: f32, momentum: f32, clip: Option<f32>) -> bool {
        // the cross-stack overflow verdict needs both merged gradient
        // buffers up front, so mt always takes the classic two-phase
        // path (no merge/finalize overlap)
        self.enc.ensure_merged();
        self.dec.ensure_merged();
        // all-or-nothing across both stacks: a half-applied step would
        // desynchronize the encoder/decoder pair
        let overflow = self.enc.grads.slices_mut().iter().any(|s| grads_overflow(s))
            || self.dec.grads.slices_mut().iter().any(|s| grads_overflow(s));
        if overflow {
            return false;
        }
        let ok = finalize_grads(&mut self.enc.grads, scale, clip)
            && finalize_grads(&mut self.dec.grads, scale, clip);
        debug_assert!(ok, "overflow was checked above");
        self.enc.masters.apply(&mut self.enc.stack, &self.enc.grads, lr, momentum);
        self.dec.masters.apply(&mut self.dec.stack, &self.dec.grads, lr, momentum);
        true
    }

    fn evaluate(&self) -> TaskEval {
        let (b_n, s_len, v_tgt) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab_tgt);
        let t_steps = Self::dec_steps(s_len);
        let t_len = s_len + 2;
        // span-sharded over the fixed lane partition: the
        // encoder→decoder state bridge is per-lane, so it never
        // crosses a span, and the span-ordered fold makes any
        // `--threads N` byte-identical
        let enc_stack = &self.enc.stack;
        let dec_stack = &self.dec.stack;
        let batches: Vec<(Vec<Vec<usize>>, Vec<Vec<usize>>, &[i32])> = self
            .gen
            .eval_set()
            .iter()
            .map(|b| {
                let (dec_ids, _) = Self::teacher_forcing(&b.y, b_n, s_len);
                (to_steps(&b.x, b_n, s_len), dec_ids, b.y.as_slice())
            })
            .collect();
        let mut spans = eval_spans(b_n, 0);
        run_shards(&mut spans, self.cfg.threads, |_, sp| {
            let timer = crate::telemetry::SpanTimer::start();
            let lanes = sp.hi - sp.lo;
            sp.buckets = vec![(0.0, 0); LENGTH_BUCKET_LABELS.len()];
            for (src_ids, dec_ids, ys) in &batches {
                let src_s = lane_slice_ids(src_ids, sp.lo, sp.hi);
                let dec_s = lane_slice_ids(dec_ids, sp.lo, sp.hi);
                // run the bridge on throwaway state: encoder final
                // state (left in hs/cs) becomes the decoder's initial
                let (mut hs, mut cs) = enc_stack.zero_flat_state(lanes);
                let mut escr = enc_stack.trace_scratches(lanes);
                let mut etape = StackTape::new(enc_stack, lanes);
                enc_stack.forward_batch_traced(&src_s, &mut hs, &mut cs, &mut escr, &mut etape);
                let mut dscr = dec_stack.trace_scratches(lanes);
                let mut dtape = StackTape::new(dec_stack, lanes);
                let logits =
                    dec_stack.forward_batch_traced(&dec_s, &mut hs, &mut cs, &mut dscr, &mut dtape);
                debug_assert_eq!(logits.len(), t_steps);
                // per-lane side accumulators for the length buckets;
                // `sp.loss` keeps its exact t-major accumulation order
                // (the held-out CE stays byte-identical with buckets on)
                let mut lane_loss = vec![0f64; lanes];
                let mut lane_count = vec![0usize; lanes];
                for (t, row) in logits.iter().enumerate() {
                    for b in 0..lanes {
                        let y = ys[(sp.lo + b) * t_len + t + 1];
                        if y == PAD {
                            continue;
                        }
                        let ce = eval_ce(&row[b * v_tgt..(b + 1) * v_tgt], y as usize);
                        sp.loss += ce;
                        sp.count += 1;
                        lane_loss[b] += ce;
                        lane_count[b] += 1;
                    }
                }
                // bucket each lane of this batch by its scored target
                // length (PAD-masked positions excluded)
                for (&l, &c) in lane_loss.iter().zip(&lane_count) {
                    if c > 0 {
                        let i = length_bucket_index(c);
                        sp.buckets[i].0 += l;
                        sp.buckets[i].1 += c as u64;
                    }
                }
            }
            sp.ms = timer.elapsed_ms();
        });
        let (loss_sum, _, count, _) = fold_spans(&spans, 0);
        let loss = loss_sum / count.max(1) as f64;
        // fold the buckets in the same ascending-span order as
        // `fold_spans` — `--threads N` stays byte-identical
        let mut folded = [(0f64, 0u64); LENGTH_BUCKET_LABELS.len()];
        for sp in &spans {
            for (acc, &(l, c)) in folded.iter_mut().zip(&sp.buckets) {
                acc.0 += l;
                acc.1 += c;
            }
        }
        let length_buckets = LENGTH_BUCKET_LABELS
            .iter()
            .zip(folded)
            .map(|(&label, (l, c))| LengthBucket { label, loss: l, count: c })
            .collect();
        TaskEval {
            task: "mt",
            loss,
            metric_name: "ppl",
            metric: loss.exp(),
            count,
            confusion: None,
            spans: super::span_timings(&spans),
            length_buckets: Some(length_buckets),
        }
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut tensors = stack_tensors("enc", &self.enc.stack, &self.enc.masters);
        tensors.extend(stack_tensors("dec", &self.dec.stack, &self.dec.masters));
        tensors.push(Tensor::from_text("meta/task_cfg", &self.cfg.to_meta_json()));
        tensors.push(Tensor::scalar_f32("meta/steps", self.steps_done as f32));
        write_tensors(path, &tensors)
    }

    fn merge_grads(&mut self) {
        self.enc.ensure_merged();
        self.dec.ensure_merged();
    }

    fn grad_tensors(&self) -> Vec<(String, &[f32])> {
        let mut v = self.enc.grads.named_slices("enc");
        v.extend(self.dec.grads.named_slices("dec"));
        v
    }

    fn weight_matrices(&self) -> Vec<(String, &QMatrix)> {
        let mut v = crate::telemetry::stack_qmatrices(&self.enc.stack, "enc");
        v.extend(crate::telemetry::stack_qmatrices(&self.dec.stack, "dec"));
        v
    }

    fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.enc.stack.set_kernel_tier(tier);
        self.dec.stack.set_kernel_tier(tier);
    }

    fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.enc.stack.set_kernel_isa(isa);
        self.dec.stack.set_kernel_isa(isa);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TaskConfig {
        let mut cfg = TaskConfig::preset(TaskKind::Mt);
        cfg.vocab = 16;
        cfg.vocab_tgt = 16;
        cfg.dim = 6;
        cfg.hidden = 8;
        cfg.batch = 3;
        cfg.seq = 4;
        cfg.eval_batches = 2;
        cfg.seed = 13;
        cfg
    }

    #[test]
    fn encoder_receives_gradient_through_the_state_bridge() {
        let mut task = MtTask::new(tiny_cfg());
        let loss = task.compute_window(1024.0);
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        task.merge_grads();
        let enc_wx: f32 = task.enc.grads.layers[0].dwx.iter().map(|g| g.abs()).sum();
        assert!(enc_wx > 0.0, "no gradient crossed the encoder/decoder bridge");
        let enc_emb: f32 = task.enc.grads.emb.iter().map(|g| g.abs()).sum();
        assert!(enc_emb > 0.0, "source embedding untouched by the bridge");
        // the loss-less encoder head must stay untouched
        assert!(task.enc.grads.head_w.iter().all(|&g| g == 0.0));
        assert!(task.enc.grads.head_b.iter().all(|&g| g == 0.0));
        assert!(task.apply_update(1024.0, 0.3, 0.9, None));
    }

    #[test]
    fn first_window_loss_sits_near_uniform_over_target_vocab() {
        let mut task = MtTask::new(tiny_cfg());
        let loss = task.compute_window(1024.0);
        let uniform = (16f64).ln();
        assert!((loss - uniform).abs() < 1.5, "loss {loss} vs ln V {uniform}");
    }

    #[test]
    fn eval_is_deterministic_and_scores_every_target_token() {
        let task = MtTask::new(tiny_cfg());
        let e1 = task.evaluate();
        let e2 = task.evaluate();
        assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
        // MtGen emits no PAD targets: count = eval_batches · B · (S+1)
        // (the +1 scores the EOS position the decoder must predict)
        assert_eq!(e1.count, 2 * 3 * (4 + 1));
    }

    #[test]
    fn length_buckets_partition_every_scored_position() {
        let task = MtTask::new(tiny_cfg());
        let e1 = task.evaluate();
        let b1 = e1.length_buckets.as_ref().expect("mt reports length buckets");
        assert_eq!(
            b1.iter().map(|b| b.label).collect::<Vec<_>>(),
            vec!["1-8", "9-16", "17-32", "33+"],
            "all buckets present in fixed order, zero-count included"
        );
        // every lane scores S+1 = 5 positions per eval batch, so the
        // whole count lands in the first bucket
        assert_eq!(b1[0].count as usize, e1.count);
        assert!(b1[1..].iter().all(|b| b.count == 0 && b.loss == 0.0));
        // bucket losses re-sum the span losses lane-wise: same numbers,
        // different association — equal up to rounding, and together
        // they must account for the full held-out CE
        let total: f64 = b1.iter().map(|b| b.loss).sum();
        let loss_sum = e1.loss * e1.count as f64;
        assert!(
            (total - loss_sum).abs() <= 1e-9 * loss_sum.abs().max(1.0),
            "bucket losses {total} should account for the fold {loss_sum}"
        );
        // byte-deterministic across repeated evaluations
        let e2 = task.evaluate();
        let b2 = e2.length_buckets.as_ref().unwrap();
        for (x, y) in b1.iter().zip(b2.iter()) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
            assert_eq!(x.count, y.count);
        }
    }

    #[test]
    fn bucket_index_boundaries() {
        use super::super::length_bucket_index as idx;
        assert_eq!(idx(1), 0);
        assert_eq!(idx(8), 0);
        assert_eq!(idx(9), 1);
        assert_eq!(idx(16), 1);
        assert_eq!(idx(17), 2);
        assert_eq!(idx(32), 2);
        assert_eq!(idx(33), 3);
        assert_eq!(idx(1000), 3);
    }
}
