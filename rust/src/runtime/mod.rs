//! PJRT runtime: load the AOT artifacts (`*.hlo.txt`), compile them on
//! the CPU PJRT client, and drive training/eval loops from rust — no
//! python anywhere on this path.
//!
//! Interchange contract (see `python/compile/aot.py`):
//!
//! * train step inputs:  `state[0..n], x:i32, y:i32`
//!   outputs: 1-tuple of `(state[0..n], loss_sum, metric_sum, count)`
//! * eval step inputs:   same; outputs `(loss_sum, metric_sum, count)`
//!
//! State round-trips through host literals once per step (PJRT's tuple
//! output buffers cannot be re-fed without decomposition — measured in
//! EXPERIMENTS.md §Perf; batch-dominated, not the bottleneck at these
//! model sizes).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactInfo, Manifest, TaskInfo};
use crate::data::Batch;
use crate::tensorfile;

/// The shared PJRT client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = artifacts_dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Load + compile one HLO text file (cached by file name).
    pub fn load_hlo(&mut self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.get(file) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {file}"))?;
        eprintln!("[runtime] compiled {file} in {:.2?}", t0.elapsed());
        let exe = Arc::new(exe);
        self.cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load initial state tensors for a task.
    pub fn load_init_state(&self, task: &TaskInfo) -> Result<Vec<xla::Literal>> {
        let path = self.manifest.dir.join(&task.init_file);
        let tensors = tensorfile::read_tensors(&path)?;
        if tensors.len() != task.n_state {
            bail!(
                "init state has {} tensors, manifest says {}",
                tensors.len(),
                task.n_state
            );
        }
        tensors.iter().map(literal_from_tensor).collect()
    }
}

fn literal_from_tensor(t: &tensorfile::Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    match t.dtype {
        tensorfile::DType::F32 => {
            let v = t.as_f32()?;
            let lit = if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&dims)?
            };
            Ok(lit)
        }
        tensorfile::DType::I32 => {
            let v = t.as_i32()?;
            let lit = if dims.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(&v).reshape(&dims)?
            };
            Ok(lit)
        }
    }
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

/// Per-step metrics returned by the train step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss_sum: f32,
    pub metric_sum: f32,
    pub count: f32,
}

impl StepMetrics {
    pub fn mean_loss(&self) -> f32 {
        self.loss_sum / self.count.max(1.0)
    }

    pub fn accuracy(&self) -> f32 {
        self.metric_sum / self.count.max(1.0)
    }

    pub fn perplexity(&self) -> f32 {
        self.mean_loss().exp()
    }

    /// The task's headline metric by name. Panics on an unrecognized
    /// name — silently defaulting to perplexity hid manifest typos
    /// (an "acuracy" task would report perplexity as its accuracy).
    pub fn named(&self, metric: &str) -> f32 {
        match metric {
            "accuracy" => self.accuracy() * 100.0,
            "perplexity" => self.perplexity(),
            other => panic!(
                "unknown metric name {other:?} (expected \"accuracy\" or \"perplexity\") — \
                 check the task's `metric` field in the artifacts manifest"
            ),
        }
    }
}

#[cfg(test)]
mod metric_tests {
    use super::StepMetrics;

    fn m() -> StepMetrics {
        StepMetrics { loss_sum: 2.0, metric_sum: 1.0, count: 2.0 }
    }

    #[test]
    fn named_matches_explicitly() {
        assert_eq!(m().named("accuracy"), 50.0);
        assert_eq!(m().named("perplexity"), 1f32.exp());
    }

    #[test]
    #[should_panic(expected = "unknown metric name")]
    fn named_rejects_unknown_metrics() {
        let _ = m().named("acuracy");
    }
}

/// A live training session over one artifact: owns the model/optimizer
/// state and the compiled executables.
pub struct TrainSession {
    pub artifact: ArtifactInfo,
    pub task: TaskInfo,
    train_exe: Arc<xla::PjRtLoadedExecutable>,
    eval_exe: Arc<xla::PjRtLoadedExecutable>,
    pub state: Vec<xla::Literal>,
    pub steps_done: u64,
    /// cumulative host<->device transfer time (perf accounting)
    pub transfer_time: std::time::Duration,
    /// cumulative execute time
    pub execute_time: std::time::Duration,
}

impl TrainSession {
    /// Create a session for `artifact_name`, loading the initial state.
    pub fn new(rt: &mut Runtime, artifact_name: &str) -> Result<TrainSession> {
        let artifact = rt.manifest.artifact(artifact_name)?.clone();
        let task = rt.manifest.task(&artifact.task)?.clone();
        let train_exe = rt.load_hlo(&artifact.train_hlo)?;
        let eval_exe = rt.load_hlo(&artifact.eval_hlo)?;
        let state = rt.load_init_state(&task)?;
        Ok(TrainSession {
            artifact,
            task,
            train_exe,
            eval_exe,
            state,
            steps_done: 0,
            transfer_time: Default::default(),
            execute_time: Default::default(),
        })
    }

    fn batch_shapes(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let x = literal_i32(&batch.x, &batch.x_shape)?;
        let y = literal_i32(&batch.y, &batch.y_shape)?;
        Ok((x, y))
    }

    /// One training step: feeds the state + batch, replaces the state
    /// with the returned one, and reports the step metrics.
    pub fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        let t0 = Instant::now();
        let (x, y) = self.batch_shapes(batch)?;
        let mut args: Vec<&xla::Literal> = self.state.iter().collect();
        args.push(&x);
        args.push(&y);
        let t1 = Instant::now();
        let result = self.train_exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let t2 = Instant::now();
        let mut parts = out.to_tuple()?;
        let n = self.task.n_state;
        if parts.len() != n + 3 {
            bail!("train step returned {} outputs, want {}", parts.len(), n + 3);
        }
        let count = scalar_f32(&parts.pop().unwrap())?;
        let metric_sum = scalar_f32(&parts.pop().unwrap())?;
        let loss_sum = scalar_f32(&parts.pop().unwrap())?;
        self.state = parts;
        self.steps_done += 1;
        self.transfer_time += t1 - t0 + t2.elapsed();
        self.execute_time += t2 - t1;
        Ok(StepMetrics { loss_sum, metric_sum, count })
    }

    /// Evaluate over a set of batches (aggregated).
    pub fn eval(&self, batches: &[Batch]) -> Result<StepMetrics> {
        let mut agg = StepMetrics::default();
        for b in batches {
            let (x, y) = self.batch_shapes(b)?;
            let mut args: Vec<&xla::Literal> = self.state.iter().collect();
            args.push(&x);
            args.push(&y);
            let result = self.eval_exe.execute::<&xla::Literal>(&args)?;
            let out = result[0][0].to_literal_sync()?;
            let parts = out.to_tuple()?;
            if parts.len() != 3 {
                bail!("eval step returned {} outputs, want 3", parts.len());
            }
            agg.loss_sum += scalar_f32(&parts[0])?;
            agg.metric_sum += scalar_f32(&parts[1])?;
            agg.count += scalar_f32(&parts[2])?;
        }
        Ok(agg)
    }

    /// Save the current state as a checkpoint (`.tensors`).
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut tensors = Vec::with_capacity(self.state.len());
        for (i, lit) in self.state.iter().enumerate() {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let name = self
                .task
                .state_names
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("state_{i}"));
            let data = lit.to_vec::<f32>()?;
            tensors.push(tensorfile::Tensor::from_f32(&name, &dims, &data));
        }
        tensorfile::write_tensors(path, &tensors)?;
        Ok(())
    }
}
