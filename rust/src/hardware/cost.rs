//! Gate-level area/power model for Table VII (40 nm, 400 MHz).
//!
//! The paper synthesized both MACs with Synopsys Design Compiler and
//! measured power with PrimeTime PX; we have no EDA tools, so (per the
//! substitution rule) we estimate both designs from a component-level
//! netlist using standard datapath gate-count formulas and published
//! 40 nm standard-cell figures. The claim under test is the **ratio**
//! (paper: 7.66× area, 5.75× power) — absolute numbers are calibration.
//!
//! Cost basis (typical 40 nm LP library):
//! * 1 GE (NAND2) ≈ 0.71 µm²;
//! * dynamic power at 0.9 V: ≈ 2.0e-4 µW per GE per MHz at α = 0.15
//!   reference activity (components scale α by their toggle profile);
//! * leakage is negligible at LP 40 nm for these block sizes (< 2%) and
//!   folded into the dynamic coefficient.
//!
//! Both MACs are modeled with the *same* formulas — only the bit widths
//! and term counts differ — so modeling error largely cancels in the
//! ratio, which is the scientific point.

/// Area of one gate equivalent (NAND2) at 40 nm, µm².
pub const GE_AREA_UM2: f64 = 0.71;
/// Dynamic power coefficient: µW per GE per MHz at reference activity.
pub const PWR_UW_PER_GE_MHZ: f64 = 2.0e-4;
/// Clock frequency of Table VII (period 2.5 ns).
pub const FREQ_MHZ: f64 = 400.0;

/// One synthesizable component of a datapath.
#[derive(Clone, Debug)]
pub struct Component {
    pub name: &'static str,
    /// gate-equivalents
    pub ge: f64,
    /// switching-activity factor relative to the reference α
    pub activity: f64,
}

/// A block's full cost breakdown.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub name: &'static str,
    pub components: Vec<Component>,
}

impl CostReport {
    pub fn total_ge(&self) -> f64 {
        self.components.iter().map(|c| c.ge).sum()
    }

    pub fn area_um2(&self) -> f64 {
        self.total_ge() * GE_AREA_UM2
    }

    pub fn power_mw(&self) -> f64 {
        self.components
            .iter()
            .map(|c| c.ge * c.activity * PWR_UW_PER_GE_MHZ * FREQ_MHZ)
            .sum::<f64>()
            / 1000.0
    }
}

// ----------------------------------------------------------------------
// Datapath gate-count formulas (GE) — classic structural estimates.
// ----------------------------------------------------------------------

/// Full adder ≈ 6.5 GE; the workhorse of everything below.
const FA: f64 = 6.5;
/// D flip-flop ≈ 6 GE (incl. local clock buffer share).
const FF: f64 = 6.0;
/// 2:1 mux ≈ 2.5 GE.
const MUX2: f64 = 2.5;

/// n×m-bit array multiplier: AND array + CSA reduction + final CPA.
pub fn multiplier_ge(n: usize, m: usize) -> f64 {
    let and_array = (n * m) as f64 * 1.2;
    let csa = (n * m - n - m) as f64 * FA;
    let cpa = (n + m) as f64 * FA;
    and_array + csa + cpa
}

/// Barrel shifter routing an `in_bits`-wide significand into an
/// `out_bits` frame across `stages` mux levels: the shifting network
/// scales with the *operand* width (each stage muxes the operand), plus
/// per-output-bit routing/OR into the frame. Modeling the full frame
/// through every stage would double-count sparse operands — the whole
/// reason the FloatSD8 aligners (4-bit significands) are nearly free.
pub fn shifter_ge(in_bits: usize, out_bits: usize, stages: usize) -> f64 {
    (in_bits * stages) as f64 * MUX2 + out_bits as f64 * 0.6
}

/// Carry-propagate adder.
pub fn adder_ge(width: usize) -> f64 {
    width as f64 * FA
}

/// Wallace/CSA reduction of `terms` operands of `width` bits + final CPA.
pub fn csa_tree_ge(terms: usize, width: usize) -> f64 {
    if terms <= 1 {
        return 0.0;
    }
    ((terms - 2) * width) as f64 * FA + adder_ge(width + terms.next_power_of_two().trailing_zeros() as usize)
}

/// Magnitude comparator.
pub fn comparator_ge(width: usize) -> f64 {
    width as f64 * 1.5
}

/// Leading-zero detector + priority encode.
pub fn lzd_ge(width: usize) -> f64 {
    width as f64 * 1.0
}

/// Round-to-nearest-even logic at `width` bits.
pub fn rounder_ge(width: usize) -> f64 {
    width as f64 * 2.0
}

/// Pipeline register bank.
pub fn regs_ge(bits: usize) -> f64 {
    bits as f64 * FF
}

// ----------------------------------------------------------------------
// The two MACs of Table VII. Both take FOUR weight/input pairs per
// cycle plus the previous accumulator (Fig. 7/8), both run at 400 MHz,
// both are 5-stage pipelined.
// ----------------------------------------------------------------------

/// FP32 MAC: 4 × (fp32 × fp32) products + fp32 accumulator, single
/// rounding (fused). Mantissa datapath is 24 bits per operand,
/// 48-bit products aligned into a ~76-bit frame.
pub fn mac_cost_fp32() -> CostReport {
    let prod_w = 48; // 24×24 product width
    let frame_w = 76; // alignment frame: product + fp32 acc span + guard
    CostReport {
        name: "FP32 MAC (4-pair)",
        components: vec![
            Component { name: "4x 24x24 multiplier", ge: 4.0 * multiplier_ge(24, 24), activity: 0.25 },
            Component { name: "4x exponent adder (9b)", ge: 4.0 * adder_ge(9), activity: 0.10 },
            Component { name: "max-exp detect (5 terms)", ge: 5.0 * comparator_ge(9) + 4.0 * MUX2 * 9.0, activity: 0.10 },
            Component { name: "5x aligner (48b→76b)", ge: 5.0 * shifter_ge(prod_w, frame_w, 7), activity: 0.15 },
            Component { name: "CSA tree 5x76b", ge: csa_tree_ge(5, frame_w), activity: 0.20 },
            Component { name: "normalizer (LZD+shift)", ge: lzd_ge(frame_w) + shifter_ge(frame_w, frame_w, 7), activity: 0.12 },
            Component { name: "rounder (24b)", ge: rounder_ge(24), activity: 0.10 },
            Component {
                name: "pipeline regs (5 stg)",
                // s1: 4 products (48b) + exps; s2: aligned set compressed
                // to 3 carry-save words of 78b; s3: 2x78b; s4: 78b + exp;
                // s5: 32b result
                ge: regs_ge(4 * prod_w + 5 * 10 + 3 * 78 + 2 * 78 + 78 + 10 + 32),
                activity: 0.50,
            },
            Component { name: "control + clock share", ge: 450.0, activity: 0.45 },
        ],
    }
}

/// FloatSD8 MAC: 4 weights decode to ≤ 8 partial products, each a
/// shifted 4-bit fp8 significand; 22-bit alignment frame (fp16 target
/// + guard); single fp16 rounding.
pub fn mac_cost_fsd8() -> CostReport {
    let frame_w = 22; // fp16 mantissa 11 + tree growth 4 + guard/round/sticky
    CostReport {
        name: "FloatSD8 MAC (4-pair)",
        components: vec![
            Component { name: "4x FloatSD8 decoder", ge: 4.0 * 28.0, activity: 0.10 },
            // a partial product is just the 4-bit significand routed by
            // the decoded shift — the "multiplier" vanishes; generation
            // is folded into the aligners below (the paper's point).
            Component { name: "9x exp adder (6b)", ge: 9.0 * adder_ge(6), activity: 0.10 },
            Component { name: "max-exp detect (9 terms)", ge: 9.0 * comparator_ge(6) + 8.0 * MUX2 * 6.0, activity: 0.10 },
            Component { name: "9x aligner (4b→22b)", ge: 9.0 * shifter_ge(4, frame_w, 5), activity: 0.15 },
            Component { name: "CSA tree 9x22b", ge: csa_tree_ge(9, frame_w), activity: 0.20 },
            Component { name: "normalizer (LZD+shift)", ge: lzd_ge(frame_w) + shifter_ge(frame_w, frame_w, 5), activity: 0.12 },
            Component { name: "rounder (11b)", ge: rounder_ge(11), activity: 0.10 },
            Component {
                name: "pipeline regs (5 stg)",
                // s1: 8 pp sig+exp (4+6)b + acc; s2: aligned set compressed
                // to 4 carry-save words of 26b (first CSA level folds into
                // the align stage); s3: 2x26b; s4: 26b+6b; s5: 16b result
                ge: regs_ge(8 * 10 + 16 + 4 * 26 + 2 * 26 + 26 + 6 + 16),
                activity: 0.50,
            },
            Component { name: "control + clock share", ge: 220.0, activity: 0.45 },
        ],
    }
}

/// The Table VII comparison: (fp32, fsd8, area_ratio, power_ratio).
pub fn table7() -> (CostReport, CostReport, f64, f64) {
    let fp32 = mac_cost_fp32();
    let fsd8 = mac_cost_fsd8();
    let ar = fp32.area_um2() / fsd8.area_um2();
    let pr = fp32.power_mw() / fsd8.power_mw();
    (fp32, fsd8, ar, pr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formulas_are_monotone_in_width() {
        assert!(multiplier_ge(24, 24) > multiplier_ge(4, 24));
        assert!(shifter_ge(48, 76, 7) > shifter_ge(4, 22, 5));
        assert!(csa_tree_ge(9, 22) > csa_tree_ge(5, 22));
        assert_eq!(csa_tree_ge(1, 22), 0.0);
    }

    #[test]
    fn fp32_mac_in_papers_area_ballpark() {
        // Paper: 26661 µm². Accept the right order of magnitude —
        // we model structure, not a specific library.
        let a = mac_cost_fp32().area_um2();
        assert!((13_000.0..55_000.0).contains(&a), "fp32 area {a}");
    }

    #[test]
    fn fsd8_mac_in_papers_area_ballpark() {
        // Paper: 3479 µm².
        let a = mac_cost_fsd8().area_um2();
        assert!((1_700.0..7_000.0).contains(&a), "fsd8 area {a}");
    }

    #[test]
    fn ratios_reproduce_table7_shape() {
        let (_, _, ar, pr) = table7();
        // Paper: 7.66x area, 5.75x power. The reproduction criterion is
        // the shape: FloatSD8 is several-fold smaller & lower power.
        assert!(ar > 4.0 && ar < 12.0, "area ratio {ar}");
        assert!(pr > 3.5 && pr < 10.0, "power ratio {pr}");
    }

    #[test]
    fn power_positive_and_area_consistent() {
        for r in [mac_cost_fp32(), mac_cost_fsd8()] {
            assert!(r.power_mw() > 0.0);
            assert!((r.area_um2() - r.total_ge() * GE_AREA_UM2).abs() < 1e-9);
        }
    }
}
