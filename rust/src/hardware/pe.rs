//! The LSTM processing element (paper Fig. 7): output-stationary matrix
//! multiply between FP8 inputs and FloatSD8 weights with partial-sum
//! registers, built on the five-stage pipelined MAC.
//!
//! Reproduces both the *numerics* (via [`MacPipeline::compute`]) and
//! the *schedule*: one MAC group (4 pairs) issues per cycle; a group
//! whose accumulator is still in flight stalls (§V-A), so utilization
//! is `min(1, interleaved_outputs / 5)` — the paper's "with the batch
//! size larger than five, the hardware utilization would reach 100%".

use crate::formats::{Fp16, Fp8};
use crate::qmath::vector::QMatrix;

use super::mac_sim::{MacPipeline, PIPELINE_DEPTH};

/// Schedule/throughput statistics of one PE run.
#[derive(Clone, Copy, Debug, Default)]
pub struct PeStats {
    pub cycles: u64,
    pub mac_groups: u64,
    pub utilization: f64,
}

/// Output-stationary PE: weights resident, inputs streamed, one
/// partial-sum register per (output-neuron, batch-lane) pair.
pub struct ProcessingElement {
    /// How many output streams are interleaved in the pipe at once
    /// (the batch dimension of §V-A; register file depth).
    pub interleave: usize,
}

impl ProcessingElement {
    pub fn new(interleave: usize) -> Self {
        assert!(interleave >= 1);
        ProcessingElement { interleave }
    }

    /// Run `y[b] = W x[b] + bias` for a batch, bit-exactly via the
    /// pipelined MAC, and report the cycle schedule.
    ///
    /// `xs` is `[batch][cols]` of FP8 codes; returns `[batch][rows]`.
    pub fn forward(
        &self,
        w: &QMatrix,
        xs: &[Vec<Fp8>],
        bias: &[Fp16],
    ) -> (Vec<Vec<Fp16>>, PeStats) {
        let batch = xs.len();
        let mut out = vec![vec![Fp16::ZERO; w.rows]; batch];
        let mut pipe = MacPipeline::new();

        // schedule: for each output row, stream the k-dimension in MAC
        // groups, interleaving `interleave` batch lanes round-robin so
        // the accumulator RAW hazard is hidden.
        let groups_per_row = w.cols.div_ceil(4);
        for r in 0..w.rows {
            let row = w.row_codes(r);
            for (ci, chunk) in xs.chunks(self.interleave).enumerate() {
                let base = ci * self.interleave;
                // init accumulators with the bias
                let mut accs: Vec<Fp16> = vec![bias[r]; chunk.len()];
                for g in 0..groups_per_row {
                    let lo = g * 4;
                    let hi = (lo + 4).min(w.cols);
                    for (lane, x) in chunk.iter().enumerate() {
                        pipe.issue(lane);
                        accs[lane] =
                            MacPipeline::compute(accs[lane], &x[lo..hi], &row[lo..hi]);
                    }
                }
                for (lane, acc) in accs.into_iter().enumerate() {
                    out[base + lane][r] = acc;
                }
            }
        }
        // drain the pipe
        for _ in 0..PIPELINE_DEPTH {
            pipe.tick();
        }
        let stats = PeStats {
            cycles: pipe.cycle,
            mac_groups: pipe.issued,
            utilization: pipe.issued as f64 / pipe.cycle as f64,
        };
        (out, stats)
    }

    /// Pure schedule model (no numerics): cycles to compute a
    /// `rows × cols` matvec over `batch` lanes with this interleave
    /// depth. Used by the utilization bench (Fig. 7 / §V-A claim).
    pub fn schedule_cycles(&self, rows: usize, cols: usize, batch: usize) -> PeStats {
        let mut pipe = MacPipeline::new();
        let groups_per_row = cols.div_ceil(4);
        for _r in 0..rows {
            for chunk_start in (0..batch).step_by(self.interleave) {
                let lanes = (batch - chunk_start).min(self.interleave);
                for _g in 0..groups_per_row {
                    for lane in 0..lanes {
                        pipe.issue(lane);
                    }
                }
            }
        }
        for _ in 0..PIPELINE_DEPTH {
            pipe.tick();
        }
        PeStats {
            cycles: pipe.cycle,
            mac_groups: pipe.issued,
            utilization: pipe.issued as f64 / pipe.cycle as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f8;
    use crate::qmath::mac::{dot_fsd8_fp8, MacMode};
    use crate::rng::SplitMix64;

    fn setup(rows: usize, cols: usize, batch: usize) -> (QMatrix, Vec<Vec<Fp8>>, Vec<Fp16>) {
        let mut rng = SplitMix64::new((rows * 31 + cols * 7 + batch) as u64);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w = QMatrix::from_f32(rows, cols, &data);
        let xs: Vec<Vec<Fp8>> = (0..batch)
            .map(|_| (0..cols).map(|_| Fp8::from_f32(round_f8(rng.uniform(-3.0, 3.0)))).collect())
            .collect();
        let bias: Vec<Fp16> = (0..rows).map(|_| Fp16::from_f32(rng.uniform(-0.5, 0.5))).collect();
        (w, xs, bias)
    }

    #[test]
    fn pe_numerics_match_architectural_dot() {
        let (w, xs, bias) = setup(6, 18, 4);
        let pe = ProcessingElement::new(4);
        let (out, _) = pe.forward(&w, &xs, &bias);
        for (b, x) in xs.iter().enumerate() {
            for r in 0..w.rows {
                let want = dot_fsd8_fp8(bias[r], x, w.row_codes(r), MacMode::Exact);
                assert_eq!(out[b][r].0, want.0, "b={b} r={r}");
            }
        }
    }

    #[test]
    fn utilization_rises_with_batch_saturating_at_five() {
        let pe1 = ProcessingElement::new(1).schedule_cycles(16, 64, 1);
        let pe2 = ProcessingElement::new(2).schedule_cycles(16, 64, 2);
        let pe5 = ProcessingElement::new(5).schedule_cycles(16, 64, 5);
        let pe8 = ProcessingElement::new(8).schedule_cycles(16, 64, 8);
        assert!(pe1.utilization < 0.25, "batch1 {}", pe1.utilization);
        assert!(pe2.utilization < 0.45, "batch2 {}", pe2.utilization);
        assert!(pe5.utilization > 0.95, "batch5 {}", pe5.utilization);
        assert!(pe8.utilization > 0.97, "batch8 {}", pe8.utilization);
    }

    #[test]
    fn mac_group_count_is_work_volume() {
        let s = ProcessingElement::new(4).schedule_cycles(8, 32, 4);
        assert_eq!(s.mac_groups, 8 * (32 / 4) as u64 * 4);
    }
}
