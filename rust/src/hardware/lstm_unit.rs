//! The LSTM neuron circuit of paper Fig. 9: four PEs (one per gate
//! matmul), σ/tanh LUTs, cell-state memory and two elementwise
//! FloatSD8 MACs computing Eq. (5)/(6).
//!
//! Numerics are cross-checked against the software engine
//! ([`crate::lstm::cell::QLstmCell`]): identical results step for step.
//! The cycle model reports per-block occupancy: the four PEs run in
//! parallel (they share the input bus but have independent MAC pipes);
//! the elementwise stage is 2 MACs wide.

use crate::formats::{round_f16, round_f8, Fp16, Fp8};
use crate::lstm::cell::QLstmCell;
use crate::qmath::qsigmoid::{sigmoid_sd8, tanh_fp8};

use super::pe::ProcessingElement;

/// Cycle/throughput report for one LSTM step on the Fig. 9 unit.
#[derive(Clone, Copy, Debug, Default)]
pub struct UnitStats {
    /// cycles of the (parallel) PE matmul phase = max over the 4 PEs
    pub pe_cycles: u64,
    /// cycles of the LUT + elementwise MAC phase
    pub elementwise_cycles: u64,
    pub pe_utilization: f64,
}

/// The Fig. 9 unit driving a [`QLstmCell`]'s weights.
pub struct LstmUnit<'a> {
    pub cell: &'a QLstmCell,
    /// batch interleave depth of each PE (≥ 5 for full utilization)
    pub interleave: usize,
}

impl<'a> LstmUnit<'a> {
    pub fn new(cell: &'a QLstmCell, interleave: usize) -> Self {
        LstmUnit { cell, interleave }
    }

    /// One time step for a batch, computed the way the circuit does:
    /// PEs produce the four gate pre-activation blocks, LUTs quantize,
    /// the two MACs produce c and h. Returns (new h, new c, stats).
    ///
    /// `xs[b]` must be on the FP8 grid; `hs[b]`/`cs[b]` are the
    /// recurrent state (FP8/FP16 grids).
    pub fn step_batch(
        &self,
        xs: &[Vec<f32>],
        hs: &[Vec<f32>],
        cs: &[Vec<f32>],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, UnitStats) {
        let hd = self.cell.hidden;
        let batch = xs.len();
        let pe = ProcessingElement::new(self.interleave);

        // ---- phase 1: the four gate PEs (schedule: each handles the
        // [hd x (D+H)] slice of the fused matmuls; we model the fused
        // wx|wh matmul as the x-part then h-part streamed through).
        let xs8: Vec<Vec<Fp8>> =
            xs.iter().map(|x| x.iter().map(|&v| Fp8::from_f32(v)).collect()).collect();
        let hs8: Vec<Vec<Fp8>> =
            hs.iter().map(|h| h.iter().map(|&v| Fp8::from_f32(v)).collect()).collect();
        let bias16: Vec<Fp16> = self.cell.bias.iter().map(|&b| Fp16::from_f32(b)).collect();
        let zero16 = vec![Fp16::ZERO; 4 * hd];

        let (zx, sx) = pe.forward(&self.cell.wx, &xs8, &bias16);
        let (zh, sh) = pe.forward(&self.cell.wh, &hs8, &zero16);
        // four PEs run the four gate row-blocks concurrently: the time
        // is (total groups / 4 PEs), utilization from the pipe model.
        let pe_cycles = (sx.cycles + sh.cycles) / 4;
        let pe_util = (sx.utilization + sh.utilization) / 2.0;

        // ---- phase 2: LUTs + elementwise MACs (Eq. 5/6)
        let mut h_out = vec![vec![0f32; hd]; batch];
        let mut c_out = vec![vec![0f32; hd]; batch];
        for b in 0..batch {
            for j in 0..hd {
                let z = |g: usize| zx[b][g * hd + j].to_f32() + zh[b][g * hd + j].to_f32();
                let f = sigmoid_sd8(z(0));
                let i = sigmoid_sd8(z(1));
                let o = sigmoid_sd8(z(2));
                let g = tanh_fp8(z(3));
                let cj = round_f16(f * cs[b][j] + i * g);
                c_out[b][j] = cj;
                h_out[b][j] = round_f8(o * tanh_fp8(cj));
            }
        }
        // elementwise stage: each output element takes one MAC group
        // through a 5-deep pipe, 2 MACs wide, batch-interleaved.
        let elem_ops = (batch * hd) as u64;
        let elementwise_cycles = elem_ops.div_ceil(2) + 5;

        let stats = UnitStats {
            pe_cycles,
            elementwise_cycles,
            pe_utilization: pe_util,
        };
        (h_out, c_out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::cell::CellScratch;
    use crate::rng::SplitMix64;

    fn rand_cell(d: usize, hd: usize, seed: u64) -> QLstmCell {
        let mut rng = SplitMix64::new(seed);
        let wx: Vec<f32> = (0..d * 4 * hd).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let wh: Vec<f32> = (0..hd * 4 * hd).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let b: Vec<f32> = (0..4 * hd).map(|_| rng.uniform(-0.1, 0.1)).collect();
        QLstmCell::from_jax_layout(d, hd, &wx, &wh, &b)
    }

    #[test]
    fn unit_matches_software_engine_bit_exactly() {
        let (d, hd, batch) = (8, 12, 6);
        let cell = rand_cell(d, hd, 21);
        let unit = LstmUnit::new(&cell, 5);
        let mut rng = SplitMix64::new(22);

        let xs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..d).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect())
            .collect();
        let hs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..hd).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect())
            .collect();
        let cs: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..hd).map(|_| round_f16(rng.uniform(-1.5, 1.5))).collect())
            .collect();

        let (hu, cu, _) = unit.step_batch(&xs, &hs, &cs);

        let mut scratch = CellScratch::new(hd);
        for b in 0..batch {
            let mut h = hs[b].clone();
            let mut c = cs[b].clone();
            cell.step(&xs[b], &mut h, &mut c, &mut scratch);
            assert_eq!(hu[b], h, "h mismatch, lane {b}");
            assert_eq!(cu[b], c, "c mismatch, lane {b}");
        }
    }

    #[test]
    fn utilization_improves_with_interleave() {
        let cell = rand_cell(8, 8, 30);
        let mk_inputs = |batch: usize, seed: u64| {
            let mut rng = SplitMix64::new(seed);
            let xs: Vec<Vec<f32>> = (0..batch)
                .map(|_| (0..8).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect())
                .collect();
            let hs = vec![vec![0f32; 8]; batch];
            let cs = vec![vec![0f32; 8]; batch];
            (xs, hs, cs)
        };
        let (xs, hs, cs) = mk_inputs(1, 1);
        let (_, _, s1) = LstmUnit::new(&cell, 1).step_batch(&xs, &hs, &cs);
        let (xs, hs, cs) = mk_inputs(6, 2);
        let (_, _, s6) = LstmUnit::new(&cell, 6).step_batch(&xs, &hs, &cs);
        assert!(s6.pe_utilization > s1.pe_utilization * 3.0,
                "batch-6 {} vs batch-1 {}", s6.pe_utilization, s1.pe_utilization);
        assert!(s6.pe_utilization > 0.95);
    }
}
