//! Hardware model of the paper's §V: the FloatSD8 MAC, the LSTM PE and
//! the LSTM neuron circuit — plus the 40 nm synthesis cost model that
//! regenerates Table VII.
//!
//! The paper validated its design with Synopsys DC + PrimeTime at 40 nm;
//! we have no EDA tools, so (per the substitution rule, DESIGN.md §4)
//! the same questions are answered by two simulators built from scratch:
//!
//! * [`mac_sim`] — a **bit-level, cycle-level** model of the five-stage
//!   pipelined FloatSD8 MAC of Fig. 8 (decode → partial products + max
//!   exponent → align → carry-save add → round/normalize). Its numerics
//!   are proven identical to the architectural definition
//!   (`qmath::mac_exact`) by exhaustive/random cross-tests.
//! * [`cost`] — a gate-level area/power estimator over the synthesizable
//!   components of both MACs (FP32 vs FloatSD8), using published 40 nm
//!   standard-cell figures. Regenerates the Table VII comparison (the
//!   claim is the *ratio*: 7.66× area, 5.75× power).
//! * [`pe`] — the output-stationary processing element of Fig. 7 with
//!   its partial-sum register file; reproduces the §V-A utilization
//!   claim (batch ≥ 5 ⇒ 100%).
//! * [`lstm_unit`] — the Fig. 9 neuron circuit: 4 PEs + σ/tanh LUTs +
//!   2 elementwise MACs; runs real inference cycle-accurately and is
//!   numerically cross-checked against the [`crate::lstm`] engine.

pub mod cost;
pub mod lstm_unit;
pub mod mac_sim;
pub mod pe;

pub use cost::{mac_cost_fp32, mac_cost_fsd8, CostReport};
pub use mac_sim::MacPipeline;
pub use pe::ProcessingElement;
