//! Bit-level, cycle-level simulator of the five-stage pipelined
//! FloatSD8 MAC (paper Fig. 8).
//!
//! Pipeline stages:
//!
//! 1. **Decode / PPG / max-exp** — the 4 FloatSD8 weights are decoded
//!    into ≤ 8 signed shift amounts; partial products are formed as
//!    (±fp8-significand, exponent) pairs; the maximum exponent among
//!    the partial products and the accumulator is found.
//! 2. **Align** — every significand is shifted right by
//!    `max_exp − own_exp` into a common fixed-point frame.
//! 3. **CSA** — Wallace-tree carry-save addition of the 9 aligned terms
//!    (modeled as an exact integer sum; carry-save order does not
//!    change the value).
//! 4. **Round** — round-to-nearest-even at the FP16 mantissa boundary.
//! 5. **Normalize** — pack to binary16.
//!
//! Numerics contract: `MacPipeline` produces **bit-identical** results
//! to the architectural spec `qmath::mac_exact` (see tests) — this is
//! the "we built the circuit and it computes the right thing" evidence
//! the paper gets from RTL simulation.
//!
//! The cycle model exposes the §V-A hazard: the accumulator is only
//! available 5 cycles after issue, so a single output stream stalls the
//! pipe (20% utilization) while ≥ 5 interleaved outputs (batch ≥ 5)
//! reach 100% — reproduced by `pe::ProcessingElement`.

use crate::formats::{FloatSd8, Fp16, Fp8, FLOAT_SD8};

/// Fixed-point scale: every partial product and the accumulator are
/// integers in units of 2^-26 (the finest bit any operand can carry:
/// fp8 subnormal LSB 2^-18 × sd8 second-group LSB 2^-9 ≈ 2^-27 — one
/// guard octave below covers the fp16 accumulator subnormal LSB 2^-24).
pub const FRAC_BITS: i32 = 28;

/// A partial product before alignment: signed fp8 significand (≤ 3 bits
/// + sign) and its power-of-two exponent.
#[derive(Clone, Copy, Debug)]
pub struct PartialProduct {
    /// signed significand in units of 2^exp (|sig| ≤ 7)
    pub sig: i32,
    /// power-of-two exponent of the significand unit
    pub exp: i32,
}

/// Decompose an FP8 operand into (significand, exponent): value =
/// sig · 2^exp with sig ∈ [−7, 7] (3-bit magnitude + sign).
fn fp8_sig_exp(x: Fp8) -> (i32, i32) {
    let bits = x.to_bits();
    let sign = if bits & 0x80 != 0 { -1 } else { 1 };
    let e = ((bits >> 2) & 0x1f) as i32;
    let m = (bits & 0x03) as i32;
    if e == 0 {
        (sign * m, -16) // subnormal: m · 2^-16
    } else {
        (sign * (4 + m), e - 15 - 2) // (1 + m/4) · 2^(e-15) = (4+m) · 2^(e-17)
    }
}

/// Stage-1 output: decoded partial products for one 4-pair group.
#[derive(Clone, Debug, Default)]
pub struct Stage1 {
    pub pps: Vec<PartialProduct>,
    pub max_exp: i32,
}

/// The five-stage pipelined MAC.
#[derive(Debug, Default)]
pub struct MacPipeline {
    /// Cycle counter (advances by 1 per [`MacPipeline::issue`] and per
    /// [`MacPipeline::tick`]).
    pub cycle: u64,
    /// Busy-until cycle per in-flight result tag (hazard tracking).
    in_flight: Vec<u64>,
    /// Total issued groups (for utilization stats).
    pub issued: u64,
}

/// Pipeline depth (result latency in cycles) — paper §V-A: "the PE
/// would have to wait for five cycles before computing another outcome".
pub const PIPELINE_DEPTH: u64 = 5;

impl MacPipeline {
    pub fn new() -> Self {
        Self::default()
    }

    // ---------------- datapath (bit-level, stage by stage) ----------------

    /// Stage 1: decode weights, generate partial products, max exponent.
    pub fn stage1(acc: Fp16, xs: &[Fp8], ws: &[FloatSd8]) -> Stage1 {
        let mut pps = Vec::with_capacity(2 * ws.len() + 1);
        for (&x, &w) in xs.iter().zip(ws) {
            let (sig, e) = fp8_sig_exp(x);
            for (s, we) in FLOAT_SD8.partial_products(w).iter() {
                // product of (sig·2^e) by (±2^we): still a ≤3-bit significand
                pps.push(PartialProduct { sig: sig * s as i32, exp: e + we });
            }
        }
        // the accumulator enters the tree as one more term: decompose
        // the fp16 into (signed 11-bit significand, exponent)
        let (asig, aexp) = fp16_sig_exp(acc);
        if asig != 0 {
            pps.push(PartialProduct { sig: asig, exp: aexp });
        }
        let max_exp = pps.iter().map(|p| p.exp).max().unwrap_or(0);
        Stage1 { pps, max_exp }
    }

    /// Stage 2+3: align to the fixed-point frame and sum exactly (the
    /// Wallace tree is value-preserving; we model the value).
    pub fn stage23(s1: &Stage1) -> i64 {
        let mut sum: i64 = 0;
        for p in &s1.pps {
            let shift = p.exp + FRAC_BITS;
            debug_assert!(
                (0..63).contains(&shift),
                "alignment shift {shift} out of datapath range"
            );
            sum += (p.sig as i64) << shift;
        }
        sum
    }

    /// Stage 4+5: round the fixed-point sum to binary16 (RNE) and pack.
    pub fn stage45(sum: i64) -> Fp16 {
        round_fixed_to_f16(sum, FRAC_BITS as u32)
    }

    /// Full combinational result of one group (the value the pipeline
    /// produces 5 cycles after issue).
    pub fn compute(acc: Fp16, xs: &[Fp8], ws: &[FloatSd8]) -> Fp16 {
        Self::stage45(Self::stage23(&Self::stage1(acc, xs, ws)))
    }

    // ---------------- cycle model ----------------

    /// Issue one MAC group for result tag `tag` (e.g. a batch lane).
    /// Returns the cycle at which the result (and thus the accumulator
    /// for the next group of the same tag) is available. If the tag's
    /// previous result is not ready yet, the issue *stalls* until it is.
    pub fn issue(&mut self, tag: usize) -> u64 {
        if self.in_flight.len() <= tag {
            self.in_flight.resize(tag + 1, 0);
        }
        // RAW hazard on the accumulator: wait for the tag's last result.
        if self.cycle < self.in_flight[tag] {
            self.cycle = self.in_flight[tag];
        }
        self.cycle += 1; // occupy one issue slot
        self.issued += 1;
        let ready = self.cycle + PIPELINE_DEPTH - 1;
        self.in_flight[tag] = ready;
        ready
    }

    /// Advance one idle cycle.
    pub fn tick(&mut self) {
        self.cycle += 1;
    }

    /// Issue-slot utilization so far: groups issued / cycles elapsed.
    pub fn utilization(&self) -> f64 {
        if self.cycle == 0 {
            0.0
        } else {
            self.issued as f64 / self.cycle as f64
        }
    }
}

/// Decompose an FP16 into (signed significand, exponent): value =
/// sig · 2^exp, |sig| ≤ 2047.
fn fp16_sig_exp(x: Fp16) -> (i32, i32) {
    let bits = x.to_bits();
    let sign = if bits & 0x8000 != 0 { -1 } else { 1 };
    let e = ((bits >> 10) & 0x1f) as i32;
    let m = (bits & 0x3ff) as i32;
    if e == 0 {
        (sign * m, -24) // subnormal / zero
    } else {
        (sign * (1024 + m), e - 15 - 10)
    }
}

/// Round an exact fixed-point value (units of 2^-frac_bits) to binary16
/// with round-to-nearest-even — the stage-4/5 rounder.
pub fn round_fixed_to_f16(v: i64, frac_bits: u32) -> Fp16 {
    if v == 0 {
        return Fp16::ZERO;
    }
    let neg = v < 0;
    let mag = v.unsigned_abs();
    let msb = 63 - mag.leading_zeros(); // position of the leading 1
    let exp = msb as i32 - frac_bits as i32; // value in [2^exp, 2^(exp+1))

    // fp16 normal needs exp in [-14, 15]; below that, subnormal frame.
    let (man_lsb_exp, biased) = if exp >= -14 {
        (exp - 10, exp + 15) // 10 fraction bits below the implicit one
    } else {
        (-24, 0) // subnormal: fixed LSB at 2^-24
    };
    // bit position (in the fixed-point frame) of the mantissa LSB:
    let lsb_pos = man_lsb_exp + frac_bits as i32;
    if lsb_pos <= 0 {
        // every bit of v is at or above the mantissa LSB: exact integer
        let man = (mag as i64) << (-lsb_pos);
        return pack_f16(neg, biased, man as u64);
    }
    let lsb_pos = lsb_pos as u32;
    let man = mag >> lsb_pos;
    let rem = mag & ((1u64 << lsb_pos) - 1);
    let half = 1u64 << (lsb_pos - 1);
    let mut man = man;
    if rem > half || (rem == half && man & 1 == 1) {
        man += 1; // may carry: 0x7ff+1 = 0x800 handled by pack (exp bump)
    }
    pack_f16(neg, biased, man)
}

/// Pack (sign, biased exponent, mantissa-with-implicit-bit) to binary16,
/// handling the carry-out of rounding and overflow saturation to inf.
fn pack_f16(neg: bool, mut biased: i32, mut man: u64) -> Fp16 {
    // mantissa with implicit bit: normal expects 1024..=2047
    if biased > 0 {
        if man >= 2048 {
            man >>= 1;
            biased += 1;
        }
        if man < 1024 {
            // can happen when the rounded value came in subnormal frame
            // (biased computed > 0 only for normals — not this path)
            debug_assert!(false, "unnormalized normal");
        }
        if biased >= 0x1f {
            return if neg { Fp16::NEG_INFINITY } else { Fp16::INFINITY };
        }
        let bits = ((neg as u16) << 15) | ((biased as u16) << 10) | ((man - 1024) as u16);
        Fp16::from_bits(bits)
    } else {
        // subnormal frame: man is the raw 10-bit fraction (may round up
        // into the smallest normal, man == 1024 → exp 1, man 0)
        if man >= 1024 {
            let bits = ((neg as u16) << 15) | (1 << 10) | ((man - 1024) as u16);
            return Fp16::from_bits(bits);
        }
        let bits = ((neg as u16) << 15) | man as u16;
        Fp16::from_bits(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qmath::mac::{mac_exact, MAC_GROUP};
    use crate::rng::SplitMix64;

    #[test]
    fn fp8_decomposition_reconstructs() {
        for b in 0..=u8::MAX {
            let x = Fp8::from_bits(b);
            let (sig, exp) = fp8_sig_exp(x);
            let v = sig as f64 * 2f64.powi(exp);
            assert_eq!(v as f32, x.to_f32(), "fp8 bits {b:#04x}");
        }
    }

    #[test]
    fn fp16_decomposition_reconstructs() {
        for b in (0..=u16::MAX).step_by(7) {
            let x = Fp16::from_bits(b);
            if x.is_nan() || x.is_infinite() {
                continue;
            }
            let (sig, exp) = fp16_sig_exp(x);
            assert_eq!((sig as f64 * 2f64.powi(exp)) as f32, x.to_f32());
        }
    }

    #[test]
    fn round_fixed_matches_from_f64() {
        let mut rng = SplitMix64::new(5);
        for _ in 0..100_000 {
            let v = (rng.next_u64() >> 20) as i64 - (1i64 << 43);
            let got = round_fixed_to_f16(v, FRAC_BITS as u32);
            let want = Fp16::from_f64(v as f64 * 2f64.powi(-FRAC_BITS));
            assert_eq!(got.0, want.0, "v={v}");
        }
    }

    #[test]
    fn pipeline_matches_architectural_mac_on_random_vectors() {
        let mut rng = SplitMix64::new(6);
        for trial in 0..20_000 {
            let n = 1 + (rng.next_below(MAC_GROUP as u64) as usize);
            let xs: Vec<Fp8> = (0..n)
                .map(|_| Fp8::from_f32((rng.next_f32() - 0.5) * 1000.0))
                .collect();
            let ws: Vec<FloatSd8> = (0..n)
                .map(|_| FLOAT_SD8.encode((rng.next_f32() - 0.5) * 9.0))
                .collect();
            let acc = Fp16::from_f32((rng.next_f32() - 0.5) * 64.0);
            let got = MacPipeline::compute(acc, &xs, &ws);
            let want = mac_exact(acc, &xs, &ws);
            assert_eq!(got.0, want.0, "trial {trial}");
        }
    }

    #[test]
    fn partial_product_count_bounded() {
        let mut rng = SplitMix64::new(8);
        for _ in 0..1000 {
            let xs: Vec<Fp8> = (0..4).map(|_| Fp8::from_f32(rng.uniform(-8.0, 8.0))).collect();
            let ws: Vec<FloatSd8> =
                (0..4).map(|_| FLOAT_SD8.encode(rng.uniform(-4.5, 4.5))).collect();
            let s1 = MacPipeline::stage1(Fp16::ZERO, &xs, &ws);
            assert!(s1.pps.len() <= 8, "more than 8 partial products");
        }
    }

    #[test]
    fn single_stream_utilization_is_one_fifth() {
        let mut pipe = MacPipeline::new();
        for _ in 0..100 {
            pipe.issue(0);
        }
        let u = pipe.utilization();
        assert!((u - 0.2).abs() < 0.02, "single-tag utilization {u}");
    }

    #[test]
    fn five_interleaved_streams_reach_full_utilization() {
        let mut pipe = MacPipeline::new();
        for round in 0..100 {
            for tag in 0..5 {
                let _ = round; // round-robin over 5 tags
                pipe.issue(tag);
            }
        }
        let u = pipe.utilization();
        assert!(u > 0.99, "batch-5 utilization {u}");
    }
}
