//! The `--trace <path>` JSONL event stream (schema
//! `floatsd-trace-v1`): one compact JSON object per line, appended at
//! step boundaries by the trainers.
//!
//! ## Schema
//!
//! Every line carries `"schema"`, `"ev"` (the event kind), and
//! `"step"` (the **logical** step clock — 0 for run-scoped events).
//! Event kinds:
//!
//! * `run_start` — `"config"`: the run's deterministic configuration
//!   (seeds as decimal strings, see `TaskConfig::to_meta_json`);
//! * `step` — per-window numerics health: `"loss"`, `"scale"`,
//!   `"applied"`, `"skipped_total"`, `"grads"` (per-tensor FP8
//!   saturation, scanned pre-`finalize_grads`), `"acts"` (cumulative
//!   sigmoid/tanh clip counts since `run_start`);
//! * `loss_scale` — a [`LossScaler`](crate::train::LossScaler)
//!   adjustment: `"cause"` (`backoff`|`growth`), `"from"`, `"to"`,
//!   `"skipped_total"`;
//! * `reencode` — `"weights"`: per-matrix FloatSD8 code stats after an
//!   applied update (exponent histogram + saturated-code count);
//! * `run_end` — run totals plus final `"weights"` and `"acts"` (so a
//!   run whose every step overflowed still reports saturation).
//!
//! ## Determinism
//!
//! All fields are deterministic functions of (config, seed) **except**
//! wall-clock data, which is confined to fields named `"timing"`.
//! Strip those and a fixed-seed rerun is byte-identical (pinned by
//! `tests/telemetry.rs` and the `trace-smoke` CI job).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::qmath::vector::QMatrix;
use crate::tensorfile::json::Json;

use super::{code_stats, grad_saturation, ActSnapshot};

/// Schema tag carried by every trace line.
pub const TRACE_SCHEMA: &str = "floatsd-trace-v1";

/// An append-only JSONL trace writer. Creating one opens the
/// process-wide telemetry gate ([`super::hot_enabled`]); dropping it
/// closes the gate and flushes.
///
/// Writes are best-effort: mid-run IO errors are deferred (training
/// never aborts mid-step over a full disk) and surfaced by
/// [`Self::finish`].
pub struct TraceSink {
    out: BufWriter<File>,
    path: PathBuf,
    deferred: Option<std::io::Error>,
}

impl TraceSink {
    pub fn create(path: &Path) -> Result<TraceSink> {
        let file = File::create(path)
            .with_context(|| format!("create trace file {}", path.display()))?;
        super::sink_opened();
        Ok(TraceSink { out: BufWriter::new(file), path: path.to_path_buf(), deferred: None })
    }

    /// Append one event line; `fields` gains the common
    /// `schema`/`ev`/`step` keys (serialized in BTreeMap key order, so
    /// lines are byte-deterministic).
    pub fn emit(&mut self, ev: &str, step: u64, mut fields: BTreeMap<String, Json>) {
        fields.insert("schema".to_string(), Json::Str(TRACE_SCHEMA.to_string()));
        fields.insert("ev".to_string(), Json::Str(ev.to_string()));
        fields.insert("step".to_string(), Json::Num(step as f64));
        if self.deferred.is_none() {
            if let Err(e) = writeln!(self.out, "{}", Json::Obj(fields)) {
                self.deferred = Some(e);
            }
        }
    }

    /// Flush and surface any deferred write error.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(e) = self.deferred.take() {
            return Err(e).with_context(|| format!("write trace {}", self.path.display()));
        }
        self.out.flush().with_context(|| format!("flush trace {}", self.path.display()))
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        let _ = self.out.flush();
        super::sink_closed();
    }
}

/// `f64` → JSON with non-finite values mapped to `null` (the writer
/// has no representation for inf/NaN; a skipped step's loss can be
/// non-finite).
pub fn fnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Per-tensor FP8 gradient-saturation block (the `step` event's
/// `"grads"` field): scans each named slice with
/// [`grad_saturation`](super::grad_saturation).
pub fn grads_json(tensors: &[(String, &[f32])]) -> Json {
    let mut m = BTreeMap::new();
    for (name, gs) in tensors {
        let s = grad_saturation(gs);
        let mut t = BTreeMap::new();
        t.insert("total".to_string(), Json::Num(s.total as f64));
        t.insert("fp8_zero".to_string(), Json::Num(s.zeros as f64));
        t.insert("fp8_top_binade".to_string(), Json::Num(s.top_binade as f64));
        t.insert("non_finite".to_string(), Json::Num(s.non_finite as f64));
        t.insert("max_abs".to_string(), fnum(f64::from(s.max_abs)));
        m.insert(name.clone(), Json::Obj(t));
    }
    Json::Obj(m)
}

/// Per-matrix FloatSD8 code-stats block (the `reencode`/`run_end`
/// events' `"weights"` field).
pub fn codes_json(mats: &[(String, &QMatrix)]) -> Json {
    let mut m = BTreeMap::new();
    for (name, mat) in mats {
        let s = code_stats(mat);
        let mut t = BTreeMap::new();
        t.insert("total".to_string(), Json::Num(s.total as f64));
        t.insert("at_max".to_string(), Json::Num(s.at_max as f64));
        t.insert(
            "exp_hist".to_string(),
            Json::Arr(s.exp_hist.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        m.insert(name.clone(), Json::Obj(t));
    }
    Json::Obj(m)
}

/// Cumulative activation-clip block (the `"acts"` field) — counts
/// since the run's baseline snapshots.
pub fn acts_json(sigmoid: ActSnapshot, tanh: ActSnapshot) -> Json {
    let one = |s: ActSnapshot| {
        let mut m = BTreeMap::new();
        m.insert("evals".to_string(), Json::Num(s.evals as f64));
        m.insert("clip_lo".to_string(), Json::Num(s.clip_lo as f64));
        m.insert("clip_hi".to_string(), Json::Num(s.clip_hi as f64));
        Json::Obj(m)
    };
    let mut m = BTreeMap::new();
    m.insert("sigmoid".to_string(), one(sigmoid));
    m.insert("tanh".to_string(), one(tanh));
    Json::Obj(m)
}

/// `loss_scale` event payload.
pub fn scale_fields(cause: &str, from: f32, to: f32, skipped_total: u64) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("cause".to_string(), Json::Str(cause.to_string()));
    m.insert("from".to_string(), Json::Num(f64::from(from)));
    m.insert("to".to_string(), Json::Num(f64::from(to)));
    m.insert("skipped_total".to_string(), Json::Num(skipped_total as f64));
    m
}

/// Wall-clock payload — the only place non-deterministic data may
/// appear; consumers strip `"timing"` before byte-comparing traces.
pub fn timing_json(step_ms: f64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("step_ms".to_string(), fnum(step_ms));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_lines_are_parseable_and_tagged() {
        let dir = std::env::temp_dir().join("fsd_telemetry_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.jsonl");
        {
            let mut sink = TraceSink::create(&path).unwrap();
            assert!(super::super::hot_enabled(), "open sink must enable the gate");
            let mut fields = BTreeMap::new();
            fields.insert("loss".to_string(), fnum(1.25));
            fields.insert("timing".to_string(), timing_json(0.5));
            sink.emit("step", 3, fields);
            sink.emit("run_end", 3, BTreeMap::new());
            sink.finish().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(TRACE_SCHEMA));
        assert_eq!(j.get("ev").unwrap().as_str(), Some("step"));
        assert_eq!(j.get("step").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("loss").unwrap().as_f64(), Some(1.25));
    }

    #[test]
    fn non_finite_values_serialize_as_null() {
        assert_eq!(fnum(f64::NAN), Json::Null);
        assert_eq!(fnum(f64::INFINITY), Json::Null);
        assert_eq!(fnum(2.0), Json::Num(2.0));
    }

    #[test]
    fn grads_json_names_every_tensor() {
        let a = [0.0f32, 1.0];
        let b = [f32::INFINITY];
        let j = grads_json(&[("emb".to_string(), &a[..]), ("head.w".to_string(), &b[..])]);
        assert_eq!(j.get("emb").unwrap().get("total").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("head.w").unwrap().get("non_finite").unwrap().as_usize(), Some(1));
    }
}
