//! Deterministic numerics-health telemetry — counters, gauges,
//! fixed-bucket histograms, sample windows, and span timers shared by
//! the training, serving, and evaluation subsystems, plus the
//! numerics scans (FP8 gradient saturation, FloatSD8 re-encode
//! saturation, qsigmoid/tanh clip rates) that feed the `--trace`
//! JSONL stream ([`trace`]) and the `floatsd-lstm report` summarizer
//! ([`report`]).
//!
//! ## The determinism contract
//!
//! Enabling telemetry must never perturb computation: `--threads N`
//! bit-identity and checkpoint bytes are pinned telemetry-on vs
//! telemetry-off (`tests/telemetry.rs`). That holds by construction,
//! in three tiers:
//!
//! * **per-shard data** (gradients, losses, latencies) is only read at
//!   step/batch boundaries, after the parallel engine's join barrier,
//!   and folded in the fixed shard order — the same contract as
//!   [`crate::train::parallel::merge_shards`];
//! * **hot-path counters** ([`Counter`], [`Gauge`], [`Histogram`],
//!   and the [`SIGMOID`]/[`TANH`] activation-clip statics) are plain
//!   `u64` atomics. Integer adds commute, so the totals observed at a
//!   join barrier are scheduling-independent; and the counters are
//!   write-only from the compute path — no kernel ever reads one — so
//!   they cannot feed back into the numbers;
//! * **boundary scans** ([`grad_saturation`], [`code_stats`]) run
//!   single-threaded on already-merged buffers, read-only.
//!
//! ## The disabled-path contract
//!
//! With no [`TraceSink`] open, the activation hooks
//! ([`note_sigmoid`]/[`note_tanh`]) are one relaxed load + branch and
//! the metric primitives never allocate (pinned by
//! `tests/telemetry_alloc.rs`). The serve-side metrics
//! ([`crate::serve::ShardStats`] rehosts on these types) stay always
//! on: they are integer atomics off the per-token hot path.

pub mod report;
pub mod serve_trace;
pub mod trace;

pub use serve_trace::{ServeTraceSink, SERVE_TRACE_SCHEMA};
pub use trace::{TraceSink, TRACE_SCHEMA};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::formats::fp8::F8_MAX;
use crate::formats::{round_f8, FLOAT_SD8};
use crate::lstm::QLstmStack;
use crate::qmath::vector::QMatrix;
use crate::qmath::{IsaPath, KernelTier};

// ---------------------------------------------------------------------
// global enable gate
// ---------------------------------------------------------------------

/// Live [`TraceSink`] count — the process-wide telemetry gate.
static ACTIVE_SINKS: AtomicUsize = AtomicUsize::new(0);

pub(crate) fn sink_opened() {
    ACTIVE_SINKS.fetch_add(1, Ordering::SeqCst);
}

pub(crate) fn sink_closed() {
    ACTIVE_SINKS.fetch_sub(1, Ordering::SeqCst);
}

/// Whether any trace sink is open — the hot-path instrumentation gate:
/// one relaxed load, so a disabled build of the same binary pays a
/// load + predictable branch per hook and nothing else.
#[inline]
pub fn hot_enabled() -> bool {
    ACTIVE_SINKS.load(Ordering::Relaxed) > 0
}

// ---------------------------------------------------------------------
// metric primitives
// ---------------------------------------------------------------------

/// A monotone event counter (relaxed `u64` atomic — adds commute, so
/// totals read at a join barrier are scheduling-independent).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins level (live session count, current loss scale …).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A fixed-bucket histogram: `bounds` are strictly ascending
/// upper-inclusive bucket edges, plus one implicit overflow bucket, so
/// `record(v)` lands in the first bucket with `bound >= v`. Bucket
/// layout is fixed at construction — recording never allocates.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
}

impl Histogram {
    pub fn new(bounds: &[u64]) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Bucket counts (`bounds.len() + 1` entries, overflow last).
    pub fn counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// A bounded ring of duration samples for percentile estimation —
/// fixed capacity allocated up front, oldest sample overwritten in
/// place once full (the serve latency window rehosts on this).
#[derive(Debug)]
pub struct SampleWindow {
    buf: Vec<Duration>,
    next: usize,
}

impl SampleWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "sample window needs capacity");
        SampleWindow { buf: Vec::with_capacity(cap), next: 0 }
    }

    pub fn push(&mut self, d: Duration) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % self.buf.len();
        }
    }

    /// The retained samples, in ring (not arrival) order.
    pub fn samples(&self) -> &[Duration] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// A wall-clock span timer. Span durations are *timing-only* data:
/// they may appear in the trace's clearly marked `"timing"` fields and
/// nowhere else (the determinism tests strip them before comparing).
#[derive(Debug)]
pub struct SpanTimer(Instant);

impl SpanTimer {
    pub fn start() -> Self {
        SpanTimer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

// ---------------------------------------------------------------------
// activation-clip hot counters
// ---------------------------------------------------------------------

/// Clip statistics of one quantized activation function.
#[derive(Debug)]
pub struct ActCounters {
    pub evals: Counter,
    /// outputs pinned at the lower rail (0 for sigmoid, −1 for tanh)
    pub clip_lo: Counter,
    /// outputs pinned at the upper rail (1)
    pub clip_hi: Counter,
}

impl ActCounters {
    const fn init() -> Self {
        ActCounters { evals: Counter::new(), clip_lo: Counter::new(), clip_hi: Counter::new() }
    }

    pub fn snapshot(&self) -> ActSnapshot {
        ActSnapshot {
            evals: self.evals.get(),
            clip_lo: self.clip_lo.get(),
            clip_hi: self.clip_hi.get(),
        }
    }
}

/// Process-wide [`crate::qmath::sigmoid_sd8`] clip statistics.
pub static SIGMOID: ActCounters = ActCounters::init();
/// Process-wide [`crate::qmath::tanh_fp8`] clip statistics.
pub static TANH: ActCounters = ActCounters::init();

/// A point-in-time copy of an [`ActCounters`] (the statics are
/// process-cumulative; trainers diff against a baseline taken at sink
/// creation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ActSnapshot {
    pub evals: u64,
    pub clip_lo: u64,
    pub clip_hi: u64,
}

impl ActSnapshot {
    /// Counts accumulated since `base` (saturating, in case another
    /// in-process run shares the statics).
    pub fn since(self, base: ActSnapshot) -> ActSnapshot {
        ActSnapshot {
            evals: self.evals.saturating_sub(base.evals),
            clip_lo: self.clip_lo.saturating_sub(base.clip_lo),
            clip_hi: self.clip_hi.saturating_sub(base.clip_hi),
        }
    }
}

/// Record one quantized-sigmoid output. Gated on [`hot_enabled`]; the
/// counters are write-only from compute, so this can never perturb the
/// numbers.
#[inline]
pub fn note_sigmoid(y: f32) {
    if !hot_enabled() {
        return;
    }
    SIGMOID.evals.add(1);
    if y == 0.0 {
        SIGMOID.clip_lo.add(1);
    } else if y == 1.0 {
        SIGMOID.clip_hi.add(1);
    }
}

/// Record one quantized-tanh output (rails at ±1).
#[inline]
pub fn note_tanh(y: f32) {
    if !hot_enabled() {
        return;
    }
    TANH.evals.add(1);
    if y == -1.0 {
        TANH.clip_lo.add(1);
    } else if y == 1.0 {
        TANH.clip_hi.add(1);
    }
}

// ---------------------------------------------------------------------
// kernel-tier profiling spans
// ---------------------------------------------------------------------

/// Which forward kernel a profiling span measured.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelOp {
    Matvec,
    Matmul,
}

impl KernelOp {
    pub fn name(self) -> &'static str {
        match self {
            KernelOp::Matvec => "matvec",
            KernelOp::Matmul => "matmul",
        }
    }
}

/// Shape-class slots in the kernel-profile table. A served model has a
/// handful of distinct `(op, tier, isa, rows, cols, batch)` classes
/// (one per weight matrix × batch width actually formed), so 64 is
/// generous; spills land in [`KERNEL_OVERFLOW`] rather than dropping.
const KP_SLOTS: usize = 64;
/// Bits per packed dimension (rows/cols/batch clamp to `2^19 - 1`;
/// one bit narrower than pre-ISA profiles to make room for the 2-bit
/// ISA field — far above every real matrix dimension here).
const KP_DIM_BITS: u64 = 19;
const KP_DIM_MAX: u64 = (1 << KP_DIM_BITS) - 1;

struct KpSlot {
    key: AtomicU64,
    calls: AtomicU64,
    nanos: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // array-repeat seed for the static table
const KP_EMPTY: KpSlot =
    KpSlot { key: AtomicU64::new(0), calls: AtomicU64::new(0), nanos: AtomicU64::new(0) };

/// Fixed-capacity lock-free open-addressing table of kernel shape
/// classes: slots claim a packed key with one CAS and accumulate
/// write-only relaxed counters afterwards, so the hot path never locks,
/// never allocates, and can never feed back into the numerics.
static KERNEL_TABLE: [KpSlot; KP_SLOTS] = [KP_EMPTY; KP_SLOTS];
/// Spans whose shape class found no free slot — counted so a saturated
/// table reads as an audited spill, not a silently lossy profile.
static KERNEL_OVERFLOW: KpSlot = KP_EMPTY;

/// Pack `(op, tier, isa, rows, cols, batch)` into a nonzero slot key.
/// The top bit is always set so an occupied slot can never collide
/// with the empty-key sentinel 0; the 2-bit ISA field sits at bits
/// 60–59 ([`IsaPath::index`]).
fn kp_key(
    op: KernelOp,
    tier: KernelTier,
    isa: IsaPath,
    rows: usize,
    cols: usize,
    batch: usize,
) -> u64 {
    let op_b = match op {
        KernelOp::Matvec => 0u64,
        KernelOp::Matmul => 1,
    };
    let tier_b = match tier {
        KernelTier::Decoded => 0u64,
        KernelTier::ShiftAdd => 1,
    };
    let clamp = |d: usize| (d as u64).min(KP_DIM_MAX);
    (1 << 63)
        | (op_b << 62)
        | (tier_b << 61)
        | ((isa.index() as u64) << (3 * KP_DIM_BITS + 2))
        | (clamp(rows) << (2 * KP_DIM_BITS))
        | (clamp(cols) << KP_DIM_BITS)
        | clamp(batch)
}

/// Record one forward-kernel wall-clock span, labeled by
/// [`KernelTier`], dispatched [`IsaPath`], and shape class. Callers
/// gate on [`hot_enabled`] first (the disabled path is one relaxed
/// load + branch, the same contract as [`note_sigmoid`]); with the
/// gate open this is a probe over preallocated atomic slots —
/// write-only from compute, so the profile can never perturb a
/// computed bit.
pub fn note_kernel(
    op: KernelOp,
    tier: KernelTier,
    isa: IsaPath,
    rows: usize,
    cols: usize,
    batch: usize,
    d: Duration,
) {
    let key = kp_key(op, tier, isa, rows, cols, batch);
    let nanos = d.as_nanos().min(u64::MAX as u128) as u64;
    let mut idx = (key % KP_SLOTS as u64) as usize;
    for _ in 0..KP_SLOTS {
        let slot = &KERNEL_TABLE[idx];
        let k = slot.key.load(Ordering::Relaxed);
        let owned = k == key
            || (k == 0
                && match slot.key.compare_exchange(0, key, Ordering::Relaxed, Ordering::Relaxed)
                {
                    Ok(_) => true,
                    Err(cur) => cur == key, // lost the race to the same class
                });
        if owned {
            slot.calls.fetch_add(1, Ordering::Relaxed);
            slot.nanos.fetch_add(nanos, Ordering::Relaxed);
            return;
        }
        idx = (idx + 1) % KP_SLOTS;
    }
    KERNEL_OVERFLOW.calls.fetch_add(1, Ordering::Relaxed);
    KERNEL_OVERFLOW.nanos.fetch_add(nanos, Ordering::Relaxed);
}

/// One shape-class row of the cumulative kernel profile. `calls` and
/// the shape labels are deterministic for a fixed request schedule;
/// `nanos` is wall clock and must only ever surface inside `"timing"`
/// fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelProfileRow {
    pub op: &'static str,
    pub tier: &'static str,
    /// the SIMD execution path the class dispatched to
    /// ([`IsaPath::name`]; `"any"` on the overflow row)
    pub isa: &'static str,
    pub rows: u64,
    pub cols: u64,
    pub batch: u64,
    pub calls: u64,
    pub nanos: u64,
}

impl KernelProfileRow {
    /// Shape-class identity (everything but the accumulators).
    fn class(&self) -> (&'static str, &'static str, &'static str, u64, u64, u64) {
        (self.op, self.tier, self.isa, self.rows, self.cols, self.batch)
    }
}

/// Snapshot the process-cumulative kernel profile, sorted by packed
/// key — a deterministic order even though concurrent workers claim
/// slots in a nondeterministic order.
pub fn kernel_profile() -> Vec<KernelProfileRow> {
    let mut keyed: Vec<(u64, u64, u64)> = Vec::new();
    for slot in &KERNEL_TABLE {
        let k = slot.key.load(Ordering::Relaxed);
        if k == 0 {
            continue;
        }
        let calls = slot.calls.load(Ordering::Relaxed);
        if calls == 0 {
            continue;
        }
        keyed.push((k, calls, slot.nanos.load(Ordering::Relaxed)));
    }
    keyed.sort_unstable_by_key(|&(k, ..)| k);
    let mut out: Vec<KernelProfileRow> = keyed
        .into_iter()
        .map(|(k, calls, nanos)| KernelProfileRow {
            op: if (k >> 62) & 1 == 0 { "matvec" } else { "matmul" },
            tier: if (k >> 61) & 1 == 0 { "decoded" } else { "shiftadd" },
            isa: IsaPath::from_index(((k >> (3 * KP_DIM_BITS + 2)) & 0b11) as u8).name(),
            rows: (k >> (2 * KP_DIM_BITS)) & KP_DIM_MAX,
            cols: (k >> KP_DIM_BITS) & KP_DIM_MAX,
            batch: k & KP_DIM_MAX,
            calls,
            nanos,
        })
        .collect();
    let spilled = KERNEL_OVERFLOW.calls.load(Ordering::Relaxed);
    if spilled > 0 {
        out.push(KernelProfileRow {
            op: "overflow",
            tier: "any",
            isa: "any",
            rows: 0,
            cols: 0,
            batch: 0,
            calls: spilled,
            nanos: KERNEL_OVERFLOW.nanos.load(Ordering::Relaxed),
        });
    }
    out
}

/// The profile accumulated since `base` (an earlier [`kernel_profile`]
/// snapshot — the statics are process-cumulative, like the activation
/// counters): matching shape classes are diffed, new classes pass
/// through, classes with no new calls drop out.
pub fn kernel_profile_since(base: &[KernelProfileRow]) -> Vec<KernelProfileRow> {
    kernel_profile()
        .into_iter()
        .filter_map(|mut r| {
            if let Some(b) = base.iter().find(|b| b.class() == r.class()) {
                r.calls = r.calls.saturating_sub(b.calls);
                r.nanos = r.nanos.saturating_sub(b.nanos);
            }
            (r.calls > 0).then_some(r)
        })
        .collect()
}

// ---------------------------------------------------------------------
// numerics boundary scans
// ---------------------------------------------------------------------

/// FP8 saturation profile of one (still loss-scaled) gradient tensor.
#[derive(Clone, Copy, Debug, Default)]
pub struct GradSat {
    pub total: u64,
    /// positions that round to FP8 zero (underflow, incl. exact zeros)
    pub zeros: u64,
    /// finite positions in the top FP8 binade (`|g| >= F8_MAX / 2`)
    pub top_binade: u64,
    /// non-finite positions — `> 0` means this window overflowed
    pub non_finite: u64,
    /// largest finite magnitude seen
    pub max_abs: f32,
}

/// Scan a merged gradient slice **before** `finalize_grads` quantizes
/// it in place — read-only, single-threaded, post-merge, so the scan
/// is deterministic and cannot perturb the update.
pub fn grad_saturation(gs: &[f32]) -> GradSat {
    let mut s = GradSat { total: gs.len() as u64, ..GradSat::default() };
    let top = F8_MAX * 0.5;
    for &g in gs {
        if !g.is_finite() {
            s.non_finite += 1;
            continue;
        }
        let a = g.abs();
        if round_f8(g) == 0.0 {
            s.zeros += 1;
        } else if a >= top {
            s.top_binade += 1;
        }
        if a > s.max_abs {
            s.max_abs = a;
        }
    }
    s
}

/// Number of FloatSD8 exponent-field values (3 bits).
pub const SD8_EXP_LEVELS: usize = 8;

/// FloatSD8 code-population profile of one weight matrix after
/// re-encode: exponent-field histogram + codes at the format's extreme
/// magnitude (±4.5 — the saturation bin).
#[derive(Clone, Copy, Debug, Default)]
pub struct CodeStats {
    pub total: u64,
    pub at_max: u64,
    pub exp_hist: [u64; SD8_EXP_LEVELS],
}

/// Scan one quantized weight matrix (read-only; run after
/// `MasterStack::apply` re-encoded the step's weights).
pub fn code_stats(m: &QMatrix) -> CodeStats {
    let mut s = CodeStats { total: m.codes.len() as u64, ..CodeStats::default() };
    for &c in &m.codes {
        s.exp_hist[FLOAT_SD8.code_exponent(c) as usize] += 1;
        if FLOAT_SD8.is_max_magnitude(c) {
            s.at_max += 1;
        }
    }
    s
}

/// The FloatSD8 weight matrices of a stack, named like the gradient
/// slices ("l1.wx", "l1.wh", …, "head.w"); `prefix` (e.g. the mt
/// encoder's "enc") is dot-joined in front when non-empty. Biases and
/// the embedding are FP16-direct, not FloatSD8, so they have no codes
/// to scan.
pub fn stack_qmatrices<'a>(stack: &'a QLstmStack, prefix: &str) -> Vec<(String, &'a QMatrix)> {
    let name = |s: String| if prefix.is_empty() { s } else { format!("{prefix}.{s}") };
    let mut out = Vec::with_capacity(2 * stack.layers.len() + 1);
    for (l, layer) in stack.layers.iter().enumerate() {
        out.push((name(format!("l{}.wx", l + 1)), &layer.fwd.wx));
        out.push((name(format!("l{}.wh", l + 1)), &layer.fwd.wh));
    }
    out.push((name("head.w".to_string()), &stack.head.w));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_are_upper_inclusive_with_overflow() {
        let h = Histogram::new(&[1, 2, 4, 8]);
        for v in [0u64, 1, 2, 3, 4, 5, 8, 9, 1000] {
            h.record(v);
        }
        // buckets: <=1, <=2, <=4, <=8, overflow
        assert_eq!(h.counts(), vec![2, 1, 2, 2, 2]);
        assert_eq!(h.total(), 9);
        assert_eq!(h.bounds(), &[1, 2, 4, 8]);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn histogram_rejects_unsorted_bounds() {
        let _ = Histogram::new(&[4, 2]);
    }

    #[test]
    fn sample_window_overwrites_oldest_in_place() {
        // mirrors the serve latency ring's pinned semantics: capacity
        // samples fill in order, then overwrites start at slot 0
        let cap = 64usize;
        let mut w = SampleWindow::new(cap);
        for i in 0..cap + 10 {
            w.push(Duration::from_nanos(i as u64));
        }
        assert_eq!(w.len(), cap);
        assert_eq!(w.samples()[0], Duration::from_nanos(cap as u64));
        assert_eq!(w.samples()[9], Duration::from_nanos(cap as u64 + 9));
        assert_eq!(w.samples()[10], Duration::from_nanos(10));
    }

    #[test]
    fn grad_saturation_classifies_zero_top_and_nonfinite() {
        let top = F8_MAX * 0.5;
        let gs =
            [0.0f32, 1e-9, 1.0, -top, F8_MAX, f32::INFINITY, f32::NAN, -f32::INFINITY, 2.0];
        let s = grad_saturation(&gs);
        assert_eq!(s.total, 9);
        assert_eq!(s.zeros, 2, "exact zero + sub-FP8 underflow");
        assert_eq!(s.top_binade, 2, "-F8_MAX/2 and F8_MAX");
        assert_eq!(s.non_finite, 3);
        assert_eq!(s.max_abs, F8_MAX);
    }

    #[test]
    fn code_stats_bins_every_code_once() {
        let vals = [0.0f32, 4.5, -4.5, 1.0, 0.25, -0.03125];
        let m = QMatrix::from_f32(2, 3, &vals);
        let s = code_stats(&m);
        assert_eq!(s.total, 6);
        assert_eq!(s.at_max, 2, "±4.5 are the saturated codes");
        assert_eq!(s.exp_hist.iter().sum::<u64>(), 6, "every code lands in one exponent bin");
    }

    #[test]
    fn kernel_profile_accumulates_and_diffs_by_shape_class() {
        // unusual shape so concurrently running lib tests (which may
        // hold the gate open) can never land in the same class
        let (r, c) = (1111usize, 222usize);
        let base = kernel_profile();
        let sc = IsaPath::Scalar;
        note_kernel(KernelOp::Matvec, KernelTier::Decoded, sc, r, c, 1, Duration::from_nanos(100));
        note_kernel(KernelOp::Matvec, KernelTier::Decoded, sc, r, c, 1, Duration::from_nanos(50));
        note_kernel(
            KernelOp::Matmul,
            KernelTier::ShiftAdd,
            IsaPath::Sse2,
            r,
            c,
            8,
            Duration::from_nanos(10),
        );
        let since = kernel_profile_since(&base);
        let mv = since
            .iter()
            .find(|x| x.op == "matvec" && x.rows == r as u64 && x.batch == 1)
            .expect("matvec class recorded");
        assert_eq!(
            (mv.tier, mv.isa, mv.cols, mv.calls, mv.nanos),
            ("decoded", "scalar", c as u64, 2, 150)
        );
        let mm = since
            .iter()
            .find(|x| x.op == "matmul" && x.rows == r as u64 && x.batch == 8)
            .expect("matmul class recorded");
        assert_eq!((mm.tier, mm.isa, mm.calls, mm.nanos), ("shiftadd", "sse2", 1, 10));
        // a second diff against the advanced profile drops both classes
        let now = kernel_profile();
        assert!(kernel_profile_since(&now)
            .iter()
            .all(|x| x.rows != r as u64));
    }

    #[test]
    fn act_snapshots_diff_against_a_baseline() {
        let base = ActSnapshot { evals: 10, clip_lo: 2, clip_hi: 1 };
        let now = ActSnapshot { evals: 15, clip_lo: 2, clip_hi: 3 };
        assert_eq!(now.since(base), ActSnapshot { evals: 5, clip_lo: 0, clip_hi: 2 });
    }
}
