//! `floatsd-lstm report <trace.jsonl>` — render a `floatsd-trace-v1`
//! stream ([`super::trace`]) into a human-readable numerics-health
//! summary: loss-scale event history, per-tensor FP8 gradient
//! saturation rates, per-matrix FloatSD8 re-encode saturation, and
//! activation clip rates.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::tensorfile::json::Json;

use super::trace::TRACE_SCHEMA;

pub fn run_cli(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("trace"))
        .context("usage: floatsd-lstm report <trace.jsonl>")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("read trace {path}"))?;
    print!("{}", summarize(&text).with_context(|| format!("summarize trace {path}"))?);
    Ok(())
}

#[derive(Default)]
struct GradAgg {
    steps: u64,
    total: u64,
    zeros: u64,
    top: u64,
    non_finite: u64,
    max_abs: f64,
}

/// Aggregate a trace into the report text (separated from [`run_cli`]
/// so tests can pin it without touching stdout).
pub fn summarize(text: &str) -> Result<String> {
    let mut events = 0u64;
    let mut config: Option<Json> = None;
    let mut steps = 0u64;
    let mut applied = 0u64;
    let mut first_loss: Option<f64> = None;
    let mut last_loss: Option<f64> = None;
    let mut backoffs = 0u64;
    let mut growths = 0u64;
    let mut scale_min = f64::INFINITY;
    let mut scale_max = f64::NEG_INFINITY;
    let mut final_scale: Option<f64> = None;
    let mut skipped: Option<f64> = None;
    let mut grads: BTreeMap<String, GradAgg> = BTreeMap::new();
    let mut weights: Option<Json> = None;
    let mut acts: Option<Json> = None;

    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("trace line {}", ln + 1))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(TRACE_SCHEMA) => {}
            other => bail!("trace line {}: schema {other:?}, expected {TRACE_SCHEMA:?}", ln + 1),
        }
        events += 1;
        let ev = j
            .get("ev")
            .and_then(Json::as_str)
            .with_context(|| format!("trace line {}: missing ev", ln + 1))?;
        let num = |key: &str| j.get(key).and_then(Json::as_f64);
        match ev {
            "run_start" => config = j.get("config").cloned(),
            "step" => {
                steps += 1;
                if j.get("applied").and_then(Json::as_bool) == Some(true) {
                    applied += 1;
                }
                if let Some(l) = num("loss") {
                    first_loss.get_or_insert(l);
                    last_loss = Some(l);
                }
                if let Some(s) = num("scale") {
                    scale_min = scale_min.min(s);
                    scale_max = scale_max.max(s);
                    final_scale = Some(s);
                }
                if let Some(g) = j.get("grads").and_then(Json::as_obj) {
                    for (name, t) in g {
                        let a = grads.entry(name.clone()).or_default();
                        let field =
                            |k: &str| t.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
                        a.steps += 1;
                        a.total += field("total");
                        a.zeros += field("fp8_zero");
                        a.top += field("fp8_top_binade");
                        a.non_finite += field("non_finite");
                        if let Some(m) = t.get("max_abs").and_then(Json::as_f64) {
                            a.max_abs = a.max_abs.max(m);
                        }
                    }
                }
                if let Some(a) = j.get("acts") {
                    acts = Some(a.clone());
                }
            }
            "loss_scale" => {
                match j.get("cause").and_then(Json::as_str) {
                    Some("backoff") => backoffs += 1,
                    Some("growth") => growths += 1,
                    _ => {}
                }
                if let Some(to) = num("to") {
                    scale_min = scale_min.min(to);
                    scale_max = scale_max.max(to);
                    final_scale = Some(to);
                }
            }
            "reencode" | "run_end" => {
                if let Some(w) = j.get("weights") {
                    weights = Some(w.clone());
                }
                if let Some(a) = j.get("acts") {
                    acts = Some(a.clone());
                }
                if ev == "run_end" {
                    if let Some(s) = num("final_scale") {
                        final_scale = Some(s);
                    }
                    skipped = num("skipped");
                }
            }
            _ => {}
        }
    }
    if events == 0 {
        bail!("empty trace");
    }

    let pct = |n: u64, d: u64| if d == 0 { 0.0 } else { 100.0 * n as f64 / d as f64 };
    let mut out = String::new();
    let _ = writeln!(out, "trace: {TRACE_SCHEMA}, {events} events");
    if let Some(cfg) = &config {
        let _ = writeln!(out, "config: {cfg}");
    }
    let skipped = skipped.unwrap_or((steps - applied) as f64);
    let _ = write!(out, "steps: {steps} ({applied} applied, {skipped} skipped)");
    if let (Some(a), Some(b)) = (first_loss, last_loss) {
        let _ = write!(out, " | loss {a:.4} -> {b:.4}");
    }
    out.push('\n');
    let _ = write!(out, "loss scale: {backoffs} backoffs, {growths} growths");
    if let Some(s) = final_scale {
        let _ = write!(out, " | final {s} (min {scale_min}, max {scale_max})");
    }
    out.push('\n');
    if !grads.is_empty() {
        let _ = writeln!(out, "fp8 gradient saturation (over {steps} steps):");
        for (name, a) in &grads {
            let _ = writeln!(
                out,
                "  {name:<12} zero {:6.2}%  top-binade {:6.2}%  non-finite {:6.2}%  max|g| {:.4}",
                pct(a.zeros, a.total),
                pct(a.top, a.total),
                pct(a.non_finite, a.total),
                a.max_abs
            );
        }
    }
    if let Some(Json::Obj(ws)) = &weights {
        let _ = writeln!(out, "floatsd8 weight saturation (final re-encode):");
        for (name, t) in ws {
            let total = t.get("total").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let at_max = t.get("at_max").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let hist: Vec<String> = t
                .get("exp_hist")
                .and_then(Json::as_arr)
                .map(|a| a.iter().map(|v| v.to_string()).collect())
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "  {name:<12} at-max {:6.2}%  exp-hist [{}]",
                pct(at_max, total),
                hist.join(",")
            );
        }
    }
    if let Some(a) = &acts {
        let one = |key: &str| -> Option<String> {
            let s = a.get(key)?;
            let f = |k: &str| s.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let (evals, lo, hi) = (f("evals"), f("clip_lo"), f("clip_hi"));
            Some(format!(
                "{key} {evals} evals (lo {:.2}%, hi {:.2}%)",
                pct(lo, evals),
                pct(hi, evals)
            ))
        };
        let parts: Vec<String> =
            ["sigmoid", "tanh"].iter().filter_map(|k| one(k)).collect();
        if !parts.is_empty() {
            let _ = writeln!(out, "activation clips: {}", parts.join("; "));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        format!("{{\"schema\":\"{TRACE_SCHEMA}\",{s}}}\n")
    }

    #[test]
    fn summarize_covers_every_section() {
        let mut t = String::new();
        t.push_str(&line(r#""ev":"run_start","step":0,"config":{"task":"lm","seed":"7"}"#));
        let grads = r#""grads":{"emb":{"total":10,"fp8_zero":4,"fp8_top_binade":1,"non_finite":2,"max_abs":99.5}}"#;
        let acts = r#""acts":{"sigmoid":{"evals":100,"clip_lo":5,"clip_hi":1},"tanh":{"evals":50,"clip_lo":0,"clip_hi":2}}"#;
        t.push_str(&line(&format!(
            r#""ev":"step","step":1,"loss":2.5,"scale":1024,"applied":false,{grads},{acts}"#
        )));
        t.push_str(&line(
            r#""ev":"loss_scale","step":1,"cause":"backoff","from":1024,"to":512,"skipped_total":1"#,
        ));
        let weights = r#""weights":{"l1.wx":{"total":64,"at_max":3,"exp_hist":[0,1,2,3,4,5,6,43]}}"#;
        t.push_str(&line(&format!(
            r#""ev":"run_end","step":1,"final_scale":512,"applied":0,"skipped":1,{weights}"#
        )));
        let s = summarize(&t).unwrap();
        assert!(s.contains("steps: 1 (0 applied, 1 skipped)"), "{s}");
        assert!(s.contains("loss 2.5000 -> 2.5000"), "{s}");
        assert!(s.contains("1 backoffs, 0 growths"), "{s}");
        assert!(s.contains("emb"), "{s}");
        assert!(s.contains("l1.wx"), "{s}");
        assert!(s.contains("at-max"), "{s}");
        assert!(s.contains("sigmoid 100 evals"), "{s}");
        assert!(s.contains("\"task\":\"lm\""), "{s}");
    }

    #[test]
    fn summarize_rejects_foreign_schemas() {
        assert!(summarize("{\"schema\":\"other-v9\",\"ev\":\"step\"}\n").is_err());
        assert!(summarize("").is_err());
    }
}
