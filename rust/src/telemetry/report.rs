//! `floatsd-lstm report <trace.jsonl>` — render a trace stream into a
//! human-readable summary. Three document schemas are understood,
//! detected from the stream itself:
//!
//! * `floatsd-trace-v1` ([`super::trace`]): numerics health — loss-
//!   scale event history, per-tensor FP8 gradient saturation rates,
//!   per-matrix FloatSD8 re-encode saturation, activation clip rates;
//! * `floatsd-serve-trace-v1` ([`super::serve_trace`]): request
//!   lifecycle — per-kind request/work counts, batch occupancy, queue
//!   depth and high-water, session lifecycle, queue-wait/service span
//!   percentiles, and the per-tier kernel profile;
//! * [`EVAL_SCHEMA`] (`floatsd-eval-v1`, [`crate::tasks::eval`]): the
//!   Table-IV eval grid — per-task loss/metric/count rows.
//!
//! `floatsd-lstm report --diff <a> <b>` compares two documents of the
//! same schema side by side and flags regressions: loss-scale
//! event-count drift, gradient-saturation deltas above
//! [`SAT_DELTA_PP`] percentage points, p50/p99 span regressions above
//! [`SPAN_REGRESSION_PCT`] percent — and, for a pair of eval reports,
//! per-task metric drift (accuracy drift in percentage points against
//! `--sat-delta-pp`, loss/ppl regressions in percent against
//! `--span-regression-pct`). Both thresholds are tunable per
//! invocation — `--sat-delta-pp X` and `--span-regression-pct Y`
//! override the defaults (values must be finite and non-negative).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::tensorfile::json::Json;

use super::serve_trace::SERVE_TRACE_SCHEMA;
use super::trace::TRACE_SCHEMA;

/// The eval-grid document schema ([`crate::tasks::eval`] writes it;
/// `report`/`report --diff` consume it).
pub const EVAL_SCHEMA: &str = "floatsd-eval-v1";

/// `--diff` flags gradient/weight saturation-rate deltas above this
/// many percentage points (default for `--sat-delta-pp`).
pub const SAT_DELTA_PP: f64 = 5.0;

/// `--diff` flags p50/p99 span (service-latency) regressions above
/// this percentage (default for `--span-regression-pct`).
pub const SPAN_REGRESSION_PCT: f64 = 20.0;

/// The `--diff` flagging thresholds; [`Default`] carries the
/// compile-time values, the CLI flags override per invocation.
#[derive(Clone, Copy, Debug)]
pub struct DiffThresholds {
    /// saturation-rate delta flag, percentage points (`--sat-delta-pp`)
    pub sat_delta_pp: f64,
    /// span-regression flag, percent (`--span-regression-pct`)
    pub span_regression_pct: f64,
}

impl Default for DiffThresholds {
    fn default() -> Self {
        DiffThresholds { sat_delta_pp: SAT_DELTA_PP, span_regression_pct: SPAN_REGRESSION_PCT }
    }
}

impl DiffThresholds {
    /// Parse from CLI flags, rejecting values a threshold can't mean:
    /// NaN/inf would silently disable (or always fire) a flag, and a
    /// negative bound can never be crossed sensibly.
    pub fn from_args(args: &Args) -> Result<DiffThresholds> {
        let th = DiffThresholds {
            sat_delta_pp: args.opt_f64("sat-delta-pp", SAT_DELTA_PP)?,
            span_regression_pct: args.opt_f64("span-regression-pct", SPAN_REGRESSION_PCT)?,
        };
        for (flag, v) in [
            ("sat-delta-pp", th.sat_delta_pp),
            ("span-regression-pct", th.span_regression_pct),
        ] {
            if !v.is_finite() {
                bail!("--{flag} must be a finite number, got {v}");
            }
            if v < 0.0 {
                bail!("--{flag} must be >= 0 (a negative threshold would flag every delta), got {v}");
            }
        }
        Ok(th)
    }
}

pub fn run_cli(args: &Args) -> Result<()> {
    if let Some(a) = args.opt("diff") {
        let b = args
            .positionals
            .first()
            .map(String::as_str)
            .context("usage: floatsd-lstm report --diff <a.jsonl> <b.jsonl>")?;
        let th = DiffThresholds::from_args(args)?;
        let ta = std::fs::read_to_string(a).with_context(|| format!("read trace {a}"))?;
        let tb = std::fs::read_to_string(b).with_context(|| format!("read trace {b}"))?;
        print!(
            "{}",
            diff_with(&ta, &tb, th).with_context(|| format!("diff traces {a} vs {b}"))?
        );
        return Ok(());
    }
    let path = args
        .positionals
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("trace"))
        .context("usage: floatsd-lstm report <trace.jsonl> | report --diff <a> <b>")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("read trace {path}"))?;
    print!("{}", summarize(&text).with_context(|| format!("summarize trace {path}"))?);
    Ok(())
}

/// Which document schema a stream carries, from its first non-empty
/// line (an eval report is a single JSON object, so its first line is
/// the whole document).
fn detect_schema(text: &str) -> Result<&'static str> {
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).context("trace line 1")?;
        return match j.get("schema").and_then(Json::as_str) {
            Some(s) if s == TRACE_SCHEMA => Ok(TRACE_SCHEMA),
            Some(s) if s == SERVE_TRACE_SCHEMA => Ok(SERVE_TRACE_SCHEMA),
            Some(s) if s == EVAL_SCHEMA => Ok(EVAL_SCHEMA),
            other => bail!(
                "trace line 1: schema {other:?}, expected {TRACE_SCHEMA:?}, \
                 {SERVE_TRACE_SCHEMA:?}, or {EVAL_SCHEMA:?}"
            ),
        };
    }
    bail!("empty trace")
}

/// Aggregate a trace into the report text (separated from [`run_cli`]
/// so tests can pin it without touching stdout). Dispatches on the
/// schema detected in the stream.
pub fn summarize(text: &str) -> Result<String> {
    match detect_schema(text)? {
        SERVE_TRACE_SCHEMA => Ok(render_serve(&parse_serve(text)?)),
        EVAL_SCHEMA => Ok(render_eval(&parse_eval(text)?)),
        _ => Ok(render_train(&parse_train(text)?)),
    }
}

/// Side-by-side comparison of two documents of the same schema,
/// flagging loss-scale drift, saturation deltas, span regressions,
/// and per-task eval metric drift at the default thresholds.
pub fn diff(a: &str, b: &str) -> Result<String> {
    diff_with(a, b, DiffThresholds::default())
}

/// [`diff`] with caller-chosen flagging thresholds.
pub fn diff_with(a: &str, b: &str, th: DiffThresholds) -> Result<String> {
    let (sa, sb) = (detect_schema(a)?, detect_schema(b)?);
    if sa != sb {
        bail!("cannot diff traces of different schemas ({sa} vs {sb})");
    }
    match sa {
        SERVE_TRACE_SCHEMA => Ok(diff_serve(&parse_serve(a)?, &parse_serve(b)?, th)),
        EVAL_SCHEMA => Ok(diff_eval(&parse_eval(a)?, &parse_eval(b)?, th)),
        _ => Ok(diff_train(&parse_train(a)?, &parse_train(b)?, th)),
    }
}

// ---------------------------------------------------------------- train

#[derive(Default)]
struct GradAgg {
    steps: u64,
    total: u64,
    zeros: u64,
    top: u64,
    non_finite: u64,
    max_abs: f64,
}

struct TrainAgg {
    events: u64,
    config: Option<Json>,
    steps: u64,
    applied: u64,
    first_loss: Option<f64>,
    last_loss: Option<f64>,
    backoffs: u64,
    growths: u64,
    scale_min: f64,
    scale_max: f64,
    final_scale: Option<f64>,
    skipped: Option<f64>,
    grads: BTreeMap<String, GradAgg>,
    weights: Option<Json>,
    acts: Option<Json>,
}

fn parse_train(text: &str) -> Result<TrainAgg> {
    let mut a = TrainAgg {
        events: 0,
        config: None,
        steps: 0,
        applied: 0,
        first_loss: None,
        last_loss: None,
        backoffs: 0,
        growths: 0,
        scale_min: f64::INFINITY,
        scale_max: f64::NEG_INFINITY,
        final_scale: None,
        skipped: None,
        grads: BTreeMap::new(),
        weights: None,
        acts: None,
    };
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("trace line {}", ln + 1))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(TRACE_SCHEMA) => {}
            other => bail!("trace line {}: schema {other:?}, expected {TRACE_SCHEMA:?}", ln + 1),
        }
        a.events += 1;
        let ev = j
            .get("ev")
            .and_then(Json::as_str)
            .with_context(|| format!("trace line {}: missing ev", ln + 1))?;
        let num = |key: &str| j.get(key).and_then(Json::as_f64);
        match ev {
            "run_start" => a.config = j.get("config").cloned(),
            "step" => {
                a.steps += 1;
                if j.get("applied").and_then(Json::as_bool) == Some(true) {
                    a.applied += 1;
                }
                if let Some(l) = num("loss") {
                    a.first_loss.get_or_insert(l);
                    a.last_loss = Some(l);
                }
                if let Some(s) = num("scale") {
                    a.scale_min = a.scale_min.min(s);
                    a.scale_max = a.scale_max.max(s);
                    a.final_scale = Some(s);
                }
                if let Some(g) = j.get("grads").and_then(Json::as_obj) {
                    for (name, t) in g {
                        let agg = a.grads.entry(name.clone()).or_default();
                        let field =
                            |k: &str| t.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
                        agg.steps += 1;
                        agg.total += field("total");
                        agg.zeros += field("fp8_zero");
                        agg.top += field("fp8_top_binade");
                        agg.non_finite += field("non_finite");
                        if let Some(m) = t.get("max_abs").and_then(Json::as_f64) {
                            agg.max_abs = agg.max_abs.max(m);
                        }
                    }
                }
                if let Some(ac) = j.get("acts") {
                    a.acts = Some(ac.clone());
                }
            }
            "loss_scale" => {
                match j.get("cause").and_then(Json::as_str) {
                    Some("backoff") => a.backoffs += 1,
                    Some("growth") => a.growths += 1,
                    _ => {}
                }
                if let Some(to) = num("to") {
                    a.scale_min = a.scale_min.min(to);
                    a.scale_max = a.scale_max.max(to);
                    a.final_scale = Some(to);
                }
            }
            "reencode" | "run_end" => {
                if let Some(w) = j.get("weights") {
                    a.weights = Some(w.clone());
                }
                if let Some(ac) = j.get("acts") {
                    a.acts = Some(ac.clone());
                }
                if ev == "run_end" {
                    if let Some(s) = num("final_scale") {
                        a.final_scale = Some(s);
                    }
                    a.skipped = num("skipped");
                }
            }
            _ => {}
        }
    }
    if a.events == 0 {
        bail!("empty trace");
    }
    Ok(a)
}

fn pct(n: u64, d: u64) -> f64 {
    if d == 0 {
        0.0
    } else {
        100.0 * n as f64 / d as f64
    }
}

fn render_train(a: &TrainAgg) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "trace: {TRACE_SCHEMA}, {} events", a.events);
    if let Some(cfg) = &a.config {
        let _ = writeln!(out, "config: {cfg}");
    }
    let skipped = a.skipped.unwrap_or((a.steps - a.applied) as f64);
    let _ = write!(out, "steps: {} ({} applied, {skipped} skipped)", a.steps, a.applied);
    if let (Some(first), Some(last)) = (a.first_loss, a.last_loss) {
        let _ = write!(out, " | loss {first:.4} -> {last:.4}");
    }
    out.push('\n');
    let _ = write!(out, "loss scale: {} backoffs, {} growths", a.backoffs, a.growths);
    if let Some(s) = a.final_scale {
        let _ = write!(out, " | final {s} (min {}, max {})", a.scale_min, a.scale_max);
    }
    out.push('\n');
    if !a.grads.is_empty() {
        let _ = writeln!(out, "fp8 gradient saturation (over {} steps):", a.steps);
        for (name, g) in &a.grads {
            let _ = writeln!(
                out,
                "  {name:<12} zero {:6.2}%  top-binade {:6.2}%  non-finite {:6.2}%  max|g| {:.4}",
                pct(g.zeros, g.total),
                pct(g.top, g.total),
                pct(g.non_finite, g.total),
                g.max_abs
            );
        }
    }
    if let Some(Json::Obj(ws)) = &a.weights {
        let _ = writeln!(out, "floatsd8 weight saturation (final re-encode):");
        for (name, t) in ws {
            let total = t.get("total").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let at_max = t.get("at_max").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let hist: Vec<String> = t
                .get("exp_hist")
                .and_then(Json::as_arr)
                .map(|arr| arr.iter().map(|v| v.to_string()).collect())
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "  {name:<12} at-max {:6.2}%  exp-hist [{}]",
                pct(at_max, total),
                hist.join(",")
            );
        }
    }
    if let Some(acts) = &a.acts {
        let one = |key: &str| -> Option<String> {
            let s = acts.get(key)?;
            let f = |k: &str| s.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let (evals, lo, hi) = (f("evals"), f("clip_lo"), f("clip_hi"));
            Some(format!(
                "{key} {evals} evals (lo {:.2}%, hi {:.2}%)",
                pct(lo, evals),
                pct(hi, evals)
            ))
        };
        let parts: Vec<String> = ["sigmoid", "tanh"].iter().filter_map(|k| one(k)).collect();
        if !parts.is_empty() {
            let _ = writeln!(out, "activation clips: {}", parts.join("; "));
        }
    }
    out
}

fn diff_train(a: &TrainAgg, b: &TrainAgg, th: DiffThresholds) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "diff ({TRACE_SCHEMA}): a={} events, b={} events", a.events, b.events);
    let _ = writeln!(
        out,
        "steps: {} -> {} (applied {} -> {})",
        a.steps, b.steps, a.applied, b.applied
    );
    if let (Some(la), Some(lb)) = (a.last_loss, b.last_loss) {
        let _ = writeln!(out, "final loss: {la:.4} -> {lb:.4} ({:+.4})", lb - la);
    }
    let drift = a.backoffs != b.backoffs || a.growths != b.growths;
    let _ = writeln!(
        out,
        "loss-scale events: backoffs {} -> {}, growths {} -> {}{}",
        a.backoffs,
        b.backoffs,
        a.growths,
        b.growths,
        if drift { "  [FLAG: loss-scale event-count drift]" } else { "" }
    );
    if !a.grads.is_empty() || !b.grads.is_empty() {
        let _ = writeln!(out, "fp8 gradient saturation deltas (percentage points):");
        let names: std::collections::BTreeSet<&String> =
            a.grads.keys().chain(b.grads.keys()).collect();
        for name in names {
            let empty = GradAgg::default();
            let ga = a.grads.get(name).unwrap_or(&empty);
            let gb = b.grads.get(name).unwrap_or(&empty);
            let dz = pct(gb.zeros, gb.total) - pct(ga.zeros, ga.total);
            let dt = pct(gb.top, gb.total) - pct(ga.top, ga.total);
            let flag = dz.abs() > th.sat_delta_pp || dt.abs() > th.sat_delta_pp;
            let _ = writeln!(
                out,
                "  {name:<12} zero {dz:+6.2}pp  top-binade {dt:+6.2}pp{}",
                if flag {
                    format!("  [FLAG: saturation delta > {}pp]", th.sat_delta_pp)
                } else {
                    String::new()
                }
            );
        }
    }
    out
}

// ---------------------------------------------------------------- serve

struct ServeAgg {
    events: u64,
    start: Option<Json>,
    end: Option<Json>,
    ev_counts: BTreeMap<String, u64>,
    kind_requests: BTreeMap<String, u64>,
    kind_work: BTreeMap<String, u64>,
    batches: u64,
    batch_requests: u64,
    queue_depth_max: u64,
    queue_high_water: u64,
    sessions_max: u64,
    opens: u64,
    closes: u64,
    rejects: BTreeMap<String, u64>,
    /// per-request spans, trace order (wall clock — marked timing data)
    queue_wait_us: Vec<f64>,
    service_us: Vec<f64>,
}

fn parse_serve(text: &str) -> Result<ServeAgg> {
    let mut a = ServeAgg {
        events: 0,
        start: None,
        end: None,
        ev_counts: BTreeMap::new(),
        kind_requests: BTreeMap::new(),
        kind_work: BTreeMap::new(),
        batches: 0,
        batch_requests: 0,
        queue_depth_max: 0,
        queue_high_water: 0,
        sessions_max: 0,
        opens: 0,
        closes: 0,
        rejects: BTreeMap::new(),
        queue_wait_us: Vec::new(),
        service_us: Vec::new(),
    };
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("trace line {}", ln + 1))?;
        match j.get("schema").and_then(Json::as_str) {
            Some(SERVE_TRACE_SCHEMA) => {}
            other => bail!(
                "trace line {}: schema {other:?}, expected {SERVE_TRACE_SCHEMA:?}",
                ln + 1
            ),
        }
        a.events += 1;
        let ev = j
            .get("ev")
            .and_then(Json::as_str)
            .with_context(|| format!("trace line {}: missing ev", ln + 1))?;
        *a.ev_counts.entry(ev.to_string()).or_default() += 1;
        let unum = |key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        match ev {
            "serve_start" => a.start = Some(j.clone()),
            "serve_end" => a.end = Some(j.clone()),
            "session_open" => a.opens += 1,
            "session_close" => a.closes += 1,
            "reject" => {
                let reason = j
                    .get("reason")
                    .and_then(Json::as_str)
                    .unwrap_or("(unspecified)")
                    .to_string();
                *a.rejects.entry(reason).or_default() += 1;
            }
            "batch" => {
                a.batches += 1;
                a.batch_requests += unum("requests");
                a.queue_depth_max = a.queue_depth_max.max(unum("queue_depth"));
                a.queue_high_water = a.queue_high_water.max(unum("queue_high_water"));
                a.sessions_max = a.sessions_max.max(unum("sessions"));
            }
            "request" => {
                let kind =
                    j.get("kind").and_then(Json::as_str).unwrap_or("(unknown)").to_string();
                *a.kind_requests.entry(kind.clone()).or_default() += 1;
                *a.kind_work.entry(kind).or_default() += unum("work");
                if let Some(t) = j.get("timing") {
                    if let Some(w) = t.get("queue_wait_us").and_then(Json::as_f64) {
                        a.queue_wait_us.push(w);
                    }
                    if let Some(s) = t.get("service_us").and_then(Json::as_f64) {
                        a.service_us.push(s);
                    }
                }
            }
            _ => {}
        }
    }
    if a.events == 0 {
        bail!("empty trace");
    }
    Ok(a)
}

/// Nearest-rank percentile of an unsorted sample set (sorts a copy).
fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|x, y| x.partial_cmp(y).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((s.len() as f64 - 1.0) * q).round() as usize;
    s[idx.min(s.len() - 1)]
}

fn render_serve(a: &ServeAgg) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "trace: {SERVE_TRACE_SCHEMA}, {} events", a.events);
    if let Some(s) = &a.start {
        let field = |k: &str| {
            s.get(k)
                .map(|v| match v {
                    Json::Str(st) => st.clone(),
                    other => other.to_string(),
                })
                .unwrap_or_else(|| "?".to_string())
        };
        let _ = writeln!(
            out,
            "serve: task={} workers={} max_batch={} window_us={} kernel_tier={}",
            field("task"),
            field("workers"),
            field("max_batch"),
            field("window_us"),
            field("kernel_tier")
        );
    }
    let counts: Vec<String> =
        a.ev_counts.iter().map(|(ev, n)| format!("{ev} {n}")).collect();
    let _ = writeln!(out, "events: {}", counts.join(", "));
    let total_rejects: u64 = a.rejects.values().sum();
    let _ = writeln!(
        out,
        "sessions: {} opened, {} closed, {} rejected requests",
        a.opens, a.closes, total_rejects
    );
    for (reason, n) in &a.rejects {
        let _ = writeln!(out, "  reject x{n}: {reason}");
    }
    let occ = if a.batches == 0 { 0.0 } else { a.batch_requests as f64 / a.batches as f64 };
    let _ = writeln!(
        out,
        "batches: {} (mean occupancy {occ:.2}) | queue depth max {} high-water {} | live sessions max {}",
        a.batches, a.queue_depth_max, a.queue_high_water, a.sessions_max
    );
    if !a.kind_requests.is_empty() {
        let _ = writeln!(out, "per-kind requests:");
        for (kind, n) in &a.kind_requests {
            let work = a.kind_work.get(kind).copied().unwrap_or(0);
            let _ = writeln!(out, "  {kind:<9} {n:>8} requests  {work:>10} work units");
        }
    }
    if !a.service_us.is_empty() {
        let _ = writeln!(
            out,
            "spans: service p50 {:.0} us, p99 {:.0} us | queue-wait p50 {:.0} us, p99 {:.0} us",
            percentile(&a.service_us, 0.50),
            percentile(&a.service_us, 0.99),
            percentile(&a.queue_wait_us, 0.50),
            percentile(&a.queue_wait_us, 0.99)
        );
    }
    if let Some(end) = &a.end {
        let unum = |k: &str| end.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let _ = writeln!(
            out,
            "totals: {} tokens / {} requests / {} batches (queue high-water {})",
            unum("tokens"),
            unum("requests"),
            unum("batches"),
            unum("queue_high_water")
        );
        if let Some(profile) = end.get("kernel_profile").and_then(Json::as_arr) {
            if !profile.is_empty() {
                let _ = writeln!(out, "kernel profile (per shape class):");
                for row in profile {
                    let s = |k: &str| row.get(k).and_then(Json::as_str).unwrap_or("?");
                    let n = |k: &str| row.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                    let t = |k: &str| {
                        row.get("timing").and_then(|t| t.get(k)).and_then(Json::as_f64)
                    };
                    let _ = writeln!(
                        out,
                        "  {:<6} {:<9} {}x{} b{}: {} calls, {:.3} ms total, {:.1} us mean",
                        s("op"),
                        s("tier"),
                        n("rows"),
                        n("cols"),
                        n("batch"),
                        n("calls"),
                        t("total_ms").unwrap_or(0.0),
                        t("mean_us").unwrap_or(0.0)
                    );
                }
            }
        }
    }
    out
}

fn diff_serve(a: &ServeAgg, b: &ServeAgg, th: DiffThresholds) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "diff ({SERVE_TRACE_SCHEMA}): a={} events, b={} events",
        a.events, b.events
    );
    let end_num = |agg: &ServeAgg, k: &str| {
        agg.end.as_ref().and_then(|e| e.get(k)).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let _ = writeln!(
        out,
        "totals: tokens {} -> {}, requests {} -> {}, batches {} -> {}",
        end_num(a, "tokens"),
        end_num(b, "tokens"),
        end_num(a, "requests"),
        end_num(b, "requests"),
        a.batches,
        b.batches
    );
    let (ra, rb): (u64, u64) = (a.rejects.values().sum(), b.rejects.values().sum());
    let _ = writeln!(
        out,
        "rejects: {ra} -> {rb} | queue high-water {} -> {} | sessions opened {} -> {}",
        a.queue_high_water, b.queue_high_water, a.opens, b.opens
    );
    let names: std::collections::BTreeSet<&String> =
        a.kind_requests.keys().chain(b.kind_requests.keys()).collect();
    for kind in names {
        let (na, nb) = (
            a.kind_requests.get(kind).copied().unwrap_or(0),
            b.kind_requests.get(kind).copied().unwrap_or(0),
        );
        if na != nb {
            let _ = writeln!(out, "  {kind}: {na} -> {nb} requests  [FLAG: request-count drift]");
        }
    }
    for (label, q) in [("p50", 0.50), ("p99", 0.99)] {
        let (va, vb) = (percentile(&a.service_us, q), percentile(&b.service_us, q));
        if va <= 0.0 && vb <= 0.0 {
            continue;
        }
        let change = if va > 0.0 { 100.0 * (vb - va) / va } else { f64::INFINITY };
        let flag = change > th.span_regression_pct;
        let _ = writeln!(
            out,
            "service {label}: {va:.0} us -> {vb:.0} us ({change:+.1}%){}",
            if flag {
                format!("  [FLAG: span regression > {}%]", th.span_regression_pct)
            } else {
                String::new()
            }
        );
    }
    out
}

// ----------------------------------------------------------------- eval

/// One task's row out of a `floatsd-eval-v1` grid document.
struct EvalTask {
    source: String,
    loss: f64,
    metric: f64,
    metric_name: String,
    count: u64,
}

struct EvalAgg {
    tasks: BTreeMap<String, EvalTask>,
}

fn parse_eval(text: &str) -> Result<EvalAgg> {
    let j = Json::parse(text.trim()).context("eval report")?;
    match j.get("schema").and_then(Json::as_str) {
        Some(EVAL_SCHEMA) => {}
        other => bail!("eval report: schema {other:?}, expected {EVAL_SCHEMA:?}"),
    }
    let Some(map) = j.get("tasks").and_then(Json::as_obj) else {
        bail!("eval report: missing tasks object");
    };
    let mut tasks = BTreeMap::new();
    for (name, e) in map {
        let num = |k: &str| {
            e.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("eval report: task {name}: missing {k}"))
        };
        tasks.insert(
            name.clone(),
            EvalTask {
                source: e.get("source").and_then(Json::as_str).unwrap_or("?").to_string(),
                loss: num("loss")?,
                metric: num("metric")?,
                metric_name: e
                    .get("metric_name")
                    .and_then(Json::as_str)
                    .unwrap_or("metric")
                    .to_string(),
                count: num("count")? as u64,
            },
        );
    }
    if tasks.is_empty() {
        bail!("eval report: empty tasks object");
    }
    Ok(EvalAgg { tasks })
}

fn render_eval(a: &EvalAgg) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "report: {EVAL_SCHEMA}, {} tasks", a.tasks.len());
    for (name, t) in &a.tasks {
        let _ = writeln!(
            out,
            "  {name:<4} loss {:.4}  {} {:.4}  ({} positions)  [{}]",
            t.loss, t.metric_name, t.metric, t.count, t.source
        );
    }
    out
}

/// Eval-grid diff (`report --diff a.json b.json` on two eval
/// reports): per-task metric drift under the same CLI-tunable
/// thresholds as the trace diffs. Accuracy-style metrics (`*_acc`
/// fractions) flag on absolute drift above `--sat-delta-pp`
/// percentage points in either direction; loss and loss-derived
/// metrics (ppl) flag on relative regressions above
/// `--span-regression-pct` percent. Eval-set size or metric-name
/// changes always flag — the two reports no longer measure the same
/// thing.
fn diff_eval(a: &EvalAgg, b: &EvalAgg, th: DiffThresholds) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "diff ({EVAL_SCHEMA}): a={} tasks, b={} tasks",
        a.tasks.len(),
        b.tasks.len()
    );
    let names: std::collections::BTreeSet<&String> = a.tasks.keys().chain(b.tasks.keys()).collect();
    for name in names {
        let (Some(ta), Some(tb)) = (a.tasks.get(name), b.tasks.get(name)) else {
            let side = if a.tasks.contains_key(name) { "b" } else { "a" };
            let _ = writeln!(out, "  {name:<4} [FLAG: task missing from {side}]");
            continue;
        };
        let mut flags: Vec<String> = Vec::new();
        if ta.count != tb.count {
            flags.push(format!("eval-set size drift ({} -> {})", ta.count, tb.count));
        }
        if ta.metric_name != tb.metric_name {
            flags.push(format!("metric changed ({} -> {})", ta.metric_name, tb.metric_name));
        }
        let dloss = if ta.loss > 0.0 { 100.0 * (tb.loss - ta.loss) / ta.loss } else { 0.0 };
        if dloss > th.span_regression_pct {
            flags.push(format!("loss regression > {}%", th.span_regression_pct));
        }
        if ta.metric_name == tb.metric_name {
            if ta.metric_name.ends_with("acc") {
                let dpp = 100.0 * (tb.metric - ta.metric);
                if dpp.abs() > th.sat_delta_pp {
                    flags.push(format!("accuracy drift > {}pp", th.sat_delta_pp));
                }
            } else {
                let rel =
                    if ta.metric > 0.0 { 100.0 * (tb.metric - ta.metric) / ta.metric } else { 0.0 };
                if rel > th.span_regression_pct {
                    flags.push(format!("metric regression > {}%", th.span_regression_pct));
                }
            }
        }
        let flag_s = if flags.is_empty() {
            String::new()
        } else {
            format!("  [FLAG: {}]", flags.join("; "))
        };
        let _ = writeln!(
            out,
            "  {name:<4} loss {:.4} -> {:.4} ({dloss:+.1}%)  {} {:.4} -> {:.4}{flag_s}",
            ta.loss, tb.loss, ta.metric_name, ta.metric, tb.metric
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        format!("{{\"schema\":\"{TRACE_SCHEMA}\",{s}}}\n")
    }

    fn sline(s: &str) -> String {
        format!("{{\"schema\":\"{SERVE_TRACE_SCHEMA}\",{s}}}\n")
    }

    fn train_trace(backoffs: u64, zero_sat: u64) -> String {
        let mut t = String::new();
        t.push_str(&line(r#""ev":"run_start","step":0,"config":{"task":"lm","seed":"7"}"#));
        t.push_str(&line(&format!(
            r#""ev":"step","step":1,"loss":2.5,"scale":1024,"applied":true,"grads":{{"emb":{{"total":100,"fp8_zero":{zero_sat},"fp8_top_binade":1,"non_finite":0,"max_abs":9.5}}}}"#
        )));
        for i in 0..backoffs {
            t.push_str(&line(&format!(
                r#""ev":"loss_scale","step":1,"cause":"backoff","from":{},"to":{}"#,
                1024 >> i,
                512 >> i
            )));
        }
        t.push_str(&line(r#""ev":"run_end","step":1,"final_scale":512,"applied":1,"skipped":0"#));
        t
    }

    fn serve_trace(service_us: f64) -> String {
        let mut t = String::new();
        t.push_str(&sline(
            r#""ev":"serve_start","task":"lm","workers":1,"max_batch":4,"window_us":50,"kernel_tier":"decoded","vocab":32,"n_out":32"#,
        ));
        t.push_str(&sline(r#""ev":"session_open","shard":0,"session":1"#));
        t.push_str(&sline(&format!(
            r#""ev":"request","shard":0,"batch":0,"session":1,"kind":"step","work":1,"occupancy":1,"timing":{{"queue_wait_us":10,"service_us":{service_us}}}"#
        )));
        t.push_str(&sline(
            r#""ev":"batch","shard":0,"batch":0,"requests":1,"work":1,"closes":0,"kinds":{"step":1},"queue_depth":2,"queue_high_water":3,"sessions":1,"timing":{"batch_ms":0.2}"#,
        ));
        t.push_str(&sline(
            r#""ev":"reject","shard":0,"session":9,"kind":"step","reason":"token 99 out of vocab""#,
        ));
        t.push_str(&sline(r#""ev":"session_close","shard":0,"session":1,"existed":true"#));
        t.push_str(&sline(
            r#""ev":"serve_end","tokens":1,"requests":1,"batches":1,"sessions":0,"queue_high_water":3,"kernel_tier":"decoded","kernel_profile":[{"op":"matvec","tier":"decoded","rows":12,"cols":8,"batch":1,"calls":4,"timing":{"total_ms":0.004,"mean_us":1.0}}],"timing":{"p50_us":40,"p99_us":40}"#,
        ));
        t
    }

    fn eval_report(lm_ppl: f64, pos_acc: f64, count: u64) -> String {
        format!(
            r#"{{"schema":"floatsd-eval-v1","tasks":{{"lm":{{"config":{{"vocab":64}},"count":{count},"loss":2.31,"metric":{lm_ppl},"metric_name":"ppl","source":"init"}},"pos":{{"config":{{"vocab":48}},"count":{count},"loss":0.9,"metric":{pos_acc},"metric_name":"tag_acc","source":"checkpoint:pos.tensors"}}}}}}"#
        ) + "\n"
    }

    #[test]
    fn summarize_covers_every_section() {
        let mut t = String::new();
        t.push_str(&line(r#""ev":"run_start","step":0,"config":{"task":"lm","seed":"7"}"#));
        let grads = r#""grads":{"emb":{"total":10,"fp8_zero":4,"fp8_top_binade":1,"non_finite":2,"max_abs":99.5}}"#;
        let acts = r#""acts":{"sigmoid":{"evals":100,"clip_lo":5,"clip_hi":1},"tanh":{"evals":50,"clip_lo":0,"clip_hi":2}}"#;
        t.push_str(&line(&format!(
            r#""ev":"step","step":1,"loss":2.5,"scale":1024,"applied":false,{grads},{acts}"#
        )));
        t.push_str(&line(
            r#""ev":"loss_scale","step":1,"cause":"backoff","from":1024,"to":512,"skipped_total":1"#,
        ));
        let weights = r#""weights":{"l1.wx":{"total":64,"at_max":3,"exp_hist":[0,1,2,3,4,5,6,43]}}"#;
        t.push_str(&line(&format!(
            r#""ev":"run_end","step":1,"final_scale":512,"applied":0,"skipped":1,{weights}"#
        )));
        let s = summarize(&t).unwrap();
        assert!(s.contains("steps: 1 (0 applied, 1 skipped)"), "{s}");
        assert!(s.contains("loss 2.5000 -> 2.5000"), "{s}");
        assert!(s.contains("1 backoffs, 0 growths"), "{s}");
        assert!(s.contains("emb"), "{s}");
        assert!(s.contains("l1.wx"), "{s}");
        assert!(s.contains("at-max"), "{s}");
        assert!(s.contains("sigmoid 100 evals"), "{s}");
        assert!(s.contains("\"task\":\"lm\""), "{s}");
    }

    #[test]
    fn summarize_rejects_foreign_schemas() {
        assert!(summarize("{\"schema\":\"other-v9\",\"ev\":\"step\"}\n").is_err());
        assert!(summarize("").is_err());
    }

    #[test]
    fn summarize_auto_detects_the_serve_schema() {
        let s = summarize(&serve_trace(40.0)).unwrap();
        assert!(s.contains(SERVE_TRACE_SCHEMA), "{s}");
        assert!(s.contains("task=lm") && s.contains("kernel_tier=decoded"), "{s}");
        assert!(s.contains("1 opened, 1 closed, 1 rejected"), "{s}");
        assert!(s.contains("token 99 out of vocab"), "{s}");
        assert!(s.contains("queue depth max 2 high-water 3"), "{s}");
        assert!(s.contains("step") && s.contains("1 requests"), "{s}");
        assert!(s.contains("service p50 40 us"), "{s}");
        assert!(s.contains("matvec") && s.contains("12x8 b1"), "{s}");
        // a train line inside a serve stream is a hard error, not a skip
        let mixed = serve_trace(40.0) + &line(r#""ev":"step","step":1"#);
        assert!(summarize(&mixed).is_err(), "mixed schemas must be rejected");
    }

    #[test]
    fn diff_flags_loss_scale_drift_and_saturation_deltas() {
        let d = diff(&train_trace(1, 4), &train_trace(3, 40)).unwrap();
        assert!(d.contains("backoffs 1 -> 3"), "{d}");
        assert!(d.contains("loss-scale event-count drift"), "{d}");
        assert!(d.contains("saturation delta > 5pp"), "{d}");
        // identical traces raise no flags
        let clean = diff(&train_trace(2, 4), &train_trace(2, 4)).unwrap();
        assert!(!clean.contains("[FLAG"), "{clean}");
    }

    #[test]
    fn diff_flags_span_regressions_above_threshold() {
        let d = diff(&serve_trace(100.0), &serve_trace(150.0)).unwrap();
        assert!(d.contains("span regression > 20%"), "{d}");
        let ok = diff(&serve_trace(100.0), &serve_trace(110.0)).unwrap();
        assert!(!ok.contains("[FLAG"), "{ok}");
        // schema mismatch is an error, not a garbage report
        assert!(diff(&serve_trace(100.0), &train_trace(1, 4)).is_err());
    }

    #[test]
    fn summarize_auto_detects_the_eval_schema() {
        let s = summarize(&eval_report(10.1, 0.75, 512)).unwrap();
        assert!(s.contains(EVAL_SCHEMA), "{s}");
        assert!(s.contains("lm") && s.contains("ppl 10.1000"), "{s}");
        assert!(s.contains("tag_acc 0.7500") && s.contains("512 positions"), "{s}");
        assert!(s.contains("[checkpoint:pos.tensors]"), "{s}");
    }

    #[test]
    fn diff_flags_eval_metric_drift_per_task() {
        // a +30% ppl regression and a -20pp accuracy drop both flag
        let d = diff(&eval_report(10.0, 0.75, 512), &eval_report(13.0, 0.55, 512)).unwrap();
        assert!(d.contains("metric regression > 20%"), "{d}");
        assert!(d.contains("accuracy drift > 5pp"), "{d}");
        // identical reports raise no flags
        let clean = diff(&eval_report(10.0, 0.75, 512), &eval_report(10.0, 0.75, 512)).unwrap();
        assert!(!clean.contains("[FLAG"), "{clean}");
        // an eval-set size change always flags: the two grids no
        // longer measure the same held-out set
        let sized = diff(&eval_report(10.0, 0.75, 512), &eval_report(10.0, 0.75, 256)).unwrap();
        assert!(sized.contains("eval-set size drift"), "{sized}");
        // thresholds stay CLI-tunable: the same +30% is silent at 50%
        let th = DiffThresholds { span_regression_pct: 50.0, ..DiffThresholds::default() };
        let loose =
            diff_with(&eval_report(10.0, 0.75, 512), &eval_report(13.0, 0.75, 512), th).unwrap();
        assert!(!loose.contains("metric regression"), "{loose}");
        // an eval report never diffs against a trace stream
        assert!(diff(&eval_report(10.0, 0.75, 512), &train_trace(1, 4)).is_err());
    }

    #[test]
    fn diff_thresholds_are_tunable_per_invocation() {
        // a +10% span change: silent at the default 20%, flagged at 5%
        let th = DiffThresholds { span_regression_pct: 5.0, ..DiffThresholds::default() };
        let d = diff_with(&serve_trace(100.0), &serve_trace(110.0), th).unwrap();
        assert!(d.contains("span regression > 5%"), "{d}");
        // a 36pp saturation delta: flagged at 5pp, silent at 40pp —
        // and the flag text names the active threshold
        let th = DiffThresholds { sat_delta_pp: 40.0, ..DiffThresholds::default() };
        let clean = diff_with(&train_trace(1, 4), &train_trace(1, 40), th).unwrap();
        assert!(!clean.contains("saturation delta"), "{clean}");
        let flagged = diff(&train_trace(1, 4), &train_trace(1, 40)).unwrap();
        assert!(flagged.contains("saturation delta > 5pp"), "{flagged}");
    }

    #[test]
    fn threshold_flags_reject_non_finite_and_negative_values() {
        let parse = |s: &str| {
            Args::parse(
                std::iter::once("bin".to_string()).chain(s.split_whitespace().map(String::from)),
            )
        };
        let ok = DiffThresholds::from_args(&parse("report --sat-delta-pp 2.5")).unwrap();
        assert_eq!(ok.sat_delta_pp, 2.5);
        assert_eq!(ok.span_regression_pct, SPAN_REGRESSION_PCT);
        for bad in [
            "report --sat-delta-pp NaN",
            "report --sat-delta-pp inf",
            "report --sat-delta-pp -1",
            "report --span-regression-pct -0.5",
            "report --span-regression-pct nope",
        ] {
            let err = DiffThresholds::from_args(&parse(bad))
                .expect_err(&format!("{bad:?} must be rejected"))
                .to_string();
            assert!(
                err.contains("sat-delta-pp") || err.contains("span-regression-pct"),
                "error for {bad:?} should name the flag: {err}"
            );
        }
    }
}
