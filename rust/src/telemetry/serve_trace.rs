//! The serve-side `--trace <path>` JSONL event stream (schema
//! `floatsd-serve-trace-v1`): request-lifecycle spans, batch-boundary
//! gauges, session-lifecycle events, and the kernel-tier profile,
//! appended by the scheduler/worker pool while serving.
//!
//! ## Schema
//!
//! Every line carries `"schema"` and `"ev"`; per-shard events also
//! carry `"shard"`. Event kinds:
//!
//! * `serve_start` — server-scoped config: `"task"`, `"workers"`,
//!   `"max_batch"`, `"window_us"`, `"kernel_tier"`, `"kernel_isa"`,
//!   `"vocab"`, `"n_out"`;
//! * `session_open` — a request created session state on its shard:
//!   `"session"`;
//! * `session_close` — a close drained at a batch boundary:
//!   `"session"`, `"existed"`;
//! * `reject` — an invalid request bounced (at submit or in-worker):
//!   `"session"`, `"kind"`, `"reason"`;
//! * `batch` — one formed micro-batch: `"batch"` (per-shard ordinal),
//!   `"requests"`, `"work"`, `"kinds"` (per-kind request counts),
//!   `"queue_depth"` (scheduler queue sampled at the batch boundary),
//!   `"queue_high_water"`, `"sessions"` (live after processing), and
//!   a `"timing"` block with the batch service span;
//! * `request` — one request's lifecycle span: `"batch"`, `"session"`,
//!   `"kind"`, `"work"`, `"occupancy"` (requests sharing its batch),
//!   and a `"timing"` block attributing `queue_wait_us` (enqueue →
//!   batch formation) and `service_us` (enqueue → reply ready);
//! * `serve_end` — run totals (`"tokens"`, `"requests"`, `"batches"`,
//!   `"queue_high_water"`) plus `"kernel_profile"`: wall time per
//!   matvec/matmul shape class, split by kernel tier
//!   (decoded/shiftadd) and dispatched SIMD path (`"isa"`),
//!   accumulated since the sink opened the gate (see
//!   [`super::note_kernel`]).
//!
//! ## Sampling (`--trace-every N`)
//!
//! A sink built with [`ServeTraceSink::create_every`] keeps only every
//! N-th micro-batch's `batch`/`request` lines per shard (the N-th,
//! 2N-th, ... by the shard's batch ordinal — [`ServeTraceSink::samples`]).
//! Lifecycle events (`serve_start`, `session_open`, `session_close`,
//! `reject`) and the `serve_end` summary are never sampled away, so a
//! sampled stream is a strict subsequence of the full stream with its
//! session bookkeeping intact. Sampling is a trace-volume choice, not
//! a numeric one: the served bits are identical at every N.
//!
//! ## Determinism
//!
//! Enabling the sink never perturbs a served logit, decode token, or
//! stats counter (pinned by `tests/serve_trace.rs`). Non-`"timing"`
//! fields are deterministic functions of the *realized* per-shard
//! request schedule: a sequential driver on one worker reproduces the
//! stream byte-identically once `"timing"` fields are stripped, while
//! concurrent load produces valid but schedule-dependent interleaving
//! (each line is still written atomically under the sink mutex).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::tensorfile::json::Json;

use super::{kernel_profile, kernel_profile_since, KernelProfileRow};

/// Schema tag carried by every serve-trace line.
pub const SERVE_TRACE_SCHEMA: &str = "floatsd-serve-trace-v1";

struct Inner {
    out: BufWriter<File>,
    deferred: Option<std::io::Error>,
}

/// An append-only JSONL serve-trace writer, shared across worker
/// shards behind an `Arc`. Creating one opens the process-wide
/// telemetry gate ([`super::hot_enabled`]) — which also arms the
/// kernel profiling hooks — and captures a kernel-profile baseline so
/// [`Self::kernel_profile`] reports only spans from this serve run.
/// Dropping it closes the gate and flushes.
///
/// Writes are best-effort: mid-run IO errors are deferred (serving
/// never aborts a batch over a full disk) and surfaced by
/// [`Self::finish`].
pub struct ServeTraceSink {
    inner: Mutex<Inner>,
    path: PathBuf,
    kernel_base: Vec<KernelProfileRow>,
    every: u64,
}

impl ServeTraceSink {
    pub fn create(path: &Path) -> Result<ServeTraceSink> {
        Self::create_every(path, 1)
    }

    /// Like [`Self::create`], but batch-level events are kept only for
    /// every `every`-th micro-batch per shard (see [`Self::samples`]).
    /// `every` must be >= 1 — callers validate before construction.
    pub fn create_every(path: &Path, every: u64) -> Result<ServeTraceSink> {
        debug_assert!(every >= 1, "trace-every is validated at the CLI boundary");
        let file = File::create(path)
            .with_context(|| format!("create serve trace file {}", path.display()))?;
        // baseline before the gate opens: spans recorded by an earlier
        // in-process sink (or another run) are excluded from this run
        let kernel_base = kernel_profile();
        super::sink_opened();
        Ok(ServeTraceSink {
            inner: Mutex::new(Inner { out: BufWriter::new(file), deferred: None }),
            path: path.to_path_buf(),
            kernel_base,
            every: every.max(1),
        })
    }

    /// The sampling period (1 = every micro-batch is traced).
    pub fn every(&self) -> u64 {
        self.every
    }

    /// Whether the shard-local micro-batch with 0-based ordinal
    /// `batch_no` should emit its `batch`/`request` lines: the N-th,
    /// 2N-th, ... batches sample (so `every = 1` keeps everything and
    /// the very first batch is kept only when `every == 1`).
    pub fn samples(&self, batch_no: u64) -> bool {
        (batch_no + 1) % self.every == 0
    }

    /// Append one event line; `fields` gains the common
    /// `schema`/`ev` keys (serialized in BTreeMap key order, so lines
    /// are byte-deterministic) and is written atomically under the
    /// sink mutex — shards never interleave partial lines.
    pub fn emit(&self, ev: &str, mut fields: BTreeMap<String, Json>) {
        fields.insert("schema".to_string(), Json::Str(SERVE_TRACE_SCHEMA.to_string()));
        fields.insert("ev".to_string(), Json::Str(ev.to_string()));
        let mut inner = self.inner.lock().unwrap();
        if inner.deferred.is_none() {
            if let Err(e) = writeln!(inner.out, "{}", Json::Obj(fields)) {
                inner.deferred = Some(e);
            }
        }
    }

    /// Kernel-tier profile accumulated since this sink opened the gate.
    pub fn kernel_profile(&self) -> Vec<KernelProfileRow> {
        kernel_profile_since(&self.kernel_base)
    }

    /// Flush and surface any deferred write error.
    pub fn finish(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner.deferred.take() {
            return Err(e).with_context(|| format!("write serve trace {}", self.path.display()));
        }
        inner.out.flush().with_context(|| format!("flush serve trace {}", self.path.display()))
    }
}

impl Drop for ServeTraceSink {
    fn drop(&mut self) {
        if let Ok(mut inner) = self.inner.lock() {
            let _ = inner.out.flush();
        }
        super::sink_closed();
    }
}

/// `u64` counter → JSON (exact for every count that fits an f64
/// mantissa — far beyond any realistic event total).
pub fn unum(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Kernel-profile block: one row per `(op, tier, isa, rows, cols,
/// batch)` shape class. `calls` and the shape labels are deterministic
/// for a fixed schedule; the accumulated wall time lives under
/// `"timing"`.
pub fn kernel_profile_json(rows: &[KernelProfileRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("op".to_string(), Json::Str(r.op.to_string()));
                m.insert("tier".to_string(), Json::Str(r.tier.to_string()));
                m.insert("isa".to_string(), Json::Str(r.isa.to_string()));
                m.insert("rows".to_string(), unum(r.rows));
                m.insert("cols".to_string(), unum(r.cols));
                m.insert("batch".to_string(), unum(r.batch));
                m.insert("calls".to_string(), unum(r.calls));
                let mut t = BTreeMap::new();
                t.insert("total_ms".to_string(), super::trace::fnum(r.nanos as f64 / 1e6));
                t.insert(
                    "mean_us".to_string(),
                    super::trace::fnum(r.nanos as f64 / 1e3 / (r.calls.max(1)) as f64),
                );
                m.insert("timing".to_string(), Json::Obj(t));
                Json::Obj(m)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_trace_lines_are_tagged_and_thread_safe_to_emit() {
        let dir = std::env::temp_dir().join("fsd_serve_trace_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.jsonl");
        {
            let sink = std::sync::Arc::new(ServeTraceSink::create(&path).unwrap());
            assert!(super::super::hot_enabled(), "open sink must enable the gate");
            let mut fields = BTreeMap::new();
            fields.insert("shard".to_string(), unum(0));
            fields.insert("requests".to_string(), unum(3));
            sink.emit("batch", fields);
            // emit takes &self — shards share the sink through the Arc
            let s2 = sink.clone();
            std::thread::spawn(move || s2.emit("serve_end", BTreeMap::new()))
                .join()
                .unwrap();
            sink.finish().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SERVE_TRACE_SCHEMA));
        assert_eq!(j.get("ev").unwrap().as_str(), Some("batch"));
        assert_eq!(j.get("requests").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn kernel_profile_json_confines_wall_clock_to_timing() {
        let rows = [KernelProfileRow {
            op: "matvec",
            tier: "shiftadd",
            isa: "sse2",
            rows: 192,
            cols: 64,
            batch: 4,
            calls: 10,
            nanos: 5_000,
        }];
        let j = kernel_profile_json(&rows);
        let r = &j.as_arr().unwrap()[0];
        assert_eq!(r.get("tier").unwrap().as_str(), Some("shiftadd"));
        assert_eq!(r.get("isa").unwrap().as_str(), Some("sse2"));
        assert_eq!(r.get("calls").unwrap().as_usize(), Some(10));
        assert_eq!(r.get("timing").unwrap().get("total_ms").unwrap().as_f64(), Some(0.005));
        assert!(r.get("nanos").is_none(), "raw nanos never leave the timing block");
    }
}
