//! Hand-rolled benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean/median/p99 statistics
//! and a black-box to defeat constant folding. Every `cargo bench`
//! target (`rust/benches/*.rs`, `harness = false`) uses this, plus a
//! small CSV writer for the figure-series outputs the paper plots.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    /// ns per iteration (mean).
    pub fn ns_per_iter(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    /// Throughput in items/sec given items per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3?}/iter (median {:.3?}, p99 {:.3?}, min {:.3?}, n={})",
            self.name, self.mean, self.median, self.p99, self.min, self.iters
        )
    }
}

/// Run `f` with warmup, auto-scaled iteration count (targets ~0.5 s of
/// measurement), and return stats.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    // warmup + calibration
    let t0 = Instant::now();
    let mut warm_iters = 0usize;
    while t0.elapsed() < Duration::from_millis(100) {
        f();
        warm_iters += 1;
    }
    let per_iter = t0.elapsed() / warm_iters.max(1) as u32;
    let target = Duration::from_millis(500);
    let iters = (target.as_nanos() / per_iter.as_nanos().max(1)).clamp(10, 1_000_000) as usize;

    let mut samples: Vec<Duration> = Vec::with_capacity(iters.min(10_000));
    let sample_batches = iters.min(200);
    let batch = (iters / sample_batches).max(1);
    for _ in 0..sample_batches {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed() / batch as u32);
    }
    let p = Percentiles::of(&mut samples);
    BenchStats {
        name: name.to_string(),
        iters: sample_batches * batch,
        mean: p.mean,
        median: p.p50,
        p99: p.p99,
        min: p.min,
    }
}

/// Percentile summary over raw duration samples — the serving engine's
/// latency statistics (p50/p99 per shard and aggregated), reusing the
/// same reporting conventions as [`BenchStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Percentiles {
    pub n: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Percentiles {
    /// Summarize a sample set (sorts in place; empty input → zeros).
    /// Single source of truth for the percentile-index convention —
    /// both [`bench`] and the serving stats go through here.
    pub fn of(samples: &mut [Duration]) -> Percentiles {
        if samples.is_empty() {
            return Percentiles::default();
        }
        samples.sort();
        let n = samples.len();
        let mean = samples.iter().sum::<Duration>() / n as u32;
        Percentiles {
            n,
            mean,
            p50: samples[n / 2],
            p99: samples[(n * 99 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

impl std::fmt::Display for Percentiles {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3?}, p50 {:.3?}, p99 {:.3?}, max {:.3?} (n={})",
            self.mean, self.p50, self.p99, self.max, self.n
        )
    }
}

/// CSV writer for figure-series outputs (the bench targets write the
/// paper's plots as CSV under `results/`).
pub struct Csv {
    path: std::path::PathBuf,
    buf: String,
}

impl Csv {
    pub fn new(path: impl Into<std::path::PathBuf>, header: &str) -> Self {
        let mut buf = String::new();
        buf.push_str(header);
        buf.push('\n');
        Csv { path: path.into(), buf }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.buf.push_str(&fields.join(","));
        self.buf.push('\n');
    }

    pub fn rowf(&mut self, fields: &[f64]) {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        self.row(&strs);
    }

    pub fn finish(self) -> anyhow::Result<std::path::PathBuf> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&self.path, self.buf)?;
        Ok(self.path)
    }
}

/// Results directory (created on demand).
pub fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from(
        std::env::var("FSD_RESULTS_DIR").unwrap_or_else(|_| "results".into()),
    );
    let _ = std::fs::create_dir_all(&d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let s = bench("spin", || {
            for i in 0..100u64 {
                x = black_box(x.wrapping_add(i));
            }
        });
        assert!(s.iters >= 10);
        assert!(s.mean.as_nanos() > 0);
        assert!(s.min <= s.median && s.median <= s.p99);
    }

    #[test]
    fn percentiles_ordering_and_edges() {
        assert_eq!(Percentiles::of(&mut []).n, 0);
        let mut one = vec![Duration::from_micros(5)];
        let p = Percentiles::of(&mut one);
        assert_eq!(p.p50, p.p99);
        assert_eq!(p.max, Duration::from_micros(5));
        let mut many: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let p = Percentiles::of(&mut many);
        assert!(p.p50 <= p.p99 && p.p99 <= p.max);
        assert_eq!(p.max, Duration::from_micros(100));
        assert_eq!(p.n, 100);
    }

    #[test]
    fn csv_writes() {
        let p = std::env::temp_dir().join("fsd_bench_test.csv");
        let mut c = Csv::new(&p, "a,b");
        c.rowf(&[1.0, 2.5]);
        c.row(&["x".into(), "y".into()]);
        let written = c.finish().unwrap();
        let body = std::fs::read_to_string(written).unwrap();
        assert_eq!(body, "a,b\n1,2.5\nx,y\n");
    }
}
