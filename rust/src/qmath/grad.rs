//! Gradient-side kernels of the training engine — the backward
//! siblings of [`super::vector::matvec_fast`]/[`matmul_fast`].
//!
//! The backward pass of a quantized matmul `y = W·x` needs two
//! contractions against the *same* FloatSD8 weight matrix:
//!
//! * `dx = Wᵀ·dy` — propagated gradient (a "backward activation",
//!   FP8 on the wire per paper Table II);
//! * `dW += dy ⊗ x` — parameter gradient (accumulated across time
//!   steps and streams, quantized to FP8 once per step like the L2
//!   graph's `tree_map(fp8, grads)`).
//!
//! The transposed contraction uses the identical accumulation
//! discipline as the forward kernel: exact f64 sums over
//! [`MAC_GROUP`]-sized groups (here groups of *rows*, i.e. output
//! units), one FP16 rounding per group — so the paper's "FP16
//! additions suffice for every accumulation" claim covers the backward
//! pass too. [`dot_col_chained`] is the single per-column kernel both
//! the per-vector and the batched path drive, which makes
//! [`matmul_t_fast`] bit-identical to per-stream [`matvec_t_fast`]
//! calls by construction (same argument as the forward pair).

use crate::formats::{round_f8, Fp16};

use super::mac::MAC_GROUP;
use super::vector::QMatrix;

/// One column of the transposed product: `Σ_r dy[r] · W[r, c]`,
/// f64-exact per [`MAC_GROUP`] rows, one FP16 rounding per group.
#[inline]
fn dot_col_chained(w: &QMatrix, c: usize, dy: &[f32]) -> f32 {
    let rows = w.rows;
    let mut acc = 0f32;
    let mut r = 0;
    while r + MAC_GROUP <= rows {
        let g = dy[r] as f64 * w.row_decoded(r)[c] as f64
            + dy[r + 1] as f64 * w.row_decoded(r + 1)[c] as f64
            + dy[r + 2] as f64 * w.row_decoded(r + 2)[c] as f64
            + dy[r + 3] as f64 * w.row_decoded(r + 3)[c] as f64;
        acc = Fp16::from_f64(acc as f64 + g).to_f32();
        r += MAC_GROUP;
    }
    if r < rows {
        let mut g = 0f64;
        for rr in r..rows {
            g += dy[rr] as f64 * w.row_decoded(rr)[c] as f64;
        }
        acc = Fp16::from_f64(acc as f64 + g).to_f32();
    }
    acc
}

/// Transposed fast matvec: `out[c] = Σ_r dy[r]·W[r,c]` with the
/// forward kernel's FP16-per-group accumulation discipline.
pub fn matvec_t_fast(w: &QMatrix, dy: &[f32], out: &mut [f32]) {
    assert_eq!(dy.len(), w.rows);
    assert_eq!(out.len(), w.cols);
    for c in 0..w.cols {
        out[c] = dot_col_chained(w, c, dy);
    }
}

/// Batched transposed matmul: `outs[b] = Wᵀ·dys[b]` for a whole batch,
/// column-stationary (each weight column is walked once per batch).
/// Bit-identical to `batch` independent [`matvec_t_fast`] calls —
/// every `(column, stream)` pair runs the same [`dot_col_chained`].
pub fn matmul_t_fast(w: &QMatrix, dys: &[f32], batch: usize, outs: &mut [f32]) {
    assert_eq!(dys.len(), batch * w.rows);
    assert_eq!(outs.len(), batch * w.cols);
    for c in 0..w.cols {
        for b in 0..batch {
            outs[b * w.cols + c] = dot_col_chained(w, c, &dys[b * w.rows..(b + 1) * w.rows]);
        }
    }
}

/// Rank-1 parameter-gradient accumulation: `acc[r,c] += dy[r]·x[c]`
/// (row-major `[rows][cols]`, the QMatrix layout). Plain f32 adds —
/// the L2 graph also accumulates weight gradients in full precision
/// and quantizes the *final* tensor to FP8 (see `optim.process_grads`).
pub fn outer_acc(dy: &[f32], x: &[f32], acc: &mut [f32]) {
    assert_eq!(acc.len(), dy.len() * x.len());
    let cols = x.len();
    for (r, &d) in dy.iter().enumerate() {
        let row = &mut acc[r * cols..(r + 1) * cols];
        for (a, &xv) in row.iter_mut().zip(x) {
            *a += d * xv;
        }
    }
}

/// Quantize a gradient buffer to the FP8 (1-5-2) grid in place — the
/// paper's "all gradients 8 bits" boundary (Table II).
pub fn quantize_fp8_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f8(*x);
    }
}

/// True when a raw (still loss-scaled) gradient buffer has overflowed
/// the FP8 gradient grid: non-finite values or magnitudes at/above
/// `F8_MAX` mean the FP8 quantization would saturate and corrupt the
/// update — the dynamic loss scaler treats this as an overflow step.
pub fn grads_overflow(xs: &[f32]) -> bool {
    xs.iter().any(|v| !v.is_finite() || v.abs() >= crate::formats::fp8::F8_MAX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f16;
    use crate::rng::SplitMix64;

    fn setup(rows: usize, cols: usize, seed: u64) -> (QMatrix, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w = QMatrix::from_f32(rows, cols, &data);
        let dy: Vec<f32> = (0..rows).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect();
        (w, dy)
    }

    #[test]
    fn transpose_matches_explicit_transposed_forward() {
        // Wᵀ·dy through the gradient kernel must equal building the
        // transposed matrix explicitly and running the forward kernel.
        for &(rows, cols) in &[(8usize, 6usize), (5, 7), (12, 4), (1, 1), (3, 9)] {
            let (w, dy) = setup(rows, cols, (rows * 31 + cols) as u64);
            let mut t = vec![0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = w.row_decoded(r)[c];
                }
            }
            let wt = QMatrix::from_f32(cols, rows, &t);
            let zero = vec![0f32; cols];
            let mut want = vec![0f32; cols];
            crate::qmath::vector::matvec_fast(&wt, &dy, &zero, &mut want);
            let mut got = vec![0f32; cols];
            matvec_t_fast(&w, &dy, &mut got);
            for c in 0..cols {
                assert_eq!(got[c].to_bits(), want[c].to_bits(), "({rows}x{cols}) col {c}");
            }
        }
    }

    #[test]
    fn batched_transpose_matches_per_stream() {
        for &(rows, cols) in &[(6usize, 5usize), (9, 7), (4, 4)] {
            let (w, _) = setup(rows, cols, 5);
            let mut rng = SplitMix64::new(11);
            let batch = 4;
            let dys: Vec<f32> =
                (0..batch * rows).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect();
            let mut outs = vec![0f32; batch * cols];
            matmul_t_fast(&w, &dys, batch, &mut outs);
            for b in 0..batch {
                let mut one = vec![0f32; cols];
                matvec_t_fast(&w, &dys[b * rows..(b + 1) * rows], &mut one);
                for c in 0..cols {
                    assert_eq!(
                        outs[b * cols + c].to_bits(),
                        one[c].to_bits(),
                        "({rows}x{cols}) stream {b} col {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn transposed_output_lands_on_fp16_grid() {
        let (w, dy) = setup(8, 6, 3);
        let mut out = vec![0f32; 6];
        matvec_t_fast(&w, &dy, &mut out);
        for &v in &out {
            assert_eq!(v, round_f16(v), "chained output must sit on the FP16 grid");
        }
    }

    #[test]
    fn outer_acc_is_rank_one_update() {
        let dy = [1.0f32, -2.0, 0.5];
        let x = [2.0f32, 4.0];
        let mut acc = vec![1.0f32; 6];
        outer_acc(&dy, &x, &mut acc);
        assert_eq!(acc, vec![3.0, 5.0, -3.0, -7.0, 2.0, 3.0]);
    }

    #[test]
    fn overflow_detection() {
        assert!(!grads_overflow(&[0.0, 1.0, -114687.0]));
        assert!(grads_overflow(&[0.0, f32::NAN]));
        assert!(grads_overflow(&[f32::INFINITY]));
        assert!(grads_overflow(&[200000.0]));
        let mut g = vec![3.1f32, -0.2];
        quantize_fp8_inplace(&mut g);
        for &v in &g {
            assert_eq!(v, round_f8(v));
        }
    }
}
