//! Gradient-side kernels of the training engine — the backward
//! siblings of [`super::vector::matvec_fast`]/[`matmul_fast`].
//!
//! The backward pass of a quantized matmul `y = W·x` needs two
//! contractions against the *same* FloatSD8 weight matrix:
//!
//! * `dx = Wᵀ·dy` — propagated gradient (a "backward activation",
//!   FP8 on the wire per paper Table II);
//! * `dW += dy ⊗ x` — parameter gradient (accumulated across time
//!   steps and streams, quantized to FP8 once per step like the L2
//!   graph's `tree_map(fp8, grads)`).
//!
//! The transposed contraction uses the identical accumulation
//! discipline as the forward kernel: exact f64 sums over
//! [`MAC_GROUP`](super::mac::MAC_GROUP)-sized groups (here groups of
//! *rows*, i.e. output units), one FP16 rounding per group — so the
//! paper's "FP16 additions suffice for every accumulation" claim
//! covers the backward pass too. The per-lane operation sequence is
//! literally the forward kernel's `chain_span_t` run over a contiguous
//! transposed column (bias 0), which makes [`matmul_t_fast`]
//! bit-identical to per-stream [`matvec_t_fast`] calls by construction
//! — at every tile width, with the same blocked batch-major write-out
//! as the forward kernels.

use crate::formats::round_f8;

use super::vector::{chain_span_t, QMatrix, MAX_TILE, ROW_BLOCK};

/// One column of the transposed product: `Σ_r dy[r] · col[r]` where
/// `col` is the contiguous column slice from the matrix's transposed
/// decoded copy ([`QMatrix::col_decoded`]) — f64-exact per
/// `MAC_GROUP` rows, one FP16 rounding per group. The transposed
/// copy turns the old stride-`cols` column walk into a unit-stride
/// stream; the values and the op order are unchanged, so the
/// transposed-reuse variant is bit-identical to indexing
/// `row_decoded(r)[c]` directly.
#[inline]
fn dot_col_chained(col: &[f32], dy: &[f32]) -> f32 {
    debug_assert_eq!(dy.len(), col.len());
    chain_span_t::<1>(col, &[dy], [0f32])[0]
}

/// Transposed fast matvec: `out[c] = Σ_r dy[r]·W[r,c]` with the
/// forward kernel's FP16-per-group accumulation discipline, reading
/// the contiguous transposed copy.
pub fn matvec_t_fast(w: &QMatrix, dy: &[f32], out: &mut [f32]) {
    assert_eq!(dy.len(), w.rows);
    assert_eq!(out.len(), w.cols);
    for c in 0..w.cols {
        out[c] = dot_col_chained(w.col_decoded(c), dy);
    }
}

/// Batched transposed matmul: `outs[b] = Wᵀ·dys[b]` for a whole batch
/// — column-stationary (each contiguous transposed column is streamed
/// once per tile) with the forward kernels' shape-aware register
/// tiling (batch ≥ 8 → tile-8, ≥ 4 → tile-4, else scalar) and blocked
/// batch-major write-out instead of the old stride-`cols` scatter.
/// Bit-identical to `batch` independent [`matvec_t_fast`] calls —
/// every `(column, stream)` pair runs the same [`dot_col_chained`]
/// operation sequence (pinned by `tests::batched_transpose_matches_per_stream`).
pub fn matmul_t_fast(w: &QMatrix, dys: &[f32], batch: usize, outs: &mut [f32]) {
    let (rows, cols) = (w.rows, w.cols);
    assert_eq!(dys.len(), batch * rows);
    assert_eq!(outs.len(), batch * cols);
    let mut b = 0usize;
    while b + 8 <= batch {
        matmul_t_tile::<8>(w, dys, outs, b);
        b += 8;
    }
    while b + 4 <= batch {
        matmul_t_tile::<4>(w, dys, outs, b);
        b += 4;
    }
    while b < batch {
        matmul_t_tile::<1>(w, dys, outs, b);
        b += 1;
    }
}

/// One `T`-stream tile of [`matmul_t_fast`]: the output columns are
/// walked in `ROW_BLOCK`-sized blocks whose results accumulate in
/// contiguous stack scratch, then land in `outs` as batch-major runs.
/// No reduction-dimension blocking — each transposed column is one
/// unit-stride stream the per-lane chain consumes whole, so the
/// per-lane sequence is exactly [`dot_col_chained`].
fn matmul_t_tile<const T: usize>(w: &QMatrix, dys: &[f32], outs: &mut [f32], b0: usize) {
    let (rows, cols) = (w.rows, w.cols);
    let mut dr: [&[f32]; T] = [&[]; T];
    for t in 0..T {
        dr[t] = &dys[(b0 + t) * rows..(b0 + t + 1) * rows];
    }
    let mut acc_blk = [0f32; MAX_TILE * ROW_BLOCK];
    let mut c0 = 0usize;
    while c0 < cols {
        let cb = ROW_BLOCK.min(cols - c0);
        for ci in 0..cb {
            let acc = chain_span_t::<T>(w.col_decoded(c0 + ci), &dr, [0f32; T]);
            for t in 0..T {
                acc_blk[t * cb + ci] = acc[t];
            }
        }
        for t in 0..T {
            outs[(b0 + t) * cols + c0..(b0 + t) * cols + c0 + cb]
                .copy_from_slice(&acc_blk[t * cb..t * cb + cb]);
        }
        c0 += cb;
    }
}

/// Rank-1 parameter-gradient accumulation: `acc[r,c] += dy[r]·x[c]`
/// (row-major `[rows][cols]`, the QMatrix layout). Plain f32 adds —
/// the L2 graph also accumulates weight gradients in full precision
/// and quantizes the *final* tensor to FP8 (see `optim.process_grads`).
///
/// Cache-blocked four output rows at a time so each `x[c]` load feeds
/// four FMAs; every accumulator element still receives exactly one
/// add per call, so the blocking is bit-identical to the plain
/// row-by-row loop (pinned by `tests::outer_acc_is_rank_one_update`).
pub fn outer_acc(dy: &[f32], x: &[f32], acc: &mut [f32]) {
    assert_eq!(acc.len(), dy.len() * x.len());
    let cols = x.len();
    let rows = dy.len();
    let mut r = 0usize;
    while r + 4 <= rows {
        let (d0, d1, d2, d3) = (dy[r], dy[r + 1], dy[r + 2], dy[r + 3]);
        let block = &mut acc[r * cols..(r + 4) * cols];
        let (row0, rest) = block.split_at_mut(cols);
        let (row1, rest) = rest.split_at_mut(cols);
        let (row2, row3) = rest.split_at_mut(cols);
        for (c, &xv) in x.iter().enumerate() {
            row0[c] += d0 * xv;
            row1[c] += d1 * xv;
            row2[c] += d2 * xv;
            row3[c] += d3 * xv;
        }
        r += 4;
    }
    while r < rows {
        let d = dy[r];
        let row = &mut acc[r * cols..(r + 1) * cols];
        for (a, &xv) in row.iter_mut().zip(x) {
            *a += d * xv;
        }
        r += 1;
    }
}

/// Quantize a gradient buffer to the FP8 (1-5-2) grid in place — the
/// paper's "all gradients 8 bits" boundary (Table II).
pub fn quantize_fp8_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f8(*x);
    }
}

/// True when a raw (still loss-scaled) gradient buffer has overflowed
/// the FP8 gradient grid: non-finite values or magnitudes at/above
/// `F8_MAX` mean the FP8 quantization would saturate and corrupt the
/// update — the dynamic loss scaler treats this as an overflow step.
pub fn grads_overflow(xs: &[f32]) -> bool {
    xs.iter().any(|v| !v.is_finite() || v.abs() >= crate::formats::fp8::F8_MAX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f16;
    use crate::rng::SplitMix64;

    fn setup(rows: usize, cols: usize, seed: u64) -> (QMatrix, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w = QMatrix::from_f32(rows, cols, &data);
        let dy: Vec<f32> = (0..rows).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect();
        (w, dy)
    }

    #[test]
    fn transpose_matches_explicit_transposed_forward() {
        // Wᵀ·dy through the gradient kernel must equal building the
        // transposed matrix explicitly and running the forward kernel.
        for &(rows, cols) in &[(8usize, 6usize), (5, 7), (12, 4), (1, 1), (3, 9)] {
            let (w, dy) = setup(rows, cols, (rows * 31 + cols) as u64);
            let mut t = vec![0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = w.row_decoded(r)[c];
                }
            }
            let wt = QMatrix::from_f32(cols, rows, &t);
            let zero = vec![0f32; cols];
            let mut want = vec![0f32; cols];
            crate::qmath::vector::matvec_fast(&wt, &dy, &zero, &mut want);
            let mut got = vec![0f32; cols];
            matvec_t_fast(&w, &dy, &mut got);
            for c in 0..cols {
                assert_eq!(got[c].to_bits(), want[c].to_bits(), "({rows}x{cols}) col {c}");
            }
        }
    }

    #[test]
    fn batched_transpose_matches_per_stream() {
        // batch sweeps both register-tile widths and every remainder
        // (1..=17 crosses 8-, 4- and scalar-tile dispatch); (5, 34)
        // crosses the 32-column output-block boundary.
        for &(rows, cols) in &[(6usize, 5usize), (9, 7), (4, 4), (1, 3), (5, 34)] {
            let (w, _) = setup(rows, cols, 5);
            for batch in 1usize..=17 {
                let mut rng = SplitMix64::new(11 + batch as u64);
                let dys: Vec<f32> =
                    (0..batch * rows).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect();
                let mut outs = vec![0f32; batch * cols];
                matmul_t_fast(&w, &dys, batch, &mut outs);
                for b in 0..batch {
                    let mut one = vec![0f32; cols];
                    matvec_t_fast(&w, &dys[b * rows..(b + 1) * rows], &mut one);
                    for c in 0..cols {
                        assert_eq!(
                            outs[b * cols + c].to_bits(),
                            one[c].to_bits(),
                            "({rows}x{cols}) batch {batch} stream {b} col {c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn transposed_output_lands_on_fp16_grid() {
        let (w, dy) = setup(8, 6, 3);
        let mut out = vec![0f32; 6];
        matvec_t_fast(&w, &dy, &mut out);
        for &v in &out {
            assert_eq!(v, round_f16(v), "chained output must sit on the FP16 grid");
        }
    }

    #[test]
    fn outer_acc_is_rank_one_update() {
        let dy = [1.0f32, -2.0, 0.5];
        let x = [2.0f32, 4.0];
        let mut acc = vec![1.0f32; 6];
        outer_acc(&dy, &x, &mut acc);
        assert_eq!(acc, vec![3.0, 5.0, -3.0, -7.0, 2.0, 3.0]);

        // row counts across the 4-row block boundary must match the
        // plain per-row loop exactly (one add per element either way)
        let mut rng = SplitMix64::new(77);
        for rows in 1usize..=9 {
            let cols = 5usize;
            let dy: Vec<f32> = (0..rows).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let x: Vec<f32> = (0..cols).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let mut blocked: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut plain = blocked.clone();
            outer_acc(&dy, &x, &mut blocked);
            for (r, &d) in dy.iter().enumerate() {
                for c in 0..cols {
                    plain[r * cols + c] += d * x[c];
                }
            }
            for (k, (a, b)) in blocked.iter().zip(&plain).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "rows {rows} elem {k}");
            }
        }
    }

    #[test]
    fn overflow_detection() {
        assert!(!grads_overflow(&[0.0, 1.0, -114687.0]));
        assert!(grads_overflow(&[0.0, f32::NAN]));
        assert!(grads_overflow(&[f32::INFINITY]));
        assert!(grads_overflow(&[200000.0]));
        let mut g = vec![3.1f32, -0.2];
        quantize_fp8_inplace(&mut g);
        for &v in &g {
            assert_eq!(v, round_f8(v));
        }
    }
}
