//! Quantized arithmetic: the software mirror of the paper's MAC datapath.
//!
//! * [`qsigmoid`] — the two-region FloatSD8-quantized sigmoid (Eq. 7/8)
//!   and its LUT realisation (§III-C: σ + quantization merged into one
//!   lookup table; 42 non-zero entries for the non-positive branch);
//! * [`mac`] — the FloatSD8×FP8→FP16 multiply-accumulate with the
//!   hardware's *exact-sum-then-round* semantics (Fig. 8: partial
//!   products aligned and added in a carry-save tree, rounded once);
//! * [`vector`] — matvec/matmul built from the MAC (the rust inference
//!   engine hot path), with a bit-identical fast path;
//! * [`shiftadd`] — the integer shift-add kernel tier: FloatSD8 digit
//!   pairs shifted into the hardware MAC's fixed-point frame, no
//!   multiplier on the weight side (`--kernel-tier shiftadd`), pinned
//!   bit-identical to the decoded path;
//! * [`simd`] — runtime-dispatched `core::arch::x86_64` execution of
//!   both tiers' span kernels (`--kernel-isa {scalar,sse2,avx2}`,
//!   AVX2 auto-detected), each SIMD lane carrying one stream's private
//!   accumulator chain — pinned bit-identical across every path;
//! * [`grad`] — the backward-pass siblings (transposed contractions,
//!   rank-1 gradient accumulation, FP8 gradient quantization) used by
//!   the offline training engine in [`crate::train`].
//!
//! Everything here is cross-validated three ways: against the jnp
//! golden vectors, against the bit-level pipelined MAC simulator in
//! [`crate::hardware`], and against the pure-f32 reference.

pub mod grad;
pub mod mac;
pub mod qsigmoid;
pub mod shiftadd;
pub mod simd;
pub mod vector;

pub use grad::{matmul_t_fast, matvec_t_fast, outer_acc, quantize_fp8_inplace};
pub use mac::{mac_exact, mac_serial, MacMode};
pub use qsigmoid::{sigmoid_sd8, sigmoid_sd8_one_region, tanh_fp8, SigmoidLut};
pub use shiftadd::{DigitPlanes, KernelTier, WeightDigits};
pub use simd::IsaPath;
