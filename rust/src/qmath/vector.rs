//! Vector/matrix kernels built on the quantized MAC — the rust
//! inference engine's hot path.
//!
//! Two implementations with **identical numerics**:
//!
//! * [`matvec_mac`] — drives `mac::dot_fsd8_fp8` pair-by-pair; the
//!   readable, obviously-hardware-faithful version;
//! * [`matvec_fast`] — the optimized path: weights pre-decoded to f32
//!   once per matrix, f64 group accumulation (exact, see mac.rs) with
//!   one FP16 rounding per 4-group; no per-element encode/decode.
//!
//! `tests::fast_equals_mac` pins the two together; the engine and the
//! benches use the fast path.
//!
//! ## Batched kernel layout
//!
//! [`matmul_fast`] is weight-stationary, register-tiled, and blocked:
//! streams are processed up to [`MAX_TILE`] at a time (shape-aware
//! dispatch 8 → 4 → scalar), the weight matrix is walked in
//! [`ROW_BLOCK`]`×`[`COL_BLOCK`] blocks so the active weight block
//! plus all tile activations stay cache-resident at paper-preset
//! shapes, and each row-block's outputs accumulate in contiguous
//! scratch written out in batch-major runs (no stride-`rows`
//! scatter). Every transform is bit-identity-preserving: each
//! `(row, stream)` lane runs the exact [`dot_row_chained`] operation
//! sequence ([`chain_span_t`]), and column blocks are
//! `MAC_GROUP`-aligned so carrying the f32 accumulator between blocks
//! reproduces the full-row rounding chain unchanged. The shift-add
//! tier mirrors the same structure over [`DigitPlanes`]
//! (`shiftadd::matmul_sa`).

use std::cell::RefCell;

use crate::formats::{FloatSd8, Fp16, Fp8, FLOAT_SD8};

use super::mac::{dot_fsd8_fp8, MacMode, MAC_GROUP};
use super::shiftadd::{self, DigitPlanes, KernelTier, WeightDigits, XTerm};
use super::simd::{self, IsaPath};

/// Widest stream tile of the batched kernels (8 independent FP16
/// accumulation chains sharing each weight load).
pub(crate) const MAX_TILE: usize = 8;

/// Row-block height of the blocked batched kernels: one block's
/// accumulators (`MAX_TILE × ROW_BLOCK` f32 = 1 KiB) live on the
/// stack.
pub(crate) const ROW_BLOCK: usize = 32;

/// Column-block width — a [`MAC_GROUP`] multiple, so block boundaries
/// coincide with rounding-group boundaries and blocking never changes
/// the chain. Sized so a `ROW_BLOCK × COL_BLOCK` decoded weight block
/// (32 KiB) plus 8 activation spans (8 KiB decoded, +32 KiB of
/// decomposed `XTerm`s on the shift-add tier) stays cache-resident at
/// the paper preset's 10k×256 matrices.
pub(crate) const COL_BLOCK: usize = 256;

const _: () = assert!(COL_BLOCK % MAC_GROUP == 0, "blocks must align to rounding groups");

/// A weight matrix stored in encoded FloatSD8 form, row-major
/// `[out][in]` (each output neuron's weights are contiguous — the
/// PE's weight-stationary layout).
pub struct QMatrix {
    pub rows: usize, // outputs
    pub cols: usize, // inputs
    pub codes: Vec<FloatSd8>,
    /// decoded f32 copy for the fast path (built once)
    decoded: Vec<f32>,
    /// decoded **transposed** copy `[cols][rows]` — contiguous columns
    /// for the backward kernels (`qmath::grad`), which contract
    /// against `Wᵀ`: walking a column of `decoded` strides by `cols`
    /// floats per element, while a `decoded_t` column is one cache-line
    /// stream. Same values, same op order — the transposed-reuse
    /// variant is bit-identical, it only changes the access pattern.
    /// Built eagerly (+4 host bytes/weight even for inference-only
    /// stacks — a deliberate simplicity trade; the paper's 1-byte
    /// storage argument is about `codes`, see [`Self::storage_bytes`]).
    decoded_t: Vec<f32>,
    /// structure-of-arrays digit planes for the shift-add tier: each
    /// code's ≤2 signed power-of-two digits scattered across four
    /// parallel `i8` planes at encode/update time (padded row stride —
    /// see [`DigitPlanes`])
    digits: DigitPlanes,
    /// which forward-kernel engine [`matvec_fast`]/[`matmul_fast`]
    /// dispatch to for this matrix (runtime-only, never checkpointed)
    tier: KernelTier,
    /// which SIMD execution path the batched span kernels run on
    /// ([`simd`]; runtime-only, never checkpointed, bit-identical
    /// across every path). Defaults to the widest host-supported ISA.
    isa: IsaPath,
}

impl QMatrix {
    /// Quantize a row-major f32 matrix `[rows][cols]` into FloatSD8.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let codes: Vec<FloatSd8> = data.iter().map(|&v| FLOAT_SD8.encode(v)).collect();
        Self::from_codes(rows, cols, codes)
    }

    /// Build from raw FloatSD8 codes (non-canonical codes decode with
    /// the same rank clamping as `FLOAT_SD8.decode`). All cached
    /// layouts — decoded, transposed, digit-planar — are derived here,
    /// the single construction path.
    pub fn from_codes(rows: usize, cols: usize, codes: Vec<FloatSd8>) -> Self {
        assert_eq!(codes.len(), rows * cols);
        let decoded: Vec<f32> = codes.iter().map(|&c| FLOAT_SD8.decode(c)).collect();
        let mut digits = DigitPlanes::new(rows, cols);
        let mut decoded_t = vec![0f32; decoded.len()];
        for r in 0..rows {
            for c in 0..cols {
                digits.set(r, c, WeightDigits::of(codes[r * cols + c]));
                decoded_t[c * rows + r] = decoded[r * cols + c];
            }
        }
        QMatrix {
            rows,
            cols,
            codes,
            decoded,
            decoded_t,
            digits,
            tier: KernelTier::default(),
            isa: IsaPath::detect(),
        }
    }

    /// Select the forward-kernel tier for this matrix.
    pub fn set_kernel_tier(&mut self, tier: KernelTier) {
        self.tier = tier;
    }

    /// The forward-kernel tier this matrix dispatches to.
    pub fn kernel_tier(&self) -> KernelTier {
        self.tier
    }

    /// Select the SIMD execution path for this matrix's span kernels.
    /// Every path is bit-identical; callers validate host support via
    /// [`IsaPath::parse`] before forcing one.
    pub fn set_kernel_isa(&mut self, isa: IsaPath) {
        self.isa = isa;
    }

    /// The SIMD execution path this matrix dispatches to.
    pub fn kernel_isa(&self) -> IsaPath {
        self.isa
    }

    /// The cached structure-of-arrays digit planes.
    #[inline]
    pub fn digits(&self) -> &DigitPlanes {
        &self.digits
    }

    /// Row `r` of the four digit planes (`s0/e0/s1/e1`), each `cols`
    /// long — the shift-add kernels' unit-stride view.
    #[inline]
    pub fn digit_row(&self, r: usize) -> (&[i8], &[i8], &[i8], &[i8]) {
        self.digits.row(r)
    }

    #[inline]
    pub fn row_codes(&self, r: usize) -> &[FloatSd8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_decoded(&self, r: usize) -> &[f32] {
        &self.decoded[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` of the decoded matrix as a contiguous slice (the
    /// transposed copy) — the backward kernels' access path.
    #[inline]
    pub fn col_decoded(&self, c: usize) -> &[f32] {
        &self.decoded_t[c * self.rows..(c + 1) * self.rows]
    }

    /// Bytes of weight storage (8 bits/weight) — the paper's memory
    /// footprint argument (§I, §III-E).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Apply one optimizer step to this matrix under the paper's
    /// FP16-master scheme (§IV-C): per weight, `masters[k]` absorbs
    /// `deltas[k]` with one FP16 rounding
    /// ([`FloatSdFormat::apply_update`](crate::formats::FloatSdFormat::apply_update)),
    /// and the live code + decoded fast-path copy are re-encoded to the
    /// nearest FloatSD8 value of the new master.
    pub fn apply_master_update(&mut self, masters: &mut [f32], deltas: &[f32]) {
        assert_eq!(masters.len(), self.codes.len());
        assert_eq!(deltas.len(), self.codes.len());
        for k in 0..self.codes.len() {
            let (m, code) = FLOAT_SD8.apply_update(masters[k], deltas[k]);
            masters[k] = m;
            self.codes[k] = code;
            let v = FLOAT_SD8.decode(code);
            self.decoded[k] = v;
            // keep the transposed and digit-plane copies in lockstep
            let (r, c) = (k / self.cols, k % self.cols);
            self.digits.set(r, c, WeightDigits::of(code));
            self.decoded_t[c * self.rows + r] = v;
        }
    }
}

/// y[r] = round chain of (bias[r] + Σ_c x[c]·W[r,c]) via the MAC.
pub fn matvec_mac(w: &QMatrix, x: &[Fp8], bias: &[Fp16], mode: MacMode) -> Vec<Fp16> {
    assert_eq!(x.len(), w.cols);
    assert_eq!(bias.len(), w.rows);
    (0..w.rows)
        .map(|r| dot_fsd8_fp8(bias[r], x, w.row_codes(r), mode))
        .collect()
}

/// The per-row kernel both fast paths share: one decoded weight row
/// against one input vector, f64-exact group sums, one FP16 rounding
/// per [`MAC_GROUP`]. Keeping this in one place is what makes the
/// batched path *bit-identical* to the per-vector path by construction.
#[inline]
fn dot_row_chained(row: &[f32], x: &[f32], bias: f32) -> f32 {
    chain_span_t::<1>(row, &[x], [bias])[0]
}

/// Advance `T` independent FP16 accumulation chains over one
/// group-aligned span of a decoded weight row — the register-tiled
/// core of the batched kernels, generalizing the old fixed 4-stream
/// tile. Per group the weight elements are loaded (and widened to f64)
/// once and reused across all `T` lanes; each lane's operations are
/// the *exact* [`dot_row_chained`] sequence (same f64 products, same
/// left-to-right group sums, same one-FP16-round-per-group chain), so
/// every lane is bit-identical to a standalone per-stream call.
///
/// Spans must start on a [`MAC_GROUP`] boundary of the full row (the
/// blocked callers use `COL_BLOCK`-multiples) so group boundaries
/// match full-row grouping; carrying the returned f32 accumulators
/// into the next span's `acc` is exactly the full-row chain, since the
/// chain state between groups *is* one f32 per lane.
#[inline]
pub(crate) fn chain_span_t<const T: usize>(
    row: &[f32],
    xs: &[&[f32]; T],
    mut acc: [f32; T],
) -> [f32; T] {
    let n = row.len();
    let mut c = 0;
    while c + MAC_GROUP <= n {
        let (w0, w1, w2, w3) =
            (row[c] as f64, row[c + 1] as f64, row[c + 2] as f64, row[c + 3] as f64);
        for t in 0..T {
            let x = xs[t];
            let g = x[c] as f64 * w0
                + x[c + 1] as f64 * w1
                + x[c + 2] as f64 * w2
                + x[c + 3] as f64 * w3;
            acc[t] = Fp16::from_f64(acc[t] as f64 + g).to_f32();
        }
        c += MAC_GROUP;
    }
    if c < n {
        for t in 0..T {
            let x = xs[t];
            let mut g = 0f64;
            for cc in c..n {
                g += x[cc] as f64 * row[cc] as f64;
            }
            acc[t] = Fp16::from_f64(acc[t] as f64 + g).to_f32();
        }
    }
    acc
}

/// Optimized path, numerically identical to
/// `matvec_mac(.., MacMode::Exact)`:
/// decoded weights, f64 exact group sums, one f16 round per group.
///
/// Dispatches on the matrix's [`KernelTier`]: the `shiftadd` tier runs
/// [`shiftadd::matvec_sa`], pinned bit-identical to this path by
/// `tests/shiftadd_equivalence.rs`.
/// With a telemetry sink open ([`crate::telemetry::hot_enabled`]) the
/// call is wall-clock timed into the kernel-tier profile
/// ([`crate::telemetry::note_kernel`]); disabled, the hook costs one
/// relaxed load + branch (pinned allocation-free by
/// `tests/telemetry_alloc.rs`). The profile is write-only — timing can
/// never perturb an output bit.
pub fn matvec_fast(w: &QMatrix, x: &[f32], bias: &[f32], out: &mut [f32]) {
    if crate::telemetry::hot_enabled() {
        let t0 = std::time::Instant::now();
        matvec_fast_impl(w, x, bias, out);
        crate::telemetry::note_kernel(
            crate::telemetry::KernelOp::Matvec,
            w.tier,
            w.isa,
            w.rows,
            w.cols,
            1,
            t0.elapsed(),
        );
        return;
    }
    matvec_fast_impl(w, x, bias, out);
}

#[inline]
fn matvec_fast_impl(w: &QMatrix, x: &[f32], bias: &[f32], out: &mut [f32]) {
    if w.tier == KernelTier::ShiftAdd {
        return shiftadd::matvec_sa(w, x, bias, out);
    }
    assert_eq!(x.len(), w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), w.rows);
    for r in 0..w.rows {
        out[r] = dot_row_chained(w.row_decoded(r), x, bias[r]);
    }
}

/// Reusable scratch for [`matmul_fast_with`]: the shift-add tier's
/// batch-wide activation-decomposition buffer. Steady batched callers
/// (the LSTM cell's `BatchScratch`) hold one so repeated matmuls never
/// touch the allocator after warm-up; [`matmul_fast`] falls back to a
/// thread-local instance.
#[derive(Default)]
pub struct MatmulScratch {
    pub(crate) xt: Vec<XTerm>,
}

impl MatmulScratch {
    pub fn new() -> MatmulScratch {
        MatmulScratch::default()
    }
}

thread_local! {
    /// Fallback scratch for [`matmul_fast`] callers that don't thread
    /// their own [`MatmulScratch`] (tape replay, benches, tests).
    static MM_SCRATCH: RefCell<MatmulScratch> =
        const { RefCell::new(MatmulScratch { xt: Vec::new() }) };
}

/// Batched fast matvec: `ys[b] = W · xs[b] + bias` for a whole batch.
///
/// **Weight-stationary, register-tiled, blocked** loop order (the
/// serving engine's amortization argument, mirroring the PE's §V-A
/// batch loop): streams dispatch shape-aware up to [`MAX_TILE`] at a
/// time (batch ≥ 8 → tile-8, ≥ 4 → tile-4, else scalar), and inside a
/// tile the weight matrix is walked in `ROW_BLOCK × COL_BLOCK` blocks
/// with each decoded row span streamed from memory once per tile and
/// reused across all lanes from registers. A row-block's outputs
/// accumulate in contiguous stack scratch and are written to `out` in
/// batch-major runs — the old per-element stride-`rows` scatter is
/// gone. Each `(row, stream)` pair still runs the identical
/// [`dot_row_chained`] operation sequence, so results are
/// bit-identical to `batch` independent [`matvec_fast`] calls (pinned
/// by `tests::matmul_fast_matches_per_row` across tile widths).
/// Timed into the kernel-tier profile exactly like [`matvec_fast`]
/// (shape class includes `batch`, so occupancy tiers profile apart).
pub fn matmul_fast(w: &QMatrix, xs: &[f32], batch: usize, bias: &[f32], out: &mut [f32]) {
    MM_SCRATCH.with(|s| matmul_fast_with(w, xs, batch, bias, out, &mut s.borrow_mut()));
}

/// [`matmul_fast`] with a caller-held [`MatmulScratch`] — the batched
/// hot loops (LSTM cell steps) thread one through so the shift-add
/// tier's decomposition buffer is reused across every time step.
pub fn matmul_fast_with(
    w: &QMatrix,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    out: &mut [f32],
    scratch: &mut MatmulScratch,
) {
    if crate::telemetry::hot_enabled() {
        let t0 = std::time::Instant::now();
        matmul_impl(w, xs, batch, bias, out, scratch, MAX_TILE, w.isa);
        crate::telemetry::note_kernel(
            crate::telemetry::KernelOp::Matmul,
            w.tier,
            w.isa,
            w.rows,
            w.cols,
            batch,
            t0.elapsed(),
        );
        return;
    }
    matmul_impl(w, xs, batch, bias, out, scratch, MAX_TILE, w.isa);
}

/// Test/bench hook: [`matmul_fast`] with the stream tile capped at
/// `max_tile` ∈ {1, 4, 8} on either tier. `matmul_fast` is
/// `max_tile = 8`; the parity suites sweep all widths against
/// per-stream references, and the kernel bench emits per-width rows.
/// Untimed (never on the profiled hot path).
pub fn matmul_tiled(
    w: &QMatrix,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    out: &mut [f32],
    max_tile: usize,
) {
    matmul_isa(w, xs, batch, bias, out, max_tile, w.isa);
}

/// Test/bench hook: [`matmul_tiled`] with the SIMD execution path
/// forced to `isa`, overriding the matrix's configured path. The
/// forced-ISA parity sweeps and the per-ISA kernel bench rows use
/// this; callers must only force host-supported paths
/// ([`IsaPath::available`]). Untimed, like [`matmul_tiled`].
pub fn matmul_isa(
    w: &QMatrix,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    out: &mut [f32],
    max_tile: usize,
    isa: IsaPath,
) {
    assert!(matches!(max_tile, 1 | 4 | 8), "max_tile must be 1, 4, or 8 (got {max_tile})");
    MM_SCRATCH.with(|s| matmul_impl(w, xs, batch, bias, out, &mut s.borrow_mut(), max_tile, isa));
}

#[allow(clippy::too_many_arguments)]
fn matmul_impl(
    w: &QMatrix,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    out: &mut [f32],
    scratch: &mut MatmulScratch,
    max_tile: usize,
    isa: IsaPath,
) {
    if w.tier == KernelTier::ShiftAdd {
        return shiftadd::matmul_sa(w, xs, batch, bias, out, &mut scratch.xt, max_tile, isa);
    }
    assert_eq!(xs.len(), batch * w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), batch * w.rows);
    let mut b = 0usize;
    if max_tile >= 8 {
        while b + 8 <= batch {
            matmul_tile_block::<8>(w, xs, bias, out, b, isa);
            b += 8;
        }
    }
    if max_tile >= 4 {
        while b + 4 <= batch {
            matmul_tile_block::<4>(w, xs, bias, out, b, isa);
            b += 4;
        }
    }
    while b < batch {
        matmul_tile_block::<1>(w, xs, bias, out, b, isa);
        b += 1;
    }
}

/// One `T`-stream tile of the decoded batched kernel: row/column
/// blocked with a contiguous per-row-block accumulator, written out
/// batch-major. Bit-identity argument as in [`chain_span_t`].
fn matmul_tile_block<const T: usize>(
    w: &QMatrix,
    xs: &[f32],
    bias: &[f32],
    out: &mut [f32],
    b0: usize,
    isa: IsaPath,
) {
    let (rows, cols) = (w.rows, w.cols);
    let mut acc_blk = [0f32; MAX_TILE * ROW_BLOCK];
    let mut r0 = 0usize;
    while r0 < rows {
        let rb = ROW_BLOCK.min(rows - r0);
        for t in 0..T {
            acc_blk[t * rb..t * rb + rb].copy_from_slice(&bias[r0..r0 + rb]);
        }
        let mut c0 = 0usize;
        while c0 < cols {
            let cb = COL_BLOCK.min(cols - c0);
            let mut xr: [&[f32]; T] = [&[]; T];
            for t in 0..T {
                let lo = (b0 + t) * cols + c0;
                xr[t] = &xs[lo..lo + cb];
            }
            for ri in 0..rb {
                let row = &w.row_decoded(r0 + ri)[c0..c0 + cb];
                let mut acc = [0f32; T];
                for t in 0..T {
                    acc[t] = acc_blk[t * rb + ri];
                }
                let acc = simd::chain_span_isa::<T>(row, &xr, acc, isa);
                for t in 0..T {
                    acc_blk[t * rb + ri] = acc[t];
                }
            }
            c0 += cb;
        }
        for t in 0..T {
            out[(b0 + t) * rows + r0..(b0 + t) * rows + r0 + rb]
                .copy_from_slice(&acc_blk[t * rb..t * rb + rb]);
        }
        r0 += rb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn setup(rows: usize, cols: usize, seed: u64) -> (QMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w = QMatrix::from_f32(rows, cols, &data);
        // x on the FP8 grid, bias on the f16 grid (architectural contract)
        let x: Vec<f32> = (0..cols)
            .map(|_| crate::formats::round_f8(rng.uniform(-4.0, 4.0)))
            .collect();
        let bias: Vec<f32> = (0..rows)
            .map(|_| crate::formats::round_f16(rng.uniform(-0.5, 0.5)))
            .collect();
        (w, x, bias)
    }

    #[test]
    fn fast_equals_mac() {
        for &(r, c) in &[(3, 4), (8, 16), (5, 7), (16, 33), (1, 1)] {
            let (w, x, bias) = setup(r, c, (r * 100 + c) as u64);
            let x8: Vec<Fp8> = x.iter().map(|&v| Fp8::from_f32(v)).collect();
            let b16: Vec<Fp16> = bias.iter().map(|&v| Fp16::from_f32(v)).collect();
            let via_mac = matvec_mac(&w, &x8, &b16, MacMode::Exact);
            let mut fast = vec![0f32; r];
            matvec_fast(&w, &x, &bias, &mut fast);
            for i in 0..r {
                assert_eq!(
                    via_mac[i].to_f32(),
                    fast[i],
                    "({r}x{c}) row {i}: mac={} fast={}",
                    via_mac[i].to_f32(),
                    fast[i]
                );
            }
        }
    }

    #[test]
    fn storage_is_one_byte_per_weight() {
        let (w, _, _) = setup(8, 8, 1);
        assert_eq!(w.storage_bytes(), 64);
    }

    #[test]
    fn matmul_fast_matches_per_row() {
        // shapes cross every blocking boundary: cols not a multiple of
        // MAC_GROUP (12, 7, 5, 17, 31), a degenerate 1x1, rows beyond
        // one ROW_BLOCK would be too slow here but 9/33-col shapes hit
        // padded digit-plane strides; batches sweep both register-tile
        // widths and every remainder (1..=17 crosses 8-, 4- and
        // scalar-tile dispatch). The blocked weight-stationary loop
        // must stay bit-identical to per-stream matvec_fast in every
        // tail case, at every forced tile width.
        for &(rows, cols) in &[(6usize, 12usize), (3, 7), (9, 5), (1, 1), (4, 16), (2, 17), (5, 31)]
        {
            let (w, _, bias) = setup(rows, cols, (rows * 1000 + cols) as u64);
            for batch in 1usize..=17 {
                let mut rng = SplitMix64::new(3 + batch as u64);
                let xs: Vec<f32> = (0..batch * cols)
                    .map(|_| crate::formats::round_f8(rng.uniform(-2.0, 2.0)))
                    .collect();
                let mut out = vec![0f32; batch * rows];
                matmul_fast(&w, &xs, batch, &bias, &mut out);
                for b in 0..batch {
                    let mut y = vec![0f32; rows];
                    matvec_fast(&w, &xs[b * cols..(b + 1) * cols], &bias, &mut y);
                    for (a, e) in out[b * rows..(b + 1) * rows].iter().zip(&y) {
                        assert_eq!(
                            a.to_bits(),
                            e.to_bits(),
                            "({rows}x{cols}) batch {batch} stream {b}"
                        );
                    }
                }
                // every capped tile width reproduces the full kernel
                for max_tile in [1usize, 4, 8] {
                    let mut tiled = vec![0f32; batch * rows];
                    matmul_tiled(&w, &xs, batch, &bias, &mut tiled, max_tile);
                    for (k, (a, e)) in tiled.iter().zip(&out).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            e.to_bits(),
                            "({rows}x{cols}) batch {batch} tile {max_tile} elem {k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_available_isa_matches_scalar_on_both_tiers() {
        // the forced-ISA hook must reproduce the scalar reference bit
        // for bit on each host-supported path, on both kernel tiers,
        // at every forced tile width, across batches spanning every
        // tile remainder. AVX2 coverage depends on the host; the
        // dedicated parity suite prints a skip notice.
        let isas: Vec<IsaPath> = [IsaPath::Scalar, IsaPath::Sse2, IsaPath::Avx2]
            .into_iter()
            .filter(|i| i.available())
            .collect();
        for &(rows, cols) in &[(6usize, 12usize), (3, 7), (5, 31)] {
            let (mut w, _, bias) = setup(rows, cols, (rows * 77 + cols) as u64);
            for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
                w.set_kernel_tier(tier);
                for batch in 1usize..=17 {
                    let mut rng = SplitMix64::new(41 + batch as u64);
                    let xs: Vec<f32> = (0..batch * cols)
                        .map(|_| crate::formats::round_f8(rng.uniform(-2.0, 2.0)))
                        .collect();
                    for max_tile in [1usize, 4, 8] {
                        let mut reference = vec![0f32; batch * rows];
                        matmul_isa(&w, &xs, batch, &bias, &mut reference, max_tile, IsaPath::Scalar);
                        for &isa in &isas {
                            let mut got = vec![0f32; batch * rows];
                            matmul_isa(&w, &xs, batch, &bias, &mut got, max_tile, isa);
                            for (k, (a, e)) in got.iter().zip(&reference).enumerate() {
                                assert_eq!(
                                    a.to_bits(),
                                    e.to_bits(),
                                    "({rows}x{cols}) {} {} batch {batch} tile {max_tile} elem {k}",
                                    tier.name(),
                                    isa.name()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_kernel_crosses_row_and_col_block_boundaries() {
        // rows > ROW_BLOCK and cols > COL_BLOCK force multi-block
        // accumulation with the f32 chain carried between column
        // blocks; the per-stream reference never blocks, so equality
        // pins the carry as a numeric no-op.
        let rows = ROW_BLOCK + 5;
        let cols = COL_BLOCK + 9;
        let (w, _, bias) = setup(rows, cols, 4242);
        let batch = 9usize; // tile-8 plus a scalar tail
        let mut rng = SplitMix64::new(17);
        let xs: Vec<f32> = (0..batch * cols)
            .map(|_| crate::formats::round_f8(rng.uniform(-2.0, 2.0)))
            .collect();
        let mut out = vec![0f32; batch * rows];
        matmul_fast(&w, &xs, batch, &bias, &mut out);
        for b in 0..batch {
            let mut y = vec![0f32; rows];
            matvec_fast(&w, &xs[b * cols..(b + 1) * cols], &bias, &mut y);
            for (r, (a, e)) in out[b * rows..(b + 1) * rows].iter().zip(&y).enumerate() {
                assert_eq!(a.to_bits(), e.to_bits(), "stream {b} row {r}");
            }
        }
    }

    #[test]
    fn transposed_copy_tracks_updates() {
        let mut rng = SplitMix64::new(31);
        let mut masters: Vec<f32> = (0..5 * 3)
            .map(|_| crate::formats::round_f16(rng.uniform(-1.0, 1.0)))
            .collect();
        let mut w = QMatrix::from_f32(5, 3, &masters);
        for r in 0..5 {
            for c in 0..3 {
                assert_eq!(w.col_decoded(c)[r], w.row_decoded(r)[c], "transpose out of sync");
            }
        }
        let deltas: Vec<f32> = (0..15).map(|_| rng.uniform(-0.3, 0.3)).collect();
        w.apply_master_update(&mut masters, &deltas);
        for r in 0..5 {
            for c in 0..3 {
                assert_eq!(
                    w.col_decoded(c)[r],
                    w.row_decoded(r)[c],
                    "transpose out of sync after update"
                );
            }
        }
    }

    #[test]
    fn apply_master_update_keeps_code_and_decoded_in_sync() {
        let mut rng = SplitMix64::new(21);
        let mut masters: Vec<f32> = (0..12)
            .map(|_| crate::formats::round_f16(rng.uniform(-1.0, 1.0)))
            .collect();
        let mut w = QMatrix::from_f32(3, 4, &masters);
        let deltas: Vec<f32> = (0..12).map(|_| rng.uniform(-0.2, 0.2)).collect();
        w.apply_master_update(&mut masters, &deltas);
        for r in 0..3 {
            for c in 0..4 {
                let k = r * 4 + c;
                assert_eq!(masters[k], crate::formats::round_f16(masters[k]));
                assert_eq!(w.row_decoded(r)[c], FLOAT_SD8.decode(w.row_codes(r)[c]));
                assert_eq!(w.row_decoded(r)[c], FLOAT_SD8.quantize(masters[k]));
            }
        }
    }

    #[test]
    fn weights_land_on_sd8_grid() {
        let (w, _, _) = setup(4, 4, 9);
        for r in 0..4 {
            for &v in w.row_decoded(r) {
                assert!(FLOAT_SD8.values().contains(&v));
            }
        }
    }
}
