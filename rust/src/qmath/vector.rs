//! Vector/matrix kernels built on the quantized MAC — the rust
//! inference engine's hot path.
//!
//! Two implementations with **identical numerics**:
//!
//! * [`matvec_mac`] — drives `mac::dot_fsd8_fp8` pair-by-pair; the
//!   readable, obviously-hardware-faithful version;
//! * [`matvec_fast`] — the optimized path: weights pre-decoded to f32
//!   once per matrix, f64 group accumulation (exact, see mac.rs) with
//!   one FP16 rounding per 4-group; no per-element encode/decode.
//!
//! `tests::fast_equals_mac` pins the two together; the engine and the
//! benches use the fast path.

use crate::formats::{FloatSd8, Fp16, Fp8, FLOAT_SD8};

use super::mac::{dot_fsd8_fp8, MacMode, MAC_GROUP};
use super::shiftadd::{self, KernelTier, WeightDigits};

/// A weight matrix stored in encoded FloatSD8 form, row-major
/// `[out][in]` (each output neuron's weights are contiguous — the
/// PE's weight-stationary layout).
pub struct QMatrix {
    pub rows: usize, // outputs
    pub cols: usize, // inputs
    pub codes: Vec<FloatSd8>,
    /// decoded f32 copy for the fast path (built once)
    decoded: Vec<f32>,
    /// decoded **transposed** copy `[cols][rows]` — contiguous columns
    /// for the backward kernels (`qmath::grad`), which contract
    /// against `Wᵀ`: walking a column of `decoded` strides by `cols`
    /// floats per element, while a `decoded_t` column is one cache-line
    /// stream. Same values, same op order — the transposed-reuse
    /// variant is bit-identical, it only changes the access pattern.
    /// Built eagerly (+4 host bytes/weight even for inference-only
    /// stacks — a deliberate simplicity trade; the paper's 1-byte
    /// storage argument is about `codes`, see [`Self::storage_bytes`]).
    decoded_t: Vec<f32>,
    /// digit-planar layout for the shift-add tier: each code's ≤2
    /// signed power-of-two digits, extracted once at encode/update
    /// time (row-major, parallel to `codes`)
    digits: Vec<WeightDigits>,
    /// which forward-kernel engine [`matvec_fast`]/[`matmul_fast`]
    /// dispatch to for this matrix (runtime-only, never checkpointed)
    tier: KernelTier,
}

impl QMatrix {
    /// Quantize a row-major f32 matrix `[rows][cols]` into FloatSD8.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let codes: Vec<FloatSd8> = data.iter().map(|&v| FLOAT_SD8.encode(v)).collect();
        Self::from_codes(rows, cols, codes)
    }

    /// Build from raw FloatSD8 codes (non-canonical codes decode with
    /// the same rank clamping as `FLOAT_SD8.decode`). All cached
    /// layouts — decoded, transposed, digit-planar — are derived here,
    /// the single construction path.
    pub fn from_codes(rows: usize, cols: usize, codes: Vec<FloatSd8>) -> Self {
        assert_eq!(codes.len(), rows * cols);
        let decoded: Vec<f32> = codes.iter().map(|&c| FLOAT_SD8.decode(c)).collect();
        let digits: Vec<WeightDigits> = codes.iter().map(|&c| WeightDigits::of(c)).collect();
        let mut decoded_t = vec![0f32; decoded.len()];
        for r in 0..rows {
            for c in 0..cols {
                decoded_t[c * rows + r] = decoded[r * cols + c];
            }
        }
        QMatrix { rows, cols, codes, decoded, decoded_t, digits, tier: KernelTier::default() }
    }

    /// Select the forward-kernel tier for this matrix.
    pub fn set_kernel_tier(&mut self, tier: KernelTier) {
        self.tier = tier;
    }

    /// The forward-kernel tier this matrix dispatches to.
    pub fn kernel_tier(&self) -> KernelTier {
        self.tier
    }

    /// The cached digit-planar layout (row-major, parallel to `codes`).
    #[inline]
    pub fn digits(&self) -> &[WeightDigits] {
        &self.digits
    }

    #[inline]
    pub fn row_digits(&self, r: usize) -> &[WeightDigits] {
        &self.digits[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_codes(&self, r: usize) -> &[FloatSd8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_decoded(&self, r: usize) -> &[f32] {
        &self.decoded[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` of the decoded matrix as a contiguous slice (the
    /// transposed copy) — the backward kernels' access path.
    #[inline]
    pub fn col_decoded(&self, c: usize) -> &[f32] {
        &self.decoded_t[c * self.rows..(c + 1) * self.rows]
    }

    /// Bytes of weight storage (8 bits/weight) — the paper's memory
    /// footprint argument (§I, §III-E).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Apply one optimizer step to this matrix under the paper's
    /// FP16-master scheme (§IV-C): per weight, `masters[k]` absorbs
    /// `deltas[k]` with one FP16 rounding
    /// ([`FloatSdFormat::apply_update`](crate::formats::FloatSdFormat::apply_update)),
    /// and the live code + decoded fast-path copy are re-encoded to the
    /// nearest FloatSD8 value of the new master.
    pub fn apply_master_update(&mut self, masters: &mut [f32], deltas: &[f32]) {
        assert_eq!(masters.len(), self.codes.len());
        assert_eq!(deltas.len(), self.codes.len());
        for k in 0..self.codes.len() {
            let (m, code) = FLOAT_SD8.apply_update(masters[k], deltas[k]);
            masters[k] = m;
            self.codes[k] = code;
            let v = FLOAT_SD8.decode(code);
            self.decoded[k] = v;
            // keep the transposed and digit-planar copies in lockstep
            self.digits[k] = WeightDigits::of(code);
            let (r, c) = (k / self.cols, k % self.cols);
            self.decoded_t[c * self.rows + r] = v;
        }
    }
}

/// y[r] = round chain of (bias[r] + Σ_c x[c]·W[r,c]) via the MAC.
pub fn matvec_mac(w: &QMatrix, x: &[Fp8], bias: &[Fp16], mode: MacMode) -> Vec<Fp16> {
    assert_eq!(x.len(), w.cols);
    assert_eq!(bias.len(), w.rows);
    (0..w.rows)
        .map(|r| dot_fsd8_fp8(bias[r], x, w.row_codes(r), mode))
        .collect()
}

/// The per-row kernel both fast paths share: one decoded weight row
/// against one input vector, f64-exact group sums, one FP16 rounding
/// per [`MAC_GROUP`]. Keeping this in one place is what makes the
/// batched path *bit-identical* to the per-vector path by construction.
#[inline]
fn dot_row_chained(row: &[f32], x: &[f32], bias: f32) -> f32 {
    let cols = row.len();
    let mut acc = bias; // callers keep bias on the f16 grid
    let mut c = 0;
    while c + MAC_GROUP <= cols {
        let g = x[c] as f64 * row[c] as f64
            + x[c + 1] as f64 * row[c + 1] as f64
            + x[c + 2] as f64 * row[c + 2] as f64
            + x[c + 3] as f64 * row[c + 3] as f64;
        acc = Fp16::from_f64(acc as f64 + g).to_f32();
        c += MAC_GROUP;
    }
    if c < cols {
        let mut g = 0f64;
        for cc in c..cols {
            g += x[cc] as f64 * row[cc] as f64;
        }
        acc = Fp16::from_f64(acc as f64 + g).to_f32();
    }
    acc
}

/// Optimized path, numerically identical to
/// `matvec_mac(.., MacMode::Exact)`:
/// decoded weights, f64 exact group sums, one f16 round per group.
///
/// Dispatches on the matrix's [`KernelTier`]: the `shiftadd` tier runs
/// [`shiftadd::matvec_sa`], pinned bit-identical to this path by
/// `tests/shiftadd_equivalence.rs`.
/// With a telemetry sink open ([`crate::telemetry::hot_enabled`]) the
/// call is wall-clock timed into the kernel-tier profile
/// ([`crate::telemetry::note_kernel`]); disabled, the hook costs one
/// relaxed load + branch (pinned allocation-free by
/// `tests/telemetry_alloc.rs`). The profile is write-only — timing can
/// never perturb an output bit.
pub fn matvec_fast(w: &QMatrix, x: &[f32], bias: &[f32], out: &mut [f32]) {
    if crate::telemetry::hot_enabled() {
        let t0 = std::time::Instant::now();
        matvec_fast_impl(w, x, bias, out);
        crate::telemetry::note_kernel(
            crate::telemetry::KernelOp::Matvec,
            w.tier,
            w.rows,
            w.cols,
            1,
            t0.elapsed(),
        );
        return;
    }
    matvec_fast_impl(w, x, bias, out);
}

#[inline]
fn matvec_fast_impl(w: &QMatrix, x: &[f32], bias: &[f32], out: &mut [f32]) {
    if w.tier == KernelTier::ShiftAdd {
        return shiftadd::matvec_sa(w, x, bias, out);
    }
    assert_eq!(x.len(), w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), w.rows);
    for r in 0..w.rows {
        out[r] = dot_row_chained(w.row_decoded(r), x, bias[r]);
    }
}

/// Four independent FP16 chains sharing one pass over the decoded
/// weight row — the register-tiled inner block of [`matmul_fast`].
/// Each stream's accumulation is the *exact* operation sequence of
/// [`dot_row_chained`] (same f64 products, same left-to-right group
/// sums, same one-FP16-round-per-group chain), so every lane of the
/// result is bit-identical to a standalone per-stream call; the tiling
/// only reuses each weight element four times from registers instead
/// of re-streaming the row per stream.
#[inline]
#[allow(clippy::too_many_arguments)]
fn dot_row_chained4(
    row: &[f32],
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
    bias: f32,
) -> [f32; 4] {
    let cols = row.len();
    let mut acc = [bias; 4];
    let mut c = 0;
    while c + MAC_GROUP <= cols {
        let (w0, w1, w2, w3) =
            (row[c] as f64, row[c + 1] as f64, row[c + 2] as f64, row[c + 3] as f64);
        let g0 = x0[c] as f64 * w0 + x0[c + 1] as f64 * w1 + x0[c + 2] as f64 * w2
            + x0[c + 3] as f64 * w3;
        let g1 = x1[c] as f64 * w0 + x1[c + 1] as f64 * w1 + x1[c + 2] as f64 * w2
            + x1[c + 3] as f64 * w3;
        let g2 = x2[c] as f64 * w0 + x2[c + 1] as f64 * w1 + x2[c + 2] as f64 * w2
            + x2[c + 3] as f64 * w3;
        let g3 = x3[c] as f64 * w0 + x3[c + 1] as f64 * w1 + x3[c + 2] as f64 * w2
            + x3[c + 3] as f64 * w3;
        acc[0] = Fp16::from_f64(acc[0] as f64 + g0).to_f32();
        acc[1] = Fp16::from_f64(acc[1] as f64 + g1).to_f32();
        acc[2] = Fp16::from_f64(acc[2] as f64 + g2).to_f32();
        acc[3] = Fp16::from_f64(acc[3] as f64 + g3).to_f32();
        c += MAC_GROUP;
    }
    if c < cols {
        let mut g = [0f64; 4];
        for cc in c..cols {
            let wv = row[cc] as f64;
            g[0] += x0[cc] as f64 * wv;
            g[1] += x1[cc] as f64 * wv;
            g[2] += x2[cc] as f64 * wv;
            g[3] += x3[cc] as f64 * wv;
        }
        for (a, gk) in acc.iter_mut().zip(g) {
            *a = Fp16::from_f64(*a as f64 + gk).to_f32();
        }
    }
    acc
}

/// Batched fast matvec: `ys[b] = W · xs[b] + bias` for a whole batch.
///
/// **Weight-stationary, register-tiled** loop order (the serving
/// engine's amortization argument, mirroring the PE's §V-A batch
/// loop): the row loop is outermost, so each decoded FloatSD8 row is
/// streamed from memory once per *batch* instead of once per
/// *stream*; inside a row, streams are processed four at a time
/// ([`dot_row_chained4`]) so each weight element loaded is reused
/// across four independent accumulation chains. For weight matrices
/// larger than cache this is where batched serving (and the sharded
/// trainer's forward) wins its throughput. Each `(row, stream)` pair
/// runs the identical [`dot_row_chained`] operation sequence, so
/// results are bit-identical to `batch` independent [`matvec_fast`]
/// calls (pinned by `tests::matmul_fast_matches_per_row`).
/// Timed into the kernel-tier profile exactly like [`matvec_fast`]
/// (shape class includes `batch`, so occupancy tiers profile apart).
pub fn matmul_fast(w: &QMatrix, xs: &[f32], batch: usize, bias: &[f32], out: &mut [f32]) {
    if crate::telemetry::hot_enabled() {
        let t0 = std::time::Instant::now();
        matmul_fast_impl(w, xs, batch, bias, out);
        crate::telemetry::note_kernel(
            crate::telemetry::KernelOp::Matmul,
            w.tier,
            w.rows,
            w.cols,
            batch,
            t0.elapsed(),
        );
        return;
    }
    matmul_fast_impl(w, xs, batch, bias, out);
}

#[inline]
fn matmul_fast_impl(w: &QMatrix, xs: &[f32], batch: usize, bias: &[f32], out: &mut [f32]) {
    if w.tier == KernelTier::ShiftAdd {
        return shiftadd::matmul_sa(w, xs, batch, bias, out);
    }
    assert_eq!(xs.len(), batch * w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), batch * w.rows);
    let (rows, cols) = (w.rows, w.cols);
    for r in 0..rows {
        let row = w.row_decoded(r);
        let b_r = bias[r];
        let mut b = 0usize;
        while b + 4 <= batch {
            let ys = dot_row_chained4(
                row,
                &xs[b * cols..(b + 1) * cols],
                &xs[(b + 1) * cols..(b + 2) * cols],
                &xs[(b + 2) * cols..(b + 3) * cols],
                &xs[(b + 3) * cols..(b + 4) * cols],
                b_r,
            );
            out[b * rows + r] = ys[0];
            out[(b + 1) * rows + r] = ys[1];
            out[(b + 2) * rows + r] = ys[2];
            out[(b + 3) * rows + r] = ys[3];
            b += 4;
        }
        while b < batch {
            out[b * rows + r] = dot_row_chained(row, &xs[b * cols..(b + 1) * cols], b_r);
            b += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn setup(rows: usize, cols: usize, seed: u64) -> (QMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w = QMatrix::from_f32(rows, cols, &data);
        // x on the FP8 grid, bias on the f16 grid (architectural contract)
        let x: Vec<f32> = (0..cols)
            .map(|_| crate::formats::round_f8(rng.uniform(-4.0, 4.0)))
            .collect();
        let bias: Vec<f32> = (0..rows)
            .map(|_| crate::formats::round_f16(rng.uniform(-0.5, 0.5)))
            .collect();
        (w, x, bias)
    }

    #[test]
    fn fast_equals_mac() {
        for &(r, c) in &[(3, 4), (8, 16), (5, 7), (16, 33), (1, 1)] {
            let (w, x, bias) = setup(r, c, (r * 100 + c) as u64);
            let x8: Vec<Fp8> = x.iter().map(|&v| Fp8::from_f32(v)).collect();
            let b16: Vec<Fp16> = bias.iter().map(|&v| Fp16::from_f32(v)).collect();
            let via_mac = matvec_mac(&w, &x8, &b16, MacMode::Exact);
            let mut fast = vec![0f32; r];
            matvec_fast(&w, &x, &bias, &mut fast);
            for i in 0..r {
                assert_eq!(
                    via_mac[i].to_f32(),
                    fast[i],
                    "({r}x{c}) row {i}: mac={} fast={}",
                    via_mac[i].to_f32(),
                    fast[i]
                );
            }
        }
    }

    #[test]
    fn storage_is_one_byte_per_weight() {
        let (w, _, _) = setup(8, 8, 1);
        assert_eq!(w.storage_bytes(), 64);
    }

    #[test]
    fn matmul_fast_matches_per_row() {
        // includes cols not a multiple of MAC_GROUP (12, 7, 5), a
        // degenerate 1x1, and every batch size across the 4-stream
        // register-tile boundary (1..=9) — the weight-stationary tiled
        // loop must stay bit-identical to per-stream matvec_fast in
        // every tail case.
        for &(rows, cols) in &[(6usize, 12usize), (3, 7), (9, 5), (1, 1)] {
            let (w, _, bias) = setup(rows, cols, (rows * 1000 + cols) as u64);
            for batch in 1usize..=9 {
                let mut rng = SplitMix64::new(3 + batch as u64);
                let xs: Vec<f32> = (0..batch * cols)
                    .map(|_| crate::formats::round_f8(rng.uniform(-2.0, 2.0)))
                    .collect();
                let mut out = vec![0f32; batch * rows];
                matmul_fast(&w, &xs, batch, &bias, &mut out);
                for b in 0..batch {
                    let mut y = vec![0f32; rows];
                    matvec_fast(&w, &xs[b * cols..(b + 1) * cols], &bias, &mut y);
                    for (a, e) in out[b * rows..(b + 1) * rows].iter().zip(&y) {
                        assert_eq!(
                            a.to_bits(),
                            e.to_bits(),
                            "({rows}x{cols}) batch {batch} stream {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn transposed_copy_tracks_updates() {
        let mut rng = SplitMix64::new(31);
        let mut masters: Vec<f32> = (0..5 * 3)
            .map(|_| crate::formats::round_f16(rng.uniform(-1.0, 1.0)))
            .collect();
        let mut w = QMatrix::from_f32(5, 3, &masters);
        for r in 0..5 {
            for c in 0..3 {
                assert_eq!(w.col_decoded(c)[r], w.row_decoded(r)[c], "transpose out of sync");
            }
        }
        let deltas: Vec<f32> = (0..15).map(|_| rng.uniform(-0.3, 0.3)).collect();
        w.apply_master_update(&mut masters, &deltas);
        for r in 0..5 {
            for c in 0..3 {
                assert_eq!(
                    w.col_decoded(c)[r],
                    w.row_decoded(r)[c],
                    "transpose out of sync after update"
                );
            }
        }
    }

    #[test]
    fn apply_master_update_keeps_code_and_decoded_in_sync() {
        let mut rng = SplitMix64::new(21);
        let mut masters: Vec<f32> = (0..12)
            .map(|_| crate::formats::round_f16(rng.uniform(-1.0, 1.0)))
            .collect();
        let mut w = QMatrix::from_f32(3, 4, &masters);
        let deltas: Vec<f32> = (0..12).map(|_| rng.uniform(-0.2, 0.2)).collect();
        w.apply_master_update(&mut masters, &deltas);
        for r in 0..3 {
            for c in 0..4 {
                let k = r * 4 + c;
                assert_eq!(masters[k], crate::formats::round_f16(masters[k]));
                assert_eq!(w.row_decoded(r)[c], FLOAT_SD8.decode(w.row_codes(r)[c]));
                assert_eq!(w.row_decoded(r)[c], FLOAT_SD8.quantize(masters[k]));
            }
        }
    }

    #[test]
    fn weights_land_on_sd8_grid() {
        let (w, _, _) = setup(4, 4, 9);
        for r in 0..4 {
            for &v in w.row_decoded(r) {
                assert!(FLOAT_SD8.values().contains(&v));
            }
        }
    }
}
