//! Two-region FloatSD8-quantized sigmoid (paper §III-C, Eq. 7/8) and
//! the merged σ+quantization LUT of the hardware (§III-C last ¶, §V-B).
//!
//! * Eq. (7): `y = Q(σ(x))` for `x ≤ 0` — one FloatSD8 number;
//! * Eq. (8): `y = 1 − Q(σ(−x))` for `x > 0` — the hardware represents
//!   this as the *pair* (+1, −Q(σ(−x))) and feeds both to the MAC; the
//!   scalar value returned here is their sum.
//!
//! With exponent bias 7 the non-positive branch hits exactly **42
//! non-zero grid points** (plus underflow to 0 for x ≲ −9.7), matching
//! the paper's "only 42 possible values … the depth of the LUT can be
//! reduced" — verified by [`SigmoidLut`]'s enumeration test.

use crate::formats::{round_f8, FLOAT_SD8};

/// `Q(σ(x))` / `1 − Q(σ(−x))` — the two-region quantized sigmoid.
///
/// Matches `python/compile/kernels/quant.sigmoid_floatsd8` bit-for-bit
/// (pinned by the golden vectors).
#[inline]
pub fn sigmoid_sd8(x: f32) -> f32 {
    // σ(−|x|) = 1 − σ(|x|), computed the same way as the jnp side to
    // keep the last-ulp behaviour identical: s = 1/(1+e^{-|x|}).
    let s = 1.0f32 / (1.0 + (-x.abs()).exp());
    let q_neg = FLOAT_SD8.quantize(1.0 - s);
    let y = if x <= 0.0 { q_neg } else { 1.0 - q_neg };
    // clip-rate telemetry on the *result* — write-only counters, so
    // the value path is untouched (one relaxed load when disabled)
    crate::telemetry::note_sigmoid(y);
    y
}

/// Fig. 4's strawman: single-region quantization over the whole range.
/// Kept only for the Fig. 4 bench and the ablation study.
#[inline]
pub fn sigmoid_sd8_one_region(x: f32) -> f32 {
    let s = 1.0f32 / (1.0 + (-x).exp());
    FLOAT_SD8.quantize(s)
}

/// tanh with FP8-quantized output (cell-gate / cell-state path — the
/// paper keeps tanh outputs on the activation grid, Table II).
#[inline]
pub fn tanh_fp8(x: f32) -> f32 {
    let y = round_f8(x.tanh());
    crate::telemetry::note_tanh(y);
    y
}

/// The hardware LUT: thresholds on x mapping directly to quantized
/// σ outputs for the non-positive branch (σ and Q merged, §III-C).
///
/// Entry `k` covers `x ∈ (threshold[k], threshold[k+1]]` and yields
/// `value[k]`. The positive branch reuses the same table via Eq. (8).
pub struct SigmoidLut {
    /// Ascending input thresholds: x at which the output steps up.
    pub thresholds: Vec<f32>,
    /// Output value for each interval (len = thresholds.len() + 1).
    pub values: Vec<f32>,
}

impl SigmoidLut {
    /// Build the LUT by enumerating the FloatSD8 grid points in (0, ½]
    /// and inverting σ at the quantization midpoints.
    pub fn build() -> Self {
        // grid points reachable as Q(σ(x)), x ≤ 0: all values in (0, 0.5]
        let grid: Vec<f32> = FLOAT_SD8
            .values()
            .iter()
            .copied()
            .filter(|&v| v > 0.0 && v <= 0.5)
            .collect();
        // outputs: 0 (underflow), then grid ascending
        let mut values = vec![0.0f32];
        values.extend(&grid);
        // threshold between value[k] and value[k+1]: x where σ(x) crosses
        // the quantization midpoint m = (v_k + v_{k+1})/2 (ties go up,
        // consistent with quantize's away-from-zero rule on positives):
        // x = logit(m) = ln(m / (1−m)).
        let mut thresholds = Vec::with_capacity(values.len() - 1);
        for k in 0..values.len() - 1 {
            let m = 0.5 * (values[k] + values[k + 1]);
            thresholds.push((m / (1.0 - m)).ln());
        }
        SigmoidLut { thresholds, values }
    }

    /// Number of *non-zero* output entries (the paper's LUT depth).
    pub fn nonzero_entries(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Evaluate via the LUT (non-positive branch + Eq. 8 reflection).
    pub fn eval(&self, x: f32) -> f32 {
        let xa = if x <= 0.0 { x } else { -x };
        // binary search over thresholds: index of first threshold >= xa
        let k = self.thresholds.partition_point(|&t| t < xa);
        let v = self.values[k];
        if x <= 0.0 {
            v
        } else {
            1.0 - v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetry_eq7_eq8() {
        for i in 0..2000 {
            let x = (i as f32 - 1000.0) / 100.0;
            let a = sigmoid_sd8(x);
            let b = sigmoid_sd8(-x);
            assert_eq!(a + b, 1.0, "q({x}) + q({}) != 1", -x);
        }
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = -1.0f32;
        for i in 0..4000 {
            let x = (i as f32 - 2000.0) / 200.0;
            let q = sigmoid_sd8(x);
            assert!(q >= prev, "sigmoid_sd8 not monotone at {x}");
            prev = q;
        }
    }

    #[test]
    fn nonpositive_branch_on_grid() {
        for i in 0..=1000 {
            let x = -(i as f32) / 100.0;
            let q = sigmoid_sd8(x);
            assert!(
                FLOAT_SD8.values().contains(&q),
                "q({x}) = {q} not a FloatSD8 value"
            );
        }
    }

    #[test]
    fn lut_has_paper_42_nonzero_entries() {
        let lut = SigmoidLut::build();
        assert_eq!(lut.nonzero_entries(), 42, "paper §III-C: 42 values");
    }

    #[test]
    fn lut_matches_direct_evaluation() {
        let lut = SigmoidLut::build();
        for i in 0..8000 {
            let x = (i as f32 - 4000.0) / 250.0; // [-16, 16]
            let direct = sigmoid_sd8(x);
            let via_lut = lut.eval(x);
            assert_eq!(
                direct, via_lut,
                "x={x}: direct {direct} vs lut {via_lut}"
            );
        }
    }

    #[test]
    fn saturation_behaviour() {
        assert_eq!(sigmoid_sd8(-30.0), 0.0, "deep negative underflows to 0");
        assert_eq!(sigmoid_sd8(30.0), 1.0, "deep positive saturates to 1");
        assert_eq!(sigmoid_sd8(0.0), 0.5, "σ(0) = 0.5 is on the grid");
    }

    #[test]
    fn tanh_fp8_on_grid() {
        for i in 0..200 {
            let x = (i as f32 - 100.0) / 10.0;
            let t = tanh_fp8(x);
            assert_eq!(t, round_f8(t), "tanh_fp8({x}) not on the FP8 grid");
        }
    }
}
