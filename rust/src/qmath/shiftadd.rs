//! Integer **shift-add** kernel tier — the paper's hardware thesis
//! (§IV, Table VII) brought onto the software hot path.
//!
//! A FloatSD8 weight is at most two signed power-of-two digits
//! ([`FloatSdFormat::partial_products`](crate::formats::FloatSdFormat::partial_products)),
//! so multiplying by it never needs a multiplier: `w·x` is
//! `Σ sign_i · (x << e_i)`. The decoded-f32 kernels in
//! [`vector`](super::vector) ignore this and multiply; this module
//! implements the same dot products by **shifting integer partial sums
//! in the fixed-point frame of the hardware MAC**
//! ([`hardware::mac_sim`](crate::hardware::mac_sim), `FRAC_BITS` = 28)
//! — in the style of int8 fixed-point inference engines (int dots →
//! one rescale/round at the group boundary).
//!
//! ## Memory layout
//!
//! Digit pairs live in [`DigitPlanes`]: a structure-of-arrays layout
//! with four parallel `i8` planes (`s0/e0/s1/e1`) and a padded row
//! stride, so the inner loop streams each plane at unit stride instead
//! of hopping over an array-of-structs — the layout a vectorizer can
//! actually chew on. The batched kernel is weight-stationary and
//! register-tiled up to eight activation streams wide (see
//! [`matmul_sa`]), with row/column blocking shared with the decoded
//! tier (`vector::{ROW_BLOCK, COL_BLOCK}`).
//!
//! ## Equivalence contract (pinned by `tests/shiftadd_equivalence.rs`)
//!
//! The decoded reference rounds once per [`MAC_GROUP`]-element group:
//! `acc ← fp16(acc + Σ_group w·x)`, with the group sum exact in f64.
//! For operands inside the fixed-point frame — `|x| ≤ 2^20` with no
//! significand bit below `2^-19`, accumulator within `2^20`/`2^-28` —
//! every product `w·x` is an exact multiple of `2^-28`, group sums
//! stay under 53 bits, and both paths compute the *same exact value*;
//! [`round_fixed_to_f16`] is RNE like `Fp16::from_f64`, so the rounded
//! results are **bit-identical**. Every grid the engine produces (FP8
//! activations, FP16 accumulators, FloatSD8 σ outputs) lives inside
//! that frame. Operands outside it (f32 denormals below `2^-19`,
//! magnitudes above `2^20`, ±inf/NaN, `-0.0`) make their *group* fall
//! back to the decoded path's literal f64 operation sequence — so
//! [`matvec_sa`] ≡ `matvec_fast` bit-for-bit on **all** inputs, not
//! just well-behaved ones.
//!
//! The whole-row single-rounding variant [`dot_row_sa_wide`] trades
//! that identity for fewer roundings; its divergence from the chained
//! reference is *characterized* (ULP/max-abs bound) rather than
//! pinned, the way `qsigmoid` documents its error envelope.
//!
//! Tier selection is a per-matrix runtime switch
//! ([`KernelTier`] on [`QMatrix`]) exposed as `--kernel-tier
//! {decoded,shiftadd}` on the train/serve/eval CLIs; backward kernels
//! always run decoded (gradients are FP8/f32, not FloatSD8).

use std::cell::RefCell;

use anyhow::{bail, Result};

use crate::formats::floatsd::SD8_EXP_BIAS;
use crate::formats::{FloatSd8, Fp16, FLOAT_SD8};
use crate::hardware::mac_sim::round_fixed_to_f16;

use super::mac::MAC_GROUP;
use super::vector::{QMatrix, COL_BLOCK, MAX_TILE, ROW_BLOCK};

/// Fixed-point frame of the accumulation: partial sums are integers in
/// units of `2^-FRAC_BITS` — the same frame as the hardware MAC
/// simulator (equality pinned by a test in
/// `tests/shiftadd_equivalence.rs`).
pub const FRAC_BITS: i32 = 28;

/// Smallest partial-product exponent a FloatSD8 digit can contribute:
/// exponent field 0 (`e = −bias`) with the second group's odd digit
/// (`g1 = ±1`, weight `2^-2`).
pub const W_EXP_MIN: i32 = -SD8_EXP_BIAS - 2;
/// Largest digit exponent: exponent field 7 with `g0 = ±4`.
pub const W_EXP_MAX: i32 = (7 - SD8_EXP_BIAS) + 2;

/// Smallest activation significand exponent the frame can hold: the
/// lowest-exponent digit (`2^-9`) times a `2^-19` activation bit still
/// lands on the `2^-28` fixed-point LSB.
const X_EXP_MIN: i32 = -FRAC_BITS - W_EXP_MIN;
/// Accumulator bits reach the frame LSB directly.
const ACC_EXP_MIN: i32 = -FRAC_BITS;
/// Magnitude cap keeping a 4-term group + accumulator within 53 exact
/// bits (`4 · 4.5 · 2^20 + 2^20 < 2^25`, times `2^28` < `2^53`). FP8
/// (max 114688 < 2^17) and FP16 (max 65504 < 2^16) grids sit far
/// inside it.
const MAG_MAX: f32 = (1u32 << 20) as f32;

/// Which dot-product engine a [`QMatrix`]'s forward kernels run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// decode-to-f32 + multiply (the bit-exactness reference; default)
    #[default]
    Decoded,
    /// integer shift-add in the fixed-point MAC frame
    ShiftAdd,
}

impl KernelTier {
    pub fn parse(s: &str) -> Result<KernelTier> {
        Ok(match s {
            "decoded" => KernelTier::Decoded,
            "shiftadd" | "shift-add" => KernelTier::ShiftAdd,
            other => bail!("unknown kernel tier {other:?} (expected decoded|shiftadd)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Decoded => "decoded",
            KernelTier::ShiftAdd => "shiftadd",
        }
    }
}

/// One weight's ≤2 signed power-of-two digits, extracted from its
/// FloatSD8 code once at encode/update time. `s0 == 0` ⇒ the weight is
/// zero; `s1 == 0` ⇒ a single-digit weight. When both digits are
/// present `e0 > e1` (the MSG digit leads). The per-matrix storage is
/// [`DigitPlanes`] (structure-of-arrays); this struct is the
/// per-weight view used at encode/update boundaries and by the wide
/// variant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WeightDigits {
    pub s0: i8,
    pub e0: i8,
    pub s1: i8,
    pub e1: i8,
}

impl WeightDigits {
    /// Extract the digit pair of a (not necessarily canonical) code —
    /// same clamping as `FLOAT_SD8.decode`.
    pub fn of(code: FloatSd8) -> WeightDigits {
        let pp = FLOAT_SD8.partial_products(code);
        let mut d = WeightDigits::default();
        let mut it = pp.iter();
        if let Some((s, e)) = it.next() {
            debug_assert!((W_EXP_MIN..=W_EXP_MAX).contains(&e), "digit exp {e} out of range");
            d.s0 = s;
            d.e0 = e as i8;
        }
        if let Some((s, e)) = it.next() {
            debug_assert!((W_EXP_MIN..=W_EXP_MAX).contains(&e), "digit exp {e} out of range");
            d.s1 = s;
            d.e1 = e as i8;
        }
        d
    }

    /// Number of non-zero digits (0..=2).
    pub fn count(self) -> usize {
        (self.s0 != 0) as usize + (self.s1 != 0) as usize
    }

    /// Reconstruct the weight value — must equal `FLOAT_SD8.decode`
    /// bit-for-bit for every code (pinned by the property tests).
    pub fn value(self) -> f32 {
        let v = self.s0 as f64 * 2f64.powi(self.e0 as i32)
            + self.s1 as f64 * 2f64.powi(self.e1 as i32);
        v as f32
    }
}

/// Structure-of-arrays digit storage for a whole matrix: four parallel
/// `i8` planes (`s0/e0/s1/e1`), each row padded to a
/// [`Self::ROW_ALIGN`]-multiple stride so plane rows start on
/// alignment-friendly boundaries and the shift-add inner loop streams
/// every plane at unit stride. Padding digits stay zero (`s == 0` ⇒ no
/// contribution) and [`Self::row`] hands kernels exactly `cols`
/// elements, so the tail is never read — the padded layout is
/// observationally identical to a dense one.
pub struct DigitPlanes {
    rows: usize,
    cols: usize,
    /// `cols` rounded up to a multiple of [`Self::ROW_ALIGN`]
    stride: usize,
    s0: Vec<i8>,
    e0: Vec<i8>,
    s1: Vec<i8>,
    e1: Vec<i8>,
}

impl DigitPlanes {
    /// Plane rows start every 16 bytes — 16 `i8` lanes, one SSE
    /// register / half a cache line.
    pub const ROW_ALIGN: usize = 16;

    /// All-zero planes (every weight reads back as the zero digit
    /// pair) — callers fill via [`Self::set`].
    pub fn new(rows: usize, cols: usize) -> DigitPlanes {
        let stride = cols.div_ceil(Self::ROW_ALIGN) * Self::ROW_ALIGN;
        let n = rows * stride;
        DigitPlanes {
            rows,
            cols,
            stride,
            s0: vec![0; n],
            e0: vec![0; n],
            s1: vec![0; n],
            e1: vec![0; n],
        }
    }

    /// The padded row stride in plane elements.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Scatter one weight's digit pair across the four planes.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, d: WeightDigits) {
        debug_assert!(r < self.rows && c < self.cols);
        let k = r * self.stride + c;
        self.s0[k] = d.s0;
        self.e0[k] = d.e0;
        self.s1[k] = d.s1;
        self.e1[k] = d.e1;
    }

    /// Gather one weight's digit pair back (update-sync checks, tests).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> WeightDigits {
        debug_assert!(r < self.rows && c < self.cols);
        let k = r * self.stride + c;
        WeightDigits { s0: self.s0[k], e0: self.e0[k], s1: self.s1[k], e1: self.e1[k] }
    }

    /// Row `r` of all four planes, each exactly `cols` long — the
    /// kernel-facing view (padding excluded).
    #[inline]
    pub fn row(&self, r: usize) -> (&[i8], &[i8], &[i8], &[i8]) {
        let lo = r * self.stride;
        let hi = lo + self.cols;
        (&self.s0[lo..hi], &self.e0[lo..hi], &self.s1[lo..hi], &self.e1[lo..hi])
    }

    /// The full backing planes, padding included — property tests
    /// assert the padding tail stays zero across update sequences.
    pub fn raw_planes(&self) -> (&[i8], &[i8], &[i8], &[i8]) {
        (&self.s0, &self.e0, &self.s1, &self.e1)
    }
}

/// An activation decomposed for the shift-add frame: `value =
/// sig · 2^exp` with `sig` odd (trailing zeros stripped). `fast` means
/// the value is exactly representable in the i64 fixed-point frame;
/// groups containing a non-`fast` operand run the decoded fallback.
#[derive(Clone, Copy, Debug, Default)]
pub struct XTerm {
    pub sig: i64,
    pub exp: i32,
    pub fast: bool,
}

#[inline]
fn split(x: f32, min_exp: i32) -> XTerm {
    let bits = x.to_bits();
    if bits == 0 {
        // +0.0 — contributes nothing on the fast path
        return XTerm { sig: 0, exp: 0, fast: true };
    }
    // -0.0 is excluded from the fast path: the decoded reference's f64
    // sums propagate the sign of zero, which the integer frame cannot
    if !x.is_finite() || bits == 0x8000_0000 || x.abs() > MAG_MAX {
        return XTerm { sig: 0, exp: 0, fast: false };
    }
    let sign: i64 = if bits >> 31 == 1 { -1 } else { 1 };
    let e = ((bits >> 23) & 0xff) as i32;
    let m = (bits & 0x007f_ffff) as i64;
    let (mut sig, mut exp) = if e == 0 { (m, -149) } else { (m | 0x0080_0000, e - 150) };
    let tz = sig.trailing_zeros() as i32;
    sig >>= tz;
    exp += tz;
    XTerm { sig: sign * sig, exp, fast: exp >= min_exp }
}

/// Decompose an activation for the shift-add kernels.
#[inline]
pub fn decompose_x(x: f32) -> XTerm {
    split(x, X_EXP_MIN)
}

#[inline]
pub(crate) fn decompose_acc(a: f32) -> XTerm {
    split(a, ACC_EXP_MIN)
}

/// One MAC group over the digit planes: shift-add the ≤2 digits of
/// each weight (read from the four parallel `i8` slices) against the
/// pre-decomposed activations, then round the fixed-point sum to the
/// FP16 grid — or, if any operand is outside the frame, run the
/// decoded reference's literal f64 sequence for this group.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn group_sa(
    acc: f32,
    s0: &[i8],
    e0: &[i8],
    s1: &[i8],
    e1: &[i8],
    row: &[f32],
    x: &[f32],
    xt: &[XTerm],
) -> f32 {
    let a = decompose_acc(acc);
    let mut fast = a.fast;
    for t in xt {
        fast &= t.fast;
    }
    if fast {
        let mut sum: i64 = a.sig << (a.exp + FRAC_BITS);
        for (i, t) in xt.iter().enumerate() {
            if t.sig != 0 {
                if s0[i] != 0 {
                    sum += (s0[i] as i64 * t.sig) << (e0[i] as i32 + t.exp + FRAC_BITS);
                }
                if s1[i] != 0 {
                    sum += (s1[i] as i64 * t.sig) << (e1[i] as i32 + t.exp + FRAC_BITS);
                }
            }
        }
        round_fixed_to_f16(sum, FRAC_BITS as u32).to_f32()
    } else {
        // bit-identical by identity: these are exactly the reference
        // group's operations (f64 products, left-to-right sum, one
        // FP16 rounding) — see `vector::dot_row_chained`
        let mut g = 0f64;
        for (w, v) in row.iter().zip(x) {
            g += *v as f64 * *w as f64;
        }
        Fp16::from_f64(acc as f64 + g).to_f32()
    }
}

/// Advance `T` independent shift-add chains over one group-aligned
/// span of a weight row. Each lane runs the exact [`group_sa`]
/// sequence of a standalone [`dot_row_sa`] — the tiling only reuses
/// the plane/row loads across lanes — so every lane is bit-identical
/// to a per-stream call by construction. Span starts must be
/// [`MAC_GROUP`]-aligned within the row (the callers block columns in
/// `COL_BLOCK`-multiples) so group boundaries match full-row grouping;
/// only the final span may carry the sub-group tail.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn sa_span_t<const T: usize>(
    s0: &[i8],
    e0: &[i8],
    s1: &[i8],
    e1: &[i8],
    row: &[f32],
    xs: &[&[f32]; T],
    xts: &[&[XTerm]; T],
    mut acc: [f32; T],
) -> [f32; T] {
    let n = row.len();
    let mut c = 0;
    while c + MAC_GROUP <= n {
        let hi = c + MAC_GROUP;
        for t in 0..T {
            acc[t] = group_sa(
                acc[t],
                &s0[c..hi],
                &e0[c..hi],
                &s1[c..hi],
                &e1[c..hi],
                &row[c..hi],
                &xs[t][c..hi],
                &xts[t][c..hi],
            );
        }
        c = hi;
    }
    if c < n {
        for t in 0..T {
            acc[t] = group_sa(
                acc[t],
                &s0[c..],
                &e0[c..],
                &s1[c..],
                &e1[c..],
                &row[c..],
                &xs[t][c..],
                &xts[t][c..],
            );
        }
    }
    acc
}

/// Shift-add mirror of `vector::dot_row_chained`: same grouping, same
/// tail handling, one FP16 rounding per group — bit-identical to the
/// decoded reference for all inputs. `planes` is one row of the four
/// digit planes ([`DigitPlanes::row`]).
pub fn dot_row_sa(
    planes: (&[i8], &[i8], &[i8], &[i8]),
    row: &[f32],
    x: &[f32],
    xt: &[XTerm],
    bias: f32,
) -> f32 {
    let (s0, e0, s1, e1) = planes;
    debug_assert_eq!(s0.len(), row.len());
    debug_assert_eq!(x.len(), row.len());
    debug_assert_eq!(xt.len(), row.len());
    sa_span_t::<1>(s0, e0, s1, e1, row, &[x], &[xt], [bias])[0]
}

/// Whole-row shift-add accumulation with a **single** final FP16
/// rounding — the "what if the hardware kept the wide accumulator"
/// variant. Not bit-identical to the chained reference (it skips the
/// per-group roundings); its error envelope is characterized by
/// `tests/shiftadd_equivalence.rs`. Returns `None` when any operand
/// falls outside the fixed-point frame or the i128 running sum leaves
/// the i64 frame.
pub fn dot_row_sa_wide(dig: &[WeightDigits], xt: &[XTerm], bias: f32) -> Option<f32> {
    let a = decompose_acc(bias);
    if !a.fast || xt.iter().any(|t| !t.fast) {
        return None;
    }
    let mut sum: i128 = (a.sig as i128) << (a.exp + FRAC_BITS);
    for (d, t) in dig.iter().zip(xt) {
        if t.sig != 0 {
            if d.s0 != 0 {
                sum += (d.s0 as i128 * t.sig as i128) << (d.e0 as i32 + t.exp + FRAC_BITS);
            }
            if d.s1 != 0 {
                sum += (d.s1 as i128 * t.sig as i128) << (d.e1 as i32 + t.exp + FRAC_BITS);
            }
        }
    }
    let sum = i64::try_from(sum).ok()?;
    Some(round_fixed_to_f16(sum, FRAC_BITS as u32).to_f32())
}

thread_local! {
    /// Per-thread activation-decomposition scratch — decomposing each
    /// `x[c]` once per matvec instead of once per (row, col) pair, with
    /// no steady-state allocation (the lane-sharded trainer runs one
    /// matvec stream per thread).
    static X_SCRATCH: RefCell<Vec<XTerm>> = const { RefCell::new(Vec::new()) };
}

/// Shift-add matvec: `out[r] = chain(bias[r] + Σ_c x[c]·W[r,c])` —
/// bit-identical to `vector::matvec_fast` on the decoded tier.
pub fn matvec_sa(w: &QMatrix, x: &[f32], bias: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), w.rows);
    X_SCRATCH.with(|s| {
        let mut xt = s.borrow_mut();
        xt.clear();
        xt.extend(x.iter().map(|&v| decompose_x(v)));
        for r in 0..w.rows {
            out[r] = dot_row_sa(w.digit_row(r), w.row_decoded(r), x, &xt, bias[r]);
        }
    });
}

/// Shift-add batched matvec: `ys[b] = W · xs[b] + bias`.
/// **Weight-stationary, register-tiled, blocked** — the same loop
/// structure as the decoded `matmul_fast`: every stream's activations
/// are decomposed once up front into `xt_buf`, then streams are tiled
/// `max_tile`-at-a-time (8 → 4 → scalar remainder) and each tile walks
/// `ROW_BLOCK × COL_BLOCK` blocks of the digit planes, accumulating a
/// row-block's outputs in contiguous scratch and writing `out` in
/// batch-major runs (no stride-`rows` scatter). Each `(row, stream)`
/// pair runs the identical [`dot_row_sa`] sequence, so results are
/// bit-identical to `batch` [`matvec_sa`] calls — and thus to the
/// decoded `matmul_fast`, whose tiling contract is the same.
///
/// `isa` selects the span execution path
/// ([`IsaPath`](super::simd::IsaPath)) — every path is bit-identical;
/// the blocked callers pass the matrix's configured path.
pub fn matmul_sa(
    w: &QMatrix,
    xs: &[f32],
    batch: usize,
    bias: &[f32],
    out: &mut [f32],
    xt_buf: &mut Vec<XTerm>,
    max_tile: usize,
    isa: super::simd::IsaPath,
) {
    assert_eq!(xs.len(), batch * w.cols);
    assert_eq!(bias.len(), w.rows);
    assert_eq!(out.len(), batch * w.rows);
    xt_buf.clear();
    xt_buf.extend(xs.iter().map(|&v| decompose_x(v)));
    let xt = &xt_buf[..];
    let mut b = 0usize;
    if max_tile >= 8 {
        while b + 8 <= batch {
            matmul_sa_tile::<8>(w, xs, xt, bias, out, b, isa);
            b += 8;
        }
    }
    if max_tile >= 4 {
        while b + 4 <= batch {
            matmul_sa_tile::<4>(w, xs, xt, bias, out, b, isa);
            b += 4;
        }
    }
    while b < batch {
        matmul_sa_tile::<1>(w, xs, xt, bias, out, b, isa);
        b += 1;
    }
}

/// One `T`-stream tile of [`matmul_sa`]: row/column-blocked over the
/// digit planes with a contiguous per-row-block accumulator, written
/// out batch-major. Column blocks are `COL_BLOCK`-aligned (a
/// [`MAC_GROUP`] multiple), so every [`sa_span_t`] span sees the same
/// group boundaries as a full-row pass, and carrying the f32
/// accumulator between spans reproduces [`dot_row_sa`]'s chain exactly.
fn matmul_sa_tile<const T: usize>(
    w: &QMatrix,
    xs: &[f32],
    xt: &[XTerm],
    bias: &[f32],
    out: &mut [f32],
    b0: usize,
    isa: super::simd::IsaPath,
) {
    let (rows, cols) = (w.rows, w.cols);
    let mut acc_blk = [0f32; MAX_TILE * ROW_BLOCK];
    let mut r0 = 0usize;
    while r0 < rows {
        let rb = ROW_BLOCK.min(rows - r0);
        for t in 0..T {
            acc_blk[t * rb..t * rb + rb].copy_from_slice(&bias[r0..r0 + rb]);
        }
        let mut c0 = 0usize;
        while c0 < cols {
            let cb = COL_BLOCK.min(cols - c0);
            let mut xr: [&[f32]; T] = [&[]; T];
            let mut xtr: [&[XTerm]; T] = [&[]; T];
            for t in 0..T {
                let lo = (b0 + t) * cols + c0;
                xr[t] = &xs[lo..lo + cb];
                xtr[t] = &xt[lo..lo + cb];
            }
            for ri in 0..rb {
                let r = r0 + ri;
                let (s0, e0, s1, e1) = w.digit_row(r);
                let mut acc = [0f32; T];
                for t in 0..T {
                    acc[t] = acc_blk[t * rb + ri];
                }
                let acc = super::simd::sa_span_isa::<T>(
                    (&s0[c0..c0 + cb], &e0[c0..c0 + cb], &s1[c0..c0 + cb], &e1[c0..c0 + cb]),
                    &w.row_decoded(r)[c0..c0 + cb],
                    &xr,
                    &xtr,
                    acc,
                    isa,
                );
                for t in 0..T {
                    acc_blk[t * rb + ri] = acc[t];
                }
            }
            c0 += cb;
        }
        for t in 0..T {
            out[(b0 + t) * rows + r0..(b0 + t) * rows + r0 + rb]
                .copy_from_slice(&acc_blk[t * rb..t * rb + rb]);
        }
        r0 += rb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_parses_and_names_round_trip() {
        for tier in [KernelTier::Decoded, KernelTier::ShiftAdd] {
            assert_eq!(KernelTier::parse(tier.name()).unwrap(), tier);
        }
        assert_eq!(KernelTier::parse("shift-add").unwrap(), KernelTier::ShiftAdd);
        assert!(KernelTier::parse("fp32").is_err());
        assert_eq!(KernelTier::default(), KernelTier::Decoded);
    }

    #[test]
    fn digits_reconstruct_every_code() {
        for bits in 0..=u8::MAX {
            let code = FloatSd8(bits);
            let d = WeightDigits::of(code);
            let want = FLOAT_SD8.decode(code);
            assert_eq!(d.value().to_bits(), want.to_bits(), "code {bits:#04x}");
            assert!(d.count() <= 2);
            if d.count() == 2 {
                assert!(d.e0 > d.e1, "MSG digit must lead: {d:?}");
            }
        }
    }

    #[test]
    fn digit_planes_round_trip_with_padded_stride() {
        let mut p = DigitPlanes::new(3, 7);
        assert_eq!(p.stride(), 16, "7 cols round up to one 16-lane row");
        for bits in [0x01u8, 0x80, 0xff] {
            let d = WeightDigits::of(FloatSd8(bits));
            p.set(2, 6, d);
            assert_eq!(p.get(2, 6), d);
        }
        // row views are exactly cols long and SoA-consistent with get()
        let (s0, e0, s1, e1) = p.row(2);
        assert_eq!(s0.len(), 7);
        let d = p.get(2, 6);
        assert_eq!((s0[6], e0[6], s1[6], e1[6]), (d.s0, d.e0, d.s1, d.e1));
        // untouched cells and the padding tail stay the zero digit pair
        assert_eq!(p.get(0, 0), WeightDigits::default());
        let (rs0, ..) = p.raw_planes();
        assert_eq!(rs0.len(), 3 * 16);
        for r in 0..3 {
            assert!(rs0[r * 16 + 7..(r + 1) * 16].iter().all(|&v| v == 0));
        }
        // an aligned width gets no padding
        assert_eq!(DigitPlanes::new(2, 32).stride(), 32);
    }

    #[test]
    fn decompose_reconstructs_and_flags_frame_exits() {
        for v in [0.0f32, 1.0, -3.5, 114688.0, 2f32.powi(-16), 65504.0, -2f32.powi(-19)] {
            let t = decompose_x(v);
            assert!(t.fast, "{v} should be in-frame");
            assert_eq!(t.sig as f64 * 2f64.powi(t.exp), v as f64, "{v}");
            if t.sig != 0 {
                assert_eq!(t.sig & 1, 1, "significand must be odd for {v}");
            }
        }
        for v in [f32::NAN, f32::INFINITY, -0.0f32, 2f32.powi(-20), 3e7f32] {
            assert!(!decompose_x(v).fast, "{v} must take the fallback");
        }
        // the accumulator frame admits two more octaves (FP16 subnormals)
        assert!(decompose_acc(2f32.powi(-24)).fast);
        assert!(!decompose_acc(2f32.powi(-29)).fast);
    }

    #[test]
    fn frame_matches_hardware_mac_sim() {
        assert_eq!(FRAC_BITS, crate::hardware::mac_sim::FRAC_BITS);
    }

    #[test]
    fn digit_exponent_window_is_tight() {
        let mut lo = i32::MAX;
        let mut hi = i32::MIN;
        for bits in 0..=u8::MAX {
            let d = WeightDigits::of(FloatSd8(bits));
            for (s, e) in [(d.s0, d.e0 as i32), (d.s1, d.e1 as i32)] {
                if s != 0 {
                    lo = lo.min(e);
                    hi = hi.max(e);
                }
            }
        }
        assert_eq!((lo, hi), (W_EXP_MIN, W_EXP_MAX));
    }
}
