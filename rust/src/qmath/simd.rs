//! Runtime-dispatched SIMD execution of the forward-kernel spans —
//! the layer that turns PR 9's structure-of-arrays / register-tile
//! layout work into explicit `core::arch::x86_64` vector code, **bit
//! identical** to the scalar tiles on every input.
//!
//! ## Lane = stream: the bit-identity argument
//!
//! Both tiled kernels ([`vector::chain_span_t`](super::vector) and
//! [`shiftadd::sa_span_t`](super::shiftadd)) already advance `T`
//! *independent* per-stream accumulator chains in lockstep; the only
//! sharing across streams is the weight load. This module vectorizes
//! **across those streams**: each SIMD lane carries exactly one
//! stream's private accumulator chain and executes the *same operation
//! sequence* the scalar span runs for that stream —
//!
//! * decoded tier: the per-group f64 products and the left-to-right
//!   group sum run as element-wise `mulpd`/`addpd` (IEEE
//!   correctly-rounded, so each lane's f64 results equal the scalar
//!   ops bit-for-bit; **no FMA is ever emitted** — fusing would change
//!   the rounding); the one-per-group `Fp16::from_f64` rounding stays
//!   scalar per lane, on the extracted lane value;
//! * shift-add tier: the i64 fixed-point frame rides `psllq`/`paddq`.
//!   The scalar op `(s·sig) << (e_w + e_x + F)` splits into a per-lane
//!   pre-shift `sig << (e_x + F - 9)` (exact: `e_x ≥ −19` keeps the
//!   count ≥ 0, and the value stays under 2⁴⁰) and a **uniform-count**
//!   vector shift by `e_w + 9` (`e_w ≥ −9` keeps that count ≥ 0) —
//!   two left shifts compose exactly, digit signs are ±1 so the digit
//!   "multiply" is a vector add or subtract, and integer adds are
//!   order-exact, so each lane's i64 sum equals the scalar sum
//!   bit-for-bit. [`round_fixed_to_f16`] stays scalar per lane.
//!
//! Groups with any out-of-frame operand, sub-group tails, and the
//! `T = 1` spans run the scalar reference code unchanged.
//!
//! ## Dispatch
//!
//! [`IsaPath`] is the three-level dispatch: `Scalar` (portable
//! reference, the only path off x86_64), `Sse2` (the x86_64 baseline —
//! two f64 / two i64 lanes), `Avx2` (runtime-detected via
//! `is_x86_feature_detected!` — four lanes). [`IsaPath::detect`]
//! picks the widest supported path once per process (cached);
//! `--kernel-isa {scalar,sse2,avx2}` on train/serve/eval forces one,
//! erroring descriptively on unknown or host-unsupported values. The
//! selected path is a per-matrix field beside [`KernelTier`]
//! (`QMatrix::set_kernel_isa`), recorded in the kernel profiler rows,
//! the `serve_start`/`serve_end` trace lines, and the `BENCH_*.json`
//! kernel rows. Parity across paths is pinned by
//! `tests/shiftadd_equivalence.rs` and the unit sweeps here and in
//! `vector.rs`.

use anyhow::{bail, Result};

use super::shiftadd::{sa_span_t, XTerm};
use super::vector::chain_span_t;

#[cfg(target_arch = "x86_64")]
use super::mac::MAC_GROUP;
#[cfg(target_arch = "x86_64")]
use super::shiftadd::{decompose_acc, group_sa, FRAC_BITS};
#[cfg(target_arch = "x86_64")]
use crate::formats::Fp16;
#[cfg(target_arch = "x86_64")]
use crate::hardware::mac_sim::round_fixed_to_f16;

/// Which instruction-set path the forward-kernel spans execute on.
/// A per-matrix runtime switch beside [`KernelTier`](super::KernelTier)
/// — never checkpointed, bit-identical across every path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaPath {
    /// portable scalar reference (the only path off x86_64)
    Scalar,
    /// x86_64 baseline vectors: 2 × f64 / 2 × i64 lanes
    Sse2,
    /// runtime-detected 256-bit vectors: 4 × f64 / 4 × i64 lanes
    Avx2,
}

impl Default for IsaPath {
    /// The widest host-supported path — [`IsaPath::detect`].
    fn default() -> Self {
        IsaPath::detect()
    }
}

impl IsaPath {
    /// Parse a `--kernel-isa` value. `auto` selects [`Self::detect`];
    /// explicit paths the host cannot execute are refused here (at CLI
    /// time), not deep in a kernel.
    pub fn parse(s: &str) -> Result<IsaPath> {
        let isa = match s {
            "auto" => return Ok(IsaPath::detect()),
            "scalar" => IsaPath::Scalar,
            "sse2" => IsaPath::Sse2,
            "avx2" => IsaPath::Avx2,
            other => bail!("unknown kernel isa {other:?} (expected scalar|sse2|avx2|auto)"),
        };
        if !isa.available() {
            bail!(
                "kernel isa {:?} is not supported by this host cpu \
                 (available: {})",
                s,
                IsaPath::detect().name()
            );
        }
        Ok(isa)
    }

    pub fn name(self) -> &'static str {
        match self {
            IsaPath::Scalar => "scalar",
            IsaPath::Sse2 => "sse2",
            IsaPath::Avx2 => "avx2",
        }
    }

    /// Stable small-int encoding for telemetry gauges and profile keys
    /// (0 = scalar, 1 = sse2, 2 = avx2).
    pub fn index(self) -> u8 {
        match self {
            IsaPath::Scalar => 0,
            IsaPath::Sse2 => 1,
            IsaPath::Avx2 => 2,
        }
    }

    /// Inverse of [`Self::index`] (telemetry decode).
    pub fn from_index(i: u8) -> IsaPath {
        match i {
            1 => IsaPath::Sse2,
            2 => IsaPath::Avx2,
            _ => IsaPath::Scalar,
        }
    }

    /// Can this host execute the path?
    pub fn available(self) -> bool {
        match self {
            IsaPath::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            IsaPath::Sse2 => true, // x86_64 baseline
            #[cfg(target_arch = "x86_64")]
            IsaPath::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }

    /// The widest host-supported path, detected once per process and
    /// cached — the startup default every `QMatrix` inherits.
    pub fn detect() -> IsaPath {
        use std::sync::OnceLock;
        static DETECTED: OnceLock<IsaPath> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            if IsaPath::Avx2.available() {
                IsaPath::Avx2
            } else if IsaPath::Sse2.available() {
                IsaPath::Sse2
            } else {
                IsaPath::Scalar
            }
        })
    }
}

/// ISA-dispatched [`chain_span_t`]: advance `T` decoded-tier FP16
/// accumulation chains over one group-aligned span. Falls back to the
/// scalar span when the path has no lane grouping for `T` (`T = 1`,
/// or any `T` off x86_64) — the scalar span *is* the per-lane op
/// sequence, so every arm returns identical bits.
#[inline]
pub(crate) fn chain_span_isa<const T: usize>(
    row: &[f32],
    xs: &[&[f32]; T],
    acc: [f32; T],
    isa: IsaPath,
) -> [f32; T] {
    #[cfg(target_arch = "x86_64")]
    {
        if T % 4 == 0 && isa == IsaPath::Avx2 {
            // SAFETY: avx2 presence was checked by IsaPath::available
            // before this path could be selected.
            return unsafe { chain_span_avx2::<T>(row, xs, acc) };
        }
        if T % 2 == 0 && matches!(isa, IsaPath::Sse2 | IsaPath::Avx2) {
            // SAFETY: sse2 is part of the x86_64 baseline.
            return unsafe { chain_span_sse2::<T>(row, xs, acc) };
        }
    }
    let _ = isa;
    chain_span_t::<T>(row, xs, acc)
}

/// ISA-dispatched [`sa_span_t`]: advance `T` shift-add chains over one
/// group-aligned span of the digit planes. Same fallback rule as
/// [`chain_span_isa`].
#[inline]
pub(crate) fn sa_span_isa<const T: usize>(
    planes: (&[i8], &[i8], &[i8], &[i8]),
    row: &[f32],
    xs: &[&[f32]; T],
    xts: &[&[XTerm]; T],
    acc: [f32; T],
    isa: IsaPath,
) -> [f32; T] {
    let (s0, e0, s1, e1) = planes;
    #[cfg(target_arch = "x86_64")]
    {
        if T % 4 == 0 && isa == IsaPath::Avx2 {
            // SAFETY: avx2 presence was checked by IsaPath::available.
            return unsafe { sa_span_avx2::<T>(s0, e0, s1, e1, row, xs, xts, acc) };
        }
        if T % 2 == 0 && matches!(isa, IsaPath::Sse2 | IsaPath::Avx2) {
            // SAFETY: sse2 is part of the x86_64 baseline.
            return unsafe { sa_span_sse2::<T>(s0, e0, s1, e1, row, xs, xts, acc) };
        }
    }
    let _ = isa;
    sa_span_t::<T>(s0, e0, s1, e1, row, xs, xts, acc)
}

/// Shift-add pre-shift bias: the per-lane pre-shift count is
/// `e_x + FRAC_BITS − SA_PRESHIFT` and the uniform vector count is
/// `e_w + SA_PRESHIFT`. 9 is the unique split keeping both counts
/// non-negative for every in-frame operand (`e_x ≥ −19`, `e_w ≥ −9`).
#[cfg(target_arch = "x86_64")]
const SA_PRESHIFT: i32 = 9;

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;
    use core::arch::x86_64::*;

    /// Decoded-tier span, 2 f64 lanes per vector.
    ///
    /// # Safety
    /// Requires SSE2 (always present on x86_64).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn chain_span_sse2<const T: usize>(
        row: &[f32],
        xs: &[&[f32]; T],
        mut acc: [f32; T],
    ) -> [f32; T] {
        debug_assert_eq!(T % 2, 0);
        let n = row.len();
        let mut c = 0;
        while c + MAC_GROUP <= n {
            let w0 = _mm_set1_pd(row[c] as f64);
            let w1 = _mm_set1_pd(row[c + 1] as f64);
            let w2 = _mm_set1_pd(row[c + 2] as f64);
            let w3 = _mm_set1_pd(row[c + 3] as f64);
            let mut t = 0;
            while t + 2 <= T {
                let (xa, xb) = (xs[t], xs[t + 1]);
                let x0 = _mm_set_pd(xb[c] as f64, xa[c] as f64);
                let x1 = _mm_set_pd(xb[c + 1] as f64, xa[c + 1] as f64);
                let x2 = _mm_set_pd(xb[c + 2] as f64, xa[c + 2] as f64);
                let x3 = _mm_set_pd(xb[c + 3] as f64, xa[c + 3] as f64);
                // per lane: (((x0·w0) + x1·w1) + x2·w2) + x3·w3 — the
                // scalar span's exact left-to-right f64 tree, no FMA
                let g = _mm_add_pd(
                    _mm_add_pd(
                        _mm_add_pd(_mm_mul_pd(x0, w0), _mm_mul_pd(x1, w1)),
                        _mm_mul_pd(x2, w2),
                    ),
                    _mm_mul_pd(x3, w3),
                );
                let s = _mm_add_pd(_mm_set_pd(acc[t + 1] as f64, acc[t] as f64), g);
                // the one-per-group FP16 rounding is scalar per lane
                acc[t] = Fp16::from_f64(_mm_cvtsd_f64(s)).to_f32();
                acc[t + 1] = Fp16::from_f64(_mm_cvtsd_f64(_mm_unpackhi_pd(s, s))).to_f32();
                t += 2;
            }
            c += MAC_GROUP;
        }
        chain_tail::<T>(row, xs, &mut acc, c);
        acc
    }

    /// Decoded-tier span, 4 f64 lanes per vector.
    ///
    /// # Safety
    /// Requires AVX2 (runtime-detected before dispatch).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn chain_span_avx2<const T: usize>(
        row: &[f32],
        xs: &[&[f32]; T],
        mut acc: [f32; T],
    ) -> [f32; T] {
        debug_assert_eq!(T % 4, 0);
        let n = row.len();
        let mut c = 0;
        while c + MAC_GROUP <= n {
            let w0 = _mm256_set1_pd(row[c] as f64);
            let w1 = _mm256_set1_pd(row[c + 1] as f64);
            let w2 = _mm256_set1_pd(row[c + 2] as f64);
            let w3 = _mm256_set1_pd(row[c + 3] as f64);
            let mut t = 0;
            while t + 4 <= T {
                let (xa, xb, xc, xd) = (xs[t], xs[t + 1], xs[t + 2], xs[t + 3]);
                let x0 =
                    _mm256_set_pd(xd[c] as f64, xc[c] as f64, xb[c] as f64, xa[c] as f64);
                let x1 = _mm256_set_pd(
                    xd[c + 1] as f64,
                    xc[c + 1] as f64,
                    xb[c + 1] as f64,
                    xa[c + 1] as f64,
                );
                let x2 = _mm256_set_pd(
                    xd[c + 2] as f64,
                    xc[c + 2] as f64,
                    xb[c + 2] as f64,
                    xa[c + 2] as f64,
                );
                let x3 = _mm256_set_pd(
                    xd[c + 3] as f64,
                    xc[c + 3] as f64,
                    xb[c + 3] as f64,
                    xa[c + 3] as f64,
                );
                let g = _mm256_add_pd(
                    _mm256_add_pd(
                        _mm256_add_pd(_mm256_mul_pd(x0, w0), _mm256_mul_pd(x1, w1)),
                        _mm256_mul_pd(x2, w2),
                    ),
                    _mm256_mul_pd(x3, w3),
                );
                let a = _mm256_set_pd(
                    acc[t + 3] as f64,
                    acc[t + 2] as f64,
                    acc[t + 1] as f64,
                    acc[t] as f64,
                );
                let s = _mm256_add_pd(a, g);
                let lo = _mm256_castpd256_pd128(s);
                let hi = _mm256_extractf128_pd(s, 1);
                acc[t] = Fp16::from_f64(_mm_cvtsd_f64(lo)).to_f32();
                acc[t + 1] = Fp16::from_f64(_mm_cvtsd_f64(_mm_unpackhi_pd(lo, lo))).to_f32();
                acc[t + 2] = Fp16::from_f64(_mm_cvtsd_f64(hi)).to_f32();
                acc[t + 3] = Fp16::from_f64(_mm_cvtsd_f64(_mm_unpackhi_pd(hi, hi))).to_f32();
                t += 4;
            }
            c += MAC_GROUP;
        }
        chain_tail::<T>(row, xs, &mut acc, c);
        acc
    }

    /// The sub-group tail, verbatim from the scalar span.
    #[inline]
    fn chain_tail<const T: usize>(row: &[f32], xs: &[&[f32]; T], acc: &mut [f32; T], c: usize) {
        let n = row.len();
        if c < n {
            for t in 0..T {
                let x = xs[t];
                let mut g = 0f64;
                for cc in c..n {
                    g += x[cc] as f64 * row[cc] as f64;
                }
                acc[t] = Fp16::from_f64(acc[t] as f64 + g).to_f32();
            }
        }
    }

    /// `sig << (e_x + FRAC_BITS − SA_PRESHIFT)` — the per-lane
    /// pre-shift. Exact for every in-frame operand: the count is
    /// ≥ 0 (`e_x ≥ −19`) and the shifted value stays below 2⁴⁰.
    #[inline]
    fn preshift(t: XTerm) -> i64 {
        t.sig << (t.exp + FRAC_BITS - SA_PRESHIFT)
    }

    /// Is every lane's group entirely inside the fixed-point frame?
    #[inline]
    fn group_all_fast<const T: usize>(accs: &[XTerm; T], xts: &[&[XTerm]; T], c: usize, hi: usize) -> bool {
        for t in 0..T {
            if !accs[t].fast {
                return false;
            }
            for x in &xts[t][c..hi] {
                if !x.fast {
                    return false;
                }
            }
        }
        true
    }

    /// Shift-add span, 2 i64 lanes per vector.
    ///
    /// # Safety
    /// Requires SSE2 (always present on x86_64).
    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn sa_span_sse2<const T: usize>(
        s0: &[i8],
        e0: &[i8],
        s1: &[i8],
        e1: &[i8],
        row: &[f32],
        xs: &[&[f32]; T],
        xts: &[&[XTerm]; T],
        mut acc: [f32; T],
    ) -> [f32; T] {
        debug_assert_eq!(T % 2, 0);
        let n = row.len();
        let mut c = 0;
        while c + MAC_GROUP <= n {
            let hi = c + MAC_GROUP;
            let accs: [XTerm; T] = std::array::from_fn(|t| decompose_acc(acc[t]));
            if group_all_fast::<T>(&accs, xts, c, hi) {
                let mut t = 0;
                while t + 2 <= T {
                    let mut sum = _mm_set_epi64x(
                        accs[t + 1].sig << (accs[t + 1].exp + FRAC_BITS),
                        accs[t].sig << (accs[t].exp + FRAC_BITS),
                    );
                    for i in c..hi {
                        if s0[i] == 0 && s1[i] == 0 {
                            continue;
                        }
                        // zero activations pre-shift to 0 — adding a
                        // zero contribution matches the scalar skip
                        let xsh =
                            _mm_set_epi64x(preshift(xts[t + 1][i]), preshift(xts[t][i]));
                        if s0[i] != 0 {
                            let cnt = _mm_cvtsi32_si128(e0[i] as i32 + SA_PRESHIFT);
                            let v = _mm_sll_epi64(xsh, cnt);
                            sum = if s0[i] > 0 {
                                _mm_add_epi64(sum, v)
                            } else {
                                _mm_sub_epi64(sum, v)
                            };
                        }
                        if s1[i] != 0 {
                            let cnt = _mm_cvtsi32_si128(e1[i] as i32 + SA_PRESHIFT);
                            let v = _mm_sll_epi64(xsh, cnt);
                            sum = if s1[i] > 0 {
                                _mm_add_epi64(sum, v)
                            } else {
                                _mm_sub_epi64(sum, v)
                            };
                        }
                    }
                    let lo = _mm_cvtsi128_si64(sum);
                    let hi64 = _mm_cvtsi128_si64(_mm_unpackhi_epi64(sum, sum));
                    acc[t] = round_fixed_to_f16(lo, FRAC_BITS as u32).to_f32();
                    acc[t + 1] = round_fixed_to_f16(hi64, FRAC_BITS as u32).to_f32();
                    t += 2;
                }
            } else {
                // any out-of-frame lane sends the whole group through
                // the scalar per-lane reference (group_sa dispatches
                // fast/fallback per lane exactly like sa_span_t)
                for t in 0..T {
                    acc[t] = group_sa(
                        acc[t],
                        &s0[c..hi],
                        &e0[c..hi],
                        &s1[c..hi],
                        &e1[c..hi],
                        &row[c..hi],
                        &xs[t][c..hi],
                        &xts[t][c..hi],
                    );
                }
            }
            c = hi;
        }
        if c < n {
            for t in 0..T {
                acc[t] = group_sa(
                    acc[t],
                    &s0[c..],
                    &e0[c..],
                    &s1[c..],
                    &e1[c..],
                    &row[c..],
                    &xs[t][c..],
                    &xts[t][c..],
                );
            }
        }
        acc
    }

    /// Shift-add span, 4 i64 lanes per vector.
    ///
    /// # Safety
    /// Requires AVX2 (runtime-detected before dispatch).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn sa_span_avx2<const T: usize>(
        s0: &[i8],
        e0: &[i8],
        s1: &[i8],
        e1: &[i8],
        row: &[f32],
        xs: &[&[f32]; T],
        xts: &[&[XTerm]; T],
        mut acc: [f32; T],
    ) -> [f32; T] {
        debug_assert_eq!(T % 4, 0);
        let n = row.len();
        let mut c = 0;
        while c + MAC_GROUP <= n {
            let hi = c + MAC_GROUP;
            let accs: [XTerm; T] = std::array::from_fn(|t| decompose_acc(acc[t]));
            if group_all_fast::<T>(&accs, xts, c, hi) {
                let mut t = 0;
                while t + 4 <= T {
                    let mut sum = _mm256_set_epi64x(
                        accs[t + 3].sig << (accs[t + 3].exp + FRAC_BITS),
                        accs[t + 2].sig << (accs[t + 2].exp + FRAC_BITS),
                        accs[t + 1].sig << (accs[t + 1].exp + FRAC_BITS),
                        accs[t].sig << (accs[t].exp + FRAC_BITS),
                    );
                    for i in c..hi {
                        if s0[i] == 0 && s1[i] == 0 {
                            continue;
                        }
                        let xsh = _mm256_set_epi64x(
                            preshift(xts[t + 3][i]),
                            preshift(xts[t + 2][i]),
                            preshift(xts[t + 1][i]),
                            preshift(xts[t][i]),
                        );
                        if s0[i] != 0 {
                            let cnt = _mm_cvtsi32_si128(e0[i] as i32 + SA_PRESHIFT);
                            let v = _mm256_sll_epi64(xsh, cnt);
                            sum = if s0[i] > 0 {
                                _mm256_add_epi64(sum, v)
                            } else {
                                _mm256_sub_epi64(sum, v)
                            };
                        }
                        if s1[i] != 0 {
                            let cnt = _mm_cvtsi32_si128(e1[i] as i32 + SA_PRESHIFT);
                            let v = _mm256_sll_epi64(xsh, cnt);
                            sum = if s1[i] > 0 {
                                _mm256_add_epi64(sum, v)
                            } else {
                                _mm256_sub_epi64(sum, v)
                            };
                        }
                    }
                    let lo = _mm256_castsi256_si128(sum);
                    let up = _mm256_extracti128_si256(sum, 1);
                    let l0 = _mm_cvtsi128_si64(lo);
                    let l1 = _mm_cvtsi128_si64(_mm_unpackhi_epi64(lo, lo));
                    let l2 = _mm_cvtsi128_si64(up);
                    let l3 = _mm_cvtsi128_si64(_mm_unpackhi_epi64(up, up));
                    acc[t] = round_fixed_to_f16(l0, FRAC_BITS as u32).to_f32();
                    acc[t + 1] = round_fixed_to_f16(l1, FRAC_BITS as u32).to_f32();
                    acc[t + 2] = round_fixed_to_f16(l2, FRAC_BITS as u32).to_f32();
                    acc[t + 3] = round_fixed_to_f16(l3, FRAC_BITS as u32).to_f32();
                    t += 4;
                }
            } else {
                for t in 0..T {
                    acc[t] = group_sa(
                        acc[t],
                        &s0[c..hi],
                        &e0[c..hi],
                        &s1[c..hi],
                        &e1[c..hi],
                        &row[c..hi],
                        &xs[t][c..hi],
                        &xts[t][c..hi],
                    );
                }
            }
            c = hi;
        }
        if c < n {
            for t in 0..T {
                acc[t] = group_sa(
                    acc[t],
                    &s0[c..],
                    &e0[c..],
                    &s1[c..],
                    &e1[c..],
                    &row[c..],
                    &xs[t][c..],
                    &xts[t][c..],
                );
            }
        }
        acc
    }
}

#[cfg(target_arch = "x86_64")]
use x86::{chain_span_avx2, chain_span_sse2, sa_span_avx2, sa_span_sse2};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_parses_names_and_indexes_round_trip() {
        for isa in [IsaPath::Scalar, IsaPath::Sse2, IsaPath::Avx2] {
            assert_eq!(IsaPath::from_index(isa.index()), isa);
            if isa.available() {
                assert_eq!(IsaPath::parse(isa.name()).unwrap(), isa);
            } else {
                let err = IsaPath::parse(isa.name()).unwrap_err().to_string();
                assert!(err.contains("not supported"), "got: {err}");
            }
        }
        let err = IsaPath::parse("neon").unwrap_err().to_string();
        assert!(err.contains("unknown kernel isa"), "got: {err}");
        assert!(err.contains("scalar|sse2|avx2"), "got: {err}");
        assert_eq!(IsaPath::parse("auto").unwrap(), IsaPath::detect());
    }

    #[test]
    fn detect_is_available_stable_and_the_default() {
        let d = IsaPath::detect();
        assert!(d.available());
        assert_eq!(IsaPath::detect(), d, "detection must be cached/stable");
        assert_eq!(IsaPath::default(), d);
        assert!(IsaPath::Scalar.available(), "scalar is always available");
        #[cfg(target_arch = "x86_64")]
        assert!(IsaPath::Sse2.available(), "sse2 is the x86_64 baseline");
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(d, IsaPath::Scalar);
    }

    #[test]
    fn spans_match_scalar_bit_for_bit_on_every_available_isa() {
        use crate::rng::SplitMix64;
        let mut rng = SplitMix64::new(77);
        // 11 cols: two full groups + a 3-wide tail
        let cols = 11usize;
        let row: Vec<f32> = (0..cols)
            .map(|_| crate::formats::FLOAT_SD8.quantize(rng.uniform(-1.0, 1.0)))
            .collect();
        let xs_data: Vec<Vec<f32>> = (0..8)
            .map(|_| {
                (0..cols).map(|_| crate::formats::round_f8(rng.uniform(-2.0, 2.0))).collect()
            })
            .collect();
        let xs: [&[f32]; 8] = std::array::from_fn(|t| xs_data[t].as_slice());
        let acc: [f32; 8] =
            std::array::from_fn(|t| crate::formats::round_f16(0.1 * t as f32 - 0.3));
        let want = chain_span_t::<8>(&row, &xs, acc);
        for isa in [IsaPath::Scalar, IsaPath::Sse2, IsaPath::Avx2] {
            if !isa.available() {
                continue;
            }
            let got = chain_span_isa::<8>(&row, &xs, acc, isa);
            for t in 0..8 {
                assert_eq!(
                    got[t].to_bits(),
                    want[t].to_bits(),
                    "{} lane {t}",
                    isa.name()
                );
            }
        }
    }
}
