//! The FloatSD8 × FP8 → FP16 multiply-accumulate (paper Fig. 8).
//!
//! Hardware semantics (five-stage pipeline, §V-A):
//!
//! 1. decode 4 FloatSD8 weights → ≤ 2 signed shifts each;
//! 2. generate ≤ 8 partial products (each = FP8 mantissa shifted);
//!    find the max exponent;
//! 3. align all partial products + the previous FP16 accumulator to the
//!    max exponent, add in a Wallace carry-save tree — **exactly**, no
//!    intermediate rounding;
//! 4./5. round + normalize the sum to FP16 once.
//!
//! [`mac_exact`] reproduces this: the product sum is computed exactly
//! (every term is a dyadic rational with few significant bits — f64
//! holds the whole sum of 8 products + accumulator without error) and
//! rounded to the binary16 grid once per 4-pair group. [`mac_serial`]
//! is the ablation alternative (round after every add) used by the
//! accumulation-boundary bench.

use crate::formats::{FloatSd8, Fp16, Fp8, FLOAT_SD8};

/// Accumulation discipline for a MAC group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MacMode {
    /// Exact Wallace-tree sum, single FP16 rounding per group (hardware).
    Exact,
    /// FP16 rounding after every individual add (strawman ablation).
    Serial,
}

/// Number of weight/input pairs one MAC consumes per cycle (Fig. 7:
/// "four FP8 inputs, four FloatSD8 weights … same IO bandwidth as an
/// FP32 MAC").
pub const MAC_GROUP: usize = 4;

/// One hardware MAC group: `round_f16(acc + Σ_i x_i · w_i)` with the
/// sum computed exactly (Wallace tree semantics).
///
/// Exactness argument: each product is (fp8 value) × (±2^a ± 2^b) — a
/// dyadic rational with ≤ 4 significant mantissa bits per partial
/// product; 8 partial products + an FP16 accumulator span < 52 bits
/// between the largest and smallest exponent in range, so an f64 sum is
/// exact. (The full bit-level datapath is replicated in
/// `hardware::mac_sim` and cross-checked against this function.)
pub fn mac_exact(acc: Fp16, xs: &[Fp8], ws: &[FloatSd8]) -> Fp16 {
    debug_assert_eq!(xs.len(), ws.len());
    debug_assert!(xs.len() <= MAC_GROUP);
    let mut sum = acc.to_f32() as f64;
    for (&x, &w) in xs.iter().zip(ws) {
        let xv = x.to_f32() as f64;
        for (s, e) in FLOAT_SD8.partial_products(w).iter() {
            sum += xv * s as f64 * 2f64.powi(e);
        }
    }
    // single correctly-rounded f64→f16 (Fig. 8 rounds once; going
    // through f32 would double-round)
    Fp16::from_f64(sum)
}

/// Ablation: FP16 rounding after *every* add (no carry-save tree).
pub fn mac_serial(acc: Fp16, xs: &[Fp8], ws: &[FloatSd8]) -> Fp16 {
    debug_assert_eq!(xs.len(), ws.len());
    let mut acc = acc;
    for (&x, &w) in xs.iter().zip(ws) {
        let xv = x.to_f32();
        for (s, e) in FLOAT_SD8.partial_products(w).iter() {
            let pp = xv * s as f32 * 2f32.powi(e); // exact: power-of-2 scale
            acc = acc.add(Fp16::from_f32(pp));
        }
    }
    acc
}

/// Dispatch by mode.
pub fn mac(mode: MacMode, acc: Fp16, xs: &[Fp8], ws: &[FloatSd8]) -> Fp16 {
    match mode {
        MacMode::Exact => mac_exact(acc, xs, ws),
        MacMode::Serial => mac_serial(acc, xs, ws),
    }
}

/// Full dot product driven in groups of [`MAC_GROUP`] (the PE inner
/// loop, Fig. 7): `round_f16` once per group, accumulator carried
/// between groups in FP16 — the paper's "FP16 additions suffice".
pub fn dot_fsd8_fp8(bias: Fp16, xs: &[Fp8], ws: &[FloatSd8], mode: MacMode) -> Fp16 {
    debug_assert_eq!(xs.len(), ws.len());
    let mut acc = bias;
    for (xc, wc) in xs.chunks(MAC_GROUP).zip(ws.chunks(MAC_GROUP)) {
        acc = mac(mode, acc, xc, wc);
    }
    acc
}

/// The count of partial products a weight vector generates — the
/// paper's complexity metric (§IV-C: ≤ 2 per weight vs 23+ for FP32).
pub fn partial_product_count(ws: &[FloatSd8]) -> usize {
    ws.iter()
        .map(|&w| FLOAT_SD8.partial_products(w).len as usize)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn rand_inputs(n: usize, seed: u64) -> (Vec<Fp8>, Vec<FloatSd8>) {
        let mut rng = SplitMix64::new(seed);
        let xs: Vec<Fp8> = (0..n)
            .map(|_| Fp8::from_f32((rng.next_f32() - 0.5) * 8.0))
            .collect();
        let ws: Vec<FloatSd8> = (0..n)
            .map(|_| FLOAT_SD8.encode((rng.next_f32() - 0.5) * 2.0))
            .collect();
        (xs, ws)
    }

    #[test]
    fn single_pair_equals_plain_multiply() {
        let (xs, ws) = rand_inputs(64, 1);
        for (&x, &w) in xs.iter().zip(&ws) {
            let got = mac_exact(Fp16::ZERO, &[x], &[w]);
            let want = Fp16::from_f32(x.to_f32() * w.to_f32());
            assert_eq!(got.0, want.0, "x={} w={}", x.to_f32(), w.to_f32());
        }
    }

    #[test]
    fn group_sum_exactness() {
        // The exact mode must equal an f64 reference sum rounded once.
        let (xs, ws) = rand_inputs(4, 2);
        let acc = Fp16::from_f32(0.375);
        let got = mac_exact(acc, &xs, &ws);
        let want: f64 = acc.to_f32() as f64
            + xs.iter()
                .zip(&ws)
                .map(|(x, w)| x.to_f32() as f64 * w.to_f32() as f64)
                .sum::<f64>();
        assert_eq!(got.0, Fp16::from_f32(want as f32).0);
    }

    #[test]
    fn dot_is_group_serial() {
        let (xs, ws) = rand_inputs(16, 3);
        let mut acc = Fp16::ZERO;
        for i in (0..16).step_by(4) {
            acc = mac_exact(acc, &xs[i..i + 4], &ws[i..i + 4]);
        }
        assert_eq!(dot_fsd8_fp8(Fp16::ZERO, &xs, &ws, MacMode::Exact).0, acc.0);
    }

    #[test]
    fn partial_products_at_most_two_per_weight() {
        let (_, ws) = rand_inputs(256, 4);
        assert!(partial_product_count(&ws) <= 2 * ws.len());
    }

    #[test]
    fn serial_and_exact_agree_on_disjoint_magnitudes() {
        // When all terms have the same sign & similar magnitude the two
        // disciplines agree (no cancellation, no sticky-bit effects at
        // f16 precision for tiny sums of 2-3-bit mantissas)... assert on
        // a crafted case rather than in general.
        let xs = vec![Fp8::from_f32(1.0); 4];
        let ws = vec![FLOAT_SD8.encode(0.5); 4];
        let a = mac_exact(Fp16::ZERO, &xs, &ws);
        let b = mac_serial(Fp16::ZERO, &xs, &ws);
        assert_eq!(a.0, b.0);
        assert_eq!(a.to_f32(), 2.0);
    }

    #[test]
    fn modes_can_differ_under_cancellation() {
        // Documented difference: serial rounding loses low bits that the
        // exact tree keeps. Find one case (it exists) to pin behaviour.
        let mut found = false;
        let mut rng = SplitMix64::new(9);
        for _ in 0..20_000 {
            let xs: Vec<Fp8> = (0..4)
                .map(|_| Fp8::from_f32((rng.next_f32() - 0.5) * 2048.0))
                .collect();
            let ws: Vec<FloatSd8> = (0..4)
                .map(|_| FLOAT_SD8.encode((rng.next_f32() - 0.5) * 4.0))
                .collect();
            if mac_exact(Fp16::ZERO, &xs, &ws).0 != mac_serial(Fp16::ZERO, &xs, &ws).0 {
                found = true;
                break;
            }
        }
        assert!(found, "expected at least one divergence in 20k trials");
    }
}
