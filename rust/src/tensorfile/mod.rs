//! `.tensors` binary interchange (rust side of
//! `python/compile/tensorio.py`) plus a minimal JSON value parser for
//! `artifacts/manifest.json` (no serde in the offline vendor set).
//!
//! Format:
//! ```text
//! magic b"TSF1" | u32 n | n × { u16 name_len, name,
//!                               u8 dtype (0=f32, 1=i32), u8 ndim,
//!                               u32 dims[ndim], raw LE data }
//! ```

pub mod json;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"TSF1";

/// Element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A named dense tensor (C-order).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// raw little-endian bytes, len = product(shape) * 4
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn from_f32(name: &str, shape: &[usize], values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.to_string(), dtype: DType::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(name: &str, shape: &[usize], values: &[i32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { name: name.to_string(), dtype: DType::I32, shape: shape.to_vec(), data }
    }

    /// Convenience: a 0-d f32 tensor (checkpoint metadata fields like
    /// the trainer's `meta/steps`).
    pub fn scalar_f32(name: &str, value: f32) -> Self {
        Tensor::from_f32(name, &[], &[value])
    }

    /// A UTF-8 text payload as a 1-d i32 tensor of byte values — how
    /// checkpoints carry structured metadata (the task subsystem's
    /// `meta/task_cfg` JSON blob) without widening the dtype set.
    pub fn from_text(name: &str, text: &str) -> Self {
        let vals: Vec<i32> = text.bytes().map(i32::from).collect();
        Tensor::from_i32(name, &[vals.len()], &vals)
    }

    /// Decode a tensor written by [`Self::from_text`].
    pub fn as_text(&self) -> Result<String> {
        let vals = self.as_i32()?;
        let mut bytes = Vec::with_capacity(vals.len());
        for v in vals {
            let b = u8::try_from(v).map_err(|_| {
                anyhow::anyhow!("{}: value {v} is not a byte — not a text tensor", self.name)
            })?;
            bytes.push(b);
        }
        String::from_utf8(bytes).with_context(|| format!("{}: text tensor utf8", self.name))
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32 (zero-copy on little-endian hosts would need unsafe;
    /// we decode — these files are small).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("{}: not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Read a `.tensors` file.
pub fn read_tensors(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let n = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u16(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name utf8")?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let dtype = match hdr[0] {
            0 => DType::F32,
            1 => DType::I32,
            d => bail!("{name}: unknown dtype {d}"),
        };
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let count: usize = shape.iter().product();
        let mut data = vec![0u8; count * 4];
        f.read_exact(&mut data)?;
        out.push(Tensor { name, dtype, shape, data });
    }
    Ok(out)
}

/// Write a `.tensors` file (checkpoints, generated datasets).
pub fn write_tensors(path: impl AsRef<Path>, tensors: &[Tensor]) -> Result<()> {
    let path = path.as_ref();
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let nb = t.name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[if t.dtype == DType::F32 { 0 } else { 1 }, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&t.data)?;
    }
    Ok(())
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("fsd_tensors_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.tensors");
        let tensors = vec![
            Tensor::from_f32("a", &[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            Tensor::from_i32("b/x", &[4], &[-1, 0, 7, i32::MAX]),
            Tensor::from_f32("scalar", &[], &[3.5]),
            Tensor::from_f32("empty", &[0], &[]),
        ];
        write_tensors(&p, &tensors).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back.len(), 4);
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.dtype, b.dtype);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        assert_eq!(back[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(back[1].as_i32().unwrap(), vec![-1, 0, 7, i32::MAX]);
    }

    #[test]
    fn scalar_round_trip() {
        let dir = std::env::temp_dir().join("fsd_tensors_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("scalar.tensors");
        write_tensors(&p, &[Tensor::scalar_f32("meta/steps", 42.0)]).unwrap();
        let back = read_tensors(&p).unwrap();
        assert_eq!(back[0].shape, Vec::<usize>::new());
        assert_eq!(back[0].as_f32().unwrap(), vec![42.0]);
    }

    #[test]
    fn text_tensor_round_trip() {
        let t = Tensor::from_text("meta/task_cfg", r#"{"task":"pos","vocab":96}"#);
        assert_eq!(t.dtype, DType::I32);
        assert_eq!(t.as_text().unwrap(), r#"{"task":"pos","vocab":96}"#);
        // non-byte values must be rejected, not silently truncated
        let bad = Tensor::from_i32("x", &[2], &[65, 300]);
        assert!(bad.as_text().is_err());
        let neg = Tensor::from_i32("x", &[1], &[-1]);
        assert!(neg.as_text().is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("fsd_tensors_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.tensors");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(read_tensors(&p).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::from_f32("x", &[1], &[1.0]);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
