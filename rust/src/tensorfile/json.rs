//! Minimal JSON parser + writer (manifest.json, metric logs). Supports
//! the full JSON value grammar minus exotic number forms; plenty for
//! our machine-generated files. No serde offline — built from scratch.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (deterministic: object keys are BTreeMap
/// iteration order). `Json::to_string()` comes from this impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => bail!("bad number {s:?} at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            // (surrogate pairs unsupported: our writers
                            // never emit them)
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected , or ] got {other:?} at {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} got {other:?} at {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "format_version": 1,
            "tasks": {"lm": {"batch": 32, "x_shape": [32], "lr": 2.0,
                             "clip_norm": 0.25, "metric": "perplexity"}},
            "artifacts": {"lm_fp32": {"train": "lm_fp32.train.hlo.txt",
                                      "pallas": false}},
            "sd8_values": [-4.5, 0, 4.5],
            "none": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format_version").unwrap().as_usize(), Some(1));
        let lm = j.get("tasks").unwrap().get("lm").unwrap();
        assert_eq!(lm.get("batch").unwrap().as_usize(), Some(32));
        assert_eq!(lm.get("metric").unwrap().as_str(), Some("perplexity"));
        assert_eq!(lm.get("clip_norm").unwrap().as_f64(), Some(0.25));
        assert_eq!(
            j.get("sd8_values").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(j.get("none").unwrap().is_null());
    }

    #[test]
    fn round_trips_through_writer() {
        let doc = r#"{"a": [1, 2.5, "x\n\"y\"", true, null], "b": {"c": -3}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "{'a':1}", "nul", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
    }
}
