//! Typed configuration: the artifact manifest written by `aot.py`
//! (shapes, state layouts, scheme table) and the experiment presets —
//! our scaled version of the paper's Table III.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensorfile::json::Json;

/// Per-task shape/hyperparameter info from the manifest.
#[derive(Clone, Debug)]
pub struct TaskInfo {
    pub name: String,
    pub init_file: String,
    pub n_state: usize,
    pub state_names: Vec<String>,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub vocab: usize,
    pub vocab_tgt: usize,
    pub n_classes: usize,
    pub optimizer: String,
    pub lr: f64,
    /// 'accuracy' | 'perplexity'
    pub metric: String,
}

/// One AOT artifact (task × scheme).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub task: String,
    pub scheme: String,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub pallas: bool,
}

/// The precision-scheme table (paper Tables II/VI as data).
#[derive(Clone, Debug)]
pub struct SchemeInfo {
    pub weights: String,
    pub activations: String,
    pub first_layer_acts: String,
    pub last_layer_acts: String,
    pub gradients: String,
    pub master: String,
    pub sigmoid: String,
    pub accum: String,
    pub loss_scale: f64,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seed: u64,
    pub tasks: BTreeMap<String, TaskInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub schemes: BTreeMap<String, SchemeInfo>,
    pub sd8_values: Vec<f32>,
}

fn jstr(j: &Json, k: &str) -> String {
    j.get(k).and_then(Json::as_str).unwrap_or_default().to_string()
}

fn jnum(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(Json::as_f64).unwrap_or(0.0)
}

fn jshape(j: &Json, k: &str) -> Vec<usize> {
    j.get(k)
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parse manifest.json")?;

        let mut tasks = BTreeMap::new();
        if let Some(tmap) = j.get("tasks").and_then(Json::as_obj) {
            for (name, t) in tmap {
                tasks.insert(
                    name.clone(),
                    TaskInfo {
                        name: name.clone(),
                        init_file: jstr(t, "init"),
                        n_state: t.get("n_state").and_then(Json::as_usize).unwrap_or(0),
                        state_names: t
                            .get("state_names")
                            .and_then(Json::as_arr)
                            .map(|a| {
                                a.iter().filter_map(|v| v.as_str().map(String::from)).collect()
                            })
                            .unwrap_or_default(),
                        batch: t.get("batch").and_then(Json::as_usize).unwrap_or(0),
                        x_shape: jshape(t, "x_shape"),
                        y_shape: jshape(t, "y_shape"),
                        vocab: t.get("vocab").and_then(Json::as_usize).unwrap_or(0),
                        vocab_tgt: t.get("vocab_tgt").and_then(Json::as_usize).unwrap_or(0),
                        n_classes: t.get("n_classes").and_then(Json::as_usize).unwrap_or(0),
                        optimizer: jstr(t, "optimizer"),
                        lr: jnum(t, "lr"),
                        metric: jstr(t, "metric"),
                    },
                );
            }
        }
        // fail fast on metric typos — `StepMetrics::named` panics on an
        // unknown name, which would otherwise surface only after a full
        // training run, at first eval
        for (name, t) in &tasks {
            if t.metric != "accuracy" && t.metric != "perplexity" {
                bail!(
                    "task {name}: unknown metric {:?} (expected \"accuracy\" or \"perplexity\")",
                    t.metric
                );
            }
        }

        let mut artifacts = BTreeMap::new();
        if let Some(amap) = j.get("artifacts").and_then(Json::as_obj) {
            for (name, a) in amap {
                artifacts.insert(
                    name.clone(),
                    ArtifactInfo {
                        name: name.clone(),
                        task: jstr(a, "task"),
                        scheme: jstr(a, "scheme"),
                        train_hlo: jstr(a, "train"),
                        eval_hlo: jstr(a, "eval"),
                        pallas: matches!(a.get("pallas"), Some(Json::Bool(true))),
                    },
                );
            }
        }

        let mut schemes = BTreeMap::new();
        if let Some(smap) = j.get("schemes").and_then(Json::as_obj) {
            for (name, s) in smap {
                schemes.insert(
                    name.clone(),
                    SchemeInfo {
                        weights: jstr(s, "weights"),
                        activations: jstr(s, "activations"),
                        first_layer_acts: jstr(s, "first_layer_acts"),
                        last_layer_acts: jstr(s, "last_layer_acts"),
                        gradients: jstr(s, "gradients"),
                        master: jstr(s, "master"),
                        sigmoid: jstr(s, "sigmoid"),
                        accum: jstr(s, "accum"),
                        loss_scale: jnum(s, "loss_scale"),
                    },
                );
            }
        }

        let sd8_values = j
            .get("sd8_values")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|f| f as f32)).collect())
            .unwrap_or_default();

        Ok(Manifest {
            dir,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            tasks,
            artifacts,
            schemes,
            sd8_values,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name}; have {:?}", self.artifacts.keys()))
    }

    pub fn task(&self, name: &str) -> Result<&TaskInfo> {
        self.tasks.get(name).with_context(|| format!("unknown task {name}"))
    }
}

/// Our Table III: training lengths per task, scaled to this testbed
/// (the paper trained 30-50 epochs on real corpora; we train
/// `epochs × steps_per_epoch` batches of synthetic data).
#[derive(Clone, Copy, Debug)]
pub struct TrainPreset {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub eval_batches: usize,
}

pub fn preset_for(task: &str) -> TrainPreset {
    match task {
        "pos" => TrainPreset { epochs: 12, steps_per_epoch: 40, eval_batches: 10 },
        "nli" => TrainPreset { epochs: 12, steps_per_epoch: 40, eval_batches: 10 },
        "mt" => TrainPreset { epochs: 12, steps_per_epoch: 40, eval_batches: 10 },
        "lm" => TrainPreset { epochs: 12, steps_per_epoch: 50, eval_batches: 10 },
        "tiny" => TrainPreset { epochs: 5, steps_per_epoch: 30, eval_batches: 5 },
        _ => TrainPreset { epochs: 10, steps_per_epoch: 40, eval_batches: 10 },
    }
}

/// Scale every preset down (smoke tests / CI) by an integer factor.
pub fn scaled(p: TrainPreset, div: usize) -> TrainPreset {
    TrainPreset {
        epochs: (p.epochs / div).max(1),
        steps_per_epoch: (p.steps_per_epoch / div).max(2),
        eval_batches: (p.eval_batches / div).max(2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_if_present() {
        // integration-style: only runs when artifacts exist
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.tasks.contains_key("tiny"));
            assert!(m.artifacts.contains_key("tiny_fp32"));
            assert_eq!(m.sd8_values.len(), 129);
            let t = m.task("tiny").unwrap();
            assert_eq!(t.batch, 8);
            assert!(t.n_state > 0);
        }
    }

    #[test]
    fn presets_are_positive() {
        for t in ["pos", "nli", "mt", "lm", "tiny", "unknown"] {
            let p = preset_for(t);
            assert!(p.epochs > 0 && p.steps_per_epoch > 0 && p.eval_batches > 0);
        }
        let s = scaled(preset_for("lm"), 10);
        assert!(s.epochs >= 1 && s.steps_per_epoch >= 2);
    }
}
