//! The paper's update discipline (§III-B / §IV-C) as an optimizer:
//! FP16 master copies for every parameter, SGD with momentum on the
//! masters, FloatSD8 re-encoding of the live weights after each step,
//! and dynamic loss scaling around the FP8 gradient grid.
//!
//! Momentum buffers stay in f32 — the paper (like its L2 mirror in
//! `python/compile/optim.py`) quantizes only the master copy, not the
//! optimizer state.

use crate::formats::round_f16;
use crate::lstm::QLstmStack;
use crate::qmath::grad::{grads_overflow, quantize_fp8_inplace};
use crate::rng::SplitMix64;

use super::backward::StackGrads;

/// One loss-scale adjustment, returned so the trainers can surface it
/// (training logs + `--trace` `loss_scale` events).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleEvent {
    /// the scaled gradients overflowed FP8: scale halved, step skipped
    Backoff { from: f32, to: f32 },
    /// a full growth interval of clean steps: scale doubled
    Growth { from: f32, to: f32 },
}

/// Dynamic loss scaler: halve on overflow (skip the step), double
/// after `growth_interval` consecutive good steps.
#[derive(Clone, Debug)]
pub struct LossScaler {
    pub scale: f32,
    pub growth_interval: u32,
    pub min_scale: f32,
    pub max_scale: f32,
    good: u32,
    /// steps skipped because the scaled gradients overflowed FP8
    pub skipped: u64,
}

impl LossScaler {
    pub fn new(init: f32) -> Self {
        LossScaler {
            scale: init,
            growth_interval: 250,
            min_scale: 1.0,
            max_scale: 32768.0,
            good: 0,
            skipped: 0,
        }
    }

    /// The gradients overflowed: skip this step and back off.
    pub fn on_overflow(&mut self) -> ScaleEvent {
        let from = self.scale;
        self.scale = (self.scale * 0.5).max(self.min_scale);
        self.good = 0;
        self.skipped += 1;
        ScaleEvent::Backoff { from, to: self.scale }
    }

    /// A step was applied cleanly; grow the scale periodically.
    /// `Some` when this step crossed the growth interval.
    pub fn on_good_step(&mut self) -> Option<ScaleEvent> {
        self.good += 1;
        if self.good >= self.growth_interval {
            let from = self.scale;
            self.scale = (self.scale * 2.0).min(self.max_scale);
            self.good = 0;
            return Some(ScaleEvent::Growth { from, to: self.scale });
        }
        None
    }
}

/// FP16 master copy + momentum buffer of one quantized LSTM cell, in
/// the QMatrix (`[out][in]` row-major) layout.
pub struct MasterCell {
    pub wx: Vec<f32>,
    pub wh: Vec<f32>,
    pub b: Vec<f32>,
    vwx: Vec<f32>,
    vwh: Vec<f32>,
    vb: Vec<f32>,
}

impl MasterCell {
    pub fn new(wx: Vec<f32>, wh: Vec<f32>, b: Vec<f32>) -> Self {
        let (nx, nh, nb) = (wx.len(), wh.len(), b.len());
        MasterCell { wx, wh, b, vwx: vec![0.0; nx], vwh: vec![0.0; nh], vb: vec![0.0; nb] }
    }
}

/// FP16 master copies + momentum state for a whole stack. The live
/// [`QLstmStack`] is the quantized *view* of these masters; after
/// every applied step [`MasterStack::apply`] re-encodes the view.
pub struct MasterStack {
    pub emb: Vec<f32>,
    pub layers: Vec<MasterCell>,
    /// dense head weights in QMatrix layout `[n_out*H_top]`
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    v_emb: Vec<f32>,
    v_head_w: Vec<f32>,
    v_head_b: Vec<f32>,
    /// scratch for per-tensor deltas
    delta: Vec<f32>,
}

/// SGD-momentum step on one tensor: `v = μ·v + g`, returns `-lr·v`
/// into `delta`.
fn momentum_delta(v: &mut [f32], g: &[f32], lr: f32, mu: f32, delta: &mut Vec<f32>) {
    delta.clear();
    delta.reserve(g.len());
    for (vk, &gk) in v.iter_mut().zip(g) {
        *vk = mu * *vk + gk;
        delta.push(-lr * *vk);
    }
}

impl MasterStack {
    /// Deterministically initialize masters (FP16 grid) and the
    /// matching quantized stack for a fresh LM-shaped training run
    /// (head width = vocab). Bit-identical to
    /// [`Self::init_with_stack_dims`] with `n_out = vocab`.
    pub fn init_with_stack(
        vocab: usize,
        dim: usize,
        hidden: usize,
        n_layers: usize,
        seed: u64,
    ) -> (Self, QLstmStack) {
        Self::init_with_stack_dims(vocab, dim, hidden, n_layers, vocab, seed)
    }

    /// [`Self::init_with_stack`] generalized over the dense-head width
    /// — the task heads (`tasks::{pos,nli,mt}`) classify into
    /// `n_out ≠ vocab` classes (tags, NLI labels, target vocabulary,
    /// or a vestigial 1-wide head for the loss-less seq2seq encoder).
    pub fn init_with_stack_dims(
        vocab: usize,
        dim: usize,
        hidden: usize,
        n_layers: usize,
        n_out: usize,
        seed: u64,
    ) -> (Self, QLstmStack) {
        use crate::lstm::cell::QLstmCell;
        use crate::lstm::model::{Dense, Embedding, QLstmLayer};
        use crate::qmath::vector::QMatrix;

        let mut rng = SplitMix64::new(seed);
        let f16 = |v: f32| round_f16(v);
        let emb: Vec<f32> = (0..vocab * dim).map(|_| f16(rng.normal() * 0.1)).collect();

        let mut masters = Vec::with_capacity(n_layers);
        let mut layers = Vec::with_capacity(n_layers);
        let mut in_dim = dim;
        for _ in 0..n_layers.max(1) {
            // generated directly in the QMatrix layout [4H][in]
            let wx: Vec<f32> =
                (0..4 * hidden * in_dim).map(|_| f16(rng.uniform(-0.3, 0.3))).collect();
            let wh: Vec<f32> =
                (0..4 * hidden * hidden).map(|_| f16(rng.uniform(-0.3, 0.3))).collect();
            let b: Vec<f32> = (0..4 * hidden).map(|_| f16(rng.uniform(-0.1, 0.1))).collect();
            layers.push(QLstmLayer {
                fwd: QLstmCell {
                    input_dim: in_dim,
                    hidden,
                    wx: QMatrix::from_f32(4 * hidden, in_dim, &wx),
                    wh: QMatrix::from_f32(4 * hidden, hidden, &wh),
                    bias: b.clone(),
                },
                bwd: None,
            });
            masters.push(MasterCell::new(wx, wh, b));
            in_dim = hidden;
        }

        let head_w: Vec<f32> =
            (0..n_out * in_dim).map(|_| f16(rng.uniform(-0.3, 0.3))).collect();
        let head_b: Vec<f32> = (0..n_out).map(|_| f16(rng.uniform(-0.1, 0.1))).collect();
        let stack = QLstmStack {
            embed: Embedding { vocab, dim, table: emb.clone() },
            layers,
            head: Dense {
                w: QMatrix::from_f32(n_out, in_dim, &head_w),
                bias: head_b.clone(),
            },
        };
        let ms = MasterStack {
            v_emb: vec![0.0; emb.len()],
            v_head_w: vec![0.0; head_w.len()],
            v_head_b: vec![0.0; head_b.len()],
            emb,
            layers: masters,
            head_w,
            head_b,
            delta: Vec::new(),
        };
        (ms, stack)
    }

    /// Rebuild a master stack from checkpointed FP16 master tensors
    /// (all in the QMatrix `[out][in]` row-major layout), with fresh
    /// zero momentum state — resuming from a `.tensors` checkpoint
    /// restores the weights, not the optimizer velocity.
    pub fn from_parts(
        emb: Vec<f32>,
        layers: Vec<MasterCell>,
        head_w: Vec<f32>,
        head_b: Vec<f32>,
    ) -> Self {
        MasterStack {
            v_emb: vec![0.0; emb.len()],
            v_head_w: vec![0.0; head_w.len()],
            v_head_b: vec![0.0; head_b.len()],
            emb,
            layers,
            head_w,
            head_b,
            delta: Vec::new(),
        }
    }

    /// Apply one SGD-momentum step to every parameter: FloatSD8
    /// tensors go through the master-update/re-encode rule
    /// ([`QMatrix::apply_master_update`](crate::qmath::vector::QMatrix::apply_master_update));
    /// FP16-native tensors (biases, embedding) update their master
    /// directly and copy it into the live stack. `grads` must already
    /// be unscaled.
    pub fn apply(&mut self, stack: &mut QLstmStack, grads: &StackGrads, lr: f32, mu: f32) {
        assert_eq!(stack.layers.len(), self.layers.len());
        for (l, m) in self.layers.iter_mut().enumerate() {
            let cell = &mut stack.layers[l].fwd;
            let g = &grads.layers[l];
            momentum_delta(&mut m.vwx, &g.dwx, lr, mu, &mut self.delta);
            cell.wx.apply_master_update(&mut m.wx, &self.delta);
            momentum_delta(&mut m.vwh, &g.dwh, lr, mu, &mut self.delta);
            cell.wh.apply_master_update(&mut m.wh, &self.delta);
            momentum_delta(&mut m.vb, &g.db, lr, mu, &mut self.delta);
            for (k, d) in self.delta.iter().enumerate() {
                m.b[k] = round_f16(m.b[k] + d);
            }
            cell.bias.copy_from_slice(&m.b);
        }
        momentum_delta(&mut self.v_head_w, &grads.head_w, lr, mu, &mut self.delta);
        stack.head.w.apply_master_update(&mut self.head_w, &self.delta);
        momentum_delta(&mut self.v_head_b, &grads.head_b, lr, mu, &mut self.delta);
        for (k, d) in self.delta.iter().enumerate() {
            self.head_b[k] = round_f16(self.head_b[k] + d);
        }
        stack.head.bias.copy_from_slice(&self.head_b);
        momentum_delta(&mut self.v_emb, &grads.emb, lr, mu, &mut self.delta);
        for (k, d) in self.delta.iter().enumerate() {
            self.emb[k] = round_f16(self.emb[k] + d);
        }
        stack.embed.table.copy_from_slice(&self.emb);
    }
}

/// Post-process raw (still loss-scaled) gradients in the paper's
/// order: overflow check against the FP8 grid, FP8 quantization,
/// exact power-of-two unscaling, optional global-norm clipping.
/// Returns `false` (and leaves the gradients untouched) on overflow —
/// the caller must skip the step and shrink the scale.
pub fn finalize_grads(grads: &mut StackGrads, scale: f32, clip_norm: Option<f32>) -> bool {
    {
        let slices = grads.slices_mut();
        if slices.iter().any(|s| grads_overflow(s)) {
            return false;
        }
        let inv = 1.0 / scale;
        for s in slices {
            quantize_fp8_inplace(s);
            for g in s.iter_mut() {
                *g *= inv;
            }
        }
    }
    if let Some(max_norm) = clip_norm {
        let slices = grads.slices_mut();
        let total: f64 = slices
            .iter()
            .map(|s| s.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>())
            .sum();
        let total = total.sqrt() as f32;
        if total > max_norm {
            let k = max_norm / (total + 1e-6);
            for s in slices {
                for g in s.iter_mut() {
                    *g *= k;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_scaler_halves_and_grows() {
        let mut s = LossScaler::new(1024.0);
        let ev = s.on_overflow();
        assert_eq!(ev, ScaleEvent::Backoff { from: 1024.0, to: 512.0 });
        assert_eq!(s.scale, 512.0);
        assert_eq!(s.skipped, 1);
        s.growth_interval = 2;
        assert_eq!(s.on_good_step(), None);
        assert_eq!(s.scale, 512.0);
        let ev = s.on_good_step();
        assert_eq!(ev, Some(ScaleEvent::Growth { from: 512.0, to: 1024.0 }));
        assert_eq!(s.scale, 1024.0, "doubles after the growth interval");
        for _ in 0..100 {
            s.on_overflow();
        }
        assert_eq!(s.scale, s.min_scale, "never collapses below min_scale");
    }

    #[test]
    fn init_masters_match_live_stack() {
        let (ms, stack) = MasterStack::init_with_stack(16, 4, 6, 2, 3);
        // masters on the FP16 grid; live SD8 weights are their nearest codes
        for (l, m) in ms.layers.iter().enumerate() {
            for &v in &m.wx {
                assert_eq!(v, round_f16(v));
            }
            let cell = &stack.layers[l].fwd;
            for r in 0..4 * cell.hidden {
                for c in 0..cell.input_dim {
                    assert_eq!(
                        cell.wx.row_decoded(r)[c],
                        crate::formats::FLOAT_SD8.quantize(m.wx[r * cell.input_dim + c])
                    );
                }
            }
            assert_eq!(cell.bias, m.b);
        }
        assert_eq!(stack.embed.table, ms.emb);
        assert_eq!(stack.head.bias, ms.head_b);
    }

    #[test]
    fn update_moves_master_and_requantizes() {
        let (mut ms, mut stack) = MasterStack::init_with_stack(8, 3, 4, 1, 9);
        let mut grads = StackGrads::zeros(&stack);
        grads.layers[0].db[0] = 1.0;
        grads.head_b[2] = -2.0;
        let b0 = ms.layers[0].b[0];
        let hb2 = ms.head_b[2];
        ms.apply(&mut stack, &grads, 0.1, 0.0);
        assert!(ms.layers[0].b[0] < b0, "positive gradient must lower the bias");
        assert!(ms.head_b[2] > hb2, "negative gradient must raise the bias");
        assert_eq!(stack.layers[0].fwd.bias[0], ms.layers[0].b[0]);
        assert_eq!(stack.head.bias[2], ms.head_b[2]);
    }

    #[test]
    fn finalize_rejects_overflow_and_unscales() {
        let (_, stack) = MasterStack::init_with_stack(8, 3, 4, 1, 9);
        let mut grads = StackGrads::zeros(&stack);
        grads.emb[0] = 512.0;
        assert!(finalize_grads(&mut grads, 1024.0, None));
        assert_eq!(grads.emb[0], 0.5, "power-of-two unscaling is exact");
        let mut bad = StackGrads::zeros(&stack);
        bad.head_w[0] = f32::INFINITY;
        assert!(!finalize_grads(&mut bad, 1024.0, None));
    }

    #[test]
    fn finalize_clips_global_norm() {
        let (_, stack) = MasterStack::init_with_stack(8, 3, 4, 1, 9);
        let mut grads = StackGrads::zeros(&stack);
        grads.emb[0] = 3.0;
        grads.emb[1] = 4.0;
        assert!(finalize_grads(&mut grads, 1.0, Some(1.0)));
        let norm: f32 = grads
            .slices_mut()
            .iter()
            .flat_map(|s| s.iter())
            .map(|&g| g * g)
            .sum::<f32>()
            .sqrt();
        assert!(norm <= 1.0 + 1e-4, "clipped norm {norm}");
    }
}
