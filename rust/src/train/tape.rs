//! Forward tapes for truncated BPTT: the traced twins of
//! [`QLstmCell::step_batch`](crate::lstm::cell::QLstmCell::step_batch)
//! and [`QLstmStack::step_batch`](crate::lstm::QLstmStack::step_batch).
//!
//! A traced step runs the **identical** kernels as the inference path
//! — [`matmul_fast`] for the two weight matmuls and the cell's own
//! `gates_inplace` for the Eq. 5/6 unit math — and additionally
//! records, per time step, exactly what the backward pass needs:
//! layer input `x`, previous state `(h, c)`, the fused gate
//! pre-activations `z = zx + zh`, and the new cell state. Gate
//! activations themselves are *recomputed* from `z` in the backward
//! pass (deterministic, and 4H floats of tape instead of 12H).
//!
//! All tape buffers are flat and stream-major (`[b*dim ..]` per
//! stream), matching the batched kernels, so a `batch = 1` tape is a
//! plain single-stream tape.

use crate::lstm::cell::{BatchScratch, QLstmCell};
use crate::lstm::QLstmStack;
use crate::qmath::vector::{matmul_fast, matvec_fast};

/// Everything the backward pass needs about one time step.
pub struct TapeStep {
    /// layer input, flat `[B*D]` (FP8 grid)
    pub x: Vec<f32>,
    /// hidden state *entering* the step, flat `[B*H]` (FP8 grid)
    pub h_prev: Vec<f32>,
    /// cell state entering the step, flat `[B*H]` (FP16 grid)
    pub c_prev: Vec<f32>,
    /// fused gate pre-activations `zx + zh`, flat `[B*4H]`
    pub z: Vec<f32>,
    /// cell state leaving the step, flat `[B*H]` (FP16 grid)
    pub c_new: Vec<f32>,
}

/// The recorded forward of one cell over one truncation window.
pub struct CellTape {
    pub batch: usize,
    pub input_dim: usize,
    pub hidden: usize,
    pub steps: Vec<TapeStep>,
}

impl CellTape {
    pub fn new(batch: usize, input_dim: usize, hidden: usize) -> Self {
        CellTape { batch, input_dim, hidden, steps: Vec::new() }
    }

    /// Number of recorded time steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

impl QLstmCell {
    /// One traced time step for `batch` streams: advances `hs`/`cs`
    /// exactly like [`Self::step_batch`] (bit-identical — same matmul
    /// kernel, same `gates_inplace`, same [`BatchScratch`]) and
    /// appends a [`TapeStep`].
    pub fn step_batch_traced(
        &self,
        xs: &[f32],
        hs: &mut [f32],
        cs: &mut [f32],
        batch: usize,
        scratch: &mut BatchScratch,
        tape: &mut CellTape,
    ) {
        let hdim = self.hidden;
        assert_eq!(xs.len(), batch * self.input_dim);
        assert_eq!(hs.len(), batch * hdim);
        assert_eq!(cs.len(), batch * hdim);
        assert_eq!(tape.batch, batch, "tape built for a different batch size");
        assert_eq!(scratch.hidden, hdim, "scratch built for a different hidden size");
        scratch.ensure(batch);
        let BatchScratch { zx, zh, zero_bias, .. } = scratch;
        let n = batch * 4 * hdim;

        let mut step = TapeStep {
            x: xs.to_vec(),
            h_prev: hs.to_vec(),
            c_prev: cs.to_vec(),
            z: vec![0.0; n],
            c_new: Vec::new(),
        };

        matmul_fast(&self.wx, xs, batch, &self.bias, &mut zx[..n]);
        matmul_fast(&self.wh, hs, batch, zero_bias, &mut zh[..n]);
        for k in 0..n {
            // same f32 add the gate kernel performs internally
            step.z[k] = zx[k] + zh[k];
        }
        for b in 0..batch {
            self.gates_inplace(
                &zx[b * 4 * hdim..(b + 1) * 4 * hdim],
                &zh[b * 4 * hdim..(b + 1) * 4 * hdim],
                &mut hs[b * hdim..(b + 1) * hdim],
                &mut cs[b * hdim..(b + 1) * hdim],
            );
        }
        step.c_new = cs.to_vec();
        tape.steps.push(step);
    }

    /// Single-stream traced step (a `batch = 1` [`Self::step_batch_traced`],
    /// but through [`matvec_fast`] like the scalar inference path —
    /// the two are pinned bit-identical by `tests/batched_equivalence.rs`).
    pub fn step_traced(
        &self,
        x: &[f32],
        h: &mut [f32],
        c: &mut [f32],
        scratch: &mut BatchScratch,
        tape: &mut CellTape,
    ) {
        let hdim = self.hidden;
        assert_eq!(tape.batch, 1);
        assert_eq!(scratch.hidden, hdim, "scratch built for a different hidden size");
        scratch.ensure(1);
        let BatchScratch { zx, zh, zero_bias, .. } = scratch;
        let n = 4 * hdim;
        let mut step = TapeStep {
            x: x.to_vec(),
            h_prev: h.to_vec(),
            c_prev: c.to_vec(),
            z: vec![0.0; n],
            c_new: Vec::new(),
        };
        matvec_fast(&self.wx, x, &self.bias, &mut zx[..n]);
        matvec_fast(&self.wh, h, zero_bias, &mut zh[..n]);
        for k in 0..n {
            step.z[k] = zx[k] + zh[k];
        }
        self.gates_inplace(&zx[..n], &zh[..n], h, c);
        step.c_new = c.to_vec();
        tape.steps.push(step);
    }
}

/// The recorded forward of a whole stack over one truncation window.
pub struct StackTape {
    pub batch: usize,
    /// token ids per time step, `ids[t][b]`
    pub ids: Vec<Vec<usize>>,
    /// one tape per LSTM layer
    pub layers: Vec<CellTape>,
    /// top-layer hidden outputs per step, flat `[B*H_top]` (FP8 grid)
    /// — the dense head's inputs, needed for its weight gradient
    pub tops: Vec<Vec<f32>>,
}

impl StackTape {
    pub fn new(stack: &QLstmStack, batch: usize) -> Self {
        let mut in_dim = stack.embed.dim;
        let mut layers = Vec::with_capacity(stack.layers.len());
        for l in &stack.layers {
            layers.push(CellTape::new(batch, in_dim, l.fwd.hidden));
            in_dim = l.fwd.hidden;
        }
        StackTape { batch, ids: Vec::new(), layers, tops: Vec::new() }
    }
}

impl QLstmStack {
    /// Traced forward of one truncated-BPTT window over `batch`
    /// parallel lanes. `ids[t]` holds the lane tokens at step `t`;
    /// `hs[l]`/`cs[l]` are the carried per-layer recurrent states
    /// (flat `[B*H]`, advanced in place — pass them back next window
    /// for stateful truncated BPTT). Returns per-step logits (flat
    /// `[B*n_out]`). Numerics are bit-identical to
    /// [`Self::step_batch`] on the same tokens.
    pub fn forward_batch_traced(
        &self,
        ids: &[Vec<usize>],
        hs: &mut [Vec<f32>],
        cs: &mut [Vec<f32>],
        scratches: &mut [BatchScratch],
        tape: &mut StackTape,
    ) -> Vec<Vec<f32>> {
        assert!(self.is_unidirectional(), "training: bidirectional layers unsupported");
        assert_eq!(hs.len(), self.layers.len());
        assert_eq!(scratches.len(), self.layers.len());
        let batch = tape.batch;
        let dim = self.embed.dim;
        let n_out = self.n_out();
        let width = self.layers.iter().map(|l| l.fwd.hidden).fold(dim, usize::max);
        let mut x = vec![0f32; batch * width];
        let mut logits = Vec::with_capacity(ids.len());

        for step_ids in ids {
            assert_eq!(step_ids.len(), batch);
            for (b, &id) in step_ids.iter().enumerate() {
                self.embed.lookup_fp8(id, &mut x[b * dim..(b + 1) * dim]);
            }
            let mut in_dim = dim;
            for (l, layer) in self.layers.iter().enumerate() {
                let hdim = layer.fwd.hidden;
                layer.fwd.step_batch_traced(
                    &x[..batch * in_dim],
                    &mut hs[l][..batch * hdim],
                    &mut cs[l][..batch * hdim],
                    batch,
                    &mut scratches[l],
                    &mut tape.layers[l],
                );
                x[..batch * hdim].copy_from_slice(&hs[l][..batch * hdim]);
                in_dim = hdim;
            }
            tape.tops.push(x[..batch * in_dim].to_vec());
            let mut y = vec![0f32; batch * n_out];
            matmul_fast(&self.head.w, &x[..batch * in_dim], batch, &self.head.bias, &mut y);
            logits.push(y);
            tape.ids.push(step_ids.clone());
        }
        logits
    }

    /// Fresh per-layer trace scratches sized for `batch` streams (the
    /// same [`BatchScratch`] the inference path uses).
    pub fn trace_scratches(&self, batch: usize) -> Vec<BatchScratch> {
        self.layers.iter().map(|l| BatchScratch::new(l.fwd.hidden, batch)).collect()
    }

    /// Fresh zeroed flat per-layer recurrent state for `batch` lanes:
    /// `(hs, cs)` with `hs[l].len() == batch * hidden[l]`.
    pub fn zero_flat_state(&self, batch: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let hs = self.layers.iter().map(|l| vec![0f32; batch * l.fwd.hidden]).collect();
        let cs = self.layers.iter().map(|l| vec![0f32; batch * l.fwd.hidden]).collect();
        (hs, cs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f8;
    use crate::lstm::cell::{BatchScratch, QLstmCell};
    use crate::lstm::synthetic_stack;
    use crate::rng::SplitMix64;

    fn rand_cell(d: usize, hidden: usize, seed: u64) -> QLstmCell {
        let mut rng = SplitMix64::new(seed);
        let wx: Vec<f32> = (0..d * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let wh: Vec<f32> =
            (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
        QLstmCell::from_jax_layout(d, hidden, &wx, &wh, &b)
    }

    #[test]
    fn traced_step_matches_untraced_bitwise() {
        let (d, hidden, batch, t_len) = (4usize, 7usize, 3usize, 5usize);
        let cell = rand_cell(d, hidden, 3);
        let mut rng = SplitMix64::new(9);
        let xs: Vec<Vec<f32>> = (0..t_len)
            .map(|_| (0..batch * d).map(|_| round_f8(rng.uniform(-1.5, 1.5))).collect())
            .collect();

        let mut h1 = vec![0f32; batch * hidden];
        let mut c1 = vec![0f32; batch * hidden];
        let mut bs = BatchScratch::new(hidden, batch);
        let mut h2 = vec![0f32; batch * hidden];
        let mut c2 = vec![0f32; batch * hidden];
        let mut ts = BatchScratch::new(hidden, batch);
        let mut tape = CellTape::new(batch, d, hidden);
        for t in 0..t_len {
            cell.step_batch(&xs[t], &mut h1, &mut c1, batch, &mut bs);
            cell.step_batch_traced(&xs[t], &mut h2, &mut c2, batch, &mut ts, &mut tape);
            for (a, b) in h1.iter().zip(&h2) {
                assert_eq!(a.to_bits(), b.to_bits(), "h diverged at t={t}");
            }
            for (a, b) in c1.iter().zip(&c2) {
                assert_eq!(a.to_bits(), b.to_bits(), "c diverged at t={t}");
            }
        }
        assert_eq!(tape.len(), t_len);
        // tape invariants: c_new of step t == c_prev of step t+1
        for t in 0..t_len - 1 {
            assert_eq!(tape.steps[t].c_new, tape.steps[t + 1].c_prev);
            assert_eq!(tape.steps[t].x, xs[t]);
        }
    }

    #[test]
    fn stack_traced_forward_matches_forward() {
        let stack = synthetic_stack(24, 5, 6, 2, 24, 11);
        let seq: Vec<usize> = vec![1, 5, 3, 0, 17, 8];
        let want = stack.forward(&seq);

        let ids: Vec<Vec<usize>> = seq.iter().map(|&t| vec![t]).collect();
        let (mut hs, mut cs) = stack.zero_flat_state(1);
        let mut scr = stack.trace_scratches(1);
        let mut tape = StackTape::new(&stack, 1);
        let got = stack.forward_batch_traced(&ids, &mut hs, &mut cs, &mut scr, &mut tape);
        assert_eq!(got.len(), want.len());
        for (t, (g, w)) in got.iter().zip(&want).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert_eq!(a.to_bits(), b.to_bits(), "logits diverged at t={t}");
            }
        }
        assert_eq!(tape.tops.len(), seq.len());
        assert_eq!(tape.layers.len(), 2);
    }
}
