//! Truncated-BPTT backward passes over the forward tapes — the
//! gradient twins of `step_batch`/`forward_batch_traced`.
//!
//! Quantization discipline (paper Table II + the L2 graph's
//! fake-quant wiring in `python/compile/fq.py`):
//!
//! * quantized forward nonlinearities get **straight-through**
//!   derivatives: the unquantized σ/tanh slope at the recorded
//!   pre-activation (exactly `fq.sigmoid_sd8`'s custom VJP);
//! * per-step gate cotangents `dz` and propagated inter-layer
//!   gradients `dx` are FP8-quantized ("all gradients 8 bits");
//! * the two transposed contractions (`Wᵀ·dz`) run the FP16-chained
//!   [`matmul_t_fast`] kernel; the recurrent cell-state cotangent is
//!   rounded to FP16 each step (all accumulations ≤ 16 bits);
//! * parameter gradients accumulate per stream in f32 and are reduced
//!   in stream order — [`QLstmCell::backward_batch`] is therefore
//!   **bit-identical** to B independent [`QLstmCell::backward`] calls
//!   folded with [`CellGrads::add_assign`] in the same order (pinned
//!   by `tests/batched_equivalence.rs`).

use crate::formats::round_f16;
use crate::lstm::cell::QLstmCell;
use crate::lstm::QLstmStack;
use crate::qmath::grad::{matmul_t_fast, outer_acc, quantize_fp8_inplace};
use crate::qmath::qsigmoid::{sigmoid_sd8, tanh_fp8};

use super::tape::{CellTape, StackTape};

/// Parameter gradients of one cell, in the QMatrix (row-major
/// `[out][in]`) layout — the same layout the FP16 master copies use.
#[derive(Clone, Debug)]
pub struct CellGrads {
    pub dwx: Vec<f32>,
    pub dwh: Vec<f32>,
    pub db: Vec<f32>,
}

impl CellGrads {
    pub fn zeros(cell: &QLstmCell) -> Self {
        CellGrads {
            dwx: vec![0.0; 4 * cell.hidden * cell.input_dim],
            dwh: vec![0.0; 4 * cell.hidden * cell.hidden],
            db: vec![0.0; 4 * cell.hidden],
        }
    }

    /// Elementwise accumulate (the stream-order reduction contract).
    pub fn add_assign(&mut self, other: &CellGrads) {
        for (a, b) in self.dwx.iter_mut().zip(&other.dwx) {
            *a += b;
        }
        for (a, b) in self.dwh.iter_mut().zip(&other.dwh) {
            *a += b;
        }
        for (a, b) in self.db.iter_mut().zip(&other.db) {
            *a += b;
        }
    }
}

/// Parameter gradients of a whole stack.
pub struct StackGrads {
    /// embedding-table gradient, `[vocab*dim]`
    pub emb: Vec<f32>,
    pub layers: Vec<CellGrads>,
    /// dense-head weight gradient in QMatrix layout `[n_out*H_top]`
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl StackGrads {
    pub fn zeros(stack: &QLstmStack) -> Self {
        StackGrads {
            emb: vec![0.0; stack.embed.vocab * stack.embed.dim],
            layers: stack.layers.iter().map(|l| CellGrads::zeros(&l.fwd)).collect(),
            head_w: vec![0.0; stack.head.w.rows * stack.head.w.cols],
            head_b: vec![0.0; stack.head.w.rows],
        }
    }

    /// All gradient tensors as mutable slices (uniform post-processing:
    /// overflow check, FP8 quantization, unscaling, clipping).
    pub fn slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut out: Vec<&mut [f32]> = vec![&mut self.emb, &mut self.head_w, &mut self.head_b];
        for l in &mut self.layers {
            out.push(&mut l.dwx);
            out.push(&mut l.dwh);
            out.push(&mut l.db);
        }
        out
    }
}

impl QLstmCell {
    /// BPTT over a recorded window for `tape.batch` streams.
    ///
    /// `dh_seq[t]` is the incoming cotangent of the step-`t` hidden
    /// output (flat `[B*H]`, from the layer above / the head).
    /// Parameter gradients are **accumulated into** `grads`; the
    /// return value is `dx_seq` — per-step input cotangents (flat
    /// `[B*D]`, FP8 grid), i.e. the `dh_seq` of the layer below.
    /// Gradients are truncated at the window boundary (`dh`, `dc`
    /// start at zero; the `t = 0` carry-out is dropped).
    pub fn backward_batch(
        &self,
        tape: &CellTape,
        dh_seq: &[Vec<f32>],
        grads: &mut CellGrads,
    ) -> Vec<Vec<f32>> {
        let b_n = tape.batch;
        let hdim = self.hidden;
        let d = self.input_dim;
        assert_eq!(tape.input_dim, d, "tape recorded for a different cell");
        assert_eq!(tape.hidden, hdim, "tape recorded for a different cell");
        let t_n = tape.steps.len();
        assert_eq!(dh_seq.len(), t_n);

        // Per-stream accumulators, reduced in stream order at the end:
        // the accumulation order inside each stream is its own reversed
        // time order, exactly as in an independent backward call.
        let mut gbuf: Vec<CellGrads> = (0..b_n).map(|_| CellGrads::zeros(self)).collect();
        let mut dh_rec = vec![0f32; b_n * hdim];
        let mut dc = vec![0f32; b_n * hdim];
        let mut dz = vec![0f32; b_n * 4 * hdim];
        let mut dx_seq: Vec<Vec<f32>> = (0..t_n).map(|_| vec![0f32; b_n * d]).collect();

        for t in (0..t_n).rev() {
            let step = &tape.steps[t];
            assert_eq!(dh_seq[t].len(), b_n * hdim);
            for b in 0..b_n {
                self.backward_units(
                    &step.z[b * 4 * hdim..(b + 1) * 4 * hdim],
                    &step.c_prev[b * hdim..(b + 1) * hdim],
                    &step.c_new[b * hdim..(b + 1) * hdim],
                    &dh_seq[t][b * hdim..(b + 1) * hdim],
                    &dh_rec[b * hdim..(b + 1) * hdim],
                    &mut dc[b * hdim..(b + 1) * hdim],
                    &mut dz[b * 4 * hdim..(b + 1) * 4 * hdim],
                );
            }
            // gate cotangents onto the FP8 gradient grid (Table II)
            quantize_fp8_inplace(&mut dz);
            // dx = Wxᵀ·dz — backward activation for the layer below
            matmul_t_fast(&self.wx, &dz, b_n, &mut dx_seq[t]);
            quantize_fp8_inplace(&mut dx_seq[t]);
            // dh_prev = Whᵀ·dz — recurrent cotangent for step t-1
            matmul_t_fast(&self.wh, &dz, b_n, &mut dh_rec);
            // parameter gradients
            for b in 0..b_n {
                let dzb = &dz[b * 4 * hdim..(b + 1) * 4 * hdim];
                outer_acc(dzb, &step.x[b * d..(b + 1) * d], &mut gbuf[b].dwx);
                outer_acc(dzb, &step.h_prev[b * hdim..(b + 1) * hdim], &mut gbuf[b].dwh);
                for (a, g) in gbuf[b].db.iter_mut().zip(dzb) {
                    *a += g;
                }
            }
        }
        for g in &gbuf {
            grads.add_assign(g);
        }
        dx_seq
    }

    /// Single-stream BPTT (a `batch = 1` tape) — see
    /// [`Self::backward_batch`] for the contract.
    pub fn backward(
        &self,
        tape: &CellTape,
        dh_seq: &[Vec<f32>],
        grads: &mut CellGrads,
    ) -> Vec<Vec<f32>> {
        assert_eq!(tape.batch, 1, "backward: use backward_batch for batched tapes");
        self.backward_batch(tape, dh_seq, grads)
    }

    /// Per-unit backward of Eq. 1–6 for one stream at one step.
    ///
    /// Reads the recorded pre-activations `z` and states; consumes the
    /// incoming hidden cotangent (`dh_in + dh_rec`) and the cell-state
    /// cotangent `dc` (in: from step t+1, out: for step t-1, rounded
    /// FP16); writes the gate pre-activation cotangents `dz` (4H).
    #[allow(clippy::too_many_arguments)]
    fn backward_units(
        &self,
        z: &[f32],
        c_prev: &[f32],
        c_new: &[f32],
        dh_in: &[f32],
        dh_rec: &[f32],
        dc: &mut [f32],
        dz: &mut [f32],
    ) {
        let hdim = self.hidden;
        let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
        for j in 0..hdim {
            let zf = z[j];
            let zi = z[hdim + j];
            let zo = z[2 * hdim + j];
            let zg = z[3 * hdim + j];

            // quantized forward values (recomputed — identical to the
            // forward pass by determinism of the quantizers)
            let f = sigmoid_sd8(zf);
            let i = sigmoid_sd8(zi);
            let o = sigmoid_sd8(zo);
            let g = tanh_fp8(zg);
            let tq = tanh_fp8(c_new[j]);

            // straight-through slopes (unquantized nonlinearities)
            let sf = sigmoid(zf);
            let si = sigmoid(zi);
            let so = sigmoid(zo);
            let th_g = zg.tanh();
            let th_c = c_new[j].tanh();

            let dh = dh_in[j] + dh_rec[j];
            // h = round_f8(o · tanh_q(c)) — STE through round_f8
            let d_o = dh * tq;
            let dcj = dc[j] + dh * o * (1.0 - th_c * th_c);
            // c = round_f16(f·c_prev + i·g) — STE through round_f16
            let df = dcj * c_prev[j];
            let di = dcj * g;
            let dg = dcj * i;
            // carry to step t-1 on the FP16 accumulation grid
            dc[j] = round_f16(dcj * f);

            dz[j] = df * sf * (1.0 - sf);
            dz[hdim + j] = di * si * (1.0 - si);
            dz[2 * hdim + j] = d_o * so * (1.0 - so);
            dz[3 * hdim + j] = dg * (1.0 - th_g * th_g);
        }
    }
}

impl QLstmStack {
    /// BPTT through head → layers (top-down) → embedding over a
    /// recorded window. `dlogits[t]` is the loss cotangent of the
    /// step-`t` logits (flat `[B*n_out]`, already loss-scaled and on
    /// the FP8 grid — see [`super::loss::cross_entropy_grad`]).
    /// Gradients are accumulated into `grads`.
    pub fn backward_batch(
        &self,
        tape: &StackTape,
        dlogits: &[Vec<f32>],
        grads: &mut StackGrads,
    ) {
        let b_n = tape.batch;
        let n_out = self.n_out();
        let h_top = self.layers.last().expect("stack has layers").fwd.hidden;
        let t_n = tape.tops.len();
        assert_eq!(dlogits.len(), t_n);
        assert_eq!(tape.ids.len(), t_n);

        // dense head: dh_top[t] = Wᵀ·dlogits[t]; dW += dlogits ⊗ top
        let mut dh_seq: Vec<Vec<f32>> = Vec::with_capacity(t_n);
        for t in 0..t_n {
            let dl = &dlogits[t];
            assert_eq!(dl.len(), b_n * n_out);
            let mut dh = vec![0f32; b_n * h_top];
            matmul_t_fast(&self.head.w, dl, b_n, &mut dh);
            quantize_fp8_inplace(&mut dh);
            for b in 0..b_n {
                let dlb = &dl[b * n_out..(b + 1) * n_out];
                outer_acc(dlb, &tape.tops[t][b * h_top..(b + 1) * h_top], &mut grads.head_w);
                for (a, g) in grads.head_b.iter_mut().zip(dlb) {
                    *a += g;
                }
            }
            dh_seq.push(dh);
        }

        // LSTM layers, top-down: each layer's dx becomes the next
        // lower layer's incoming dh
        for l in (0..self.layers.len()).rev() {
            let cell = &self.layers[l].fwd;
            dh_seq = cell.backward_batch(&tape.layers[l], &dh_seq, &mut grads.layers[l]);
        }

        // embedding scatter: dL/demb[id] += dx0 (STE through the FP8
        // lookup rounding)
        let dim = self.embed.dim;
        for t in 0..t_n {
            for b in 0..b_n {
                let id = tape.ids[t][b];
                let row = &mut grads.emb[id * dim..(id + 1) * dim];
                for (a, g) in row.iter_mut().zip(&dh_seq[t][b * dim..(b + 1) * dim]) {
                    *a += g;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f8;
    use crate::lstm::cell::BatchScratch;
    use crate::lstm::reference::F32LstmCell;
    use crate::rng::SplitMix64;

    /// The quantized BPTT must point in the same direction as the
    /// full-precision reference BPTT on the same (well-conditioned)
    /// problem — the paper's trainability premise, gradient edition.
    #[test]
    fn quantized_gradients_align_with_reference() {
        let (d, hdim, t_n) = (4usize, 6usize, 5usize);
        let mut rng = SplitMix64::new(17);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hdim).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let qcell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        let rcell = F32LstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);

        let xs: Vec<Vec<f32>> = (0..t_n)
            .map(|_| (0..d).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect())
            .collect();
        let dh_seq: Vec<Vec<f32>> = (0..t_n)
            .map(|_| (0..hdim).map(|_| round_f8(rng.uniform(-0.5, 0.5))).collect())
            .collect();

        // quantized path
        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scr = BatchScratch::new(hdim, 1);
        let mut tape = CellTape::new(1, d, hdim);
        for x in &xs {
            qcell.step_traced(x, &mut h, &mut c, &mut scr, &mut tape);
        }
        let mut grads = CellGrads::zeros(&qcell);
        qcell.backward(&tape, &dh_seq, &mut grads);

        // reference path
        let rtape = rcell.forward_traced(&xs);
        let dh64: Vec<Vec<f64>> = dh_seq
            .iter()
            .map(|v| v.iter().map(|&x| x as f64).collect())
            .collect();
        let rgrads = rcell.bptt(&rtape, &dh64);

        let cosine = |a: &[f32], b: &[f64]| {
            let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
            for (x, y) in a.iter().zip(b) {
                dot += *x as f64 * y;
                na += (*x as f64) * (*x as f64);
                nb += y * y;
            }
            dot / (na.sqrt() * nb.sqrt()).max(1e-12)
        };
        // Loose directional bounds: the quantized path differs from the
        // reference by FP8 gradient quantization, STE slopes at
        // quantized operating points, and FP16 accumulation — the
        // descent *direction* must survive all of that (the paper's
        // premise), but bitwise agreement is not expected.
        assert!(
            cosine(&grads.dwx, &rgrads.dwx) > 0.5,
            "dwx misaligned: cos={}",
            cosine(&grads.dwx, &rgrads.dwx)
        );
        assert!(
            cosine(&grads.dwh, &rgrads.dwh) > 0.4,
            "dwh misaligned: cos={}",
            cosine(&grads.dwh, &rgrads.dwh)
        );
        assert!(
            cosine(&grads.db, &rgrads.db) > 0.5,
            "db misaligned: cos={}",
            cosine(&grads.db, &rgrads.db)
        );
    }

    /// Zero incoming cotangents must produce exactly zero gradients.
    #[test]
    fn zero_cotangent_gives_zero_grads() {
        let (d, hdim, t_n) = (3usize, 5usize, 4usize);
        let mut rng = SplitMix64::new(2);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b = vec![0.0; 4 * hdim];
        let cell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scr = BatchScratch::new(hdim, 1);
        let mut tape = CellTape::new(1, d, hdim);
        for _ in 0..t_n {
            let x: Vec<f32> = (0..d).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect();
            cell.step_traced(&x, &mut h, &mut c, &mut scr, &mut tape);
        }
        let dh_seq = vec![vec![0f32; hdim]; t_n];
        let mut grads = CellGrads::zeros(&cell);
        let dx = cell.backward(&tape, &dh_seq, &mut grads);
        assert!(grads.dwx.iter().all(|&g| g == 0.0));
        assert!(grads.dwh.iter().all(|&g| g == 0.0));
        assert!(grads.db.iter().all(|&g| g == 0.0));
        assert!(dx.iter().flatten().all(|&g| g == 0.0));
    }
}
