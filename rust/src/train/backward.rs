//! Truncated-BPTT backward passes over the forward tapes — the
//! gradient twins of `step_batch`/`forward_batch_traced`.
//!
//! Quantization discipline (paper Table II + the L2 graph's
//! fake-quant wiring in `python/compile/fq.py`):
//!
//! * quantized forward nonlinearities get **straight-through**
//!   derivatives: the unquantized σ/tanh slope at the recorded
//!   pre-activation (exactly `fq.sigmoid_sd8`'s custom VJP);
//! * per-step gate cotangents `dz` and propagated inter-layer
//!   gradients `dx` are FP8-quantized ("all gradients 8 bits");
//! * the two transposed contractions (`Wᵀ·dz`) run the FP16-chained
//!   [`matmul_t_fast`] kernel; the recurrent cell-state cotangent is
//!   rounded to FP16 each step (all accumulations ≤ 16 bits);
//! * parameter gradients accumulate per stream in f32 and are reduced
//!   in stream order — [`QLstmCell::backward_batch`] is therefore
//!   **bit-identical** to B independent [`QLstmCell::backward`] calls
//!   folded with [`CellGrads::add_assign`] in the same order (pinned
//!   by `tests/batched_equivalence.rs`).

use crate::formats::round_f16;
use crate::lstm::cell::QLstmCell;
use crate::lstm::QLstmStack;
use crate::qmath::grad::{matmul_t_fast, outer_acc, quantize_fp8_inplace};
use crate::qmath::qsigmoid::{sigmoid_sd8, tanh_fp8};

use super::tape::{CellTape, StackTape};

/// Parameter gradients of one cell, in the QMatrix (row-major
/// `[out][in]`) layout — the same layout the FP16 master copies use.
#[derive(Clone, Debug)]
pub struct CellGrads {
    pub dwx: Vec<f32>,
    pub dwh: Vec<f32>,
    pub db: Vec<f32>,
}

impl CellGrads {
    pub fn zeros(cell: &QLstmCell) -> Self {
        CellGrads {
            dwx: vec![0.0; 4 * cell.hidden * cell.input_dim],
            dwh: vec![0.0; 4 * cell.hidden * cell.hidden],
            db: vec![0.0; 4 * cell.hidden],
        }
    }

    /// Elementwise accumulate (the stream-order reduction contract).
    pub fn add_assign(&mut self, other: &CellGrads) {
        for (a, b) in self.dwx.iter_mut().zip(&other.dwx) {
            *a += b;
        }
        for (a, b) in self.dwh.iter_mut().zip(&other.dwh) {
            *a += b;
        }
        for (a, b) in self.db.iter_mut().zip(&other.db) {
            *a += b;
        }
    }

    /// Zero in place (buffer reuse across windows — no reallocation).
    pub fn reset(&mut self) {
        self.dwx.fill(0.0);
        self.dwh.fill(0.0);
        self.db.fill(0.0);
    }
}

/// Parameter gradients of a whole stack.
pub struct StackGrads {
    /// embedding-table gradient, `[vocab*dim]`
    pub emb: Vec<f32>,
    pub layers: Vec<CellGrads>,
    /// dense-head weight gradient in QMatrix layout `[n_out*H_top]`
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

impl StackGrads {
    pub fn zeros(stack: &QLstmStack) -> Self {
        StackGrads {
            emb: vec![0.0; stack.embed.vocab * stack.embed.dim],
            layers: stack.layers.iter().map(|l| CellGrads::zeros(&l.fwd)).collect(),
            head_w: vec![0.0; stack.head.w.rows * stack.head.w.cols],
            head_b: vec![0.0; stack.head.w.rows],
        }
    }

    /// Zero every tensor in place — a window's shard buffers are
    /// reused, never reallocated (see `train::parallel`).
    pub fn reset(&mut self) {
        self.emb.fill(0.0);
        for l in &mut self.layers {
            l.reset();
        }
        self.head_w.fill(0.0);
        self.head_b.fill(0.0);
    }

    /// Elementwise accumulate another stack's gradients — the shard
    /// merge step of the fixed-order tree reduction
    /// ([`crate::train::merge_shards`]).
    pub fn add_assign(&mut self, other: &StackGrads) {
        debug_assert_eq!(self.emb.len(), other.emb.len());
        debug_assert_eq!(self.layers.len(), other.layers.len());
        for (a, b) in self.emb.iter_mut().zip(&other.emb) {
            *a += b;
        }
        for (l, o) in self.layers.iter_mut().zip(&other.layers) {
            l.add_assign(o);
        }
        for (a, b) in self.head_w.iter_mut().zip(&other.head_w) {
            *a += b;
        }
        for (a, b) in self.head_b.iter_mut().zip(&other.head_b) {
            *a += b;
        }
    }

    /// All gradient tensors as mutable slices (uniform post-processing:
    /// overflow check, FP8 quantization, unscaling, clipping).
    pub fn slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut out: Vec<&mut [f32]> = vec![&mut self.emb, &mut self.head_w, &mut self.head_b];
        for l in &mut self.layers {
            out.push(&mut l.dwx);
            out.push(&mut l.dwh);
            out.push(&mut l.db);
        }
        out
    }

    /// Number of independent gradient tensors ("slots") — `3 +
    /// 3·layers` — the unit of the overlapped merge/finalize pipeline
    /// ([`crate::train::merge_finalize_overlapped`]).
    pub fn slot_count(&self) -> usize {
        3 + 3 * self.layers.len()
    }

    /// Slot `i` read-only, in [`Self::slices_mut`] order: emb, head.w,
    /// head.b, then wx/wh/b per layer.
    pub fn slot(&self, i: usize) -> &[f32] {
        match i {
            0 => &self.emb,
            1 => &self.head_w,
            2 => &self.head_b,
            _ => {
                let g = &self.layers[(i - 3) / 3];
                match (i - 3) % 3 {
                    0 => &g.dwx,
                    1 => &g.dwh,
                    _ => &g.db,
                }
            }
        }
    }

    /// Slot `i` mutable — same order as [`Self::slot`].
    pub fn slot_mut(&mut self, i: usize) -> &mut [f32] {
        match i {
            0 => &mut self.emb,
            1 => &mut self.head_w,
            2 => &mut self.head_b,
            _ => {
                let g = &mut self.layers[(i - 3) / 3];
                match (i - 3) % 3 {
                    0 => &mut g.dwx,
                    1 => &mut g.dwh,
                    _ => &mut g.db,
                }
            }
        }
    }

    /// The same tensors read-only, named for telemetry's per-tensor
    /// FP8 saturation scans ("emb", "l1.wx", …, "head.b"); `prefix`
    /// (e.g. the mt encoder's "enc") is dot-joined in front when
    /// non-empty. Names match `telemetry::stack_qmatrices` so gradient
    /// and re-encode stats line up per tensor in the trace.
    pub fn named_slices(&self, prefix: &str) -> Vec<(String, &[f32])> {
        let name = |s: String| if prefix.is_empty() { s } else { format!("{prefix}.{s}") };
        let mut out: Vec<(String, &[f32])> = vec![(name("emb".to_string()), &self.emb[..])];
        for (l, g) in self.layers.iter().enumerate() {
            out.push((name(format!("l{}.wx", l + 1)), &g.dwx[..]));
            out.push((name(format!("l{}.wh", l + 1)), &g.dwh[..]));
            out.push((name(format!("l{}.b", l + 1)), &g.db[..]));
        }
        out.push((name("head.w".to_string()), &self.head_w[..]));
        out.push((name("head.b".to_string()), &self.head_b[..]));
        out
    }
}

/// Cotangent of a recurrent state — `dh`/`dc` flat `[B*H]`, the
/// gradient flowing across a window (or model) boundary into the state
/// that *entered* it. Produced by [`QLstmCell::backward_batch_carry`]
/// for the window's initial state; consumed by the same function as
/// the incoming future-cotangent of the window's final state. This is
/// the seq2seq state bridge: the decoder's initial-state cotangents
/// are the encoder's final-state cotangents (`tasks::mt`).
#[derive(Clone, Debug)]
pub struct StateCot {
    /// hidden-state cotangent, flat `[B*H]` (FP16 grid — `Whᵀ·dz`)
    pub dh: Vec<f32>,
    /// cell-state cotangent, flat `[B*H]` (FP16-rounded carry)
    pub dc: Vec<f32>,
}

impl StateCot {
    pub fn zeros(batch: usize, hidden: usize) -> Self {
        StateCot { dh: vec![0.0; batch * hidden], dc: vec![0.0; batch * hidden] }
    }
}

impl QLstmCell {
    /// BPTT over a recorded window for `tape.batch` streams.
    ///
    /// `dh_seq[t]` is the incoming cotangent of the step-`t` hidden
    /// output (flat `[B*H]`, from the layer above / the head).
    /// Parameter gradients are **accumulated into** `grads`; the
    /// return value is `dx_seq` — per-step input cotangents (flat
    /// `[B*D]`, FP8 grid), i.e. the `dh_seq` of the layer below.
    /// Gradients are truncated at the window boundary (`dh`, `dc`
    /// start at zero; the `t = 0` carry-out is dropped). For the
    /// carry-aware variant see [`Self::backward_batch_carry`].
    pub fn backward_batch(
        &self,
        tape: &CellTape,
        dh_seq: &[Vec<f32>],
        grads: &mut CellGrads,
    ) -> Vec<Vec<f32>> {
        self.backward_batch_carry(tape, dh_seq, None, grads).0
    }

    /// [`Self::backward_batch`] with explicit state-cotangent carry.
    ///
    /// `carry_in` (when present) is the cotangent of the *final*
    /// `(h, c)` this window produced, arriving from whatever consumed
    /// that state downstream — e.g. the decoder's initial-state
    /// cotangent flowing back into the seq2seq encoder. It seeds the
    /// recurrent accumulators exactly where truncation would have
    /// zeroed them, so `carry_in = None` is bit-identical to plain
    /// truncated BPTT. The second return value is the carry-*out*: the
    /// cotangent of the `(h, c)` that *entered* step 0.
    pub fn backward_batch_carry(
        &self,
        tape: &CellTape,
        dh_seq: &[Vec<f32>],
        carry_in: Option<&StateCot>,
        grads: &mut CellGrads,
    ) -> (Vec<Vec<f32>>, StateCot) {
        let b_n = tape.batch;
        let hdim = self.hidden;
        let d = self.input_dim;
        assert_eq!(tape.input_dim, d, "tape recorded for a different cell");
        assert_eq!(tape.hidden, hdim, "tape recorded for a different cell");
        let t_n = tape.steps.len();
        assert_eq!(dh_seq.len(), t_n);

        // Per-stream accumulators, reduced in stream order at the end:
        // the accumulation order inside each stream is its own reversed
        // time order, exactly as in an independent backward call.
        let mut gbuf: Vec<CellGrads> = (0..b_n).map(|_| CellGrads::zeros(self)).collect();
        let (mut dh_rec, mut dc) = match carry_in {
            Some(c) => {
                assert_eq!(c.dh.len(), b_n * hdim, "carry dh shape");
                assert_eq!(c.dc.len(), b_n * hdim, "carry dc shape");
                (c.dh.clone(), c.dc.clone())
            }
            None => (vec![0f32; b_n * hdim], vec![0f32; b_n * hdim]),
        };
        let mut dz = vec![0f32; b_n * 4 * hdim];
        let mut dx_seq: Vec<Vec<f32>> = (0..t_n).map(|_| vec![0f32; b_n * d]).collect();

        for t in (0..t_n).rev() {
            let step = &tape.steps[t];
            assert_eq!(dh_seq[t].len(), b_n * hdim);
            for b in 0..b_n {
                self.backward_units(
                    &step.z[b * 4 * hdim..(b + 1) * 4 * hdim],
                    &step.c_prev[b * hdim..(b + 1) * hdim],
                    &step.c_new[b * hdim..(b + 1) * hdim],
                    &dh_seq[t][b * hdim..(b + 1) * hdim],
                    &dh_rec[b * hdim..(b + 1) * hdim],
                    &mut dc[b * hdim..(b + 1) * hdim],
                    &mut dz[b * 4 * hdim..(b + 1) * 4 * hdim],
                );
            }
            // gate cotangents onto the FP8 gradient grid (Table II)
            quantize_fp8_inplace(&mut dz);
            // dx = Wxᵀ·dz — backward activation for the layer below
            matmul_t_fast(&self.wx, &dz, b_n, &mut dx_seq[t]);
            quantize_fp8_inplace(&mut dx_seq[t]);
            // dh_prev = Whᵀ·dz — recurrent cotangent for step t-1
            matmul_t_fast(&self.wh, &dz, b_n, &mut dh_rec);
            // parameter gradients
            for b in 0..b_n {
                let dzb = &dz[b * 4 * hdim..(b + 1) * 4 * hdim];
                outer_acc(dzb, &step.x[b * d..(b + 1) * d], &mut gbuf[b].dwx);
                outer_acc(dzb, &step.h_prev[b * hdim..(b + 1) * hdim], &mut gbuf[b].dwh);
                for (a, g) in gbuf[b].db.iter_mut().zip(dzb) {
                    *a += g;
                }
            }
        }
        for g in &gbuf {
            grads.add_assign(g);
        }
        (dx_seq, StateCot { dh: dh_rec, dc })
    }

    /// Single-stream BPTT (a `batch = 1` tape) — see
    /// [`Self::backward_batch`] for the contract.
    pub fn backward(
        &self,
        tape: &CellTape,
        dh_seq: &[Vec<f32>],
        grads: &mut CellGrads,
    ) -> Vec<Vec<f32>> {
        assert_eq!(tape.batch, 1, "backward: use backward_batch for batched tapes");
        self.backward_batch(tape, dh_seq, grads)
    }

    /// Per-unit backward of Eq. 1–6 for one stream at one step.
    ///
    /// Reads the recorded pre-activations `z` and states; consumes the
    /// incoming hidden cotangent (`dh_in + dh_rec`) and the cell-state
    /// cotangent `dc` (in: from step t+1, out: for step t-1, rounded
    /// FP16); writes the gate pre-activation cotangents `dz` (4H).
    #[allow(clippy::too_many_arguments)]
    fn backward_units(
        &self,
        z: &[f32],
        c_prev: &[f32],
        c_new: &[f32],
        dh_in: &[f32],
        dh_rec: &[f32],
        dc: &mut [f32],
        dz: &mut [f32],
    ) {
        let hdim = self.hidden;
        let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
        for j in 0..hdim {
            let zf = z[j];
            let zi = z[hdim + j];
            let zo = z[2 * hdim + j];
            let zg = z[3 * hdim + j];

            // quantized forward values (recomputed — identical to the
            // forward pass by determinism of the quantizers)
            let f = sigmoid_sd8(zf);
            let i = sigmoid_sd8(zi);
            let o = sigmoid_sd8(zo);
            let g = tanh_fp8(zg);
            let tq = tanh_fp8(c_new[j]);

            // straight-through slopes (unquantized nonlinearities)
            let sf = sigmoid(zf);
            let si = sigmoid(zi);
            let so = sigmoid(zo);
            let th_g = zg.tanh();
            let th_c = c_new[j].tanh();

            let dh = dh_in[j] + dh_rec[j];
            // h = round_f8(o · tanh_q(c)) — STE through round_f8
            let d_o = dh * tq;
            let dcj = dc[j] + dh * o * (1.0 - th_c * th_c);
            // c = round_f16(f·c_prev + i·g) — STE through round_f16
            let df = dcj * c_prev[j];
            let di = dcj * g;
            let dg = dcj * i;
            // carry to step t-1 on the FP16 accumulation grid
            dc[j] = round_f16(dcj * f);

            dz[j] = df * sf * (1.0 - sf);
            dz[hdim + j] = di * si * (1.0 - si);
            dz[2 * hdim + j] = d_o * so * (1.0 - so);
            dz[3 * hdim + j] = dg * (1.0 - th_g * th_g);
        }
    }
}

impl QLstmStack {
    /// BPTT through head → layers (top-down) → embedding over a
    /// recorded window. `dlogits[t]` is the loss cotangent of the
    /// step-`t` logits (flat `[B*n_out]`, already loss-scaled and on
    /// the FP8 grid — see [`super::loss::cross_entropy_grad`]).
    /// Gradients are accumulated into `grads`.
    pub fn backward_batch(
        &self,
        tape: &StackTape,
        dlogits: &[Vec<f32>],
        grads: &mut StackGrads,
    ) {
        self.backward_batch_carry(tape, dlogits, None, grads);
    }

    /// [`Self::backward_batch`] with per-layer state-cotangent carry —
    /// the stack-level seq2seq bridge (`tasks::mt`).
    ///
    /// * `dlogits` may be **empty** for a stack whose head never fed a
    ///   loss (the seq2seq encoder): the head stage is skipped and the
    ///   top layer's incoming cotangents start at zero, leaving only
    ///   the carry to drive the backward pass.
    /// * `carry_in[l]` (when present) is layer `l`'s final-state
    ///   cotangent arriving from downstream (e.g. the decoder's
    ///   initial-state cotangent for the encoder's layer `l`).
    /// * Returns, per layer, the cotangent of the state that *entered*
    ///   the window — the carry to hand further upstream.
    ///
    /// `carry_in = None` with non-empty `dlogits` is exactly
    /// [`Self::backward_batch`].
    pub fn backward_batch_carry(
        &self,
        tape: &StackTape,
        dlogits: &[Vec<f32>],
        carry_in: Option<&[StateCot]>,
        grads: &mut StackGrads,
    ) -> Vec<StateCot> {
        let b_n = tape.batch;
        let n_out = self.n_out();
        let h_top = self.layers.last().expect("stack has layers").fwd.hidden;
        let t_n = tape.tops.len();
        assert_eq!(tape.ids.len(), t_n);
        if let Some(cs) = carry_in {
            assert_eq!(cs.len(), self.layers.len(), "one carry per layer");
        }

        // dense head: dh_top[t] = Wᵀ·dlogits[t]; dW += dlogits ⊗ top.
        // A loss-less stack (empty dlogits) starts from zero cotangents.
        let mut dh_seq: Vec<Vec<f32>> = if dlogits.is_empty() {
            (0..t_n).map(|_| vec![0f32; b_n * h_top]).collect()
        } else {
            assert_eq!(dlogits.len(), t_n);
            let mut dh_seq = Vec::with_capacity(t_n);
            for t in 0..t_n {
                let dl = &dlogits[t];
                assert_eq!(dl.len(), b_n * n_out);
                let mut dh = vec![0f32; b_n * h_top];
                matmul_t_fast(&self.head.w, dl, b_n, &mut dh);
                quantize_fp8_inplace(&mut dh);
                for b in 0..b_n {
                    let dlb = &dl[b * n_out..(b + 1) * n_out];
                    outer_acc(dlb, &tape.tops[t][b * h_top..(b + 1) * h_top], &mut grads.head_w);
                    for (a, g) in grads.head_b.iter_mut().zip(dlb) {
                        *a += g;
                    }
                }
                dh_seq.push(dh);
            }
            dh_seq
        };

        // LSTM layers, top-down: each layer's dx becomes the next
        // lower layer's incoming dh; collect each layer's carry-out
        let mut carries: Vec<StateCot> = Vec::with_capacity(self.layers.len());
        for l in (0..self.layers.len()).rev() {
            let cell = &self.layers[l].fwd;
            let (dx, cot) = cell.backward_batch_carry(
                &tape.layers[l],
                &dh_seq,
                carry_in.map(|cs| &cs[l]),
                &mut grads.layers[l],
            );
            dh_seq = dx;
            carries.push(cot);
        }
        carries.reverse(); // back to layer-index order

        // embedding scatter: dL/demb[id] += dx0 (STE through the FP8
        // lookup rounding)
        let dim = self.embed.dim;
        for t in 0..t_n {
            for b in 0..b_n {
                let id = tape.ids[t][b];
                let row = &mut grads.emb[id * dim..(id + 1) * dim];
                for (a, g) in row.iter_mut().zip(&dh_seq[t][b * dim..(b + 1) * dim]) {
                    *a += g;
                }
            }
        }
        carries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::round_f8;
    use crate::lstm::cell::BatchScratch;
    use crate::lstm::reference::F32LstmCell;
    use crate::rng::SplitMix64;

    /// The quantized BPTT must point in the same direction as the
    /// full-precision reference BPTT on the same (well-conditioned)
    /// problem — the paper's trainability premise, gradient edition.
    #[test]
    fn quantized_gradients_align_with_reference() {
        let (d, hdim, t_n) = (4usize, 6usize, 5usize);
        let mut rng = SplitMix64::new(17);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hdim).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let qcell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        let rcell = F32LstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);

        let xs: Vec<Vec<f32>> = (0..t_n)
            .map(|_| (0..d).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect())
            .collect();
        let dh_seq: Vec<Vec<f32>> = (0..t_n)
            .map(|_| (0..hdim).map(|_| round_f8(rng.uniform(-0.5, 0.5))).collect())
            .collect();

        // quantized path
        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scr = BatchScratch::new(hdim, 1);
        let mut tape = CellTape::new(1, d, hdim);
        for x in &xs {
            qcell.step_traced(x, &mut h, &mut c, &mut scr, &mut tape);
        }
        let mut grads = CellGrads::zeros(&qcell);
        qcell.backward(&tape, &dh_seq, &mut grads);

        // reference path
        let rtape = rcell.forward_traced(&xs);
        let dh64: Vec<Vec<f64>> = dh_seq
            .iter()
            .map(|v| v.iter().map(|&x| x as f64).collect())
            .collect();
        let rgrads = rcell.bptt(&rtape, &dh64);

        let cosine = |a: &[f32], b: &[f64]| {
            let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
            for (x, y) in a.iter().zip(b) {
                dot += *x as f64 * y;
                na += (*x as f64) * (*x as f64);
                nb += y * y;
            }
            dot / (na.sqrt() * nb.sqrt()).max(1e-12)
        };
        // Loose directional bounds: the quantized path differs from the
        // reference by FP8 gradient quantization, STE slopes at
        // quantized operating points, and FP16 accumulation — the
        // descent *direction* must survive all of that (the paper's
        // premise), but bitwise agreement is not expected.
        assert!(
            cosine(&grads.dwx, &rgrads.dwx) > 0.5,
            "dwx misaligned: cos={}",
            cosine(&grads.dwx, &rgrads.dwx)
        );
        assert!(
            cosine(&grads.dwh, &rgrads.dwh) > 0.4,
            "dwh misaligned: cos={}",
            cosine(&grads.dwh, &rgrads.dwh)
        );
        assert!(
            cosine(&grads.db, &rgrads.db) > 0.5,
            "db misaligned: cos={}",
            cosine(&grads.db, &rgrads.db)
        );
    }

    fn clone_step(s: &crate::train::tape::TapeStep) -> crate::train::tape::TapeStep {
        crate::train::tape::TapeStep {
            x: s.x.clone(),
            h_prev: s.h_prev.clone(),
            c_prev: s.c_prev.clone(),
            z: s.z.clone(),
            c_new: s.c_new.clone(),
        }
    }

    /// A carried-in `dh` must be numerically interchangeable with the
    /// same cotangent arriving through `dh_seq` at the last step (both
    /// feed the same `dh_in + dh_rec` sum), and splitting a window in
    /// two with the carry must be bit-identical to the unsplit
    /// backward — the contract the seq2seq encoder/decoder bridge
    /// rests on.
    #[test]
    fn carry_is_equivalent_to_unsplit_backward() {
        let (d, hdim, b_n, t_n) = (3usize, 5usize, 2usize, 4usize);
        let mut rng = SplitMix64::new(23);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hdim).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let cell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);

        let mut h = vec![0f32; b_n * hdim];
        let mut c = vec![0f32; b_n * hdim];
        let mut scr = BatchScratch::new(hdim, b_n);
        let mut tape = CellTape::new(b_n, d, hdim);
        for _ in 0..t_n {
            let x: Vec<f32> =
                (0..b_n * d).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect();
            cell.step_batch_traced(&x, &mut h, &mut c, b_n, &mut scr, &mut tape);
        }
        let dh_seq: Vec<Vec<f32>> = (0..t_n)
            .map(|_| (0..b_n * hdim).map(|_| round_f8(rng.uniform(-0.5, 0.5))).collect())
            .collect();

        // 1) dh carried in == the same dh arriving via dh_seq (dc = 0)
        {
            let last = tape.steps.len() - 1;
            let one = CellTape {
                batch: b_n,
                input_dim: d,
                hidden: hdim,
                steps: vec![clone_step(&tape.steps[last])],
            };
            let carry = StateCot { dh: dh_seq[last].clone(), dc: vec![0.0; b_n * hdim] };
            let mut ga = CellGrads::zeros(&cell);
            let (dxa, _) = cell.backward_batch_carry(
                &one,
                &[vec![0.0; b_n * hdim]],
                Some(&carry),
                &mut ga,
            );
            let mut gb = CellGrads::zeros(&cell);
            let dxb = cell.backward_batch(&one, &[dh_seq[last].clone()], &mut gb);
            assert_eq!(ga.dwx, gb.dwx);
            assert_eq!(ga.db, gb.db);
            assert_eq!(dxa, dxb);
        }

        // 2) split window + carry == unsplit window. The propagated
        // cotangents (dx, dz, the carry itself) are bit-identical —
        // they never depend on how parameter grads are folded; the
        // parameter grads themselves differ only by f32 summation
        // association (window-major vs split-major), so they get a
        // tight tolerance instead of bit equality.
        let mut g_full = CellGrads::zeros(&cell);
        let (dx_full, cot_full) =
            cell.backward_batch_carry(&tape, &dh_seq, None, &mut g_full);

        let split = 2usize;
        let hi = CellTape {
            batch: b_n,
            input_dim: d,
            hidden: hdim,
            steps: tape.steps[split..].iter().map(clone_step).collect(),
        };
        let lo = CellTape {
            batch: b_n,
            input_dim: d,
            hidden: hdim,
            steps: tape.steps[..split].iter().map(clone_step).collect(),
        };
        let mut g_split = CellGrads::zeros(&cell);
        let (dx_hi, mid) =
            cell.backward_batch_carry(&hi, &dh_seq[split..], None, &mut g_split);
        let (dx_lo, cot_split) =
            cell.backward_batch_carry(&lo, &dh_seq[..split], Some(&mid), &mut g_split);

        let close = |a: &[f32], b: &[f32], what: &str| {
            for (k, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4 * x.abs().max(1.0),
                    "{what}[{k}]: {x} vs {y}"
                );
            }
        };
        close(&g_full.dwx, &g_split.dwx, "dwx");
        close(&g_full.dwh, &g_split.dwh, "dwh");
        close(&g_full.db, &g_split.db, "db");
        assert_eq!(cot_full.dh, cot_split.dh);
        assert_eq!(cot_full.dc, cot_split.dc);
        for (t, want) in dx_full.iter().enumerate() {
            let got = if t < split { &dx_lo[t] } else { &dx_hi[t - split] };
            assert_eq!(got, want, "dx diverged at t={t}");
        }
    }

    /// Zero incoming cotangents must produce exactly zero gradients.
    #[test]
    fn zero_cotangent_gives_zero_grads() {
        let (d, hdim, t_n) = (3usize, 5usize, 4usize);
        let mut rng = SplitMix64::new(2);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b = vec![0.0; 4 * hdim];
        let cell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scr = BatchScratch::new(hdim, 1);
        let mut tape = CellTape::new(1, d, hdim);
        for _ in 0..t_n {
            let x: Vec<f32> = (0..d).map(|_| round_f8(rng.uniform(-1.0, 1.0))).collect();
            cell.step_traced(&x, &mut h, &mut c, &mut scr, &mut tape);
        }
        let dh_seq = vec![vec![0f32; hdim]; t_n];
        let mut grads = CellGrads::zeros(&cell);
        let dx = cell.backward(&tape, &dh_seq, &mut grads);
        assert!(grads.dwx.iter().all(|&g| g == 0.0));
        assert!(grads.dwh.iter().all(|&g| g == 0.0));
        assert!(grads.db.iter().all(|&g| g == 0.0));
        assert!(dx.iter().flatten().all(|&g| g == 0.0));
    }
}
