//! The offline training loop: `data::lm` char-LM stream → traced
//! forward → cross-entropy → quantized BPTT → FP16-master/FloatSD8
//! update, with truncated-BPTT state carried across windows (the
//! `lm` lanes are contiguous streams, so each training batch is one
//! truncation window of the same B parallel streams).
//!
//! Since the lane-sharded refactor the window itself runs on the
//! [`super::parallel`] engine: the batch lanes are split into fixed
//! shards (a function of the batch size alone), each shard's traced
//! forward + BPTT runs on whichever of the `cfg.threads` scoped
//! threads picks it up, and a fixed-order tree reduction merges the
//! shard gradients — so `--threads N` is **bit-identical** to
//! `--threads 1` (pinned by `tests/train_parallel.rs`).
//!
//! Behind `floatsd-lstm train`: trains a char-LM from scratch,
//! entirely in pure rust, and writes a `.tensors` checkpoint that
//! `floatsd-lstm serve --model <ckpt>` loads directly — the
//! train→checkpoint→serve loop in one binary.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::data::lm::LmGen;
use crate::data::BatchSource;
use crate::lstm::QLstmStack;
use crate::qmath::{IsaPath, KernelTier};
use crate::telemetry::{self, trace, ActSnapshot, SpanTimer, TraceSink};
use crate::tensorfile::json::Json;
use crate::tensorfile::{write_tensors, Tensor};

use super::backward::StackGrads;
use super::loss::cross_entropy_grad;
use super::optimizer::{finalize_grads, LossScaler, MasterStack, ScaleEvent};
use super::parallel::{
    check_threads, lane_slice_ids, merge_finalize_overlapped, merge_shards, run_shards, LaneShard,
};

/// The three size tiers every trainer CLI accepts via `--preset`:
/// `tiny` (CI smoke scale), `default` (the historical miniature), and
/// `paper` (the source paper's scale class — 10k-vocab LM, 2×256
/// hidden stacks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PresetTier {
    Tiny,
    Default,
    Paper,
}

impl PresetTier {
    pub fn parse(s: &str) -> Result<PresetTier> {
        Ok(match s {
            "tiny" => PresetTier::Tiny,
            "default" => PresetTier::Default,
            "paper" => PresetTier::Paper,
            other => bail!("unknown preset {other:?} (expected tiny|default|paper)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PresetTier::Tiny => "tiny",
            PresetTier::Default => "default",
            PresetTier::Paper => "paper",
        }
    }
}

/// Configuration of one offline training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub vocab: usize,
    pub dim: usize,
    pub hidden: usize,
    pub layers: usize,
    pub batch: usize,
    /// truncated-BPTT window length
    pub seq: usize,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    pub loss_scale: f32,
    pub clip_norm: Option<f32>,
    pub log_every: usize,
    /// worker threads the lane shards are distributed over
    /// (numerics-neutral — see `train::parallel`)
    pub threads: usize,
    pub checkpoint: Option<PathBuf>,
    /// `--trace`: write a `floatsd-trace-v1` JSONL numerics-health
    /// stream here (numerics-neutral — see `crate::telemetry`)
    pub trace: Option<PathBuf>,
    /// `--trace-every N`: emit `step`/`reencode` trace events (and pay
    /// the gradient scan) only every N-th step; `run_start`/`run_end`/
    /// `loss_scale` always emit, so a sampled trace is a strict
    /// subsequence of the N=1 trace (numerics-neutral)
    pub trace_every: usize,
    /// `--kernel-tier`: forward matvec/matmul tier (runtime-only —
    /// never written into checkpoints; see `qmath::shiftadd`)
    pub kernel_tier: KernelTier,
    /// `--kernel-isa`: SIMD execution path of the forward kernels
    /// (runtime-only, bit-identical across paths; see `qmath::simd`)
    pub kernel_isa: IsaPath,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig::preset(PresetTier::Default)
    }
}

impl TrainConfig {
    /// The char-LM trainer's size tiers (`--preset`).
    pub fn preset(tier: PresetTier) -> TrainConfig {
        let mut cfg = TrainConfig {
            vocab: 64,
            dim: 16,
            hidden: 24,
            layers: 1,
            batch: 8,
            seq: 16,
            steps: 400,
            lr: 0.3,
            momentum: 0.9,
            seed: 42,
            loss_scale: 1024.0,
            clip_norm: None,
            log_every: 25,
            threads: 1,
            checkpoint: None,
            trace: None,
            trace_every: 1,
            kernel_tier: KernelTier::Decoded,
            kernel_isa: IsaPath::detect(),
        };
        match tier {
            PresetTier::Default => {}
            PresetTier::Tiny => {
                cfg.vocab = 32;
                cfg.dim = 8;
                cfg.hidden = 12;
                cfg.batch = 4;
                cfg.seq = 8;
                cfg.steps = 60;
                cfg.log_every = 0;
            }
            PresetTier::Paper => {
                cfg.vocab = 10_000;
                cfg.dim = 128;
                cfg.hidden = 256;
                cfg.layers = 2;
                cfg.batch = 16;
                cfg.seq = 32;
                cfg.steps = 200;
                cfg.lr = 0.1;
                cfg.log_every = 10;
            }
        }
        cfg
    }

    /// Turn every would-be constructor panic into a descriptive error
    /// (the `data::make_source` validation style): shape floors,
    /// window length, lane/thread consistency.
    pub fn validate(&self) -> Result<()> {
        if self.vocab < 2 {
            bail!("train: vocab {} too small (need >= 2)", self.vocab);
        }
        if self.dim == 0 || self.hidden == 0 || self.layers == 0 {
            bail!("train: dim/hidden/layers must all be >= 1");
        }
        if self.batch == 0 {
            bail!("train: batch must be >= 1 — it is the lane count the shards split");
        }
        if self.seq < 2 {
            bail!("train: seq {} too short (need >= 2)", self.seq);
        }
        if self.steps == 0 {
            bail!("train: steps must be >= 1");
        }
        if self.trace_every == 0 {
            bail!("train: --trace-every must be >= 1 (N samples every N-th step)");
        }
        check_threads(self.threads)
    }
}

/// What one [`Trainer::step`] did.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// mean cross-entropy (nats/token) of this window, pre-update
    pub loss: f64,
    /// false when the loss scaler skipped the update (overflow)
    pub applied: bool,
    /// loss scale used for this window
    pub scale: f32,
}

/// Summary of a full [`Trainer::train`] run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub steps_applied: usize,
    pub steps_skipped: u64,
    pub final_scale: f32,
}

/// The offline quantized trainer (see module docs).
pub struct Trainer {
    pub cfg: TrainConfig,
    pub stack: QLstmStack,
    pub masters: MasterStack,
    pub scaler: LossScaler,
    /// merged (tree-reduced) gradients of the last window
    pub grads: StackGrads,
    data: LmGen,
    shards: Vec<LaneShard>,
    pub steps_done: usize,
    pub steps_applied: usize,
    /// open `--trace` sink, if any (never touches the value path)
    trace: Option<TraceSink>,
    /// activation-clip counter baselines at sink creation, so per-run
    /// deltas stay meaningful when other runs share the process
    act_base: (ActSnapshot, ActSnapshot),
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let (masters, mut stack) = MasterStack::init_with_stack(
            cfg.vocab,
            cfg.dim,
            cfg.hidden,
            cfg.layers,
            cfg.seed,
        );
        stack.set_kernel_tier(cfg.kernel_tier);
        stack.set_kernel_isa(cfg.kernel_isa);
        let data = LmGen::char_lm(cfg.batch, cfg.seq, cfg.vocab, cfg.seed ^ 0xDA7A);
        let shards = LaneShard::build(&stack, cfg.batch);
        let grads = StackGrads::zeros(&stack);
        let scaler = LossScaler::new(cfg.loss_scale);
        let mut trace = match &cfg.trace {
            Some(path) => Some(TraceSink::create(path)?),
            None => None,
        };
        let act_base = (telemetry::SIGMOID.snapshot(), telemetry::TANH.snapshot());
        if let Some(sink) = trace.as_mut() {
            let mut fields = BTreeMap::new();
            fields.insert("config".to_string(), config_json(&cfg));
            sink.emit("run_start", 0, fields);
        }
        Ok(Trainer {
            cfg,
            stack,
            masters,
            scaler,
            grads,
            data,
            shards,
            steps_done: 0,
            steps_applied: 0,
            trace,
            act_base,
        })
    }

    /// One truncated-BPTT window: every lane shard runs its traced
    /// forward + loss + BPTT (in parallel over `cfg.threads`), the
    /// fixed-order tree reduction merges the shard gradients — on
    /// untraced steps without a clip norm, overlapped slot-by-slot
    /// with the update's gradient finalize
    /// ([`merge_finalize_overlapped`]) — then the single
    /// FP16-master/FloatSD8 update applies (or the loss scaler skips
    /// on overflow).
    pub fn step(&mut self) -> StepOutcome {
        // wall-clock is telemetry-only: it lands in the trace's marked
        // `timing` field and never influences any computed value;
        // `--trace-every N` samples the per-step events (and skips the
        // gradient scan) on all but every N-th step
        let sampled = self.trace.is_some() && (self.steps_done + 1) % self.cfg.trace_every == 0;
        let timer = sampled.then(SpanTimer::start);
        let (b_n, seq, vocab) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab);
        let threads = self.cfg.threads;
        let batch = self.data.next_train();
        let mut ids = vec![vec![0usize; b_n]; seq];
        let mut targets = vec![vec![0usize; b_n]; seq];
        for lane in 0..b_n {
            for t in 0..seq {
                ids[t][lane] = batch.x[lane * seq + t] as usize;
                targets[t][lane] = batch.y[lane * seq + t] as usize;
            }
        }

        let scale = self.scaler.scale;
        let inv_count = 1.0 / (b_n * seq) as f32;
        let stack = &self.stack;
        let ids_ref = &ids;
        let targets_ref = &targets;
        run_shards(&mut self.shards, threads, |_, shard| {
            shard.begin_window();
            let ids_s = lane_slice_ids(ids_ref, shard.lo, shard.hi);
            let (tape, logits) = shard.forward_traced(stack, &ids_s);
            let lanes = shard.lanes();
            let mut loss = 0f64;
            let mut dlogits = Vec::with_capacity(seq);
            for t in 0..seq {
                let mut dl = vec![0f32; lanes * vocab];
                loss += cross_entropy_grad(
                    &logits[t],
                    &targets_ref[t][shard.lo..shard.hi],
                    vocab,
                    inv_count,
                    scale,
                    &mut dl,
                );
                dlogits.push(dl);
            }
            shard.loss = loss;
            shard.scored = lanes * seq;
            shard.backward(stack, &tape, &dlogits);
        });
        let (loss_sum, grads_ev, applied) = if sampled || self.cfg.clip_norm.is_some() {
            // classic two-phase path: the trace's gradient scan needs
            // the merged, still-scaled gradients, and a global clip
            // norm must see every slot before any scaling decision
            let (loss_sum, _scored) = {
                let Trainer { shards, grads, .. } = self;
                let mut refs: Vec<&mut LaneShard> = shards.iter_mut().collect();
                merge_shards(&mut refs, grads)
            };
            // telemetry: scan the merged, still-scaled gradients
            // *before* finalize_grads quantizes them in place
            // (read-only scan, only when a sink is open)
            let grads_ev = sampled.then(|| trace::grads_json(&self.grads.named_slices("")));
            let applied = finalize_grads(&mut self.grads, scale, self.cfg.clip_norm);
            (loss_sum, grads_ev, applied)
        } else {
            // hot path: fold the gradient tree slot by slot while a
            // worker thread finalizes each completed slot —
            // bit-identical to the two-phase path by the fixed
            // per-slot pairwise order (see `merge_finalize_overlapped`)
            let Trainer { shards, grads, .. } = self;
            let mut refs: Vec<&mut LaneShard> = shards.iter_mut().collect();
            let (loss_sum, _scored, applied) = merge_finalize_overlapped(&mut refs, grads, scale);
            (loss_sum, None, applied)
        };
        let scale_ev = if applied {
            self.masters.apply(&mut self.stack, &self.grads, self.cfg.lr, self.cfg.momentum);
            self.steps_applied += 1;
            self.scaler.on_good_step()
        } else {
            Some(self.scaler.on_overflow())
        };
        self.steps_done += 1;
        let loss = loss_sum / (b_n * seq) as f64;
        if self.trace.is_some() {
            self.emit_step_events(loss, applied, scale, scale_ev, grads_ev, timer, sampled);
        }
        StepOutcome { loss, applied, scale }
    }

    /// Emit this step's trace events: `loss_scale` on scaler action
    /// (always — scaler actions are too rare and too important to
    /// sample away), `step`/`reencode` only on steps sampled by
    /// `--trace-every`. Only called with an open sink.
    #[allow(clippy::too_many_arguments)]
    fn emit_step_events(
        &mut self,
        loss: f64,
        applied: bool,
        scale: f32,
        scale_ev: Option<ScaleEvent>,
        grads_ev: Option<Json>,
        timer: Option<SpanTimer>,
        sampled: bool,
    ) {
        let step = self.steps_done as u64;
        let skipped = self.scaler.skipped;
        let acts = sampled.then(|| {
            trace::acts_json(
                telemetry::SIGMOID.snapshot().since(self.act_base.0),
                telemetry::TANH.snapshot().since(self.act_base.1),
            )
        });
        let reencode = (sampled && applied)
            .then(|| trace::codes_json(&telemetry::stack_qmatrices(&self.stack, "")));
        let Some(sink) = self.trace.as_mut() else { return };
        if let Some(ev) = scale_ev {
            let (cause, from, to) = match ev {
                ScaleEvent::Backoff { from, to } => ("backoff", from, to),
                ScaleEvent::Growth { from, to } => ("growth", from, to),
            };
            sink.emit("loss_scale", step, trace::scale_fields(cause, from, to, skipped));
        }
        let Some(acts) = acts else { return };
        let mut fields = BTreeMap::new();
        fields.insert("loss".to_string(), trace::fnum(loss));
        fields.insert("scale".to_string(), Json::Num(f64::from(scale)));
        fields.insert("applied".to_string(), Json::Bool(applied));
        fields.insert("skipped_total".to_string(), Json::Num(skipped as f64));
        if let Some(g) = grads_ev {
            fields.insert("grads".to_string(), g);
        }
        fields.insert("acts".to_string(), acts);
        if let Some(t) = &timer {
            fields.insert("timing".to_string(), trace::timing_json(t.elapsed_ms()));
        }
        sink.emit("step", step, fields);
        if let Some(weights) = reencode {
            let mut fields = BTreeMap::new();
            fields.insert("weights".to_string(), weights);
            sink.emit("reencode", step, fields);
        }
    }

    /// Emit the `run_end` event and flush/close the trace sink,
    /// surfacing any deferred IO error. No-op without a sink.
    fn finish_trace(&mut self) -> Result<()> {
        if self.trace.is_none() {
            return Ok(());
        }
        let acts = trace::acts_json(
            telemetry::SIGMOID.snapshot().since(self.act_base.0),
            telemetry::TANH.snapshot().since(self.act_base.1),
        );
        let weights = trace::codes_json(&telemetry::stack_qmatrices(&self.stack, ""));
        let mut fields = BTreeMap::new();
        fields.insert("steps".to_string(), Json::Num(self.steps_done as f64));
        fields.insert("applied".to_string(), Json::Num(self.steps_applied as f64));
        fields.insert("skipped".to_string(), Json::Num(self.scaler.skipped as f64));
        fields.insert("final_scale".to_string(), Json::Num(f64::from(self.scaler.scale)));
        fields.insert("weights".to_string(), weights);
        fields.insert("acts".to_string(), acts);
        let sink = self.trace.as_mut().expect("checked above");
        sink.emit("run_end", self.steps_done as u64, fields);
        sink.finish()
    }

    /// Point-in-time numerics-health block for bench rows
    /// (`BENCH_train.json`): loss-scale totals + per-matrix FloatSD8
    /// code stats. Deterministic — no wall-clock fields.
    pub fn numerics_snapshot(&self) -> Json {
        let mut scale = BTreeMap::new();
        scale.insert("final".to_string(), Json::Num(f64::from(self.scaler.scale)));
        scale.insert("applied".to_string(), Json::Num(self.steps_applied as f64));
        scale.insert("skipped".to_string(), Json::Num(self.scaler.skipped as f64));
        scale.insert("steps".to_string(), Json::Num(self.steps_done as f64));
        let mut m = BTreeMap::new();
        m.insert("loss_scale".to_string(), Json::Obj(scale));
        m.insert(
            "weights".to_string(),
            trace::codes_json(&telemetry::stack_qmatrices(&self.stack, "")),
        );
        Json::Obj(m)
    }

    /// Run the configured number of steps; logs every
    /// `cfg.log_every` windows and writes the checkpoint at the end
    /// when `cfg.checkpoint` is set.
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut losses = Vec::with_capacity(self.cfg.steps);
        for s in 0..self.cfg.steps {
            let out = self.step();
            losses.push(out.loss);
            if self.cfg.log_every > 0 && (s + 1) % self.cfg.log_every == 0 {
                let window = &losses[losses.len().saturating_sub(self.cfg.log_every)..];
                let mean: f64 = window.iter().sum::<f64>() / window.len() as f64;
                println!(
                    "step {:>5}  loss {:.4}  scale {:>7.0}  skipped {:>4}{}",
                    s + 1,
                    mean,
                    out.scale,
                    self.scaler.skipped,
                    if out.applied { "" } else { "  (skipped)" }
                );
            }
        }
        self.finish_trace()?;
        if let Some(path) = self.cfg.checkpoint.clone() {
            self.save_checkpoint(&path)?;
            println!("checkpoint: {}", path.display());
        }
        Ok(TrainReport {
            losses,
            steps_applied: self.steps_applied,
            steps_skipped: self.scaler.skipped,
            final_scale: self.scaler.scale,
        })
    }

    /// Write the FP16 master weights as a `.tensors` checkpoint in the
    /// JAX-layout naming `build_tiny_from_params` (and therefore
    /// `floatsd-lstm serve --model`) consumes. Re-loading quantizes
    /// the masters exactly like the live stack does, so the served
    /// model's logits are **bit-identical** to this trainer's
    /// (pinned by `tests/train_offline.rs`).
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let ms = &self.masters;
        let (vocab, dim) = (self.stack.embed.vocab, self.stack.embed.dim);
        let mut tensors =
            vec![Tensor::from_f32("['params']['emb']['emb']", &[vocab, dim], &ms.emb)];
        let mut in_dim = dim;
        for (l, m) in ms.layers.iter().enumerate() {
            let hidden = self.stack.layers[l].fwd.hidden;
            // QMatrix layout [4H][in] -> JAX layout [in][4H]
            let mut wx = vec![0f32; m.wx.len()];
            for r in 0..4 * hidden {
                for k in 0..in_dim {
                    wx[k * 4 * hidden + r] = m.wx[r * in_dim + k];
                }
            }
            let mut wh = vec![0f32; m.wh.len()];
            for r in 0..4 * hidden {
                for k in 0..hidden {
                    wh[k * 4 * hidden + r] = m.wh[r * hidden + k];
                }
            }
            let idx = l + 1;
            tensors.push(Tensor::from_f32(
                &format!("['params']['l{idx}']['wx']"),
                &[in_dim, 4 * hidden],
                &wx,
            ));
            tensors.push(Tensor::from_f32(
                &format!("['params']['l{idx}']['wh']"),
                &[hidden, 4 * hidden],
                &wh,
            ));
            tensors.push(Tensor::from_f32(
                &format!("['params']['l{idx}']['b']"),
                &[4 * hidden],
                &m.b,
            ));
            in_dim = hidden;
        }
        let n_out = self.stack.n_out();
        let mut ow = vec![0f32; ms.head_w.len()];
        for r in 0..n_out {
            for k in 0..in_dim {
                ow[k * n_out + r] = ms.head_w[r * in_dim + k];
            }
        }
        tensors.push(Tensor::from_f32("['params']['out']['w']", &[in_dim, n_out], &ow));
        tensors.push(Tensor::from_f32("['params']['out']['b']", &[n_out], &ms.head_b));
        tensors.push(Tensor::scalar_f32("meta/steps", self.steps_done as f32));
        tensors.push(Tensor::scalar_f32("meta/loss_scale", self.scaler.scale));
        write_tensors(path, &tensors)
    }
}

/// The char-LM trainer's `run_start` config block (deterministic:
/// fixed keys, seed rendered as a decimal string to dodge f64
/// rounding of large u64 seeds).
fn config_json(cfg: &TrainConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Json::Str("char_lm".to_string()));
    m.insert("vocab".to_string(), Json::Num(cfg.vocab as f64));
    m.insert("dim".to_string(), Json::Num(cfg.dim as f64));
    m.insert("hidden".to_string(), Json::Num(cfg.hidden as f64));
    m.insert("layers".to_string(), Json::Num(cfg.layers as f64));
    m.insert("batch".to_string(), Json::Num(cfg.batch as f64));
    m.insert("seq".to_string(), Json::Num(cfg.seq as f64));
    m.insert("steps".to_string(), Json::Num(cfg.steps as f64));
    m.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    m.insert("seed".to_string(), Json::Str(cfg.seed.to_string()));
    m.insert("loss_scale".to_string(), Json::Num(f64::from(cfg.loss_scale)));
    Json::Obj(m)
}

/// `floatsd-lstm train` (offline path) — see `main.rs` docs.
pub fn run_cli(args: &Args) -> Result<()> {
    let tier = PresetTier::parse(args.opt("preset").unwrap_or("default"))?;
    let preset = TrainConfig::preset(tier);
    let parse_f32 = |key: &str, default: f32| -> Result<f32> {
        match args.opt(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse::<f32>()?),
        }
    };
    let cfg = TrainConfig {
        vocab: args.opt_usize("vocab", preset.vocab)?,
        dim: args.opt_usize("dim", preset.dim)?,
        hidden: args.opt_usize("hidden", preset.hidden)?,
        layers: args.opt_usize("layers", preset.layers)?,
        batch: args.opt_usize("batch", preset.batch)?,
        seq: args.opt_usize("seq", preset.seq)?,
        steps: args.opt_usize("steps", preset.steps)?,
        lr: parse_f32("lr", preset.lr)?,
        momentum: parse_f32("momentum", preset.momentum)?,
        seed: args.opt_u64("seed", preset.seed)?,
        loss_scale: parse_f32("loss-scale", preset.loss_scale)?,
        clip_norm: match args.opt("clip") {
            None => None,
            Some(v) => Some(v.parse::<f32>()?),
        },
        log_every: args.opt_usize("log-every", preset.log_every)?,
        threads: args.opt_usize("threads", preset.threads)?,
        checkpoint: Some(PathBuf::from(args.opt_or("out", "char_lm.tensors"))),
        trace: args.opt("trace").map(PathBuf::from),
        trace_every: args.opt_usize("trace-every", 1)?,
        kernel_tier: KernelTier::parse(args.opt_or("kernel-tier", "decoded"))?,
        kernel_isa: IsaPath::parse(args.opt_or("kernel-isa", "auto"))?,
    };
    println!(
        "offline FloatSD8 training [{} preset]: vocab={} dim={} hidden={} layers={} | batch={} \
         seq={} steps={} threads={} lr={} momentum={} loss-scale={}",
        tier.name(),
        cfg.vocab,
        cfg.dim,
        cfg.hidden,
        cfg.layers,
        cfg.batch,
        cfg.seq,
        cfg.steps,
        cfg.threads,
        cfg.lr,
        cfg.momentum,
        cfg.loss_scale
    );
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.train()?;
    let head: f64 = report.losses.iter().take(10).sum::<f64>()
        / report.losses.len().min(10).max(1) as f64;
    let n = report.losses.len();
    let tail: f64 = report.losses[n.saturating_sub(10)..].iter().sum::<f64>()
        / report.losses[n.saturating_sub(10)..].len().max(1) as f64;
    println!(
        "done: loss {head:.4} -> {tail:.4} ({} applied, {} skipped, final scale {})",
        report.steps_applied, report.steps_skipped, report.final_scale
    );
    println!("serve it: floatsd-lstm serve --model <checkpoint> --sessions 8 --tokens 32");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            vocab: 32,
            dim: 8,
            hidden: 10,
            layers: 1,
            batch: 4,
            seq: 8,
            steps: 12,
            lr: 0.3,
            momentum: 0.9,
            seed: 5,
            loss_scale: 1024.0,
            clip_norm: None,
            log_every: 0,
            threads: 1,
            checkpoint: None,
            trace: None,
            trace_every: 1,
            kernel_tier: KernelTier::Decoded,
            kernel_isa: IsaPath::detect(),
        }
    }

    #[test]
    fn steps_run_and_loss_is_sane() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        let out = t.step();
        assert!(out.loss.is_finite());
        // first-window loss must sit near ln(vocab) at random init
        let uniform = (32f64).ln();
        assert!((out.loss - uniform).abs() < 1.5, "loss {} vs ln V {}", out.loss, uniform);
        assert_eq!(t.steps_done, 1);
    }

    #[test]
    fn weights_stay_on_their_grids_after_updates() {
        let mut t = Trainer::new(tiny_cfg()).unwrap();
        for _ in 0..3 {
            t.step();
        }
        let cell = &t.stack.layers[0].fwd;
        for r in 0..4 * cell.hidden {
            for &v in cell.wx.row_decoded(r) {
                assert!(crate::formats::FLOAT_SD8.values().contains(&v));
            }
        }
        for &b in &cell.bias {
            assert_eq!(b, crate::formats::round_f16(b));
        }
        for &e in &t.stack.embed.table {
            assert_eq!(e, crate::formats::round_f16(e));
        }
    }

    #[test]
    fn degenerate_configs_error_instead_of_panicking() {
        let mut cfg = tiny_cfg();
        cfg.threads = 0;
        let err = Trainer::new(cfg).unwrap_err().to_string();
        assert!(err.contains("--threads"), "got: {err}");
        let mut cfg = tiny_cfg();
        cfg.seq = 1;
        assert!(Trainer::new(cfg).is_err());
        let mut cfg = tiny_cfg();
        cfg.batch = 0;
        assert!(Trainer::new(cfg).is_err());
        let mut cfg = tiny_cfg();
        cfg.trace_every = 0;
        assert!(Trainer::new(cfg).is_err());
        assert!(PresetTier::parse("papr").is_err());
        assert_eq!(PresetTier::parse("paper").unwrap(), PresetTier::Paper);
    }

    #[test]
    fn preset_tiers_scale_monotonically() {
        let tiny = TrainConfig::preset(PresetTier::Tiny);
        let default = TrainConfig::preset(PresetTier::Default);
        let paper = TrainConfig::preset(PresetTier::Paper);
        assert!(tiny.vocab < default.vocab && default.vocab < paper.vocab);
        assert!(tiny.hidden < default.hidden && default.hidden < paper.hidden);
        assert_eq!(paper.vocab, 10_000, "paper tier: 10k-class LM");
        assert_eq!(paper.hidden, 256, "paper tier: 256-wide stacks");
        assert_eq!(paper.layers, 2, "paper tier: 2-layer stacks");
        for cfg in [tiny, default, paper] {
            cfg.validate().expect("presets must validate");
        }
    }
}
