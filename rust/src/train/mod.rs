//! Pure-rust **quantized training engine** — the paper's §III/§IV
//! training scheme (FloatSD8 weights, FP8 gradients/activations, FP16
//! accumulations and master copies) implemented offline, with no
//! Python/XLA in the loop. This is the training counterpart of the
//! [`crate::lstm`] inference engine and shares its kernels:
//!
//! * [`tape`] — tape-recording forwards (`step_batch_traced`,
//!   `forward_batch_traced`) that run the *identical* inference
//!   kernels and cache what BPTT needs;
//! * [`backward`] — truncated-BPTT backward passes
//!   (`QLstmCell::backward`/`backward_batch`,
//!   `QLstmStack::backward_batch`) under the paper's quantization
//!   discipline, on the gradient kernels in [`crate::qmath::grad`];
//! * [`loss`] — cross-entropy heads (dense LM targets + the masked
//!   task-head variant) with loss-scaled FP8 cotangents;
//! * [`optimizer`] — FP16 master copies + SGD-momentum + dynamic loss
//!   scaling; the §III-B re-encode-to-FloatSD8 step lives in
//!   [`crate::formats::FloatSdFormat::apply_update`];
//! * [`trainer`] — the `floatsd-lstm train` loop over the
//!   [`crate::data::lm`] char-LM stream, writing `.tensors`
//!   checkpoints the serve subsystem loads directly;
//! * [`parallel`] — the lane-sharded data-parallel window engine
//!   (`std::thread` shards + a fixed-order tree reduction) that makes
//!   `--threads N` bit-identical to `--threads 1`; both [`trainer`]
//!   and the generic [`crate::tasks::TaskTrainer`] run their windows
//!   on it.
//!
//! The multi-task layer ([`crate::tasks`]) builds on these same
//! pieces: [`backward`] additionally exposes the carry-aware
//! `backward_batch_carry` (the seq2seq encoder→decoder gradient
//! bridge), and [`optimizer`] the head-width-generalized
//! `init_with_stack_dims`.
//!
//! Numerics contracts (all pinned in tier-1 tests):
//! traced forward ≡ inference forward bit-for-bit;
//! `backward_batch` ≡ B independent `backward` calls bit-for-bit
//! (`tests/batched_equivalence.rs`); the BPTT equation set matches
//! central finite differences on the f32 reference cell
//! (`tests/gradcheck.rs`); training reduces char-LM loss and its
//! checkpoints serve bit-identically (`tests/train_offline.rs`).

pub mod backward;
pub mod loss;
pub mod optimizer;
pub mod parallel;
pub mod tape;
pub mod trainer;

pub use backward::{CellGrads, StackGrads, StateCot};
pub use loss::{cross_entropy_grad, eval_ce, masked_cross_entropy_grad};
pub use optimizer::{finalize_grads, LossScaler, MasterStack, ScaleEvent};
pub use parallel::{
    check_threads, lane_slice_ids, lane_spans, merge_finalize_overlapped, merge_shards,
    run_shards, LaneShard, LANE_SHARDS_MAX,
};
pub use tape::{CellTape, StackTape};
pub use trainer::{run_cli, PresetTier, StepOutcome, TrainConfig, TrainReport, Trainer};
