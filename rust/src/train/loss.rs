//! Cross-entropy heads: loss + loss-scaled FP8 logit cotangents.
//!
//! The loss itself is measured in f64 (it is a *metric*, never fed
//! back into the quantized datapath); the cotangent
//! `(softmax − onehot) / count × scale` is what enters the backward
//! pass and is therefore FP8-quantized at the source, like every other
//! gradient in the scheme (Table II + §IV-A loss scaling).
//!
//! [`cross_entropy_grad`] is the LM head (dense targets, every
//! position scored). [`masked_cross_entropy_grad`] is the generic
//! task-head variant (`tasks::{pos,nli,mt}`): i32 targets straight
//! from a [`crate::data::Batch`], with an optional ignored class (PAD)
//! whose positions contribute zero loss *and* zero cotangent.

use crate::formats::round_f8;

/// Softmax cross-entropy over one step's flat logits `[B*vocab]`.
///
/// Writes the scaled, FP8-quantized cotangents into `dlogits` (same
/// shape) and returns the **unscaled** summed loss over the `B`
/// tokens. `inv_count` is `1 / (batch · seq)` (mean reduction over the
/// whole window), `scale` the current dynamic loss scale.
pub fn cross_entropy_grad(
    logits: &[f32],
    targets: &[usize],
    vocab: usize,
    inv_count: f32,
    scale: f32,
    dlogits: &mut [f32],
) -> f64 {
    assert_eq!(logits.len(), targets.len() * vocab);
    assert_eq!(dlogits.len(), logits.len());
    let mut loss = 0f64;
    for (b, &y) in targets.iter().enumerate() {
        assert!(y < vocab, "target {y} out of vocab {vocab}");
        let lg = &logits[b * vocab..(b + 1) * vocab];
        let mx = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        for &v in lg {
            denom += (v - mx).exp();
        }
        loss += (denom.ln() + mx - lg[y]) as f64;
        let dl = &mut dlogits[b * vocab..(b + 1) * vocab];
        for (v, out) in dl.iter_mut().enumerate() {
            let p = (lg[v] - mx).exp() / denom;
            let onehot = if v == y { 1.0 } else { 0.0 };
            *out = round_f8((p - onehot) * inv_count * scale);
        }
    }
    loss
}

/// Masked softmax cross-entropy over one step's flat logits
/// `[B*n_out]` — the task-head sibling of [`cross_entropy_grad`].
///
/// `targets` are raw i32 labels (one per stream); positions whose
/// label equals `ignore` (the PAD convention of `data::nli` /
/// `data::translation`) are masked out: zero loss, zero cotangent.
/// Writes scaled, FP8-quantized cotangents into `dlogits` and returns
/// `(unscaled summed loss, scored-position count)`.
pub fn masked_cross_entropy_grad(
    logits: &[f32],
    targets: &[i32],
    n_out: usize,
    ignore: Option<i32>,
    inv_count: f32,
    scale: f32,
    dlogits: &mut [f32],
) -> (f64, usize) {
    assert_eq!(logits.len(), targets.len() * n_out);
    assert_eq!(dlogits.len(), logits.len());
    let mut loss = 0f64;
    let mut scored = 0usize;
    for (b, &t) in targets.iter().enumerate() {
        let dl = &mut dlogits[b * n_out..(b + 1) * n_out];
        if ignore == Some(t) {
            dl.fill(0.0);
            continue;
        }
        assert!(t >= 0 && (t as usize) < n_out, "target {t} out of range {n_out}");
        let y = t as usize;
        scored += 1;
        let lg = &logits[b * n_out..(b + 1) * n_out];
        let mx = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        for &v in lg {
            denom += (v - mx).exp();
        }
        loss += (denom.ln() + mx - lg[y]) as f64;
        for (v, out) in dl.iter_mut().enumerate() {
            let p = (lg[v] - mx).exp() / denom;
            let onehot = if v == y { 1.0 } else { 0.0 };
            *out = round_f8((p - onehot) * inv_count * scale);
        }
    }
    (loss, scored)
}

/// Metric-side cross-entropy of one logit row (nats, f64; no
/// cotangent) — the evaluation harness' loss, kept next to the
/// training heads so the two always share the same softmax convention.
pub fn eval_ce(logits: &[f32], target: usize) -> f64 {
    assert!(target < logits.len());
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut denom = 0f64;
    for &v in logits {
        denom += (v as f64 - mx).exp();
    }
    denom.ln() + mx - logits[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_vocab() {
        let vocab = 8;
        let logits = vec![0f32; 2 * vocab];
        let mut dl = vec![0f32; 2 * vocab];
        let loss = cross_entropy_grad(&logits, &[3, 5], vocab, 1.0, 1.0, &mut dl);
        let want = 2.0 * (vocab as f64).ln();
        assert!((loss - want).abs() < 1e-5, "loss {loss} vs {want}");
    }

    #[test]
    fn cotangent_signs_and_grid() {
        let vocab = 4;
        let logits = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut dl = vec![0f32; 4];
        cross_entropy_grad(&logits, &[2], vocab, 1.0, 64.0, &mut dl);
        // target entry negative, all others positive, all on FP8 grid
        assert!(dl[2] < 0.0, "target cotangent must push its logit up");
        for (v, &g) in dl.iter().enumerate() {
            if v != 2 {
                assert!(g > 0.0, "non-target {v} must be pushed down");
            }
            assert_eq!(g, round_f8(g));
        }
    }

    #[test]
    fn perfect_prediction_has_near_zero_loss() {
        let vocab = 4;
        let mut logits = vec![0f32; 4];
        logits[1] = 30.0;
        let mut dl = vec![0f32; 4];
        let loss = cross_entropy_grad(&logits, &[1], vocab, 1.0, 1.0, &mut dl);
        assert!(loss < 1e-6, "confident correct prediction: loss {loss}");
    }

    #[test]
    fn masked_ce_matches_unmasked_on_dense_targets() {
        let n_out = 5;
        let logits = vec![0.3f32, -1.0, 2.0, 0.0, 0.5, 1.0, 1.0, -2.0, 0.25, 0.0];
        let mut dl_a = vec![0f32; 10];
        let mut dl_b = vec![0f32; 10];
        let la = cross_entropy_grad(&logits, &[2, 4], n_out, 0.5, 64.0, &mut dl_a);
        let (lb, n) =
            masked_cross_entropy_grad(&logits, &[2, 4], n_out, None, 0.5, 64.0, &mut dl_b);
        assert_eq!(n, 2);
        assert_eq!(la.to_bits(), lb.to_bits());
        assert_eq!(dl_a, dl_b);
    }

    #[test]
    fn masked_positions_are_silent() {
        let n_out = 3;
        let logits = vec![1.0f32, 0.0, -1.0, 0.5, 0.5, 0.5];
        let mut dl = vec![9.0f32; 6];
        let (loss, n) =
            masked_cross_entropy_grad(&logits, &[0, 2], n_out, Some(0), 1.0, 8.0, &mut dl);
        assert_eq!(n, 1, "PAD lane must not be scored");
        assert!(dl[..3].iter().all(|&g| g == 0.0), "PAD cotangent must be zero");
        assert!(dl[3..].iter().any(|&g| g != 0.0));
        let want = eval_ce(&logits[3..], 2);
        assert!((loss - want).abs() < 1e-5);
    }

    #[test]
    fn eval_ce_agrees_with_training_loss() {
        let logits = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut dl = vec![0f32; 4];
        let train = cross_entropy_grad(&logits, &[2], 4, 1.0, 1.0, &mut dl);
        // eval_ce accumulates in f64, the training loss in f32 — the
        // two agree to f32 rounding, not bitwise
        let eval = eval_ce(&logits, 2);
        assert!((train - eval).abs() < 1e-5, "{train} vs {eval}");
    }
}
