//! Cross-entropy LM head: loss + loss-scaled FP8 logit cotangents.
//!
//! The loss itself is measured in f64 (it is a *metric*, never fed
//! back into the quantized datapath); the cotangent
//! `(softmax − onehot) / count × scale` is what enters the backward
//! pass and is therefore FP8-quantized at the source, like every other
//! gradient in the scheme (Table II + §IV-A loss scaling).

use crate::formats::round_f8;

/// Softmax cross-entropy over one step's flat logits `[B*vocab]`.
///
/// Writes the scaled, FP8-quantized cotangents into `dlogits` (same
/// shape) and returns the **unscaled** summed loss over the `B`
/// tokens. `inv_count` is `1 / (batch · seq)` (mean reduction over the
/// whole window), `scale` the current dynamic loss scale.
pub fn cross_entropy_grad(
    logits: &[f32],
    targets: &[usize],
    vocab: usize,
    inv_count: f32,
    scale: f32,
    dlogits: &mut [f32],
) -> f64 {
    assert_eq!(logits.len(), targets.len() * vocab);
    assert_eq!(dlogits.len(), logits.len());
    let mut loss = 0f64;
    for (b, &y) in targets.iter().enumerate() {
        assert!(y < vocab, "target {y} out of vocab {vocab}");
        let lg = &logits[b * vocab..(b + 1) * vocab];
        let mx = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f32;
        for &v in lg {
            denom += (v - mx).exp();
        }
        loss += (denom.ln() + mx - lg[y]) as f64;
        let dl = &mut dlogits[b * vocab..(b + 1) * vocab];
        for (v, out) in dl.iter_mut().enumerate() {
            let p = (lg[v] - mx).exp() / denom;
            let onehot = if v == y { 1.0 } else { 0.0 };
            *out = round_f8((p - onehot) * inv_count * scale);
        }
    }
    loss
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_loss_is_log_vocab() {
        let vocab = 8;
        let logits = vec![0f32; 2 * vocab];
        let mut dl = vec![0f32; 2 * vocab];
        let loss = cross_entropy_grad(&logits, &[3, 5], vocab, 1.0, 1.0, &mut dl);
        let want = 2.0 * (vocab as f64).ln();
        assert!((loss - want).abs() < 1e-5, "loss {loss} vs {want}");
    }

    #[test]
    fn cotangent_signs_and_grid() {
        let vocab = 4;
        let logits = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut dl = vec![0f32; 4];
        cross_entropy_grad(&logits, &[2], vocab, 1.0, 64.0, &mut dl);
        // target entry negative, all others positive, all on FP8 grid
        assert!(dl[2] < 0.0, "target cotangent must push its logit up");
        for (v, &g) in dl.iter().enumerate() {
            if v != 2 {
                assert!(g > 0.0, "non-target {v} must be pushed down");
            }
            assert_eq!(g, round_f8(g));
        }
    }

    #[test]
    fn perfect_prediction_has_near_zero_loss() {
        let vocab = 4;
        let mut logits = vec![0f32; 4];
        logits[1] = 30.0;
        let mut dl = vec![0f32; 4];
        let loss = cross_entropy_grad(&logits, &[1], vocab, 1.0, 1.0, &mut dl);
        assert!(loss < 1e-6, "confident correct prediction: loss {loss}");
    }
}
