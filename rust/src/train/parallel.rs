//! Lane-sharded data-parallel window execution — the training-side
//! sibling of the `serve` worker pool (`std::thread` shards; rayon is
//! deliberately out).
//!
//! ## The determinism contract
//!
//! A truncated-BPTT window over `B` batch lanes is embarrassingly
//! parallel until the gradient reduction: every lane's forward state,
//! tape, and per-lane parameter gradient are independent, and every
//! kernel on the path is per-stream bit-identical whatever batch it
//! rides in (pinned by `tests/batched_equivalence.rs`). The only place
//! thread count could leak into the numbers is the **order** f32/f64
//! partial sums are folded. So that order is fixed structurally:
//!
//! * the lane partition ([`lane_spans`]) is a pure function of the
//!   *batch size alone* — never of `--threads`;
//! * each shard computes its span's gradients/loss into its own
//!   buffers ([`LaneShard`]), on whichever OS thread happens to run it;
//! * [`merge_shards`] folds the per-shard results in a **fixed
//!   pairwise tree over the shard index** ((0,1)(2,3) → ((01)(23)) →
//!   …), single-threaded, after every shard has finished.
//!
//! `--threads N` therefore only changes *which* OS thread executes a
//! shard, never what any shard computes nor how results combine:
//! `--threads N` is bit-identical to `--threads 1` by construction
//! (pinned end-to-end — checkpoints and per-step loss traces — by
//! `tests/train_parallel.rs`).
//!
//! [`merge_finalize_overlapped`] pipelines the same fixed-order tree
//! **slot by slot** against the optimizer's gradient finalize
//! (overflow check → FP8 quantize → exact unscale) on a worker
//! thread, so the merge overlaps the update instead of strictly
//! preceding it — per-slot order is unchanged, so the bits are too.
//!
//! Threads beyond the shard count idle; shards beyond the thread count
//! queue onto the same threads in fixed chunks. [`LANE_SHARDS_MAX`]
//! caps per-window gradient-buffer memory (one [`StackGrads`] per
//! shard) and is the parallelism ceiling.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;

use anyhow::bail;

use crate::lstm::cell::BatchScratch;
use crate::lstm::QLstmStack;
use crate::qmath::grad::{grads_overflow, quantize_fp8_inplace};

use super::backward::{StackGrads, StateCot};
use super::tape::StackTape;

/// Upper bound on lane shards per stack (== the parallel-speedup
/// ceiling, and the per-window gradient-buffer multiplier).
pub const LANE_SHARDS_MAX: usize = 8;

/// The fixed lane partition: contiguous `[lo, hi)` spans covering
/// `0..batch`, `min(batch, LANE_SHARDS_MAX)` of them, the first
/// `batch % n` spans one lane longer. A pure function of `batch` —
/// **never** of the thread count — which is what makes the reduction
/// order thread-count-invariant.
pub fn lane_spans(batch: usize) -> Vec<(usize, usize)> {
    assert!(batch >= 1, "lane partition needs at least one lane");
    let n = batch.min(LANE_SHARDS_MAX);
    let base = batch / n;
    let rem = batch % n;
    let mut spans = Vec::with_capacity(n);
    let mut lo = 0usize;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        spans.push((lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, batch);
    spans
}

/// `--threads` validation shared by every trainer config: an error,
/// not a panic (mirroring `data::make_source`'s style).
pub fn check_threads(threads: usize) -> crate::Result<()> {
    if threads == 0 {
        bail!("--threads 0: the trainer needs at least one worker thread");
    }
    if threads > 256 {
        bail!("--threads {threads} out of range 1..=256");
    }
    Ok(())
}

/// One lane shard's private slice of the training state: the carried
/// recurrent state, trace scratches, gradient buffers, and window
/// loss for lanes `[lo, hi)`. All buffers are lane-local, so shards
/// never share mutable state — a shard's window is a pure function of
/// (weights, its lanes' tokens, its carried state).
pub struct LaneShard {
    /// first lane (inclusive)
    pub lo: usize,
    /// last lane (exclusive)
    pub hi: usize,
    /// per-layer carried recurrent state, flat `[(hi-lo)*H]`
    pub hs: Vec<Vec<f32>>,
    pub cs: Vec<Vec<f32>>,
    scratches: Vec<BatchScratch>,
    /// this shard's parameter gradients for the current window
    pub grads: StackGrads,
    /// summed (unscaled, f64) window loss over this shard's lanes
    pub loss: f64,
    /// scored positions behind `loss`
    pub scored: usize,
}

impl LaneShard {
    pub fn new(stack: &QLstmStack, lo: usize, hi: usize) -> Self {
        assert!(hi > lo, "empty lane span");
        let lanes = hi - lo;
        let (hs, cs) = stack.zero_flat_state(lanes);
        LaneShard {
            lo,
            hi,
            hs,
            cs,
            scratches: stack.trace_scratches(lanes),
            grads: StackGrads::zeros(stack),
            loss: 0.0,
            scored: 0,
        }
    }

    /// The full shard set for a stack: one [`LaneShard`] per
    /// [`lane_spans`] entry.
    pub fn build(stack: &QLstmStack, batch: usize) -> Vec<LaneShard> {
        lane_spans(batch).into_iter().map(|(lo, hi)| LaneShard::new(stack, lo, hi)).collect()
    }

    pub fn lanes(&self) -> usize {
        self.hi - self.lo
    }

    /// Zero the carried recurrent state (per-window reset for tasks
    /// whose batches are independent examples).
    pub fn reset_state(&mut self) {
        for v in self.hs.iter_mut().chain(self.cs.iter_mut()) {
            v.fill(0.0);
        }
    }

    /// Zero the gradient/loss accumulators for a new window (the
    /// buffers are reused across windows — no per-step allocation).
    pub fn begin_window(&mut self) {
        self.grads.reset();
        self.loss = 0.0;
        self.scored = 0;
    }

    /// Traced forward over this shard's lanes (`ids[t]` already
    /// lane-sliced to `hi - lo` entries), advancing the carried state.
    pub fn forward_traced(
        &mut self,
        stack: &QLstmStack,
        ids: &[Vec<usize>],
    ) -> (StackTape, Vec<Vec<f32>>) {
        let mut tape = StackTape::new(stack, self.lanes());
        let logits = stack.forward_batch_traced(
            ids,
            &mut self.hs,
            &mut self.cs,
            &mut self.scratches,
            &mut tape,
        );
        (tape, logits)
    }

    /// BPTT into this shard's gradient buffers (call
    /// [`Self::begin_window`] first).
    pub fn backward(&mut self, stack: &QLstmStack, tape: &StackTape, dlogits: &[Vec<f32>]) {
        stack.backward_batch(tape, dlogits, &mut self.grads);
    }

    /// [`Self::backward`] with the seq2seq state-cotangent bridge —
    /// see [`QLstmStack::backward_batch_carry`].
    pub fn backward_carry(
        &mut self,
        stack: &QLstmStack,
        tape: &StackTape,
        dlogits: &[Vec<f32>],
        carry: Option<&[StateCot]>,
    ) -> Vec<StateCot> {
        stack.backward_batch_carry(tape, dlogits, carry, &mut self.grads)
    }
}

/// Column-slice of per-step ids: `out[t] = ids[t][lo..hi]` — the
/// forward inputs must be shard-owned `Vec`s (the traced forward
/// consumes `&[Vec<usize>]`); labels, by contrast, are sliced inline
/// at the loss call sites (`&targets[t][lo..hi]`), no copy needed.
pub fn lane_slice_ids(ids: &[Vec<usize>], lo: usize, hi: usize) -> Vec<Vec<usize>> {
    ids.iter().map(|row| row[lo..hi].to_vec()).collect()
}

/// Run `f(shard_index, item)` for every item, distributing items over
/// at most `threads` scoped OS threads in fixed contiguous chunks
/// (item `i` runs on thread `i / ceil(n / threads)`).
///
/// `f` must be a pure function of the item (plus shared immutable
/// captures) — it runs identically wherever it is scheduled, which is
/// the "what a shard computes never depends on threads" half of the
/// determinism contract. `threads <= 1` runs inline with no spawn at
/// all, so single-threaded training pays zero threading overhead.
pub fn run_shards<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let per = n.div_ceil(threads.min(n));
    std::thread::scope(|scope| {
        let f = &f;
        for (chunk_idx, chunk) in items.chunks_mut(per).enumerate() {
            let base = chunk_idx * per;
            scope.spawn(move || {
                for (j, item) in chunk.iter_mut().enumerate() {
                    f(base + j, item);
                }
            });
        }
    });
}

/// The fixed-order reduction: fold per-shard gradients in a pairwise
/// binary tree over the shard index — stride-1 pairs (0,1)(2,3)…,
/// then stride-2, … — mutating the left operand of each pair; the
/// fully merged gradients end in shard 0's buffer and are swapped
/// into `out`. Losses/scored counts fold in plain shard-index order.
///
/// Runs single-threaded *after* every shard completed, and the tree
/// shape depends only on the shard count (a pure function of the
/// batch size), so the merged bits are identical for every
/// `--threads` value.
pub fn merge_shards(shards: &mut [&mut LaneShard], out: &mut StackGrads) -> (f64, usize) {
    let n = shards.len();
    assert!(n >= 1, "merge needs at least one shard");
    let mut loss = 0f64;
    let mut scored = 0usize;
    for s in shards.iter() {
        loss += s.loss;
        scored += s.scored;
    }
    let mut stride = 1usize;
    while stride < n {
        let mut i = 0usize;
        while i + stride < n {
            let (left, right) = shards.split_at_mut(i + stride);
            left[i].grads.add_assign(&right[0].grads);
            i += 2 * stride;
        }
        stride *= 2;
    }
    std::mem::swap(out, &mut shards[0].grads);
    (loss, scored)
}

/// Elementwise slot accumulate — the per-tensor half of
/// [`StackGrads::add_assign`], applied to one slot of the tree.
fn add_slot(dst: &mut [f32], src: &[f32]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a += b;
    }
}

/// [`merge_shards`] fused with the finalize half of
/// [`super::finalize_grads`]: the tree reduction is folded **slot by
/// slot** (one slot = one gradient tensor, in [`StackGrads::slot`]
/// order), and as soon as a slot's tree completes it is handed to a
/// finalize worker thread that overflow-checks, FP8-quantizes, and
/// exactly unscales it while the merging thread folds the next slot —
/// the merge overlaps the update's gradient post-processing instead
/// of running strictly before it.
///
/// Bit-identity with the classic two-phase path holds because the
/// fold stays in the **same fixed pairwise order per slot**
/// ([`StackGrads::add_assign`] is elementwise per tensor, so a
/// whole-struct tree and per-slot trees produce the same sums), and
/// the finalize math is elementwise per slot — thread count never
/// enters either. `--threads N` therefore stays byte-identical to
/// `--threads 1` (pinned by `tests/train_parallel.rs`).
///
/// Returns `(loss, scored, applied)`. `applied == false` means a slot
/// overflowed the FP8 grid and the step must be skipped, exactly as
/// with [`super::finalize_grads`]; the merged buffer is left
/// partially finalized in that case, which is unobservable — a
/// skipped window's gradients are never read, and every shard rewrites
/// its buffers at the next [`LaneShard::begin_window`].
///
/// Callers that need the merged-but-still-scaled gradients (the
/// trace's gradient scan) or a global clip norm (which must see every
/// slot before any scaling decision) must keep using
/// [`merge_shards`] + [`super::finalize_grads`].
pub fn merge_finalize_overlapped(
    shards: &mut [&mut LaneShard],
    out: &mut StackGrads,
    scale: f32,
) -> (f64, usize, bool) {
    let n = shards.len();
    assert!(n >= 1, "merge needs at least one shard");
    let mut loss = 0f64;
    let mut scored = 0usize;
    for s in shards.iter() {
        loss += s.loss;
        scored += s.scored;
    }
    let inv = 1.0 / scale;
    let overflowed = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let overflowed = &overflowed;
        let (tx, rx) = mpsc::channel::<&mut [f32]>();
        scope.spawn(move || {
            for slot in rx {
                if grads_overflow(slot) {
                    overflowed.store(true, Ordering::Relaxed);
                }
                // once any slot overflowed the step is skipped, so the
                // remaining slots keep their raw merged values (never
                // read — see the doc note above)
                if overflowed.load(Ordering::Relaxed) {
                    continue;
                }
                quantize_fp8_inplace(slot);
                for g in slot.iter_mut() {
                    *g *= inv;
                }
            }
        });
        for (i, dst) in out.slices_mut().into_iter().enumerate() {
            // the same fixed pairwise tree merge_shards runs,
            // restricted to slot i
            let mut stride = 1usize;
            while stride < n {
                let mut j = 0usize;
                while j + stride < n {
                    let (left, right) = shards.split_at_mut(j + stride);
                    add_slot(left[j].grads.slot_mut(i), right[0].grads.slot(i));
                    j += 2 * stride;
                }
                stride *= 2;
            }
            dst.copy_from_slice(shards[0].grads.slot(i));
            tx.send(dst).expect("the finalize worker outlives the sender");
        }
        drop(tx);
    });
    (loss, scored, !overflowed.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn lane_spans_cover_contiguously_and_ignore_threads() {
        for batch in [1usize, 2, 3, 6, 7, 8, 11, 16, 33] {
            let spans = lane_spans(batch);
            assert_eq!(spans.len(), batch.min(LANE_SHARDS_MAX), "batch {batch}");
            assert_eq!(spans[0].0, 0);
            assert_eq!(spans.last().unwrap().1, batch);
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap in partition for batch {batch}");
                // balanced: sizes differ by at most one, larger first
                assert!(w[0].1 - w[0].0 >= w[1].1 - w[1].0);
            }
        }
        // non-divisible example pinned exactly
        assert_eq!(lane_spans(6), vec![(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6)]);
        assert_eq!(
            lane_spans(11),
            vec![(0, 2), (2, 4), (4, 6), (6, 7), (7, 8), (8, 9), (9, 10), (10, 11)]
        );
    }

    #[test]
    fn run_shards_visits_every_item_exactly_once_with_its_own_index() {
        for threads in [1usize, 2, 3, 7, 12] {
            let mut items: Vec<(usize, usize)> = (0..7).map(|i| (i, 0)).collect();
            let visits = AtomicUsize::new(0);
            run_shards(&mut items, threads, |idx, item| {
                assert_eq!(idx, item.0, "index/item mismatch at threads={threads}");
                item.1 += 1;
                visits.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(visits.load(Ordering::SeqCst), 7);
            assert!(items.iter().all(|&(_, v)| v == 1), "threads={threads}");
        }
    }

    #[test]
    fn check_threads_rejects_degenerate_counts() {
        assert!(check_threads(0).is_err());
        assert!(check_threads(1).is_ok());
        assert!(check_threads(256).is_ok());
        assert!(check_threads(257).is_err());
    }

    #[test]
    fn overlapped_merge_finalize_matches_the_classic_two_phase_path() {
        use crate::train::optimizer::finalize_grads;
        use crate::train::MasterStack;

        let (_, stack) = MasterStack::init_with_stack(12, 4, 6, 2, 5);
        let scale = 1024.0;
        for shard_count in [1usize, 2, 3, 5, 8] {
            // same-seed builds so both paths fold identical inputs
            let build = || {
                let mut rng = crate::rng::SplitMix64::new(shard_count as u64 * 31 + 7);
                (0..shard_count)
                    .map(|i| {
                        let mut s = LaneShard::new(&stack, i, i + 1);
                        s.loss = i as f64 + 0.25;
                        s.scored = 10 + i;
                        for slot in s.grads.slices_mut() {
                            for g in slot.iter_mut() {
                                *g = rng.uniform(-300.0, 300.0);
                            }
                        }
                        s
                    })
                    .collect::<Vec<LaneShard>>()
            };

            let mut a = build();
            let mut out_a = StackGrads::zeros(&stack);
            let (loss_a, scored_a) = {
                let mut refs: Vec<&mut LaneShard> = a.iter_mut().collect();
                merge_shards(&mut refs, &mut out_a)
            };
            let ok_a = finalize_grads(&mut out_a, scale, None);

            let mut b = build();
            let mut out_b = StackGrads::zeros(&stack);
            let (loss_b, scored_b, ok_b) = {
                let mut refs: Vec<&mut LaneShard> = b.iter_mut().collect();
                merge_finalize_overlapped(&mut refs, &mut out_b, scale)
            };

            assert_eq!(loss_b.to_bits(), loss_a.to_bits(), "shards {shard_count}");
            assert_eq!(scored_b, scored_a, "shards {shard_count}");
            assert!(ok_a && ok_b, "in-range gradients must not overflow");
            for i in 0..out_a.slot_count() {
                let (sa, sb) = (out_a.slot(i), out_b.slot(i));
                assert_eq!(sa.len(), sb.len());
                for (x, y) in sa.iter().zip(sb) {
                    assert_eq!(x.to_bits(), y.to_bits(), "shards {shard_count} slot {i}");
                }
            }
        }

        // an overflow in a late slot skips the step on both paths
        let poison = |shards: &mut [LaneShard]| {
            let last = shards.last_mut().unwrap();
            let n_slots = last.grads.slot_count();
            last.grads.slot_mut(n_slots - 1)[0] = f32::INFINITY;
        };
        let mut a: Vec<LaneShard> = (0..3).map(|i| LaneShard::new(&stack, i, i + 1)).collect();
        poison(&mut a);
        let mut out_a = StackGrads::zeros(&stack);
        let mut refs: Vec<&mut LaneShard> = a.iter_mut().collect();
        merge_shards(&mut refs, &mut out_a);
        assert!(!finalize_grads(&mut out_a, scale, None));
        let mut b: Vec<LaneShard> = (0..3).map(|i| LaneShard::new(&stack, i, i + 1)).collect();
        poison(&mut b);
        let mut out_b = StackGrads::zeros(&stack);
        let mut refs: Vec<&mut LaneShard> = b.iter_mut().collect();
        let (_, _, ok) = merge_finalize_overlapped(&mut refs, &mut out_b, scale);
        assert!(!ok, "the overlapped path must report the overflow verdict");
    }
}
