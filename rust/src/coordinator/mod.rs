//! L3 experiment coordinator: runs the paper's experiments end-to-end
//! (train → per-epoch eval → metric curves → final results), writes
//! CSV/JSONL logs, and provides the multi-experiment drivers behind
//! the Table IV / Table V / Fig. 6 bench targets.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{preset_for, scaled, TrainPreset};
use crate::data::make_source;
use crate::runtime::{Runtime, StepMetrics, TrainSession};

/// One experiment = one artifact trained with a preset.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub artifact: String,
    pub preset: TrainPreset,
    pub data_seed: u64,
    /// write per-epoch curve CSV + JSONL log under results/
    pub log: bool,
}

impl ExperimentSpec {
    /// Standard spec for an artifact (preset from our Table III),
    /// optionally scaled down by `div` for quick runs.
    pub fn standard(rt: &Runtime, artifact: &str, div: usize) -> Result<Self> {
        let info = rt.manifest.artifact(artifact)?;
        Ok(ExperimentSpec {
            artifact: artifact.to_string(),
            preset: scaled(preset_for(&info.task), div),
            data_seed: 20200711,
            log: true,
        })
    }
}

/// A point on the Fig. 6 training curve.
#[derive(Clone, Copy, Debug)]
pub struct EpochPoint {
    pub epoch: usize,
    pub train_loss: f32,
    pub eval_metric: f32,
    pub eval_loss: f32,
}

/// Result of one experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub artifact: String,
    pub metric_name: String,
    pub curve: Vec<EpochPoint>,
    pub final_metric: f32,
    /// best (max for accuracy, min for perplexity) eval metric seen
    pub best_metric: f32,
    pub wall: std::time::Duration,
    pub steps: u64,
    pub transfer_time: std::time::Duration,
    pub execute_time: std::time::Duration,
}

/// Run one experiment to completion.
pub fn run_experiment(rt: &mut Runtime, spec: &ExperimentSpec) -> Result<ExperimentResult> {
    let t0 = Instant::now();
    let mut session = TrainSession::new(rt, &spec.artifact)?;
    let task = session.task.clone();
    let mut source = make_source(
        &task.name,
        task.batch,
        &task.x_shape,
        &task.y_shape,
        task.vocab,
        task.vocab_tgt,
        task.n_classes,
        spec.preset.eval_batches,
        spec.data_seed,
    )?;

    let metric_name = task.metric.clone();
    let higher_better = metric_name == "accuracy";
    let mut curve = Vec::with_capacity(spec.preset.epochs);
    let mut best = if higher_better { f32::MIN } else { f32::MAX };

    let mut log = if spec.log { Some(ExperimentLog::new(&spec.artifact)?) } else { None };

    for epoch in 0..spec.preset.epochs {
        let mut train_agg = StepMetrics::default();
        for _ in 0..spec.preset.steps_per_epoch {
            let batch = source.next_train();
            let m = session.step(&batch)?;
            train_agg.loss_sum += m.loss_sum;
            train_agg.metric_sum += m.metric_sum;
            train_agg.count += m.count;
        }
        let eval = session.eval(source.eval_set())?;
        let point = EpochPoint {
            epoch,
            train_loss: train_agg.mean_loss(),
            eval_metric: eval.named(&metric_name),
            eval_loss: eval.mean_loss(),
        };
        if higher_better {
            best = best.max(point.eval_metric);
        } else {
            best = best.min(point.eval_metric);
        }
        if let Some(l) = &mut log {
            l.epoch(&point, &metric_name)?;
        }
        eprintln!(
            "[{}] epoch {:>2}: train_loss {:.4}  eval {} {:.3}",
            spec.artifact, epoch, point.train_loss, metric_name, point.eval_metric
        );
        curve.push(point);
    }

    let final_metric = curve.last().map(|p| p.eval_metric).unwrap_or(f32::NAN);
    if let Some(l) = log {
        l.finish()?;
    }
    Ok(ExperimentResult {
        artifact: spec.artifact.clone(),
        metric_name,
        curve,
        final_metric,
        best_metric: best,
        wall: t0.elapsed(),
        steps: session.steps_done,
        transfer_time: session.transfer_time,
        execute_time: session.execute_time,
    })
}

/// Run a list of artifacts sequentially, returning results in order.
/// (PJRT-CPU saturates the machine's cores per executable, so the
/// coordinator runs experiments back-to-back rather than oversubscribing;
/// the queue abstraction still centralizes logging and failure handling.)
pub fn run_suite(
    rt: &mut Runtime,
    artifacts: &[&str],
    div: usize,
) -> Result<Vec<ExperimentResult>> {
    let mut out = Vec::with_capacity(artifacts.len());
    for a in artifacts {
        let spec = ExperimentSpec::standard(rt, a, div)?;
        out.push(run_experiment(rt, &spec).with_context(|| format!("experiment {a}"))?);
    }
    Ok(out)
}

/// CSV + JSONL logging for one experiment.
struct ExperimentLog {
    csv: std::fs::File,
    jsonl: std::fs::File,
}

impl ExperimentLog {
    fn new(artifact: &str) -> Result<Self> {
        let dir = crate::benchlib::results_dir().join("curves");
        std::fs::create_dir_all(&dir)?;
        let mut csv = std::fs::File::create(dir.join(format!("{artifact}.csv")))?;
        writeln!(csv, "epoch,train_loss,eval_loss,eval_metric")?;
        let jsonl = std::fs::File::create(dir.join(format!("{artifact}.jsonl")))?;
        Ok(ExperimentLog { csv, jsonl })
    }

    fn epoch(&mut self, p: &EpochPoint, metric: &str) -> Result<()> {
        writeln!(
            self.csv,
            "{},{},{},{}",
            p.epoch, p.train_loss, p.eval_loss, p.eval_metric
        )?;
        writeln!(
            self.jsonl,
            "{{\"epoch\":{},\"train_loss\":{},\"eval_loss\":{},\"{}\":{}}}",
            p.epoch, p.train_loss, p.eval_loss, metric, p.eval_metric
        )?;
        Ok(())
    }

    fn finish(mut self) -> Result<()> {
        self.csv.flush()?;
        self.jsonl.flush()?;
        Ok(())
    }
}

/// Checkpoint directory helper.
pub fn checkpoint_path(artifact: &str) -> PathBuf {
    let dir = crate::benchlib::results_dir().join("checkpoints");
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("{artifact}.tensors"))
}
