//! # floatsd-lstm
//!
//! Reproduction of **"Low-Complexity LSTM Training and Inference with
//! FloatSD8 Weight Representation"** (Liu & Chiueh, IJCNN 2020) as a
//! three-layer rust + JAX/Pallas stack:
//!
//! * **L1** — Pallas kernels (FloatSD8/FP8 quantizers, quantized matmul,
//!   two-region quantized sigmoid) authored in `python/compile/kernels/`,
//!   lowered at build time.
//! * **L2** — the quantized LSTM training step (forward/backward with
//!   fake-quantization hooks, Adam/SGD, loss scaling) in
//!   `python/compile/model.py`, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** — this crate: the runtime coordinator that loads the AOT
//!   artifacts via PJRT ([`runtime`]), drives training experiments
//!   ([`coordinator`]), generates the synthetic workloads ([`data`]),
//!   and hosts the paper's numeric formats ([`formats`]), software
//!   quantized math ([`qmath`]), a pure-rust quantized LSTM inference
//!   engine ([`lstm`]) and the gate/cycle-level hardware model of the
//!   paper's FloatSD8 MAC and LSTM neuron circuit ([`hardware`]).
//!
//! Python never runs at inference/training-loop time: `make artifacts`
//! runs once, then the rust binary is self-contained.
//!
//! On top of the inference engine sits [`serve`]: a batched,
//! multi-threaded **task-generic** serving core (per-client session
//! state, dynamic micro-batching, a sharded worker pool, per-task
//! request kinds incl. an encoder→decoder MT decode loop) behind the
//! `floatsd-lstm serve` subcommand — any checkpoint the trainers
//! write serves with its task auto-detected from `meta/task_cfg`,
//! bit-identical to the offline eval path.
//!
//! Next to it sits [`train`]: a pure-rust offline quantized training
//! engine (truncated BPTT, FP8 gradients, FP16 master weights with
//! FloatSD8 re-encoding, dynamic loss scaling) behind the
//! `floatsd-lstm train` subcommand — train → checkpoint → serve runs
//! end to end in this one binary, no XLA required.
//!
//! On top of the training engine sits [`tasks`]: the paper's Table-IV
//! scenario grid as pluggable task heads (language modeling, POS
//! tagging, NLI classification, encoder–decoder translation) behind
//! `floatsd-lstm train --task {lm,pos,nli,mt}`, plus the evaluation
//! harness behind `floatsd-lstm eval` that turns any checkpoint into
//! a deterministic JSON report across all four workloads.
//!
//! Cutting across all of these is [`telemetry`]: a deterministic
//! numerics-health observability layer (counters, histograms, span
//! timers; FP8/FloatSD8 saturation scans) feeding the `--trace` JSONL
//! stream and the `floatsd-lstm report` summarizer — enabling it
//! never changes a single computed bit.
//!
//! The PJRT-dependent layers ([`runtime`], [`coordinator`], the
//! `--artifact` train path and the suite CLI) are gated behind the
//! default-off `pjrt` cargo feature so the crate builds and tests
//! fully offline.
//!
//! See `DESIGN.md` for the experiment index (every table and figure of
//! the paper mapped to a module and a bench target) and for the serve
//! subsystem's architecture and batching contract; `EXPERIMENTS.md`
//! holds measured results.

pub mod benchlib;
pub mod cli;
pub mod config;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod data;
pub mod formats;
pub mod hardware;
pub mod lstm;
pub mod qmath;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod tasks;
pub mod telemetry;
pub mod tensorfile;
pub mod testing;
pub mod train;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
