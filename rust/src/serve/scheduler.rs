//! Dynamic micro-batching scheduler: one bounded-wait request queue
//! per shard, now carrying **task-generic** requests.
//!
//! Batch formation rules (the paper-adjacent deployments — FINN-L,
//! fixed-point RNN serving — all batch across streams to amortize
//! weight traffic; this queue is where that batching happens):
//!
//! * a micro-batch closes as soon as it holds `max_batch` requests, or
//!   `batch_window` after collection started, whichever comes first —
//!   the first waiting request is never delayed by more than the
//!   window;
//! * at most **one request per session** per batch (a session's second
//!   in-flight request must see the state produced by its first), and
//!   requests of one session keep FIFO order across batches;
//! * session-close commands order correctly against that session's
//!   still-queued requests (a close never jumps ahead of them).
//!
//! Per-task batching happens **inside** a micro-batch: the worker
//! groups its requests by kind — single-token [`RequestKind::Step`]s
//! share one `step_batch`, [`RequestKind::Sequence`]s run in ragged
//! lockstep, greedy [`RequestKind::Decode`]s share the decode loop's
//! lanes, and beam decodes batch their own beams — so the queue itself
//! stays kind-agnostic and the ordering invariants above are the only
//! scheduling contract.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::model::DecodeParams;
use super::session::SessionId;

/// What a request asks the engine to do — the per-task shapes of the
/// serving API (see [`super::model::ServeModel`] for the task table).
pub enum RequestKind {
    /// advance the session's stream by one token and return that
    /// step's head output (lm next-token logits, pos tag scores; for
    /// mt sessions this feeds the encoder)
    Step { token: usize },
    /// submit a whole (sub)sequence at once: prefill (lm/nli),
    /// whole-sentence tagging (pos), source upload (mt encoder)
    Sequence { tokens: Vec<usize> },
    /// classify the sequence submitted so far from its final hidden
    /// state (nli's submit-sequence-then-finalize protocol)
    Finalize,
    /// run the encoder→decoder decode loop from the session's current
    /// encoder state (mt); does not disturb that state
    Decode(DecodeParams),
}

impl RequestKind {
    /// Recurrent-state steps this request costs the engine — the unit
    /// of the throughput counters (a `Finalize` reads cached logits
    /// and costs none; a beam decode steps every beam lane once per
    /// emitted token).
    pub fn work(&self) -> u64 {
        match self {
            RequestKind::Step { .. } => 1,
            RequestKind::Sequence { tokens } => tokens.len() as u64,
            RequestKind::Finalize => 0,
            RequestKind::Decode(p) => (p.max_len * p.beam_width.max(1)) as u64,
        }
    }
}

/// One request of one session, awaiting scheduling.
pub struct Request {
    pub session: SessionId,
    pub kind: RequestKind,
    /// when the request entered the queue (service-latency clock)
    pub enqueued: Instant,
    pub reply_to: mpsc::Sender<Reply>,
}

impl Request {
    /// Single-token step — the streaming hot path's constructor.
    pub fn new(session: SessionId, token: usize, reply_to: mpsc::Sender<Reply>) -> Request {
        Request::with_kind(session, RequestKind::Step { token }, reply_to)
    }

    pub fn with_kind(
        session: SessionId,
        kind: RequestKind,
        reply_to: mpsc::Sender<Reply>,
    ) -> Request {
        Request { session, kind, enqueued: Instant::now(), reply_to }
    }
}

/// The per-task payload of a [`Reply`]. Every numeric field is
/// bit-identical to the unbatched sequential engine
/// ([`crate::lstm::QLstmStack::forward_from`]) on the same inputs —
/// batching is a throughput lever, never an accuracy one.
pub enum Payload {
    /// one streamed step's full head output; `top` is its argmax (the
    /// greedy next token / most likely tag), precomputed so
    /// load-generating clients don't rescan the vector
    Step { logits: Vec<f32>, top: usize },
    /// sequence accepted; the **last** step's head output (lm prefill:
    /// the next-token distribution after the whole prefix)
    Prefilled { consumed: usize, logits: Vec<f32>, top: usize },
    /// per-step head outputs for the whole submitted sequence — pos
    /// replies tag scores for every position (posteriors are a softmax
    /// away; raw logits keep the bit-exactness contract checkable)
    Steps { logits: Vec<Vec<f32>> },
    /// source consumed into the session's encoder state (mt)
    Encoded { consumed: usize },
    /// sequence-level classification from the final hidden state (nli
    /// finalize): 3-way logits + their argmax label
    Class { logits: Vec<f32>, label: usize },
    /// decode-loop result (mt): emitted target tokens and the total
    /// log-probability of that hypothesis
    Decoded { tokens: Vec<usize>, score: f32 },
    /// rejected without touching any model state
    Rejected { reason: String },
}

/// The server's answer to one request.
pub struct Reply {
    pub session: SessionId,
    pub payload: Payload,
    /// enqueue → reply-ready service latency
    pub latency: Duration,
}

impl Reply {
    /// True when the request was rejected without being processed.
    pub fn is_rejected(&self) -> bool {
        matches!(self.payload, Payload::Rejected { .. })
    }

    /// The single logit row of a `Step`/`Prefilled`/`Class` reply.
    pub fn logits(&self) -> Option<&[f32]> {
        match &self.payload {
            Payload::Step { logits, .. }
            | Payload::Prefilled { logits, .. }
            | Payload::Class { logits, .. } => Some(logits),
            _ => None,
        }
    }

    /// Argmax of [`Self::logits`] (greedy token / tag / class label).
    pub fn top_token(&self) -> Option<usize> {
        match &self.payload {
            Payload::Step { top, .. } | Payload::Prefilled { top, .. } => Some(*top),
            Payload::Class { label, .. } => Some(*label),
            _ => None,
        }
    }
}

enum Item {
    Req(Request),
    Close(SessionId),
}

struct Inner {
    q: VecDeque<Item>,
    shutdown: bool,
    /// deepest the queue has ever been — the backpressure telemetry
    /// gauge ([`RequestQueue::high_water`]); tracked inside the push
    /// critical section, so it costs one compare on a lock already held
    high_water: usize,
}

/// MPSC micro-batching queue (many client handles push, the owning
/// worker pops batches).
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl Default for RequestQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), shutdown: false, high_water: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request (dropped silently after shutdown).
    pub fn push(&self, r: Request) {
        let mut g = self.inner.lock().unwrap();
        if !g.shutdown {
            g.q.push_back(Item::Req(r));
            g.high_water = g.high_water.max(g.q.len());
            self.cv.notify_one();
        }
    }

    /// Enqueue a session close (ordered against that session's
    /// still-queued requests).
    pub fn push_close(&self, session: SessionId) {
        let mut g = self.inner.lock().unwrap();
        if !g.shutdown {
            g.q.push_back(Item::Close(session));
            g.high_water = g.high_water.max(g.q.len());
            self.cv.notify_one();
        }
    }

    /// Items currently queued (requests + closes) — the batch-boundary
    /// queue-depth gauge the serve trace samples.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Deepest the queue has ever been — the scheduler backpressure
    /// high-water mark reported by serve stats and traces.
    pub fn high_water(&self) -> usize {
        self.inner.lock().unwrap().high_water
    }

    /// Stop accepting new work and wake the worker; already-queued
    /// items are still delivered (drain semantics).
    pub fn shutdown(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shutdown = true;
        self.cv.notify_all();
    }

    /// Blockingly collect the next micro-batch into `batch` (cleared
    /// first) and any due session-closes into `closes` (cleared first).
    ///
    /// Returns `false` only once the queue is shut down **and** fully
    /// drained; until then at least one request or close is delivered
    /// per call (after shutdown the window wait is skipped so drain is
    /// prompt).
    pub fn next_batch(
        &self,
        max_batch: usize,
        window: Duration,
        batch: &mut Vec<Request>,
        closes: &mut Vec<SessionId>,
    ) -> bool {
        batch.clear();
        closes.clear();
        let mut g = self.inner.lock().unwrap();

        // wait for the first item (or shutdown+empty)
        loop {
            if !g.q.is_empty() {
                break;
            }
            if g.shutdown {
                return false;
            }
            g = self.cv.wait(g).unwrap();
        }

        let deadline = Instant::now() + window;
        // items blocked this call (dup-session requests, closes behind
        // their session's requests) — drained to here and pushed back
        // to the queue front afterwards, preserving FIFO. O(1) per
        // item: no mid-queue removal, so batch formation stays linear
        // in the items examined even with a deep backlog. Empty in the
        // common case, so no allocation on the happy path. The scan
        // budget caps how far past blocked items we look for
        // co-batchable sessions, so one session pipelining thousands
        // of requests can't make every batch shuffle its whole
        // backlog.
        let scan_budget = max_batch.saturating_mul(8);
        let mut deferred: VecDeque<Item> = VecDeque::new();
        loop {
            // drain from the front; take what's schedulable now
            while batch.len() < max_batch && deferred.len() < scan_budget {
                let Some(item) = g.q.pop_front() else { break };
                match item {
                    Item::Req(r) => {
                        // one request per session per batch
                        if batch.iter().any(|b| b.session == r.session) {
                            deferred.push_back(Item::Req(r));
                        } else {
                            batch.push(r);
                        }
                    }
                    Item::Close(s) => {
                        // a close may not overtake queued/batched
                        // requests of its session
                        let blocked = batch.iter().any(|b| b.session == s)
                            || deferred.iter().any(
                                |it| matches!(it, Item::Req(r) if r.session == s),
                            );
                        if blocked {
                            deferred.push_back(Item::Close(s));
                        } else {
                            closes.push(s);
                        }
                    }
                }
            }

            if batch.len() >= max_batch || g.shutdown {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g2, _timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
        // restore blocked items to the queue front in original order
        while let Some(it) = deferred.pop_back() {
            g.q.push_front(it);
        }
        // a call that reaches here always carries work: the first-item
        // wait guaranteed a non-empty queue, and the drain moves at
        // least that item into `batch` or `closes` (an all-blocked
        // prefix implies `batch` is non-empty, since blocking requires
        // a same-session request already batched).
        debug_assert!(!batch.is_empty() || !closes.is_empty());
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(session: SessionId, token: usize, tx: &mpsc::Sender<Reply>) -> Request {
        Request::new(session, token, tx.clone())
    }

    fn token_of(r: &Request) -> usize {
        match r.kind {
            RequestKind::Step { token } => token,
            _ => panic!("expected a step request"),
        }
    }

    #[test]
    fn batch_respects_max_and_session_dedupe() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        // session 1 twice: second occurrence must wait for a later batch
        for (s, t) in [(1u64, 10usize), (2, 20), (1, 11), (3, 30)] {
            q.push(mk(s, t, &tx));
        }
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        let got: Vec<(u64, usize)> = batch.iter().map(|r| (r.session, token_of(r))).collect();
        assert_eq!(got, vec![(1, 10), (2, 20), (3, 30)], "dup session deferred, FIFO kept");
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        let got: Vec<(u64, usize)> = batch.iter().map(|r| (r.session, token_of(r))).collect();
        assert_eq!(got, vec![(1, 11)], "deferred token arrives next, in order");
    }

    #[test]
    fn mixed_kinds_share_a_batch_but_not_a_session() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(Request::with_kind(7, RequestKind::Sequence { tokens: vec![1, 2, 3] }, tx.clone()));
        q.push(Request::with_kind(7, RequestKind::Finalize, tx.clone()));
        q.push(Request::with_kind(8, RequestKind::Decode(DecodeParams::default()), tx.clone()));
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        // the finalize of session 7 must wait for its sequence; the
        // decode of session 8 co-batches freely
        assert_eq!(batch.len(), 2);
        assert!(matches!(batch[0].kind, RequestKind::Sequence { .. }));
        assert!(matches!(batch[1].kind, RequestKind::Decode(_)));
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        assert_eq!(batch.len(), 1);
        assert!(matches!(batch[0].kind, RequestKind::Finalize), "finalize kept FIFO order");
    }

    #[test]
    fn close_does_not_overtake_own_session() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(mk(5, 1, &tx));
        q.push(mk(5, 2, &tx));
        q.push_close(5);
        q.push_close(6); // unrelated close may be taken immediately
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        assert_eq!(batch.len(), 1, "only first token of session 5");
        assert_eq!(closes, vec![6], "session 5's close still behind its second token");
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        assert_eq!(batch.len(), 1, "second token of session 5");
        assert!(closes.is_empty(), "close may not share a batch with its own session's token");
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        assert!(batch.is_empty());
        assert_eq!(closes, vec![5]);
    }

    #[test]
    fn max_batch_closes_immediately_without_waiting_window() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        for s in 0..4u64 {
            q.push(mk(s, 0, &tx));
        }
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        let t0 = Instant::now();
        assert!(q.next_batch(4, Duration::from_secs(5), &mut batch, &mut closes));
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(1), "full batch must not wait the window");
    }

    #[test]
    fn shutdown_drains_then_ends() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(mk(1, 0, &tx));
        q.shutdown();
        q.push(mk(2, 0, &tx)); // rejected after shutdown
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        assert!(q.next_batch(8, Duration::from_secs(5), &mut batch, &mut closes));
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].session, 1);
        assert!(!q.next_batch(8, Duration::from_secs(5), &mut batch, &mut closes));
    }

    #[test]
    fn depth_and_high_water_track_the_backlog() {
        let q = RequestQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert_eq!((q.depth(), q.high_water()), (0, 0));
        for s in 0..3u64 {
            q.push(mk(s, 0, &tx));
        }
        q.push_close(9); // unrelated close counts toward depth too
        assert_eq!((q.depth(), q.high_water()), (4, 4));
        let (mut batch, mut closes) = (Vec::new(), Vec::new());
        assert!(q.next_batch(8, Duration::from_millis(1), &mut batch, &mut closes));
        assert_eq!(batch.len(), 3);
        assert_eq!(closes, vec![9]);
        assert_eq!(q.depth(), 0, "batch formation drains the queue");
        assert_eq!(q.high_water(), 4, "the high-water mark survives the drain");
    }

    #[test]
    fn work_accounting_per_kind() {
        assert_eq!(RequestKind::Step { token: 3 }.work(), 1);
        assert_eq!(RequestKind::Sequence { tokens: vec![1, 2, 3] }.work(), 3);
        assert_eq!(RequestKind::Finalize.work(), 0);
        // a beam decode steps beam_width lanes per emitted token
        assert_eq!(RequestKind::Decode(DecodeParams { max_len: 9, beam_width: 2, len_norm: 0.0 }).work(), 18);
        assert_eq!(RequestKind::Decode(DecodeParams { max_len: 9, beam_width: 1, len_norm: 0.0 }).work(), 9);
    }
}
