//! Batched, multi-threaded **inference serving engine** over the
//! pure-rust FloatSD8 LSTM stack — the deployment layer the paper's
//! low-complexity arithmetic exists to enable.
//!
//! Architecture (one box per module):
//!
//! ```text
//!   clients ──► Server::submit ──► shard = session_id % workers
//!                                        │
//!                     ┌──────────────────┴──────────────────┐
//!                     ▼                                     ▼
//!              RequestQueue (scheduler)             RequestQueue ...
//!               deadline- & max-batch-               one per worker
//!               bounded micro-batches
//!                     │
//!                     ▼
//!              worker thread: SessionStore (h,c per client)
//!                     │   gather states → QLstmStack::step_batch
//!                     │   (weight-stationary matmul_fast, flat
//!                     │    scratch, zero allocation per token)
//!                     ▼
//!              replies + ShardStats (tokens/s, p50/p99, occupancy)
//! ```
//!
//! Contracts:
//!
//! * **Incremental sessions** — clients stream one token at a time;
//!   the per-client `(h, c)` state lives server-side in the shard's
//!   [`session::SessionStore`], so nothing is ever re-computed.
//! * **Bit-exact batching** — a token's logits are bit-identical no
//!   matter which micro-batch it rides in (pinned by
//!   `tests/batched_equivalence.rs`); batching is purely a throughput
//!   lever, never an accuracy one.
//! * **Per-session ordering** — the scheduler never places two
//!   requests of one session in the same micro-batch and preserves
//!   FIFO order across batches, so pipelined clients are safe.
//! * **Shard isolation** — a session is owned by exactly one worker
//!   thread (`session_id % workers`); worker state is lock-free on the
//!   hot path (the only lock is the request queue).

pub mod demo;
pub mod scheduler;
pub mod session;
pub mod stats;
pub mod worker;

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use crate::lstm::QLstmStack;

pub use scheduler::{Reply, Request, RequestQueue};
pub use session::{SessionId, SessionStore};
pub use stats::{ShardStats, StatsSnapshot};
pub use worker::WorkerPool;

/// Serving engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// worker threads (= session shards)
    pub workers: usize,
    /// micro-batch size cap per scheduled step
    pub max_batch: usize,
    /// how long the scheduler waits for a batch to fill once the first
    /// request arrives (the latency/throughput knob)
    pub batch_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            max_batch: 16,
            batch_window: Duration::from_micros(200),
        }
    }
}

/// The serving engine: a shared read-only model + one scheduler queue,
/// session store, and thread per shard.
pub struct Server {
    pool: WorkerPool,
    workers: usize,
    vocab: usize,
}

impl Server {
    /// Spawn the worker pool over a shared (immutable, hence freely
    /// shareable) quantized stack. The stack must be unidirectional.
    pub fn start(stack: Arc<QLstmStack>, cfg: ServeConfig) -> Server {
        assert!(
            stack.is_unidirectional(),
            "serving requires a unidirectional stack (bidirectional layers cannot stream)"
        );
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let workers = cfg.workers;
        let vocab = stack.embed.vocab;
        Server { pool: WorkerPool::spawn(stack, &cfg), workers, vocab }
    }

    /// Which shard (worker) owns a session.
    pub fn shard_of(&self, session: SessionId) -> usize {
        (session % self.workers as u64) as usize
    }

    /// Enqueue one token of one session. The reply (logits for this
    /// token) arrives on `reply_to`; a session is created implicitly on
    /// first use. Requests of the same session are processed in
    /// submission order.
    ///
    /// Rejects out-of-vocabulary tokens up front — a bad client input
    /// must never reach (and panic) a shard worker.
    pub fn submit(
        &self,
        session: SessionId,
        token: usize,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        if token >= self.vocab {
            anyhow::bail!("token id {token} out of range for vocab {}", self.vocab);
        }
        let shard = self.shard_of(session);
        self.pool.queues[shard].push(Request::new(session, token, reply_to));
        Ok(())
    }

    /// Drop a session's server-side state (frees the shard's map entry).
    pub fn close_session(&self, session: SessionId) {
        let shard = self.shard_of(session);
        self.pool.queues[shard].push_close(session);
    }

    /// Per-shard statistics snapshots, in shard order.
    pub fn shard_stats(&self) -> Vec<StatsSnapshot> {
        self.pool.stats.iter().map(|s| s.snapshot()).collect()
    }

    /// Aggregate statistics across all shards (latency percentiles are
    /// recomputed over the merged sample set, not averaged).
    pub fn stats(&self) -> StatsSnapshot {
        stats::merged(&self.pool.stats)
    }

    /// Stop accepting work, drain the queues, and join the workers.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn server_round_trips_tokens_across_shards() {
        let stack = Arc::new(synthetic_stack(32, 8, 12, 1, 32, 11));
        let server = Server::start(
            stack.clone(),
            ServeConfig { workers: 2, max_batch: 4, batch_window: Duration::from_micros(50) },
        );
        let (tx, rx) = mpsc::channel();
        let sessions: Vec<SessionId> = (0..5).collect();
        for &s in &sessions {
            server.submit(s, (s as usize) % 32, tx.clone()).unwrap();
        }
        assert!(
            server.submit(0, 32, tx.clone()).is_err(),
            "out-of-vocab token must be rejected at submit"
        );
        let mut got = 0;
        while got < sessions.len() {
            let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            assert_eq!(reply.logits.len(), stack.n_out());
            assert!(reply.logits.iter().all(|v| v.is_finite()));
            got += 1;
        }
        let agg = server.stats();
        assert_eq!(agg.tokens, sessions.len() as u64);
        server.shutdown();
    }

    #[test]
    #[should_panic(expected = "unidirectional")]
    fn server_rejects_bidirectional_stacks() {
        let mut stack = synthetic_stack(16, 4, 6, 1, 16, 3);
        let extra = synthetic_stack(16, 6, 6, 1, 16, 4).layers.remove(0).fwd;
        stack.layers[0].bwd = Some(extra);
        let _ = Server::start(Arc::new(stack), ServeConfig::default());
    }
}
