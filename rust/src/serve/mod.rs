//! Batched, multi-threaded **task-generic inference serving engine**
//! over the pure-rust FloatSD8 LSTM stacks — the deployment layer the
//! paper's low-complexity arithmetic exists to enable, serving every
//! head the trainer produces (`lm`, `pos`, `nli`, `mt`).
//!
//! Architecture (one box per module):
//!
//! ```text
//!   clients ──► Server::{submit,submit_sequence,finalize,decode}
//!                                        │ shard = session_id % workers
//!                     ┌──────────────────┴──────────────────┐
//!                     ▼                                     ▼
//!              RequestQueue (scheduler)             RequestQueue ...
//!               deadline- & max-batch-               one per worker
//!               bounded micro-batches
//!                     │
//!                     ▼
//!              worker thread: SessionStore (state per client)
//!                     │   group by kind → batched kernels
//!                     │   steps | sequences | finalizes | decodes
//!                     ▼
//!              replies + ShardStats (tokens/s, p50/p99, occupancy)
//! ```
//!
//! The model side is a [`ServeModel`] ([`model`]): any `.tensors`
//! checkpoint loads with its task auto-detected from `meta/task_cfg`
//! (the parser shared with `floatsd-lstm eval`), and the engine serves
//! the task's request/response shape — streaming logits (lm),
//! per-step tag scores (pos), submit-sequence-then-finalize 3-way
//! classification (nli), and the encoder→decoder decode loop (mt;
//! greedy, or beam search behind [`DecodeParams::beam_width`]).
//!
//! Contracts:
//!
//! * **Incremental sessions** — clients stream tokens (or whole
//!   sequences); the per-client state lives server-side in the shard's
//!   [`session::SessionStore`] (for mt that state is the encoder
//!   context each decode bridges from), so nothing is re-computed.
//! * **Bit-exact batching** — every reply is bit-identical no matter
//!   which micro-batch (or per-kind group, or decode lane) produced it
//!   (pinned by `tests/batched_equivalence.rs` and
//!   `tests/serve_tasks.rs`); batching is purely a throughput lever,
//!   never an accuracy one. The single-token streaming path is
//!   unchanged from the LM-only engine.
//! * **Per-session ordering** — the scheduler never places two
//!   requests of one session in the same micro-batch and preserves
//!   FIFO order across batches, so pipelined clients are safe.
//! * **Shard isolation** — a session is owned by exactly one worker
//!   thread (`session_id % workers`); worker state is lock-free on the
//!   hot path (the only lock is the request queue).

pub mod demo;
pub mod model;
pub mod scheduler;
pub mod session;
pub mod stats;
pub mod worker;

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::bail;

use crate::lstm::QLstmStack;
use crate::tasks::TaskKind;
use crate::telemetry::serve_trace::{kernel_profile_json, unum};
use crate::telemetry::ServeTraceSink;
use crate::tensorfile::json::Json;

pub use model::{DecodeParams, ServeModel, MAX_BEAM_WIDTH, MAX_DECODE_LEN, MAX_LEN_NORM};
pub use scheduler::{Payload, Reply, Request, RequestKind, RequestQueue};
pub use session::{SessionId, SessionStore};
pub use stats::{kind_index, KindSnapshot, ShardStats, StatsSnapshot, KIND_NAMES};
pub use worker::WorkerPool;

/// Serving engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// worker threads (= session shards)
    pub workers: usize,
    /// micro-batch size cap per scheduled step
    pub max_batch: usize,
    /// how long the scheduler waits for a batch to fill once the first
    /// request arrives (the latency/throughput knob)
    pub batch_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: std::thread::available_parallelism().map_or(2, |n| n.get().min(8)),
            max_batch: 16,
            batch_window: Duration::from_micros(200),
        }
    }
}

/// The serving engine: a shared read-only model + one scheduler queue,
/// session store, and thread per shard.
pub struct Server {
    pool: WorkerPool,
    model: Arc<ServeModel>,
    workers: usize,
    /// request-lifecycle trace shared with every shard (`--trace`)
    trace: Option<Arc<ServeTraceSink>>,
}

impl Server {
    /// Spawn the worker pool over a shared (immutable, hence freely
    /// shareable) model. Fails — with an error, not a panic; a bad
    /// checkpoint or config is a client-facing condition — when the
    /// model breaks a serving invariant (bidirectional layers, a
    /// head/task width mismatch, a missing mt decoder) or the config
    /// is degenerate.
    pub fn start(model: Arc<ServeModel>, cfg: ServeConfig) -> crate::Result<Server> {
        Server::start_traced(model, cfg, None)
    }

    /// [`Self::start`] with an optional request-lifecycle trace sink
    /// ([`crate::telemetry::serve_trace`]): every shard shares the
    /// sink, a `serve_start` config line is emitted here, and
    /// [`Self::shutdown`] closes the stream with a `serve_end`
    /// summary (run totals + the kernel-tier profile). Tracing never
    /// perturbs a served logit, decode token, or stats counter
    /// (pinned by `tests/serve_trace.rs`).
    pub fn start_traced(
        model: Arc<ServeModel>,
        cfg: ServeConfig,
        trace: Option<Arc<ServeTraceSink>>,
    ) -> crate::Result<Server> {
        model.validate()?;
        if cfg.workers < 1 || cfg.max_batch < 1 {
            bail!(
                "serve config: workers ({}) and max_batch ({}) must both be >= 1",
                cfg.workers,
                cfg.max_batch
            );
        }
        let workers = cfg.workers;
        if let Some(tr) = &trace {
            let mut f = BTreeMap::new();
            f.insert("task".to_string(), Json::Str(model.task.name().to_string()));
            f.insert("workers".to_string(), unum(workers as u64));
            f.insert("max_batch".to_string(), unum(cfg.max_batch as u64));
            f.insert("window_us".to_string(), unum(cfg.batch_window.as_micros() as u64));
            f.insert(
                "kernel_tier".to_string(),
                Json::Str(model.stack.kernel_tier().name().to_string()),
            );
            f.insert(
                "kernel_isa".to_string(),
                Json::Str(model.stack.kernel_isa().name().to_string()),
            );
            f.insert("vocab".to_string(), unum(model.input_vocab() as u64));
            f.insert("n_out".to_string(), unum(model.n_out() as u64));
            f.insert("trace_every".to_string(), unum(tr.every()));
            tr.emit("serve_start", f);
        }
        Ok(Server {
            pool: WorkerPool::spawn(model.clone(), &cfg, trace.clone()),
            model,
            workers,
            trace,
        })
    }

    /// [`Self::start`] over a raw single stack served as a language
    /// model (synthetic stacks, legacy checkpoints without metadata).
    pub fn start_lm(stack: Arc<QLstmStack>, cfg: ServeConfig) -> crate::Result<Server> {
        Server::start(Arc::new(ServeModel::lm(stack)?), cfg)
    }

    /// The model being served (task, stacks, checkpoint config).
    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    /// The task this server answers requests for.
    pub fn task(&self) -> TaskKind {
        self.model.task
    }

    /// Which shard (worker) owns a session.
    pub fn shard_of(&self, session: SessionId) -> usize {
        (session % self.workers as u64) as usize
    }

    /// Enqueue one token of one session; the reply (that step's head
    /// output) arrives on `reply_to`. A session is created implicitly
    /// on first use; requests of one session are processed in
    /// submission order. For mt sessions the token feeds the encoder.
    ///
    /// Rejects out-of-vocabulary tokens up front — a bad client input
    /// must never reach (and panic) a shard worker.
    pub fn submit(
        &self,
        session: SessionId,
        token: usize,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        self.submit_kind(session, RequestKind::Step { token }, reply_to)
    }

    /// Enqueue a whole (sub)sequence: one request, one reply — prefill
    /// for lm/nli (reply carries the last step's logits), per-step tag
    /// scores for pos, source upload into the encoder context for mt.
    pub fn submit_sequence(
        &self,
        session: SessionId,
        tokens: Vec<usize>,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        self.submit_kind(session, RequestKind::Sequence { tokens }, reply_to)
    }

    /// Enqueue an nli finalize: classify the sequence submitted so far
    /// from its final hidden state. Head-width-aware: only a task with
    /// a sequence-level classification head accepts it.
    pub fn finalize(
        &self,
        session: SessionId,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        self.submit_kind(session, RequestKind::Finalize, reply_to)
    }

    /// Enqueue an mt decode: run the encoder→decoder loop from the
    /// session's current encoder context (left untouched, so a client
    /// can re-decode with different parameters).
    pub fn decode(
        &self,
        session: SessionId,
        params: DecodeParams,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        self.submit_kind(session, RequestKind::Decode(params), reply_to)
    }

    /// Validate (against the one per-task rule set shared with the
    /// workers) and enqueue.
    fn submit_kind(
        &self,
        session: SessionId,
        kind: RequestKind,
        reply_to: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        if let Err(reason) = model::validate_request(&self.model, &kind) {
            if let Some(tr) = &self.trace {
                let mut f = BTreeMap::new();
                f.insert("shard".to_string(), unum(self.shard_of(session) as u64));
                f.insert("session".to_string(), unum(session));
                f.insert(
                    "kind".to_string(),
                    Json::Str(KIND_NAMES[kind_index(&kind)].to_string()),
                );
                f.insert("reason".to_string(), Json::Str(reason.clone()));
                tr.emit("reject", f);
            }
            bail!("{reason}");
        }
        let shard = self.shard_of(session);
        self.pool.queues[shard].push(Request::with_kind(session, kind, reply_to));
        Ok(())
    }

    /// Drop a session's server-side state (frees the shard's map
    /// entry). Closing a session that never existed is a cheap no-op.
    pub fn close_session(&self, session: SessionId) {
        let shard = self.shard_of(session);
        self.pool.queues[shard].push_close(session);
    }

    /// Per-shard statistics snapshots, in shard order.
    pub fn shard_stats(&self) -> Vec<StatsSnapshot> {
        self.pool.stats.iter().map(|s| s.snapshot()).collect()
    }

    /// Aggregate statistics across all shards (latency percentiles are
    /// recomputed over the merged sample set, not averaged).
    pub fn stats(&self) -> StatsSnapshot {
        stats::merged(&self.pool.stats)
    }

    /// Stop accepting work, drain the queues, and join the workers.
    /// With a trace sink attached, the stream closes with a
    /// `serve_end` summary: run totals plus the per-tier kernel
    /// profile accumulated since the sink opened the gate.
    pub fn shutdown(self) {
        let Server { pool, trace, .. } = self;
        // keep handles to the shard stats across the join — the
        // summary must include batches drained during shutdown
        let stat_handles = pool.stats.clone();
        pool.shutdown();
        if let Some(tr) = &trace {
            let snap = stats::merged(&stat_handles);
            let mut f = BTreeMap::new();
            f.insert("tokens".to_string(), unum(snap.tokens));
            f.insert("requests".to_string(), unum(snap.requests));
            f.insert("batches".to_string(), unum(snap.batches));
            f.insert("sessions".to_string(), unum(snap.sessions));
            f.insert("queue_high_water".to_string(), unum(snap.queue_high_water));
            f.insert(
                "kernel_tier".to_string(),
                Json::Str(snap.kernel_tier.name().to_string()),
            );
            f.insert(
                "kernel_isa".to_string(),
                Json::Str(snap.kernel_isa.name().to_string()),
            );
            f.insert("kernel_profile".to_string(), kernel_profile_json(&tr.kernel_profile()));
            let mut t = BTreeMap::new();
            t.insert("p50_us".to_string(), Json::Num(snap.latency.p50.as_micros() as f64));
            t.insert("p99_us".to_string(), Json::Num(snap.latency.p99.as_micros() as f64));
            f.insert("timing".to_string(), Json::Obj(t));
            tr.emit("serve_end", f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn server_round_trips_tokens_across_shards() {
        let stack = Arc::new(synthetic_stack(32, 8, 12, 1, 32, 11));
        let server = Server::start_lm(
            stack.clone(),
            ServeConfig { workers: 2, max_batch: 4, batch_window: Duration::from_micros(50) },
        )
        .unwrap();
        let (tx, rx) = mpsc::channel();
        let sessions: Vec<SessionId> = (0..5).collect();
        for &s in &sessions {
            server.submit(s, (s as usize) % 32, tx.clone()).unwrap();
        }
        assert!(
            server.submit(0, 32, tx.clone()).is_err(),
            "out-of-vocab token must be rejected at submit"
        );
        let mut got = 0;
        while got < sessions.len() {
            let reply = rx.recv_timeout(Duration::from_secs(5)).expect("reply");
            let logits = reply.logits().expect("step reply carries logits");
            assert_eq!(logits.len(), stack.n_out());
            assert!(logits.iter().all(|v| v.is_finite()));
            got += 1;
        }
        let agg = server.stats();
        assert_eq!(agg.tokens, sessions.len() as u64);
        assert_eq!(agg.requests, sessions.len() as u64);
        server.shutdown();
    }

    #[test]
    fn server_rejects_bidirectional_stacks_with_an_error() {
        let mut stack = synthetic_stack(16, 4, 6, 1, 16, 3);
        let extra = synthetic_stack(16, 6, 6, 1, 16, 4).layers.remove(0).fwd;
        stack.layers[0].bwd = Some(extra);
        let err = Server::start_lm(Arc::new(stack), ServeConfig::default())
            .err()
            .expect("bidirectional stacks cannot stream and must be refused");
        let msg = err.to_string();
        assert!(
            msg.contains("unidirectional") && msg.contains("stream"),
            "error should explain the streaming constraint, got: {msg}"
        );
    }

    #[test]
    fn degenerate_config_is_an_error_not_a_panic() {
        let stack = Arc::new(synthetic_stack(16, 4, 6, 1, 16, 5));
        let cfg = ServeConfig { workers: 0, max_batch: 4, batch_window: Duration::ZERO };
        assert!(Server::start_lm(stack.clone(), cfg).is_err());
        let cfg = ServeConfig { workers: 2, max_batch: 0, batch_window: Duration::ZERO };
        assert!(Server::start_lm(stack, cfg).is_err());
    }

    #[test]
    fn close_of_never_created_session_is_a_noop_end_to_end() {
        let stack = Arc::new(synthetic_stack(32, 8, 12, 1, 32, 11));
        let server = Server::start_lm(
            stack,
            ServeConfig { workers: 1, max_batch: 4, batch_window: Duration::from_micros(50) },
        )
        .unwrap();
        // close a session that never submitted anything, then stream a
        // real one through the same shard
        server.close_session(999);
        let (tx, rx) = mpsc::channel();
        server.submit(1, 3, tx.clone()).unwrap();
        let reply = rx.recv_timeout(Duration::from_secs(5)).expect("live session still served");
        assert!(!reply.is_rejected());
        // the phantom close neither panicked the shard nor left (or
        // created) a session entry: only the live session is counted
        let agg = server.stats();
        assert_eq!(agg.sessions, 1, "unknown close must not leak a session entry");
        server.close_session(1);
        // a second phantom close after real traffic is equally harmless
        server.close_session(999);
        let (tx2, rx2) = mpsc::channel();
        server.submit(2, 5, tx2).unwrap();
        assert!(!rx2.recv_timeout(Duration::from_secs(5)).unwrap().is_rejected());
        assert_eq!(server.stats().sessions, 1, "session 1 closed, session 2 live");
        server.shutdown();
    }

    #[test]
    fn per_task_requests_are_validated_at_submit() {
        let stack = Arc::new(synthetic_stack(32, 8, 12, 1, 32, 7));
        let server = Server::start_lm(stack, ServeConfig::default()).unwrap();
        let (tx, _rx) = mpsc::channel();
        assert!(server.submit_sequence(1, vec![], tx.clone()).is_err(), "empty sequence");
        assert!(server.submit_sequence(1, vec![1, 40], tx.clone()).is_err(), "oov in sequence");
        assert!(server.finalize(1, tx.clone()).is_err(), "lm has no classification head");
        assert!(
            server.decode(1, DecodeParams::default(), tx.clone()).is_err(),
            "lm has no decoder"
        );
        server.shutdown();
    }
}
