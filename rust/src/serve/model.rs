//! Task-generic serving model: the layer that turns "a `.tensors`
//! checkpoint" into "something the engine knows how to serve".
//!
//! [`ServeModel`] wraps the checkpoint's `meta/task_cfg` (parsed by
//! the same [`crate::tasks::read_task_cfg`] the eval harness uses, so
//! serve and eval always rebuild the identical topology) and exposes
//! the per-task request/response contract:
//!
//! | task | request shape                   | response shape                  |
//! |------|---------------------------------|---------------------------------|
//! | lm   | stream tokens / prefill         | per-step next-token logits      |
//! | pos  | stream tokens / whole sentence  | per-step tag scores             |
//! | nli  | stream pair, then finalize      | 3-way classification logits     |
//! | mt   | upload source, then decode      | decoded target tokens + score   |
//!
//! For `mt` the model holds **two** stacks (encoder = the primary
//! stack whose state lives in the session store, decoder = the stack
//! the decode loop steps); their per-layer hidden sizes must match so
//! the encoder's final state can seed the decoder — the inference side
//! of the training subsystem's gradient state bridge.
//!
//! Checkpoints without task metadata (raw/synthetic LM stacks) load
//! as plain language models with no head-width constraints.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::translation::{BOS, EOS};
use crate::lstm::model::{build_stack_from_params, ParamBag};
use crate::lstm::{QLstmStack, StreamState};
use crate::tasks::{read_task_cfg, TaskConfig, TaskKind};
use crate::tensorfile::{read_tensors, Tensor};

/// Hard cap on [`DecodeParams::max_len`]: a single decode request may
/// not monopolize a shard for longer than this many decoder steps.
pub const MAX_DECODE_LEN: usize = 1024;

/// Hard cap on [`DecodeParams::beam_width`]: beams ride the batched
/// kernels as lanes, and the decoder scratch grows to hold them.
pub const MAX_BEAM_WIDTH: usize = 16;

/// Upper bound on [`DecodeParams::len_norm`]: α beyond this rewards
/// length so aggressively the normalized score stops ranking anything.
pub const MAX_LEN_NORM: f32 = 4.0;

/// Parameters of one MT decode request.
#[derive(Clone, Copy, Debug)]
pub struct DecodeParams {
    /// decode-step budget; lanes retire early when they emit
    /// [`EOS`](crate::data::translation::EOS) (EOS included in the
    /// reply), so this is a *maximum*, not an exact length
    pub max_len: usize,
    /// 1 = greedy (batched across concurrent decodes); >1 = beam
    /// search, beams batched as lanes of one request
    pub beam_width: usize,
    /// length-normalization exponent α for beam scores: hypotheses
    /// rank (and the reply scores) by `score / len^α`. `0.0` (the
    /// default) disables it — raw summed log-probs, bit-identical to
    /// the unnormalized engine. CLI: `--beam-len-norm <alpha>`.
    pub len_norm: f32,
}

impl Default for DecodeParams {
    fn default() -> Self {
        DecodeParams { max_len: 16, beam_width: 1, len_norm: 0.0 }
    }
}

/// `score / len^α` — the beam ranking unit when length normalization
/// is on. `α = 0` returns `score` unchanged (the exact same bits), so
/// the default-off path is untouched arithmetic, not just an
/// approximate no-op.
pub(crate) fn length_normalized(score: f32, len: usize, alpha: f32) -> f32 {
    if alpha == 0.0 {
        score
    } else {
        score / (len.max(1) as f32).powf(alpha)
    }
}

/// A loaded, validated model plus the task contract it serves.
pub struct ServeModel {
    pub task: TaskKind,
    /// primary stack: the whole model for lm/pos/nli, the **encoder**
    /// for mt (its state is what the session store holds)
    pub stack: Arc<QLstmStack>,
    /// mt decoder stack (`None` for single-stack tasks)
    pub decoder: Option<Arc<QLstmStack>>,
    /// checkpoint task config (`None` for raw/synthetic LM stacks —
    /// no head-width constraints apply then)
    pub cfg: Option<TaskConfig>,
}

impl ServeModel {
    /// Wrap a raw single stack as a language model — synthetic stacks,
    /// legacy LM checkpoints without task metadata.
    pub fn lm(stack: Arc<QLstmStack>) -> Result<ServeModel> {
        ServeModel::from_parts(TaskKind::Lm, stack, None, None)
    }

    /// Assemble from already-built stacks (benches, tests). Validates
    /// the same per-task topology rules as checkpoint loading.
    pub fn from_parts(
        task: TaskKind,
        stack: Arc<QLstmStack>,
        decoder: Option<Arc<QLstmStack>>,
        cfg: Option<TaskConfig>,
    ) -> Result<ServeModel> {
        let m = ServeModel { task, stack, decoder, cfg };
        m.validate()?;
        Ok(m)
    }

    /// Load any `.tensors` checkpoint, auto-detecting the task from
    /// its `meta/task_cfg` blob (absent → raw LM topology).
    pub fn load(path: impl AsRef<Path>) -> Result<ServeModel> {
        let path = path.as_ref();
        let tensors =
            read_tensors(path).with_context(|| format!("load {}", path.display()))?;
        ServeModel::from_tensors(tensors)
            .with_context(|| format!("assemble serving model from {}", path.display()))
    }

    /// [`Self::load`] over already-read tensors.
    pub fn from_tensors(tensors: Vec<Tensor>) -> Result<ServeModel> {
        let cfg = read_task_cfg(&tensors)?;
        let bag = ParamBag::from_tensors(tensors);
        let (task, stack, decoder) = match &cfg {
            None => (TaskKind::Lm, build_stack_from_params(&bag, "")?, None),
            Some(c) => match c.task {
                TaskKind::Mt => (
                    TaskKind::Mt,
                    build_stack_from_params(&bag, "enc").context("mt encoder sub-tree")?,
                    Some(build_stack_from_params(&bag, "dec").context("mt decoder sub-tree")?),
                ),
                kind => (kind, build_stack_from_params(&bag, "")?, None),
            },
        };
        ServeModel::from_parts(task, Arc::new(stack), decoder.map(Arc::new), cfg)
    }

    /// Select the forward-kernel tier (`--kernel-tier`) on every stack
    /// the model holds. Tiers are a runtime choice applied at load
    /// time, before worker threads clone the `Arc`s — once the model
    /// is shared the stacks are frozen, so this errors on an aliased
    /// stack instead of silently serving mixed tiers.
    pub fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) -> Result<()> {
        let Some(stack) = Arc::get_mut(&mut self.stack) else {
            bail!("kernel tier must be selected before the model is shared across workers");
        };
        stack.set_kernel_tier(tier);
        if let Some(dec) = &mut self.decoder {
            let Some(dec) = Arc::get_mut(dec) else {
                bail!("kernel tier must be selected before the model is shared across workers");
            };
            dec.set_kernel_tier(tier);
        }
        Ok(())
    }

    /// Select the SIMD execution path (`--kernel-isa`) on every stack
    /// the model holds — same load-time-only contract as
    /// [`Self::set_kernel_tier`], and bit-identical across paths
    /// ([`crate::qmath::simd`]).
    pub fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) -> Result<()> {
        let Some(stack) = Arc::get_mut(&mut self.stack) else {
            bail!("kernel isa must be selected before the model is shared across workers");
        };
        stack.set_kernel_isa(isa);
        if let Some(dec) = &mut self.decoder {
            let Some(dec) = Arc::get_mut(dec) else {
                bail!("kernel isa must be selected before the model is shared across workers");
            };
            dec.set_kernel_isa(isa);
        }
        Ok(())
    }

    /// Vocabulary the client's input tokens are validated against
    /// (the source vocabulary for mt).
    pub fn input_vocab(&self) -> usize {
        self.stack.embed.vocab
    }

    /// Head width of the stack whose logits clients receive: the
    /// primary head for lm/pos/nli, the decoder head for mt.
    pub fn n_out(&self) -> usize {
        match &self.decoder {
            Some(d) => d.n_out(),
            None => self.stack.n_out(),
        }
    }

    /// Streamability + per-task topology rules — everything that must
    /// hold before a worker thread may trust the model. Errors here,
    /// not panics: a bad checkpoint is a client-facing condition.
    pub fn validate(&self) -> Result<()> {
        if !self.stack.is_unidirectional() {
            bail!("serving requires a unidirectional stack (bidirectional layers cannot stream)");
        }
        match (self.task, &self.decoder) {
            (TaskKind::Mt, None) => bail!("task mt needs an encoder/decoder pair"),
            (TaskKind::Mt, Some(dec)) => {
                if !dec.is_unidirectional() {
                    bail!(
                        "serving requires a unidirectional decoder stack \
                         (bidirectional layers cannot stream)"
                    );
                }
                if dec.hidden_dims() != self.stack.hidden_dims() {
                    bail!(
                        "mt state bridge needs matching hidden sizes: encoder {:?} vs decoder {:?}",
                        self.stack.hidden_dims(),
                        dec.hidden_dims()
                    );
                }
            }
            (task, Some(_)) => {
                bail!("task {} is single-stack but a decoder was supplied", task.name())
            }
            (_, None) => {}
        }
        let Some(cfg) = &self.cfg else { return Ok(()) };
        if cfg.task != self.task {
            bail!("task mismatch: model {} vs config {}", self.task.name(), cfg.task.name());
        }
        // head-width-aware checks: the head must be exactly as wide as
        // the task's output space, or every reply would be mis-shaped
        let n_out = self.stack.n_out();
        match self.task {
            TaskKind::Lm => {
                if n_out != cfg.vocab {
                    bail!("lm head is {n_out}-wide but the vocabulary has {} tokens", cfg.vocab);
                }
            }
            TaskKind::Pos => {
                if n_out != cfg.n_classes {
                    bail!("pos head is {n_out}-wide but the tag set has {} classes", cfg.n_classes);
                }
            }
            TaskKind::Nli => {
                if n_out != cfg.n_classes || cfg.n_classes != 3 {
                    bail!(
                        "nli head must be 3-wide (entail/contradict/neutral), \
                         got head {n_out} / config {}",
                        cfg.n_classes
                    );
                }
            }
            TaskKind::Mt => {
                let dec = self.decoder.as_ref().expect("checked above");
                if dec.embed.vocab != cfg.vocab_tgt || dec.n_out() != cfg.vocab_tgt {
                    bail!(
                        "mt decoder must embed and predict the {}-token target vocabulary, \
                         got embed {} / head {}",
                        cfg.vocab_tgt,
                        dec.embed.vocab,
                        dec.n_out()
                    );
                }
                if self.stack.embed.vocab != cfg.vocab {
                    bail!(
                        "mt encoder embeds {} tokens but the source vocabulary has {}",
                        self.stack.embed.vocab,
                        cfg.vocab
                    );
                }
            }
        }
        Ok(())
    }

    /// Decoder initial state = a copy of the (encoder) stream state —
    /// the inference side of the training state bridge. The encoder
    /// state itself is untouched, so a session can decode repeatedly.
    pub fn bridge_state(&self, enc_state: &StreamState) -> StreamState {
        let dec = self.decoder.as_ref().expect("bridge_state needs a decoder");
        let mut st = dec.new_stream_state();
        for (l, h) in enc_state.h.iter().enumerate() {
            st.h[l].copy_from_slice(h);
            st.c[l].copy_from_slice(&enc_state.c[l]);
        }
        st
    }

    /// Offline, unbatched reference of the greedy decode loop: encoder
    /// [`QLstmStack::forward_from`] over the source, then one
    /// sequential decoder step per emitted token, stopping early when
    /// the lane emits EOS (EOS included in the output). The serving
    /// decode loop must match this bit-for-bit whatever micro-batch
    /// its steps ride in (pinned by `tests/serve_tasks.rs`).
    pub fn reference_greedy_decode(
        &self,
        src: &[usize],
        max_len: usize,
    ) -> Result<(Vec<usize>, f32)> {
        let Some(dec) = &self.decoder else {
            bail!("greedy decode needs an encoder/decoder pair (task {})", self.task.name())
        };
        let mut enc_state = self.stack.new_stream_state();
        self.stack.forward_from(src, &mut enc_state);
        let mut state = self.bridge_state(&enc_state);
        let mut tokens = Vec::with_capacity(max_len);
        let mut score = 0f32;
        let mut cur = BOS as usize;
        for _ in 0..max_len {
            let logits = dec.forward_from(&[cur], &mut state);
            let lg = &logits[0];
            let next = argmax(lg);
            score += token_log_prob(lg, next);
            tokens.push(next);
            if next == EOS as usize {
                break;
            }
            cur = next;
        }
        Ok((tokens, score))
    }
}

/// Per-task request validation — the single source of truth for what
/// a model accepts, used both by the `Server` submit methods (friendly
/// errors before anything is queued) and by the worker threads
/// (defense in depth: a request pushed onto a queue directly must not
/// panic a shard). Returns the rejection reason for a bad request.
pub(crate) fn validate_request(
    model: &ServeModel,
    kind: &super::scheduler::RequestKind,
) -> Result<(), String> {
    use super::scheduler::RequestKind;
    let vocab = model.stack.embed.vocab;
    match kind {
        RequestKind::Step { token } => {
            if *token >= vocab {
                return Err(format!("token id {token} out of range for vocab {vocab}"));
            }
        }
        RequestKind::Sequence { tokens } => {
            if tokens.is_empty() {
                return Err("empty sequence".to_string());
            }
            if let Some(&t) = tokens.iter().find(|&&t| t >= vocab) {
                return Err(format!("token id {t} out of range for vocab {vocab}"));
            }
        }
        RequestKind::Finalize => {
            if model.task != TaskKind::Nli {
                return Err(format!(
                    "finalize: task {} has no sequence-level classification head",
                    model.task.name()
                ));
            }
        }
        RequestKind::Decode(p) => {
            if model.decoder.is_none() {
                return Err(format!(
                    "decode: task {} has no encoder/decoder pair",
                    model.task.name()
                ));
            }
            if p.max_len == 0 || p.max_len > MAX_DECODE_LEN {
                return Err(format!(
                    "decode max_len {} out of range 1..={MAX_DECODE_LEN}",
                    p.max_len
                ));
            }
            if p.beam_width == 0 || p.beam_width > MAX_BEAM_WIDTH {
                return Err(format!(
                    "beam width {} out of range 1..={MAX_BEAM_WIDTH}",
                    p.beam_width
                ));
            }
            // NaN fails the range check too — a NaN α would poison
            // every score comparison in the beam
            if !(0.0..=MAX_LEN_NORM).contains(&p.len_norm) {
                return Err(format!(
                    "beam length-norm alpha {} out of range 0..={MAX_LEN_NORM}",
                    p.len_norm
                ));
            }
        }
    }
    Ok(())
}

/// Index of the largest value (first on ties — deterministic).
pub(crate) fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// `(max, ln Σ exp(v - max))` of a logit row — the two shared terms of
/// a numerically-stable log-softmax.
pub(crate) fn log_softmax_terms(logits: &[f32]) -> (f32, f32) {
    let mut m = f32::NEG_INFINITY;
    for &v in logits {
        if v > m {
            m = v;
        }
    }
    let mut z = 0f32;
    for &v in logits {
        z += (v - m).exp();
    }
    (m, z.ln())
}

/// `log P(tok)` under a softmax over `logits` — the score unit of the
/// decode loop. One shared arithmetic (`logits[tok] - max - lnZ`, in
/// this operation order) so the serving loop, the beam expansion, and
/// the offline reference accumulate bit-identical scores.
pub fn token_log_prob(logits: &[f32], tok: usize) -> f32 {
    let (m, lnz) = log_softmax_terms(logits);
    logits[tok] - m - lnz
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn raw_stack_loads_as_lm_and_rejects_bidirectional() {
        let stack = Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3));
        let m = ServeModel::lm(stack).unwrap();
        assert_eq!(m.task, TaskKind::Lm);
        assert_eq!(m.input_vocab(), 16);
        assert_eq!(m.n_out(), 16);

        let mut bidi = synthetic_stack(16, 4, 6, 1, 16, 3);
        let extra = synthetic_stack(16, 6, 6, 1, 16, 4).layers.remove(0).fwd;
        bidi.layers[0].bwd = Some(extra);
        let err = ServeModel::lm(Arc::new(bidi)).err().expect("bidirectional must be refused");
        assert!(err.to_string().contains("unidirectional"), "got: {err}");
    }

    #[test]
    fn mt_pair_validates_hidden_bridge_and_head_width() {
        let enc = Arc::new(synthetic_stack(20, 4, 8, 1, 1, 5));
        let dec = Arc::new(synthetic_stack(24, 4, 8, 1, 24, 6));
        let m = ServeModel::from_parts(TaskKind::Mt, enc.clone(), Some(dec), None).unwrap();
        assert_eq!(m.n_out(), 24, "mt replies carry decoder-head logits");

        // mismatched hidden sizes break the state bridge
        let dec_bad = Arc::new(synthetic_stack(24, 4, 10, 1, 24, 7));
        let err = ServeModel::from_parts(TaskKind::Mt, enc.clone(), Some(dec_bad), None)
            .err()
            .expect("mismatched hidden sizes must be refused");
        assert!(err.to_string().contains("hidden"), "got: {err}");

        // single-stack task with a decoder is a wiring bug
        let dec2 = Arc::new(synthetic_stack(24, 4, 8, 1, 24, 8));
        assert!(ServeModel::from_parts(TaskKind::Lm, enc, Some(dec2), None).is_err());
        // mt without a decoder cannot decode
        let solo = Arc::new(synthetic_stack(20, 4, 8, 1, 1, 9));
        assert!(ServeModel::from_parts(TaskKind::Mt, solo, None, None).is_err());
    }

    #[test]
    fn head_width_checks_use_task_cfg() {
        let mut cfg = TaskConfig::preset(TaskKind::Pos);
        cfg.vocab = 60;
        cfg.n_classes = 6;
        // head width 5 != 6 classes must be rejected
        let stack = Arc::new(synthetic_stack(60, 8, 10, 1, 5, 2));
        let err = ServeModel::from_parts(TaskKind::Pos, stack, None, Some(cfg.clone()))
            .err()
            .expect("head/class width mismatch must be refused");
        assert!(err.to_string().contains("classes"), "got: {err}");
        let ok = Arc::new(synthetic_stack(60, 8, 10, 1, 6, 2));
        assert!(ServeModel::from_parts(TaskKind::Pos, ok, None, Some(cfg)).is_ok());
    }

    #[test]
    fn validate_request_rejects_per_task() {
        use super::super::scheduler::RequestKind;
        let stack = Arc::new(synthetic_stack(16, 4, 6, 1, 16, 3));
        let lm = ServeModel::lm(stack).unwrap();
        assert!(validate_request(&lm, &RequestKind::Step { token: 15 }).is_ok());
        assert!(validate_request(&lm, &RequestKind::Step { token: 16 }).is_err());
        assert!(validate_request(&lm, &RequestKind::Sequence { tokens: vec![] }).is_err());
        assert!(validate_request(&lm, &RequestKind::Sequence { tokens: vec![1, 99] }).is_err());
        assert!(
            validate_request(&lm, &RequestKind::Finalize).is_err(),
            "lm has no classification head"
        );
        assert!(
            validate_request(&lm, &RequestKind::Decode(DecodeParams::default())).is_err(),
            "lm has no decoder"
        );

        let enc = Arc::new(synthetic_stack(20, 4, 8, 1, 1, 5));
        let dec = Arc::new(synthetic_stack(24, 4, 8, 1, 24, 6));
        let mt = ServeModel::from_parts(TaskKind::Mt, enc, Some(dec), None).unwrap();
        assert!(validate_request(&mt, &RequestKind::Decode(DecodeParams::default())).is_ok());
        let too_long = DecodeParams { max_len: MAX_DECODE_LEN + 1, beam_width: 1, len_norm: 0.0 };
        assert!(validate_request(&mt, &RequestKind::Decode(too_long)).is_err());
        let too_wide =
            DecodeParams { max_len: 4, beam_width: MAX_BEAM_WIDTH + 1, len_norm: 0.0 };
        assert!(validate_request(&mt, &RequestKind::Decode(too_wide)).is_err());
        for bad_alpha in [-0.5f32, MAX_LEN_NORM + 0.5, f32::NAN] {
            let p = DecodeParams { max_len: 4, beam_width: 2, len_norm: bad_alpha };
            assert!(
                validate_request(&mt, &RequestKind::Decode(p)).is_err(),
                "alpha {bad_alpha} must be rejected"
            );
        }
        let ok = DecodeParams { max_len: 4, beam_width: 2, len_norm: 0.7 };
        assert!(validate_request(&mt, &RequestKind::Decode(ok)).is_ok());
    }

    #[test]
    fn length_normalization_is_exact_noop_at_alpha_zero() {
        let s = -3.372_817_f32;
        assert_eq!(length_normalized(s, 7, 0.0).to_bits(), s.to_bits());
        // α = 1 divides by the length
        assert!((length_normalized(-8.0, 4, 1.0) - -2.0).abs() < 1e-6);
        // longer hypotheses are penalized less per token under α > 0
        assert!(length_normalized(-8.0, 8, 1.0) > length_normalized(-8.0, 4, 1.0));
    }

    #[test]
    fn token_log_prob_is_a_log_probability() {
        let lg = [0.5f32, -1.0, 2.0, 0.0];
        let mut total = 0f64;
        for t in 0..lg.len() {
            total += (token_log_prob(&lg, t) as f64).exp();
        }
        assert!((total - 1.0).abs() < 1e-6, "probabilities sum to {total}");
        assert_eq!(argmax(&lg), 2);
        assert!(token_log_prob(&lg, 2) > token_log_prob(&lg, 0));
    }
}
