//! The worker pool: one thread per shard, each owning its session
//! store, its flat [`StackScratch`]es (one for the primary stack, one
//! for the mt decoder), and its request queue. A micro-batch is
//! processed in per-kind groups, every group on the same batched
//! kernels:
//!
//! * **steps** — all single-token requests share one `step_batch`;
//! * **sequences** — prefills/whole sentences run in ragged lockstep
//!   (the idle lanes drop out as their sequences end);
//! * **finalizes** — answered from the session's cached head output,
//!   no model work;
//! * **decodes** — greedy decodes share the decode loop's lanes, each
//!   lane feeding its own argmax back; beam decodes batch their beams
//!   as lanes of one request.
//!
//! Grouping is a scheduling choice, not a numeric one: `step_batch` is
//! bit-identical for every batch composition, so replies never depend
//! on which group (or which micro-batch) a token rode in.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::translation::{BOS, EOS};
use crate::lstm::{QLstmStack, StackScratch, StreamState};
use crate::tasks::TaskKind;
use crate::telemetry::serve_trace::unum;
use crate::telemetry::ServeTraceSink;
use crate::tensorfile::json::Json;

use super::model::{
    argmax, length_normalized, log_softmax_terms, token_log_prob, validate_request, DecodeParams,
    ServeModel, MAX_BEAM_WIDTH,
};
use super::scheduler::{Payload, Reply, Request, RequestKind, RequestQueue};
use super::session::{SessionId, SessionStore};
use super::stats::{kind_index, ShardStats, KIND_NAMES};
use super::ServeConfig;

/// A reply ready to send, paired with its client's channel.
type Outgoing = (mpsc::Sender<Reply>, Reply);

/// Handles to the running shards.
pub struct WorkerPool {
    pub queues: Vec<Arc<RequestQueue>>,
    pub stats: Vec<Arc<ShardStats>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `cfg.workers` shard threads over a shared model. With a
    /// serve-trace sink, every shard shares it and emits its
    /// lifecycle/batch/request events at batch boundaries (the sink
    /// serializes whole lines internally).
    pub fn spawn(
        model: Arc<ServeModel>,
        cfg: &ServeConfig,
        trace: Option<Arc<ServeTraceSink>>,
    ) -> WorkerPool {
        let mut queues = Vec::with_capacity(cfg.workers);
        let mut stats = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for shard in 0..cfg.workers {
            let queue = Arc::new(RequestQueue::new());
            let stat = Arc::new(ShardStats::new());
            queues.push(queue.clone());
            stats.push(stat.clone());
            let model = model.clone();
            let max_batch = cfg.max_batch;
            let window = cfg.batch_window;
            let trace = trace.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-shard-{shard}"))
                    .spawn(move || {
                        run_worker(&model, &queue, &stat, max_batch, window, shard, trace)
                    })
                    .expect("spawn shard thread"),
            );
        }
        WorkerPool { queues, stats, handles }
    }

    /// Signal shutdown, let the workers drain their queues, and join.
    pub fn shutdown(self) {
        for q in &self.queues {
            q.shutdown();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Seed field map for a per-shard trace event.
fn shard_fields(shard: usize) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("shard".to_string(), unum(shard as u64));
    m
}

/// One request's trace metadata, captured at batch formation and
/// emitted (aligned with the per-kind `lats` order) after processing.
struct ReqMeta {
    session: SessionId,
    kind: usize,
    work: u64,
    queue_wait: Duration,
}

#[allow(clippy::too_many_arguments)]
fn run_worker(
    model: &ServeModel,
    queue: &RequestQueue,
    stats: &ShardStats,
    max_batch: usize,
    window: Duration,
    shard: usize,
    trace: Option<Arc<ServeTraceSink>>,
) {
    let mut store = SessionStore::new();
    let mut scratch = model.stack.scratch(max_batch);
    // sized for the bigger of the micro-batch lanes and a full beam —
    // a beam decode batches its beams as lanes of this scratch, and
    // `load_state` slices into it before `step_batch` could grow it
    let mut dec_scratch =
        model.decoder.as_ref().map(|d| d.scratch(max_batch.max(MAX_BEAM_WIDTH)));
    stats.set_kernel_tier(model.stack.kernel_tier());
    stats.set_kernel_isa(model.stack.kernel_isa());

    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    let mut closes: Vec<SessionId> = Vec::new();
    let mut steps: Vec<Request> = Vec::with_capacity(max_batch);
    let mut seqs: Vec<Request> = Vec::new();
    let mut finals: Vec<Request> = Vec::new();
    let mut decodes: Vec<Request> = Vec::new();
    let mut lats: Vec<Duration> = Vec::with_capacity(max_batch);
    let mut outbox: Vec<Outgoing> = Vec::with_capacity(max_batch);
    let mut meta: Vec<ReqMeta> = Vec::with_capacity(max_batch);
    // per-shard micro-batch ordinal (the trace's `batch` key)
    let mut batch_no: u64 = 0;

    while queue.next_batch(max_batch, window, &mut batch, &mut closes) {
        // batch-formation instant: splits every request's lifecycle
        // into queue-wait (enqueue → here) and service (here → reply)
        let formed = Instant::now();
        // closes are ordered by the scheduler to never precede queued
        // requests of their session, so dropping state here is safe
        let n_closes = closes.len();
        for s in closes.drain(..) {
            let existed = store.close(s);
            if let Some(tr) = &trace {
                let mut f = shard_fields(shard);
                f.insert("session".to_string(), unum(s));
                f.insert("existed".to_string(), Json::Bool(existed));
                tr.emit("session_close", f);
            }
        }
        batch.retain(|r| match validate_request(model, &r.kind) {
            Ok(()) => true,
            Err(reason) => {
                if let Some(tr) = &trace {
                    let mut f = shard_fields(shard);
                    f.insert("session".to_string(), unum(r.session));
                    f.insert(
                        "kind".to_string(),
                        Json::Str(KIND_NAMES[kind_index(&r.kind)].to_string()),
                    );
                    f.insert("reason".to_string(), Json::Str(reason.clone()));
                    tr.emit("reject", f);
                }
                // answer with an explicit rejection — the client may
                // hold its own Sender clone, so merely dropping the
                // request would leave it blocked on recv forever
                let _ = r.reply_to.send(Reply {
                    session: r.session,
                    payload: Payload::Rejected { reason },
                    latency: r.enqueued.elapsed(),
                });
                false
            }
        });
        if batch.is_empty() {
            stats.set_sessions(store.len());
            stats.set_queue_high_water(queue.high_water());
            continue;
        }

        if let Some(tr) = &trace {
            // a processed Step/Sequence/Decode creates session state on
            // first use (Finalize never does) — emitted before the
            // groups run, while `contains` still answers "not yet"
            for r in batch.iter() {
                if kind_index(&r.kind) != 2 && !store.contains(r.session) {
                    let mut f = shard_fields(shard);
                    f.insert("session".to_string(), unum(r.session));
                    tr.emit("session_open", f);
                }
            }
        }

        let n_requests = batch.len();
        let mut work = 0u64;
        let mut kind_reqs = [0u64; 4];
        let mut kind_work = [0u64; 4];
        meta.clear();
        for r in batch.drain(..) {
            let w = r.kind.work();
            let k = kind_index(&r.kind);
            work += w;
            kind_reqs[k] += 1;
            kind_work[k] += w;
            if trace.is_some() {
                meta.push(ReqMeta {
                    session: r.session,
                    kind: k,
                    work: w,
                    queue_wait: formed.saturating_duration_since(r.enqueued),
                });
            }
            match r.kind {
                RequestKind::Step { .. } => steps.push(r),
                RequestKind::Sequence { .. } => seqs.push(r),
                RequestKind::Finalize => finals.push(r),
                RequestKind::Decode(_) => decodes.push(r),
            }
        }
        lats.clear();
        outbox.clear();

        run_steps(model, &mut store, &mut scratch, &mut steps, &mut lats, &mut outbox);
        run_sequences(model, &mut store, &mut scratch, &mut seqs, &mut lats, &mut outbox);
        run_finalizes(&mut store, &mut finals, &mut lats, &mut outbox);
        run_decodes(model, &mut store, dec_scratch.as_mut(), &mut decodes, &mut lats, &mut outbox);
        let batch_span = formed.elapsed();

        // record before sending so an observer that saw all replies
        // also sees the matching counters
        stats.record_batch(n_requests, work, &lats);
        stats.record_kinds(&kind_reqs, &kind_work);
        stats.set_sessions(store.len());
        stats.set_queue_high_water(queue.high_water());
        // batch-level lines honor the sink's `--trace-every` sampling;
        // lifecycle events above always emit, so the sampled stream
        // keeps its session bookkeeping intact
        if let Some(tr) = trace.as_ref().filter(|tr| tr.samples(batch_no)) {
            // groups ran in kind order (steps, seqs, finals, decodes),
            // each preserving batch order, so a stable sort by kind
            // aligns `meta` index-wise with `lats`
            meta.sort_by_key(|m| m.kind);
            for (m, lat) in meta.iter().zip(lats.iter()) {
                let mut f = shard_fields(shard);
                f.insert("batch".to_string(), unum(batch_no));
                f.insert("session".to_string(), unum(m.session));
                f.insert("kind".to_string(), Json::Str(KIND_NAMES[m.kind].to_string()));
                f.insert("work".to_string(), unum(m.work));
                f.insert("occupancy".to_string(), unum(n_requests as u64));
                let mut t = BTreeMap::new();
                t.insert(
                    "queue_wait_us".to_string(),
                    Json::Num(m.queue_wait.as_secs_f64() * 1e6),
                );
                t.insert("service_us".to_string(), Json::Num(lat.as_secs_f64() * 1e6));
                f.insert("timing".to_string(), Json::Obj(t));
                tr.emit("request", f);
            }
            let mut f = shard_fields(shard);
            f.insert("batch".to_string(), unum(batch_no));
            f.insert("requests".to_string(), unum(n_requests as u64));
            f.insert("work".to_string(), unum(work));
            f.insert("closes".to_string(), unum(n_closes as u64));
            let mut kinds = BTreeMap::new();
            for (k, name) in KIND_NAMES.iter().enumerate() {
                kinds.insert(name.to_string(), unum(kind_reqs[k]));
            }
            f.insert("kinds".to_string(), Json::Obj(kinds));
            f.insert("queue_depth".to_string(), unum(queue.depth() as u64));
            f.insert("queue_high_water".to_string(), unum(queue.high_water() as u64));
            f.insert("sessions".to_string(), unum(store.len() as u64));
            let mut t = BTreeMap::new();
            t.insert("batch_ms".to_string(), Json::Num(batch_span.as_secs_f64() * 1e3));
            f.insert("timing".to_string(), Json::Obj(t));
            tr.emit("batch", f);
        }
        batch_no += 1;
        for (to, reply) in outbox.drain(..) {
            let _ = to.send(reply);
        }
    }
}

/// All single-token requests of the batch share one `step_batch`.
fn run_steps(
    model: &ServeModel,
    store: &mut SessionStore,
    scratch: &mut StackScratch,
    steps: &mut Vec<Request>,
    lats: &mut Vec<Duration>,
    outbox: &mut Vec<Outgoing>,
) {
    if steps.is_empty() {
        return;
    }
    let stack: &QLstmStack = &model.stack;
    // only nli's Finalize ever reads the cache — keep the streaming
    // hot path free of the per-token O(n_out) copy for other tasks
    let cache_last = model.task == TaskKind::Nli;
    let n_out = stack.n_out();
    let ids: Vec<usize> = steps
        .iter()
        .map(|r| match &r.kind {
            RequestKind::Step { token } => *token,
            _ => unreachable!("steps group holds only Step requests"),
        })
        .collect();
    // gather: session states → flat batch slots
    for (slot, r) in steps.iter().enumerate() {
        let sess = store.open(r.session, stack);
        scratch.load_state(slot, &sess.state);
    }
    stack.step_batch(&ids, scratch);
    // scatter: batch slots → session states; build replies
    for (slot, r) in steps.drain(..).enumerate() {
        let sess = store.get_mut(r.session).expect("opened above");
        scratch.store_state(slot, &mut sess.state);
        sess.tokens += 1;
        let logits = scratch.logits[slot * n_out..(slot + 1) * n_out].to_vec();
        if cache_last {
            sess.last_logits.clone_from(&logits);
        }
        let top = argmax(&logits);
        let latency = r.enqueued.elapsed();
        lats.push(latency);
        outbox.push((
            r.reply_to,
            Reply { session: r.session, payload: Payload::Step { logits, top }, latency },
        ));
    }
}

/// Whole-sequence requests run in ragged lockstep: lanes drop out as
/// their sequences end, exactly like the offline
/// [`QLstmStack::forward_batch`] — and therefore bit-identical to
/// streaming the same tokens one `Step` at a time.
fn run_sequences(
    model: &ServeModel,
    store: &mut SessionStore,
    scratch: &mut StackScratch,
    seqs: &mut Vec<Request>,
    lats: &mut Vec<Duration>,
    outbox: &mut Vec<Outgoing>,
) {
    if seqs.is_empty() {
        return;
    }
    let stack: &QLstmStack = &model.stack;
    let n_out = stack.n_out();
    let n = seqs.len();
    // pos replies need every step's tag scores; other tasks only the last
    let collect_steps = model.task == TaskKind::Pos;
    // only nli's Finalize ever reads the session's cached head output
    let cache_last = model.task == TaskKind::Nli;
    // local copies of the session states, written back after lockstep
    let mut states: Vec<StreamState> = Vec::with_capacity(n);
    for r in seqs.iter() {
        states.push(store.open(r.session, stack).state.clone());
    }
    let mut per_step: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut last: Vec<Vec<f32>> = vec![Vec::new(); n];
    {
        let toks: Vec<&[usize]> = seqs
            .iter()
            .map(|r| match &r.kind {
                RequestKind::Sequence { tokens } => tokens.as_slice(),
                _ => unreachable!("sequence group holds only Sequence requests"),
            })
            .collect();
        let t_max = toks.iter().map(|s| s.len()).max().unwrap_or(0);
        let mut ids: Vec<usize> = Vec::with_capacity(n);
        let mut active: Vec<usize> = Vec::with_capacity(n);
        for t in 0..t_max {
            ids.clear();
            active.clear();
            for (i, s) in toks.iter().enumerate() {
                if t < s.len() {
                    active.push(i);
                    ids.push(s[t]);
                }
            }
            for (slot, &i) in active.iter().enumerate() {
                scratch.load_state(slot, &states[i]);
            }
            stack.step_batch(&ids, scratch);
            for (slot, &i) in active.iter().enumerate() {
                scratch.store_state(slot, &mut states[i]);
                let lg = scratch.logits[slot * n_out..(slot + 1) * n_out].to_vec();
                if collect_steps {
                    per_step[i].push(lg.clone());
                }
                last[i] = lg;
            }
        }
    }
    for (i, r) in seqs.drain(..).enumerate() {
        let consumed = match &r.kind {
            RequestKind::Sequence { tokens } => tokens.len(),
            _ => unreachable!("sequence group holds only Sequence requests"),
        };
        let sess = store.get_mut(r.session).expect("opened above");
        sess.state = std::mem::take(&mut states[i]);
        sess.tokens += consumed as u64;
        if cache_last {
            sess.last_logits.clone_from(&last[i]);
        }
        let payload = match model.task {
            TaskKind::Pos => Payload::Steps { logits: std::mem::take(&mut per_step[i]) },
            TaskKind::Mt => Payload::Encoded { consumed },
            _ => {
                let logits = std::mem::take(&mut last[i]);
                let top = argmax(&logits);
                Payload::Prefilled { consumed, logits, top }
            }
        };
        let latency = r.enqueued.elapsed();
        lats.push(latency);
        outbox.push((r.reply_to, Reply { session: r.session, payload, latency }));
    }
}

/// Finalize answers from the session's cached head output — no model
/// work, and no session is created for a stream that never existed.
fn run_finalizes(
    store: &mut SessionStore,
    finals: &mut Vec<Request>,
    lats: &mut Vec<Duration>,
    outbox: &mut Vec<Outgoing>,
) {
    for r in finals.drain(..) {
        let payload = match store.get_mut(r.session) {
            Some(sess) if !sess.last_logits.is_empty() => {
                let logits = sess.last_logits.clone();
                let label = argmax(&logits);
                Payload::Class { logits, label }
            }
            _ => Payload::Rejected { reason: "finalize before any submitted token".to_string() },
        };
        let latency = r.enqueued.elapsed();
        lats.push(latency);
        outbox.push((r.reply_to, Reply { session: r.session, payload, latency }));
    }
}

/// The mt decode loop. Each request's encoder context is bridged (by
/// copy — the session state is untouched, so clients can re-decode)
/// into a decoder state; greedy requests then share lanes of one
/// lockstep loop while beam requests batch their own beams.
fn run_decodes(
    model: &ServeModel,
    store: &mut SessionStore,
    dec_scratch: Option<&mut StackScratch>,
    decodes: &mut Vec<Request>,
    lats: &mut Vec<Duration>,
    outbox: &mut Vec<Outgoing>,
) {
    if decodes.is_empty() {
        return;
    }
    let (Some(dec), Some(scratch)) = (model.decoder.as_deref(), dec_scratch) else {
        unreachable!("decode requests are validated against the decoder")
    };
    let mut params: Vec<DecodeParams> = Vec::with_capacity(decodes.len());
    for r in decodes.iter() {
        match &r.kind {
            RequestKind::Decode(p) => params.push(*p),
            _ => unreachable!("decode group holds only Decode requests"),
        }
    }
    let mut results: Vec<Option<(Vec<usize>, f32)>> =
        (0..decodes.len()).map(|_| None).collect();

    // greedy decodes (beam_width == 1) share the loop's lanes
    let greedy_idx: Vec<usize> =
        (0..params.len()).filter(|&i| params[i].beam_width <= 1).collect();
    if !greedy_idx.is_empty() {
        let mut states: Vec<StreamState> = greedy_idx
            .iter()
            .map(|&i| model.bridge_state(&store.open(decodes[i].session, &model.stack).state))
            .collect();
        let max_lens: Vec<usize> = greedy_idx.iter().map(|&i| params[i].max_len).collect();
        let out = greedy_decode_batch(dec, scratch, &mut states, &max_lens);
        for (&i, res) in greedy_idx.iter().zip(out) {
            results[i] = Some(res);
        }
    }
    // beam decodes: each request's beams become the lanes
    for (i, p) in params.iter().enumerate() {
        if p.beam_width > 1 {
            let init =
                model.bridge_state(&store.open(decodes[i].session, &model.stack).state);
            results[i] = Some(beam_decode(dec, scratch, init, *p));
        }
    }
    for (i, r) in decodes.drain(..).enumerate() {
        let (tokens, score) = results[i].take().expect("decoded above");
        let latency = r.enqueued.elapsed();
        lats.push(latency);
        outbox.push((
            r.reply_to,
            Reply { session: r.session, payload: Payload::Decoded { tokens, score }, latency },
        ));
    }
}

/// Lockstep greedy decode over `states.len()` lanes: every lane feeds
/// its own argmax back, and lanes drop out as they reach their
/// `max_len` — or **retire early at EOS** (EOS included in the lane's
/// output, exactly like the offline reference). Bit-identical to the
/// single-lane [`ServeModel::reference_greedy_decode`] — lane
/// composition is a throughput choice, never a numeric one.
fn greedy_decode_batch(
    dec: &QLstmStack,
    scratch: &mut StackScratch,
    states: &mut [StreamState],
    max_lens: &[usize],
) -> Vec<(Vec<usize>, f32)> {
    let n = states.len();
    let dn = dec.n_out();
    let eos = EOS as usize;
    let mut toks: Vec<Vec<usize>> = max_lens.iter().map(|&m| Vec::with_capacity(m)).collect();
    let mut scores = vec![0f32; n];
    let mut cur: Vec<usize> = vec![BOS as usize; n];
    let mut done = vec![false; n];
    let t_max = max_lens.iter().copied().max().unwrap_or(0);
    let mut ids: Vec<usize> = Vec::with_capacity(n);
    let mut active: Vec<usize> = Vec::with_capacity(n);
    for t in 0..t_max {
        ids.clear();
        active.clear();
        for i in 0..n {
            if t < max_lens[i] && !done[i] {
                active.push(i);
                ids.push(cur[i]);
            }
        }
        if ids.is_empty() {
            break;
        }
        for (slot, &i) in active.iter().enumerate() {
            scratch.load_state(slot, &states[i]);
        }
        dec.step_batch(&ids, scratch);
        for (slot, &i) in active.iter().enumerate() {
            scratch.store_state(slot, &mut states[i]);
            let lg = &scratch.logits[slot * dn..(slot + 1) * dn];
            let next = argmax(lg);
            scores[i] += token_log_prob(lg, next);
            toks[i].push(next);
            if next == eos {
                done[i] = true;
            }
            cur[i] = next;
        }
    }
    toks.into_iter().zip(scores).collect()
}

/// One live hypothesis of a beam search.
struct Beam {
    toks: Vec<usize>,
    score: f32,
    state: StreamState,
}

/// Deterministic beam search for one request, live beams batched as
/// lanes. Per round, candidate ties break by (raw score desc, beam
/// index asc, token asc); a selected candidate whose token is EOS
/// **finishes** (retires from the lanes, EOS included in its tokens)
/// while the rest stay live — the loop ends when every selected
/// hypothesis has finished or `max_len` rounds have run.
///
/// The winner is the best hypothesis (finished or still live) under
/// the length-normalized score `score / len^α`
/// ([`length_normalized`]; α = [`DecodeParams::len_norm`], default 0
/// = raw scores, exact same bits as the unnormalized engine). Ties
/// keep the earliest hypothesis in (finished-order, then live-order),
/// so results are deterministic for every α.
///
/// `beam_width = 1` with α = 0 reproduces the greedy argmax path
/// exactly — same tokens incl. the EOS early stop, and (via the
/// shared [`token_log_prob`] arithmetic) the same score bits.
fn beam_decode(
    dec: &QLstmStack,
    scratch: &mut StackScratch,
    init: StreamState,
    p: DecodeParams,
) -> (Vec<usize>, f32) {
    let dn = dec.n_out();
    let k = p.beam_width.max(1);
    let eos = EOS as usize;
    let alpha = p.len_norm;
    let mut beams = vec![Beam { toks: Vec::new(), score: 0.0, state: init }];
    // best finished hypothesis so far under the (normalized) final
    // criterion; candidates arrive in deterministic priority order and
    // only a strictly better score replaces, so earliest wins ties
    let mut finished: Option<(f32, Beam)> = None;
    for _ in 0..p.max_len {
        if beams.is_empty() {
            break; // every surviving hypothesis has emitted EOS
        }
        let ids: Vec<usize> =
            beams.iter().map(|b| b.toks.last().copied().unwrap_or(BOS as usize)).collect();
        for (slot, b) in beams.iter().enumerate() {
            scratch.load_state(slot, &b.state);
        }
        dec.step_batch(&ids, scratch);
        // post-step states, one per live beam (parents may fan out)
        let stepped: Vec<StreamState> = (0..beams.len())
            .map(|slot| {
                let mut st = dec.new_stream_state();
                scratch.store_state(slot, &mut st);
                st
            })
            .collect();
        let mut cand: Vec<(f32, usize, usize)> = Vec::with_capacity(beams.len() * dn);
        for (slot, b) in beams.iter().enumerate() {
            let lg = &scratch.logits[slot * dn..(slot + 1) * dn];
            let (m, lnz) = log_softmax_terms(lg);
            for tok in 0..dn {
                // identical arithmetic to token_log_prob (same op order)
                cand.push((b.score + (lg[tok] - m - lnz), slot, tok));
            }
        }
        cand.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        cand.truncate(k);
        let mut next: Vec<Beam> = Vec::with_capacity(k);
        for (score, slot, tok) in cand {
            let mut toks = beams[slot].toks.clone();
            toks.push(tok);
            let hyp = Beam { toks, score, state: stepped[slot].clone() };
            if tok == eos {
                let norm = length_normalized(hyp.score, hyp.toks.len(), alpha);
                let better = match &finished {
                    None => true,
                    Some((best, _)) => norm > *best,
                };
                if better {
                    finished = Some((norm, hyp));
                }
            } else {
                next.push(hyp);
            }
        }
        beams = next;
    }
    // final selection: finished hypotheses compete with whatever is
    // still live at max_len; strictly-better-only keeps the earliest
    // (finished before live) on exact ties
    let mut best: Option<(f32, Beam)> = finished;
    for b in beams {
        let norm = length_normalized(b.score, b.toks.len(), alpha);
        let better = match &best {
            None => true,
            Some((s, _)) => norm > *s,
        };
        if better {
            best = Some((norm, b));
        }
    }
    let (norm_score, b) = best.expect("at least one hypothesis");
    (b.toks, norm_score)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn beam_width_one_matches_greedy_bitwise() {
        let enc = Arc::new(synthetic_stack(20, 4, 8, 1, 1, 11));
        let dec_stack = Arc::new(synthetic_stack(24, 4, 8, 1, 24, 12));
        let mt =
            ServeModel::from_parts(TaskKind::Mt, enc, Some(dec_stack.clone()), None).unwrap();
        let src = [3usize, 7, 1, 15, 2];
        let max_len = 9;
        let (want_toks, want_score) = mt.reference_greedy_decode(&src, max_len).unwrap();

        // beam k=1 through the batched machinery
        let mut enc_state = mt.stack.new_stream_state();
        mt.stack.forward_from(&src, &mut enc_state);
        let init = mt.bridge_state(&enc_state);
        let mut scratch = dec_stack.scratch(4);
        let (toks, score) = beam_decode(
            &dec_stack,
            &mut scratch,
            init,
            DecodeParams { max_len, beam_width: 1, len_norm: 0.0 },
        );
        assert_eq!(toks, want_toks, "k=1 beam must walk the greedy path");
        assert_eq!(score.to_bits(), want_score.to_bits(), "scores share the same arithmetic");

        // greedy batch with one lane agrees too
        let mut enc_state2 = mt.stack.new_stream_state();
        mt.stack.forward_from(&src, &mut enc_state2);
        let mut states = vec![mt.bridge_state(&enc_state2)];
        let out = greedy_decode_batch(&dec_stack, &mut scratch, &mut states, &[max_len]);
        assert_eq!(out[0].0, want_toks);
        assert_eq!(out[0].1.to_bits(), want_score.to_bits());
    }

    /// EOS contract: a lane that stops short of `max_len` must have
    /// stopped *because* it emitted EOS, EOS appears at most once and
    /// only as the final token, and the beam engine obeys the same
    /// rule for every length-normalization α (deterministically).
    #[test]
    fn decode_lanes_retire_at_eos_and_len_norm_is_deterministic() {
        let enc = Arc::new(synthetic_stack(20, 4, 8, 1, 1, 21));
        let dec_stack = Arc::new(synthetic_stack(24, 4, 8, 1, 24, 22));
        let mt =
            ServeModel::from_parts(TaskKind::Mt, enc, Some(dec_stack.clone()), None).unwrap();
        let mut scratch = dec_stack.scratch(8);
        let max_len = 12usize;
        let eos = EOS as usize;

        let check_toks = |toks: &[usize], what: &str| {
            assert!(toks.len() <= max_len, "{what}: ran past max_len");
            let eos_count = toks.iter().filter(|&&t| t == eos).count();
            assert!(eos_count <= 1, "{what}: EOS emitted more than once");
            if toks.len() < max_len {
                assert_eq!(toks.last(), Some(&eos), "{what}: early stop without EOS");
            }
            if eos_count == 1 {
                assert_eq!(toks.last(), Some(&eos), "{what}: EOS not final");
            }
        };

        // several greedy lanes of different sources share the loop
        let srcs = [vec![3usize, 7, 1], vec![15usize, 2, 9], vec![4usize, 4, 4]];
        let mut states: Vec<StreamState> = srcs
            .iter()
            .map(|src| {
                let mut st = mt.stack.new_stream_state();
                mt.stack.forward_from(src, &mut st);
                mt.bridge_state(&st)
            })
            .collect();
        let out =
            greedy_decode_batch(&dec_stack, &mut scratch, &mut states, &[max_len; 3]);
        for (i, (toks, _)) in out.iter().enumerate() {
            check_toks(toks, &format!("greedy lane {i}"));
            // and each lane matches its single-lane reference
            let (want, _) = mt.reference_greedy_decode(&srcs[i], max_len).unwrap();
            assert_eq!(*toks, want, "greedy lane {i} diverged from reference");
        }

        // beams: deterministic for α off and α on
        for alpha in [0.0f32, 0.8] {
            let p = DecodeParams { max_len, beam_width: 3, len_norm: alpha };
            let run = |scratch: &mut StackScratch| {
                let mut st = mt.stack.new_stream_state();
                mt.stack.forward_from(&srcs[0], &mut st);
                beam_decode(&dec_stack, scratch, mt.bridge_state(&st), p)
            };
            let (t1, s1) = run(&mut scratch);
            let (t2, s2) = run(&mut scratch);
            assert_eq!(t1, t2, "beam decode must be deterministic at alpha {alpha}");
            assert_eq!(s1.to_bits(), s2.to_bits());
            check_toks(&t1, &format!("beam alpha {alpha}"));
        }
    }
}
