//! The worker pool: one thread per shard, each owning its session
//! store, its flat [`StackScratch`], and its request queue. The hot
//! loop allocates only the per-reply logit vectors; states move
//! between sessions and batch slots by `memcpy` (O(H) per layer,
//! against the O(H²) step itself).

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::lstm::QLstmStack;

use super::scheduler::{Reply, Request, RequestQueue};
use super::session::{SessionId, SessionStore};
use super::stats::ShardStats;
use super::ServeConfig;

/// Handles to the running shards.
pub struct WorkerPool {
    pub queues: Vec<Arc<RequestQueue>>,
    pub stats: Vec<Arc<ShardStats>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `cfg.workers` shard threads over a shared stack.
    pub fn spawn(stack: Arc<QLstmStack>, cfg: &ServeConfig) -> WorkerPool {
        let mut queues = Vec::with_capacity(cfg.workers);
        let mut stats = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        for shard in 0..cfg.workers {
            let queue = Arc::new(RequestQueue::new());
            let stat = Arc::new(ShardStats::new());
            queues.push(queue.clone());
            stats.push(stat.clone());
            let stack = stack.clone();
            let max_batch = cfg.max_batch;
            let window = cfg.batch_window;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-shard-{shard}"))
                    .spawn(move || run_worker(&stack, &queue, &stat, max_batch, window))
                    .expect("spawn shard thread"),
            );
        }
        WorkerPool { queues, stats, handles }
    }

    /// Signal shutdown, let the workers drain their queues, and join.
    pub fn shutdown(self) {
        for q in &self.queues {
            q.shutdown();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

fn run_worker(
    stack: &QLstmStack,
    queue: &RequestQueue,
    stats: &ShardStats,
    max_batch: usize,
    window: Duration,
) {
    let mut store = SessionStore::new();
    let mut scratch = stack.scratch(max_batch);
    let n_out = stack.n_out();

    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    let mut closes: Vec<SessionId> = Vec::new();
    let mut ids: Vec<usize> = Vec::with_capacity(max_batch);
    let mut lats: Vec<Duration> = Vec::with_capacity(max_batch);
    let mut replies: Vec<(Request, Reply)> = Vec::with_capacity(max_batch);

    while queue.next_batch(max_batch, window, &mut batch, &mut closes) {
        // closes are ordered by the scheduler to never precede queued
        // tokens of their session, so dropping state here is safe
        for s in closes.drain(..) {
            store.close(s);
        }
        // defense in depth: Server::submit already rejects
        // out-of-vocabulary tokens, but a request pushed onto the queue
        // directly must not panic the shard. Answer it with an explicit
        // empty-logits rejection (the client may hold its own Sender
        // clone, so merely dropping the request would leave it blocked
        // on recv forever).
        batch.retain(|r| {
            if r.token < stack.embed.vocab {
                return true;
            }
            let _ = r.reply_to.send(Reply {
                session: r.session,
                logits: Vec::new(),
                top_token: 0,
                latency: r.enqueued.elapsed(),
            });
            false
        });
        if batch.is_empty() {
            continue;
        }

        // gather: session states → flat batch slots
        ids.clear();
        ids.extend(batch.iter().map(|r| r.token));
        for (slot, r) in batch.iter().enumerate() {
            let sess = store.open(r.session, stack);
            scratch.load_state(slot, &sess.state);
        }

        stack.step_batch(&ids, &mut scratch);

        // scatter: batch slots → session states; build replies
        lats.clear();
        replies.clear();
        let bsz = batch.len();
        for (slot, r) in batch.drain(..).enumerate() {
            let sess = store.get_mut(r.session).expect("opened above");
            scratch.store_state(slot, &mut sess.state);
            sess.tokens += 1;
            let logits = scratch.logits[slot * n_out..(slot + 1) * n_out].to_vec();
            let top_token = argmax(&logits);
            let latency = r.enqueued.elapsed();
            lats.push(latency);
            let reply = Reply { session: r.session, logits, top_token, latency };
            replies.push((r, reply));
        }
        // record before sending so an observer that saw all replies
        // also sees the matching counters
        stats.record_batch(bsz, &lats);
        for (r, reply) in replies.drain(..) {
            let _ = r.reply_to.send(reply);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_takes_first_maximum() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
        assert_eq!(argmax(&[0.0, 0.0]), 0);
    }
}
