//! `floatsd-lstm serve` — self-contained serving demo: loads a
//! checkpoint (task auto-detected from its `meta/task_cfg`) or builds
//! a synthetic LM stack, starts the [`Server`], drives it with a
//! task-appropriate synthetic multi-client load, and reports
//! throughput, batch occupancy, and latency percentiles per shard.
//!
//! ```text
//! floatsd-lstm serve [--model ckpt.tensors] [--workers N] [--max-batch B]
//!                    [--window-us U] [--sessions S] [--tokens T] [--clients C]
//!                    [--kernel-tier decoded|shiftadd] [--kernel-isa scalar|sse2|avx2|auto]
//!                    [--trace serve_trace.jsonl]   (request-lifecycle JSONL trace)
//!                    [--trace-every N]   (keep every N-th micro-batch's batch/request
//!                                         lines; lifecycle + summary always traced)
//!                    [--decode-len L] [--beam K] [--beam-len-norm A]  (mt decode knobs)
//!                    [--vocab V --dim D --hidden H --layers L]   (synthetic model)
//! ```
//!
//! Per-task drivers:
//!
//! * **lm** — each client streams greedily: one token per session per
//!   round, feeding each reply's argmax back as the next input — a
//!   closed feedback loop through the recurrent state, so any
//!   session-state mixup would change the token stream immediately;
//! * **pos** — each session submits whole sentences and receives
//!   per-step tag scores;
//! * **nli** — each session submits a premise+hypothesis pair and
//!   finalizes into a 3-way classification;
//! * **mt** — each session uploads a source sequence into its encoder
//!   context, then runs the decode loop (`--beam` > 1 for beam
//!   search); the reported rate is decoded tokens per second.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cli::Args;
use crate::lstm::model::synthetic_stack;
use crate::lstm::QLstmStack;
use crate::rng::SplitMix64;
use crate::tasks::TaskKind;

use super::{DecodeParams, Payload, ServeConfig, ServeModel, Server, SessionId};

/// Entry point for the `serve` subcommand.
pub fn run(args: &Args) -> Result<()> {
    let cfg = ServeConfig {
        workers: args.opt_usize("workers", ServeConfig::default().workers)?.max(1),
        max_batch: args.opt_usize("max-batch", 16)?.max(1),
        batch_window: Duration::from_micros(args.opt_usize("window-us", 200)? as u64),
    };
    let n_sessions = args.opt_usize("sessions", 64)?.max(1);
    let n_tokens = args.opt_usize("tokens", 256)?;
    let n_clients = args.opt_usize("clients", 4)?.max(1).min(n_sessions);
    let decode = DecodeParams {
        max_len: args.opt_usize("decode-len", 16)?.max(1),
        beam_width: args.opt_usize("beam", 1)?.max(1),
        // length-normalization exponent for beam scores; 0 (the
        // default) keeps raw summed log-probs, bit-identical to the
        // unnormalized engine
        len_norm: match args.opt("beam-len-norm") {
            None => 0.0,
            Some(v) => v.parse::<f32>()?,
        },
    };

    let mut model = match args.opt("model") {
        Some(path) => ServeModel::load(path)?,
        None => ServeModel::lm(Arc::new(synthetic_stack(
            args.opt_usize("vocab", 256)?,
            args.opt_usize("dim", 64)?,
            args.opt_usize("hidden", 128)?,
            args.opt_usize("layers", 2)?.max(1),
            args.opt_usize("vocab", 256)?,
            20200711,
        )))?,
    };
    // kernel tier and SIMD path are load-time choices: set them while
    // this thread still exclusively owns the stacks, before workers
    // share them
    model.set_kernel_tier(crate::qmath::KernelTier::parse(
        args.opt_or("kernel-tier", "decoded"),
    )?)?;
    model.set_kernel_isa(crate::qmath::IsaPath::parse(args.opt_or("kernel-isa", "auto"))?)?;
    let model = Arc::new(model);

    let stack = &model.stack;
    let (mut sd8, mut fp32) = stack.weight_bytes();
    if let Some(dec) = &model.decoder {
        let (d8, d32) = dec.weight_bytes();
        sd8 += d8;
        fp32 += d32;
    }
    println!(
        "model: task={} vocab={} dim={} layers={} hidden={:?} n_out={} | weights {} B FloatSD8 ({} B as FP32)",
        model.task.name(),
        stack.embed.vocab,
        stack.embed.dim,
        stack.layers.len(),
        stack.hidden_dims(),
        model.n_out(),
        sd8,
        fp32
    );
    println!(
        "serve: {} workers × max-batch {} × window {:?} | load: {} sessions × {} tokens via {} clients{}",
        cfg.workers,
        cfg.max_batch,
        cfg.batch_window,
        n_sessions,
        n_tokens,
        n_clients,
        if model.task == TaskKind::Mt {
            format!(" | decode-len {} beam {}", decode.max_len, decode.beam_width)
        } else {
            String::new()
        }
    );

    // open the trace sink before the server so the `serve_start`
    // config line leads the stream; sharing it through an Arc keeps
    // the same sink alive across every shard
    let trace = match args.opt("trace") {
        Some(path) => {
            // batch-level sampling period: every N-th micro-batch per
            // shard keeps its batch/request lines (lifecycle events and
            // the serve_end summary always emit)
            let every = args.opt_u64("trace-every", 1)?;
            if every == 0 {
                anyhow::bail!("serve: --trace-every must be >= 1 (N keeps every N-th batch)");
            }
            Some(Arc::new(crate::telemetry::ServeTraceSink::create_every(
                std::path::Path::new(path),
                every,
            )?))
        }
        None => None,
    };
    let server = Server::start_traced(model.clone(), cfg, trace.clone())?;
    let t0 = Instant::now();
    let streamed = drive_task_load(&server, &model, n_sessions, n_tokens, n_clients, decode);
    let wall = t0.elapsed();

    println!("\nper-shard:");
    for (i, s) in server.shard_stats().iter().enumerate() {
        println!("  shard {i}: {s}");
    }
    let agg = server.stats();
    println!("aggregate: {agg}");
    println!("per-kind:");
    for (name, k) in super::KIND_NAMES.iter().zip(agg.per_kind.iter()) {
        if k.requests > 0 {
            println!("  {name:<8} {:>8} requests  {:>10} work units", k.requests, k.work);
        }
    }
    println!(
        "\nthroughput: {:.0} tokens/s ({} tokens in {:.2?})",
        streamed as f64 / wall.as_secs_f64(),
        streamed,
        wall
    );
    server.shutdown();
    if let Some(tr) = &trace {
        // surface deferred IO errors after the serve_end summary landed
        tr.finish()?;
        if let Some(path) = args.opt("trace") {
            println!("trace: wrote request-lifecycle stream to {path}");
        }
    }
    Ok(())
}

/// Drive the task-appropriate synthetic load; returns tokens streamed
/// (for mt: decoded target tokens — the decode-loop throughput).
pub fn drive_task_load(
    server: &Server,
    model: &ServeModel,
    n_sessions: usize,
    n_tokens: usize,
    n_clients: usize,
    decode: DecodeParams,
) -> u64 {
    match model.task {
        TaskKind::Lm => drive_load(server, &model.stack, n_sessions, n_tokens, n_clients),
        TaskKind::Pos => drive_pos_load(server, model, n_sessions, n_tokens, n_clients),
        TaskKind::Nli => drive_nli_load(server, model, n_sessions, n_tokens, n_clients),
        TaskKind::Mt => drive_mt_load(server, model, n_sessions, n_tokens, n_clients, decode),
    }
}

/// Partition `n_sessions` across `n_clients`: client `c` owns sessions
/// `{c, c + C, c + 2C, ...}`.
fn client_sessions(client: usize, n_sessions: usize, n_clients: usize) -> Vec<SessionId> {
    (client..n_sessions).step_by(n_clients.max(1)).map(|s| s as SessionId).collect()
}

/// Drive `n_sessions` greedy-decoding LM sessions (partitioned over
/// `n_clients` threads) for `n_tokens` rounds; returns tokens streamed.
pub fn drive_load(
    server: &Server,
    stack: &QLstmStack,
    n_sessions: usize,
    n_tokens: usize,
    n_clients: usize,
) -> u64 {
    let vocab = stack.embed.vocab;
    let mut streamed = 0u64;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for client in 0..n_clients {
            let sessions = client_sessions(client, n_sessions, n_clients);
            joins.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel();
                let mut next: HashMap<SessionId, usize> =
                    sessions.iter().map(|&s| (s, s as usize % vocab)).collect();
                let mut sent = 0u64;
                for _round in 0..n_tokens {
                    for &s in &sessions {
                        server.submit(s, next[&s], tx.clone()).expect("token within vocab");
                        sent += 1;
                    }
                    for _ in 0..sessions.len() {
                        let reply = rx.recv().expect("server dropped reply channel");
                        assert!(!reply.is_rejected(), "submit-validated token rejected");
                        // greedy feedback: the reply's argmax becomes
                        // the session's next input token
                        let top = reply.top_token().expect("step reply carries a top token");
                        next.insert(reply.session, top % vocab);
                    }
                }
                for &s in &sessions {
                    server.close_session(s);
                }
                sent
            }));
        }
        for j in joins {
            streamed += j.join().expect("client thread");
        }
    });
    streamed
}

/// POS load: every session submits whole sentences and receives
/// per-step tag scores; returns positions tagged.
pub fn drive_pos_load(
    server: &Server,
    model: &ServeModel,
    n_sessions: usize,
    sent_len: usize,
    n_clients: usize,
) -> u64 {
    let vocab = model.input_vocab();
    let sent_len = sent_len.max(1);
    let mut streamed = 0u64;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for client in 0..n_clients {
            let sessions = client_sessions(client, n_sessions, n_clients);
            joins.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel();
                for &s in &sessions {
                    let mut rng = SplitMix64::new(0x9053_0000 ^ s);
                    let toks: Vec<usize> =
                        (0..sent_len).map(|_| rng.next_below(vocab as u64) as usize).collect();
                    server.submit_sequence(s, toks, tx.clone()).expect("tokens within vocab");
                }
                let mut tagged = 0u64;
                for _ in 0..sessions.len() {
                    let reply = rx.recv().expect("server dropped reply channel");
                    match reply.payload {
                        Payload::Steps { logits } => tagged += logits.len() as u64,
                        _ => panic!("pos sequence reply must carry per-step tag scores"),
                    }
                }
                for &s in &sessions {
                    server.close_session(s);
                }
                tagged
            }));
        }
        for j in joins {
            streamed += j.join().expect("client thread");
        }
    });
    streamed
}

/// NLI load: every session submits a premise+hypothesis pair, then
/// finalizes into a 3-way classification; returns tokens consumed.
pub fn drive_nli_load(
    server: &Server,
    model: &ServeModel,
    n_sessions: usize,
    pair_len: usize,
    n_clients: usize,
) -> u64 {
    let vocab = model.input_vocab();
    let pair_len = pair_len.max(2);
    let n_out = model.n_out();
    let mut streamed = 0u64;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for client in 0..n_clients {
            let sessions = client_sessions(client, n_sessions, n_clients);
            joins.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel();
                let mut consumed = 0u64;
                for &s in &sessions {
                    let mut rng = SplitMix64::new(0x0911_0000 ^ s);
                    let toks: Vec<usize> =
                        (0..pair_len).map(|_| rng.next_below(vocab as u64) as usize).collect();
                    server.submit_sequence(s, toks, tx.clone()).expect("tokens within vocab");
                    let reply = rx.recv().expect("server dropped reply channel");
                    match reply.payload {
                        Payload::Prefilled { consumed: c, .. } => consumed += c as u64,
                        _ => panic!("nli sequence reply must be a prefill"),
                    }
                    server.finalize(s, tx.clone()).expect("nli accepts finalize");
                    let reply = rx.recv().expect("server dropped reply channel");
                    match reply.payload {
                        Payload::Class { logits, label } => {
                            assert_eq!(logits.len(), n_out);
                            assert!(label < n_out);
                        }
                        _ => panic!("finalize reply must be a classification"),
                    }
                    server.close_session(s);
                }
                consumed
            }));
        }
        for j in joins {
            streamed += j.join().expect("client thread");
        }
    });
    streamed
}

/// MT load: every session uploads a source sequence and runs the
/// decode loop; returns decoded target tokens (the decode throughput).
pub fn drive_mt_load(
    server: &Server,
    model: &ServeModel,
    n_sessions: usize,
    src_len: usize,
    n_clients: usize,
    decode: DecodeParams,
) -> u64 {
    let vocab = model.input_vocab();
    let src_len = src_len.max(1);
    let mut streamed = 0u64;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for client in 0..n_clients {
            let sessions = client_sessions(client, n_sessions, n_clients);
            joins.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel();
                // upload every source first so decodes can co-batch
                for &s in &sessions {
                    let mut rng = SplitMix64::new(0x0017_0000 ^ s);
                    let toks: Vec<usize> =
                        (0..src_len).map(|_| rng.next_below(vocab as u64) as usize).collect();
                    server.submit_sequence(s, toks, tx.clone()).expect("tokens within vocab");
                }
                for _ in 0..sessions.len() {
                    let reply = rx.recv().expect("server dropped reply channel");
                    assert!(
                        matches!(reply.payload, Payload::Encoded { .. }),
                        "mt sequence reply must be an encoder ack"
                    );
                }
                for &s in &sessions {
                    server.decode(s, decode, tx.clone()).expect("decode params in range");
                }
                let mut decoded = 0u64;
                for _ in 0..sessions.len() {
                    let reply = rx.recv().expect("server dropped reply channel");
                    match reply.payload {
                        Payload::Decoded { tokens, score } => {
                            assert!(score.is_finite());
                            decoded += tokens.len() as u64;
                        }
                        _ => panic!("decode reply must carry decoded tokens"),
                    }
                }
                for &s in &sessions {
                    server.close_session(s);
                }
                decoded
            }));
        }
        for j in joins {
            streamed += j.join().expect("client thread");
        }
    });
    streamed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig { workers: 2, max_batch: 4, batch_window: Duration::from_micros(50) }
    }

    #[test]
    fn demo_load_runs_end_to_end() {
        let stack = Arc::new(synthetic_stack(32, 8, 10, 1, 32, 5));
        let server = Server::start_lm(stack.clone(), tiny_cfg()).unwrap();
        let streamed = drive_load(&server, &stack, 6, 5, 2);
        assert_eq!(streamed, 30);
        let agg = server.stats();
        assert_eq!(agg.tokens, 30);
        assert!(agg.batches > 0 && agg.mean_occupancy >= 1.0);
        server.shutdown();
    }

    #[test]
    fn pos_and_nli_loads_run_end_to_end() {
        let pos_stack = Arc::new(synthetic_stack(60, 8, 10, 1, 6, 21));
        let model =
            Arc::new(ServeModel::from_parts(TaskKind::Pos, pos_stack, None, None).unwrap());
        let server = Server::start(model.clone(), tiny_cfg()).unwrap();
        let tagged = drive_pos_load(&server, &model, 4, 7, 2);
        assert_eq!(tagged, 4 * 7, "every position of every sentence tagged");
        server.shutdown();

        let nli_stack = Arc::new(synthetic_stack(24, 8, 10, 1, 3, 22));
        let model =
            Arc::new(ServeModel::from_parts(TaskKind::Nli, nli_stack, None, None).unwrap());
        let server = Server::start(model.clone(), tiny_cfg()).unwrap();
        let consumed = drive_nli_load(&server, &model, 3, 8, 1);
        assert_eq!(consumed, 3 * 8);
        server.shutdown();
    }

    #[test]
    fn mt_load_decodes_end_to_end() {
        let enc = Arc::new(synthetic_stack(20, 6, 12, 1, 1, 23));
        let dec = Arc::new(synthetic_stack(20, 6, 12, 1, 20, 24));
        let model =
            Arc::new(ServeModel::from_parts(TaskKind::Mt, enc, Some(dec), None).unwrap());
        let server = Server::start(model.clone(), tiny_cfg()).unwrap();
        let decoded =
            drive_mt_load(
                &server,
                &model,
                3,
                5,
                1,
                DecodeParams { max_len: 6, beam_width: 2, len_norm: 0.0 },
            );
        // lanes may retire early at EOS, so max_len bounds (not pins)
        // the emitted count; every decode still emits at least one token
        assert!(decoded >= 3 && decoded <= 3 * 6, "decoded {decoded} outside 3..=18");
        let agg = server.stats();
        assert!(agg.tokens >= decoded, "decode work counted in throughput");
        server.shutdown();
    }
}
