//! `floatsd-lstm serve` — self-contained serving demo: builds (or
//! loads) a quantized stack, starts the [`Server`], drives it with a
//! synthetic multi-client token-streaming load, and reports
//! throughput, batch occupancy, and latency percentiles per shard.
//!
//! ```text
//! floatsd-lstm serve [--model ckpt.tensors] [--workers N] [--max-batch B]
//!                    [--window-us U] [--sessions S] [--tokens T] [--clients C]
//!                    [--vocab V --dim D --hidden H --layers L]   (synthetic model)
//! ```
//!
//! Each synthetic client owns a slice of the sessions and streams
//! greedily: it sends one token per session, waits for that round's
//! replies, and feeds each reply's argmax back as the session's next
//! token — a closed feedback loop through the recurrent state, so any
//! session-state mixup would change the token stream immediately.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::lstm::model::{build_tiny_from_params, synthetic_stack, ParamBag};
use crate::lstm::QLstmStack;
use crate::tensorfile::read_tensors;

use super::{ServeConfig, Server, SessionId};

/// Entry point for the `serve` subcommand.
pub fn run(args: &Args) -> Result<()> {
    let cfg = ServeConfig {
        workers: args.opt_usize("workers", ServeConfig::default().workers)?.max(1),
        max_batch: args.opt_usize("max-batch", 16)?.max(1),
        batch_window: Duration::from_micros(args.opt_usize("window-us", 200)? as u64),
    };
    let n_sessions = args.opt_usize("sessions", 64)?.max(1);
    let n_tokens = args.opt_usize("tokens", 256)?;
    let n_clients = args.opt_usize("clients", 4)?.max(1).min(n_sessions);

    let stack = Arc::new(match args.opt("model") {
        Some(path) => {
            let tensors = read_tensors(path).with_context(|| format!("load {path}"))?;
            build_tiny_from_params(&ParamBag::from_tensors(tensors))
                .with_context(|| format!("assemble model from {path}"))?
        }
        None => synthetic_stack(
            args.opt_usize("vocab", 256)?,
            args.opt_usize("dim", 64)?,
            args.opt_usize("hidden", 128)?,
            args.opt_usize("layers", 2)?.max(1),
            args.opt_usize("vocab", 256)?,
            20200711,
        ),
    });

    let (sd8, fp32) = stack.weight_bytes();
    println!(
        "model: vocab={} dim={} layers={} hidden={:?} n_out={} | weights {} B FloatSD8 ({} B as FP32)",
        stack.embed.vocab,
        stack.embed.dim,
        stack.layers.len(),
        stack.hidden_dims(),
        stack.n_out(),
        sd8,
        fp32
    );
    println!(
        "serve: {} workers × max-batch {} × window {:?} | load: {} sessions × {} tokens via {} clients",
        cfg.workers, cfg.max_batch, cfg.batch_window, n_sessions, n_tokens, n_clients
    );

    let server = Server::start(stack.clone(), cfg);
    let t0 = Instant::now();
    let streamed = drive_load(&server, &stack, n_sessions, n_tokens, n_clients);
    let wall = t0.elapsed();

    println!("\nper-shard:");
    for (i, s) in server.shard_stats().iter().enumerate() {
        println!("  shard {i}: {s}");
    }
    let agg = server.stats();
    println!("aggregate: {agg}");
    println!(
        "\nthroughput: {:.0} tokens/s ({} tokens in {:.2?})",
        streamed as f64 / wall.as_secs_f64(),
        streamed,
        wall
    );
    server.shutdown();
    Ok(())
}

/// Drive `n_sessions` greedy-decoding sessions (partitioned over
/// `n_clients` threads) for `n_tokens` rounds; returns tokens streamed.
pub fn drive_load(
    server: &Server,
    stack: &QLstmStack,
    n_sessions: usize,
    n_tokens: usize,
    n_clients: usize,
) -> u64 {
    let vocab = stack.embed.vocab;
    let mut streamed = 0u64;
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for client in 0..n_clients {
            // client c owns sessions {c, c + C, c + 2C, ...}
            let sessions: Vec<SessionId> =
                (client..n_sessions).step_by(n_clients).map(|s| s as SessionId).collect();
            joins.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel();
                let mut next: HashMap<SessionId, usize> =
                    sessions.iter().map(|&s| (s, s as usize % vocab)).collect();
                let mut sent = 0u64;
                for _round in 0..n_tokens {
                    for &s in &sessions {
                        server.submit(s, next[&s], tx.clone()).expect("token within vocab");
                        sent += 1;
                    }
                    for _ in 0..sessions.len() {
                        let reply = rx.recv().expect("server dropped reply channel");
                        assert!(!reply.is_rejected(), "submit-validated token rejected");
                        // greedy feedback: the reply's argmax becomes the
                        // session's next input token
                        next.insert(reply.session, reply.top_token % vocab);
                    }
                }
                for &s in &sessions {
                    server.close_session(s);
                }
                sent
            }));
        }
        for j in joins {
            streamed += j.join().expect("client thread");
        }
    });
    streamed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_load_runs_end_to_end() {
        let stack = Arc::new(synthetic_stack(32, 8, 10, 1, 32, 5));
        let server = Server::start(
            stack.clone(),
            ServeConfig { workers: 2, max_batch: 4, batch_window: Duration::from_micros(50) },
        );
        let streamed = drive_load(&server, &stack, 6, 5, 2);
        assert_eq!(streamed, 30);
        let agg = server.stats();
        assert_eq!(agg.tokens, 30);
        assert!(agg.batches > 0 && agg.mean_occupancy >= 1.0);
        server.shutdown();
    }
}
