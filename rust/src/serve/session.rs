//! Per-shard session store: the server-side home of each client's
//! recurrent `(h, c)` state, so clients stream tokens incrementally
//! instead of resending (and the server recomputing) whole prefixes.
//!
//! A store is owned by exactly one worker thread — no interior
//! locking; cross-shard isolation comes from the `session_id % workers`
//! routing in [`super::Server`].

use std::collections::HashMap;

use crate::lstm::{QLstmStack, StreamState};

/// Client-chosen session identifier. Sessions are created implicitly
/// on first use and routed to shard `id % workers` for their lifetime.
pub type SessionId = u64;

/// One client's server-side state.
pub struct Session {
    pub state: StreamState,
    /// tokens processed for this session (monotonic)
    pub tokens: u64,
}

/// All sessions owned by one shard.
#[derive(Default)]
pub struct SessionStore {
    sessions: HashMap<SessionId, Session>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore { sessions: HashMap::new() }
    }

    /// Fetch a session, creating zeroed state on first use.
    pub fn open(&mut self, id: SessionId, stack: &QLstmStack) -> &mut Session {
        self.sessions
            .entry(id)
            .or_insert_with(|| Session { state: stack.new_stream_state(), tokens: 0 })
    }

    pub fn get_mut(&mut self, id: SessionId) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// Drop a session's state. Returns whether it existed.
    pub fn close(&mut self, id: SessionId) -> bool {
        self.sessions.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn open_is_idempotent_and_close_frees() {
        let stack = synthetic_stack(16, 4, 6, 2, 16, 1);
        let mut store = SessionStore::new();
        {
            let s = store.open(42, &stack);
            assert_eq!(s.tokens, 0);
            assert_eq!(s.state.h.len(), 2, "one (h,c) pair per layer");
            assert_eq!(s.state.h[0].len(), 6);
            s.tokens = 7;
        }
        assert_eq!(store.open(42, &stack).tokens, 7, "second open returns same session");
        assert_eq!(store.len(), 1);
        assert!(store.close(42));
        assert!(!store.close(42));
        assert!(store.is_empty());
    }
}
