//! Per-shard session store: the server-side home of each client's
//! recurrent state, so clients stream tokens incrementally instead of
//! resending (and the server recomputing) whole prefixes.
//!
//! What the state *means* is per task: for lm/pos/nli it is the model
//! stack's `(h, c)` pair per layer; for mt it is the **encoder
//! context** — the encoder state accumulated from `Step`/`Sequence`
//! submissions, which each `Decode` request bridges (by copy) into a
//! fresh decoder state. For nli (only — other tasks never read it,
//! so their hot path skips the copy) `last_logits` caches the most
//! recent head output so `Finalize` can classify without
//! recomputation.
//!
//! A store is owned by exactly one worker thread — no interior
//! locking; cross-shard isolation comes from the `session_id % workers`
//! routing in [`super::Server`].

use std::collections::HashMap;

use crate::lstm::{QLstmStack, StreamState};

/// Client-chosen session identifier. Sessions are created implicitly
/// on first use and routed to shard `id % workers` for their lifetime.
pub type SessionId = u64;

/// One client's server-side state.
pub struct Session {
    /// primary-stack recurrent state (encoder state for mt)
    pub state: StreamState,
    /// the most recent head output of the primary stack — what
    /// `Finalize` classifies. Populated only for tasks whose protocol
    /// reads it back (nli); empty until the first processed token
    pub last_logits: Vec<f32>,
    /// tokens processed for this session (monotonic)
    pub tokens: u64,
}

/// All sessions owned by one shard.
#[derive(Default)]
pub struct SessionStore {
    sessions: HashMap<SessionId, Session>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore { sessions: HashMap::new() }
    }

    /// Fetch a session, creating zeroed state on first use.
    pub fn open(&mut self, id: SessionId, stack: &QLstmStack) -> &mut Session {
        self.sessions.entry(id).or_insert_with(|| Session {
            state: stack.new_stream_state(),
            last_logits: Vec::new(),
            tokens: 0,
        })
    }

    /// Fetch an existing session without creating one (`Finalize` must
    /// not conjure state for a session that never streamed).
    pub fn get_mut(&mut self, id: SessionId) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    /// Drop a session's state. Returns whether it existed — closing a
    /// never-created session is a cheap no-op and never inserts a map
    /// entry.
    pub fn close(&mut self, id: SessionId) -> bool {
        self.sessions.remove(&id).is_some()
    }

    /// Whether a session already has state — the serve trace asks this
    /// *before* processing a request to emit `session_open` exactly
    /// once per lifecycle (read-only: never creates an entry).
    pub fn contains(&self, id: SessionId) -> bool {
        self.sessions.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::synthetic_stack;

    #[test]
    fn open_is_idempotent_and_close_frees() {
        let stack = synthetic_stack(16, 4, 6, 2, 16, 1);
        let mut store = SessionStore::new();
        {
            let s = store.open(42, &stack);
            assert_eq!(s.tokens, 0);
            assert!(s.last_logits.is_empty(), "no head output before the first token");
            assert_eq!(s.state.h.len(), 2, "one (h,c) pair per layer");
            assert_eq!(s.state.h[0].len(), 6);
            s.tokens = 7;
        }
        assert_eq!(store.open(42, &stack).tokens, 7, "second open returns same session");
        assert_eq!(store.len(), 1);
        assert!(store.close(42));
        assert!(!store.close(42));
        assert!(store.is_empty());
    }

    #[test]
    fn close_of_never_created_session_is_a_noop_and_leaks_nothing() {
        let stack = synthetic_stack(16, 4, 6, 1, 16, 2);
        let mut store = SessionStore::new();
        store.open(1, &stack);
        // closing a session that never existed must not panic and must
        // not insert a map entry as a side effect
        assert!(!store.close(999));
        assert_eq!(store.len(), 1, "unknown close neither removed nor created entries");
        assert!(store.get_mut(999).is_none(), "get_mut must not create either");
        assert_eq!(store.len(), 1);
        assert!(store.contains(1) && !store.contains(999), "contains is a pure probe");
        assert_eq!(store.len(), 1);
    }
}
