//! Per-shard serving statistics: token/batch counters on atomics (read
//! by any thread without stopping the worker) and raw service-latency
//! samples summarized through [`benchlib::Percentiles`] — the same
//! reporting machinery the paper benches use.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::benchlib::Percentiles;

/// Cap on retained latency samples per shard: percentiles describe a
/// sliding window of the most recent samples instead of the full
/// history, keeping a long-running server's memory bounded and
/// snapshot cost O(window), not O(lifetime-tokens). Sized so the
/// `snapshot()` clone under the shard mutex (which the worker also
/// takes in `record_batch`) stays a ~128 KB memcpy — ample samples
/// for a stable p99, small enough that a polling monitor doesn't add
/// visible tail latency to in-flight batches.
pub const LATENCY_WINDOW: usize = 16_384;

/// Bounded ring of the most recent latency samples.
#[derive(Default)]
struct LatencyRing {
    buf: Vec<Duration>,
    next: usize,
}

impl LatencyRing {
    fn push(&mut self, d: Duration) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

/// Live counters for one shard (one worker thread writes, anyone reads).
#[derive(Default)]
pub struct ShardStats {
    tokens: AtomicU64,
    batches: AtomicU64,
    latencies: Mutex<LatencyRing>,
}

/// Point-in-time summary of one shard (or of all shards, merged).
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    pub tokens: u64,
    pub batches: u64,
    /// mean requests per scheduled micro-batch — how full batches ran
    pub mean_occupancy: f64,
    /// enqueue → reply-ready service latency
    pub latency: Percentiles,
}

impl ShardStats {
    pub fn new() -> ShardStats {
        ShardStats::default()
    }

    /// Record one scheduled micro-batch and its per-request latencies.
    pub fn record_batch(&self, batch: usize, lats: &[Duration]) {
        self.tokens.fetch_add(batch as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.latencies.lock().unwrap();
        for &l in lats {
            ring.push(l);
        }
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let mut samples = self.latencies.lock().unwrap().buf.clone();
        let tokens = self.tokens.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        StatsSnapshot {
            tokens,
            batches,
            mean_occupancy: if batches == 0 { 0.0 } else { tokens as f64 / batches as f64 },
            latency: Percentiles::of(&mut samples),
        }
    }
}

/// Merge shards into one snapshot; percentiles are recomputed over the
/// union of the raw samples (averaging per-shard percentiles would be
/// statistically wrong).
pub fn merged(shards: &[Arc<ShardStats>]) -> StatsSnapshot {
    let mut samples: Vec<Duration> = Vec::new();
    let mut tokens = 0u64;
    let mut batches = 0u64;
    for s in shards {
        tokens += s.tokens.load(Ordering::Relaxed);
        batches += s.batches.load(Ordering::Relaxed);
        samples.extend_from_slice(&s.latencies.lock().unwrap().buf);
    }
    StatsSnapshot {
        tokens,
        batches,
        mean_occupancy: if batches == 0 { 0.0 } else { tokens as f64 / batches as f64 },
        latency: Percentiles::of(&mut samples),
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} tokens in {} batches (occupancy {:.2}); latency {}",
            self.tokens, self.batches, self.mean_occupancy, self.latency
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_merge() {
        let a = Arc::new(ShardStats::new());
        let b = Arc::new(ShardStats::new());
        a.record_batch(4, &[Duration::from_micros(10); 4]);
        a.record_batch(2, &[Duration::from_micros(30); 2]);
        b.record_batch(6, &[Duration::from_micros(20); 6]);
        let sa = a.snapshot();
        assert_eq!(sa.tokens, 6);
        assert_eq!(sa.batches, 2);
        assert!((sa.mean_occupancy - 3.0).abs() < 1e-9);
        let m = merged(&[a, b]);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.batches, 3);
        assert_eq!(m.latency.n, 12);
        assert_eq!(m.latency.max, Duration::from_micros(30));
    }

    #[test]
    fn latency_window_is_bounded() {
        let mut ring = LatencyRing::default();
        for i in 0..(LATENCY_WINDOW + 10) {
            ring.push(Duration::from_nanos(i as u64));
        }
        assert_eq!(ring.buf.len(), LATENCY_WINDOW, "window never exceeds the cap");
        // the 10 oldest samples were overwritten in place
        assert_eq!(ring.buf[0], Duration::from_nanos(LATENCY_WINDOW as u64));
        assert_eq!(ring.buf[9], Duration::from_nanos(LATENCY_WINDOW as u64 + 9));
        assert_eq!(ring.buf[10], Duration::from_nanos(10));
    }
}
