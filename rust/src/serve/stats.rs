//! Per-shard serving statistics, hosted on the [`crate::telemetry`]
//! primitives: request/token [`Counter`]s and a live session
//! [`Gauge`] (read by any thread without stopping the worker), a
//! fixed-bucket batch-occupancy [`Histogram`], per-request-kind
//! counters, and raw service-latency samples in a bounded
//! [`SampleWindow`] summarized through [`benchlib::Percentiles`] —
//! the same reporting machinery the paper benches use.
//!
//! With task-generic requests, *requests* and *work* diverge: a
//! `Sequence` is one request but many recurrent steps, a `Decode` is
//! one request but `max_len` decoder steps. `tokens` counts the work
//! (the throughput number), `requests` counts scheduling units (the
//! occupancy number). The per-kind split shows which request shapes
//! carry the load.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::benchlib::Percentiles;
use crate::qmath::{IsaPath, KernelTier};
use crate::telemetry::{Counter, Gauge, Histogram, SampleWindow};
use crate::tensorfile::json::Json;

use super::scheduler::RequestKind;

/// Cap on retained latency samples per shard: percentiles describe a
/// sliding window of the most recent samples instead of the full
/// history, keeping a long-running server's memory bounded and
/// snapshot cost O(window), not O(lifetime-tokens). Sized so the
/// `snapshot()` clone under the shard mutex (which the worker also
/// takes in `record_batch`) stays a ~128 KB memcpy — ample samples
/// for a stable p99, small enough that a polling monitor doesn't add
/// visible tail latency to in-flight batches.
pub const LATENCY_WINDOW: usize = 16_384;

/// Request kinds in the fixed reporting order ([`RequestKind`] variant
/// order) — index with [`kind_index`].
pub const KIND_NAMES: [&str; 4] = ["step", "sequence", "finalize", "decode"];

/// Upper-inclusive batch-occupancy bucket bounds (requests per
/// scheduled micro-batch); one overflow bucket follows, so the
/// histogram has `OCCUPANCY_BOUNDS.len() + 1` counts.
pub const OCCUPANCY_BOUNDS: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Index of a request kind in [`KIND_NAMES`]-ordered arrays.
pub fn kind_index(kind: &RequestKind) -> usize {
    match kind {
        RequestKind::Step { .. } => 0,
        RequestKind::Sequence { .. } => 1,
        RequestKind::Finalize => 2,
        RequestKind::Decode(_) => 3,
    }
}

/// Live counters for one shard (one worker thread writes, anyone reads).
pub struct ShardStats {
    tokens: Counter,
    requests: Counter,
    batches: Counter,
    sessions: Gauge,
    /// requests answered per kind, [`KIND_NAMES`] order
    kind_requests: [Counter; 4],
    /// recurrent-step work per kind, [`KIND_NAMES`] order
    kind_work: [Counter; 4],
    /// requests-per-micro-batch distribution
    occupancy: Histogram,
    latencies: Mutex<SampleWindow>,
    /// active forward-kernel tier (0 = decoded, 1 = shiftadd) — set
    /// once by the worker at spawn so bench rows are self-describing
    kernel_tier: Gauge,
    /// active SIMD execution path ([`IsaPath::index`] encoding) — set
    /// once by the worker at spawn, beside the tier
    kernel_isa: Gauge,
    /// scheduler queue high-water mark, republished at batch
    /// boundaries from [`super::scheduler::RequestQueue::high_water`]
    queue_high_water: Gauge,
}

/// Per-request-kind slice of a snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KindSnapshot {
    pub requests: u64,
    /// recurrent-step work those requests carried
    pub work: u64,
}

/// Point-in-time summary of one shard (or of all shards, merged).
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    /// recurrent-state steps processed (streamed + prefilled + decoded)
    pub tokens: u64,
    /// requests answered (the scheduling unit)
    pub requests: u64,
    pub batches: u64,
    /// live sessions currently holding server-side state
    pub sessions: u64,
    /// mean requests per scheduled micro-batch — how full batches ran
    pub mean_occupancy: f64,
    /// per-kind requests/work, [`KIND_NAMES`] order
    pub per_kind: [KindSnapshot; 4],
    /// occupancy histogram counts ([`OCCUPANCY_BOUNDS`] + overflow)
    pub occupancy_hist: [u64; 8],
    /// active forward-kernel tier the shard's worker served with
    pub kernel_tier: KernelTier,
    /// active SIMD execution path the shard's worker served with
    pub kernel_isa: IsaPath,
    /// deepest the shard's scheduler queue has been (merged: the max
    /// across shards — the backpressure headline)
    pub queue_high_water: u64,
    /// enqueue → reply-ready service latency
    pub latency: Percentiles,
}

impl ShardStats {
    pub fn new() -> ShardStats {
        ShardStats {
            tokens: Counter::new(),
            requests: Counter::new(),
            batches: Counter::new(),
            sessions: Gauge::new(),
            kind_requests: [Counter::new(), Counter::new(), Counter::new(), Counter::new()],
            kind_work: [Counter::new(), Counter::new(), Counter::new(), Counter::new()],
            occupancy: Histogram::new(&OCCUPANCY_BOUNDS),
            latencies: Mutex::new(SampleWindow::new(LATENCY_WINDOW)),
            kernel_tier: Gauge::new(),
            kernel_isa: Gauge::new(),
            queue_high_water: Gauge::new(),
        }
    }

    /// Publish the tier the worker serves with (once, at spawn).
    pub fn set_kernel_tier(&self, tier: KernelTier) {
        self.kernel_tier.set(match tier {
            KernelTier::Decoded => 0,
            KernelTier::ShiftAdd => 1,
        });
    }

    /// Publish the SIMD path the worker serves with (once, at spawn).
    pub fn set_kernel_isa(&self, isa: IsaPath) {
        self.kernel_isa.set(isa.index() as u64);
    }

    /// Republish the scheduler queue's high-water mark (worker-side,
    /// at batch boundaries — monotone, so last-write-wins is exact).
    pub fn set_queue_high_water(&self, n: usize) {
        self.queue_high_water.set(n as u64);
    }

    /// Record one scheduled micro-batch: its request count, the
    /// recurrent-step work it carried, and per-request latencies.
    pub fn record_batch(&self, requests: usize, work_tokens: u64, lats: &[Duration]) {
        self.tokens.add(work_tokens);
        self.requests.add(requests as u64);
        self.batches.add(1);
        self.occupancy.record(requests as u64);
        let mut window = self.latencies.lock().unwrap();
        for &l in lats {
            window.push(l);
        }
    }

    /// Record the batch's per-kind split ([`KIND_NAMES`] order):
    /// requests answered and the work they carried.
    pub fn record_kinds(&self, requests: &[u64; 4], work: &[u64; 4]) {
        for k in 0..4 {
            self.kind_requests[k].add(requests[k]);
            self.kind_work[k].add(work[k]);
        }
    }

    /// Publish the shard's live session count (worker-side, after each
    /// batch's opens/closes are applied).
    pub fn set_sessions(&self, n: usize) {
        self.sessions.set(n as u64);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let mut samples = self.latencies.lock().unwrap().samples().to_vec();
        let tokens = self.tokens.get();
        let requests = self.requests.get();
        let batches = self.batches.get();
        let mut per_kind = [KindSnapshot::default(); 4];
        for k in 0..4 {
            per_kind[k] = KindSnapshot {
                requests: self.kind_requests[k].get(),
                work: self.kind_work[k].get(),
            };
        }
        let occupancy_hist: [u64; 8] =
            self.occupancy.counts().try_into().expect("7 bounds + overflow");
        StatsSnapshot {
            tokens,
            requests,
            batches,
            sessions: self.sessions.get(),
            mean_occupancy: if batches == 0 { 0.0 } else { requests as f64 / batches as f64 },
            per_kind,
            occupancy_hist,
            kernel_tier: if self.kernel_tier.get() == 0 {
                KernelTier::Decoded
            } else {
                KernelTier::ShiftAdd
            },
            kernel_isa: IsaPath::from_index(self.kernel_isa.get() as u8),
            queue_high_water: self.queue_high_water.get(),
            latency: Percentiles::of(&mut samples),
        }
    }
}

impl Default for ShardStats {
    fn default() -> Self {
        ShardStats::new()
    }
}

/// Merge shards into one snapshot; percentiles are recomputed over the
/// union of the raw samples (averaging per-shard percentiles would be
/// statistically wrong).
pub fn merged(shards: &[Arc<ShardStats>]) -> StatsSnapshot {
    let mut samples: Vec<Duration> = Vec::new();
    let mut out = StatsSnapshot::default();
    for (i, s) in shards.iter().enumerate() {
        let snap = s.snapshot();
        out.tokens += snap.tokens;
        out.requests += snap.requests;
        out.batches += snap.batches;
        out.sessions += snap.sessions;
        for k in 0..4 {
            out.per_kind[k].requests += snap.per_kind[k].requests;
            out.per_kind[k].work += snap.per_kind[k].work;
        }
        for (acc, c) in out.occupancy_hist.iter_mut().zip(snap.occupancy_hist) {
            *acc += c;
        }
        if i == 0 {
            // every worker serves the same shared model, so the tier
            // and ISA are uniform across shards
            out.kernel_tier = snap.kernel_tier;
            out.kernel_isa = snap.kernel_isa;
        }
        out.queue_high_water = out.queue_high_water.max(snap.queue_high_water);
        samples.extend_from_slice(s.latencies.lock().unwrap().samples());
    }
    out.mean_occupancy =
        if out.batches == 0 { 0.0 } else { out.requests as f64 / out.batches as f64 };
    out.latency = Percentiles::of(&mut samples);
    out
}

impl StatsSnapshot {
    /// Telemetry block for `BENCH_serve.json` rows: counters, the
    /// per-kind split, and the occupancy histogram are deterministic
    /// for a fixed request schedule; wall-clock stays confined to the
    /// marked `timing` sub-object.
    pub fn telemetry_json(&self) -> Json {
        use std::collections::BTreeMap;
        let num = |v: u64| Json::Num(v as f64);
        let mut kinds = BTreeMap::new();
        for (k, name) in KIND_NAMES.iter().enumerate() {
            let mut m = BTreeMap::new();
            m.insert("requests".to_string(), num(self.per_kind[k].requests));
            m.insert("work".to_string(), num(self.per_kind[k].work));
            kinds.insert(name.to_string(), Json::Obj(m));
        }
        let mut timing = BTreeMap::new();
        timing.insert("p50_us".to_string(), Json::Num(self.latency.p50.as_micros() as f64));
        timing.insert("p99_us".to_string(), Json::Num(self.latency.p99.as_micros() as f64));
        timing.insert("max_us".to_string(), Json::Num(self.latency.max.as_micros() as f64));
        let mut m = BTreeMap::new();
        m.insert("tokens".to_string(), num(self.tokens));
        m.insert("requests".to_string(), num(self.requests));
        m.insert("batches".to_string(), num(self.batches));
        m.insert("sessions".to_string(), num(self.sessions));
        m.insert("kernel_tier".to_string(), Json::Str(self.kernel_tier.name().to_string()));
        m.insert("kernel_isa".to_string(), Json::Str(self.kernel_isa.name().to_string()));
        m.insert("queue_high_water".to_string(), num(self.queue_high_water));
        m.insert("mean_occupancy".to_string(), Json::Num(self.mean_occupancy));
        m.insert("per_kind".to_string(), Json::Obj(kinds));
        m.insert(
            "occupancy_hist".to_string(),
            Json::Arr(self.occupancy_hist.iter().map(|&c| num(c)).collect()),
        );
        m.insert("timing".to_string(), Json::Obj(timing));
        Json::Obj(m)
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} tokens / {} requests in {} batches (occupancy {:.2}, {} live sessions); latency {}",
            self.tokens, self.requests, self.batches, self.mean_occupancy, self.sessions,
            self.latency
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_merge() {
        let a = Arc::new(ShardStats::new());
        let b = Arc::new(ShardStats::new());
        a.record_batch(4, 4, &[Duration::from_micros(10); 4]);
        a.record_batch(2, 2, &[Duration::from_micros(30); 2]);
        b.record_batch(6, 6, &[Duration::from_micros(20); 6]);
        a.set_sessions(3);
        b.set_sessions(2);
        let sa = a.snapshot();
        assert_eq!(sa.tokens, 6);
        assert_eq!(sa.requests, 6);
        assert_eq!(sa.batches, 2);
        assert_eq!(sa.sessions, 3);
        assert!((sa.mean_occupancy - 3.0).abs() < 1e-9);
        a.set_queue_high_water(5);
        b.set_queue_high_water(9);
        a.set_kernel_tier(KernelTier::ShiftAdd);
        b.set_kernel_tier(KernelTier::ShiftAdd);
        a.set_kernel_isa(IsaPath::Scalar);
        b.set_kernel_isa(IsaPath::Scalar);
        let m = merged(&[a, b]);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.batches, 3);
        assert_eq!(m.sessions, 5);
        assert_eq!(m.queue_high_water, 9, "merged high water is the max across shards");
        assert_eq!(m.kernel_tier, KernelTier::ShiftAdd);
        assert_eq!(m.kernel_isa, IsaPath::Scalar);
        assert_eq!(m.latency.n, 12);
        assert_eq!(m.latency.max, Duration::from_micros(30));
        // occupancy: batches of 4, 2, 6 → buckets (≤4), (≤2), (≤8)
        assert_eq!(m.occupancy_hist, [0, 1, 1, 1, 0, 0, 0, 0]);
    }

    #[test]
    fn work_and_requests_diverge_for_heavy_requests() {
        // one decode request carrying 32 decoder steps
        let s = ShardStats::new();
        s.record_batch(1, 32, &[Duration::from_micros(500)]);
        s.record_kinds(&[0, 0, 0, 1], &[0, 0, 0, 32]);
        let snap = s.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.tokens, 32, "throughput counts the decoded tokens");
        assert!((snap.mean_occupancy - 1.0).abs() < 1e-9);
        assert_eq!(snap.per_kind[3], KindSnapshot { requests: 1, work: 32 });
        assert_eq!(snap.per_kind[0], KindSnapshot::default());
    }

    #[test]
    fn latency_window_is_bounded() {
        let s = ShardStats::new();
        for i in 0..(LATENCY_WINDOW + 10) {
            s.record_batch(1, 1, &[Duration::from_nanos(i as u64)]);
        }
        let win = s.latencies.lock().unwrap();
        assert_eq!(win.len(), LATENCY_WINDOW, "window never exceeds the cap");
        // the 10 oldest samples were overwritten in place
        assert_eq!(win.samples()[0], Duration::from_nanos(LATENCY_WINDOW as u64));
        assert_eq!(win.samples()[9], Duration::from_nanos(LATENCY_WINDOW as u64 + 9));
        assert_eq!(win.samples()[10], Duration::from_nanos(10));
    }

    #[test]
    fn telemetry_json_is_deterministic_and_marks_timing() {
        let s = ShardStats::new();
        s.record_batch(2, 5, &[Duration::from_micros(10), Duration::from_micros(20)]);
        s.record_kinds(&[1, 1, 0, 0], &[1, 4, 0, 0]);
        s.set_kernel_tier(KernelTier::ShiftAdd);
        s.set_kernel_isa(IsaPath::Scalar);
        s.set_queue_high_water(7);
        let j1 = s.snapshot().telemetry_json();
        let j2 = s.snapshot().telemetry_json();
        assert_eq!(j1.to_string(), j2.to_string(), "same state → same bytes");
        assert!(j1.get("timing").is_some(), "wall-clock lives under timing");
        assert_eq!(
            j1.get("kernel_tier").and_then(Json::as_str),
            Some("shiftadd"),
            "bench rows are self-describing about the tier"
        );
        assert_eq!(
            j1.get("kernel_isa").and_then(Json::as_str),
            Some("scalar"),
            "the active ISA rides beside the tier"
        );
        assert_eq!(j1.get("queue_high_water").and_then(Json::as_f64), Some(7.0));
        let kinds = j1.get("per_kind").expect("per_kind block");
        assert_eq!(
            kinds.get("sequence").and_then(|k| k.get("work")).and_then(Json::as_f64),
            Some(4.0)
        );
        let hist = j1.get("occupancy_hist").and_then(Json::as_arr).expect("hist");
        assert_eq!(hist.len(), OCCUPANCY_BOUNDS.len() + 1);
    }
}
