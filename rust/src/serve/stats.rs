//! Per-shard serving statistics: request/token counters and a live
//! session gauge on atomics (read by any thread without stopping the
//! worker) and raw service-latency samples summarized through
//! [`benchlib::Percentiles`] — the same reporting machinery the paper
//! benches use.
//!
//! With task-generic requests, *requests* and *work* diverge: a
//! `Sequence` is one request but many recurrent steps, a `Decode` is
//! one request but `max_len` decoder steps. `tokens` counts the work
//! (the throughput number), `requests` counts scheduling units (the
//! occupancy number).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::benchlib::Percentiles;

/// Cap on retained latency samples per shard: percentiles describe a
/// sliding window of the most recent samples instead of the full
/// history, keeping a long-running server's memory bounded and
/// snapshot cost O(window), not O(lifetime-tokens). Sized so the
/// `snapshot()` clone under the shard mutex (which the worker also
/// takes in `record_batch`) stays a ~128 KB memcpy — ample samples
/// for a stable p99, small enough that a polling monitor doesn't add
/// visible tail latency to in-flight batches.
pub const LATENCY_WINDOW: usize = 16_384;

/// Bounded ring of the most recent latency samples.
#[derive(Default)]
struct LatencyRing {
    buf: Vec<Duration>,
    next: usize,
}

impl LatencyRing {
    fn push(&mut self, d: Duration) {
        if self.buf.len() < LATENCY_WINDOW {
            self.buf.push(d);
        } else {
            self.buf[self.next] = d;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

/// Live counters for one shard (one worker thread writes, anyone reads).
#[derive(Default)]
pub struct ShardStats {
    tokens: AtomicU64,
    requests: AtomicU64,
    batches: AtomicU64,
    sessions: AtomicU64,
    latencies: Mutex<LatencyRing>,
}

/// Point-in-time summary of one shard (or of all shards, merged).
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsSnapshot {
    /// recurrent-state steps processed (streamed + prefilled + decoded)
    pub tokens: u64,
    /// requests answered (the scheduling unit)
    pub requests: u64,
    pub batches: u64,
    /// live sessions currently holding server-side state
    pub sessions: u64,
    /// mean requests per scheduled micro-batch — how full batches ran
    pub mean_occupancy: f64,
    /// enqueue → reply-ready service latency
    pub latency: Percentiles,
}

impl ShardStats {
    pub fn new() -> ShardStats {
        ShardStats::default()
    }

    /// Record one scheduled micro-batch: its request count, the
    /// recurrent-step work it carried, and per-request latencies.
    pub fn record_batch(&self, requests: usize, work_tokens: u64, lats: &[Duration]) {
        self.tokens.fetch_add(work_tokens, Ordering::Relaxed);
        self.requests.fetch_add(requests as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.latencies.lock().unwrap();
        for &l in lats {
            ring.push(l);
        }
    }

    /// Publish the shard's live session count (worker-side, after each
    /// batch's opens/closes are applied).
    pub fn set_sessions(&self, n: usize) {
        self.sessions.store(n as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        let mut samples = self.latencies.lock().unwrap().buf.clone();
        let tokens = self.tokens.load(Ordering::Relaxed);
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        StatsSnapshot {
            tokens,
            requests,
            batches,
            sessions: self.sessions.load(Ordering::Relaxed),
            mean_occupancy: if batches == 0 { 0.0 } else { requests as f64 / batches as f64 },
            latency: Percentiles::of(&mut samples),
        }
    }
}

/// Merge shards into one snapshot; percentiles are recomputed over the
/// union of the raw samples (averaging per-shard percentiles would be
/// statistically wrong).
pub fn merged(shards: &[Arc<ShardStats>]) -> StatsSnapshot {
    let mut samples: Vec<Duration> = Vec::new();
    let mut tokens = 0u64;
    let mut requests = 0u64;
    let mut batches = 0u64;
    let mut sessions = 0u64;
    for s in shards {
        tokens += s.tokens.load(Ordering::Relaxed);
        requests += s.requests.load(Ordering::Relaxed);
        batches += s.batches.load(Ordering::Relaxed);
        sessions += s.sessions.load(Ordering::Relaxed);
        samples.extend_from_slice(&s.latencies.lock().unwrap().buf);
    }
    StatsSnapshot {
        tokens,
        requests,
        batches,
        sessions,
        mean_occupancy: if batches == 0 { 0.0 } else { requests as f64 / batches as f64 },
        latency: Percentiles::of(&mut samples),
    }
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} tokens / {} requests in {} batches (occupancy {:.2}, {} live sessions); latency {}",
            self.tokens, self.requests, self.batches, self.mean_occupancy, self.sessions,
            self.latency
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_and_merge() {
        let a = Arc::new(ShardStats::new());
        let b = Arc::new(ShardStats::new());
        a.record_batch(4, 4, &[Duration::from_micros(10); 4]);
        a.record_batch(2, 2, &[Duration::from_micros(30); 2]);
        b.record_batch(6, 6, &[Duration::from_micros(20); 6]);
        a.set_sessions(3);
        b.set_sessions(2);
        let sa = a.snapshot();
        assert_eq!(sa.tokens, 6);
        assert_eq!(sa.requests, 6);
        assert_eq!(sa.batches, 2);
        assert_eq!(sa.sessions, 3);
        assert!((sa.mean_occupancy - 3.0).abs() < 1e-9);
        let m = merged(&[a, b]);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.batches, 3);
        assert_eq!(m.sessions, 5);
        assert_eq!(m.latency.n, 12);
        assert_eq!(m.latency.max, Duration::from_micros(30));
    }

    #[test]
    fn work_and_requests_diverge_for_heavy_requests() {
        // one decode request carrying 32 decoder steps
        let s = ShardStats::new();
        s.record_batch(1, 32, &[Duration::from_micros(500)]);
        let snap = s.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.tokens, 32, "throughput counts the decoded tokens");
        assert!((snap.mean_occupancy - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_window_is_bounded() {
        let mut ring = LatencyRing::default();
        for i in 0..(LATENCY_WINDOW + 10) {
            ring.push(Duration::from_nanos(i as u64));
        }
        assert_eq!(ring.buf.len(), LATENCY_WINDOW, "window never exceeds the cap");
        // the 10 oldest samples were overwritten in place
        assert_eq!(ring.buf[0], Duration::from_nanos(LATENCY_WINDOW as u64));
        assert_eq!(ring.buf[9], Duration::from_nanos(LATENCY_WINDOW as u64 + 9));
        assert_eq!(ring.buf[10], Duration::from_nanos(10));
    }
}
