//! Deterministic RNGs (the offline vendor set has no `rand` crate).
//!
//! [`SplitMix64`] seeds quickly and is used everywhere a stream of
//! reproducible pseudo-random numbers is needed (dataset synthesis,
//! tests, benches). [`Xoshiro256ss`] is the long-period generator
//! behind the dataset generators' independent per-shard streams.

/// SplitMix64 (Steele et al.) — tiny, full 64-bit state, passes BigCrush
/// when used as a seeder; plenty for workload synthesis.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method (bias < 2^-64 for our n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }
}

/// xoshiro256** — long-period generator for independent shard streams.
#[derive(Clone, Debug)]
pub struct Xoshiro256ss {
    s: [u64; 4],
}

impl Xoshiro256ss {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256ss { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// The jump function: advances 2^128 steps — gives independent
    /// streams for parallel shards.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] =
            [0x180EC6D33CFD0ABA, 0xD5A61266F0C9392C, 0xA9582618E03FC9AA, 0x39ABDC4529B1661C];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j >> b) & 1 == 1 {
                    for (dst, src) in s.iter_mut().zip(&self.s) {
                        *dst ^= src;
                    }
                }
                self.next_u64();
            }
        }
        self.s = s;
    }
}

/// Sample from a Zipf(s) distribution over {0..n-1} by inverse CDF
/// (precomputed) — the vocabulary shape of natural corpora, used by the
/// WikiText-2-like generator.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    #[inline]
    pub fn sample(&self, rng: &mut SplitMix64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn xoshiro_jump_decorrelates() {
        let mut a = Xoshiro256ss::new(5);
        let mut b = a.clone();
        b.jump();
        let eq = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(eq, 0);
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipf::new(100, 1.1);
        let mut r = SplitMix64::new(1);
        let mut counts = vec![0u32; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }
}
