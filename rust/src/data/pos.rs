//! UDPOS stand-in: POS tagging over a synthetic template grammar.
//!
//! * tag sequences follow a bigram grammar (each tag has a preferred
//!   successor distribution) — mimics syntactic structure;
//! * each tag owns a disjoint word inventory, **except** a 25% slice of
//!   "ambiguous" words shared between two tags: for those, the correct
//!   tag is decidable only from the *previous* tag — this is what makes
//!   the task require recurrent context rather than a per-token lookup,
//!   the property that makes LSTM quantization errors visible.

use crate::rng::SplitMix64;

use super::{Batch, BatchSource};

pub struct PosGen {
    batch: usize,
    seq: usize,
    vocab: usize,
    n_tags: usize,
    rng: SplitMix64,
    eval: Vec<Batch>,
    /// words_per_tag[t] = (lo, hi) id range owned by tag t
    spans: Vec<(usize, usize)>,
    /// ambiguous word ids: shared between tag t and (t+1)%n
    amb_lo: usize,
}

impl PosGen {
    pub fn new(
        batch: usize,
        seq: usize,
        vocab: usize,
        n_tags: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Self {
        assert!(n_tags >= 2 && vocab > 4 * n_tags);
        // reserve the top quarter of the vocab for ambiguous words
        let amb_lo = vocab - vocab / 4;
        let per_tag = amb_lo / n_tags;
        let spans: Vec<(usize, usize)> =
            (0..n_tags).map(|t| (t * per_tag, (t + 1) * per_tag)).collect();
        let mut gen = PosGen {
            batch,
            seq,
            vocab,
            n_tags,
            rng: SplitMix64::new(seed),
            eval: Vec::new(),
            spans,
            amb_lo,
        };
        // held-out eval stream: independent generator state
        let mut eval_rng = SplitMix64::new(seed ^ 0xEEEE_0000_1111);
        gen.eval = (0..eval_batches).map(|_| gen.gen_batch(&mut eval_rng)).collect();
        gen
    }

    fn next_tag(&self, prev: usize, rng: &mut SplitMix64) -> usize {
        // bigram grammar: 60% preferred successor (prev+1), 40% uniform
        if rng.next_f32() < 0.6 {
            (prev + 1) % self.n_tags
        } else {
            rng.next_below(self.n_tags as u64) as usize
        }
    }

    fn gen_batch(&self, rng: &mut SplitMix64) -> Batch {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        let amb_per_pair = (self.vocab - self.amb_lo) / self.n_tags;
        for _ in 0..self.batch {
            let mut tag = rng.next_below(self.n_tags as u64) as usize;
            for t in 0..self.seq {
                if t > 0 {
                    tag = self.next_tag(tag, rng);
                }
                // 25% of tokens are ambiguous words: word id encodes the
                // *pair* (tag, tag+1) — the tag label is still `tag`, so
                // the model must read the bigram context.
                let word = if rng.next_f32() < 0.25 && amb_per_pair > 0 {
                    let k = rng.next_below(amb_per_pair as u64) as usize;
                    // the pair index is min(tag, paired) so both tags of a
                    // pair emit the same word ids
                    let pair = tag % self.n_tags;
                    let pair = pair.min((pair + self.n_tags - 1) % self.n_tags);
                    self.amb_lo + (pair * amb_per_pair + k) % (self.vocab - self.amb_lo)
                } else {
                    let (lo, hi) = self.spans[tag];
                    lo + rng.next_below((hi - lo) as u64) as usize
                };
                x.push(word as i32);
                y.push(tag as i32);
            }
        }
        Batch {
            x,
            y,
            x_shape: vec![self.batch, self.seq],
            y_shape: vec![self.batch, self.seq],
        }
    }
}

impl BatchSource for PosGen {
    fn next_train(&mut self) -> Batch {
        let mut rng = SplitMix64::new(self.rng.next_u64());
        self.gen_batch(&mut rng)
    }

    fn eval_set(&self) -> &[Batch] {
        &self.eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut g = PosGen::new(8, 24, 600, 12, 3, 1);
        let b = g.next_train();
        assert_eq!(b.x.len(), 8 * 24);
        for (&w, &t) in b.x.iter().zip(&b.y) {
            assert!((0..600).contains(&(w as usize)));
            assert!((0..12).contains(&(t as usize)));
        }
    }

    #[test]
    fn unambiguous_words_determine_tags() {
        // words below amb_lo belong to exactly one tag span
        let g = PosGen::new(4, 24, 600, 12, 1, 2);
        let mut seen: std::collections::HashMap<i32, i32> = Default::default();
        let mut rng = SplitMix64::new(3);
        for _ in 0..50 {
            let b = g.gen_batch(&mut rng);
            for (&w, &t) in b.x.iter().zip(&b.y) {
                if (w as usize) < g.amb_lo {
                    let prev = seen.insert(w, t);
                    if let Some(p) = prev {
                        assert_eq!(p, t, "word {w} got two tags");
                    }
                }
            }
        }
    }

    #[test]
    fn ambiguous_words_exist_and_are_shared() {
        let g = PosGen::new(16, 24, 600, 12, 1, 4);
        let mut tags_per_word: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        let mut rng = SplitMix64::new(5);
        for _ in 0..200 {
            let b = g.gen_batch(&mut rng);
            for (&w, &t) in b.x.iter().zip(&b.y) {
                if (w as usize) >= g.amb_lo {
                    tags_per_word.entry(w).or_default().insert(t);
                }
            }
        }
        let shared = tags_per_word.values().filter(|s| s.len() >= 2).count();
        assert!(shared > 0, "no ambiguous word observed with 2 tags");
    }
}
