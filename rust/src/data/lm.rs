//! WikiText-2 stand-in: a Zipf-vocabulary order-2 Markov language
//! stream.
//!
//! Construction: with probability 0.65 the next token is the
//! deterministic function `g(w_{t-2}, w_{t-1})` (a fixed hash into the
//! vocabulary, biased toward frequent types); otherwise it is a fresh
//! Zipf draw. The deterministic skeleton gives an LSTM something to
//! learn (perplexity falls well below the unigram baseline) while the
//! Zipf noise keeps the entropy floor > 0; the large vocabulary
//! reproduces the output-layer dynamic-range behaviour that drives the
//! paper's Table V (the WikiText-2-specific finding).

use crate::rng::{SplitMix64, Zipf};

use super::{Batch, BatchSource};

pub struct LmGen {
    batch: usize,
    seq: usize,
    vocab: usize,
    zipf: Zipf,
    rng: SplitMix64,
    /// per-lane rolling context (w_{t-2}, w_{t-1}) — each batch lane is
    /// an independent stream, contiguous across batches (standard
    /// BPTT-truncated LM batching)
    ctx: Vec<(i32, i32)>,
    eval: Vec<Batch>,
    p_deterministic: f32,
}

impl LmGen {
    pub fn new(batch: usize, seq: usize, vocab: usize, eval_batches: usize, seed: u64) -> Self {
        let zipf = Zipf::new(vocab, 1.1);
        let mut rng = SplitMix64::new(seed);
        let ctx: Vec<(i32, i32)> = (0..batch)
            .map(|_| (zipf_draw(&zipf, &mut rng, vocab), zipf_draw(&zipf, &mut rng, vocab)))
            .collect();
        let mut g = LmGen {
            batch,
            seq,
            vocab,
            zipf,
            rng,
            ctx,
            eval: Vec::new(),
            p_deterministic: 0.65,
        };
        // eval: separate lanes, same language (same g), held-out stream
        let mut eval_rng = SplitMix64::new(seed ^ 0x1357_9BDF_0246);
        let mut eval_ctx: Vec<(i32, i32)> = (0..batch)
            .map(|_| (zipf_draw(&g.zipf, &mut eval_rng, vocab), zipf_draw(&g.zipf, &mut eval_rng, vocab)))
            .collect();
        g.eval = (0..eval_batches)
            .map(|_| g.gen_batch(&mut eval_ctx, &mut eval_rng))
            .collect();
        g
    }

    /// The offline trainer's "char-LM" preset: the same order-2 Markov
    /// language over a character-alphabet-sized vocabulary, with a
    /// small fixed eval set. Lanes are contiguous streams, so each
    /// successive batch is the next truncated-BPTT window.
    pub fn char_lm(batch: usize, seq: usize, vocab: usize, seed: u64) -> Self {
        LmGen::new(batch, seq, vocab, 2, seed)
    }

    /// The language's deterministic bigram-successor function: a fixed
    /// hash of the context, folded toward small ids so the marginal
    /// stays Zipf-ish.
    #[inline]
    fn succ(&self, a: i32, b: i32) -> i32 {
        let h = (a as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((b as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        let h = (h ^ (h >> 29)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let u = ((h >> 33) as f64) / (1u64 << 31) as f64;
        // fold uniform into a Zipf-like curve: id ~ V * u^2.2
        ((self.vocab as f64 - 1.0) * u.powf(2.2)) as i32
    }

    fn step(&self, ctx: &mut (i32, i32), rng: &mut SplitMix64) -> i32 {
        let next = if rng.next_f32() < self.p_deterministic {
            self.succ(ctx.0, ctx.1)
        } else {
            zipf_draw(&self.zipf, rng, self.vocab)
        };
        *ctx = (ctx.1, next);
        next
    }

    fn gen_batch(&self, ctx: &mut [(i32, i32)], rng: &mut SplitMix64) -> Batch {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for lane in 0..self.batch {
            let mut prev = ctx[lane].1;
            for _ in 0..self.seq {
                let next = self.step(&mut ctx[lane], rng);
                x.push(prev);
                y.push(next);
                prev = next;
            }
        }
        Batch {
            x,
            y,
            x_shape: vec![self.batch, self.seq],
            y_shape: vec![self.batch, self.seq],
        }
    }
}

fn zipf_draw(z: &Zipf, rng: &mut SplitMix64, vocab: usize) -> i32 {
    (z.sample(rng).min(vocab - 1)) as i32
}

impl BatchSource for LmGen {
    fn next_train(&mut self) -> Batch {
        let mut ctx = std::mem::take(&mut self.ctx);
        let mut rng = self.rng.clone();
        let b = self.gen_batch(&mut ctx, &mut rng);
        self.rng = rng;
        self.ctx = ctx;
        b
    }

    fn eval_set(&self) -> &[Batch] {
        &self.eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn y_is_next_token_of_x() {
        let mut g = LmGen::new(4, 16, 100, 1, 3);
        let b = g.next_train();
        for lane in 0..4 {
            let xs = &b.x[lane * 16..(lane + 1) * 16];
            let ys = &b.y[lane * 16..(lane + 1) * 16];
            for t in 0..15 {
                assert_eq!(xs[t + 1], ys[t], "x must be y shifted");
            }
        }
    }

    #[test]
    fn lanes_are_contiguous_across_batches() {
        let mut g = LmGen::new(2, 8, 100, 1, 4);
        let b1 = g.next_train();
        let b2 = g.next_train();
        for lane in 0..2 {
            let last_y = b1.y[lane * 8 + 7];
            let first_x = b2.x[lane * 8];
            assert_eq!(last_y, first_x, "stream must continue across batches");
        }
    }

    #[test]
    fn marginal_is_skewed() {
        let mut g = LmGen::new(8, 32, 200, 1, 5);
        let mut counts = vec![0u32; 200];
        for _ in 0..50 {
            let b = g.next_train();
            for &w in &b.x {
                counts[w as usize] += 1;
            }
        }
        let top: u32 = counts[..20].iter().sum();
        let bottom: u32 = counts[100..120].iter().sum();
        assert!(top > bottom * 3, "vocabulary should be Zipf-skewed");
    }

    #[test]
    fn deterministic_skeleton_is_learnable() {
        // given (a, b), succ is a function — the conditional entropy of
        // the stream is bounded by H(p) + (1-p) log V < log V.
        let g = LmGen::new(1, 8, 100, 1, 6);
        assert_eq!(g.succ(5, 9), g.succ(5, 9));
        assert!((0..100).contains(&g.succ(5, 9)));
    }
}
