//! Synthetic workload generators standing in for the paper's four NLP
//! datasets (substitution table in DESIGN.md §4). Each generator
//! produces int32 batches with exactly the shapes the AOT artifacts
//! expect, plus a held-out eval stream; all are deterministic in the
//! seed so every precision scheme trains on the *identical* token
//! stream (the paper's controlled-comparison requirement).
//!
//! | module | stands in for | task structure |
//! |---|---|---|
//! | [`pos`] | UDPOS | template-grammar POS tagging with context-dependent ambiguous words |
//! | [`nli`] | SNLI | premise/hypothesis pairs, rule-generated 3-way labels |
//! | [`translation`] | Multi30K | deterministic reverse+relabel "translation" |
//! | [`lm`] | WikiText-2 | Zipf-vocabulary order-2 Markov language stream |

pub mod lm;
pub mod nli;
pub mod pos;
pub mod translation;

/// One int32 batch: flattened x and y plus their shapes.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
}

/// A deterministic batch stream (train) + a fixed eval set.
pub trait BatchSource {
    /// Next training batch (advances the stream).
    fn next_train(&mut self) -> Batch;
    /// The fixed held-out eval set.
    fn eval_set(&self) -> &[Batch];
}

/// Build the generator for a task by name with the shapes the manifest
/// dictates.
pub fn make_source(
    task: &str,
    batch: usize,
    x_shape: &[usize],
    y_shape: &[usize],
    vocab: usize,
    vocab_tgt: usize,
    n_classes: usize,
    eval_batches: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn BatchSource>> {
    Ok(match task {
        "pos" => Box::new(pos::PosGen::new(batch, x_shape[0], vocab, n_classes, eval_batches, seed)),
        "nli" => Box::new(nli::NliGen::new(batch, x_shape[1], vocab, eval_batches, seed)),
        "mt" => Box::new(translation::MtGen::new(
            batch, x_shape[0], y_shape[0], vocab, vocab_tgt, eval_batches, seed,
        )),
        "lm" | "tiny" => Box::new(lm::LmGen::new(batch, x_shape[0], vocab, eval_batches, seed)),
        other => anyhow::bail!("unknown task {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_tasks() {
        let specs: &[(&str, Vec<usize>, Vec<usize>, usize, usize, usize)] = &[
            ("pos", vec![24], vec![24], 600, 0, 12),
            ("nli", vec![2, 16], vec![], 800, 0, 3),
            ("mt", vec![16], vec![17], 400, 400, 0),
            ("lm", vec![32], vec![32], 2000, 0, 0),
            ("tiny", vec![8], vec![8], 64, 0, 0),
        ];
        for (task, xs, ys, v, vt, nc) in specs {
            let mut src = make_source(task, 4, xs, ys, *v, *vt, *nc, 2, 7).unwrap();
            let b = src.next_train();
            assert_eq!(b.x.len(), 4 * xs.iter().product::<usize>(), "{task} x");
            let want_y = 4 * ys.iter().product::<usize>().max(1);
            assert_eq!(b.y.len(), want_y, "{task} y");
            assert_eq!(src.eval_set().len(), 2, "{task} eval");
            // all ids in range
            for &t in &b.x {
                assert!((t as usize) < *v, "{task}: x token {t} >= vocab {v}");
            }
        }
    }

    #[test]
    fn streams_are_deterministic_in_seed() {
        let mk = || make_source("lm", 2, &[8], &[8], 100, 0, 0, 1, 42).unwrap();
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..5 {
            let (ba, bb) = (a.next_train(), b.next_train());
            assert_eq!(ba.x, bb.x);
            assert_eq!(ba.y, bb.y);
        }
    }
}
