//! Synthetic workload generators standing in for the paper's four NLP
//! datasets (substitution table in DESIGN.md §4). Each generator
//! produces int32 batches with exactly the shapes the AOT artifacts
//! expect, plus a held-out eval stream; all are deterministic in the
//! seed so every precision scheme trains on the *identical* token
//! stream (the paper's controlled-comparison requirement).
//!
//! | module | stands in for | task structure |
//! |---|---|---|
//! | [`pos`] | UDPOS | template-grammar POS tagging with context-dependent ambiguous words |
//! | [`nli`] | SNLI | premise/hypothesis pairs, rule-generated 3-way labels |
//! | [`translation`] | Multi30K | deterministic reverse+relabel "translation" |
//! | [`lm`] | WikiText-2 | Zipf-vocabulary order-2 Markov language stream |

pub mod lm;
pub mod nli;
pub mod pos;
pub mod translation;

/// One int32 batch: flattened x and y plus their shapes.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
}

/// A deterministic batch stream (train) + a fixed eval set.
pub trait BatchSource {
    /// Next training batch (advances the stream).
    fn next_train(&mut self) -> Batch;
    /// The fixed held-out eval set.
    fn eval_set(&self) -> &[Batch];
}

/// Domain preconditions of the generators (vocabulary floors, class
/// counts) as errors instead of the constructors' asserts — the single
/// copy shared by [`make_source`] and the task heads
/// ([`crate::tasks`]), which build the concrete generator types
/// directly.
pub fn check_task_args(
    task: &str,
    vocab: usize,
    vocab_tgt: usize,
    n_classes: usize,
) -> anyhow::Result<()> {
    use anyhow::bail;
    match task {
        "pos" => {
            if n_classes < 2 {
                bail!("pos: need >= 2 tag classes, got {n_classes}");
            }
            if vocab <= 4 * n_classes {
                bail!(
                    "pos: vocab {vocab} too small for {n_classes} tags (need > {})",
                    4 * n_classes
                );
            }
        }
        "nli" => {
            if vocab <= 10 {
                bail!("nli: vocab {vocab} too small (need > 10: 2 reserved + content)");
            }
        }
        "mt" => {
            if vocab <= 3 || vocab_tgt <= 3 {
                bail!(
                    "mt: vocab {vocab}/vocab_tgt {vocab_tgt} too small \
                     (3 ids are reserved: PAD, BOS, EOS)"
                );
            }
        }
        "lm" | "tiny" => {
            if vocab < 2 {
                bail!("{task}: vocab {vocab} too small");
            }
        }
        other => bail!("unknown task {other} (expected pos|nli|mt|lm|tiny)"),
    }
    Ok(())
}

/// Build the generator for a task by name with the shapes the manifest
/// dictates.
///
/// `x_shape`/`y_shape` are **per-example** shapes (no batch
/// dimension), matching the manifest convention: `pos`/`lm` take a
/// rank-1 `[seq]` for both, `nli` a rank-2 `[2, seq]` premise/
/// hypothesis pair with a scalar (empty-shape) label, and `mt` rank-1
/// `[src_len]` / `[src_len + 2]`. Note the per-task index asymmetry —
/// `nli` reads its sequence length from `x_shape[1]`, everything else
/// from `x_shape[0]` — which is why ranks are validated up front with
/// descriptive errors instead of letting indexing (or the generators'
/// own asserts) panic.
pub fn make_source(
    task: &str,
    batch: usize,
    x_shape: &[usize],
    y_shape: &[usize],
    vocab: usize,
    vocab_tgt: usize,
    n_classes: usize,
    eval_batches: usize,
    seed: u64,
) -> anyhow::Result<Box<dyn BatchSource>> {
    use anyhow::bail;

    let rank = |what: &str, shape: &[usize], want: usize| -> anyhow::Result<()> {
        if shape.len() != want {
            bail!(
                "{task}: {what} must be rank {want} (per-example, no batch dim), \
                 got shape {shape:?}"
            );
        }
        if shape.iter().any(|&d| d == 0) {
            bail!("{task}: {what} has a zero dimension: {shape:?}");
        }
        Ok(())
    };

    check_task_args(task, vocab, vocab_tgt, n_classes)?;
    Ok(match task {
        "pos" => {
            rank("x_shape", x_shape, 1)?;
            rank("y_shape", y_shape, 1)?;
            if y_shape[0] != x_shape[0] {
                bail!("pos: tag sequence {y_shape:?} must match token sequence {x_shape:?}");
            }
            Box::new(pos::PosGen::new(batch, x_shape[0], vocab, n_classes, eval_batches, seed))
        }
        "nli" => {
            rank("x_shape", x_shape, 2)?;
            if x_shape[0] != 2 {
                bail!("nli: x_shape must be [2, seq] (premise/hypothesis), got {x_shape:?}");
            }
            if !y_shape.is_empty() {
                bail!("nli: labels are per-example scalars — y_shape must be [], got {y_shape:?}");
            }
            Box::new(nli::NliGen::new(batch, x_shape[1], vocab, eval_batches, seed))
        }
        "mt" => {
            rank("x_shape", x_shape, 1)?;
            rank("y_shape", y_shape, 1)?;
            if y_shape[0] != x_shape[0] + 2 {
                bail!(
                    "mt: target length {} must be source length {} + 2 (BOS prefix, EOS suffix)",
                    y_shape[0],
                    x_shape[0]
                );
            }
            Box::new(translation::MtGen::new(
                batch, x_shape[0], y_shape[0], vocab, vocab_tgt, eval_batches, seed,
            ))
        }
        "lm" | "tiny" => {
            rank("x_shape", x_shape, 1)?;
            Box::new(lm::LmGen::new(batch, x_shape[0], vocab, eval_batches, seed))
        }
        // unreachable: check_task_args already rejected unknown names
        other => bail!("unknown task {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_all_tasks() {
        let specs: &[(&str, Vec<usize>, Vec<usize>, usize, usize, usize)] = &[
            ("pos", vec![24], vec![24], 600, 0, 12),
            ("nli", vec![2, 16], vec![], 800, 0, 3),
            ("mt", vec![16], vec![18], 400, 400, 0),
            ("lm", vec![32], vec![32], 2000, 0, 0),
            ("tiny", vec![8], vec![8], 64, 0, 0),
        ];
        for (task, xs, ys, v, vt, nc) in specs {
            let mut src = make_source(task, 4, xs, ys, *v, *vt, *nc, 2, 7).unwrap();
            let b = src.next_train();
            assert_eq!(b.x.len(), 4 * xs.iter().product::<usize>(), "{task} x");
            let want_y = 4 * ys.iter().product::<usize>().max(1);
            assert_eq!(b.y.len(), want_y, "{task} y");
            assert_eq!(src.eval_set().len(), 2, "{task} eval");
            // all ids in range
            for &t in &b.x {
                assert!((t as usize) < *v, "{task}: x token {t} >= vocab {v}");
            }
        }
    }

    #[test]
    fn factory_rejects_bad_shapes_with_descriptive_errors() {
        // (task, x_shape, y_shape, vocab, vocab_tgt, n_classes, expect)
        let bad: &[(&str, Vec<usize>, Vec<usize>, usize, usize, usize, &str)] = &[
            ("pos", vec![24, 2], vec![24], 600, 0, 12, "rank 1"),
            ("pos", vec![24], vec![12], 600, 0, 12, "must match"),
            ("pos", vec![24], vec![24], 40, 0, 12, "too small"),
            ("pos", vec![24], vec![24], 600, 0, 1, ">= 2 tag classes"),
            ("nli", vec![16], vec![], 800, 0, 3, "rank 2"),
            ("nli", vec![3, 16], vec![], 800, 0, 3, "[2, seq]"),
            ("nli", vec![2, 16], vec![1], 800, 0, 3, "scalar"),
            ("mt", vec![16], vec![17], 400, 400, 0, "+ 2"),
            ("mt", vec![16], vec![18], 400, 1, 0, "too small"),
            ("lm", vec![], vec![], 100, 0, 0, "rank 1"),
            ("lm", vec![0], vec![0], 100, 0, 0, "zero dimension"),
            ("wat", vec![8], vec![8], 100, 0, 0, "unknown task"),
        ];
        for (task, xs, ys, v, vt, nc, needle) in bad {
            let err = make_source(task, 4, xs, ys, *v, *vt, *nc, 1, 7).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(needle), "{task}: error {msg:?} missing {needle:?}");
        }
    }

    #[test]
    fn streams_are_deterministic_in_seed() {
        let mk = || make_source("lm", 2, &[8], &[8], 100, 0, 0, 1, 42).unwrap();
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..5 {
            let (ba, bb) = (a.next_train(), b.next_train());
            assert_eq!(ba.x, bb.x);
            assert_eq!(ba.y, bb.y);
        }
    }
}
