//! Multi30K stand-in: deterministic synthetic "translation".
//!
//! Source sentences are Zipf-distributed token sequences; the target
//! "language" is `BOS · map(reverse(source)) · EOS` where `map` is a
//! fixed bijective token relabeling — a deterministic transformation
//! with the long-range dependency structure (reversal) that an
//! encoder-decoder LSTM must carry through its bottleneck, like real
//! translation re-ordering. The trailing [`EOS`] is what lets the
//! serving decode loop retire lanes early instead of always emitting
//! `max_len` tokens (and what the teacher-forced trainer scores as
//! the final target position).

use crate::rng::{SplitMix64, Zipf};

use super::{Batch, BatchSource};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
/// End-of-sequence marker closing every target row; greedy/beam
/// decode lanes retire when they emit it.
pub const EOS: i32 = 2;
const RESERVED: usize = 3;

pub struct MtGen {
    batch: usize,
    src_len: usize,
    tgt_len: usize,
    vocab_src: usize,
    vocab_tgt: usize,
    zipf: Zipf,
    rng: SplitMix64,
    eval: Vec<Batch>,
}

impl MtGen {
    pub fn new(
        batch: usize,
        src_len: usize,
        tgt_len: usize,
        vocab_src: usize,
        vocab_tgt: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Self {
        assert_eq!(tgt_len, src_len + 2, "target = BOS + mapped reverse + EOS");
        let mut g = MtGen {
            batch,
            src_len,
            tgt_len,
            vocab_src,
            vocab_tgt,
            zipf: Zipf::new(vocab_src - RESERVED, 1.05),
            rng: SplitMix64::new(seed),
            eval: Vec::new(),
        };
        let mut eval_rng = SplitMix64::new(seed ^ 0x7777_1234_0000);
        g.eval = (0..eval_batches).map(|_| g.gen_batch(&mut eval_rng)).collect();
        g
    }

    /// The fixed "translation lexicon": bijective over content ids.
    #[inline]
    pub fn map_token(&self, w: i32) -> i32 {
        let n = (self.vocab_tgt - RESERVED) as i64;
        let c = (w as i64) - RESERVED as i64;
        // multiplier coprime with n for bijectivity (n even ⇒ use odd mult)
        (RESERVED as i64 + (c * 7 + 3).rem_euclid(n)) as i32
    }

    fn gen_batch(&self, rng: &mut SplitMix64) -> Batch {
        let mut x = Vec::with_capacity(self.batch * self.src_len);
        let mut y = Vec::with_capacity(self.batch * self.tgt_len);
        for _ in 0..self.batch {
            let src: Vec<i32> = (0..self.src_len)
                .map(|_| (RESERVED + self.zipf.sample(rng)) as i32)
                .collect();
            y.push(BOS);
            for &w in src.iter().rev() {
                y.push(self.map_token(w));
            }
            y.push(EOS);
            x.extend(src);
        }
        Batch {
            x,
            y,
            x_shape: vec![self.batch, self.src_len],
            y_shape: vec![self.batch, self.tgt_len],
        }
    }
}

impl BatchSource for MtGen {
    fn next_train(&mut self) -> Batch {
        let mut rng = SplitMix64::new(self.rng.next_u64());
        self.gen_batch(&mut rng)
    }

    fn eval_set(&self) -> &[Batch] {
        &self.eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_is_mapped_reverse_of_source_with_eos() {
        let mut g = MtGen::new(4, 16, 18, 400, 400, 1, 6);
        let b = g.next_train();
        for i in 0..4 {
            let src = &b.x[i * 16..(i + 1) * 16];
            let tgt = &b.y[i * 18..(i + 1) * 18];
            assert_eq!(tgt[0], BOS);
            for (k, &w) in src.iter().rev().enumerate() {
                assert_eq!(tgt[1 + k], g.map_token(w));
            }
            assert_eq!(tgt[17], EOS, "every target row closes with EOS");
        }
    }

    #[test]
    fn lexicon_is_bijective() {
        let g = MtGen::new(1, 16, 18, 400, 400, 1, 7);
        let mut seen = std::collections::HashSet::new();
        for w in RESERVED as i32..400 {
            let m = g.map_token(w);
            assert!((RESERVED as i32..400).contains(&m));
            assert!(seen.insert(m), "collision at {w}");
        }
    }

    #[test]
    fn ids_in_range() {
        let mut g = MtGen::new(8, 16, 18, 400, 400, 1, 8);
        let b = g.next_train();
        assert!(b.x.iter().all(|&w| (RESERVED as i32..400).contains(&w)));
        assert!(b.y.iter().all(|&w| (0..400).contains(&w)));
        // EOS appears exactly once per target row, at the end
        for lane in 0..8 {
            let tgt = &b.y[lane * 18..(lane + 1) * 18];
            assert_eq!(tgt.iter().filter(|&&w| w == EOS).count(), 1);
            assert_eq!(tgt[17], EOS);
        }
    }
}
