//! SNLI stand-in: premise/hypothesis pairs with rule-generated labels.
//!
//! * **entailment (0)** — hypothesis is a random subsequence of the
//!   premise (token subset ⇒ entailed);
//! * **contradiction (1)** — hypothesis is a premise subsequence with
//!   the reserved NEG token (id 1) spliced in;
//! * **neutral (2)** — hypothesis drawn independently of the premise.
//!
//! The decision signal is token overlap + NEG detection through the
//! encoder — the same "compare two encoded sentences through FC
//! layers" pathway as the SNLI model.

use crate::rng::SplitMix64;

use super::{Batch, BatchSource};

pub const PAD: i32 = 0;
pub const NEG: i32 = 1;
const RESERVED: usize = 2;

pub struct NliGen {
    batch: usize,
    seq: usize,
    vocab: usize,
    rng: SplitMix64,
    eval: Vec<Batch>,
}

impl NliGen {
    pub fn new(batch: usize, seq: usize, vocab: usize, eval_batches: usize, seed: u64) -> Self {
        assert!(vocab > RESERVED + 8);
        let mut g = NliGen { batch, seq, vocab, rng: SplitMix64::new(seed), eval: Vec::new() };
        let mut eval_rng = SplitMix64::new(seed ^ 0xAAAA_5555_0000);
        g.eval = (0..eval_batches).map(|_| g.gen_batch(&mut eval_rng)).collect();
        g
    }

    fn content_word(&self, rng: &mut SplitMix64) -> i32 {
        (RESERVED + rng.next_below((self.vocab - RESERVED) as u64) as usize) as i32
    }

    fn gen_pair(&self, rng: &mut SplitMix64) -> (Vec<i32>, Vec<i32>, i32) {
        let premise: Vec<i32> = (0..self.seq).map(|_| self.content_word(rng)).collect();
        let label = rng.next_below(3) as i32;
        let mut hyp = vec![PAD; self.seq];
        match label {
            0 => {
                // subsequence (keep each token with p=0.5, at least 2)
                let mut k = 0;
                for &w in &premise {
                    if rng.next_f32() < 0.5 && k < self.seq {
                        hyp[k] = w;
                        k += 1;
                    }
                }
                for need in k..2 {
                    hyp[need] = premise[need];
                }
            }
            1 => {
                let mut k = 0;
                for &w in &premise {
                    if rng.next_f32() < 0.5 && k < self.seq - 1 {
                        hyp[k] = w;
                        k += 1;
                    }
                }
                // splice NEG at a random kept position
                let pos = rng.next_below((k.max(1) + 1) as u64) as usize;
                hyp.insert(pos, NEG);
                hyp.truncate(self.seq);
            }
            _ => {
                for slot in hyp.iter_mut() {
                    *slot = self.content_word(rng);
                }
            }
        }
        (premise, hyp, label)
    }

    fn gen_batch(&self, rng: &mut SplitMix64) -> Batch {
        let mut x = Vec::with_capacity(self.batch * 2 * self.seq);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let (p, h, label) = self.gen_pair(rng);
            x.extend(&p);
            x.extend(&h);
            y.push(label);
        }
        Batch {
            x,
            y,
            x_shape: vec![self.batch, 2, self.seq],
            y_shape: vec![self.batch],
        }
    }
}

impl BatchSource for NliGen {
    fn next_train(&mut self) -> Batch {
        let mut rng = SplitMix64::new(self.rng.next_u64());
        self.gen_batch(&mut rng)
    }

    fn eval_set(&self) -> &[Batch] {
        &self.eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_balanced_and_in_range() {
        let mut g = NliGen::new(64, 16, 800, 1, 3);
        let mut counts = [0usize; 3];
        for _ in 0..20 {
            let b = g.next_train();
            for &l in &b.y {
                counts[l as usize] += 1;
            }
        }
        for c in counts {
            assert!(c > 250, "label counts {counts:?}");
        }
    }

    #[test]
    fn contradiction_contains_neg_token() {
        let g = NliGen::new(1, 16, 800, 1, 4);
        let mut rng = SplitMix64::new(9);
        let mut checked = 0;
        for _ in 0..200 {
            let (_, h, label) = g.gen_pair(&mut rng);
            if label == 1 {
                assert!(h.contains(&NEG), "contradiction without NEG: {h:?}");
                checked += 1;
            } else if label == 0 {
                assert!(!h.contains(&NEG));
            }
        }
        assert!(checked > 30);
    }

    #[test]
    fn entailment_is_subsequence() {
        let g = NliGen::new(1, 16, 800, 1, 5);
        let mut rng = SplitMix64::new(11);
        for _ in 0..200 {
            let (p, h, label) = g.gen_pair(&mut rng);
            if label == 0 {
                // every non-pad hyp token appears in the premise
                for &w in h.iter().filter(|&&w| w != PAD) {
                    assert!(p.contains(&w), "{w} not in premise");
                }
            }
        }
    }
}
