//! `floatsd-lstm` — CLI entrypoint of the L3 coordinator.
//!
//! ```text
//! floatsd-lstm info                      # manifest + scheme tables (II/VI)   [pjrt]
//! floatsd-lstm formats                   # Table I + FloatSD8 grid facts
//! floatsd-lstm hardware                  # Table VII cost breakdown
//! floatsd-lstm serve [--model ckpt.tensors] [--workers N --max-batch B]
//!                    [--decode-len L --beam K --beam-len-norm A]
//!                    [--kernel-tier decoded|shiftadd] [--kernel-isa scalar|sse2|avx2|auto]
//!                    [--trace serve.jsonl] [--trace-every N]
//!                                        # task-generic batched inference server
//!                                        # + per-task load gen (lm|pos|nli|mt)
//!                                        # --trace: request-lifecycle JSONL stream
//!                                        # (queue/batch/kernel spans, tier profile)
//!                                        # --trace-every: keep every N-th batch's
//!                                        # batch/request lines (lifecycle + summary
//!                                        # always traced)
//! floatsd-lstm train [--preset tiny|default|paper] [--threads N] [--trace t.jsonl]
//!                    [--trace-every N] [--kernel-tier decoded|shiftadd]
//!                    [--kernel-isa scalar|sse2|avx2|auto]
//!                    [--steps N --hidden H --out ckpt.tensors ...]
//!                                        # offline pure-rust quantized training
//!                                        # (lane-sharded; --threads N ≡ --threads 1 bit-for-bit)
//! floatsd-lstm train --task {lm,pos,nli,mt} [--preset tiny|default|paper]
//!                    [--threads N] [--trace-every N] [--kernel-tier decoded|shiftadd]
//!                    [--kernel-isa scalar|sse2|avx2|auto] [--steps N --out ckpt.tensors ...]
//!                                        # multi-task offline training (tasks/)
//! floatsd-lstm eval [--model a.tensors[,b.tensors...]] [--threads N] [--out report.json]
//!                   [--kernel-tier decoded|shiftadd] [--kernel-isa scalar|sse2|avx2|auto]
//!                   [--trace eval.jsonl]
//!                                        # held-out eval grid across all four tasks
//!                                        # (span-sharded; byte-identical for any N;
//!                                        # --trace adds per-shard eval_span timings)
//! floatsd-lstm report trace.jsonl        # summarize a --trace stream or eval report
//!                                        # (schema auto-detected): loss-scale events,
//!                                        # saturation, request spans, kernel profile
//! floatsd-lstm report --diff a.jsonl b.jsonl
//!                     [--sat-delta-pp P] [--span-regression-pct P]
//!                                        # compare two traces — or two saved eval
//!                                        # reports — of the same schema; flags
//!                                        # loss-scale drift, saturation deltas
//!                                        # (default > 5pp), p50/p99 span regressions
//!                                        # (default > 20%), and per-task eval metric
//!                                        # drift (accuracy vs --sat-delta-pp, loss/ppl
//!                                        # vs --span-regression-pct); both thresholds
//!                                        # tunable, finite and >= 0
//! floatsd-lstm train --artifact lm_fsd8m16 [--div 4]  # PJRT/XLA path          [pjrt]
//! floatsd-lstm suite --task lm [--div 4] # fp32 vs fsd8 vs fsd8m16            [pjrt]
//! ```
//!
//! `train` without `--artifact` runs the offline pure-rust trainer:
//! with `--task` the multi-task engine ([`floatsd_lstm::tasks`])
//! trains any of the four Table-IV heads from scratch; without it the
//! historical char-LM path ([`floatsd_lstm::train`]) runs. Both write
//! `.tensors` checkpoints; **every** task checkpoint loads into
//! `serve --model`, which auto-detects the task from the checkpoint's
//! `meta/task_cfg` and serves its request shape — streamed logits
//! (lm), per-step tag scores (pos), submit-sequence-then-finalize
//! classification (nli), or the encoder→decoder decode loop (mt;
//! `--beam` > 1 for beam search). The same checkpoints feed
//! `floatsd-lstm eval`, which rebuilds the task from the same
//! `meta/task_cfg` parser and emits a deterministic JSON report
//! covering all four tasks (untrained tasks are scored at preset
//! init); served outputs are bit-identical to that offline eval path
//! (pinned by `tests/serve_tasks.rs`). `--kernel-tier shiftadd` routes
//! every forward matvec/matmul through the integer shift-add tier
//! ([`floatsd_lstm::qmath::shiftadd`]) — bit-identical outputs, pinned
//! by `tests/shiftadd_equivalence.rs`. `--kernel-isa` forces the SIMD
//! execution path ([`floatsd_lstm::qmath::simd`]) for either tier —
//! `auto` (default) picks the widest ISA the host supports; every path
//! is bit-identical to `scalar`, also pinned by the same suite. Subcommands
//! marked `[pjrt]` need the crate built with `--features pjrt` (and
//! real XLA bindings in place of the offline stub); everything else —
//! the serving engine, the offline trainers, and the eval harness —
//! is pure rust and always available.

use anyhow::Result;

use floatsd_lstm::cli::Args;
use floatsd_lstm::formats::FLOAT_SD8;
use floatsd_lstm::hardware::cost;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(&args),
        Some("formats") => formats(),
        Some("hardware") => hardware(),
        Some("serve") => floatsd_lstm::serve::demo::run(&args),
        // `--artifact` selects the PJRT/XLA experiment path; without it
        // the offline pure-rust trainers run (always available). A bare
        // `--artifact` flag (value forgotten) must reach the PJRT path
        // too, so it errors instead of silently training offline.
        Some("train") if args.opt("artifact").is_none() && !args.has_flag("artifact") => {
            if args.opt("task").is_some() {
                floatsd_lstm::tasks::run_train_cli(&args)
            } else {
                floatsd_lstm::train::run_cli(&args)
            }
        }
        Some("train") => train(&args),
        Some("eval") => floatsd_lstm::tasks::eval::run_cli(&args),
        Some("report") => floatsd_lstm::telemetry::report::run_cli(&args),
        Some("suite") => suite(&args),
        _ => {
            eprintln!(
                "usage: floatsd-lstm <info|formats|hardware|serve|train|eval|report|suite> \
                 [options]\n\
                 see `rust/src/main.rs` docs for details"
            );
            Ok(())
        }
    }
}

fn formats() -> Result<()> {
    println!("Table I — 3-digit SD group values:");
    for v in floatsd_lstm::formats::sd::group_values(3) {
        println!("  {v:+}");
    }
    println!(
        "\nzero-digit probability K=3: {:.3} (CSD: {:.3})",
        floatsd_lstm::formats::sd::zero_digit_probability(3),
        floatsd_lstm::formats::sd::csd_zero_probability()
    );
    println!("\nFloatSD8: 3-bit exponent (bias 7) + 31-value mantissa codebook");
    println!("mantissas: {:?}", FLOAT_SD8.mantissa_codebook());
    println!("distinct values: {}", FLOAT_SD8.distinct_value_count());
    println!("range: ±{} … ±{}", FLOAT_SD8.min_positive(), FLOAT_SD8.max_value());
    let lut = floatsd_lstm::qmath::qsigmoid::SigmoidLut::build();
    println!("quantized-σ LUT non-zero entries (paper: 42): {}", lut.nonzero_entries());
    Ok(())
}

fn hardware() -> Result<()> {
    let (fp32, fsd8, ar, pr) = cost::table7();
    for r in [&fp32, &fsd8] {
        println!(
            "{} — {:.0} GE, {:.0} µm², {:.3} mW @400 MHz",
            r.name,
            r.total_ge(),
            r.area_um2(),
            r.power_mw()
        );
        for c in &r.components {
            println!("   {:<28} {:>8.0} GE", c.name, c.ge);
        }
    }
    println!("\nTable VII comparison (paper: 7.66x area, 5.75x power):");
    println!("  area ratio  {ar:.2}x");
    println!("  power ratio {pr:.2}x");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn info(args: &Args) -> Result<()> {
    use floatsd_lstm::runtime::Runtime;

    let rt = Runtime::new(args.opt_or("artifacts", "artifacts"))?;
    println!("platform: {}", rt.client.platform_name());
    println!("tasks:");
    for (name, t) in &rt.manifest.tasks {
        println!(
            "  {name:<6} batch={:<3} x{:?} vocab={} opt={} lr={} metric={}",
            t.batch, t.x_shape, t.vocab, t.optimizer, t.lr, t.metric
        );
    }
    println!("\nprecision schemes (paper Tables II/VI):");
    println!(
        "  {:<8} {:>4} {:>5} {:>6} {:>5} {:>5} {:>5} {:>5} {:>6}",
        "scheme", "w", "g", "a", "first", "last", "m", "s", "scale"
    );
    for (name, s) in &rt.manifest.schemes {
        println!(
            "  {name:<8} {:>4} {:>5} {:>6} {:>5} {:>5} {:>5} {:>5} {:>6}",
            s.weights, s.gradients, s.activations, s.first_layer_acts,
            s.last_layer_acts, s.master, s.sigmoid, s.loss_scale
        );
    }
    println!("\nartifacts: {}", rt.manifest.artifacts.len());
    for name in rt.manifest.artifacts.keys() {
        println!("  {name}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn train(args: &Args) -> Result<()> {
    use floatsd_lstm::coordinator::{run_experiment, ExperimentSpec};
    use floatsd_lstm::runtime::Runtime;

    let artifact = args.require_opt("artifact")?.to_string();
    let div = args.opt_usize("div", 1)?;
    let mut rt = Runtime::new(args.opt_or("artifacts", "artifacts"))?;
    let mut spec = ExperimentSpec::standard(&rt, &artifact, div)?;
    if let Some(e) = args.opt("epochs") {
        spec.preset.epochs = e.parse()?;
    }
    let res = run_experiment(&mut rt, &spec)?;
    println!(
        "{}: final {} {:.3} (best {:.3}) in {:.1?} [{} steps, exec {:.1?}, transfer {:.1?}]",
        res.artifact,
        res.metric_name,
        res.final_metric,
        res.best_metric,
        res.wall,
        res.steps,
        res.execute_time,
        res.transfer_time
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn suite(args: &Args) -> Result<()> {
    use anyhow::bail;
    use floatsd_lstm::coordinator::run_suite;
    use floatsd_lstm::runtime::Runtime;

    let task = args.opt_or("task", "lm");
    let div = args.opt_usize("div", 1)?;
    let mut rt = Runtime::new(args.opt_or("artifacts", "artifacts"))?;
    let names: Vec<String> =
        ["fp32", "fsd8", "fsd8m16"].iter().map(|s| format!("{task}_{s}")).collect();
    for n in &names {
        if !rt.manifest.artifacts.contains_key(n) {
            bail!("artifact {n} not found — run `make artifacts`");
        }
    }
    let refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let results = run_suite(&mut rt, &refs, div)?;
    println!("\n=== {task}: Table IV row ===");
    for r in &results {
        println!("  {:<16} {:>10.3} ({})", r.artifact, r.final_metric, r.metric_name);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn info(_args: &Args) -> Result<()> {
    pjrt_unavailable("info")
}

#[cfg(not(feature = "pjrt"))]
fn train(_args: &Args) -> Result<()> {
    pjrt_unavailable("train")
}

#[cfg(not(feature = "pjrt"))]
fn suite(_args: &Args) -> Result<()> {
    pjrt_unavailable("suite")
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<()> {
    anyhow::bail!(
        "`{cmd}` needs the PJRT training runtime — rebuild with `cargo build --features pjrt` \
         (and point the `xla` dependency at real PJRT bindings; see vendor/xla). \
         For pure-rust offline training, run `train` without `--artifact`."
    )
}
