//! Signed-digit (SD) group machinery behind the FloatSD representation
//! (paper §II-B, Fig. 2, Table I).
//!
//! A *K-digit SD group* holds at most **one** non-zero digit, each digit
//! being ±1 at some binary position inside the group, so a group takes
//! one of `2K + 1` values: `{0, ±1, ±2, …, ±2^(K-1)}`. A multiplication
//! by a group is therefore a single shifted add/subtract — that is the
//! whole complexity story of the paper.
//!
//! This module provides:
//! * [`group_values`] — the `2K+1` values of a K-digit group (Table I is
//!   `group_values(3)`);
//! * [`zero_digit_probability`] — the paper's `(2K-1)/(2K+1)` digit-level
//!   zero probability, cross-checked against exhaustive enumeration;
//! * [`csd_zero_probability`] — the canonical-signed-digit comparison
//!   point (≈ 2/3) quoted in §II-B;
//! * [`GenericFloatSd`] — the full FloatSD format of Fig. 2 (arbitrary
//!   group list + exponent), including the group-truncation shortcut of
//!   Fig. 3 used for low-cost inference/backprop.

/// The `2K+1` values representable by a K-digit SD group with at most one
/// non-zero digit, in descending order as the paper's Table I lists them:
/// `+2^(K-1) … +2, +1, 0, -1, -2 … -2^(K-1)`.
pub fn group_values(k: u32) -> Vec<i32> {
    assert!(k >= 1 && k <= 16, "group width out of range");
    let mut v: Vec<i32> = (0..k).rev().map(|i| 1i32 << i).collect();
    v.push(0);
    v.extend((0..k).map(|i| -(1i32 << i)));
    v
}

/// Probability that a single digit inside a K-digit SD group is zero,
/// assuming the `2K+1` group values are equiprobable — the paper's
/// `(2K-1)/(2K+1)` (§II-B; 71.4% for K = 3).
pub fn zero_digit_probability(k: u32) -> f64 {
    (2.0 * k as f64 - 1.0) / (2.0 * k as f64 + 1.0)
}

/// Digit-level zero probability of Canonical Signed Digit recoding for
/// long words (tends to 2/3 ≈ 66.6%, the figure the paper compares
/// against). For an n-digit CSD word the expected fraction of zeros is
/// `2/3 + 1/(9n) * (1 - (-1/2)^n)` → we return the asymptote.
pub fn csd_zero_probability() -> f64 {
    2.0 / 3.0
}

/// One SD group instance: `value ∈ {0, ±2^i, i < width}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SdGroup {
    /// Number of digits in the group.
    pub width: u32,
    /// The group's value (must be 0 or ±2^i with i < width).
    pub value: i32,
}

impl SdGroup {
    /// Create a group, validating the one-non-zero-digit constraint.
    pub fn new(width: u32, value: i32) -> Option<Self> {
        let mag = value.unsigned_abs();
        if value == 0 || (mag.is_power_of_two() && mag < (1 << width)) {
            Some(SdGroup { width, value })
        } else {
            None
        }
    }

    /// Number of non-zero digits this group contributes to a multiply
    /// (0 or 1) — i.e. the number of partial products.
    pub fn nonzero_digits(&self) -> u32 {
        (self.value != 0) as u32
    }

    /// The shift amount of the non-zero digit (None if zero).
    pub fn shift(&self) -> Option<u32> {
        if self.value == 0 {
            None
        } else {
            Some(self.value.unsigned_abs().trailing_zeros())
        }
    }
}

/// The general FloatSD format of Fig. 2: an exponent field plus a list
/// of SD groups forming the mantissa. Group *i* (0 = most significant)
/// has its own width; the MSG's digit weights start at `2^(w0 - 1)` and
/// each subsequent group continues at the next lower binary positions.
///
/// `mantissa_value = Σ_i g_i · 2^(-offset_i)` where `offset_i` is the
/// number of digits in groups 0..i *below* the MSG's unit digit — i.e.
/// groups are laid out as contiguous binary digit positions, exactly
/// like Fig. 2's "eight three-digit groups".
#[derive(Clone, Debug)]
pub struct GenericFloatSd {
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Exponent bias.
    pub exp_bias: i32,
    /// Widths of the SD groups, most-significant first.
    pub group_widths: Vec<u32>,
}

impl GenericFloatSd {
    /// The Fig. 2 example: 8-bit exponent, eight 3-digit groups.
    pub fn fig2_example() -> Self {
        GenericFloatSd { exp_bits: 8, exp_bias: 127, group_widths: vec![3; 8] }
    }

    /// Mantissa value of a list of group values (`groups[i]` must be a
    /// legal value for width `group_widths[i]`). The MSG is interpreted
    /// with its least-significant digit at binary weight 2^0; each later
    /// group continues below it.
    pub fn mantissa_value(&self, groups: &[i32]) -> f64 {
        assert_eq!(groups.len(), self.group_widths.len());
        let mut weight_lsb = 0i32; // lsb position of current group, relative to MSG lsb = 0
        let mut acc = 0f64;
        for (i, (&g, &w)) in groups.iter().zip(&self.group_widths).enumerate() {
            if i > 0 {
                weight_lsb -= w as i32;
            }
            acc += g as f64 * 2f64.powi(weight_lsb);
        }
        acc
    }

    /// Full value given an exponent-field code and group values.
    pub fn value(&self, exp_code: u32, groups: &[i32]) -> f64 {
        assert!(exp_code < (1 << self.exp_bits));
        self.mantissa_value(groups) * 2f64.powi(exp_code as i32 - self.exp_bias)
    }

    /// Fig. 3's truncation: keep only the first `n` mantissa digit groups
    /// (for inference / backprop), zeroing the rest.
    pub fn truncate_groups(&self, groups: &[i32], n: usize) -> Vec<i32> {
        groups
            .iter()
            .enumerate()
            .map(|(i, &g)| if i < n { g } else { 0 })
            .collect()
    }

    /// Maximum number of partial products a multiply by this format can
    /// generate = number of groups (one non-zero digit each).
    pub fn max_partial_products(&self) -> usize {
        self.group_widths.len()
    }

    /// Enumerate every legal mantissa combination (careful: grows as
    /// Π(2w_i+1); fine for the small formats used in tests).
    pub fn enumerate_mantissas(&self) -> Vec<Vec<i32>> {
        let mut out: Vec<Vec<i32>> = vec![vec![]];
        for &w in &self.group_widths {
            let vals = group_values(w);
            let mut next = Vec::with_capacity(out.len() * vals.len());
            for prefix in &out {
                for &v in &vals {
                    let mut p = prefix.clone();
                    p.push(v);
                    next.push(p);
                }
            }
            out = next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_three_digit_group() {
        // Paper Table I: +4,+2,+1,0,-1,-2,-4.
        assert_eq!(group_values(3), vec![4, 2, 1, 0, -1, -2, -4]);
    }

    #[test]
    fn two_digit_group() {
        assert_eq!(group_values(2), vec![2, 1, 0, -1, -2]);
    }

    #[test]
    fn zero_probability_formula_matches_enumeration() {
        for k in 1..=8u32 {
            // Enumerate: each of the 2K+1 values, count zero digits of K.
            let vals = group_values(k);
            let total_digits = (vals.len() as u32 * k) as f64;
            let nonzero: u32 = vals.iter().map(|v| (*v != 0) as u32).sum();
            let zero_digits = total_digits - nonzero as f64;
            let p = zero_digits / total_digits;
            assert!(
                (p - zero_digit_probability(k)).abs() < 1e-12,
                "k={k}: {p} vs formula {}",
                zero_digit_probability(k)
            );
        }
        // The paper's headline number for K=3:
        assert!((zero_digit_probability(3) - 0.7142857).abs() < 1e-6);
        assert!(zero_digit_probability(3) > csd_zero_probability());
    }

    #[test]
    fn sd_group_validation() {
        assert!(SdGroup::new(3, 4).is_some());
        assert!(SdGroup::new(3, 3).is_none(), "3 needs two non-zero digits");
        assert!(SdGroup::new(3, 8).is_none(), "8 is outside a 3-digit group");
        assert!(SdGroup::new(3, -4).is_some());
        assert!(SdGroup::new(3, 0).is_some());
        assert_eq!(SdGroup::new(3, 4).unwrap().shift(), Some(2));
        assert_eq!(SdGroup::new(3, 0).unwrap().nonzero_digits(), 0);
    }

    #[test]
    fn fig2_format_shape() {
        let f = GenericFloatSd::fig2_example();
        assert_eq!(f.max_partial_products(), 8);
        // mantissa of [4,0,0,0,0,0,0,0] is 4.0
        let mut g = vec![0; 8];
        g[0] = 4;
        assert_eq!(f.mantissa_value(&g), 4.0);
        // second group's +2 sits 3 digits below the MSG lsb: 2 * 2^-3
        let mut g = vec![0; 8];
        g[1] = 2;
        assert_eq!(f.mantissa_value(&g), 0.25);
    }

    #[test]
    fn fig3_truncation() {
        let f = GenericFloatSd::fig2_example();
        let g = vec![4, 2, 1, -1, 2, -4, 1, 1];
        let t = f.truncate_groups(&g, 2);
        assert_eq!(t, vec![4, 2, 0, 0, 0, 0, 0, 0]);
        // Truncation error is bounded by the weight of group 2's position.
        let err = (f.mantissa_value(&g) - f.mantissa_value(&t)).abs();
        assert!(err <= 2f64.powi(-6) * 4.0 * 2.0);
    }

    #[test]
    fn floatsd8_mantissa_layout_matches_paper() {
        // FloatSD8 = 3-digit MSG + 2-digit second group: m = g0 + g1/4.
        let f = GenericFloatSd { exp_bits: 3, exp_bias: 7, group_widths: vec![3, 2] };
        assert_eq!(f.mantissa_value(&[1, 0]), 1.0);
        assert_eq!(f.mantissa_value(&[0, 1]), 0.25);
        assert_eq!(f.mantissa_value(&[0, 2]), 0.5);
        assert_eq!(f.mantissa_value(&[4, -2]), 3.5);
        // 35 combinations, 31 distinct (paper §III-A).
        let all = f.enumerate_mantissas();
        assert_eq!(all.len(), 35);
        let mut vals: Vec<f64> = all.iter().map(|g| f.mantissa_value(g)).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        assert_eq!(vals.len(), 31);
    }
}
