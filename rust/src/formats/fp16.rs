//! IEEE 754 binary16 ("FP16") implemented bit-exactly in software.
//!
//! The paper's central complexity claim (§IV-C) is that **FP16 addition
//! suffices for every accumulation** in LSTM training once weights are
//! FloatSD8 and activations/gradients are FP8. To honour that claim we
//! need an FP16 whose rounding we control exactly — the offline build
//! has no `half` crate, and hardware simulation needs the raw bits
//! anyway — so this is a from-scratch binary16:
//!
//! * 1 sign bit, 5 exponent bits (bias 15), 10 mantissa bits
//! * subnormals, ±inf and NaN fully supported
//! * `f32 -> f16` uses round-to-nearest-even (RNE), matching both IEEE
//!   hardware and `numpy.float16`, which is what the JAX side uses —
//!   the golden-vector test pins the two together.

/// An IEEE binary16 value stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Fp16(pub u16);

const F16_SIGN: u16 = 0x8000;
const F16_EXP_MASK: u16 = 0x7c00;
const F16_MAN_MASK: u16 = 0x03ff;

impl Fp16 {
    pub const ZERO: Fp16 = Fp16(0);
    pub const ONE: Fp16 = Fp16(0x3c00);
    pub const INFINITY: Fp16 = Fp16(0x7c00);
    pub const NEG_INFINITY: Fp16 = Fp16(0xfc00);
    /// Largest finite value, 65504.
    pub const MAX: Fp16 = Fp16(0x7bff);
    /// Smallest positive normal, 2^-14.
    pub const MIN_POSITIVE: Fp16 = Fp16(0x0400);
    /// Smallest positive subnormal, 2^-24.
    pub const MIN_SUBNORMAL: Fp16 = Fp16(0x0001);

    /// Construct from raw bits.
    #[inline]
    pub const fn from_bits(bits: u16) -> Self {
        Fp16(bits)
    }

    /// Raw bit pattern.
    #[inline]
    pub const fn to_bits(self) -> u16 {
        self.0
    }

    /// Convert an `f32` to binary16 with round-to-nearest-even.
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp32 = ((bits >> 23) & 0xff) as i32;
        let man32 = bits & 0x007f_ffff;

        // Inf / NaN propagate; NaN keeps a payload bit so it stays NaN.
        if exp32 == 0xff {
            let nan_payload = if man32 != 0 { 0x0200 } else { 0 };
            return Fp16(sign | F16_EXP_MASK | nan_payload);
        }

        // Re-bias: f32 exponent-127 == f16 exponent-15.
        let exp = exp32 - 127 + 15;

        if exp >= 0x1f {
            // Overflow -> infinity (IEEE RNE semantics).
            return Fp16(sign | F16_EXP_MASK);
        }

        if exp <= 0 {
            // Result is subnormal (or rounds up into the smallest normal).
            if exp < -10 {
                // Below half of the smallest subnormal: rounds to zero.
                // (exp == -10 is exactly 2^-25 * 1.m which can round up.)
                return Fp16(sign);
            }
            // f32 subnormal inputs are < 2^-126, far below f16 range; the
            // implicit bit is only valid for normals. exp32 == 0 implies
            // exp == -112 which was caught above, so `man` is normal here.
            let man = man32 | 0x0080_0000; // make implicit bit explicit
            let shift = (14 - exp) as u32; // 14..=24
            let man16 = (man >> shift) as u16;
            let rem = man & ((1u32 << shift) - 1);
            let half = 1u32 << (shift - 1);
            let mut h = sign | man16;
            if rem > half || (rem == half && (man16 & 1) == 1) {
                h += 1; // may carry into the exponent: 0x03ff+1 = 0x0400, correct
            }
            return Fp16(h);
        }

        // Normal: keep top 10 of 23 mantissa bits, RNE on the rest.
        let man16 = (man32 >> 13) as u16;
        let rem = man32 & 0x1fff;
        let mut h = sign | ((exp as u16) << 10) | man16;
        if rem > 0x1000 || (rem == 0x1000 && (man16 & 1) == 1) {
            // Carry propagation into the exponent (and potentially up to
            // infinity at 0x7c00) is exactly what IEEE wants.
            h += 1;
        }
        Fp16(h)
    }

    /// Correctly-rounded conversion from `f64` (single RNE rounding).
    ///
    /// `Fp16::from_f32(x as f32)` double-rounds (f64→f32 RNE, then
    /// f32→f16 RNE) which can differ from the correctly-rounded result
    /// exactly at f16 tie points. The MAC's contract is *exact sum,
    /// round once* (Fig. 8's Wallace tree + single round stage), so we
    /// go through a round-to-odd f32 intermediate: with 13 extra
    /// mantissa bits, RNE(odd-rounded x) == RNE(x) — the classic
    /// double-rounding fix.
    pub fn from_f64(x: f64) -> Self {
        let y = x as f32; // RNE
        if y as f64 == x || !y.is_finite() {
            return Fp16::from_f32(y);
        }
        let odd = if y.to_bits() & 1 == 1 {
            y
        } else if (y as f64) < x {
            y.next_up()
        } else {
            y.next_down()
        };
        Fp16::from_f32(odd)
    }

    /// Convert to `f32` (exact — every binary16 value is representable).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & F16_SIGN) as u32) << 16;
        let exp = ((self.0 & F16_EXP_MASK) >> 10) as u32;
        let man = (self.0 & F16_MAN_MASK) as u32;
        let bits = match exp {
            0 => {
                if man == 0 {
                    sign // +-0
                } else {
                    // Subnormal: value = man * 2^-24 (exact in f32; the
                    // multiply is a power-of-two scale of an integer).
                    let v = man as f32 * 2f32.powi(-24);
                    return f32::from_bits(sign | v.to_bits());
                }
            }
            0x1f => sign | 0x7f80_0000 | (man << 13), // inf / nan
            _ => sign | ((exp + 127 - 15) << 23) | (man << 13),
        };
        f32::from_bits(bits)
    }

    /// True if the value is NaN.
    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & F16_EXP_MASK) == F16_EXP_MASK && (self.0 & F16_MAN_MASK) != 0
    }

    /// True if the value is +inf or -inf.
    #[inline]
    pub fn is_infinite(self) -> bool {
        (self.0 & !F16_SIGN) == F16_EXP_MASK
    }

    /// True for zero or subnormal.
    #[inline]
    pub fn is_subnormal_or_zero(self) -> bool {
        (self.0 & F16_EXP_MASK) == 0
    }

    /// FP16 addition: performed in f32 and rounded back to the f16 grid.
    ///
    /// A single f32 add of two f16 operands is exact (f32 has enough
    /// mantissa for any aligned sum of two 11-bit mantissas), so
    /// `round(f32-add)` is bit-identical to a native IEEE f16 adder with
    /// RNE — this is the paper's FP16 accumulator.
    #[inline]
    pub fn add(self, other: Fp16) -> Fp16 {
        Fp16::from_f32(self.to_f32() + other.to_f32())
    }

    /// FP16 multiplication (same exactness argument as [`Fp16::add`]).
    #[inline]
    pub fn mul(self, other: Fp16) -> Fp16 {
        Fp16::from_f32(self.to_f32() * other.to_f32())
    }
}

impl std::fmt::Display for Fp16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

impl From<f32> for Fp16 {
    fn from(x: f32) -> Self {
        Fp16::from_f32(x)
    }
}

impl From<Fp16> for f32 {
    fn from(h: Fp16) -> f32 {
        h.to_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference f16->f32 decode, independent arithmetic (no bit tricks).
    fn decode_ref(bits: u16) -> f32 {
        let sign = if bits & 0x8000 != 0 { -1.0f64 } else { 1.0 };
        let exp = ((bits >> 10) & 0x1f) as i32;
        let man = (bits & 0x3ff) as f64;
        let v = match exp {
            0 => sign * man * 2f64.powi(-24),
            0x1f => {
                if man == 0.0 {
                    sign * f64::INFINITY
                } else {
                    f64::NAN
                }
            }
            _ => sign * (1.0 + man / 1024.0) * 2f64.powi(exp - 15),
        };
        v as f32
    }

    #[test]
    fn decode_matches_reference_for_all_65536_codes() {
        for bits in 0..=u16::MAX {
            let got = Fp16(bits).to_f32();
            let want = decode_ref(bits);
            if want.is_nan() {
                assert!(got.is_nan(), "bits {bits:#06x}: want NaN got {got}");
            } else {
                assert_eq!(got, want, "bits {bits:#06x}");
            }
        }
    }

    #[test]
    fn round_trip_all_finite_codes() {
        for bits in 0..=u16::MAX {
            let h = Fp16(bits);
            if h.is_nan() {
                continue;
            }
            let back = Fp16::from_f32(h.to_f32());
            assert_eq!(back.0, h.0, "bits {bits:#06x} -> {} -> {:#06x}", h.to_f32(), back.0);
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(Fp16::from_f32(1.0).0, 0x3c00);
        assert_eq!(Fp16::from_f32(-2.0).0, 0xc000);
        assert_eq!(Fp16::from_f32(65504.0).0, 0x7bff);
        assert_eq!(Fp16::from_f32(65536.0).0, 0x7c00); // overflow -> inf
        assert_eq!(Fp16::from_f32(2f32.powi(-24)).0, 0x0001); // min subnormal
        assert_eq!(Fp16::from_f32(2f32.powi(-14)).0, 0x0400); // min normal
        assert_eq!(Fp16::from_f32(0.0).0, 0x0000);
        assert_eq!(Fp16::from_f32(-0.0).0, 0x8000);
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 (even mantissa) and
        // 1.0009765625; RNE keeps the even one.
        let tie = 1.0 + 2f32.powi(-11);
        assert_eq!(Fp16::from_f32(tie).0, 0x3c00);
        // Next tie up: 1 + 3*2^-11 is halfway between man=1 and man=2 -> man=2.
        let tie2 = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(Fp16::from_f32(tie2).0, 0x3c02);
    }

    #[test]
    fn rounding_is_nearest() {
        // For a dense sweep, from_f32(x) must be one of the two codes
        // bracketing x and the nearer one when not a tie.
        let mut prev = f32::NEG_INFINITY;
        for bits in 0..0x7c00u16 {
            let v = Fp16(bits).to_f32();
            assert!(v > prev, "f16 grid must be strictly increasing on positives");
            prev = v;
        }
        for i in 0..10_000 {
            let x = (i as f32 - 5000.0) / 77.3;
            let q = Fp16::from_f32(x).to_f32();
            // distance to q must be <= distance to q's neighbours
            let qb = Fp16::from_f32(x).0;
            for nb in [qb.wrapping_sub(1), qb.wrapping_add(1)] {
                let h = Fp16(nb);
                if h.is_nan() || h.is_infinite() {
                    continue;
                }
                // skip sign-boundary artifacts
                if (nb & 0x8000) != (qb & 0x8000) {
                    continue;
                }
                assert!(
                    (x - q).abs() <= (x - h.to_f32()).abs() + 1e-12,
                    "x={x}: chose {q} but {} is closer",
                    h.to_f32()
                );
            }
        }
    }

    #[test]
    fn subnormal_rounding_boundary() {
        // 2^-25 is exactly half of the min subnormal; ties-to-even -> 0.
        assert_eq!(Fp16::from_f32(2f32.powi(-25)).0, 0x0000);
        // Slightly above rounds up to the min subnormal.
        assert_eq!(Fp16::from_f32(2f32.powi(-25) * 1.001).0, 0x0001);
        // 3*2^-25 is a tie between subnormal 1 and 2 -> even (2).
        assert_eq!(Fp16::from_f32(3.0 * 2f32.powi(-25)).0, 0x0002);
    }

    #[test]
    fn add_is_fp16_grid_exact() {
        let a = Fp16::from_f32(1.0);
        let b = Fp16::from_f32(2f32.powi(-11)); // below 1 ulp of 1.0
        // 1.0 + 2^-11 ties back to 1.0 on the grid.
        assert_eq!(a.add(b).0, a.0);
        assert_eq!(Fp16::from_f32(1.5).add(Fp16::from_f32(2.5)).to_f32(), 4.0);
    }
}
