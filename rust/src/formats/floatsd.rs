//! **FloatSD8** — the paper's 8-bit weight format (§III-A).
//!
//! Layout: 3-bit exponent field + a 5-bit code for the two SD groups:
//!
//! * MSG: 3-digit SD group, values `{+4, +2, +1, 0, −1, −2, −4}`
//!   (digit weights 4/2/1, at most one non-zero digit — Table I);
//! * second group: 2-digit SD group, values `{+2, +1, 0, −1, −2}`
//!   (digit weights continue below the MSG, so its group value is scaled
//!   by 1/4).
//!
//! Mantissa `m = g0 + g1/4` ⇒ 7×5 = 35 combinations of which **31 are
//! distinct** (±0.5 and ±1.5 are each expressible two ways), so 5 bits
//! suffice. Value `v = m · 2^(e − 7)` (the 3-bit exponent is biased by 7
//! — the paper leaves the bias unspecified; 7 covers both weight
//! initialisation ranges and the σ-output range `(0, 0.5]` used by the
//! two-region sigmoid quantizer, and reproduces the paper's "42 LUT
//! entries" count — verified in `qmath::qsigmoid` tests).
//!
//! The canonical 8-bit code is `eee r rrrr` = `exp << 5 | rank`, where
//! `rank ∈ 0..31` indexes the ascending mantissa codebook (rank 15 = 0).
//!
//! A FloatSD8 weight generates **at most two partial products**
//! ([`FloatSdFormat::partial_products`]) — each a signed power of two —
//! which is the entire hardware story of §V.

use std::sync::OnceLock;

/// Exponent bias used by this implementation (see module docs).
pub const SD8_EXP_BIAS: i32 = 7;
/// Exponent field width.
pub const SD8_EXP_BITS: u32 = 3;
/// Number of distinct mantissa values.
pub const SD8_MANTISSA_COUNT: usize = 31;

/// A FloatSD8 value stored as its canonical 8-bit code.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct FloatSd8(pub u8);

/// Up to two signed power-of-two partial products: `(sign, exponent)`
/// meaning `sign * 2^exponent`. This is what the hardware multiplier
/// consumes (Fig. 8's partial product generator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartialProducts {
    pub terms: [(i8, i32); 2],
    pub len: u8,
}

impl PartialProducts {
    /// Evaluate the decomposition back to f32 (test/debug helper).
    pub fn value(&self) -> f32 {
        self.iter().map(|(s, e)| s as f32 * 2f32.powi(e)).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (i8, i32)> + '_ {
        self.terms.iter().copied().take(self.len as usize)
    }
}

/// A FloatSD format instance: codebooks, full value grid, quantizer.
///
/// Built once (see [`FLOAT_SD8`]); all lookups afterwards are allocation-
/// free. The same tables are exported to JAX via the golden-vector
/// artifacts so both sides share one grid.
#[derive(Debug)]
pub struct FloatSdFormat {
    pub exp_bits: u32,
    pub exp_bias: i32,
    /// The 31 distinct mantissa values, ascending (index = rank).
    mantissa: Vec<f32>,
    /// Canonical `(g0, g1)` group decomposition per rank (fewest non-zero
    /// digits wins ties, then larger `g0`).
    groups: Vec<(i8, i8)>,
    /// Every distinct representable value, ascending.
    values: Vec<f32>,
    /// Midpoints between consecutive `values` (len = values.len() - 1).
    midpoints: Vec<f32>,
    /// Canonical code for each entry of `values`.
    codes: Vec<u8>,
}

impl FloatSdFormat {
    /// Build the FloatSD8 format (3-bit exponent, 3+2-digit groups).
    pub fn new_sd8() -> Self {
        // --- mantissa codebook -------------------------------------------------
        let g0s: [i8; 7] = [-4, -2, -1, 0, 1, 2, 4];
        let g1s: [i8; 5] = [-2, -1, 0, 1, 2];
        // value-in-quarters -> best (g0, g1)
        let mut best: std::collections::BTreeMap<i32, (i8, i8)> = Default::default();
        for &g0 in &g0s {
            for &g1 in &g1s {
                let q = g0 as i32 * 4 + g1 as i32; // mantissa in units of 1/4
                let cand = (g0, g1);
                let cost = |(a, b): (i8, i8)| (a != 0) as u32 * 1 + (b != 0) as u32;
                match best.get(&q) {
                    Some(&cur) if cost(cur) < cost(cand) => {}
                    Some(&cur) if cost(cur) == cost(cand) && cur.0.abs() >= cand.0.abs() => {}
                    _ => {
                        best.insert(q, cand);
                    }
                }
            }
        }
        assert_eq!(best.len(), SD8_MANTISSA_COUNT);
        let mantissa: Vec<f32> = best.keys().map(|&q| q as f32 / 4.0).collect();
        let groups: Vec<(i8, i8)> = best.values().copied().collect();

        // --- full value grid ---------------------------------------------------
        // code -> value for all (exp, rank); dedup to distinct values while
        // remembering a canonical code (prefer the largest-mantissa
        // representation, i.e. the smallest exponent, like a normalized
        // hardware encoding).
        let mut pairs: Vec<(f32, u8)> = Vec::new();
        for e in 0..(1u8 << SD8_EXP_BITS) {
            for (rank, &m) in mantissa.iter().enumerate() {
                let v = m * 2f32.powi(e as i32 - SD8_EXP_BIAS);
                pairs.push((v, (e << 5) | rank as u8));
            }
        }
        pairs.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                // canonical tie-break: smaller exponent field first
                .then((a.1 >> 5).cmp(&(b.1 >> 5)))
        });
        let mut values: Vec<f32> = Vec::new();
        let mut codes: Vec<u8> = Vec::new();
        for (v, c) in pairs {
            if values.last().map_or(true, |&last| v != last) {
                values.push(v);
                codes.push(c);
            }
        }
        // canonical zero: exp 0, rank of 0
        let zero_rank = mantissa.iter().position(|&m| m == 0.0).unwrap() as u8;
        let zi = values.iter().position(|&v| v == 0.0).unwrap();
        codes[zi] = zero_rank;

        let midpoints: Vec<f32> =
            values.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();

        FloatSdFormat {
            exp_bits: SD8_EXP_BITS,
            exp_bias: SD8_EXP_BIAS,
            mantissa,
            groups,
            values,
            midpoints,
            codes,
        }
    }

    /// The 31 mantissa values, ascending.
    pub fn mantissa_codebook(&self) -> &[f32] {
        &self.mantissa
    }

    /// All distinct representable values, ascending.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Largest representable magnitude (= 4.5 · 2^(7−bias) = 4.5).
    pub fn max_value(&self) -> f32 {
        *self.values.last().unwrap()
    }

    /// Smallest positive representable value (= 0.25 · 2^(−bias)).
    pub fn min_positive(&self) -> f32 {
        let zi = self.values.iter().position(|&v| v == 0.0).unwrap();
        self.values[zi + 1]
    }

    /// Round `x` to the nearest representable value. Ties round **away
    /// from zero** (the hardware compares against midpoints and takes the
    /// upper bucket, mirrored for negatives). Saturates at ±max; NaN → 0.
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        self.values[self.quantize_index(x)]
    }

    /// Index into [`Self::values`] of the quantization of `x`.
    #[inline]
    pub fn quantize_index(&self, x: f32) -> usize {
        if x.is_nan() {
            return self.values.iter().position(|&v| v == 0.0).unwrap();
        }
        if x >= 0.0 {
            self.midpoints.partition_point(|&m| m <= x)
        } else {
            self.midpoints.partition_point(|&m| m < x)
        }
    }

    /// Quantize and return the canonical 8-bit code.
    #[inline]
    pub fn encode(&self, x: f32) -> FloatSd8 {
        FloatSd8(self.codes[self.quantize_index(x)])
    }

    /// Decode an arbitrary (not necessarily canonical) 8-bit code.
    #[inline]
    pub fn decode(&self, code: FloatSd8) -> f32 {
        let (e, rank) = (code.0 >> 5, (code.0 & 0x1f) as usize);
        debug_assert!(rank < SD8_MANTISSA_COUNT, "rank {rank} out of range");
        let rank = rank.min(SD8_MANTISSA_COUNT - 1);
        self.mantissa[rank] * 2f32.powi(e as i32 - self.exp_bias)
    }

    /// The `(g0, g1)` SD-group decomposition of a code's mantissa.
    #[inline]
    pub fn to_groups(&self, code: FloatSd8) -> (i8, i8) {
        let rank = ((code.0 & 0x1f) as usize).min(SD8_MANTISSA_COUNT - 1);
        self.groups[rank]
    }

    /// Build a code from exponent field + group values (must be legal).
    pub fn from_groups(&self, exp: u8, g0: i8, g1: i8) -> Option<FloatSd8> {
        if exp >= (1 << self.exp_bits) {
            return None;
        }
        let m = g0 as f32 + g1 as f32 / 4.0;
        let rank = self.mantissa.iter().position(|&c| c == m)?;
        // validate group legality
        crate::formats::sd::SdGroup::new(3, g0 as i32)?;
        crate::formats::sd::SdGroup::new(2, g1 as i32)?;
        Some(FloatSd8((exp << 5) | rank as u8))
    }

    /// The ≤2 signed power-of-two partial products of a code — the whole
    /// point of the format: multiplying `x` by this weight is
    /// `Σ sign_i · (x << exp_i)`.
    pub fn partial_products(&self, code: FloatSd8) -> PartialProducts {
        let (g0, g1) = self.to_groups(code);
        let e = (code.0 >> 5) as i32 - self.exp_bias;
        let mut terms = [(0i8, 0i32); 2];
        let mut len = 0u8;
        if g0 != 0 {
            let shift = g0.unsigned_abs().trailing_zeros() as i32;
            terms[len as usize] = (g0.signum(), e + shift);
            len += 1;
        }
        if g1 != 0 {
            let shift = g1.unsigned_abs().trailing_zeros() as i32 - 2;
            terms[len as usize] = (g1.signum(), e + shift);
            len += 1;
        }
        PartialProducts { terms, len }
    }

    /// Number of distinct representable values (tests / docs).
    pub fn distinct_value_count(&self) -> usize {
        self.values.len()
    }

    /// The paper's §III-B weight-update rule under the modified (FP16
    /// master) scheme of §IV-C: the master copy absorbs the update with
    /// a single FP16 RNE rounding, and the working weight for the next
    /// iteration is the **nearest** FloatSD8 code of the new master.
    ///
    /// Returns `(new_master, code)`. The master is saturated at the
    /// largest finite FP16 magnitude so a runaway update can never
    /// poison it with ±inf (the loss scaler should already have skipped
    /// such a step — this is defense in depth). Both outputs are
    /// monotone in `update`: a positive update can never move either
    /// the master or the decoded weight down (pinned by the property
    /// tests in `tests/proptest_formats.rs`).
    #[inline]
    pub fn apply_update(&self, master: f32, update: f32) -> (f32, FloatSd8) {
        let mut m = crate::formats::round_f16(master + update);
        if m.is_infinite() {
            m = if m > 0.0 { 65504.0 } else { -65504.0 };
        }
        (m, self.encode(m))
    }

    /// Raw (biased) exponent field of a code — the top 3 bits, 0..=7;
    /// the bin index of telemetry's re-encode exponent histograms.
    #[inline]
    pub fn code_exponent(&self, code: FloatSd8) -> u8 {
        code.0 >> 5
    }

    /// Whether a code decodes to the format's extreme magnitude
    /// (±[`Self::max_value`]) — the saturation bin of the re-encode
    /// histograms: weights parked here can no longer grow.
    #[inline]
    pub fn is_max_magnitude(&self, code: FloatSd8) -> bool {
        self.decode(code).abs() == self.max_value()
    }
}

/// The process-wide FloatSD8 format instance.
pub static FLOAT_SD8_CELL: OnceLock<FloatSdFormat> = OnceLock::new();

/// Accessor struct so call-sites can write `FLOAT_SD8.quantize(x)`.
pub struct FloatSd8Handle;

impl std::ops::Deref for FloatSd8Handle {
    type Target = FloatSdFormat;
    fn deref(&self) -> &FloatSdFormat {
        FLOAT_SD8_CELL.get_or_init(FloatSdFormat::new_sd8)
    }
}

/// Global FloatSD8 format: `FLOAT_SD8.quantize(x)`, `FLOAT_SD8.encode(x)`…
pub static FLOAT_SD8: FloatSd8Handle = FloatSd8Handle;

impl FloatSd8 {
    /// Quantize an f32 to its canonical FloatSD8 code.
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        FLOAT_SD8.encode(x)
    }

    /// Decode to f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        FLOAT_SD8.decode(self)
    }

    /// Raw code.
    #[inline]
    pub const fn to_bits(self) -> u8 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fmt() -> &'static FloatSdFormat {
        FLOAT_SD8_CELL.get_or_init(FloatSdFormat::new_sd8)
    }

    #[test]
    fn mantissa_codebook_is_the_31_paper_values() {
        let f = fmt();
        let expected: Vec<f32> = vec![
            -4.5, -4.25, -4.0, -3.75, -3.5, -2.5, -2.25, -2.0, -1.75, -1.5, -1.25,
            -1.0, -0.75, -0.5, -0.25, 0.0, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75,
            2.0, 2.25, 2.5, 3.5, 3.75, 4.0, 4.25, 4.5,
        ];
        assert_eq!(f.mantissa_codebook(), expected.as_slice());
    }

    #[test]
    fn groups_are_legal_and_reconstruct_mantissa() {
        let f = fmt();
        for (rank, &m) in f.mantissa_codebook().iter().enumerate() {
            let (g0, g1) = f.groups[rank];
            assert!(crate::formats::sd::SdGroup::new(3, g0 as i32).is_some());
            assert!(crate::formats::sd::SdGroup::new(2, g1 as i32).is_some());
            assert_eq!(g0 as f32 + g1 as f32 / 4.0, m, "rank {rank}");
        }
    }

    #[test]
    fn duplicates_use_fewest_nonzero_digits() {
        let f = fmt();
        // 0.5 is representable as (0,+2) [1 digit] or (1,-2) [2 digits].
        let rank = f.mantissa_codebook().iter().position(|&m| m == 0.5).unwrap();
        assert_eq!(f.groups[rank], (0, 2));
    }

    #[test]
    fn range_constants() {
        let f = fmt();
        assert_eq!(f.max_value(), 4.5);
        assert_eq!(f.min_positive(), 0.25 * 2f32.powi(-7));
    }

    #[test]
    fn encode_decode_round_trip_on_grid() {
        let f = fmt();
        for &v in f.values() {
            let code = f.encode(v);
            assert_eq!(f.decode(code), v, "value {v}");
        }
    }

    #[test]
    fn every_code_decodes_into_grid() {
        let f = fmt();
        for e in 0..8u8 {
            for rank in 0..31u8 {
                let v = f.decode(FloatSd8((e << 5) | rank));
                assert!(
                    f.values().iter().any(|&g| g == v),
                    "code e={e} rank={rank} -> {v} not on grid"
                );
            }
        }
    }

    #[test]
    fn quantize_is_nearest_with_ties_away_from_zero() {
        let f = fmt();
        let vals = f.values();
        for i in 0..vals.len() - 1 {
            let (lo, hi) = (vals[i], vals[i + 1]);
            let mid = 0.5 * (lo + hi);
            // strictly inside each half
            let eps = (hi - lo) * 1e-3;
            assert_eq!(f.quantize(mid - eps), lo, "below midpoint of [{lo},{hi}]");
            assert_eq!(f.quantize(mid + eps), hi, "above midpoint of [{lo},{hi}]");
            // at the midpoint: away from zero
            let expect = if mid >= 0.0 { hi } else { lo };
            assert_eq!(f.quantize(mid), expect, "tie at {mid} in [{lo},{hi}]");
        }
    }

    #[test]
    fn quantize_saturates_and_handles_nan() {
        let f = fmt();
        assert_eq!(f.quantize(1e9), 4.5);
        assert_eq!(f.quantize(-1e9), -4.5);
        assert_eq!(f.quantize(f32::NAN), 0.0);
        assert_eq!(f.quantize(0.0), 0.0);
    }

    #[test]
    fn partial_products_reconstruct_every_value() {
        let f = fmt();
        for &v in f.values() {
            let code = f.encode(v);
            let pp = f.partial_products(code);
            assert!(pp.len <= 2, "more than two partial products for {v}");
            assert_eq!(pp.value(), v, "decomposition of {v}");
        }
    }

    #[test]
    fn zero_has_no_partial_products() {
        let f = fmt();
        let pp = f.partial_products(f.encode(0.0));
        assert_eq!(pp.len, 0);
    }

    #[test]
    fn distinct_value_count_is_stable() {
        // 31 mantissas x 8 exponents with power-of-two overlap chains.
        // This count is part of the format contract (the JAX side builds
        // the same grid); pin it.
        let f = fmt();
        // 31 mantissas x 8 exponents = 248 codes; power-of-two overlap
        // chains (e.g. 0.25·2^e = 0.5·2^(e-1) = 1·2^(e-2) …) collapse
        // them to 64 positive + 0 + 64 negative = 129 distinct values.
        assert_eq!(f.distinct_value_count(), 129);
    }

    #[test]
    fn apply_update_basics() {
        let f = fmt();
        // zero update: master unchanged, code is the nearest grid point
        let (m, code) = f.apply_update(0.3, 0.0);
        assert_eq!(m, crate::formats::round_f16(0.3));
        assert_eq!(f.decode(code), f.quantize(m));
        // a sub-grid-gap update still moves the FP16 master even when
        // the FloatSD8 code cannot move yet — the whole point of the
        // master-copy scheme (small updates accumulate across steps)
        let m0 = crate::formats::round_f16(1.0);
        let (m1, c1) = f.apply_update(m0, 2f32.powi(-9));
        assert!(m1 > m0, "master must accumulate sub-gap updates");
        assert_eq!(f.decode(c1), 1.0, "decoded weight unmoved by a tiny update");
        // saturation instead of inf
        let (m2, c2) = f.apply_update(65504.0, 1e9);
        assert_eq!(m2, 65504.0);
        assert_eq!(f.decode(c2), f.max_value());
    }

    #[test]
    fn quantize_idempotent() {
        let f = fmt();
        for i in 0..5000 {
            let x = (i as f32 - 2500.0) / 300.0;
            let q = f.quantize(x);
            assert_eq!(f.quantize(q), q, "x={x}");
        }
    }
}
