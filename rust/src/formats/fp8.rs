//! FP8 (1-5-2) — the 8-bit floating-point format of Wang et al.
//! (NeurIPS 2018), used by the paper for **gradients and activations**
//! (§III-D, Table II): 1 sign bit, 5 exponent bits (bias 15), 2 mantissa
//! bits.
//!
//! Semantics implemented here (and mirrored in
//! `python/compile/kernels/quant.py`):
//!
//! * subnormals supported (min positive = 2^-16);
//! * round-to-nearest-even from f32;
//! * **saturating**: values beyond ±max-normal (±1.75·2^16 = ±114688)
//!   clamp to ±max instead of producing infinity — there is no inf/NaN
//!   encoding at runtime (QPyTorch's `float_quantize(..., rounding=
//!   "nearest")` behaves the same way); NaN inputs map to +max to keep
//!   training numerics observable rather than poisoning silently.
//!
//! The exponent range is deliberately wide (2^-16..2^16): the paper
//! relies on this plus ×1024 loss scaling to keep backward activations
//! representable (§IV-A).

/// An FP8 (1-5-2) value stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Fp8(pub u8);

const F8_SIGN: u8 = 0x80;
const F8_EXP_MASK: u8 = 0x7c;
const F8_MAN_MASK: u8 = 0x03;
/// Exponent bias.
pub const F8_BIAS: i32 = 15;
/// Largest finite magnitude: (1 + 3/4) * 2^(31-15) = 114688.
pub const F8_MAX: f32 = 1.75 * 65536.0;
/// Smallest positive normal: 2^(1-15) = 2^-14.
pub const F8_MIN_NORMAL: f32 = 6.103515625e-5;
/// Smallest positive subnormal: 0.25 * 2^-14 = 2^-16.
pub const F8_MIN_SUBNORMAL: f32 = 1.52587890625e-5;

impl Fp8 {
    pub const ZERO: Fp8 = Fp8(0);
    pub const ONE: Fp8 = Fp8(0x3c); // exp=15, man=0
    pub const MAX: Fp8 = Fp8(0x7f);
    pub const MIN: Fp8 = Fp8(0xff);

    /// Construct from raw bits.
    #[inline]
    pub const fn from_bits(bits: u8) -> Self {
        Fp8(bits)
    }

    /// Raw bit pattern.
    #[inline]
    pub const fn to_bits(self) -> u8 {
        self.0
    }

    /// Quantize an `f32` to FP8 with RNE + saturation.
    pub fn from_f32(x: f32) -> Self {
        if x.is_nan() {
            return Fp8::MAX;
        }
        let sign = if x.is_sign_negative() { F8_SIGN } else { 0 };
        let a = x.abs();
        if a >= F8_MAX {
            // Saturate. Note IEEE RNE would overflow to inf only above
            // (max + 1/2 ulp); the paper's hardware clamps, so we clamp
            // everywhere above max for monotonicity.
            return Fp8(sign | 0x7f);
        }
        if a == 0.0 {
            return Fp8(sign); // signed zero
        }

        let bits = a.to_bits();
        let exp32 = ((bits >> 23) & 0xff) as i32;
        let man32 = bits & 0x007f_ffff;
        // Unbiased exponent of `a` (a is normal in f32: anything subnormal
        // in f32 is < 2^-126, far below half of F8_MIN_SUBNORMAL -> 0).
        if exp32 == 0 {
            return Fp8(sign);
        }
        let e = exp32 - 127;
        let e8 = e + F8_BIAS;

        if e8 >= 1 {
            // Normal range: round 23-bit mantissa to 2 bits.
            let man8 = (man32 >> 21) as u8;
            let rem = man32 & 0x1f_ffff;
            let half = 0x10_0000;
            let mut code = sign | ((e8 as u8) << 2) | man8;
            if rem > half || (rem == half && (man8 & 1) == 1) {
                // Carry may bump the exponent; if it overflows past
                // exp=31 man=3 it would wrap into sign — but that can only
                // happen from a >= F8_MAX which we already clamped...
                // except for the last half-ulp below max; guard anyway.
                if (code & !F8_SIGN) == 0x7f {
                    return Fp8(sign | 0x7f);
                }
                code += 1;
            }
            return Fp8(code);
        }

        // Subnormal result: value = man8 * 2^-16, man8 in 0..=3.
        // Compute round(a / 2^-16) with RNE.
        let scaled = a * 65536.0; // exact (power-of-two scale)
        let floor = scaled.floor();
        let frac = scaled - floor;
        let mut man8 = floor as u32;
        if frac > 0.5 || (frac == 0.5 && man8 & 1 == 1) {
            man8 += 1;
        }
        if man8 >= 4 {
            // Rounded up into the smallest normal.
            return Fp8(sign | (1 << 2));
        }
        Fp8(sign | man8 as u8)
    }

    /// Exact conversion to `f32`.
    pub fn to_f32(self) -> f32 {
        let sign = if self.0 & F8_SIGN != 0 { -1.0f32 } else { 1.0 };
        let exp = ((self.0 & F8_EXP_MASK) >> 2) as i32;
        let man = (self.0 & F8_MAN_MASK) as f32;
        if exp == 0 {
            sign * man * 2f32.powi(-16) // subnormal (or zero)
        } else {
            sign * (1.0 + man / 4.0) * 2f32.powi(exp - F8_BIAS)
        }
    }

    /// All 256 representable values (including -0), ascending by code
    /// within each sign. Useful for exhaustive tests and LUT builds.
    pub fn all_values() -> Vec<f32> {
        (0..=u8::MAX).map(|b| Fp8(b).to_f32()).collect()
    }
}

impl std::fmt::Display for Fp8 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f32())
    }
}

impl From<f32> for Fp8 {
    fn from(x: f32) -> Self {
        Fp8::from_f32(x)
    }
}

impl From<Fp8> for f32 {
    fn from(v: Fp8) -> f32 {
        v.to_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_256_codes() {
        for b in 0..=u8::MAX {
            let v = Fp8(b).to_f32();
            let back = Fp8::from_f32(v);
            // -0 and +0 collapse is acceptable only sign-preserved:
            assert_eq!(back.0, b, "code {b:#04x} -> {v} -> {:#04x}", back.0);
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(Fp8::from_f32(1.0).0, 0x3c);
        assert_eq!(Fp8(0x3c).to_f32(), 1.0);
        assert_eq!(Fp8::from_f32(1.25).0, 0x3d);
        assert_eq!(Fp8::from_f32(1.75).0, 0x3f);
        assert_eq!(Fp8::from_f32(F8_MAX).0, 0x7f);
        assert_eq!(Fp8::from_f32(1e9).0, 0x7f, "saturation");
        assert_eq!(Fp8::from_f32(-1e9).0, 0xff, "saturation");
        assert_eq!(Fp8::from_f32(2f32.powi(-16)).0, 0x01, "min subnormal");
        assert_eq!(Fp8::from_f32(2f32.powi(-14)).0, 0x04, "min normal");
        assert_eq!(Fp8::from_f32(0.0).0, 0x00);
    }

    #[test]
    fn grid_is_monotonic() {
        // Positive codes 0..0x7f decode to strictly increasing values.
        let mut prev = -1.0f32;
        for b in 0..=0x7fu8 {
            let v = Fp8(b).to_f32();
            assert!(v > prev, "code {b:#04x}: {v} <= {prev}");
            prev = v;
        }
    }

    #[test]
    fn quantize_is_nearest() {
        let grid: Vec<f32> = (0..=0x7fu8).map(|b| Fp8(b).to_f32()).collect();
        for i in 0..20_000 {
            let x = (i as f32 / 20_000.0 - 0.5) * 300_000.0;
            let q = Fp8::from_f32(x).to_f32();
            let best = grid
                .iter()
                .map(|g| (x.abs() - g).abs())
                .fold(f32::INFINITY, f32::min);
            assert!(
                ((x.abs() - q.abs()).abs() - best).abs() <= best * 1e-6 + 1e-12,
                "x={x} q={q} best-dist={best}"
            );
        }
    }

    #[test]
    fn rne_tie_behavior() {
        // Halfway between 1.0 (code 0x3c, even) and 1.25 (0x3d) is 1.125:
        assert_eq!(Fp8::from_f32(1.125).0, 0x3c, "tie to even (down)");
        // Halfway between 1.25 (0x3d, odd) and 1.5 (0x3e): 1.375 -> up to even.
        assert_eq!(Fp8::from_f32(1.375).0, 0x3e, "tie to even (up)");
    }

    #[test]
    fn subnormal_ties() {
        let ulp = 2f32.powi(-16);
        assert_eq!(Fp8::from_f32(0.5 * ulp).0, 0x00, "tie to even at 0");
        assert_eq!(Fp8::from_f32(1.5 * ulp).0, 0x02, "tie to even at 2");
        assert_eq!(Fp8::from_f32(3.5 * ulp).0, 0x04, "tie rounds into min normal");
    }

    #[test]
    fn nan_maps_to_max() {
        assert_eq!(Fp8::from_f32(f32::NAN).0, 0x7f);
    }
}
