//! proptest-lite: a tiny property-testing harness (no proptest crate
//! offline). Deterministic generator streams + a fixed trial budget;
//! on failure it reports the seed so the case replays exactly.
//!
//! ```
//! use floatsd_lstm::testing::{property, Gen};
//! property("abs is nonneg", 1000, |g: &mut Gen| {
//!     let x = g.f32_range(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0, "x={x}");
//! });
//! ```

use crate::rng::SplitMix64;

/// Value generator handed to each property trial.
pub struct Gen {
    rng: SplitMix64,
    /// seed of this trial (printed on failure)
    pub seed: u64,
}

impl Gen {
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// Log-uniform magnitude with random sign — good coverage of float
    /// grids across binades.
    pub fn f32_log(&mut self, min_exp: i32, max_exp: i32) -> f32 {
        let e = self.rng.uniform(min_exp as f32, max_exp as f32);
        let m = self.rng.uniform(1.0, 2.0);
        let s = if self.rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        s * m * (e as f64).exp2() as f32
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.rng.next_below(n as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_range(lo, hi)).collect()
    }
}

/// Run `trials` deterministic trials of `prop`. Panics (with the trial
/// seed) on the first failing case. Override the base seed with
/// `FSD_PROPTEST_SEED` to replay a reported failure.
pub fn property<F: Fn(&mut Gen)>(name: &str, trials: u64, prop: F) {
    let base: u64 = std::env::var("FSD_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF0A7_5D81);
    for t in 0..trials {
        let seed = base.wrapping_add(t.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen { rng: SplitMix64::new(seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at trial {t} (replay with FSD_PROPTEST_SEED={seed} and trials=1)"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_trials() {
        let count = std::cell::Cell::new(0u64);
        property("count", 50, |_g| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic]
    fn property_reports_failures() {
        property("fail", 10, |g| {
            let x = g.f32_range(0.0, 1.0);
            assert!(x < 0.0, "x={x}");
        });
    }

    #[test]
    fn generators_in_bounds() {
        property("bounds", 200, |g| {
            let v = g.f32_range(-3.0, 5.0);
            assert!((-3.0..=5.0).contains(&v));
            let u = g.usize_below(17);
            assert!(u < 17);
            let l = g.f32_log(-10, 10).abs();
            assert!(l == 0.0 || (2f32.powi(-11)..2f32.powi(12)).contains(&l));
        });
    }
}
