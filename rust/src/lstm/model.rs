//! Model-level blocks of the inference engine: embedding, LSTM layers
//! (uni/bidirectional), dense heads, and a stack container that loads
//! weights from `.tensors` files (JAX pytree leaves written by aot.py
//! or checkpoints written by the coordinator).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::formats::{round_f16, round_f8};
use crate::qmath::vector::{matvec_fast, QMatrix};
use crate::tensorfile::Tensor;

use super::cell::{CellScratch, QLstmCell};

/// Embedding table (kept in f32; its *outputs* are the paper's
/// first-layer activations and are FP8-quantized here).
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
    pub table: Vec<f32>,
}

impl Embedding {
    pub fn lookup_fp8(&self, id: usize, out: &mut [f32]) {
        assert!(id < self.vocab, "token id {id} out of range {}", self.vocab);
        let row = &self.table[id * self.dim..(id + 1) * self.dim];
        for (o, &v) in out.iter_mut().zip(row) {
            *o = round_f8(v);
        }
    }
}

/// Dense layer with FloatSD8 weights (out = W·x + b, FP16-chained).
pub struct Dense {
    pub w: QMatrix, // rows = out, cols = in
    pub bias: Vec<f32>,
}

impl Dense {
    /// From JAX layout `w [in][out]` row-major.
    pub fn from_jax_layout(in_dim: usize, out_dim: usize, w: &[f32], b: &[f32]) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        let mut t = vec![0f32; w.len()];
        for r in 0..in_dim {
            for c in 0..out_dim {
                t[c * in_dim + r] = w[r * out_dim + c];
            }
        }
        Dense {
            w: QMatrix::from_f32(out_dim, in_dim, &t),
            bias: b.iter().map(|&x| round_f16(x)).collect(),
        }
    }

    /// `x` must be on the FP8 grid already.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        matvec_fast(&self.w, x, &self.bias, out);
    }
}

/// One (optionally bidirectional) quantized LSTM layer.
pub struct QLstmLayer {
    pub fwd: QLstmCell,
    pub bwd: Option<QLstmCell>,
}

impl QLstmLayer {
    pub fn out_dim(&self) -> usize {
        self.fwd.hidden * if self.bwd.is_some() { 2 } else { 1 }
    }

    /// Run over a sequence `xs [T][D]` (FP8 grid), producing `[T][out]`
    /// FP8 hidden activations (inter-layer activation quantization).
    pub fn forward(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let t_len = xs.len();
        let hdim = self.fwd.hidden;
        let odim = self.out_dim();
        let mut out = vec![vec![0f32; odim]; t_len];

        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scratch = CellScratch::new(hdim);
        for (t, x) in xs.iter().enumerate() {
            self.fwd.step(x, &mut h, &mut c, &mut scratch);
            out[t][..hdim].copy_from_slice(&h);
        }
        if let Some(bwd) = &self.bwd {
            let mut h = vec![0f32; hdim];
            let mut c = vec![0f32; hdim];
            let mut scratch = CellScratch::new(hdim);
            for (t, x) in xs.iter().enumerate().rev() {
                bwd.step(x, &mut h, &mut c, &mut scratch);
                out[t][hdim..].copy_from_slice(&h);
            }
        }
        out
    }
}

/// A named-parameter view over a `.tensors` file for model assembly.
pub struct ParamBag {
    tensors: HashMap<String, Tensor>,
}

impl ParamBag {
    pub fn from_tensors(tensors: Vec<Tensor>) -> Self {
        ParamBag { tensors: tensors.into_iter().map(|t| (t.name.clone(), t)).collect() }
    }

    /// Fetch an f32 tensor by trying several name spellings (JAX
    /// keystr paths look like `['params']['l1']['wx']`).
    pub fn f32(&self, keys: &[&str]) -> Result<(Vec<usize>, Vec<f32>)> {
        for k in keys {
            if let Some(t) = self.tensors.get(*k) {
                let data = t.as_f32().context("dtype")?;
                return Ok((t.shape.clone(), data));
            }
        }
        bail!(
            "none of {keys:?} found; have: {:?}",
            self.tensors.keys().take(8).collect::<Vec<_>>()
        )
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }
}

/// A generic quantized stack: embedding → LSTM layers → dense head.
/// Covers the pos/lm/tiny topologies (the examples and benches build
/// the nli/mt variants from the same blocks).
pub struct QLstmStack {
    pub embed: Embedding,
    pub layers: Vec<QLstmLayer>,
    pub head: Dense,
}

impl QLstmStack {
    /// Forward one token sequence → per-step logits `[T][n_out]`.
    pub fn forward(&self, ids: &[usize]) -> Vec<Vec<f32>> {
        let mut xs: Vec<Vec<f32>> = ids
            .iter()
            .map(|&id| {
                let mut e = vec![0f32; self.embed.dim];
                self.embed.lookup_fp8(id, &mut e);
                e
            })
            .collect();
        for layer in &self.layers {
            xs = layer.forward(&xs);
        }
        let n_out = self.head.w.rows;
        xs.iter()
            .map(|h| {
                let mut y = vec![0f32; n_out];
                self.head.forward(h, &mut y);
                y
            })
            .collect()
    }

    /// Total weight storage in bytes with FloatSD8 packing (the paper's
    /// memory-footprint argument) vs FP32.
    pub fn weight_bytes(&self) -> (usize, usize) {
        let mut sd8 = 0usize;
        for l in &self.layers {
            sd8 += l.fwd.wx.storage_bytes() + l.fwd.wh.storage_bytes();
            if let Some(b) = &l.bwd {
                sd8 += b.wx.storage_bytes() + b.wh.storage_bytes();
            }
        }
        sd8 += self.head.w.storage_bytes();
        (sd8, sd8 * 4)
    }
}

/// Build the `tiny` LM topology (embed → 1×LSTM → dense) from a
/// `.tensors` state written by aot.py / the coordinator.
pub fn build_tiny_from_params(bag: &ParamBag) -> Result<QLstmStack> {
    let (esh, emb) = bag.f32(&["['params']['emb']['emb']"])?;
    let (vocab, dim) = (esh[0], esh[1]);
    let (_, wx) = bag.f32(&["['params']['l1']['wx']"])?;
    let (whs, wh) = bag.f32(&["['params']['l1']['wh']"])?;
    let (_, b) = bag.f32(&["['params']['l1']['b']"])?;
    let hidden = whs[0];
    let (_, ow) = bag.f32(&["['params']['out']['w']"])?;
    let (obs, ob) = bag.f32(&["['params']['out']['b']"])?;
    Ok(QLstmStack {
        embed: Embedding { vocab, dim, table: emb.to_vec() },
        layers: vec![QLstmLayer {
            fwd: QLstmCell::from_jax_layout(dim, hidden, &wx, &wh, &b),
            bwd: None,
        }],
        head: Dense::from_jax_layout(hidden, obs[0], &ow, &ob),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn rand_stack(vocab: usize, dim: usize, hidden: usize, out: usize, seed: u64) -> QLstmStack {
        let mut rng = SplitMix64::new(seed);
        let table: Vec<f32> = (0..vocab * dim).map(|_| rng.normal() * 0.1).collect();
        let wx: Vec<f32> = (0..dim * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let ow: Vec<f32> = (0..hidden * out).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let ob: Vec<f32> = (0..out).map(|_| rng.uniform(-0.1, 0.1)).collect();
        QLstmStack {
            embed: Embedding { vocab, dim, table },
            layers: vec![QLstmLayer {
                fwd: QLstmCell::from_jax_layout(dim, hidden, &wx, &wh, &b),
                bwd: None,
            }],
            head: Dense::from_jax_layout(hidden, out, &ow, &ob),
        }
    }

    #[test]
    fn forward_shapes() {
        let stack = rand_stack(16, 4, 8, 16, 1);
        let logits = stack.forward(&[1, 5, 3, 0, 15]);
        assert_eq!(logits.len(), 5);
        assert_eq!(logits[0].len(), 16);
    }

    #[test]
    fn bidirectional_layer_concats() {
        let mut rng = SplitMix64::new(3);
        let d = 4;
        let hdim = 6;
        let mk = |rng: &mut SplitMix64| {
            let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
            let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
            let b = vec![0.0; 4 * hdim];
            QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b)
        };
        let layer = QLstmLayer { fwd: mk(&mut rng), bwd: Some(mk(&mut rng)) };
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..d).map(|_| crate::formats::round_f8(rng.uniform(-1.0, 1.0))).collect()).collect();
        let out = layer.forward(&xs);
        assert_eq!(out[0].len(), 12);
        // perturbing the last input must not change fwd half at t=0
        let mut xs2 = xs.clone();
        xs2[4][0] = crate::formats::round_f8(xs[4][0] + 1.0);
        let out2 = layer.forward(&xs2);
        assert_eq!(out[0][..6], out2[0][..6], "fwd causal");
        assert_ne!(out[0][6..], out2[0][6..], "bwd anticausal");
    }

    #[test]
    fn weight_bytes_ratio_is_4x() {
        let stack = rand_stack(16, 4, 8, 16, 2);
        let (sd8, fp32) = stack.weight_bytes();
        assert_eq!(fp32, 4 * sd8);
    }

    #[test]
    fn embedding_output_on_fp8_grid() {
        let stack = rand_stack(16, 4, 8, 16, 4);
        let mut e = vec![0f32; 4];
        stack.embed.lookup_fp8(3, &mut e);
        for &v in &e {
            assert_eq!(v, crate::formats::round_f8(v));
        }
    }
}
