//! Model-level blocks of the inference engine: embedding, LSTM layers
//! (uni/bidirectional), dense heads, and a stack container that loads
//! weights from `.tensors` files (JAX pytree leaves written by aot.py
//! or checkpoints written by the coordinator).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::formats::{round_f16, round_f8};
use crate::qmath::vector::{matmul_fast, matvec_fast, QMatrix};
use crate::rng::SplitMix64;
use crate::tensorfile::Tensor;

use super::cell::{BatchScratch, CellScratch, QLstmCell};

/// Embedding table (kept in f32; its *outputs* are the paper's
/// first-layer activations and are FP8-quantized here).
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
    pub table: Vec<f32>,
}

impl Embedding {
    pub fn lookup_fp8(&self, id: usize, out: &mut [f32]) {
        assert!(id < self.vocab, "token id {id} out of range {}", self.vocab);
        let row = &self.table[id * self.dim..(id + 1) * self.dim];
        for (o, &v) in out.iter_mut().zip(row) {
            *o = round_f8(v);
        }
    }
}

/// Dense layer with FloatSD8 weights (out = W·x + b, FP16-chained).
pub struct Dense {
    pub w: QMatrix, // rows = out, cols = in
    pub bias: Vec<f32>,
}

impl Dense {
    /// From JAX layout `w [in][out]` row-major.
    pub fn from_jax_layout(in_dim: usize, out_dim: usize, w: &[f32], b: &[f32]) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        let mut t = vec![0f32; w.len()];
        for r in 0..in_dim {
            for c in 0..out_dim {
                t[c * in_dim + r] = w[r * out_dim + c];
            }
        }
        Dense {
            w: QMatrix::from_f32(out_dim, in_dim, &t),
            bias: b.iter().map(|&x| round_f16(x)).collect(),
        }
    }

    /// `x` must be on the FP8 grid already.
    pub fn forward(&self, x: &[f32], out: &mut [f32]) {
        matvec_fast(&self.w, x, &self.bias, out);
    }

    /// Select the forward-kernel tier for the weight matrix.
    pub fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.w.set_kernel_tier(tier);
    }

    /// Select the SIMD execution path for the weight matrix.
    pub fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.w.set_kernel_isa(isa);
    }
}

/// One (optionally bidirectional) quantized LSTM layer.
pub struct QLstmLayer {
    pub fwd: QLstmCell,
    pub bwd: Option<QLstmCell>,
}

impl QLstmLayer {
    pub fn out_dim(&self) -> usize {
        self.fwd.hidden * if self.bwd.is_some() { 2 } else { 1 }
    }

    /// Run over a sequence `xs [T][D]` (FP8 grid), producing `[T][out]`
    /// FP8 hidden activations (inter-layer activation quantization).
    pub fn forward(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let t_len = xs.len();
        let hdim = self.fwd.hidden;
        let odim = self.out_dim();
        let mut out = vec![vec![0f32; odim]; t_len];

        let mut h = vec![0f32; hdim];
        let mut c = vec![0f32; hdim];
        let mut scratch = CellScratch::new(hdim);
        for (t, x) in xs.iter().enumerate() {
            self.fwd.step(x, &mut h, &mut c, &mut scratch);
            out[t][..hdim].copy_from_slice(&h);
        }
        if let Some(bwd) = &self.bwd {
            let mut h = vec![0f32; hdim];
            let mut c = vec![0f32; hdim];
            let mut scratch = CellScratch::new(hdim);
            for (t, x) in xs.iter().enumerate().rev() {
                bwd.step(x, &mut h, &mut c, &mut scratch);
                out[t][hdim..].copy_from_slice(&h);
            }
        }
        out
    }
}

/// A named-parameter view over a `.tensors` file for model assembly.
pub struct ParamBag {
    tensors: HashMap<String, Tensor>,
}

impl ParamBag {
    pub fn from_tensors(tensors: Vec<Tensor>) -> Self {
        ParamBag { tensors: tensors.into_iter().map(|t| (t.name.clone(), t)).collect() }
    }

    /// Fetch an f32 tensor by trying several name spellings (JAX
    /// keystr paths look like `['params']['l1']['wx']`).
    pub fn f32(&self, keys: &[&str]) -> Result<(Vec<usize>, Vec<f32>)> {
        for k in keys {
            if let Some(t) = self.tensors.get(*k) {
                let data = t.as_f32().context("dtype")?;
                return Ok((t.shape.clone(), data));
            }
        }
        bail!(
            "none of {keys:?} found; have: {:?}",
            self.tensors.keys().take(8).collect::<Vec<_>>()
        )
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }
}

/// A generic quantized stack: embedding → LSTM layers → dense head.
/// Covers the pos/lm/tiny topologies (the examples and benches build
/// the nli/mt variants from the same blocks).
pub struct QLstmStack {
    pub embed: Embedding,
    pub layers: Vec<QLstmLayer>,
    pub head: Dense,
}

impl QLstmStack {
    /// Forward one token sequence → per-step logits `[T][n_out]`.
    pub fn forward(&self, ids: &[usize]) -> Vec<Vec<f32>> {
        let mut xs: Vec<Vec<f32>> = ids
            .iter()
            .map(|&id| {
                let mut e = vec![0f32; self.embed.dim];
                self.embed.lookup_fp8(id, &mut e);
                e
            })
            .collect();
        for layer in &self.layers {
            xs = layer.forward(&xs);
        }
        let n_out = self.head.w.rows;
        xs.iter()
            .map(|h| {
                let mut y = vec![0f32; n_out];
                self.head.forward(h, &mut y);
                y
            })
            .collect()
    }

    /// Output (logit) dimension of the dense head.
    pub fn n_out(&self) -> usize {
        self.head.w.rows
    }

    /// Select the forward-kernel tier for every weight matrix in the
    /// stack (all LSTM cells, both directions, plus the dense head).
    /// Tiers are a runtime choice — they never enter checkpoints.
    pub fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        for layer in &mut self.layers {
            layer.fwd.set_kernel_tier(tier);
            if let Some(bwd) = &mut layer.bwd {
                bwd.set_kernel_tier(tier);
            }
        }
        self.head.set_kernel_tier(tier);
    }

    /// The stack's active forward-kernel tier ([`set_kernel_tier`]
    /// sets every matrix uniformly; the head is the representative) —
    /// the observability label serve stats and traces report.
    ///
    /// [`set_kernel_tier`]: Self::set_kernel_tier
    pub fn kernel_tier(&self) -> crate::qmath::KernelTier {
        self.head.w.kernel_tier()
    }

    /// Select the SIMD execution path for every weight matrix in the
    /// stack (all LSTM cells, both directions, plus the dense head).
    /// Like tiers, the ISA is a runtime choice — it never enters
    /// checkpoints, and every path is bit-identical
    /// ([`crate::qmath::simd`]).
    pub fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        for layer in &mut self.layers {
            layer.fwd.set_kernel_isa(isa);
            if let Some(bwd) = &mut layer.bwd {
                bwd.set_kernel_isa(isa);
            }
        }
        self.head.set_kernel_isa(isa);
    }

    /// The stack's active SIMD execution path ([`set_kernel_isa`] sets
    /// every matrix uniformly; the head is the representative).
    ///
    /// [`set_kernel_isa`]: Self::set_kernel_isa
    pub fn kernel_isa(&self) -> crate::qmath::IsaPath {
        self.head.w.kernel_isa()
    }

    /// True when every layer is forward-only — the precondition for
    /// incremental (token-at-a-time) streaming and thus for serving.
    pub fn is_unidirectional(&self) -> bool {
        self.layers.iter().all(|l| l.bwd.is_none())
    }

    /// Hidden size of each layer, in order.
    pub fn hidden_dims(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.fwd.hidden).collect()
    }

    /// Fresh zeroed per-stream recurrent state (one `(h, c)` pair per
    /// layer), ready for [`Self::step_batch`] via
    /// [`StackScratch::load_state`].
    pub fn new_stream_state(&self) -> StreamState {
        StreamState {
            h: self.layers.iter().map(|l| vec![0f32; l.fwd.hidden]).collect(),
            c: self.layers.iter().map(|l| vec![0f32; l.fwd.hidden]).collect(),
        }
    }

    /// Build the reusable flat scratch for batched stepping (sized for
    /// `max_batch` streams; grows on demand).
    pub fn scratch(&self, max_batch: usize) -> StackScratch {
        let max_batch = max_batch.max(1);
        let mut width = self.embed.dim;
        for l in &self.layers {
            width = width.max(l.fwd.hidden);
        }
        StackScratch {
            batch_cap: max_batch,
            hs: self.layers.iter().map(|l| vec![0f32; max_batch * l.fwd.hidden]).collect(),
            cs: self.layers.iter().map(|l| vec![0f32; max_batch * l.fwd.hidden]).collect(),
            logits: vec![0f32; max_batch * self.n_out()],
            x: vec![0f32; max_batch * width],
            width,
            cells: self
                .layers
                .iter()
                .map(|l| BatchScratch::new(l.fwd.hidden, max_batch))
                .collect(),
        }
    }

    /// Advance `ids.len()` independent streams by **one token each**.
    ///
    /// The streams' recurrent state lives flat in `scratch.hs`/`scratch.cs`
    /// (stream-major, `[b*H .. (b+1)*H]` per stream — use
    /// [`StackScratch::load_state`]/[`StackScratch::store_state`] to move
    /// per-session state in and out). Logits land in
    /// `scratch.logits[b*n_out ..]`. Unidirectional stacks only.
    ///
    /// Batching contract: outputs and post-states are **bit-identical**
    /// to stepping each stream alone (`batch = 1`), which in turn is
    /// bit-identical to the sequential [`Self::forward`] path — pinned
    /// by `tests/batched_equivalence.rs`.
    pub fn step_batch(&self, ids: &[usize], scratch: &mut StackScratch) {
        let batch = ids.len();
        assert!(
            self.is_unidirectional(),
            "step_batch: bidirectional layers cannot stream token-at-a-time"
        );
        scratch.ensure(self, batch);
        let StackScratch { hs, cs, logits, x, width, cells, .. } = scratch;

        // embed → FP8 first-layer activations, gathered flat
        let dim = self.embed.dim;
        for (b, &id) in ids.iter().enumerate() {
            self.embed.lookup_fp8(id, &mut x[b * dim..(b + 1) * dim]);
        }

        // LSTM layers: x (flat [B*in]) → h (flat [B*H]), then h becomes
        // the next layer's input (inter-layer activations are already
        // on the FP8 grid — h is produced by round_f8).
        let mut in_dim = dim;
        for (l, layer) in self.layers.iter().enumerate() {
            let hdim = layer.fwd.hidden;
            layer.fwd.step_batch(
                &x[..batch * in_dim],
                &mut hs[l][..batch * hdim],
                &mut cs[l][..batch * hdim],
                batch,
                &mut cells[l],
            );
            x[..batch * hdim].copy_from_slice(&hs[l][..batch * hdim]);
            in_dim = hdim;
        }
        debug_assert!(in_dim <= *width);

        // dense head over the last layer's hidden state
        let n_out = self.n_out();
        matmul_fast(
            &self.head.w,
            &x[..batch * in_dim],
            batch,
            &self.head.bias,
            &mut logits[..batch * n_out],
        );
    }

    /// Sequential (unbatched) forward over `ids`, continuing from —
    /// and advancing — a carried per-stream state: [`Self::forward`]
    /// generalized to a non-zero starting state. This is the reference
    /// engine for serving's prefill and decode loops: the batched
    /// paths must be bit-identical to it whatever micro-batch a token
    /// rides in. Unidirectional stacks only.
    pub fn forward_from(&self, ids: &[usize], state: &mut StreamState) -> Vec<Vec<f32>> {
        assert!(
            self.is_unidirectional(),
            "forward_from: bidirectional layers cannot stream token-at-a-time"
        );
        assert_eq!(state.h.len(), self.layers.len(), "state/stack layer mismatch");
        let n_out = self.n_out();
        let mut scratches: Vec<CellScratch> =
            self.layers.iter().map(|l| CellScratch::new(l.fwd.hidden)).collect();
        let mut width = self.embed.dim;
        for l in &self.layers {
            width = width.max(l.fwd.hidden);
        }
        let mut x = vec![0f32; width];
        let mut out = Vec::with_capacity(ids.len());
        for &id in ids {
            self.embed.lookup_fp8(id, &mut x[..self.embed.dim]);
            let mut in_dim = self.embed.dim;
            for (l, layer) in self.layers.iter().enumerate() {
                let hdim = layer.fwd.hidden;
                layer.fwd.step(&x[..in_dim], &mut state.h[l], &mut state.c[l], &mut scratches[l]);
                x[..hdim].copy_from_slice(&state.h[l]);
                in_dim = hdim;
            }
            let mut y = vec![0f32; n_out];
            self.head.forward(&x[..in_dim], &mut y);
            out.push(y);
        }
        out
    }

    /// Forward `seqs.len()` full (possibly ragged) sequences in
    /// lockstep micro-batches, returning per-sequence logit series
    /// `[T_i][n_out]` — the offline counterpart of the serving loop,
    /// bit-identical to calling [`Self::forward`] on each sequence.
    pub fn forward_batch(&self, seqs: &[&[usize]]) -> Vec<Vec<Vec<f32>>> {
        let n = seqs.len();
        let n_out = self.n_out();
        let mut states: Vec<StreamState> = (0..n).map(|_| self.new_stream_state()).collect();
        let mut scratch = self.scratch(n);
        let mut out: Vec<Vec<Vec<f32>>> =
            seqs.iter().map(|s| Vec::with_capacity(s.len())).collect();
        let t_max = seqs.iter().map(|s| s.len()).max().unwrap_or(0);

        let mut ids = Vec::with_capacity(n);
        let mut active = Vec::with_capacity(n);
        for t in 0..t_max {
            ids.clear();
            active.clear();
            for (i, s) in seqs.iter().enumerate() {
                if t < s.len() {
                    active.push(i);
                    ids.push(s[t]);
                }
            }
            for (slot, &i) in active.iter().enumerate() {
                scratch.load_state(slot, &states[i]);
            }
            self.step_batch(&ids, &mut scratch);
            for (slot, &i) in active.iter().enumerate() {
                scratch.store_state(slot, &mut states[i]);
                out[i].push(scratch.logits[slot * n_out..(slot + 1) * n_out].to_vec());
            }
        }
        out
    }

    /// Total weight storage in bytes with FloatSD8 packing (the paper's
    /// memory-footprint argument) vs FP32.
    pub fn weight_bytes(&self) -> (usize, usize) {
        let mut sd8 = 0usize;
        for l in &self.layers {
            sd8 += l.fwd.wx.storage_bytes() + l.fwd.wh.storage_bytes();
            if let Some(b) = &l.bwd {
                sd8 += b.wx.storage_bytes() + b.wh.storage_bytes();
            }
        }
        sd8 += self.head.w.storage_bytes();
        (sd8, sd8 * 4)
    }
}

/// Per-stream (per serving session) recurrent state: one `(h, c)` pair
/// per layer, h on the FP8 grid, c on the FP16 grid. Small enough to
/// copy in and out of the flat batch slots each scheduled step — state
/// movement is O(H) per layer while the step itself is O(H²).
#[derive(Clone, Debug, Default)]
pub struct StreamState {
    pub h: Vec<Vec<f32>>,
    pub c: Vec<Vec<f32>>,
}

/// Reusable flat buffers for [`QLstmStack::step_batch`] — gathered
/// state slots, layer pre-activations, and logits. One per worker
/// thread; nothing allocates in the steady-state serving loop.
pub struct StackScratch {
    batch_cap: usize,
    /// per-layer flat h state, stream-major (`[b*H .. (b+1)*H]`)
    pub hs: Vec<Vec<f32>>,
    /// per-layer flat c state, stream-major
    pub cs: Vec<Vec<f32>>,
    /// flat logits of the last `step_batch`, `[b*n_out .. (b+1)*n_out]`
    pub logits: Vec<f32>,
    x: Vec<f32>,
    width: usize,
    cells: Vec<BatchScratch>,
}

impl StackScratch {
    fn ensure(&mut self, stack: &QLstmStack, batch: usize) {
        if batch <= self.batch_cap {
            return;
        }
        self.batch_cap = batch;
        for (l, layer) in stack.layers.iter().enumerate() {
            self.hs[l].resize(batch * layer.fwd.hidden, 0.0);
            self.cs[l].resize(batch * layer.fwd.hidden, 0.0);
        }
        self.logits.resize(batch * stack.n_out(), 0.0);
        self.x.resize(batch * self.width, 0.0);
    }

    /// Copy a stream's state into batch slot `slot` before stepping.
    pub fn load_state(&mut self, slot: usize, st: &StreamState) {
        for (l, h) in st.h.iter().enumerate() {
            let hd = h.len();
            self.hs[l][slot * hd..(slot + 1) * hd].copy_from_slice(h);
            self.cs[l][slot * hd..(slot + 1) * hd].copy_from_slice(&st.c[l]);
        }
    }

    /// Copy batch slot `slot` back into a stream's state after stepping.
    pub fn store_state(&self, slot: usize, st: &mut StreamState) {
        for (l, h) in st.h.iter_mut().enumerate() {
            let hd = h.len();
            h.copy_from_slice(&self.hs[l][slot * hd..(slot + 1) * hd]);
            st.c[l].copy_from_slice(&self.cs[l][slot * hd..(slot + 1) * hd]);
        }
    }

    /// Zero every state slot (fresh streams in every slot — bench use).
    pub fn reset_states(&mut self) {
        for v in self.hs.iter_mut().chain(self.cs.iter_mut()) {
            v.fill(0.0);
        }
    }
}

/// Build a deterministic randomly-initialized quantized stack — the
/// self-contained model behind the `serve` demo, the serving benches,
/// and the batched-equivalence tests (no checkpoint required).
pub fn synthetic_stack(
    vocab: usize,
    dim: usize,
    hidden: usize,
    n_layers: usize,
    n_out: usize,
    seed: u64,
) -> QLstmStack {
    let mut rng = SplitMix64::new(seed);
    let table: Vec<f32> = (0..vocab * dim).map(|_| rng.normal() * 0.1).collect();
    let mut layers = Vec::with_capacity(n_layers);
    let mut in_dim = dim;
    for _ in 0..n_layers.max(1) {
        let wx: Vec<f32> = (0..in_dim * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
        layers.push(QLstmLayer {
            fwd: QLstmCell::from_jax_layout(in_dim, hidden, &wx, &wh, &b),
            bwd: None,
        });
        in_dim = hidden;
    }
    let ow: Vec<f32> = (0..hidden * n_out).map(|_| rng.uniform(-0.3, 0.3)).collect();
    let ob: Vec<f32> = (0..n_out).map(|_| rng.uniform(-0.1, 0.1)).collect();
    QLstmStack {
        embed: Embedding { vocab, dim, table },
        layers,
        head: Dense::from_jax_layout(hidden, n_out, &ow, &ob),
    }
}

/// JAX-keystr parameter name, optionally under a sub-tree prefix
/// (`"enc"`/`"dec"` for the seq2seq pair; `""` for single-stack
/// models). The one spelling shared by the checkpoint writers
/// ([`crate::tasks`]) and the loaders below.
pub fn param_key(prefix: &str, rest: &str) -> String {
    if prefix.is_empty() {
        format!("['params']{rest}")
    } else {
        format!("['params']['{prefix}']{rest}")
    }
}

/// Build one stack topology (embed → N×LSTM → dense) from the
/// `.tensors` sub-tree under `prefix` — `""` for the historical
/// single-stack layout, `"enc"`/`"dec"` for the translation head's
/// encoder/decoder pair. Layer params are named `l1..lN`; `l1` is
/// required, further layers are loaded while present.
pub fn build_stack_from_params(bag: &ParamBag, prefix: &str) -> Result<QLstmStack> {
    let (esh, emb) = bag.f32(&[param_key(prefix, "['emb']['emb']").as_str()])?;
    if esh.len() != 2 {
        bail!("embedding under prefix {prefix:?} must be rank 2, got {esh:?}");
    }
    let (vocab, dim) = (esh[0], esh[1]);
    let mut layers = Vec::new();
    let mut in_dim = dim;
    for l in 1usize.. {
        let wx_key = param_key(prefix, &format!("['l{l}']['wx']"));
        if l > 1 && bag.f32(&[wx_key.as_str()]).is_err() {
            break;
        }
        let (_, wx) = bag.f32(&[wx_key.as_str()])?;
        let wh_key = param_key(prefix, &format!("['l{l}']['wh']"));
        let (whs, wh) = bag.f32(&[wh_key.as_str()])?;
        let b_key = param_key(prefix, &format!("['l{l}']['b']"));
        let (_, b) = bag.f32(&[b_key.as_str()])?;
        let hidden = whs[0];
        layers.push(QLstmLayer {
            fwd: QLstmCell::from_jax_layout(in_dim, hidden, &wx, &wh, &b),
            bwd: None,
        });
        in_dim = hidden;
    }
    let (_, ow) = bag.f32(&[param_key(prefix, "['out']['w']").as_str()])?;
    let (obs, ob) = bag.f32(&[param_key(prefix, "['out']['b']").as_str()])?;
    Ok(QLstmStack {
        embed: Embedding { vocab, dim, table: emb.to_vec() },
        layers,
        head: Dense::from_jax_layout(in_dim, obs[0], &ow, &ob),
    })
}

/// Build the LM topology from a `.tensors` state written by aot.py,
/// the coordinator, or the offline trainers' checkpoints — the
/// unprefixed single-stack case of [`build_stack_from_params`] (the
/// historical `tiny` topology is the 1-layer instance).
pub fn build_tiny_from_params(bag: &ParamBag) -> Result<QLstmStack> {
    build_stack_from_params(bag, "")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn rand_stack(vocab: usize, dim: usize, hidden: usize, out: usize, seed: u64) -> QLstmStack {
        let mut rng = SplitMix64::new(seed);
        let table: Vec<f32> = (0..vocab * dim).map(|_| rng.normal() * 0.1).collect();
        let wx: Vec<f32> = (0..dim * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hidden * 4 * hidden).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hidden).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let ow: Vec<f32> = (0..hidden * out).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let ob: Vec<f32> = (0..out).map(|_| rng.uniform(-0.1, 0.1)).collect();
        QLstmStack {
            embed: Embedding { vocab, dim, table },
            layers: vec![QLstmLayer {
                fwd: QLstmCell::from_jax_layout(dim, hidden, &wx, &wh, &b),
                bwd: None,
            }],
            head: Dense::from_jax_layout(hidden, out, &ow, &ob),
        }
    }

    #[test]
    fn forward_shapes() {
        let stack = rand_stack(16, 4, 8, 16, 1);
        let logits = stack.forward(&[1, 5, 3, 0, 15]);
        assert_eq!(logits.len(), 5);
        assert_eq!(logits[0].len(), 16);
    }

    #[test]
    fn bidirectional_layer_concats() {
        let mut rng = SplitMix64::new(3);
        let d = 4;
        let hdim = 6;
        let mk = |rng: &mut SplitMix64| {
            let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
            let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.3, 0.3)).collect();
            let b = vec![0.0; 4 * hdim];
            QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b)
        };
        let layer = QLstmLayer { fwd: mk(&mut rng), bwd: Some(mk(&mut rng)) };
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..d).map(|_| crate::formats::round_f8(rng.uniform(-1.0, 1.0))).collect()).collect();
        let out = layer.forward(&xs);
        assert_eq!(out[0].len(), 12);
        // perturbing the last input must not change fwd half at t=0
        let mut xs2 = xs.clone();
        xs2[4][0] = crate::formats::round_f8(xs[4][0] + 1.0);
        let out2 = layer.forward(&xs2);
        assert_eq!(out[0][..6], out2[0][..6], "fwd causal");
        assert_ne!(out[0][6..], out2[0][6..], "bwd anticausal");
    }

    #[test]
    fn forward_from_matches_forward_and_carries_state() {
        let stack = synthetic_stack(24, 5, 7, 2, 11, 6);
        let seq = [1usize, 9, 3, 20, 7, 7];
        let want = stack.forward(&seq);
        let mut st = stack.new_stream_state();
        let got = stack.forward_from(&seq, &mut st);
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            for (a, b) in w.iter().zip(g) {
                assert_eq!(a.to_bits(), b.to_bits(), "forward_from diverged from forward");
            }
        }
        // split calls must carry state bit-exactly across the boundary
        let mut st2 = stack.new_stream_state();
        let mut split = stack.forward_from(&seq[..2], &mut st2);
        split.extend(stack.forward_from(&seq[2..], &mut st2));
        for (w, g) in want.iter().zip(&split) {
            for (a, b) in w.iter().zip(g) {
                assert_eq!(a.to_bits(), b.to_bits(), "carried state diverged");
            }
        }
    }

    #[test]
    fn weight_bytes_ratio_is_4x() {
        let stack = rand_stack(16, 4, 8, 16, 2);
        let (sd8, fp32) = stack.weight_bytes();
        assert_eq!(fp32, 4 * sd8);
    }

    #[test]
    fn embedding_output_on_fp8_grid() {
        let stack = rand_stack(16, 4, 8, 16, 4);
        let mut e = vec![0f32; 4];
        stack.embed.lookup_fp8(3, &mut e);
        for &v in &e {
            assert_eq!(v, crate::formats::round_f8(v));
        }
    }
}
