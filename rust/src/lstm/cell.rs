//! The quantized LSTM cell (paper Eq. 1-6 under the §III scheme).
//!
//! Numerics contract (all pinned by tests):
//!
//! * matmuls: FP8 inputs × FloatSD8 weights, exact 4-group sums, one
//!   FP16 rounding per group (`qmath::vector::matvec_fast` ==
//!   `hardware::MacPipeline` bit-for-bit);
//! * gates f/i/o: two-region FloatSD8 sigmoid (Eq. 7/8);
//! * cell gate g and tanh(c): FP8-quantized tanh;
//! * cell state: `c = round_f16(f·c + i·g)` with the two products exact
//!   in f32 (≤ 11+11 significant bits) and the sum rounded at f32 then
//!   f16 — byte-identical to the L2 JAX graph (see ref.ref_lstm_gates);
//! * output: `h = round_f8(o · tanh_q(c))`.

use crate::formats::{round_f16, round_f8};
use crate::qmath::qsigmoid::{sigmoid_sd8, tanh_fp8};
use crate::qmath::vector::{matmul_fast_with, matvec_fast, MatmulScratch, QMatrix};

/// Gate packing order within the fused weight matrices (must match
/// `python/compile/lstm.py`: f, i, o, g).
pub const GATE_ORDER: [&str; 4] = ["f", "i", "o", "g"];

/// A quantized LSTM cell: fused weights `wx [4H][D]`, `wh [4H][H]`
/// (row-major, one row per output unit — transposed vs the JAX layout,
/// which is column-major `[D][4H]`; the loader handles the transpose).
pub struct QLstmCell {
    pub input_dim: usize,
    pub hidden: usize,
    pub wx: QMatrix,
    pub wh: QMatrix,
    /// bias on the FP16 grid
    pub bias: Vec<f32>,
}

/// Scratch buffers reused across time steps (no allocation in the hot
/// loop).
pub struct CellScratch {
    zx: Vec<f32>,
    zh: Vec<f32>,
    zero_bias: Vec<f32>,
}

impl CellScratch {
    pub fn new(hidden: usize) -> Self {
        CellScratch {
            zx: vec![0.0; 4 * hidden],
            zh: vec![0.0; 4 * hidden],
            zero_bias: vec![0.0; 4 * hidden],
        }
    }
}

/// Flat scratch for the batched step: pre-activations for up to
/// `max_batch` streams, reused across time steps — the serving hot
/// loop allocates nothing per token. The tape-recording training
/// forward (`crate::train::tape`) reuses this same scratch, hence the
/// `pub(crate)` internals.
pub struct BatchScratch {
    pub(crate) hidden: usize,
    pub(crate) zx: Vec<f32>,
    pub(crate) zh: Vec<f32>,
    pub(crate) zero_bias: Vec<f32>,
    /// matmul-kernel scratch (the shift-add tier's batch-wide
    /// activation decomposition) threaded through every step so the
    /// buffer is reused across time steps instead of bouncing on a
    /// thread-local
    pub(crate) mm: MatmulScratch,
}

impl BatchScratch {
    pub fn new(hidden: usize, max_batch: usize) -> Self {
        BatchScratch {
            hidden,
            zx: vec![0.0; max_batch.max(1) * 4 * hidden],
            zh: vec![0.0; max_batch.max(1) * 4 * hidden],
            zero_bias: vec![0.0; 4 * hidden],
            mm: MatmulScratch::new(),
        }
    }

    pub(crate) fn ensure(&mut self, batch: usize) {
        let need = batch * 4 * self.hidden;
        if self.zx.len() < need {
            self.zx.resize(need, 0.0);
            self.zh.resize(need, 0.0);
        }
    }
}

impl QLstmCell {
    /// Build from f32 weights in the **JAX layout**: `wx [D][4H]`
    /// col-major-for-us (i.e. `wx_jax[d][j]` = weight from input d to
    /// unit j), quantizing to FloatSD8.
    pub fn from_jax_layout(
        input_dim: usize,
        hidden: usize,
        wx_jax: &[f32], // D x 4H row-major
        wh_jax: &[f32], // H x 4H row-major
        bias: &[f32],   // 4H
    ) -> Self {
        assert_eq!(wx_jax.len(), input_dim * 4 * hidden);
        assert_eq!(wh_jax.len(), hidden * 4 * hidden);
        assert_eq!(bias.len(), 4 * hidden);
        let transpose = |src: &[f32], rows: usize, cols: usize| {
            // src is rows x cols; produce cols x rows (row-major)
            let mut t = vec![0f32; src.len()];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = src[r * cols + c];
                }
            }
            t
        };
        let wx_t = transpose(wx_jax, input_dim, 4 * hidden);
        let wh_t = transpose(wh_jax, hidden, 4 * hidden);
        QLstmCell {
            input_dim,
            hidden,
            wx: QMatrix::from_f32(4 * hidden, input_dim, &wx_t),
            wh: QMatrix::from_f32(4 * hidden, hidden, &wh_t),
            bias: bias.iter().map(|&b| round_f16(b)).collect(),
        }
    }

    /// Select the forward-kernel tier for both fused weight matrices
    /// (`decoded` multiply vs integer `shiftadd`; bit-identical — see
    /// [`crate::qmath::shiftadd`]).
    pub fn set_kernel_tier(&mut self, tier: crate::qmath::KernelTier) {
        self.wx.set_kernel_tier(tier);
        self.wh.set_kernel_tier(tier);
    }

    /// Select the SIMD execution path for both fused weight matrices
    /// (bit-identical across every path — see [`crate::qmath::simd`]).
    pub fn set_kernel_isa(&mut self, isa: crate::qmath::IsaPath) {
        self.wx.set_kernel_isa(isa);
        self.wh.set_kernel_isa(isa);
    }

    /// One time step. `x` must already be on the FP8 grid (the caller
    /// quantizes embeddings / inter-layer activations); `h`/`c` are the
    /// recurrent state (h on FP8, c on FP16 — maintained by this fn).
    pub fn step(
        &self,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
        scratch: &mut CellScratch,
    ) {
        let hdim = self.hidden;
        debug_assert_eq!(x.len(), self.input_dim);
        debug_assert_eq!(h.len(), hdim);

        // z = round_chain(wx·x) + round_chain(wh·h) + b   (Eq. 1-4 fused)
        matvec_fast(&self.wx, x, &self.bias, &mut scratch.zx);
        matvec_fast(&self.wh, h, &scratch.zero_bias, &mut scratch.zh);

        self.gates_inplace(&scratch.zx, &scratch.zh, h, c);
    }

    /// One time step for `batch` independent streams at once, all
    /// buffers flat: `xs [B*D]`, `hs`/`cs [B*H]` (stream-major). The
    /// matmuls go through the weight-stationary
    /// [`matmul_fast`](crate::qmath::vector::matmul_fast) so each
    /// decoded weight row is streamed once per batch; the per-unit gate
    /// math is the *same code* as [`Self::step`] — outputs are
    /// bit-identical to `batch` independent `step` calls.
    pub fn step_batch(
        &self,
        xs: &[f32],
        hs: &mut [f32],
        cs: &mut [f32],
        batch: usize,
        scratch: &mut BatchScratch,
    ) {
        let hdim = self.hidden;
        assert_eq!(scratch.hidden, hdim, "scratch built for a different hidden size");
        assert_eq!(xs.len(), batch * self.input_dim);
        assert_eq!(hs.len(), batch * hdim);
        assert_eq!(cs.len(), batch * hdim);
        scratch.ensure(batch);
        let BatchScratch { zx, zh, zero_bias, mm, .. } = scratch;

        matmul_fast_with(&self.wx, xs, batch, &self.bias, &mut zx[..batch * 4 * hdim], mm);
        matmul_fast_with(&self.wh, hs, batch, zero_bias, &mut zh[..batch * 4 * hdim], mm);

        for b in 0..batch {
            self.gates_inplace(
                &zx[b * 4 * hdim..(b + 1) * 4 * hdim],
                &zh[b * 4 * hdim..(b + 1) * 4 * hdim],
                &mut hs[b * hdim..(b + 1) * hdim],
                &mut cs[b * hdim..(b + 1) * hdim],
            );
        }
    }

    /// The per-unit gate/state update shared by [`Self::step`] and
    /// [`Self::step_batch`] — single source of truth for the Eq. 5/6
    /// numerics, which is what makes the two paths bit-identical.
    /// `pub(crate)` so the tape-recording training forward
    /// (`crate::train::tape`) drives the *same* kernel and stays
    /// bit-identical to inference by construction.
    #[inline]
    pub(crate) fn gates_inplace(&self, zx: &[f32], zh: &[f32], h: &mut [f32], c: &mut [f32]) {
        let hdim = self.hidden;
        for j in 0..hdim {
            // gate pre-activations (f32 add of two f16-grid values —
            // exact, both have ≤11-bit significands and close exponents
            // ... not exact in general; matches the L2 graph which also
            // adds the two matmul outputs in f32)
            let zf = zx[j] + zh[j];
            let zi = zx[hdim + j] + zh[hdim + j];
            let zo = zx[2 * hdim + j] + zh[2 * hdim + j];
            let zg = zx[3 * hdim + j] + zh[3 * hdim + j];

            let f = sigmoid_sd8(zf);
            let i = sigmoid_sd8(zi);
            let o = sigmoid_sd8(zo);
            let g = tanh_fp8(zg);

            // Eq. 5: FP16 cell-state accumulation (products exact in f32)
            let cj = round_f16(f * c[j] + i * g);
            c[j] = cj;
            // Eq. 6: FP8 output activation
            h[j] = round_f8(o * tanh_fp8(cj));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{round_f8, FLOAT_SD8};
    use crate::rng::SplitMix64;

    fn rand_cell(d: usize, hdim: usize, seed: u64) -> QLstmCell {
        let mut rng = SplitMix64::new(seed);
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let wh: Vec<f32> = (0..hdim * 4 * hdim).map(|_| rng.uniform(-0.4, 0.4)).collect();
        let b: Vec<f32> = (0..4 * hdim).map(|_| rng.uniform(-0.1, 0.1)).collect();
        QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b)
    }

    #[test]
    fn transpose_is_correct() {
        // wx_jax[d][j]: make a 2x8 (d=2, 4H=8 with H=2) pattern and
        // check the QMatrix row for unit j holds wx_jax[.][j].
        let d = 2;
        let hdim = 2;
        let wx: Vec<f32> = (0..d * 4 * hdim).map(|i| (i as f32) / 8.0).collect();
        let wh = vec![0.0; hdim * 4 * hdim];
        let b = vec![0.0; 4 * hdim];
        let cell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        for j in 0..4 * hdim {
            let row = cell.wx.row_decoded(j);
            for dd in 0..d {
                assert_eq!(row[dd], FLOAT_SD8.quantize(wx[dd * 4 * hdim + j]));
            }
        }
    }

    #[test]
    fn state_stays_on_grids() {
        let cell = rand_cell(6, 8, 1);
        let mut rng = SplitMix64::new(2);
        let mut h = vec![0.0f32; 8];
        let mut c = vec![0.0f32; 8];
        let mut scratch = CellScratch::new(8);
        for _ in 0..20 {
            let x: Vec<f32> = (0..6).map(|_| round_f8(rng.uniform(-2.0, 2.0))).collect();
            cell.step(&x, &mut h, &mut c, &mut scratch);
            for &v in &h {
                assert_eq!(v, round_f8(v), "h not on FP8 grid");
            }
            for &v in &c {
                assert_eq!(v, crate::formats::round_f16(v), "c not on FP16 grid");
            }
        }
    }

    #[test]
    fn forget_gate_saturation_preserves_memory_scale() {
        // With hugely positive forget-gate bias and zero input/cell
        // gates, c must persist exactly (f quantizes to 1.0 via Eq. 8).
        let d = 2;
        let hdim = 2;
        let wx = vec![0.0; d * 4 * hdim];
        let wh = vec![0.0; hdim * 4 * hdim];
        let mut b = vec![0.0; 4 * hdim];
        b[0] = 30.0; // f-gate unit 0
        b[1] = 30.0;
        b[hdim..2 * hdim].iter_mut().for_each(|v| *v = -30.0); // i = 0
        let cell = QLstmCell::from_jax_layout(d, hdim, &wx, &wh, &b);
        let mut h = vec![0.0; hdim];
        let mut c = vec![0.25, -1.5];
        let mut s = CellScratch::new(hdim);
        cell.step(&[0.0, 0.0], &mut h, &mut c, &mut s);
        assert_eq!(c, vec![0.25, -1.5], "perfect forget-gate memory");
    }

    #[test]
    fn deterministic() {
        let cell = rand_cell(4, 4, 7);
        let x = vec![0.5, -0.25, 1.0, 0.0];
        let run = || {
            let mut h = vec![0.0; 4];
            let mut c = vec![0.0; 4];
            let mut s = CellScratch::new(4);
            for _ in 0..5 {
                cell.step(&x, &mut h, &mut c, &mut s);
            }
            (h, c)
        };
        assert_eq!(run(), run());
    }
}
