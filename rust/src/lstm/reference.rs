//! FP32 reference engine — the paper's baseline arithmetic, same API
//! as the quantized engine (used by the ablation/throughput benches
//! and as the numerical anchor for quantization-error measurements).

/// Plain f32 LSTM cell with the same JAX weight layout as
/// [`super::cell::QLstmCell`].
pub struct F32LstmCell {
    pub input_dim: usize,
    pub hidden: usize,
    /// row-major [4H][D] (transposed at construction like the Q cell)
    pub wx: Vec<f32>,
    /// row-major [4H][H]
    pub wh: Vec<f32>,
    pub bias: Vec<f32>,
}

impl F32LstmCell {
    pub fn from_jax_layout(
        input_dim: usize,
        hidden: usize,
        wx_jax: &[f32],
        wh_jax: &[f32],
        bias: &[f32],
    ) -> Self {
        let transpose = |src: &[f32], rows: usize, cols: usize| {
            let mut t = vec![0f32; src.len()];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = src[r * cols + c];
                }
            }
            t
        };
        F32LstmCell {
            input_dim,
            hidden,
            wx: transpose(wx_jax, input_dim, 4 * hidden),
            wh: transpose(wh_jax, hidden, 4 * hidden),
            bias: bias.to_vec(),
        }
    }

    fn matvec(w: &[f32], rows: usize, cols: usize, x: &[f32], bias: &[f32], out: &mut [f32]) {
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let mut acc = bias[r];
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            out[r] = acc;
        }
    }

    pub fn step(&self, x: &[f32], h: &mut Vec<f32>, c: &mut Vec<f32>) {
        let hd = self.hidden;
        let mut zx = vec![0f32; 4 * hd];
        let mut zh = vec![0f32; 4 * hd];
        let zero = vec![0f32; 4 * hd];
        Self::matvec(&self.wx, 4 * hd, self.input_dim, x, &self.bias, &mut zx);
        Self::matvec(&self.wh, 4 * hd, hd, h, &zero, &mut zh);
        let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
        for j in 0..hd {
            let f = sigmoid(zx[j] + zh[j]);
            let i = sigmoid(zx[hd + j] + zh[hd + j]);
            let o = sigmoid(zx[2 * hd + j] + zh[2 * hd + j]);
            let g = (zx[3 * hd + j] + zh[3 * hd + j]).tanh();
            c[j] = f * c[j] + i * g;
            h[j] = o * c[j].tanh();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::cell::{CellScratch, QLstmCell};
    use crate::rng::SplitMix64;

    /// The quantized engine must track the FP32 reference closely on
    /// well-conditioned weights — the paper's entire premise. This is a
    /// sanity bound, not bit-exactness.
    #[test]
    fn quantized_tracks_reference() {
        let (d, hd) = (8, 16);
        let mut rng = SplitMix64::new(10);
        let wx: Vec<f32> = (0..d * 4 * hd).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hd * 4 * hd).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hd).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let qcell = QLstmCell::from_jax_layout(d, hd, &wx, &wh, &b);
        let rcell = F32LstmCell::from_jax_layout(d, hd, &wx, &wh, &b);

        let (mut qh, mut qc) = (vec![0f32; hd], vec![0f32; hd]);
        let (mut rh, mut rc) = (vec![0f32; hd], vec![0f32; hd]);
        let mut s = CellScratch::new(hd);
        let mut max_err = 0f32;
        for _ in 0..10 {
            let x: Vec<f32> =
                (0..d).map(|_| crate::formats::round_f8(rng.uniform(-1.0, 1.0))).collect();
            qcell.step(&x, &mut qh, &mut qc, &mut s);
            rcell.step(&x, &mut rh, &mut rc);
            for j in 0..hd {
                max_err = max_err.max((qh[j] - rh[j]).abs());
            }
        }
        assert!(max_err < 0.25, "quantized diverges from fp32: {max_err}");
        assert!(max_err > 0.0, "suspiciously exact — quantization inactive?");
    }
}
