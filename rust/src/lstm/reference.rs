//! FP32 reference engine — the paper's baseline arithmetic, same API
//! as the quantized engine (used by the ablation/throughput benches
//! and as the numerical anchor for quantization-error measurements).

/// Plain f32 LSTM cell with the same JAX weight layout as
/// [`super::cell::QLstmCell`].
pub struct F32LstmCell {
    pub input_dim: usize,
    pub hidden: usize,
    /// row-major [4H][D] (transposed at construction like the Q cell)
    pub wx: Vec<f32>,
    /// row-major [4H][H]
    pub wh: Vec<f32>,
    pub bias: Vec<f32>,
}

impl F32LstmCell {
    pub fn from_jax_layout(
        input_dim: usize,
        hidden: usize,
        wx_jax: &[f32],
        wh_jax: &[f32],
        bias: &[f32],
    ) -> Self {
        let transpose = |src: &[f32], rows: usize, cols: usize| {
            let mut t = vec![0f32; src.len()];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = src[r * cols + c];
                }
            }
            t
        };
        F32LstmCell {
            input_dim,
            hidden,
            wx: transpose(wx_jax, input_dim, 4 * hidden),
            wh: transpose(wh_jax, hidden, 4 * hidden),
            bias: bias.to_vec(),
        }
    }

    fn matvec(w: &[f32], rows: usize, cols: usize, x: &[f32], bias: &[f32], out: &mut [f32]) {
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let mut acc = bias[r];
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            out[r] = acc;
        }
    }

    pub fn step(&self, x: &[f32], h: &mut [f32], c: &mut [f32]) {
        let hd = self.hidden;
        let mut zx = vec![0f32; 4 * hd];
        let mut zh = vec![0f32; 4 * hd];
        let zero = vec![0f32; 4 * hd];
        Self::matvec(&self.wx, 4 * hd, self.input_dim, x, &self.bias, &mut zx);
        Self::matvec(&self.wh, 4 * hd, hd, h, &zero, &mut zh);
        let sigmoid = |v: f32| 1.0 / (1.0 + (-v).exp());
        for j in 0..hd {
            let f = sigmoid(zx[j] + zh[j]);
            let i = sigmoid(zx[hd + j] + zh[hd + j]);
            let o = sigmoid(zx[2 * hd + j] + zh[2 * hd + j]);
            let g = (zx[3 * hd + j] + zh[3 * hd + j]).tanh();
            c[j] = f * c[j] + i * g;
            h[j] = o * c[j].tanh();
        }
    }
}

/// The recorded forward of [`F32LstmCell::forward_traced`]: per step,
/// everything the analytic BPTT needs. Arithmetic is carried in f64 so
/// the tape is also usable as a finite-difference anchor (the
/// gradient-check test perturbs f32 weights but evaluates the loss in
/// f64, keeping FD noise far below the 1e-3 tolerance).
pub struct RefTape {
    pub xs: Vec<Vec<f64>>,
    pub h_prev: Vec<Vec<f64>>,
    pub c_prev: Vec<Vec<f64>>,
    /// fused gate pre-activations, `[4H]` per step (f/i/o/g packing)
    pub z: Vec<Vec<f64>>,
    pub c_new: Vec<Vec<f64>>,
    pub h_new: Vec<Vec<f64>>,
}

/// Analytic BPTT gradients of the reference cell (f64).
pub struct RefGrads {
    /// `[4H*D]` row-major — same layout as the cell's `wx`
    pub dwx: Vec<f64>,
    /// `[4H*H]` row-major
    pub dwh: Vec<f64>,
    pub db: Vec<f64>,
    /// per-step input cotangents
    pub dx: Vec<Vec<f64>>,
}

impl F32LstmCell {
    /// Full-precision traced forward from the zero state (f64
    /// arithmetic over the f32 weights). The training engine's
    /// quantized tape ([`crate::train::tape::CellTape`]) mirrors this
    /// structure; this one is the numerical anchor.
    pub fn forward_traced(&self, xs: &[Vec<f32>]) -> RefTape {
        let hd = self.hidden;
        let d = self.input_dim;
        let mut tape = RefTape {
            xs: Vec::new(),
            h_prev: Vec::new(),
            c_prev: Vec::new(),
            z: Vec::new(),
            c_new: Vec::new(),
            h_new: Vec::new(),
        };
        let mut h = vec![0f64; hd];
        let mut c = vec![0f64; hd];
        let sigmoid = |v: f64| 1.0 / (1.0 + (-v).exp());
        for x in xs {
            assert_eq!(x.len(), d);
            let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let mut z = vec![0f64; 4 * hd];
            for r in 0..4 * hd {
                let mut acc = self.bias[r] as f64;
                for (k, &xv) in x64.iter().enumerate() {
                    acc += self.wx[r * d + k] as f64 * xv;
                }
                for (k, &hv) in h.iter().enumerate() {
                    acc += self.wh[r * hd + k] as f64 * hv;
                }
                z[r] = acc;
            }
            tape.xs.push(x64);
            tape.h_prev.push(h.clone());
            tape.c_prev.push(c.clone());
            let mut h_new = vec![0f64; hd];
            let mut c_new = vec![0f64; hd];
            for j in 0..hd {
                let f = sigmoid(z[j]);
                let i = sigmoid(z[hd + j]);
                let o = sigmoid(z[2 * hd + j]);
                let g = z[3 * hd + j].tanh();
                c_new[j] = f * c[j] + i * g;
                h_new[j] = o * c_new[j].tanh();
            }
            tape.z.push(z);
            tape.c_new.push(c_new.clone());
            tape.h_new.push(h_new.clone());
            h = h_new;
            c = c_new;
        }
        tape
    }

    /// Analytic truncated-BPTT gradients: given per-step cotangents
    /// `dh_seq[t]` of the hidden outputs, accumulate `dwx`/`dwh`/`db`
    /// and return per-step input cotangents. This is the equation set
    /// the quantized backward in `train::backward` implements under
    /// the paper's quantization discipline; here it runs unquantized
    /// in f64 so it can be pinned against central finite differences
    /// (`tests/gradcheck.rs`).
    pub fn bptt(&self, tape: &RefTape, dh_seq: &[Vec<f64>]) -> RefGrads {
        let hd = self.hidden;
        let d = self.input_dim;
        let t_n = tape.z.len();
        assert_eq!(dh_seq.len(), t_n);
        let sigmoid = |v: f64| 1.0 / (1.0 + (-v).exp());
        let mut grads = RefGrads {
            dwx: vec![0f64; 4 * hd * d],
            dwh: vec![0f64; 4 * hd * hd],
            db: vec![0f64; 4 * hd],
            dx: (0..t_n).map(|_| vec![0f64; d]).collect(),
        };
        let mut dh_rec = vec![0f64; hd];
        let mut dc = vec![0f64; hd];
        let mut dz = vec![0f64; 4 * hd];
        for t in (0..t_n).rev() {
            let z = &tape.z[t];
            for j in 0..hd {
                let f = sigmoid(z[j]);
                let i = sigmoid(z[hd + j]);
                let o = sigmoid(z[2 * hd + j]);
                let g = z[3 * hd + j].tanh();
                let th_c = tape.c_new[t][j].tanh();
                let dh = dh_seq[t][j] + dh_rec[j];
                let d_o = dh * th_c;
                let dcj = dc[j] + dh * o * (1.0 - th_c * th_c);
                let df = dcj * tape.c_prev[t][j];
                let di = dcj * g;
                let dg = dcj * i;
                dc[j] = dcj * f;
                dz[j] = df * f * (1.0 - f);
                dz[hd + j] = di * i * (1.0 - i);
                dz[2 * hd + j] = d_o * o * (1.0 - o);
                dz[3 * hd + j] = dg * (1.0 - g * g);
            }
            for r in 0..4 * hd {
                let dzr = dz[r];
                grads.db[r] += dzr;
                for (k, &xv) in tape.xs[t].iter().enumerate() {
                    grads.dwx[r * d + k] += dzr * xv;
                }
                for (k, &hv) in tape.h_prev[t].iter().enumerate() {
                    grads.dwh[r * hd + k] += dzr * hv;
                }
            }
            for k in 0..d {
                let mut acc = 0f64;
                for r in 0..4 * hd {
                    acc += self.wx[r * d + k] as f64 * dz[r];
                }
                grads.dx[t][k] = acc;
            }
            for k in 0..hd {
                let mut acc = 0f64;
                for r in 0..4 * hd {
                    acc += self.wh[r * hd + k] as f64 * dz[r];
                }
                dh_rec[k] = acc;
            }
        }
        grads
    }
}

/// Full-precision dense head over hidden states (f32 parameters,
/// f64 arithmetic) + softmax cross-entropy — the reference for the
/// tagging/classification task heads (`tasks::pos` / `tasks::nli`),
/// anchored by finite differences in `tests/gradcheck.rs` exactly like
/// [`F32LstmCell::bptt`].
pub struct RefDense {
    pub in_dim: usize,
    pub n_out: usize,
    /// row-major `[n_out][in_dim]` (the QMatrix layout)
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl RefDense {
    /// Logits of one hidden state (f64).
    pub fn forward(&self, h: &[f64]) -> Vec<f64> {
        assert_eq!(h.len(), self.in_dim);
        (0..self.n_out)
            .map(|r| {
                let mut acc = self.b[r] as f64;
                for (k, &hv) in h.iter().enumerate() {
                    acc += self.w[r * self.in_dim + k] as f64 * hv;
                }
                acc
            })
            .collect()
    }

    /// Softmax cross-entropy of one logit row: `(loss, dlogits)`.
    pub fn ce(logits: &[f64], target: usize) -> (f64, Vec<f64>) {
        assert!(target < logits.len());
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let denom: f64 = logits.iter().map(|&v| (v - mx).exp()).sum();
        let loss = denom.ln() + mx - logits[target];
        let dlogits: Vec<f64> = logits
            .iter()
            .enumerate()
            .map(|(v, &lv)| {
                let p = (lv - mx).exp() / denom;
                p - if v == target { 1.0 } else { 0.0 }
            })
            .collect();
        (loss, dlogits)
    }

    /// Backward of [`Self::forward`]: accumulate `dw += dlogits ⊗ h`,
    /// `db += dlogits`, return `dh = Wᵀ·dlogits`.
    pub fn backward(
        &self,
        h: &[f64],
        dlogits: &[f64],
        dw: &mut [f64],
        db: &mut [f64],
    ) -> Vec<f64> {
        assert_eq!(h.len(), self.in_dim);
        assert_eq!(dlogits.len(), self.n_out);
        assert_eq!(dw.len(), self.n_out * self.in_dim);
        assert_eq!(db.len(), self.n_out);
        for (r, &dl) in dlogits.iter().enumerate() {
            db[r] += dl;
            for (k, &hv) in h.iter().enumerate() {
                dw[r * self.in_dim + k] += dl * hv;
            }
        }
        (0..self.in_dim)
            .map(|k| {
                let mut acc = 0f64;
                for (r, &dl) in dlogits.iter().enumerate() {
                    acc += self.w[r * self.in_dim + k] as f64 * dl;
                }
                acc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lstm::cell::{CellScratch, QLstmCell};
    use crate::rng::SplitMix64;

    /// The quantized engine must track the FP32 reference closely on
    /// well-conditioned weights — the paper's entire premise. This is a
    /// sanity bound, not bit-exactness.
    #[test]
    fn quantized_tracks_reference() {
        let (d, hd) = (8, 16);
        let mut rng = SplitMix64::new(10);
        let wx: Vec<f32> = (0..d * 4 * hd).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let wh: Vec<f32> = (0..hd * 4 * hd).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * hd).map(|_| rng.uniform(-0.1, 0.1)).collect();
        let qcell = QLstmCell::from_jax_layout(d, hd, &wx, &wh, &b);
        let rcell = F32LstmCell::from_jax_layout(d, hd, &wx, &wh, &b);

        let (mut qh, mut qc) = (vec![0f32; hd], vec![0f32; hd]);
        let (mut rh, mut rc) = (vec![0f32; hd], vec![0f32; hd]);
        let mut s = CellScratch::new(hd);
        let mut max_err = 0f32;
        for _ in 0..10 {
            let x: Vec<f32> =
                (0..d).map(|_| crate::formats::round_f8(rng.uniform(-1.0, 1.0))).collect();
            qcell.step(&x, &mut qh, &mut qc, &mut s);
            rcell.step(&x, &mut rh, &mut rc);
            for j in 0..hd {
                max_err = max_err.max((qh[j] - rh[j]).abs());
            }
        }
        assert!(max_err < 0.25, "quantized diverges from fp32: {max_err}");
        assert!(max_err > 0.0, "suspiciously exact — quantization inactive?");
    }
}
