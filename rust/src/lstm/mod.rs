//! Pure-rust quantized LSTM **inference engine** — the deployable
//! counterpart of the training stack: FloatSD8 weights (1 byte each),
//! FP8 activations, FP16 accumulation, quantized-σ gates. No python, no
//! XLA; this is what the paper's accelerator executes, in software.
//!
//! * [`cell`] — the quantized LSTM cell (Eq. 1-6 with §III quantizers),
//!   numerics aligned with the L2 JAX graph (golden-pinned) and with
//!   the Fig. 9 hardware unit (bit-exact cross-test);
//! * [`model`] — layers/stacks: embedding, (bi)LSTM layers, dense
//!   head; loads weights from `.tensors` checkpoints written by the
//!   coordinator;
//! * [`reference`] — the FP32 reference engine (the paper's baseline),
//!   same API, plain f32 arithmetic — plus the full-precision traced
//!   forward/BPTT pair that anchors the training engine's gradients
//!   (`tests/gradcheck.rs`).
//!
//! The training-side twins of the cell/stack forward passes
//! (`step_batch_traced`, `backward_batch`, …) live in [`crate::train`]
//! as inherent impls on the same types, sharing these kernels.

pub mod cell;
pub mod model;
pub mod reference;

pub use cell::QLstmCell;
pub use model::{
    synthetic_stack, Dense, Embedding, QLstmLayer, QLstmStack, StackScratch, StreamState,
};
