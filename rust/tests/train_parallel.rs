//! Shard-determinism pins for the lane-sharded parallel training
//! engine: `--threads N` must be **bit-identical** to `--threads 1` —
//! byte-identical checkpoints and bit-identical per-step loss traces
//! — for all four task heads and the char-LM trainer, including
//! thread counts that don't divide the lane count and thread counts
//! exceeding the shard count.
//!
//! Why this holds (and what would break it): the lane partition is a
//! pure function of the batch size, every kernel is per-stream
//! bit-identical, and the shard reduction is a fixed-order tree run
//! after all shards complete — see `rust/src/train/parallel.rs` docs.
//! Any accidental shared mutable state between shards, or any
//! thread-count-dependent fold order, shows up here as a one-bit
//! checkpoint diff.

use std::path::PathBuf;

use floatsd_lstm::tasks::{TaskConfig, TaskKind, TaskTrainer};
use floatsd_lstm::train::{lane_spans, PresetTier, TrainConfig, Trainer, LANE_SHARDS_MAX};

/// A miniature of each task with a deliberately awkward lane count:
/// batch 6 → six 1-lane shards, so `--threads 4` gets uneven chunks
/// and `--threads 7` has more threads than shards.
fn tiny_task_cfg(kind: TaskKind) -> TaskConfig {
    let mut cfg = TaskConfig::preset_tier(kind, PresetTier::Tiny);
    cfg.batch = 6;
    cfg.steps = 5;
    cfg.eval_batches = 2;
    cfg.log_every = 0;
    cfg.seed = 33;
    cfg
}

/// Train `steps` windows at a given thread count; return the per-step
/// loss bits and the checkpoint file bytes.
fn run_task(kind: TaskKind, threads: usize) -> (Vec<u64>, Vec<u8>) {
    let dir = std::env::temp_dir().join("fsd_train_parallel");
    std::fs::create_dir_all(&dir).unwrap();
    let path: PathBuf = dir.join(format!("{}_{}t.tensors", kind.name(), threads));
    let mut cfg = tiny_task_cfg(kind);
    cfg.threads = threads;
    cfg.checkpoint = Some(path.clone());
    let mut trainer = TaskTrainer::new(cfg).expect("valid task config");
    let report = trainer.train().expect("tiny training run");
    let bits: Vec<u64> = report.losses.iter().map(|l| l.to_bits()).collect();
    let bytes = std::fs::read(&path).expect("checkpoint written");
    (bits, bytes)
}

#[test]
fn all_four_tasks_are_bit_identical_across_thread_counts() {
    for kind in TaskKind::ALL {
        let (base_bits, base_bytes) = run_task(kind, 1);
        assert!(!base_bits.is_empty());
        for threads in [2usize, 4, 7] {
            let (bits, bytes) = run_task(kind, threads);
            assert_eq!(
                bits,
                base_bits,
                "{}: per-step loss trace diverged at --threads {threads}",
                kind.name()
            );
            assert_eq!(
                bytes,
                base_bytes,
                "{}: checkpoint bytes diverged at --threads {threads}",
                kind.name()
            );
        }
    }
}

#[test]
fn char_lm_trainer_is_bit_identical_across_thread_counts() {
    let run = |threads: usize| -> Vec<u64> {
        let mut cfg = TrainConfig::preset(PresetTier::Tiny);
        cfg.batch = 5; // five 1-lane shards: 2/4/7 threads all chunk unevenly
        cfg.steps = 8;
        cfg.seed = 9;
        cfg.log_every = 0;
        cfg.threads = threads;
        let mut t = Trainer::new(cfg).expect("valid config");
        t.train().expect("run").losses.iter().map(|l| l.to_bits()).collect()
    };
    let base = run(1);
    for threads in [2usize, 4, 7] {
        assert_eq!(run(threads), base, "char-LM loss trace diverged at --threads {threads}");
    }
}

/// The partition itself is a pure function of the batch size — if it
/// ever consults the thread count, the bit-identity contract is gone.
#[test]
fn lane_partition_depends_on_batch_only() {
    assert_eq!(lane_spans(1), vec![(0, 1)]);
    for batch in [2usize, 5, 6, 8, 11, 19] {
        let spans = lane_spans(batch);
        assert_eq!(spans.len(), batch.min(LANE_SHARDS_MAX), "batch {batch}");
        assert_eq!(spans.first().unwrap().0, 0);
        assert_eq!(spans.last().unwrap().1, batch);
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0, "batch {batch}: spans must tile contiguously");
        }
    }
}

/// Config ergonomics: degenerate `--threads` / shape values come back
/// as descriptive errors, not panics, from both trainer fronts.
#[test]
fn degenerate_training_configs_error_descriptively() {
    let mut cfg = tiny_task_cfg(TaskKind::Lm);
    cfg.threads = 0;
    let err = TaskTrainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("threads"), "got: {err}");

    let mut cfg = tiny_task_cfg(TaskKind::Mt);
    cfg.threads = 300;
    assert!(TaskTrainer::new(cfg).is_err(), "absurd thread counts must be refused");

    let mut cfg = TrainConfig::preset(PresetTier::Tiny);
    cfg.batch = 0;
    let err = Trainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("batch"), "got: {err}");

    assert!(PresetTier::parse("big").is_err());
    assert_eq!(PresetTier::parse("tiny").unwrap(), PresetTier::Tiny);
}
