//! Disabled-path overhead guard: with no [`TraceSink`] open, the
//! telemetry hot paths — the activation hooks and the metric
//! primitives — must not allocate. This test binary installs a
//! counting global allocator and holds exactly one test, so no
//! concurrent harness thread can pollute the count.
//!
//! [`TraceSink`]: floatsd_lstm::telemetry::TraceSink

use std::alloc::{GlobalAlloc, Layout, System};
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use floatsd_lstm::formats::round_sd8;
use floatsd_lstm::qmath::vector::{matmul_fast, matvec_fast, QMatrix};
use floatsd_lstm::qmath::KernelTier;
use floatsd_lstm::telemetry::{
    hot_enabled, note_sigmoid, note_tanh, Counter, Gauge, Histogram, SampleWindow,
};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_telemetry_hot_paths_do_not_allocate() {
    assert!(!hot_enabled(), "no sink is open in this process");

    // construct everything (and warm the lazily-built FloatSD8 tables)
    // before the measured window — only recording must be free
    let counter = Counter::new();
    let gauge = Gauge::new();
    let hist = Histogram::new(&[1, 2, 4, 8, 16]);
    let mut window = SampleWindow::new(64);
    for i in 0..80u64 {
        window.push(Duration::from_nanos(i));
    }
    black_box(round_sd8(0.123));

    // the gated kernel-profiling wrappers: with the sink closed, the
    // wrapper is one relaxed load + a branch around the kernel impl.
    // Build the matrices and output buffers up front and warm both
    // tiers before measuring (the shift-add tier builds thread-local
    // scratch on first use).
    let (rows, cols, batch) = (12usize, 8usize, 3usize);
    let data: Vec<f32> = (0..rows * cols).map(|i| (i as f32 - 40.0) * 0.01).collect();
    let mut w_dec = QMatrix::from_f32(rows, cols, &data);
    w_dec.set_kernel_tier(KernelTier::Decoded);
    let mut w_sa = QMatrix::from_f32(rows, cols, &data);
    w_sa.set_kernel_tier(KernelTier::ShiftAdd);
    let x: Vec<f32> = (0..cols).map(|i| 0.1 * i as f32).collect();
    let xs: Vec<f32> = (0..cols * batch).map(|i| 0.05 * i as f32).collect();
    let bias = vec![0.25f32; rows];
    let mut out = vec![0f32; rows];
    let mut outs = vec![0f32; rows * batch];
    for w in [&w_dec, &w_sa] {
        matvec_fast(w, &x, &bias, &mut out);
        matmul_fast(w, &xs, batch, &bias, &mut outs);
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        note_sigmoid(black_box(0.5));
        note_sigmoid(black_box(1.0));
        note_tanh(black_box(-1.0));
        counter.add(1);
        gauge.set(i);
        hist.record(i % 23);
        window.push(Duration::from_nanos(i));
    }
    for w in [&w_dec, &w_sa] {
        for _ in 0..100 {
            matvec_fast(black_box(w), &x, &bias, &mut out);
            matmul_fast(black_box(w), &xs, batch, &bias, &mut outs);
        }
    }
    black_box(&out);
    black_box(&outs);
    black_box(counter.get());
    black_box(gauge.get());
    black_box(hist.total());
    black_box(window.len());
    let after = ALLOCS.load(Ordering::Relaxed);

    assert_eq!(
        after - before,
        0,
        "telemetry hot paths allocated {} times with the sink closed",
        after - before
    );
    assert_eq!(counter.get(), 10_000);
    assert_eq!(hist.total(), 10_000);
    assert_eq!(window.len(), 64, "the sample ring must stay at capacity");
}
